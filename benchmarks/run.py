"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  fig56/*      — paper Figures 5/6: KG-creation wall time, engine vs baseline
                 (derived = naive/optimized speedup)
  opmodel/*    — §III.iv operation-count model (derived = φ̂/φ ratio)
  kernels/*    — Pallas kernel micro-benches vs jnp reference paths
  dedup/*      — dedup_gather traffic/time vs plain gather
  stream/*     — streamed vs eager ingestion (rows/s, peak traced alloc)
  kg/*         — repro.kg store build + batched single-pattern queries/s
  live/*       — repro.live write path, overlay queries vs delta fraction,
                 and compaction (writes BENCH_live.json)
  shard/*      — repro.shard routed vs scatter-all query cost at 1/2/4
                 shards vs the unsharded baseline (writes BENCH_shard.json)
  roofline/*   — (when results/dryrun.json exists) the three terms per cell

The ``stream`` and ``kg`` sections also write machine-readable
``BENCH_stream.json`` / ``BENCH_kg.json`` (to ``--json-dir``, default the
current directory) so the perf trajectory can be tracked across commits.

``--full`` widens fig56 to the paper's 1M-row tier.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _row(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def _write_json(json_dir: str, name: str, payload: dict) -> None:
    path = os.path.join(json_dir, name)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {path}", flush=True)


def bench_fig56(full: bool) -> None:
    from benchmarks import paper_figs

    sizes = (10_000, 100_000, 1_000_000) if full else (10_000, 100_000)
    n_poms = (1, 2, 4) if full else (1, 2)
    for kind in ("SOM", "ORM", "OJM"):
        for n in sizes:
            for dup in (0.25, 0.75):
                for npm in n_poms:
                    opt = paper_figs.run_cell(kind, n, dup, npm, "optimized", repeats=2)
                    nav = paper_figs.run_cell(kind, n, dup, npm, "naive", repeats=2)
                    name = f"fig56/{kind.lower()}{npm}-{n}-{int(dup*100)}"
                    if nav["status"] == "DNF":
                        _row(name, opt["time_s"] * 1e6, "naive=DNF")
                    else:
                        _row(
                            name, opt["time_s"] * 1e6,
                            f"speedup={nav['time_s']/opt['time_s']:.2f}x",
                        )
                    assert (
                        nav["status"] == "DNF"
                        or nav["n_triples"] == opt["n_triples"]
                    ), f"engine mismatch at {name}"


def bench_op_model() -> None:
    from benchmarks import op_model

    for r in op_model.run(sizes=(10_000,), dups=(0.25, 0.75)):
        _row(
            f"opmodel/{r['kind'].lower()}-{r['rows']}-{int(r['dup']*100)}",
            0.0,
            f"phi_ratio={r['ratio']:.1f}x",
        )


def bench_kernels() -> None:
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import hashing
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    n = 1 << 16
    words = jnp.asarray(rng.integers(0, 2**31, (3, n)).astype(np.int32))

    def timeit(fn, *a, repeats=5):
        jax.block_until_ready(fn(*a))
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*a))
            ts.append(time.perf_counter() - t0)
        return min(ts) * 1e6

    t_kernel = timeit(lambda w: ops.fused_hash_mix(w), words)
    t_ref = timeit(jax.jit(lambda w: hashing.mix64([w[0], w[1], w[2]])), words)
    _row("kernels/hash_mix_pallas", t_kernel, f"jnp_ref_us={t_ref:.1f}")

    vals = rng.integers(0, 5000, n).astype(np.int32)
    hi, lo = hashing.mix64([jnp.asarray(vals)])
    valid = jnp.ones(n, bool)

    table = ops.make_radix_table(4 * n, 8)
    t_radix = timeit(
        lambda h, l, v: ops.radix_dedup_insert(ops.make_radix_table(4 * n, 8), h, l, v)[1],
        hi, lo, valid,
    )
    from repro.core import hashset

    t_flat = timeit(
        jax.jit(lambda h, l, v: hashset.insert_masked(hashset.make(4 * n), h, l, v).is_new),
        hi, lo, valid,
    )
    _row("kernels/radix_dedup_pallas", t_radix, f"flat_hashset_us={t_flat:.1f}")

    pk = jnp.asarray(rng.integers(0, 128, 4096).astype(np.int32))
    ps = jnp.asarray(rng.integers(0, 10**6, 4096).astype(np.int32))
    ck = jnp.asarray(rng.integers(0, 128, 2048).astype(np.int32))
    K = int(np.bincount(np.asarray(pk)).max()) + 1
    t_join = timeit(lambda a, b, c: ops.blocked_nested_join(a, b, c, K)[0], pk, ps, ck)
    from repro.core import pjtt

    idx = pjtt.build_sorted(pk, ps)
    t_pjtt = timeit(
        jax.jit(lambda s, u, c: pjtt.probe_sorted(pjtt.PJTTSorted(s, u), c, K).subjects),
        idx.skeys, idx.ssubj, ck,
    )
    _row("kernels/nested_join_pallas", t_join, f"pjtt_index_join_us={t_pjtt:.1f}")


def bench_dedup_gather() -> None:
    from benchmarks import dedup_gather_bench

    for r in dedup_gather_bench.run(n=65_536, dup_factors=(1, 8, 64)):
        _row(
            f"dedup/x{r['dup_factor']}",
            r["t_dedup_s"] * 1e6,
            f"plain_us={r['t_plain_s']*1e6:.1f};traffic={r['traffic_saving']:.1f}x",
        )


_WIDE_TTL = """
@prefix rr: <http://www.w3.org/ns/r2rml#> .
@prefix rml: <http://semweb.mmlab.be/ns/rml#> .
@prefix ql: <http://semweb.mmlab.be/ns/ql#> .
@prefix ex: <http://example.com/> .
ex:Wide a rr:TriplesMap ;
  rml:logicalSource [ rml:source "wide.csv" ; rml:referenceFormulation ql:CSV ] ;
  rr:subjectMap [ rr:template "http://example.com/r/{C0}" ] ;
  rr:predicateObjectMap [ rr:predicate ex:p1 ; rr:objectMap [ rml:reference "C1" ] ] ;
  rr:predicateObjectMap [ rr:predicate ex:p2 ; rr:objectMap [ rml:reference "C2" ] ] ;
  rr:predicateObjectMap [ rr:predicate ex:p3 ; rr:objectMap [ rml:reference "C3" ] ] ;
  rr:predicateObjectMap [ rr:predicate ex:p4 ; rr:objectMap [ rml:reference "C4" ] ] ;
  rr:predicateObjectMap [ rr:predicate ex:p5 ; rr:objectMap [ rml:reference "C5" ] ] .
"""


def bench_stream(json_dir: str = ".") -> None:
    """Streaming vs eager ingestion over the generator's 10K/100K CSV
    testbeds: rows/s and peak traced allocation (tracemalloc covers numpy
    buffers; RSS is monotonic per process and useless for per-phase peaks).
    The streamed path reads + dictionary-encodes block-at-a-time, the eager
    path materializes the whole table first.  A second family of cells
    runs full streamed ``create_kg`` over a 40-column/6-mapped CSV with
    the mapping planner's projection pushdown on vs off — the MapSDI win
    condition: pruned columns are never accumulated, so rows/s rises and
    peak allocation falls.  Results also land in ``BENCH_stream.json``."""
    import tempfile
    import tracemalloc

    from repro.data.encoder import Dictionary
    from repro.data.sources import load_csv
    from repro.rml import generator
    from repro.stream import read_csv

    report: dict[str, dict] = {}
    for n in (10_000, 100_000):
        tb = generator.make_testbed("SOM", n, 0.75, n_poms=2, seed=0)
        with tempfile.TemporaryDirectory() as d:
            tb.write(d)
            path = os.path.join(d, "child.csv")
            cols = list(tb.child)

            def eager():
                dct = Dictionary()
                table = load_csv(path)
                for c in cols:
                    dct.encode(table[c])

            def streamed():
                dct = Dictionary()
                ds = read_csv(path, block_rows=1 << 13).encode(dct)
                for block in ds.iter_blocks():
                    assert block.n_rows > 0

            for name, fn in (("stream", streamed), ("eager", eager)):
                tracemalloc.start()
                t0 = time.perf_counter()
                fn()
                dt = time.perf_counter() - t0
                _, peak = tracemalloc.get_traced_memory()
                tracemalloc.stop()
                _row(
                    f"stream/{name}-{n}",
                    dt * 1e6,
                    f"rows_per_s={n / dt:.0f};peak_alloc_mb={peak / 1e6:.1f}",
                )
                report[f"{name}-{n}"] = {
                    "rows": n,
                    "wall_s": dt,
                    "rows_per_s": n / dt,
                    "peak_alloc_mb": peak / 1e6,
                }

    # ---- wide-source ingestion: 40 columns, 6 mapped, pushdown on/off
    from repro.core.executor import create_kg
    from repro.rml import parser as rml_parser

    n, n_cols = 40_000, 40
    doc = rml_parser.parse(_WIDE_TTL)
    with tempfile.TemporaryDirectory() as d:
        with open(os.path.join(d, "wide.csv"), "w") as f:
            f.write(",".join(f"C{j}" for j in range(n_cols)) + "\n")
            for i in range(n):
                f.write(",".join(f"v{i % 997}_{j}" for j in range(n_cols)) + "\n")
        create_kg(doc, data_root=d, stream=True)  # jit warmup, untimed
        for label, on in (("pushdown-off", False), ("pushdown-on", True)):
            tracemalloc.start()
            t0 = time.perf_counter()
            res = create_kg(doc, data_root=d, stream=True, mapping_plan=on)
            dt = time.perf_counter() - t0
            _, peak = tracemalloc.get_traced_memory()
            tracemalloc.stop()
            _row(
                f"stream/wide40x6-{label}",
                dt * 1e6,
                f"rows_per_s={n / dt:.0f};peak_alloc_mb={peak / 1e6:.1f}",
            )
            report[f"wide40x6-{label}"] = {
                "rows": n,
                "n_triples": res.n_triples,
                "wall_s": dt,
                "rows_per_s": n / dt,
                "peak_alloc_mb": peak / 1e6,
            }
    report["wide40x6-pushdown-speedup"] = round(
        report["wide40x6-pushdown-on"]["rows_per_s"]
        / report["wide40x6-pushdown-off"]["rows_per_s"],
        2,
    )
    _write_json(json_dir, "BENCH_stream.json", report)


def bench_kg(json_dir: str = ".") -> None:
    """The ``repro.kg`` serving benchmark on the paper's 100K-row testbed:
    KG creation -> ``to_store()`` (term re-key + three jax lexsorts) ->
    batched single-pattern queries/s through the jitted range-scan path.
    Writes ``BENCH_kg.json``."""
    import tracemalloc

    from repro.core.executor import create_kg
    from repro.kg.bench import bench_single_pattern
    from repro.rml import generator

    n = 100_000
    tb = generator.make_testbed("SOM", n, 0.75, n_poms=2, seed=0)
    tables = {"csv:child.csv": tb.child}
    if tb.parent is not None:
        tables["csv:parent.csv"] = tb.parent
    t0 = time.perf_counter()
    kg = create_kg(tb.doc, tables=tables)
    t_create = time.perf_counter() - t0
    tracemalloc.start()
    t0 = time.perf_counter()
    store = kg.to_store()
    t_build = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    report = bench_single_pattern(store, n_queries=50_000, batch=4096)
    report.update(
        {
            "testbed_rows": n,
            "create_s": t_create,
            "store_build_s": t_build,
            "store_build_peak_alloc_mb": peak / 1e6,
        }
    )
    _row(
        f"kg/build-{n}", t_build * 1e6,
        f"triples={store.n_triples};peak_alloc_mb={peak / 1e6:.1f}",
    )
    _row(
        f"kg/query-{n}",
        report["wall_s"] / report["n_queries"] * 1e6,
        f"queries_per_s={report['queries_per_s']:.0f};batch={report['batch']};"
        f"p99_ms={report['latency_p99_ms']:.3f}",
    )
    _write_json(json_dir, "BENCH_kg.json", report)


def bench_serve(json_dir: str = ".") -> None:
    """The ``repro.serve`` pipeline benchmark on the same 100K-row testbed
    store as the ``kg`` section (numbers directly comparable): end-to-end
    queries/s AND per-dispatch latency p50/p99 through the fused jitted
    executor for point lookups, a 3-pattern star BGP, an OPTIONAL+FILTER
    query, a 2-arm UNION, an ORDER BY DESC, and a GROUP BY-COUNT, each at
    batch sizes 1/64/4096 — plus the ``smallbatch`` section: the
    chain-eligible classes at batch 1/8/64 through the fused scan-join
    fast path.  Writes ``BENCH_serve.json`` (``queries_per_s``
    and ``latency_p99_ms`` gated in CI by ``benchmarks/compare.py``
    against the committed baseline — see ``benchmarks/README.md``) plus
    the run's dispatch trace (``TRACE_serve.json``, Perfetto-loadable)
    and metrics snapshot (``METRICS_serve.json``) as CI artifacts."""
    from repro import obs
    from repro.core.executor import create_kg
    from repro.rml import generator
    from repro.serve.bench import bench_serve as run_serve_bench

    n = 100_000
    tb = generator.make_testbed("SOM", n, 0.75, n_poms=2, seed=0)
    tables = {"csv:child.csv": tb.child}
    if tb.parent is not None:
        tables["csv:parent.csv"] = tb.parent
    store = create_kg(tb.doc, tables=tables).to_store()
    obs.enable_tracing()
    report = run_serve_bench(store)
    obs.get_tracer().disable()
    report["testbed_rows"] = n
    for name, cls in report["classes"].items():
        for batch, r in cls["batches"].items():
            _row(
                f"serve/{name}-b{batch}",
                r["wall_s"] / r["n_queries"] * 1e6,
                f"queries_per_s={r['queries_per_s']:.0f};"
                f"p50_ms={r['latency_p50_ms']:.3f};"
                f"p99_ms={r['latency_p99_ms']:.3f}",
            )
    # the interactive regime: per-dispatch tails through the fused
    # scan-join fast path at batch 1/8/64 (see repro.serve.fastpath)
    for name, cls in report["smallbatch"].items():
        for batch, r in cls["batches"].items():
            _row(
                f"serve/smallbatch-{name}-b{batch}",
                r["wall_s"] / r["n_queries"] * 1e6,
                f"p50_ms={r['latency_p50_ms']:.3f};"
                f"p99_ms={r['latency_p99_ms']:.3f};"
                f"fastpath={r['fastpath_dispatches']}",
            )
    _write_json(json_dir, "BENCH_serve.json", report)
    _write_json(json_dir, "TRACE_serve.json", obs.get_tracer().export())
    _write_json(json_dir, "METRICS_serve.json", obs.get_registry().snapshot())


def bench_live(json_dir: str = ".") -> None:
    """The ``repro.live`` mutable-store benchmark on a 20K-row testbed
    (small enough that the per-level overlay pipelines compile inside the
    CI budget): insert/delete rows/s through the overlay log, fused
    ``base ⊕ delta`` query throughput + latency at delta fractions
    0/1%/10%, and one compaction.  Writes ``BENCH_live.json``
    (``queries_per_s`` / ``latency_p99_ms`` gated by
    ``benchmarks/compare.py``)."""
    from repro.core.executor import create_kg
    from repro.live.bench import bench_live as run_live_bench
    from repro.rml import generator

    n = 20_000
    tb = generator.make_testbed("SOM", n, 0.75, n_poms=2, seed=0)
    tables = {"csv:child.csv": tb.child}
    if tb.parent is not None:
        tables["csv:parent.csv"] = tb.parent
    store = create_kg(tb.doc, tables=tables).to_store()
    report = run_live_bench(store)
    report["testbed_rows"] = n
    for op in ("insert", "delete"):
        w = report["write"][op]
        _row(
            f"live/{op}", w["wall_s"] / w["rows"] * 1e6,
            f"rows_per_s={w['rows_per_s']:.0f}",
        )
    for label, r in report["query"].items():
        _row(
            f"live/query-{label}",
            r["wall_s"] / r["n_queries"] * 1e6,
            f"queries_per_s={r['queries_per_s']:.0f};"
            f"p50_ms={r['latency_p50_ms']:.3f};"
            f"p99_ms={r['latency_p99_ms']:.3f}",
        )
    _row(
        "live/compact", report["compaction"]["compact_ms"] * 1e3,
        f"triples={report['compaction']['triples']}",
    )
    _write_json(json_dir, "BENCH_live.json", report)


def bench_shard(json_dir: str = ".") -> None:
    """The ``repro.shard`` scatter/gather benchmark on a 20K-row testbed
    (shard stores are rebuilt in-process at 1/2/4 shards, so the testbed
    stays small enough to re-encode three times inside the CI budget):
    routed bound-subject lookups and scatter-all 3-pattern star BGPs
    through the in-process shard session, per shard count, against the
    unsharded baseline.  Writes ``BENCH_shard.json``
    (``queries_per_s`` / ``latency_p99_ms`` gated by
    ``benchmarks/compare.py``; the ``criteria`` section carries the
    routed-overhead and scatter-cost acceptance ratios)."""
    from repro.core.executor import create_kg
    from repro.rml import generator
    from repro.shard.bench import bench_shard as run_shard_bench

    n = 20_000
    tb = generator.make_testbed("SOM", n, 0.75, n_poms=2, seed=0)
    tables = {"csv:child.csv": tb.child}
    if tb.parent is not None:
        tables["csv:parent.csv"] = tb.parent
    store = create_kg(tb.doc, tables=tables).to_store()
    report = run_shard_bench(store)
    report["testbed_rows"] = n
    for name, cls in report["classes"].items():
        for config, r in cls["configs"].items():
            _row(
                f"shard/{name}-{config}",
                r["wall_s"] / r["n_queries"] * 1e6,
                f"queries_per_s={r['queries_per_s']:.0f};"
                f"p50_ms={r['latency_p50_ms']:.3f};"
                f"p99_ms={r['latency_p99_ms']:.3f};"
                f"fanout={r['fanout_per_query']:.1f}",
            )
    for key, v in report.get("criteria", {}).items():
        _row(f"shard/criteria-{key}", 0.0, f"ratio={v:.2f}")
    _write_json(json_dir, "BENCH_shard.json", report)


def bench_roofline() -> None:
    from benchmarks import roofline

    path = os.path.join(roofline.RESULTS, "dryrun.json")
    if not os.path.exists(path):
        print("# roofline: results/dryrun.json missing (run repro.launch.dryrun)",
              flush=True)
        return
    for r in roofline.derive(path):
        if r.get("status") != "ok":
            continue
        _row(
            f"roofline/{r['cell']}",
            r["t_bound_s"] * 1e6,
            f"bound={r['bound']};frac={r.get('roofline_frac', 0)*100:.1f}%",
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=(None, "fig56", "opmodel", "kernels", "dedup",
                             "stream", "kg", "serve", "live", "shard",
                             "roofline"))
    ap.add_argument("--json-dir", default=".",
                    help="where BENCH_*.json reports are written")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    sections = {
        "fig56": lambda: bench_fig56(args.full),
        "opmodel": bench_op_model,
        "kernels": bench_kernels,
        "dedup": bench_dedup_gather,
        "stream": lambda: bench_stream(args.json_dir),
        "kg": lambda: bench_kg(args.json_dir),
        "serve": lambda: bench_serve(args.json_dir),
        "live": lambda: bench_live(args.json_dir),
        "shard": lambda: bench_shard(args.json_dir),
        "roofline": bench_roofline,
    }
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", flush=True)
        fn()


if __name__ == "__main__":
    main()
