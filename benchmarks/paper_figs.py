"""Paper Figures 5 & 6: execution time of KG creation.

Grid: dataset size x duplicate rate {25%, 75%} x operator {SOM, ORM, OJM} x
n predicate-object maps, engine (SDM-RDFizer) vs baseline (SDM-RDFizer⁻).
The naive OJM is Θ(|N_parent|·|N_child|); at 1M rows it is the paper's
"times out" cell — we cap it with a budget and report DNF, as the paper
reports timeouts for RMLMapper/RocketRML.
"""

from __future__ import annotations

import time

from repro.core.executor import create_kg
from repro.rml import generator

NAIVE_OJM_COMPARISON_BUDGET = 1.2e10  # |Np|x|Nc| above this -> DNF (paper: timeout)


def run_cell(kind: str, n_rows: int, dup: float, n_poms: int, engine: str,
             repeats: int = 1) -> dict:
    tb = generator.make_testbed(kind, n_rows, dup, n_poms=n_poms, seed=17)
    tables = {"csv:child.csv": tb.child}
    if tb.parent is not None:
        tables["csv:parent.csv"] = tb.parent
    if engine == "naive" and kind == "OJM":
        if n_rows * n_rows * n_poms > NAIVE_OJM_COMPARISON_BUDGET:
            return {"status": "DNF", "time_s": float("inf"), "n_triples": -1}
    times = []
    res = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = create_kg(tb.doc, tables=tables, engine=engine)
        times.append(time.perf_counter() - t0)
    return {
        "status": "ok",
        "time_s": min(times),
        "n_triples": res.n_triples,
        "stats": {
            p: dict(kind=s.kind, Np=s.n_candidates, Sp=s.n_unique,
                    phi=int(s.phi_optimized()), phi_naive=int(s.phi_naive()))
            for p, s in res.stats.items()
        },
    }


def sweep(sizes=(10_000, 100_000), dups=(0.25, 0.75), kinds=("SOM", "ORM", "OJM"),
          n_poms_list=(1, 2, 4), engines=("optimized", "naive")):
    rows = []
    for kind in kinds:
        for n in sizes:
            for dup in dups:
                for npm in n_poms_list:
                    for eng in engines:
                        r = run_cell(kind, n, dup, npm, eng)
                        rows.append(
                            dict(kind=kind, rows=n, dup=dup, n_poms=npm,
                                 engine=eng, **{k: r[k] for k in ("status", "time_s", "n_triples")})
                        )
                        t = "DNF" if r["status"] == "DNF" else f"{r['time_s']:.2f}s"
                        print(f"  {kind} n={n} dup={int(dup*100)}% poms={npm} "
                              f"{eng:9s}: {t} triples={r['n_triples']}")
    return rows


if __name__ == "__main__":
    import argparse, json

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="include the 1M-row tier")
    ap.add_argument("--out", default="results/paper_figs.json")
    args = ap.parse_args()
    sizes = (10_000, 100_000, 1_000_000) if args.full else (10_000, 100_000)
    rows = sweep(sizes=sizes, n_poms_list=(1, 2, 4) if args.full else (1, 2))
    import os

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {args.out}")
