"""dedup_gather benchmark — the paper's PTT saving applied to embedding
lookups (DESIGN.md §5).

Measures wall time of plain gather vs dedup_gather across duplicate rates,
and reports the *traffic model*: rows fetched (|N| vs |S|), which on a
row-sharded production table is the cross-device collective traffic.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dedup_gather import dedup_gather


def _time(fn, *args, repeats=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(vocab=1_000_000, dim=64, n=262_144, dup_factors=(1, 4, 16, 64)):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(vocab, dim)).astype(np.float32))
    rows = []
    plain = jax.jit(lambda t, i: jnp.take(t, i, axis=0))
    for f in dup_factors:
        n_distinct = max(n // f, 1)
        ids = jnp.asarray(
            rng.choice(n_distinct, size=n).astype(np.int32)
        )
        cap = int(n_distinct * 1.5)
        dedup = jax.jit(lambda t, i: dedup_gather(t, i, cap).values)
        t_plain = _time(plain, table, ids)
        t_dedup = _time(dedup, table, ids)
        res = dedup_gather(table, ids, cap)
        rows.append(
            dict(dup_factor=f, n=n, n_unique=int(res.n_unique),
                 t_plain_s=t_plain, t_dedup_s=t_dedup,
                 rows_fetched_plain=n, rows_fetched_dedup=cap,
                 traffic_saving=n / cap)
        )
        print(f"  dup x{f:<3}: plain {t_plain*1e3:7.2f}ms  dedup {t_dedup*1e3:7.2f}ms  "
              f"unique={int(res.n_unique):>7}  traffic |N|/|S|cap = {n/cap:.1f}x")
    return rows


if __name__ == "__main__":
    run()
