"""Operation-count model check (paper §III.iv).

Runs each operator on controlled data and verifies the measured candidate /
unique counts reproduce the φ expressions, then reports the φ̂/φ ratio — the
paper's analytical explanation for the observed two-orders-of-magnitude
speedups.
"""

from __future__ import annotations

import numpy as np

from repro.core.executor import create_kg
from repro.rml import generator


def run(sizes=(10_000, 100_000), dups=(0.25, 0.75)):
    rows = []
    for kind in ("SOM", "ORM", "OJM"):
        for n in sizes:
            for dup in dups:
                tb = generator.make_testbed(kind, n, dup, n_poms=1, seed=23)
                tables = {"csv:child.csv": tb.child}
                if tb.parent is not None:
                    tables["csv:parent.csv"] = tb.parent
                res = create_kg(tb.doc, tables=tables)
                st = [s for s in res.stats.values() if s.kind == kind][0]
                ratio = st.phi_naive() / max(st.phi_optimized(), 1)
                rows.append(
                    dict(kind=kind, rows=n, dup=dup, Np=st.n_candidates,
                         Sp=st.n_unique, phi=int(st.phi_optimized()),
                         phi_naive=int(st.phi_naive()), ratio=ratio)
                )
                print(f"  {kind} n={n} dup={int(dup*100)}%: |Np|={st.n_candidates} "
                      f"|Sp|={st.n_unique} phi={int(st.phi_optimized()):,} "
                      f"phi_naive={int(st.phi_naive()):,} ratio={ratio:.1f}x")
    return rows


if __name__ == "__main__":
    run()
