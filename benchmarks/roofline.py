"""Roofline derivation from the dry-run artifacts (deliverable g).

For each (arch x shape x mesh) cell in results/dryrun.json:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

cost_analysis() on the SPMD-partitioned module reports PER-DEVICE flops and
bytes (validated against 8·N·D/devices for qwen2.5-3b within 1%); collective
bytes are parsed from the per-device HLO (max of operand/result shape per
collective ≈ wire bytes for ring algorithms).  Scanned LM cells use the
unrolled L=1/L=2 marginal extrapolation (see launch/dryrun.py).

MODEL_FLOPS uses the paper-standard accounting: train 6·N·D, prefill 2·N·D,
decode 2·N·B (active params for MoE), D = global tokens.
"""

from __future__ import annotations

import json
import os

# TPU v5e (assignment constants)
PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # B/s
LINK_BW = 50e9        # B/s per ICI link

RESULTS = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "results"))

LM_SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,
    "long_500k": 1,
}


def model_flops(arch: str, shape: str) -> float | None:
    """Paper-standard useful FLOPs for the LM family (global)."""
    from repro.configs import registry

    entry = registry.ARCHS.get(arch)
    if entry is None or entry.family != "lm":
        return None
    cfg = entry.config()
    n_active = cfg.active_param_count()
    toks = LM_SHAPE_TOKENS[shape]
    if shape == "train_4k":
        return 6.0 * n_active * toks
    return 2.0 * n_active * toks  # forward-only (prefill / one decode step)


def derive(results_path: str | None = None) -> list[dict]:
    path = results_path or os.path.join(RESULTS, "dryrun.json")
    with open(path) as f:
        results = json.load(f)

    rows = []
    for key, r in sorted(results.items()):
        if r.get("status") != "ok":
            if r.get("status") == "skipped":
                rows.append({"cell": key, "status": "skipped"})
            continue
        cell, mesh = key.split("@")
        arch, shape = (cell.split("/") + [""])[:2]
        n_dev = r.get("devices", 256)
        t_compute = r["flops"] / PEAK_FLOPS
        t_memory = r.get("bytes_accessed", 0.0) / HBM_BW
        t_coll = r["collectives"]["total_bytes"] / LINK_BW
        terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
        bound = max(terms, key=terms.get)
        t_bound = terms[bound]

        mf = model_flops(arch, shape)
        # the CPU backend's bytes_accessed counts every unfused op access —
        # an UPPER bound on TPU HBM traffic; report a second bound that
        # excludes it (compute/collective only) to bracket the truth
        t_bound_nm = max(t_compute, t_coll)
        bound_nm = "compute" if t_compute >= t_coll else "collective"
        row = {
            "cell": key,
            "status": "ok",
            "devices": n_dev,
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "bound": bound,
            "t_bound_s": t_bound,
            "bound_excl_mem": bound_nm,
        }
        if mf is not None:
            t_ideal = mf / n_dev / PEAK_FLOPS
            row["model_flops_global"] = mf
            row["useful_flops_ratio"] = (mf / n_dev) / max(r["flops"], 1.0)
            row["roofline_frac"] = t_ideal / max(t_bound, 1e-30)
            row["roofline_frac_excl_mem"] = t_ideal / max(t_bound_nm, 1e-30)
        else:
            row["roofline_frac"] = t_compute / max(t_bound, 1e-30)
            row["roofline_frac_excl_mem"] = t_compute / max(t_bound_nm, 1e-30)
        rows.append(row)
    return rows


def render(rows: list[dict]) -> str:
    hdr = (
        f"{'cell':<46} {'bound':<10} {'t_comp(s)':>10} {'t_mem(s)':>10} "
        f"{'t_coll(s)':>10} {'roofl%':>7} {'xm%':>6} {'useful%':>8}"
    )
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"{r['cell']:<46} skipped")
            continue
        rf = r.get("roofline_frac", 0.0) * 100
        rx = r.get("roofline_frac_excl_mem", 0.0) * 100
        uf = r.get("useful_flops_ratio")
        out.append(
            f"{r['cell']:<46} {r['bound']:<10} {r['t_compute_s']:>10.4f} "
            f"{r['t_memory_s']:>10.4f} {r['t_collective_s']:>10.4f} "
            f"{rf:>6.1f}% {rx:>5.1f}% {('%7.1f%%' % (uf*100)) if uf else '      —'}"
        )
    return "\n".join(out)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=None)
    ap.add_argument("--out", default=os.path.join(RESULTS, "roofline.json"))
    args = ap.parse_args()
    rows = derive(args.results)
    print(render(rows))
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {args.out}")
