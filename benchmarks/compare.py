"""Bench-regression gate — diff fresh ``BENCH_*.json`` against baselines.

CI runs the bench sections into ``bench-out/`` and then::

    python benchmarks/compare.py --baseline-dir . --new-dir bench-out \
        --commit-msg "$(git log -1 --pretty=%B)"

For every ``BENCH_*.json`` in ``--new-dir`` that also exists (committed)
in ``--baseline-dir``, every ``queries_per_s`` leaf is compared: the gate
**fails** (exit 1) when a leaf regresses by more than ``--threshold``
(default 30%).  **Tail latency is gated too**: every ``latency_p99_ms``
leaf fails the gate when it grows by more than ``--latency-threshold``
(default 50%) after machine-speed normalization — so the regression
harness sees what users feel, not just mean throughput.  That generic
walk covers ``BENCH_serve.json``'s ``smallbatch`` section too: the
batch-1/8/64 per-dispatch tails through the scan-join fast path are
gated the moment their baseline leaves are committed.
``rows_per_s`` and ``latency_p50_ms`` leaves are reported but never
gated.  Leaves with a zero or missing baseline — a new query class, an
empty-store section — are reported as ``new`` and never gated, so adding
classes does not require touching the gate.

Baselines are committed from whatever machine last refreshed them while
CI runs on shared runners, so raw cross-machine ratios would fail every
leaf on a slower box.  The gate therefore computes one **global
machine-speed factor** — the median ``new/baseline`` ratio over every
gated leaf of every report — and gates each leaf on its *deviation from
that median*: a uniformly slower runner shifts every leaf equally and
passes, while any leaf (even a report with a single one, like
``BENCH_kg.json``) regressing relative to the rest still fails.  When
fewer than 3 gated leaves exist in total the factor falls back to 1
(a lone leaf's median is itself, which would blind the gate).  The
trade-off — a change slowing *everything* uniformly also passes — is
covered by refreshing baselines periodically; ``--no-normalize``
restores the absolute comparison.

Escape hatch: a commit message containing ``[bench-skip]`` downgrades the
gate to report-only (the delta table still prints).  Refreshing a
baseline = re-running ``benchmarks/run.py --only <section> --json-dir .``
and committing the changed ``BENCH_*.json`` (see ``benchmarks/README.md``).

A markdown delta table is always printed; when ``$GITHUB_STEP_SUMMARY``
is set it is appended there too, so the PR's job summary shows the perf
trajectory inline.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

# throughput leaves: gated on a drop; latency leaves: gated on growth
# (machine speed cancels both ways — a slow runner divides throughput and
# multiplies latency by the same factor)
GATED_METRICS = ("queries_per_s",)
GATED_LATENCY_METRICS = ("latency_p99_ms",)
REPORTED_METRICS = (
    "queries_per_s", "rows_per_s", "latency_p50_ms", "latency_p99_ms"
)


def _leaves(obj, prefix: str = "") -> dict[str, float]:
    """Flatten a report to ``path -> value`` for the reported metrics."""
    out: dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in sorted(obj.items()):
            path = f"{prefix}/{k}" if prefix else str(k)
            if k in REPORTED_METRICS and isinstance(v, (int, float)):
                out[path] = float(v)
            else:
                out.update(_leaves(v, path))
    return out


def _median(xs: list[float]) -> float:
    s = sorted(xs)
    mid = len(s) // 2
    return s[mid] if len(s) % 2 else (s[mid - 1] + s[mid]) / 2


def gated_ratios(baseline: dict, fresh: dict) -> list[float]:
    """new/baseline ratios of the gated leaves present in both reports."""
    base = _leaves(baseline)
    new = _leaves(fresh)
    return [
        new[p] / base[p]
        for p in base
        if p in new and base[p] > 0.0
        and p.rsplit("/", 1)[-1] in GATED_METRICS
    ]


def speed_factor(ratios: list[float]) -> float:
    """The global machine-speed factor: the median gated ratio.  With
    fewer than 3 leaves the median IS (close to) each leaf — a regression
    would normalize itself away — so fall back to absolute comparison."""
    if len(ratios) < 3:
        return 1.0
    factor = _median(ratios)
    return factor if factor > 0.0 else 1.0


def compare_file(
    name: str, baseline: dict, fresh: dict, threshold: float,
    factor: float = 1.0, latency_threshold: float = 0.50,
) -> tuple[list[dict], list[str]]:
    """Rows for the delta table plus the failing leaf paths; each gated
    leaf is thresholded on its deviation from the machine-speed
    ``factor`` the caller divided out (latency leaves use the inverse
    factor: a uniformly slower box multiplies every latency)."""
    base = _leaves(baseline)
    new = _leaves(fresh)
    rows: list[dict] = []
    failures: list[str] = []
    for path in sorted(set(base) | set(new)):
        b = base.get(path)
        n = new.get(path)
        metric = path.rsplit("/", 1)[-1]
        latency = metric in GATED_LATENCY_METRICS or metric.startswith(
            "latency_"
        )
        gated = metric in GATED_METRICS or metric in GATED_LATENCY_METRICS
        if n is None:
            status = "gone"
            delta = None
        elif b is None or b == 0.0:
            status = "new"
            delta = None
        else:
            # deviation from the global median ratio: machine speed
            # cancels, a leaf regressing relative to the rest fails
            if latency:
                # expected latency on this machine is b / factor
                delta = n * factor / b - 1.0
                bad = delta > latency_threshold
            else:
                delta = n / (b * factor) - 1.0
                bad = delta < -threshold
            if gated and bad:
                status = "REGRESSION"
                failures.append(f"{name}:{path}")
            else:
                status = "ok" if gated else "info"
        rows.append(
            {
                "file": name,
                "path": path,
                "baseline": b,
                "new": n,
                "delta": delta,
                "status": status,
            }
        )
    return rows, failures


def _fmt(v: float | None) -> str:
    if v is None:
        return "—"
    return f"{v:,.0f}" if abs(v) >= 100 else f"{v:,.2f}"


def markdown_table(
    rows: list[dict], threshold: float, factor: float,
    latency_threshold: float = 0.50,
) -> str:
    lines = [
        f"### Bench gate (fail below −{threshold:.0%} queries_per_s or "
        f"above +{latency_threshold:.0%} latency_p99_ms, "
        "median-normalized)",
        "",
        f"machine-speed factor (median new/baseline over gated leaves): "
        f"×{factor:.2f}",
        "",
        "| report | metric | baseline | new | delta vs median | status |",
        "|---|---|---:|---:|---:|---|",
    ]
    for r in rows:
        delta = "—" if r["delta"] is None else f"{r['delta']:+.1%}"
        status = r["status"]
        if status == "REGRESSION":
            status = "❌ **REGRESSION**"
        elif status == "ok":
            status = "✅"
        lines.append(
            f"| {r['file']} | `{r['path']}` | {_fmt(r['baseline'])} "
            f"| {_fmt(r['new'])} | {delta} | {status} |"
        )
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", default=".",
                    help="where the committed BENCH_*.json baselines live")
    ap.add_argument("--new-dir", default="bench-out",
                    help="where the fresh BENCH_*.json reports were written")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed queries_per_s regression (fraction)")
    ap.add_argument("--latency-threshold", type=float, default=0.50,
                    help="max allowed latency_p99_ms growth (fraction)")
    ap.add_argument("--commit-msg", default="",
                    help="head commit message; '[bench-skip]' makes the "
                         "gate report-only")
    ap.add_argument("--no-normalize", action="store_true",
                    help="gate on raw cross-machine ratios instead of "
                         "deviation from the per-report median")
    args = ap.parse_args()

    fresh_paths = sorted(glob.glob(os.path.join(args.new_dir, "BENCH_*.json")))
    if not fresh_paths:
        print(f"bench-gate: no BENCH_*.json under {args.new_dir}", flush=True)
        return 1
    pairs: list[tuple[str, dict, dict]] = []
    all_rows: list[dict] = []
    for path in fresh_paths:
        name = os.path.basename(path)
        with open(path, encoding="utf-8") as f:
            fresh = json.load(f)
        base_path = os.path.join(args.baseline_dir, name)
        if not os.path.exists(base_path):
            all_rows.append(
                {"file": name, "path": "(whole report)", "baseline": None,
                 "new": None, "delta": None, "status": "new"}
            )
            continue
        with open(base_path, encoding="utf-8") as f:
            baseline = json.load(f)
        pairs.append((name, baseline, fresh))

    ratios = [r for _, b, f in pairs for r in gated_ratios(b, f)]
    factor = 1.0 if args.no_normalize else speed_factor(ratios)
    failures: list[str] = []
    for name, baseline, fresh in pairs:
        rows, fails = compare_file(
            name, baseline, fresh, args.threshold, factor,
            args.latency_threshold,
        )
        all_rows.extend(rows)
        failures.extend(fails)

    skipped = "[bench-skip]" in args.commit_msg
    table = markdown_table(
        all_rows, args.threshold, factor, args.latency_threshold
    )
    if failures:
        verdict = (
            "⚠️ regressions present but gate skipped via `[bench-skip]`"
            if skipped
            else "❌ bench gate FAILED: " + ", ".join(failures)
        )
    else:
        verdict = "✅ bench gate passed"
    report = f"{table}\n\n{verdict}\n"
    print(report, flush=True)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a", encoding="utf-8") as f:
            f.write(report + "\n")
    return 1 if (failures and not skipped) else 0


if __name__ == "__main__":
    sys.exit(main())
