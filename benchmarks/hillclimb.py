import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Perf hillclimbs (§Perf): build baseline + optimized variants of the three
selected cells, compile both on the single-pod mesh, and record the roofline
terms before/after into results/hillclimb.json.

  1. dbrx-132b/train_4k      — int8-quantized FSDP expert-weight gathers
  2. command-r-plus-104b/decode_32k — serve-resident TP layout (no per-token
                                FSDP weight gathers)
  3. wide-deep/train_batch   — PTT dedup-gather on the embedding id stream

Usage: PYTHONPATH=src python -m benchmarks.hillclimb [--which 1 2 3]
"""

import argparse
import dataclasses
import json

import jax

from repro.launch.dryrun import _compile_costs, _extrapolate
from repro.launch.mesh import make_production_mesh

RESULTS = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "results"))


def _costs_lm(arch_cfg_pairs, mesh, build):
    """Compile the (scanned) deployable and the L1/L2 cost variants."""
    spec = build(None)
    base = _compile_costs(spec, mesh)
    c1 = _compile_costs(build(1), mesh)
    c2 = _compile_costs(build(2), mesh)
    n_layers = arch_cfg_pairs.n_layers
    out = dict(base)
    out.update(_extrapolate(c1, c2, n_layers))
    return out


def hc1_dbrx(mesh):
    from repro.configs import cells, dbrx_132b

    out = {}
    for name, quant in (("baseline", False), ("int8_gather", True)):
        cfg = dataclasses.replace(dbrx_132b.config(), moe_quant_gather=quant)

        def build(n_layers):
            c = cfg if n_layers is None else dataclasses.replace(
                cfg, n_layers=n_layers, scan_layers=False
            )
            return cells.lm_train_cell(
                c, mesh, batch=256, seq=4096, unroll_accum=n_layers is not None
            )

        out[name] = _costs_lm(cfg, mesh, build)
        print(f"  dbrx train {name}: flops={out[name]['flops']:.3e} "
              f"coll={out[name]['collectives']['total_bytes']:.3e} "
              f"temp={out[name]['memory'].get('temp_size_in_bytes',0)/(1<<30):.2f}GiB")
    return out


def hc2_commandr_decode(mesh):
    from repro.configs import cells, command_r_plus_104b

    cfg = command_r_plus_104b.config()
    out = {}
    for name, serve in (("baseline_fsdp", False), ("serve_resident_tp", True)):
        def build(n_layers):
            c = cfg if n_layers is None else dataclasses.replace(
                cfg, n_layers=n_layers, scan_layers=False
            )
            return cells.lm_decode_cell(c, mesh, 128, 32768, serve_layout=serve)

        out[name] = _costs_lm(cfg, mesh, build)
        print(f"  command-r decode {name}: flops={out[name]['flops']:.3e} "
              f"coll={out[name]['collectives']['total_bytes']:.3e} "
              f"args={out[name]['memory'].get('argument_size_in_bytes',0)/(1<<30):.2f}GiB "
              f"temp={out[name]['memory'].get('temp_size_in_bytes',0)/(1<<30):.2f}GiB")
    return out


def hc3_widedeep(mesh):
    from repro.configs import cells, wide_deep

    out = {}
    # per-shard id stream: B*F/dp = 65536*40/16 = 163,840; heavy-tailed CTR
    # streams dedup 4-10x -> cap 40,960 per shard
    for name, cap in (("baseline", None), ("dedup_gather", 40960)):
        cfg = dataclasses.replace(wide_deep.config(), dedup_cap=cap)
        spec = cells.recsys_train_cell(cfg, mesh, 65536)
        out[name] = _compile_costs(spec, mesh)
        print(f"  wide-deep train {name}: flops={out[name]['flops']:.3e} "
              f"coll={out[name]['collectives']['total_bytes']:.3e} "
              f"temp={out[name]['memory'].get('temp_size_in_bytes',0)/(1<<30):.2f}GiB")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", nargs="*", type=int, default=[1, 2, 3])
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False)
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "hillclimb.json")
    results = {}
    if os.path.exists(path):
        with open(path) as f:
            results = json.load(f)
    runs = {1: ("dbrx_train_int8_gather", hc1_dbrx),
            2: ("commandr_decode_serve_tp", hc2_commandr_decode),
            3: ("widedeep_dedup_gather", hc3_widedeep)}
    for i in args.which:
        name, fn = runs[i]
        print(f"[hillclimb {i}] {name}")
        with jax.set_mesh(mesh):
            pass
        results[name] = fn(mesh)
        with open(path, "w") as f:
            json.dump(results, f, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
