"""End-to-end driver: the paper's evaluation in one script.

Creates the three testbed families (SOM / ORM / OJM) at a chosen scale,
runs BOTH engines (SDM-RDFizer vs the naive SDM-RDFizer⁻ baseline),
verifies the knowledge graphs are identical, and prints the
speedup + φ table — a miniature of the paper's Figures 5/6.

    PYTHONPATH=src python examples/kg_biomedical.py --rows 20000 --dup 0.75
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.executor import create_kg  # noqa: E402
from repro.rml import generator  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=20000)
    ap.add_argument("--dup", type=float, default=0.75)
    ap.add_argument("--n-poms", type=int, default=2)
    args = ap.parse_args()

    print(f"{'testbed':<10} {'engine':<10} {'time':>8} {'triples':>9}  speedup")
    for kind in ("SOM", "ORM", "OJM"):
        tb = generator.make_testbed(kind, args.rows, args.dup, n_poms=args.n_poms)
        tables = {"csv:child.csv": tb.child}
        if tb.parent is not None:
            tables["csv:parent.csv"] = tb.parent

        t0 = time.perf_counter()
        opt = create_kg(tb.doc, tables=tables, engine="optimized")
        t_opt = time.perf_counter() - t0

        t0 = time.perf_counter()
        nav = create_kg(tb.doc, tables=tables, engine="naive")
        t_nav = time.perf_counter() - t0

        assert opt.as_set() == nav.as_set(), "engines disagree!"
        print(f"{kind:<10} {'optimized':<10} {t_opt:>7.2f}s {opt.n_triples:>9}")
        print(f"{'':<10} {'naive':<10} {t_nav:>7.2f}s {nav.n_triples:>9}  "
              f"{t_nav/t_opt:.2f}x")
        for pred, st in opt.stats.items():
            if st.kind == kind:
                print(f"{'':<21}  phi ratio {pred.rsplit('/',1)[-1]}: "
                      f"{st.phi_naive()/max(st.phi_optimized(),1):.0f}x")


if __name__ == "__main__":
    main()
