"""Train a ~100M-parameter qwen-family model for a few hundred steps on CPU
— the end-to-end training driver at example scale (deliverable b).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--size", choices=("tiny", "100m"), default="tiny")
    args = ap.parse_args()

    from repro.configs import qwen2_5_3b
    from repro.models import transformer
    from repro.train.optimizer import AdamW
    from repro.train.trainer import make_train_step

    if args.size == "100m":
        cfg = dataclasses.replace(
            qwen2_5_3b.config(), n_layers=8, d_model=512, n_heads=8, n_kv=2,
            head_dim=64, d_ff=2048, vocab=32000, dtype=jnp.float32,
            sequence_parallel=False, attn_chunk=None, microbatches=1,
        )
    else:
        cfg = qwen2_5_3b.smoke_config()
    print(f"training {cfg.name} variant: {cfg.param_count()/1e6:.1f}M params")

    key = jax.random.PRNGKey(0)
    params = transformer.init(key, cfg)
    opt = AdamW(lr=3e-3)
    opt_state = opt.init(params)
    step = jax.jit(
        make_train_step(lambda p, t, l: transformer.loss_fn(cfg, p, t, l), opt),
        donate_argnums=(0, 1),
    )

    # fixed random corpus -> loss must fall (memorization signal)
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, cfg.vocab, size=(32, args.seq + 1)).astype(np.int32)

    losses = []
    t0 = time.perf_counter()
    for i in range(args.steps):
        idx = rng.integers(0, len(corpus), size=args.batch)
        toks, labels = corpus[idx, :-1], corpus[idx, 1:]
        params, opt_state, m = step(
            params, opt_state, jnp.asarray(toks), jnp.asarray(labels)
        )
        losses.append(float(m["loss"]))
        if i % 50 == 0:
            print(f"step {i:4d}  loss {losses[-1]:.4f}")
    dt = time.perf_counter() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s; loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss did not improve"
    print("loss improved — training works end to end")


if __name__ == "__main__":
    main()
