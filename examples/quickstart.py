"""Quickstart: create an RDF knowledge graph from CSVs with the SDM-RDFizer
engine — the paper's motivating example in miniature.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.executor import create_kg  # noqa: E402
from repro.rml import generator, parser, serializer  # noqa: E402


def main() -> None:
    # 1. A biomedical-style testbed: mutations (child) joined to exons
    #    (parent) on the ENST accession — the paper's Figure 1 scenario.
    tb = generator.make_ojm_testbed(n_rows=5000, dup_rate=0.25, n_poms=2)

    with tempfile.TemporaryDirectory() as tmp:
        tb.write(tmp)
        mapping_path = os.path.join(tmp, "mapping.ttl")
        serializer.write_turtle(tb.doc, mapping_path)
        print(f"mapping written to {mapping_path}:")
        print("\n".join(serializer.to_turtle(tb.doc).splitlines()[:12]), "\n...")

        # 2. Parse the RML document back, look at the mapping planner's
        #    decisions (what `rdfize --explain-mapping` prints: kept vs
        #    pruned columns, factored shared terms, rule groups), then
        #    create the knowledge graph.
        doc = parser.parse_file(mapping_path)
        from repro import api

        print("\nmapping plan (rdfize --explain-mapping):")
        print(api.explain_mapping(doc, data_root=tmp))
        result = create_kg(doc, data_root=tmp, engine="optimized")

        print(f"\ncreated {result.n_triples} unique RDF triples "
              f"in {result.wall_time_s:.2f}s")
        for pred, st in result.stats.items():
            print(f"  {st.kind:5s} {pred.rsplit('/', 1)[-1]:20s} "
                  f"|N_p|={st.n_candidates:>7} |S_p|={st.n_unique:>7} "
                  f"phi_naive/phi={st.phi_naive()/max(st.phi_optimized(),1):>8.1f}x")

        # 3. Serialize a sample.
        out = os.path.join(tmp, "kg.nt")
        result.write_ntriples(out)
        with open(out) as f:
            print("\nfirst three triples:")
            for _ in range(3):
                print(" ", f.readline().strip())


if __name__ == "__main__":
    main()
