"""The paper's technique beyond RDF: PTT-style dedup-gather on the wide-deep
recsys embedding path (DESIGN.md §5).

Trains the smoke wide-deep model on a synthetic CTR stream whose id
distribution is heavy-tailed (realistic for recsys), with and without
dedup_gather, and shows (a) identical losses, (b) the |N| -> |S| traffic
reduction the PTT idea buys.

    PYTHONPATH=src python examples/recsys_dedup.py
"""

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def main() -> None:
    from repro.configs import wide_deep
    from repro.core.dedup_gather import dedup_gather
    from repro.models import recsys
    from repro.train.optimizer import AdamW
    from repro.train.trainer import make_train_step

    cfg = wide_deep.smoke_config()
    rng = np.random.default_rng(0)
    B = 256

    # heavy-tailed ids: a few hot items dominate (Zipf) — the high-duplicate
    # regime the paper targets
    zipf = np.minimum(rng.zipf(1.3, size=(512, cfg.n_sparse, 1)), cfg.vocab_per_field) - 1
    dense = rng.normal(size=(512, cfg.n_dense)).astype(np.float32)
    w_true = rng.normal(size=cfg.n_dense).astype(np.float32)
    labels = (dense @ w_true + 0.3 * rng.normal(size=512) > 0).astype(np.int32)

    flat_ids = (
        zipf[:B] + (np.arange(cfg.n_sparse)[None, :, None] * cfg.vocab_per_field)
    ).reshape(-1)
    n_unique = len(np.unique(flat_ids))
    print(f"id stream: {len(flat_ids)} lookups, {n_unique} distinct "
          f"(|N|/|S| = {len(flat_ids)/n_unique:.1f}x duplicate factor)")

    cap = int(n_unique * 1.5)
    cfg_dedup = dataclasses.replace(cfg, dedup_cap=cap)

    for name, c in (("plain", cfg), ("dedup-gather", cfg_dedup)):
        params = recsys.init(jax.random.PRNGKey(0), c)
        opt = AdamW(lr=1e-2)
        step = jax.jit(
            make_train_step(
                lambda p, s, d, y: recsys.loss_fn(p, c, s, d, y), opt
            ),
            donate_argnums=(0, 1),
        )
        state = opt.init(params)
        losses = []
        for i in range(60):
            idx = rng.integers(0, 512, size=B)
            params, state, m = step(
                params, state, jnp.asarray(zipf[idx]),
                jnp.asarray(dense[idx]), jnp.asarray(labels[idx]),
            )
            losses.append(float(m["loss"]))
        print(f"  {name:14s}: loss {losses[0]:.4f} -> {losses[-1]:.4f}")

    # traffic accounting (what a row-sharded table would move across chips)
    table = jnp.zeros((cfg.n_sparse * cfg.vocab_per_field, cfg.embed_dim))
    res = dedup_gather(table, jnp.asarray(flat_ids.astype(np.int32)), cap)
    print(f"\nrows fetched: plain={len(flat_ids)}  dedup={cap} "
          f"(true unique {int(res.n_unique)}) -> "
          f"{len(flat_ids)/cap:.1f}x less gather/collective traffic")


if __name__ == "__main__":
    main()
