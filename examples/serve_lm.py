"""Serve a small model with batched requests: prefill + batched decode with
a KV cache, demonstrating the serving engine (deliverable b).

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --gen 48
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    from repro.launch import serve

    sys.argv = [
        "serve", "--arch", args.arch, "--batch", str(args.batch),
        "--prompt-len", str(args.prompt_len), "--gen", str(args.gen),
    ]
    serve.main()


if __name__ == "__main__":
    main()
