#!/usr/bin/env bash
# Tier-1 verification: the full test suite, bounded by a timeout so a hung
# jit compile or prefetch thread cannot wedge CI.
#
#   scripts/tier1.sh            # defaults: 1800s timeout
#   TIER1_TIMEOUT=600 scripts/tier1.sh -k stream   # extra args -> pytest
set -euo pipefail
cd "$(dirname "$0")/.."
exec timeout "${TIER1_TIMEOUT:-1800}" \
    env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "$@"
