#!/usr/bin/env bash
# End-to-end serving smoke: generate a tiny testbed, rdfize it to a .kgz
# snapshot, start the batching query server, run client queries over the
# wire (plain, UNION, GROUP BY-COUNT), then the live mutation round-trip
# (insert -> query -> delete -> query -> compact -> query) and the metrics
# op, asserting every answer.  Used by CI (fast: ~1 min) and runnable
# locally:
#
#   scripts/serve_smoke.sh [port]
#
# By default the server binds port 0 (the kernel picks a free port) and
# the script parses the chosen port from the startup log — parallel CI
# jobs and the shard smoke test can never collide on a fixed port.
set -euo pipefail
cd "$(dirname "$0")/.."
PORT="${1:-0}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
WORK="$(mktemp -d)"
trap 'kill "${SERVER_PID:-}" 2>/dev/null || true; rm -rf "$WORK"' EXIT

# wait for "[serve] listening on host:port" in a server log and echo the
# port (the server announces the kernel-chosen port there when bound to 0)
wait_for_port() {
    local log="$1" port=""
    for _ in $(seq 150); do
        port="$(sed -n 's/.*\[serve\] listening on [^ :]*:\([0-9][0-9]*\).*/\1/p' "$log" | head -n 1)"
        if [ -n "$port" ]; then echo "$port"; return 0; fi
        sleep 0.2
    done
    echo "server never announced a listening port; log follows:" >&2
    cat "$log" >&2
    return 1
}

# tiny testbed: 200 rows, SOM mapping, written as csv + turtle
python - "$WORK" <<'EOF'
import sys
from repro.rml import generator, serializer
tb = generator.make_testbed("SOM", 200, 0.5, n_poms=2, seed=3)
tb.write(sys.argv[1])
serializer.write_turtle(tb.doc, sys.argv[1] + "/mapping.ttl")
EOF

python -m repro.launch.rdfize \
    --mapping "$WORK/mapping.ttl" --data-root "$WORK" \
    --out "$WORK/kg.kgz" --emit kgz

python -m repro.launch.serve --kg "$WORK/kg.kgz" --port "$PORT" \
    --trace "$WORK/trace.json" 2>"$WORK/server.log" &
SERVER_PID=$!
PORT="$(wait_for_port "$WORK/server.log")"
echo "[smoke] server is up on port $PORT"

QUERY='SELECT * WHERE { ?m <http://repro.org/vocab/gene_name> ?g } LIMIT 3'
OUT="$(python -m repro.launch.serve --connect "127.0.0.1:$PORT" \
    --query "$QUERY" --retry-s 30)"
echo "$OUT"

# the snapshot always holds gene_name triples: assert rows came back
python - "$OUT" <<'EOF'
import json, sys
resp = json.loads(sys.argv[1])
assert resp.get("vars") == ["?m", "?g"], resp
assert resp.get("n_total", 0) > 0 and len(resp["rows"]) == 3, resp
m, g = resp["rows"][0]
assert m.startswith("<http://repro.org/") and g.startswith('"'), resp
print(f"serve smoke OK: {resp['n_total']} solutions, "
      f"batch={resp['batch_size']}, {resp['latency_ms']}ms")
EOF

# algebra breadth over the wire: a 2-arm UNION and a GROUP BY-COUNT must
# answer consistently with the plain query (full counts, decoded cells)
GN='<http://repro.org/vocab/gene_name>'
AN='<http://repro.org/vocab/accession_number>'
BASE_OUT="$(python -m repro.launch.serve --connect "127.0.0.1:$PORT" \
    --query "SELECT * WHERE { ?m $GN ?g }" --retry-s 30)"
UNION_OUT="$(python -m repro.launch.serve --connect "127.0.0.1:$PORT" \
    --query "SELECT * WHERE { { ?m $GN ?x } UNION { ?m $AN ?x } }" --retry-s 30)"
AN_OUT="$(python -m repro.launch.serve --connect "127.0.0.1:$PORT" \
    --query "SELECT * WHERE { ?m $AN ?x }" --retry-s 30)"
COUNT_OUT="$(python -m repro.launch.serve --connect "127.0.0.1:$PORT" \
    --query "SELECT ?g (COUNT(?m) AS ?n) WHERE { ?m $GN ?g } GROUP BY ?g ORDER BY DESC(?n)" \
    --retry-s 30)"

python - "$BASE_OUT" "$UNION_OUT" "$AN_OUT" "$COUNT_OUT" <<'EOF'
import json, sys
base, union, accn, count = (json.loads(a) for a in sys.argv[1:5])
# UNION = bag union of the two single-predicate queries
assert union["vars"] == ["?m", "?x"], union
assert union["n_total"] == base["n_total"] + accn["n_total"], (
    union["n_total"], base["n_total"], accn["n_total"])
assert all(m.startswith("<") and x.startswith('"') for m, x in union["rows"]), union["rows"][:3]
# GROUP BY-COUNT: integer cells flagged via agg_vars, counts sum to the
# plain query's solution count, ORDER BY DESC(?n) sorts them descending
assert count["vars"] == ["?g", "?n"] and count["agg_vars"] == ["?n"], count
ns = [n for _, n in count["rows"]]
assert all(isinstance(n, int) and n >= 1 for n in ns), ns[:5]
assert ns == sorted(ns, reverse=True), ns[:10]
assert count["n_total"] == len(count["rows"]), count["n_total"]
assert sum(ns) == base["n_total"], (sum(ns), base["n_total"])
print(f"algebra smoke OK: union={union['n_total']} rows, "
      f"{count['n_total']} gene groups summing to {sum(ns)}")
EOF

# live round-trip over the wire, driven through the unified repro.api
# client path: insert -> query -> delete a base triple (tombstoned until
# compaction) -> compact (persists back to the .kgz) -> query, asserting
# counts, typed errors, and the live.* observability counters
python - "$PORT" <<'EOF'
import sys
from repro import api

GN = "<http://repro.org/vocab/gene_name>"
q = f"SELECT * WHERE {{ ?m {GN} ?g }}"
with api.connect(f"127.0.0.1:{int(sys.argv[1])}", retry_s=30) as c:
    before = c.query(q)
    n0 = before.n_total
    try:  # typed errors surface over the wire with their structured code
        c.query("SELECT nonsense")
        raise AssertionError("bad query text must raise")
    except api.QueryParseError as e:
        assert e.code == "parse", e.code
    r = c.insert([["<http://smoke/x1>", GN, '"live-one"'],
                  ["<http://smoke/x2>", GN, '"live-two"']])
    assert r["inserted"] == 2 and r["generation"] >= 1, r
    mid = c.query(q)
    assert mid.n_total == n0 + 2, (mid.n_total, n0)
    # tombstone a base triple (delete before compaction masks, not rewrites)
    m, g = before.rows[0]
    d = c.delete([[m, GN, g]])
    assert (d["deleted"], d["tombstoned"]) == (1, 1), d
    assert d["delta_fraction"] > 0, d
    after = c.query(q)
    assert after.n_total == n0 + 1, (after.n_total, n0)
    rc = c.compact()
    assert rc["compacted"] and rc["persisted"], rc
    assert rc["delta_fraction"] == 0 and rc["n_total"] >= n0 + 1, rc
    final = c.query(q)
    assert final.n_total == n0 + 1, (final.n_total, n0)
    met = c.metrics()["metrics"]
    cnt = met["counters"]
    assert cnt["live.inserts"] == 2, cnt
    assert cnt["live.deletes"] == 1 and cnt["live.tombstone_hits"] == 1, cnt
    assert cnt["live.compactions"] == 1, cnt
    assert met["histograms"]["live.compact_ms"]["count"] == 1, met["histograms"]
    assert met["gauges"]["live.delta_fraction"] == 0.0, met["gauges"]
    print(f"live smoke OK: {n0} -> insert 2 -> tombstone 1 -> "
          f"compact({rc['compact_ms']}ms, persisted) -> {final.n_total}")
EOF

# observability over the wire: the metrics op must report a non-empty
# request-latency histogram and the queue-wait vs execute-time split
METRICS_OUT="$(python -m repro.launch.serve --connect "127.0.0.1:$PORT" \
    --metrics --retry-s 30)"

python - "$METRICS_OUT" <<'EOF2'
import json, sys
m = json.loads(sys.argv[1])
hists = m["metrics"]["histograms"]
counters = m["metrics"]["counters"]
req = hists["serve.request_ms"]
assert req["count"] >= 5 and req["p50"] is not None and req["p99"] is not None, req
# the split: every request recorded a queue wait AND an execute time
assert hists["serve.queue_wait_ms"]["count"] == req["count"], hists["serve.queue_wait_ms"]
assert hists["serve.exec_ms"]["count"] >= 1, hists["serve.exec_ms"]
assert counters["serve.queries"] == req["count"], counters
# per-signature latency histograms, labeled with example query texts
sig_hists = [k for k in hists if k.startswith("serve.exec_ms.sig=")]
assert sig_hists and m["signatures"], (sig_hists, m["signatures"])
print(f"metrics smoke OK: {req['count']} requests, "
      f"queue p50={hists['serve.queue_wait_ms']['p50']:.3f}ms, "
      f"exec p50={hists['serve.exec_ms']['p50']:.3f}ms, "
      f"{len(sig_hists)} signatures")
EOF2

# shutdown writes the Chrome trace; assert it is Perfetto-loadable JSON
# with the queue-wait and dispatch spans of the live batches above
kill -TERM "$SERVER_PID"
wait "$SERVER_PID" || true
python - "$WORK/trace.json" <<'EOF2'
import json, sys
doc = json.load(open(sys.argv[1]))
evs = doc["traceEvents"]
assert isinstance(evs, list) and evs, "empty trace"
names = {e["name"] for e in evs}
assert {"queue_wait", "dispatch"} <= names, names
for e in evs:
    assert e["ph"] == "X" and "ts" in e and "dur" in e, e
print(f"trace smoke OK: {len(evs)} events, spans={sorted(names)}")
EOF2
