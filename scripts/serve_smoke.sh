#!/usr/bin/env bash
# End-to-end serving smoke: generate a tiny testbed, rdfize it to a .kgz
# snapshot, start the batching query server, run one client query over the
# wire, and assert the answer is correct.  Used by CI (fast: ~1 min) and
# runnable locally:
#
#   scripts/serve_smoke.sh [port]
set -euo pipefail
cd "$(dirname "$0")/.."
PORT="${1:-7351}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
WORK="$(mktemp -d)"
trap 'kill "${SERVER_PID:-}" 2>/dev/null || true; rm -rf "$WORK"' EXIT

# tiny testbed: 200 rows, SOM mapping, written as csv + turtle
python - "$WORK" <<'EOF'
import sys
from repro.rml import generator, serializer
tb = generator.make_testbed("SOM", 200, 0.5, n_poms=2, seed=3)
tb.write(sys.argv[1])
serializer.write_turtle(tb.doc, sys.argv[1] + "/mapping.ttl")
EOF

python -m repro.launch.rdfize \
    --mapping "$WORK/mapping.ttl" --data-root "$WORK" \
    --out "$WORK/kg.kgz" --emit kgz

python -m repro.launch.serve --kg "$WORK/kg.kgz" --port "$PORT" &
SERVER_PID=$!

QUERY='SELECT * WHERE { ?m <http://repro.org/vocab/gene_name> ?g } LIMIT 3'
OUT="$(python -m repro.launch.serve --connect "127.0.0.1:$PORT" \
    --query "$QUERY" --retry-s 30)"
echo "$OUT"

# the snapshot always holds gene_name triples: assert rows came back
python - "$OUT" <<'EOF'
import json, sys
resp = json.loads(sys.argv[1])
assert resp.get("vars") == ["?m", "?g"], resp
assert resp.get("n_total", 0) > 0 and len(resp["rows"]) == 3, resp
m, g = resp["rows"][0]
assert m.startswith("<http://repro.org/") and g.startswith('"'), resp
print(f"serve smoke OK: {resp['n_total']} solutions, "
      f"batch={resp['batch_size']}, {resp['latency_ms']}ms")
EOF
