#!/usr/bin/env bash
# End-to-end sharding smoke: generate a testbed, rdfize it into a
# 2-shard KG (multi-process shard builds), then assert three access
# paths against the unsharded snapshot built from the same sources:
#
#   1. repro.api.connect(<manifest>)  — in-process scatter/gather session,
#      byte-identical answers (plain / chain / GROUP BY-COUNT / DISTINCT),
#      insert routed to exactly one shard;
#   2. launch.serve --kg <manifest>   — the coordinator NDJSON server
#      (port 0, parsed from the startup log), queried over the wire with
#      the ordinary client, fan-out counters checked via the metrics op;
#   3. launch.query --kg <manifest>   — the CLI front door.
#
#   scripts/shard_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
WORK="$(mktemp -d)"
trap 'kill "${SERVER_PID:-}" 2>/dev/null || true; rm -rf "$WORK"' EXIT

wait_for_port() {
    local log="$1" port=""
    for _ in $(seq 150); do
        port="$(sed -n 's/.*\[serve\] listening on [^ :]*:\([0-9][0-9]*\).*/\1/p' "$log" | head -n 1)"
        if [ -n "$port" ]; then echo "$port"; return 0; fi
        sleep 0.2
    done
    echo "coordinator never announced a listening port; log follows:" >&2
    cat "$log" >&2
    return 1
}

python - "$WORK" <<'EOF'
import sys
from repro.rml import generator, serializer
tb = generator.make_testbed("SOM", 200, 0.5, n_poms=2, seed=3)
tb.write(sys.argv[1])
serializer.write_turtle(tb.doc, sys.argv[1] + "/mapping.ttl")
EOF

# the same sources, unsharded and sharded (2 shards, 2 build workers)
python -m repro.launch.rdfize \
    --mapping "$WORK/mapping.ttl" --data-root "$WORK" \
    --out "$WORK/kg.kgz" --emit kgz
python -m repro.launch.rdfize \
    --mapping "$WORK/mapping.ttl" --data-root "$WORK" \
    --out "$WORK/kg.shards.json" --emit kgz --shards 2 --shard-workers 2

# 1) in-process shard session: byte-identical to the single store,
#    routed insert touches exactly one shard
python - "$WORK" <<'EOF'
import sys
from repro import api

work = sys.argv[1]
GN = "<http://repro.org/vocab/gene_name>"
AN = "<http://repro.org/vocab/accession_number>"
QUERIES = [
    f"SELECT * WHERE {{ ?m {GN} ?g }}",
    f"SELECT * WHERE {{ ?m {GN} ?g . ?m {AN} ?a }} LIMIT 10",
    f"SELECT ?g (COUNT(?m) AS ?n) WHERE {{ ?m {GN} ?g }} "
    "GROUP BY ?g ORDER BY DESC(?n)",
    f"SELECT DISTINCT ?g WHERE {{ ?m {GN} ?g }} ORDER BY ?g LIMIT 5",
]
with api.connect(f"{work}/kg.kgz") as single, \
        api.connect(f"{work}/kg.shards.json") as sharded:
    for q in QUERIES:
        a, b = single.query(q), sharded.query(q)
        assert a.rows == b.rows, (q, a.rows[:3], b.rows[:3])
        assert a.n_total == b.n_total, (q, a.n_total, b.n_total)
    r = sharded.insert([["<http://smoke/shard1>", GN, '"sharded-live"']])
    assert r["inserted"] == 1 and r["shards_touched"] == 1, r
    got = sharded.query(f"SELECT ?g WHERE {{ <http://smoke/shard1> {GN} ?g }}")
    assert got.rows == [('"sharded-live"',)], got.rows
print(f"shard session smoke OK: {len(QUERIES)} queries byte-identical, "
      "insert routed to 1 shard")
EOF

# 2) the coordinator server over the wire
python -m repro.launch.serve --kg "$WORK/kg.shards.json" --port 0 \
    2>"$WORK/coord.log" &
SERVER_PID=$!
PORT="$(wait_for_port "$WORK/coord.log")"
echo "[smoke] coordinator is up on port $PORT"

python - "$PORT" <<'EOF'
import sys
from repro import api

GN = "<http://repro.org/vocab/gene_name>"
with api.connect(f"127.0.0.1:{int(sys.argv[1])}", retry_s=30) as c:
    scattered = c.query(f"SELECT * WHERE {{ ?m {GN} ?g }}")
    assert scattered.n_total > 0 and scattered.rows, scattered
    m0, _g0 = scattered.rows[0]
    routed = c.query(f"SELECT ?g WHERE {{ {m0} {GN} ?g }}")
    assert routed.n_total >= 1, routed
    r = c.insert([["<http://smoke/wire1>", GN, '"wire-live"']])
    assert r["inserted"] == 1 and r["shards_touched"] == 1, r
    got = c.query(f"SELECT ?g WHERE {{ <http://smoke/wire1> {GN} ?g }}")
    assert got.rows == [('"wire-live"',)], got.rows
    met = c.metrics()["metrics"]
    cnt = met["counters"]
    # the scatter fanned out to both shards; the routed queries hit one
    assert cnt.get("shard.scattered", 0) >= 1, cnt
    assert cnt.get("shard.routed", 0) >= 2, cnt
    fanout = met["histograms"].get("shard.fanout", {})
    assert fanout.get("count", 0) >= 3 and fanout.get("max") == 2.0, fanout
    print(f"coordinator wire smoke OK: {scattered.n_total} solutions, "
          f"routed={cnt['shard.routed']} scattered={cnt['shard.scattered']} "
          f"shard_requests={cnt['shard.shard_requests']}")
EOF

kill -TERM "$SERVER_PID"
wait "$SERVER_PID" || true

# 3) the CLI front door reads the manifest transparently
OUT="$(python -m repro.launch.query --kg "$WORK/kg.shards.json" \
    'SELECT * WHERE { ?m <http://repro.org/vocab/gene_name> ?g } LIMIT 3' 2>&1)"
echo "$OUT" | grep -q "shards from" || { echo "$OUT"; exit 1; }
echo "shard smoke OK"
