"""Columnar source readers: CSV and JSON-lines, no pandas dependency.

Each reader returns ``dict[column] -> np.ndarray[object]`` — the columnar
form the encoder and pipeline operate on.  Sources are loaded exactly once
per executor run and cached by path (the paper: "avoid ... uploading the
parent triples map's data source of a join multiple times").
"""

from __future__ import annotations

import csv
import json

import numpy as np


def load_csv(path: str, delimiter: str = ",") -> dict[str, np.ndarray]:
    with open(path, newline="", encoding="utf-8") as f:
        reader = csv.reader(f, delimiter=delimiter)
        header = next(reader)
        cols: list[list[str]] = [[] for _ in header]
        for row in reader:
            for i, cell in enumerate(row):
                cols[i].append(cell)
    return {h: np.array(c, dtype=object) for h, c in zip(header, cols)}


def expand_iterator(record, iterator: str | None) -> list:
    """Apply the '$.items'-style dotted iterator path to one parsed record.

    Shared by the eager loader and the streamed JSON datasource so the two
    paths can never drift apart on iterator semantics."""
    if not iterator:
        return [record]
    sel = iterator.lstrip("$").strip(".")
    if not sel:
        return [record]
    node = record
    for part in sel.split("."):
        node = node[part]
    return node if isinstance(node, list) else [node]


def records_to_columns(records: list) -> dict[str, np.ndarray]:
    """Rows -> columns with key union across ALL records (heterogeneous rows
    would otherwise silently drop fields absent from records[0]); missing
    cells become "".  Shared by the eager loader and ``stream.Block``."""
    keys: dict[str, None] = {}
    for r in records:
        for k in r:
            keys.setdefault(k, None)
    return {
        k: np.array([str(r.get(k, "")) for r in records], dtype=object) for k in keys
    }


def load_json(path: str, iterator: str | None = None) -> dict[str, np.ndarray]:
    """JSON-lines or a top-level array; ``iterator`` selects a nested list
    field (a '$.items'-style path with dots)."""
    with open(path, encoding="utf-8") as f:
        head = f.read(1)
        f.seek(0)
        if head == "[":
            records = json.load(f)
        else:
            records = [json.loads(line) for line in f if line.strip()]
    if iterator:
        out = []
        for r in records:
            out.extend(expand_iterator(r, iterator))
        records = out
    if not records:
        return {}
    return records_to_columns(records)


def load(path: str, fmt: str = "csv", iterator: str | None = None):
    if fmt == "csv":
        return load_csv(path)
    if fmt == "tsv":
        return load_csv(path, delimiter="\t")
    if fmt == "json":
        return load_json(path, iterator)
    raise ValueError(f"unsupported source format {fmt!r}")


class SourceCache:
    """Per-run cache so each logical source is read and encoded once."""

    def __init__(self, root: str = "."):
        self.root = root
        self._cache: dict[str, dict[str, np.ndarray]] = {}

    def get(self, source) -> dict[str, np.ndarray]:
        from repro.rml.model import source_key

        key = source_key(source)
        if key not in self._cache:
            import os

            path = source.path
            if not os.path.isabs(path):
                path = os.path.join(self.root, path)
            self._cache[key] = load(path, source.fmt, source.iterator)
        return self._cache[key]
