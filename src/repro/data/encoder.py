"""Dictionary encoding — strings leave the host, int32 ids go to the device.

The global :class:`Dictionary` maps every distinct RDF term *value* to a dense
int32 id.  Equality of ids == equality of strings across columns and sources,
which is what makes join keys comparable on device (DESIGN.md §2).  Bulk
encoding is vectorized with ``np.unique``; only the per-dictionary novel
values pay a Python-dict insertion.
"""

from __future__ import annotations

import numpy as np

_SEP = "\x1f"  # joins multi-column template values; cannot occur in CSV cells


class Dictionary:
    """Bidirectional str <-> int32, append-only."""

    def __init__(self) -> None:
        self._to_id: dict[str, int] = {}
        self._to_str: list[str] = []

    def __len__(self) -> int:
        return len(self._to_str)

    def encode_scalar(self, value: str) -> int:
        vid = self._to_id.get(value)
        if vid is None:
            vid = len(self._to_str)
            self._to_id[value] = vid
            self._to_str.append(value)
        return vid

    def encode(self, values: np.ndarray) -> np.ndarray:
        """Vectorized bulk encode of a 1-D string array -> int32 ids."""
        values = np.asarray(values)
        uniq, inverse = np.unique(values, return_inverse=True)
        uniq_ids = np.fromiter(
            (self.encode_scalar(str(u)) for u in uniq), dtype=np.int32, count=len(uniq)
        )
        return uniq_ids[inverse].astype(np.int32)

    def decode(self, ids: np.ndarray) -> np.ndarray:
        table = np.asarray(self._to_str, dtype=object)
        return table[np.asarray(ids)]

    def decode_scalar(self, vid: int) -> str:
        return self._to_str[int(vid)]

    def strings(self) -> list[str]:
        """The id -> string table (ids are positions) — for persistence."""
        return list(self._to_str)

    @classmethod
    def from_strings(cls, strings: list[str]) -> "Dictionary":
        """Rebuild from a persisted id -> string table."""
        d = cls()
        d._to_str = list(strings)
        d._to_id = {s: i for i, s in enumerate(strings)}
        return d


def join_columns(columns: list[np.ndarray]) -> np.ndarray:
    """Combine multi-placeholder template columns into one value string."""
    if len(columns) == 1:
        return np.asarray(columns[0])
    out = np.asarray(columns[0]).astype(object)
    for col in columns[1:]:
        out = out + _SEP
        out = out + np.asarray(col).astype(object)
    return out


def render_template(pattern: str, value: str) -> str:
    """Inverse of the encoding for output materialization: fill the ``{}``
    slots of a canonical pattern with the (possibly multi-part) value."""
    parts = value.split(_SEP)
    out, i = [], 0
    for chunk in pattern.split("{}"):
        out.append(chunk)
        if i < len(parts):
            out.append(parts[i])
            i += 1
    # pattern.split yields len(parts)+1 chunks for a well-formed pair
    return "".join(out)
