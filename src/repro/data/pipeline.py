"""Fixed-shape batching for jit-stable streaming execution.

The executor streams encoded columns through jitted operators; XLA requires
static shapes, so the tail batch is padded and carries a validity mask.
"""

from __future__ import annotations

from typing import Iterator, NamedTuple

import numpy as np


class Batch(NamedTuple):
    arrays: dict[str, np.ndarray]  # each int32[batch_size]
    valid: np.ndarray              # bool[batch_size]
    start: int                     # global row offset of this batch


def batches(
    columns: dict[str, np.ndarray], batch_size: int
) -> Iterator[Batch]:
    if not columns:
        return
    n = len(next(iter(columns.values())))
    for start in range(0, n, batch_size):
        end = min(start + batch_size, n)
        size = end - start
        pad = batch_size - size
        arrays = {}
        for name, col in columns.items():
            chunk = col[start:end]
            if pad:
                chunk = np.concatenate([chunk, np.zeros(pad, dtype=chunk.dtype)])
            arrays[name] = chunk
        valid = np.zeros(batch_size, dtype=bool)
        valid[:size] = True
        yield Batch(arrays=arrays, valid=valid, start=start)


def pick_batch_size(n_rows: int, target: int = 1 << 16) -> int:
    """Batch size heuristic: one batch for small inputs, else the target."""
    if n_rows <= target:
        return max(int(np.int64(1) << int(np.ceil(np.log2(max(n_rows, 2))))), 2)
    return target
