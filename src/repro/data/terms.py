"""Shared RDF term rendering — N-Triples escaping and (pattern, value) decode.

A term leaves the engine as a *(pattern id, value id)* pair into the global
:class:`~repro.data.encoder.Dictionary`; this module is the single place that
turns the pair back into a concrete N-Triples term string.  It is shared by
``core.executor`` (the N-Triples dump) and ``repro.kg`` (query-time binding
decode), so both emit byte-identical — and *valid* — N-Triples: literals get
full string escaping (backslash, quote, and control characters), not just
``"``.  It lives beside the encoder in ``repro.data`` so the dependency DAG
stays one-directional (``data`` ← ``core`` ← ``kg``).
"""

from __future__ import annotations

import re

from repro.data.encoder import Dictionary, render_template

# N-Triples ECHAR escapes; everything else in the forbidden range goes \uXXXX.
_ESCAPES = {
    "\\": "\\\\",
    '"': '\\"',
    "\n": "\\n",
    "\r": "\\r",
    "\t": "\\t",
    "\b": "\\b",
    "\f": "\\f",
}
_NEEDS_ESCAPE = re.compile(r'[\x00-\x1f"\\\x7f]')
_UNESCAPE = re.compile(r"\\(u[0-9A-Fa-f]{4}|U[0-9A-Fa-f]{8}|.)")
_ECHAR_INV = {v[1]: k for k, v in _ESCAPES.items()}  # 'n' -> '\n', ...


def escape_literal(s: str) -> str:
    """Escape a raw string for an N-Triples STRING_LITERAL_QUOTE body."""
    if not _NEEDS_ESCAPE.search(s):
        return s

    def repl(m: re.Match) -> str:
        ch = m.group(0)
        e = _ESCAPES.get(ch)
        return e if e is not None else f"\\u{ord(ch):04X}"

    return _NEEDS_ESCAPE.sub(repl, s)


def unescape_literal(s: str) -> str:
    """Inverse of :func:`escape_literal` (accepts any valid ECHAR/UCHAR)."""

    def repl(m: re.Match) -> str:
        body = m.group(1)
        if body[0] in "uU":
            return chr(int(body[1:], 16))
        return _ECHAR_INV.get(body, body)

    return _UNESCAPE.sub(repl, s)


def render_term(d: Dictionary, pat_id: int, val_id: int) -> str:
    """(pattern id, value id) -> concrete N-Triples term (``<iri>`` or
    ``"literal"``).  Patterns are the planner's namespaced strings
    (``iri:...`` / ``lit:...``); ``{}`` slots take the dictionary value."""
    pat = d.decode_scalar(pat_id)
    kind, pattern = pat.split(":", 1)
    value = d.decode_scalar(val_id) if "{}" in pattern else ""
    body = render_template(pattern, value) if "{}" in pattern else pattern
    if kind == "iri":
        return f"<{body}>"
    return '"' + escape_literal(body) + '"'


def canonical_term(token: str) -> str:
    """Normalize a user-supplied constant term (``<iri>`` or a quoted
    literal, possibly with escapes) to the exact string :func:`render_term`
    produces, so it can key a rendered-term lookup."""
    token = token.strip()
    if token.startswith("<") and token.endswith(">"):
        return token
    if token.startswith('"') and token.endswith('"') and len(token) >= 2:
        return '"' + escape_literal(unescape_literal(token[1:-1])) + '"'
    raise ValueError(f"not an N-Triples term: {token!r}")
