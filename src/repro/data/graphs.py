"""Graph data substrate: synthetic graphs, batch builders, neighbor sampler.

``NeighborSampler`` is the real fanout sampler required by the
``minibatch_lg`` shape (232,965 nodes / 114.6M edges, fanout 15-10): CSR
adjacency on the host, uniform neighbor sampling per layer, and — the
paper's technique applied to GNNs (DESIGN.md §5) — *deduplication of the
sampled node ids* before feature gather, so each distinct node's features
are fetched once (|N_p| -> |S_p| in the paper's notation).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.gnn.common import GraphBatch


def random_graph(
    n_nodes: int, n_edges: int, d_feat: int, n_classes: int = 7,
    seed: int = 0, task: str = "node_cls", n_graphs: int = 1,
):
    """Synthetic padded GraphBatch with positions (numpy arrays)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    pos = rng.normal(size=(n_nodes, 3)).astype(np.float32)
    graph_id = (
        np.sort(rng.integers(0, n_graphs, size=n_nodes)).astype(np.int32)
        if n_graphs > 1
        else np.zeros(n_nodes, np.int32)
    )
    if task == "node_cls":
        labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
        label_mask = np.ones(n_nodes, bool)
    else:
        labels = rng.normal(size=n_graphs).astype(np.float32)
        label_mask = np.ones(n_graphs, bool)
    return GraphBatch(
        node_feat=feat,
        positions=pos,
        edge_src=src,
        edge_dst=dst,
        node_mask=np.ones(n_nodes, bool),
        edge_mask=np.ones(n_edges, bool),
        labels=labels,
        graph_id=graph_id,
        label_mask=label_mask,
    )


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (E,)
    feat: np.ndarray | None = None
    labels: np.ndarray | None = None

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @classmethod
    def random(cls, n_nodes: int, avg_degree: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        deg = rng.poisson(avg_degree, size=n_nodes).astype(np.int64)
        indptr = np.concatenate([[0], np.cumsum(deg)])
        indices = rng.integers(0, n_nodes, size=int(indptr[-1])).astype(np.int32)
        return cls(indptr=indptr, indices=indices)


class NeighborSampler:
    """Layered uniform neighbor sampling (GraphSAGE-style) with hash dedup.

    Output layout: a padded subgraph whose node table is the deduplicated
    union of all sampled nodes (seeds first), with edges (sampled neighbor ->
    its target) expressed in local indices.  Static output sizes derive from
    batch_nodes x prod(fanouts) worst case; real occupancy carried in masks.
    """

    def __init__(self, graph: CSRGraph, fanouts: tuple[int, ...], seed: int = 0):
        self.graph = graph
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def layer_sizes(self, batch_nodes: int) -> list[int]:
        sizes = [batch_nodes]
        for f in self.fanouts:
            sizes.append(sizes[-1] * f)
        return sizes

    def sample(self, seeds: np.ndarray):
        g = self.graph
        sizes = self.layer_sizes(len(seeds))
        max_nodes = sum(sizes)
        max_edges = sum(sizes[1:])

        all_nodes = [seeds.astype(np.int32)]
        edge_src_g, edge_dst_g = [], []
        frontier = seeds.astype(np.int64)
        for fanout in self.fanouts:
            starts = g.indptr[frontier]
            degs = g.indptr[frontier + 1] - starts
            # uniform with replacement (standard for high-degree graphs)
            offs = (self.rng.random((len(frontier), fanout)) * np.maximum(degs, 1)[:, None]).astype(np.int64)
            neigh = g.indices[starts[:, None] + offs]
            valid = (degs > 0)[:, None] & np.ones_like(neigh, bool)
            edge_src_g.append(neigh[valid].astype(np.int32))
            edge_dst_g.append(
                np.broadcast_to(frontier[:, None], neigh.shape)[valid].astype(np.int32)
            )
            frontier = neigh[valid].astype(np.int64)
            all_nodes.append(frontier.astype(np.int32))

        # ---- the PTT idea: dedup the sampled node multiset before gather
        cat = np.concatenate(all_nodes)
        uniq, inverse = np.unique(cat, return_inverse=True)
        # keep seeds at the front: map seed ids to 0..len(seeds)-1
        seed_pos = inverse[: len(seeds)]
        order = np.concatenate(
            [seed_pos, np.setdiff1d(np.arange(len(uniq)), seed_pos)]
        )
        rank = np.empty_like(order)
        rank[order] = np.arange(len(order))
        node_table = uniq[order].astype(np.int32)

        src = rank[
            np.searchsorted(uniq, np.concatenate(edge_src_g))
        ].astype(np.int32) if edge_src_g else np.zeros(0, np.int32)
        dst = rank[
            np.searchsorted(uniq, np.concatenate(edge_dst_g))
        ].astype(np.int32) if edge_dst_g else np.zeros(0, np.int32)

        n_real = len(node_table)
        e_real = len(src)
        node_ids = np.zeros(max_nodes, np.int32)
        node_ids[:n_real] = node_table
        node_mask = np.zeros(max_nodes, bool)
        node_mask[:n_real] = True
        es = np.zeros(max_edges, np.int32)
        ed = np.zeros(max_edges, np.int32)
        es[:e_real] = src
        ed[:e_real] = dst
        edge_mask = np.zeros(max_edges, bool)
        edge_mask[:e_real] = True
        return {
            "node_ids": node_ids,       # global ids to gather features for
            "node_mask": node_mask,
            "edge_src": es,
            "edge_dst": ed,
            "edge_mask": edge_mask,
            "n_seeds": len(seeds),
            "dedup_ratio": float(len(cat)) / max(n_real, 1),
        }

    def batch(self, seeds: np.ndarray, d_feat: int, n_classes: int = 41) -> GraphBatch:
        """Materialize a GraphBatch (synthetic features when the CSR graph
        carries none — shape-faithful for the dry-run cells)."""
        s = self.sample(seeds)
        g = self.graph
        n = len(s["node_ids"])
        rng = np.random.default_rng(int(seeds[0]))
        if g.feat is not None:
            feat = g.feat[s["node_ids"]]
        else:
            feat = rng.normal(size=(n, d_feat)).astype(np.float32)
        if g.labels is not None:
            labels = g.labels[s["node_ids"]].astype(np.int32)
        else:
            labels = rng.integers(0, n_classes, size=n).astype(np.int32)
        label_mask = np.zeros(n, bool)
        label_mask[: s["n_seeds"]] = True  # loss only on the seed nodes
        return GraphBatch(
            node_feat=feat.astype(np.float32),
            positions=rng.normal(size=(n, 3)).astype(np.float32),
            edge_src=s["edge_src"],
            edge_dst=s["edge_dst"],
            node_mask=s["node_mask"],
            edge_mask=s["edge_mask"],
            labels=labels,
            graph_id=np.zeros(n, np.int32),
            label_mask=label_mask & s["node_mask"],
        )
