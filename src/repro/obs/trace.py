"""Dispatch tracing: timed spans in a ring buffer, exported as Chrome
trace-event JSON (loadable in Perfetto / ``chrome://tracing``).

A :class:`Tracer` records *complete* events (``ph: "X"``): name, category,
start timestamp, duration, thread id, and free-form ``args``.  Events live
in a bounded ring buffer (old spans fall off; a long-lived server never
grows without bound) and are timestamped with ``perf_counter_ns`` relative
to the tracer's epoch, so nested spans from one thread render as a proper
flame graph.

Tracing is off by default and the disabled path is one attribute check —
instrumentation can stay inline on hot paths.  The global tracer is turned
on by the ``--trace out.json`` CLI flags (``rdfize`` / ``query`` /
``serve``); :func:`save_trace` writes the JSON at exit.

    with span("dispatch", cat="serve", plan="1f2e3d4c", batch=64):
        ...                       # timed; recorded only when enabled

    add_complete("queue_wait", "serve", t_enq_ns, t_start_ns, req=7)
        ...                       # retroactive span from raw timestamps
"""

from __future__ import annotations

import collections
import contextlib
import json
import os
import threading
import time

_DEFAULT_CAPACITY = 1 << 16


class Tracer:
    """Ring-buffered span recorder; one per process is the normal mode."""

    def __init__(self, capacity: int = _DEFAULT_CAPACITY):
        self.enabled = False
        self._lock = threading.Lock()
        self._events: collections.deque = collections.deque(maxlen=capacity)
        self._t0_ns = time.perf_counter_ns()
        self.dropped = 0  # events pushed past a full ring

    # -- lifecycle -----------------------------------------------------------

    def enable(self, capacity: int | None = None) -> None:
        with self._lock:
            if capacity is not None:
                self._events = collections.deque(
                    self._events, maxlen=capacity
                )
            self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0
            self._t0_ns = time.perf_counter_ns()

    # -- recording -----------------------------------------------------------

    def add_complete(
        self, name: str, cat: str, t0_ns: int, t1_ns: int, **args
    ) -> None:
        """Record a span from raw ``perf_counter_ns`` endpoints — the form
        used for retroactive spans (queue wait is only known once the
        dispatcher picks the request up)."""
        if not self.enabled:
            return
        ev = {
            "ph": "X",
            "name": name,
            "cat": cat or "default",
            "ts": (t0_ns - self._t0_ns) / 1e3,  # trace-event ts is µs
            "dur": max(t1_ns - t0_ns, 0) / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
        }
        if args:
            ev["args"] = args
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "", **args):
        """Time a block; records on exit (exceptions included — the span
        still lands, so a failing dispatch is visible in the trace)."""
        if not self.enabled:
            yield
            return
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            self.add_complete(name, cat, t0, time.perf_counter_ns(), **args)

    # -- export --------------------------------------------------------------

    def export(self) -> dict:
        """The Chrome trace-event JSON object (``traceEvents`` array form,
        which both Perfetto and ``chrome://tracing`` load directly)."""
        with self._lock:
            events = list(self._events)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def save(self, path: str) -> int:
        """Write the trace JSON; returns the number of events written."""
        doc = self.export()
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])


_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def tracing_enabled() -> bool:
    return _TRACER.enabled


def enable_tracing(capacity: int | None = None) -> None:
    _TRACER.enable(capacity)


def span(name: str, cat: str = "", **args):
    return _TRACER.span(name, cat, **args)


def add_complete(name: str, cat: str, t0_ns: int, t1_ns: int, **args) -> None:
    _TRACER.add_complete(name, cat, t0_ns, t1_ns, **args)


def save_trace(path: str) -> int:
    return _TRACER.save(path)
