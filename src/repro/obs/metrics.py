"""Process metrics: counters, gauges, and log-bucketed latency histograms.

The substrate the ingest / executor / server layers report into — zero
dependencies, one lock, JSON-serializable end to end.

* :class:`Counter` / :class:`Gauge` — monotone totals and last-value
  samples (floats allowed: ``serve.exec_s`` accumulates seconds).
* :class:`Histogram` — fixed log2 major buckets, each split into
  ``SUBBUCKETS`` linear sub-buckets (HdrHistogram-style), so any recorded
  value lands in a bucket whose upper/lower edge ratio is at most
  ``1 + 1/SUBBUCKETS`` (6.25%).  Quantiles are nearest-rank over the
  bucket cumulative counts and return the bucket's upper edge — within
  one bucket's relative error of the exact sample quantile, at any
  magnitude (1µs and 10s latencies share one histogram).  Histograms
  merge associatively (bucket-count addition), which is what makes
  per-shard / per-signature metrics aggregatable.
* :class:`MetricsRegistry` — a named collection of the above behind a
  single lock, so updates from the server's accept/client/dispatch
  threads are atomic (the old hand-rolled ``ServerStats`` counters were
  racy).  ``snapshot()`` returns a plain-dict view that serves as the
  ``metrics`` wire op's payload and the benchmark's metrics artifact.

A process-global registry (:func:`get_registry`) is the default sink for
library instrumentation; tests and embedded servers can pass their own.
"""

from __future__ import annotations

import math
import threading

SUBBUCKETS = 16  # linear sub-buckets per power of two: <= 6.25% bucket width


def bucket_index(value: float) -> int:
    """The histogram bucket of a positive value.

    ``value = m * 2**e`` with ``m in [0.5, 1)`` (``math.frexp``); the
    mantissa picks one of ``SUBBUCKETS`` linear slices of the octave, so
    the flat index is ``e * SUBBUCKETS + slice``.
    """
    m, e = math.frexp(value)
    sub = int((m - 0.5) * 2 * SUBBUCKETS)
    if sub >= SUBBUCKETS:  # m == 1.0 - eps rounding
        sub = SUBBUCKETS - 1
    return e * SUBBUCKETS + sub


def bucket_bounds(idx: int) -> tuple[float, float]:
    """The value interval ``(lower, upper]`` of bucket ``idx``."""
    e, sub = divmod(idx, SUBBUCKETS)
    lo = math.ldexp(0.5 + sub / (2 * SUBBUCKETS), e)
    hi = math.ldexp(0.5 + (sub + 1) / (2 * SUBBUCKETS), e)
    return lo, hi


class Counter:
    """A monotone total (int or float increments)."""

    __slots__ = ("value", "_lock")

    def __init__(self, lock: threading.Lock):
        self.value = 0
        self._lock = lock

    def add(self, n=1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """A last-value (or running-max) sample."""

    __slots__ = ("value", "_lock")

    def __init__(self, lock: threading.Lock):
        self.value = 0
        self._lock = lock

    def set(self, v) -> None:
        with self._lock:
            self.value = v

    def set_max(self, v) -> None:
        with self._lock:
            if v > self.value:
                self.value = v


class Histogram:
    """Log-bucketed distribution; see the module docstring for the bucket
    layout.  Standalone histograms (no lock) are plain accumulators; the
    registry wires its lock in for thread-safe observation."""

    __slots__ = ("buckets", "count", "sum", "max", "zero", "_lock")

    def __init__(self, lock: threading.Lock | None = None):
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.max = 0.0
        self.zero = 0  # non-positive observations (a zero-length wait)
        self._lock = lock

    def observe(self, value: float) -> None:
        if self._lock is None:
            return self._observe(value)
        with self._lock:
            self._observe(value)

    def _observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        if value > self.max:
            self.max = value
        if value <= 0.0:
            self.zero += 1
            return
        idx = bucket_index(value)
        self.buckets[idx] = self.buckets.get(idx, 0) + 1

    def percentile(self, q: float) -> float | None:
        """Nearest-rank quantile estimate: the upper edge of the bucket
        holding the ``ceil(q/100 * count)``-th smallest observation (so
        exact_value <= estimate < exact_value * bucket_width).  ``None``
        on an empty histogram."""
        if self.count == 0:
            return None
        rank = max(1, math.ceil(q / 100.0 * self.count))
        seen = self.zero
        if rank <= seen:
            return 0.0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if rank <= seen:
                return bucket_bounds(idx)[1]
        return self.max  # rank beyond the last bucket: fp edge, cap at max

    def merge(self, other: "Histogram") -> "Histogram":
        """Pointwise bucket addition into ``self`` (associative and
        commutative up to float addition order in ``sum``/``max``)."""
        self.count += other.count
        self.sum += other.sum
        self.max = max(self.max, other.max)
        self.zero += other.zero
        for idx, n in other.buckets.items():
            self.buckets[idx] = self.buckets.get(idx, 0) + n
        return self

    @staticmethod
    def merged(*hists: "Histogram") -> "Histogram":
        out = Histogram()
        for h in hists:
            out.merge(h)
        return out

    def to_dict(self) -> dict:
        d = {
            "count": self.count,
            "sum": self.sum,
            "max": self.max,
            "zero": self.zero,
            "buckets": {str(i): n for i, n in sorted(self.buckets.items())},
        }
        for q in (50, 90, 99):
            d[f"p{q}"] = self.percentile(q)
        return d

    @staticmethod
    def from_dict(d: dict) -> "Histogram":
        h = Histogram()
        h.count = int(d["count"])
        h.sum = float(d["sum"])
        h.max = float(d["max"])
        h.zero = int(d.get("zero", 0))
        h.buckets = {int(i): int(n) for i, n in d["buckets"].items()}
        return h


class MetricsRegistry:
    """Named counters / gauges / histograms behind one lock.

    Names are dotted paths (``serve.queue_wait_ms``); per-key variants
    append ``.key=value`` (``serve.request_ms.sig=1f2e3d4c``).  Metrics
    are created on first touch, so instrumentation never needs
    registration order.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    # -- access (create on first touch) --------------------------------------

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(self._lock))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(self._lock))
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, Histogram(self._lock))
        return h

    # -- shorthands ----------------------------------------------------------

    def inc(self, name: str, n=1) -> None:
        self.counter(name).add(n)

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).observe(value)

    # -- export --------------------------------------------------------------

    def snapshot(self) -> dict:
        """A JSON-ready view of every metric (the ``metrics`` wire op
        payload and the benchmark metrics artifact)."""
        with self._lock:
            return {
                "counters": {
                    k: c.value for k, c in sorted(self._counters.items())
                },
                "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
                "histograms": {
                    k: h.to_dict() for k, h in sorted(self._hists.items())
                },
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-global registry — the default sink for library
    instrumentation (stream readers, the fused executor, CLIs)."""
    return _REGISTRY
