"""repro.obs — the observability substrate: metrics + dispatch tracing.

One import surface for the three instrumented layers (``repro.stream``
block ingestion, the ``repro.serve`` fused executor, the batching query
server) and their consumers (the ``metrics`` wire op, ``--trace`` CLI
flags, the latency columns in ``BENCH_*.json``).

    from repro import obs

    obs.get_registry().inc("serve.queries", 64)
    obs.get_registry().observe("serve.exec_ms", 1.9)
    with obs.span("dispatch", cat="serve", batch=64):
        ...
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    SUBBUCKETS,
    bucket_bounds,
    bucket_index,
    get_registry,
)
from repro.obs.trace import (
    Tracer,
    add_complete,
    enable_tracing,
    get_tracer,
    save_trace,
    span,
    tracing_enabled,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SUBBUCKETS",
    "Tracer",
    "add_complete",
    "bucket_bounds",
    "bucket_index",
    "enable_tracing",
    "get_registry",
    "get_tracer",
    "save_trace",
    "span",
    "tracing_enabled",
]
