"""jax version-compat shims, dependency-neutral (imports only jax).

The repo targets current jax APIs; on older jax (0.4.x, no
``get_abstract_mesh`` / ``jax.set_mesh`` / ``jax.shard_map`` /
``AxisType``) these wrappers fall back to the legacy equivalents.  Every
layer (core, models, launch, tests) should use these instead of touching
the jax API surface directly.
"""

from __future__ import annotations

import jax


def pallas_native() -> bool:
    """True when the active jax backend compiles Pallas kernels natively
    (TPU/GPU).  On CPU hosts Pallas only runs under ``interpret=True`` —
    correct but slow — so production call sites (the serving fast path)
    use this gate to pick the fused-kernel launch on accelerators and the
    jitted reference formulation on CPU, while tests exercise the kernel
    in interpret mode regardless of backend."""
    try:
        return jax.default_backend() in ("tpu", "gpu")
    except Exception:  # pragma: no cover - backend probing never raises
        return False


def current_mesh():
    """The active mesh: the abstract mesh on new jax, the ``with mesh:``
    context mesh on jax<=0.4 (no ``get_abstract_mesh``)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax._src import mesh as _mesh_impl

    return _mesh_impl.thread_resources.env.physical_mesh


def set_mesh(mesh):
    """``jax.set_mesh(mesh)`` when available, else the Mesh context manager
    (both are used as ``with set_mesh(mesh):``)."""
    setter = getattr(jax, "set_mesh", None)
    return setter(mesh) if setter is not None else mesh


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer jax."""
    kwargs = (
        {"axis_types": (jax.sharding.AxisType.Auto,) * len(axes)}
        if hasattr(jax.sharding, "AxisType")
        else {}
    )
    return jax.make_mesh(shape, axes, **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across jax versions (older jax ships it as
    ``jax.experimental.shard_map`` with ``check_rep`` for ``check_vma``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as legacy

    return legacy(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
