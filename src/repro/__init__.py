"""repro — SDM-RDFizer as a production-grade multi-pod JAX framework.

The paper's contribution (PTT/PJTT physical data structures + SOM/ORM/OJM
operators for duplicate-free RDF knowledge-graph creation) lives in
``repro.core``.  The surrounding substrate — RML parsing, data pipeline,
the assigned model architectures, distributed training/serving, launchers —
lives in sibling subpackages.  See DESIGN.md for the system inventory.
"""

__version__ = "1.0.0"
