"""Subject-hash partitioning — one ``TripleStore`` into N shard stores.

The assignment rule (pinned by the manifest's ``partition`` spec) is

    shard(triple) = crc32(utf-8 rendered subject term) % n_shards

Term *ids* are ranks of rendered term strings and therefore differ
between builds (and between shards), so the hash runs over the rendered
subject — the stable content those ids rank.  Everything downstream
leans on one consequence: all triples sharing a subject land on one
shard, so any solution whose matched triples share a subject (single
patterns, star BGPs, bound-subject queries) is found on exactly one
shard and on no other — scatter/gather needs no cross-shard dedup.

Each shard store is a normal :class:`~repro.kg.store.TripleStore` built
with :meth:`~repro.kg.store.TripleStore.from_ntriples`, carrying its own
term dictionary; results cross the merge as rendered terms, whose sort
order equals every store's term-id order, so the coordinator's merge
reproduces the unsharded engine's deterministic ordering exactly.
"""

from __future__ import annotations

import zlib

import numpy as np

from repro.kg.store import TripleStore

# bump when the assignment rule changes; load_manifest rejects specs this
# build cannot reproduce
HASH_NAME = "crc32"
PARTITION_SPEC = {"by": "subject", "hash": HASH_NAME}


def shard_of_term(rendered_subject: str, n_shards: int) -> int:
    """The shard a subject's triples live on.  crc32 is stable across
    Python versions, processes and platforms — a manifest written on one
    machine routes identically on every other."""
    if n_shards <= 1:
        return 0
    return zlib.crc32(rendered_subject.encode("utf-8")) % n_shards


def partition_triples(
    triples, n_shards: int
) -> "list[list[tuple[str, str, str]]]":
    """Rendered ``(s, p, o)`` triples -> one bucket per shard."""
    buckets: list[list[tuple[str, str, str]]] = [[] for _ in range(n_shards)]
    for t in triples:
        buckets[shard_of_term(t[0], n_shards)].append(tuple(t))
    return buckets


def partition_store(
    store: TripleStore, n_shards: int
) -> "list[list[tuple[str, str, str]]]":
    """Partition an existing store's triples by subject hash.  Hashing is
    vectorized over *distinct* subject ids (each rendered once), then
    broadcast to the triple rows — O(distinct subjects) string work."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    subj_ids = np.unique(store.s)
    shard_by_id = np.zeros(
        int(subj_ids.max()) + 1 if len(subj_ids) else 1, np.int32
    )
    for tid in subj_ids:
        shard_by_id[int(tid)] = shard_of_term(
            store.decode_term(int(tid)), n_shards
        )
    row_shard = shard_by_id[store.s] if store.n_triples else np.zeros(0, np.int32)
    buckets: list[list[tuple[str, str, str]]] = [[] for _ in range(n_shards)]
    for i in range(store.n_triples):
        buckets[int(row_shard[i])].append(
            (
                store.decode_term(int(store.s[i])),
                store.decode_term(int(store.p[i])),
                store.decode_term(int(store.o[i])),
            )
        )
    return buckets


def build_shard_stores(
    store: TripleStore, n_shards: int
) -> "list[TripleStore]":
    """Partition and build the N shard stores in-process (the test/local
    path; :mod:`repro.shard.ingest` adds the persisted, multi-process
    variant)."""
    return [
        TripleStore.from_ntriples(bucket)
        for bucket in partition_store(store, n_shards)
    ]
