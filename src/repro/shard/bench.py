"""Scatter/gather serving cost across shard counts (``BENCH_shard.json``).

Two query classes through the in-process :class:`~repro.shard.coordinator.
ShardGroup` (``_LocalBackend`` per shard — the ``api.connect(<manifest>)``
path, no sockets, so the numbers isolate the dispatch/merge overhead from
wire costs), each at shard counts 1 / 2 / 4 plus the unsharded
:class:`~repro.api.LocalSession` baseline:

* ``routed_single``  — ``<s> <p> ?o`` with the subject bound: the router
  hashes the subject and dispatches to exactly **one** shard (asserted via
  the ``shard.shard_requests`` counter — ``fanout_per_query`` must be 1.0),
  so its per-query cost should track the unsharded baseline;
* ``scatter_bgp3``   — a 3-pattern star BGP anchored at a constant object:
  every shard executes, the gatherer merges in global term order, so its
  per-query cost pays one dispatch per shard plus the merge.

Every query is derived from an existing triple (non-empty answers), with
constants varied per query and one plan signature per class — the
coordinator's steady state.  A representative query per class is answered
on every config and checked byte-identical against the baseline, so the
bench doubles as a parity smoke.

The report's ``queries_per_s`` / ``latency_p99_ms`` leaves are gated by
``benchmarks/compare.py`` once ``BENCH_shard.json`` is committed; the
``criteria`` section records the two acceptance ratios directly
(scatter bgp3 at 2 shards within 2.5x of the single-store per-query
cost, routed within 25% of the unsharded baseline).
"""

from __future__ import annotations

import time

import numpy as np

from repro.api import LocalSession
from repro.kg.store import TripleStore
from repro.obs import Histogram, MetricsRegistry

SHARD_COUNTS = (1, 2, 4)


def _workload(store: TripleStore, n_queries: int, seed: int):
    """(routed texts, scatter texts): non-empty queries with varied
    constants and one plan signature per class."""
    rng = np.random.default_rng(seed)
    ids, counts = np.unique(np.asarray(store.p), return_counts=True)
    if len(ids) < 3:
        raise ValueError("shard bench needs >= 3 predicates in the store")
    order = np.argsort(counts)
    p0, p1, p2 = (int(ids[i]) for i in order[-3:])
    t0, t1, t2 = (store.decode_term(p) for p in (p0, p1, p2))

    rows0 = np.nonzero(np.asarray(store.p) == p0)[0]
    pick = rows0[rng.integers(0, len(rows0), n_queries)]
    routed = [
        f"SELECT ?o WHERE {{ {store.decode_term(int(store.s[i]))} {t0} ?o }}"
        for i in pick
    ]
    anchors = store.o[rows0[rng.integers(0, len(rows0), n_queries)]]
    scatter = [
        f"SELECT * WHERE {{ ?m {t0} {store.decode_term(int(o))} . "
        f"?m {t1} ?b . ?m {t2} ?c }}"
        for o in anchors
    ]
    return routed, scatter


N_PASSES = 2


def _time_queries(session, texts: "list[str]") -> dict:
    """Per-query wall/latency through a session, one query per call (the
    interactive regime the acceptance ratios are stated in).  The warm-up
    replays the full workload once so compilation and the executor's
    capacity feedback converge on every shard before the timed passes —
    otherwise a late capacity recompile on one shard pollutes the p99.
    Each query is timed over ``N_PASSES`` passes and its best lap kept:
    one-off scheduler/GC stalls land in *some* lap of *some* pass, and a
    128-sample p99 is two bad laps away from garbage otherwise."""
    for text in texts:
        session.query(text)
    best = [float("inf")] * len(texts)
    for _ in range(N_PASSES):
        for j, text in enumerate(texts):
            d0 = time.perf_counter_ns()
            session.query(text)
            lap = (time.perf_counter_ns() - d0) / 1e6
            if lap < best[j]:
                best[j] = lap
    lat = Histogram()
    for lap in best:
        lat.observe(lap)
    wall = sum(best) / 1e3
    return {
        "n_queries": len(texts),
        "wall_s": wall,
        "queries_per_s": len(texts) / wall,
        "latency_p50_ms": lat.percentile(50),
        "latency_p99_ms": lat.percentile(99),
        "latency_max_ms": lat.max,
    }


def _sharded_session(store: TripleStore, n_shards: int):
    """An in-process ShardSession over ``n_shards`` partitions of
    ``store``, with its own registry so fan-out counters are per-config."""
    from repro.shard.coordinator import ShardGroup, ShardSession, _LocalBackend
    from repro.shard.partition import build_shard_stores

    registry = MetricsRegistry()
    backends = [
        _LocalBackend(LocalSession(s)) for s in build_shard_stores(store, n_shards)
    ]
    return ShardSession(ShardGroup(backends, registry=registry)), registry


def bench_shard(
    store: TripleStore,
    shard_counts: tuple[int, ...] = SHARD_COUNTS,
    n_queries: int = 128,
    seed: int = 0,
) -> dict:
    """Time both classes on the unsharded baseline and every shard count;
    returns a json-ready report keyed ``{class: {configs: {...}}}`` plus
    the two acceptance ratios under ``criteria``."""
    routed_texts, scatter_texts = _workload(store, n_queries, seed)
    classes = {
        "routed_single": routed_texts,
        "scatter_bgp3": scatter_texts,
    }
    report: dict = {
        "n_triples": int(store.n_triples),
        "n_terms": int(store.n_terms),
        "shard_counts": list(shard_counts),
        "classes": {
            name: {"query": texts[0], "configs": {}}
            for name, texts in classes.items()
        },
    }

    base = LocalSession(store)
    expected = {
        name: (sorted(base.query(texts[0]).rows), base.query(texts[0]).n_total)
        for name, texts in classes.items()
    }
    for name, texts in classes.items():
        leaf = _time_queries(base, texts)
        leaf["fanout_per_query"] = 1.0
        report["classes"][name]["configs"]["unsharded"] = leaf

    for n in shard_counts:
        session, registry = _sharded_session(store, n)
        try:
            for name, texts in classes.items():
                got = session.query(texts[0])
                assert (sorted(got.rows), got.n_total) == expected[name], (
                    f"{name} diverged at {n} shards"
                )
                req0 = registry.counter("shard.shard_requests").value
                leaf = _time_queries(session, texts)
                reqs = registry.counter("shard.shard_requests").value - req0
                # the warm-up pass fans out like the N_PASSES timed ones
                leaf["fanout_per_query"] = reqs / ((N_PASSES + 1) * len(texts))
                report["classes"][name]["configs"][f"shards{n}"] = leaf
            report.setdefault("fanout", {})[f"shards{n}"] = {
                "routed": registry.counter("shard.routed").value,
                "scattered": registry.counter("shard.scattered").value,
                "decomposed": registry.counter("shard.decomposed").value,
                "shard_requests": registry.counter("shard.shard_requests").value,
            }
            if n > 1:
                routed_fanout = report["classes"]["routed_single"]["configs"][
                    f"shards{n}"
                ]["fanout_per_query"]
                assert routed_fanout == 1.0, (
                    f"routed queries touched {routed_fanout} shards at N={n}"
                )
        finally:
            session.close()

    cfg = report["classes"]
    base_cost = {
        name: cfg[name]["configs"]["unsharded"]["wall_s"]
        / cfg[name]["configs"]["unsharded"]["n_queries"]
        for name in classes
    }
    if 2 in shard_counts:
        two = {
            name: cfg[name]["configs"]["shards2"]["wall_s"]
            / cfg[name]["configs"]["shards2"]["n_queries"]
            for name in classes
        }
        report["criteria"] = {
            # acceptance: <= 2.5x single-store per-query cost at 2 shards
            "scatter_bgp3_shards2_cost_ratio":
                two["scatter_bgp3"] / base_cost["scatter_bgp3"],
            # acceptance: within 25% of the unsharded baseline throughput
            "routed_single_shards2_qps_frac":
                cfg["routed_single"]["configs"]["shards2"]["queries_per_s"]
                / cfg["routed_single"]["configs"]["unsharded"]["queries_per_s"],
        }
    return report
