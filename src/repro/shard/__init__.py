"""repro.shard — hash-partitioned stores, scatter/gather serving, sharded
ingestion.

A sharded KG is N ordinary ``.kgz`` stores (one term dictionary each)
plus one JSON manifest pinning the partition rule
(crc32 of the rendered subject term, modulo N — see
:mod:`repro.shard.partition` and the manifest format in
:mod:`repro.kg.persist`).  Build one with ``rdfize --shards N`` or
:func:`repro.shard.ingest.ingest_sharded`; query it through
``repro.api.connect(<manifest>)`` (in-process) or a
:class:`repro.shard.coordinator.Coordinator` (the NDJSON server face,
``launch.serve --kg <manifest>``).  The merge that makes shard answers
byte-identical to the unsharded engine lives in
:mod:`repro.shard.merge`.
"""

from repro.shard.coordinator import (  # noqa: F401
    Coordinator,
    ShardGroup,
    ShardLink,
    ShardSession,
    connect_shard_group,
    open_shard_group,
    spawn_shard_servers,
)
from repro.shard.ingest import ingest_sharded, shard_store  # noqa: F401
from repro.shard.merge import choose_dispatch  # noqa: F401
from repro.shard.partition import (  # noqa: F401
    PARTITION_SPEC,
    build_shard_stores,
    partition_store,
    partition_triples,
    shard_of_term,
)
