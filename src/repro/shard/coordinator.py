"""Scatter/gather serving over N hash-partitioned shard stores.

Layers, bottom up:

* :class:`ShardLink` — one pipelined NDJSON socket to a shard server:
  ``request_many`` writes a whole micro-batch in one send and correlates
  the replies by ``id`` (the shard's dispatcher may answer signature
  groups out of order), so a scattered batch reaches the shard's linger
  window together and micro-batches *there* too.
* backends — one per shard, same contract either way:
  :class:`_SocketBackend` (a :class:`ShardLink`) or :class:`_LocalBackend`
  (an in-process :class:`repro.api.LocalSession`); errors come back as
  structured ``{"error", "code"}`` dicts, never exceptions, so one bad
  query cannot abort a whole gathered batch.
* :class:`ShardGroup` — the dispatch/merge brain: per query it picks
  routed / scatter / decompose (:func:`repro.shard.merge.choose_dispatch`),
  fans sub-requests out (shards run concurrently on a thread pool),
  merges with :mod:`repro.shard.merge`, routes mutations by subject hash,
  and counts fan-out in :mod:`repro.obs`
  (``shard.routed`` / ``shard.scattered`` / ``shard.decomposed`` /
  ``shard.shard_requests``, ``shard.fanout`` + per-shard
  ``shard.request_ms.shard=K`` histograms).
* :class:`ShardSession` — the :class:`repro.api.Session` face over a
  group, what ``repro.api.connect(<manifest>)`` hands back.
* :class:`Coordinator` — the NDJSON TCP server face: accepts ordinary
  client requests, micro-batches them per plan signature exactly like
  ``serve.server.KGServer`` (mutations are ordering barriers), and
  answers through a :class:`ShardGroup`.  Clients cannot tell it from a
  single-store server.
"""

from __future__ import annotations

import dataclasses
import json
import queue
import socket
import sys
import threading
import time
from concurrent.futures import ThreadPoolExecutor

from repro.api import LocalSession, Session, QueryResult
from repro.api.errors import KGError, ProtocolError, error_from_reply
from repro.obs import MetricsRegistry, get_registry
from repro.serve import algebra
from repro.serve.server import track_sig
from repro.shard import merge as M
from repro.shard.partition import shard_of_term


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------


class ShardLink:
    """One persistent connection to a shard server, pipelined: a batch of
    requests goes out as one write, replies are re-ordered by ``id``."""

    def __init__(
        self, host: str, port: int, timeout: float = 30.0, retry_s: float = 0.0
    ):
        deadline = time.monotonic() + retry_s
        while True:
            try:
                self._sock = socket.create_connection(
                    (host, port), timeout=timeout
                )
                break
            except OSError:
                if time.monotonic() >= deadline:
                    raise
                time.sleep(0.05)
        self._rfile = self._sock.makefile("r", encoding="utf-8")
        self._lock = threading.Lock()
        self._next_id = 0

    def request_many(self, reqs: "list[dict]") -> "list[dict]":
        """Send every request, then collect exactly one reply each,
        matched by ``id`` — arrival order is the shard dispatcher's
        business, not ours."""
        if not reqs:
            return []
        with self._lock:
            ids = []
            lines = []
            for r in reqs:
                self._next_id += 1
                ids.append(self._next_id)
                # "_"-prefixed keys are in-process hints (the pre-parsed
                # query object for local backends) — never wire payload
                wire = {k: v for k, v in r.items() if not k.startswith("_")}
                lines.append(json.dumps({"id": self._next_id, **wire}))
            self._sock.sendall(("\n".join(lines) + "\n").encode("utf-8"))
            by_id: dict = {}
            for _ in reqs:
                line = self._rfile.readline()
                if not line:
                    raise ProtocolError("shard closed the connection")
                reply = json.loads(line)
                by_id[reply.get("id")] = reply
        try:
            return [by_id[i] for i in ids]
        except KeyError as e:
            raise ProtocolError(f"shard dropped request id {e}") from e

    def request(self, req: dict) -> dict:
        return self.request_many([req])[0]

    def close(self) -> None:
        try:
            self._rfile.close()
            self._sock.close()
        except OSError:
            pass


class _SocketBackend:
    def __init__(self, link: ShardLink):
        self.link = link

    def run(self, reqs: "list[dict]") -> "list[dict]":
        return self.link.request_many(reqs)

    def close(self) -> None:
        self.link.close()


class _LocalBackend:
    """The same request/reply contract over an in-process session — what
    ``api.connect(<manifest>)`` serves through, no sockets involved."""

    def __init__(self, session: LocalSession):
        self.session = session

    def run(self, reqs: "list[dict]") -> "list[dict]":
        out = []
        for r in reqs:
            try:
                op = r.get("op")
                if op is None:
                    res = self.session.query(
                        r.get("query"),
                        limit=r.get("limit"),
                        parsed=r.get("_q"),
                    )
                    # to_dict() copies every row into a list for the json
                    # wire; in-process the tuples pass through untouched
                    # (json serializes tuples as arrays anyway)
                    reply = {
                        "vars": list(res.vars),
                        "rows": res.rows,
                        "n_total": res.n_total,
                        "batch_size": res.batch_size,
                        "latency_ms": round(res.latency_ms, 3),
                    }
                    if res.agg_vars:
                        reply["agg_vars"] = list(res.agg_vars)
                    out.append(reply)
                elif op == "explain":
                    out.append({"plan": self.session.explain(r.get("query"))})
                elif op == "insert":
                    out.append(self.session.insert(r.get("triples")))
                elif op == "delete":
                    out.append(self.session.delete(r.get("triples")))
                elif op == "compact":
                    out.append(self.session.compact())
                else:
                    out.append(
                        {"error": f"unknown op {op!r}", "code": "bad_request"}
                    )
            except KGError as e:
                out.append(
                    {"error": str(e), "code": e.code or "internal"}
                )
            except Exception as e:  # noqa: BLE001 — mirror the server's catch
                out.append(
                    {"error": f"{type(e).__name__}: {e}", "code": "internal"}
                )
        return out

    def close(self) -> None:
        self.session.close()


# ---------------------------------------------------------------------------
# the dispatch/merge brain
# ---------------------------------------------------------------------------


def _tuple_rows(rows) -> "list[tuple]":
    """Rows as tuples: socket replies carry json lists, in-process replies
    already carry tuples (left untouched — no per-row copy)."""
    if rows and not isinstance(rows[0], tuple):
        return [tuple(r) for r in rows]
    return rows if isinstance(rows, list) else list(rows)


@dataclasses.dataclass
class _Item:
    """One client query inside a gathered group."""

    text: str
    limit: int | None
    q: algebra.SelectQuery | None = None
    error: dict | None = None


class ShardGroup:
    """N shard backends behind one query/mutation surface with exact
    single-store semantics (see :mod:`repro.shard.merge` for the modes
    and their correctness arguments)."""

    def __init__(
        self,
        backends: list,
        registry: MetricsRegistry | None = None,
        max_rows: int = 1000,
    ):
        if not backends:
            raise ValueError("a shard group needs at least one backend")
        self.backends = list(backends)
        self.n_shards = len(self.backends)
        self.registry = registry if registry is not None else get_registry()
        self.max_rows = max_rows
        self.registry.gauge("shard.n_shards").set(self.n_shards)
        self._req_ms = [
            f"shard.request_ms.shard={i}" for i in range(self.n_shards)
        ]
        self._pool = (
            ThreadPoolExecutor(
                max_workers=self.n_shards, thread_name_prefix="shard-gather"
            )
            if self.n_shards > 1
            else None
        )

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
        for b in self.backends:
            b.close()

    # -- fan-out plumbing ---------------------------------------------------

    def _run_on(
        self, requests_by_shard: "dict[int, list[dict]]"
    ) -> "dict[int, list[dict]]":
        """Run each shard's request list, shards concurrently; every
        sub-request lands in the fan-out counters and the per-shard
        latency histograms."""
        reg = self.registry
        for sid, reqs in requests_by_shard.items():
            reg.inc("shard.shard_requests", len(reqs))

        def run_one(sid: int, reqs: "list[dict]") -> "list[dict]":
            t0 = time.perf_counter_ns()
            replies = self.backends[sid].run(reqs)
            reg.observe(
                self._req_ms[sid], (time.perf_counter_ns() - t0) / 1e6
            )
            return replies

        items = list(requests_by_shard.items())
        if self._pool is None or len(items) == 1:
            return {sid: run_one(sid, reqs) for sid, reqs in items}
        # the gather thread does one shard's work itself instead of idling
        # on futures — one fewer pool round-trip per fan-out
        futures = {
            sid: self._pool.submit(run_one, sid, reqs)
            for sid, reqs in items[1:]
        }
        out = {items[0][0]: run_one(*items[0])}
        out.update({sid: f.result() for sid, f in futures.items()})
        return out

    # -- queries ------------------------------------------------------------

    def execute_query(self, text: str, limit: int | None = None) -> dict:
        """One query -> one wire-shaped reply dict (``error``/``code`` on
        failure — callers pick exceptions or passthrough)."""
        return self.execute_query_group([_Item(text=text, limit=limit)])[0]

    def execute_query_group(self, items: "list[_Item]") -> "list[dict]":
        """A micro-batch (one plan signature, when called by the
        coordinator) -> one reply per item, order preserved.  Routed
        items sub-group by target shard; scattered items ship to every
        shard in a single pipelined batch per shard."""
        reg = self.registry
        t0 = time.perf_counter_ns()
        replies: "list[dict | None]" = [None] * len(items)
        routed: "dict[int, list[int]]" = {}
        scattered: "list[int]" = []
        decomposed: "list[int]" = []
        for i, it in enumerate(items):
            if it.error is not None:
                replies[i] = it.error
                continue
            if it.q is None:
                try:
                    it.q = algebra.parse_select(it.text)
                except ValueError as e:
                    replies[i] = {"error": str(e), "code": "parse"}
                    continue
            mode, target = M.choose_dispatch(it.q, self.n_shards)
            if mode == M.ROUTED:
                routed.setdefault(target, []).append(i)
            elif mode == M.SCATTER:
                scattered.append(i)
            else:
                decomposed.append(i)

        if routed:
            reg.inc("shard.routed", sum(len(v) for v in routed.values()))
            requests = {
                sid: [
                    {"query": items[i].text, "_q": items[i].q, **(
                        {"limit": items[i].limit}
                        if items[i].limit is not None else {}
                    )}
                    for i in idxs
                ]
                for sid, idxs in routed.items()
            }
            for sid, shard_replies in self._run_on(requests).items():
                for i, reply in zip(routed[sid], shard_replies):
                    replies[i] = reply  # single-shard truth: pass through
                    reg.observe("shard.fanout", 1)

        if scattered:
            reg.inc("shard.scattered", len(scattered))
            self._run_scattered(items, scattered, replies)

        for i in decomposed:
            reg.inc("shard.decomposed")
            replies[i] = self._run_decomposed(items[i])

        reg.observe("shard.gather_ms", (time.perf_counter_ns() - t0) / 1e6)
        return replies

    def _run_scattered(
        self,
        items: "list[_Item]",
        idxs: "list[int]",
        replies: "list[dict | None]",
    ) -> None:
        subs = []
        for i in idxs:
            q = items[i].q
            sub = M.scatter_query(q)
            cap = items[i].limit if items[i].limit is not None else self.max_rows
            subs.append({
                # an unchanged sub-query ships the client's own text
                "query": items[i].text if sub is q else algebra.to_text(sub),
                "_q": sub,
                "limit": M.scatter_decode_limit(q, cap),
            })
        per_shard = self._run_on(
            {sid: list(subs) for sid in range(self.n_shards)}
        )
        for pos, i in enumerate(idxs):
            q = items[i].q
            shard_replies = [per_shard[sid][pos] for sid in range(self.n_shards)]
            err = next((r for r in shard_replies if r.get("error")), None)
            if err is not None:
                replies[i] = {"error": err["error"], "code": err.get("code")}
                continue
            rows, n_total = M.merge_scatter(
                q,
                [
                    (_tuple_rows(rep.get("rows", ())),
                     int(rep.get("n_total", 0)))
                    for rep in shard_replies
                ],
            )
            cap = items[i].limit if items[i].limit is not None else self.max_rows
            reply = {
                "vars": shard_replies[0].get("vars", list(q.out_vars())),
                "rows": rows[:cap],
                "n_total": n_total,
                "batch_size": len(idxs),
                "latency_ms": max(
                    float(r.get("latency_ms", 0.0)) for r in shard_replies
                ),
            }
            if shard_replies[0].get("agg_vars"):
                reply["agg_vars"] = shard_replies[0]["agg_vars"]
            replies[i] = reply
            self.registry.observe("shard.fanout", self.n_shards)

    def _run_decomposed(self, item: _Item) -> dict:
        """Chains and friends: gather each pattern's matches (single
        patterns partition cleanly by their own subject), then run the
        oracle's algebra tail host-side."""
        q = item.q
        subs = M.decompose_queries(q)
        requests: "dict[int, list[dict]]" = {}
        slots: "list[list[tuple[int, int]]]" = []  # per sub: (shard, pos)
        for sub, subject in subs:
            targets = (
                [shard_of_term(subject, self.n_shards)]
                if subject is not None
                else range(self.n_shards)
            )
            placed = []
            for sid in targets:
                lst = requests.setdefault(sid, [])
                placed.append((sid, len(lst)))
                lst.append(
                    {"query": algebra.to_text(sub), "_q": sub,
                     "limit": M.BIG_LIMIT}
                )
            slots.append(placed)
        per_shard = self._run_on(requests)
        fanout = len(requests)
        pattern_sols = []
        for (sub, _subject), placed in zip(subs, slots):
            shard_rows = []
            for sid, pos in placed:
                rep = per_shard[sid][pos]
                if rep.get("error"):
                    return {"error": rep["error"], "code": rep.get("code")}
                shard_rows.append(_tuple_rows(rep.get("rows", ())))
            pattern_sols.append(M.pattern_rows_to_solutions(sub, shard_rows))
        rows, n_total = M.combine_decomposed(q, pattern_sols)
        cap = item.limit if item.limit is not None else self.max_rows
        reply = {
            "vars": list(q.out_vars()),
            "rows": rows[:cap],
            "n_total": n_total,
            "batch_size": 1,
            "latency_ms": 0.0,
        }
        if q.agg is not None:
            reply["agg_vars"] = [q.agg.alias]
        self.registry.observe("shard.fanout", fanout)
        return reply

    # -- mutations / misc ---------------------------------------------------

    def mutate(self, op: str, triples=None) -> dict:
        """insert/delete route each triple to its subject's shard;
        compact broadcasts.  The merged reply sums counts and reports the
        *total* triple count across shards."""
        if op == "compact":
            requests = {
                sid: [{"op": "compact"}] for sid in range(self.n_shards)
            }
        else:
            buckets: "dict[int, list[list[str]]]" = {}
            for t in triples:
                sid = shard_of_term(t[0], self.n_shards)
                buckets.setdefault(sid, []).append([t[0], t[1], t[2]])
            requests = {
                sid: [{"op": op, "triples": ts}]
                for sid, ts in buckets.items()
            }
        merged: dict = {}
        n_total = 0
        generation = 0
        for sid, reps in self._run_on(requests).items():
            rep = reps[0]
            if rep.get("error"):
                return {"error": rep["error"], "code": rep.get("code")}
            for key in ("inserted", "deleted", "tombstoned"):
                if key in rep:
                    merged[key] = merged.get(key, 0) + rep[key]
            if "compacted" in rep:
                merged["compacted"] = True
                merged["compact_ms"] = round(
                    merged.get("compact_ms", 0.0) + rep.get("compact_ms", 0.0),
                    3,
                )
            n_total += int(rep.get("n_total", 0))
            generation = max(generation, int(rep.get("generation", 0)))
        merged["n_total"] = n_total
        merged["generation"] = generation
        merged["shards_touched"] = len(requests)
        return merged

    def explain(self, text: str) -> dict:
        """The dispatch decision, plus the routed/first shard's own plan."""
        try:
            q = algebra.parse_select(text)
        except ValueError as e:
            return {"error": str(e), "code": "parse"}
        mode, target = M.choose_dispatch(q, self.n_shards)
        sid = target if mode == M.ROUTED else 0
        rep = self.backends[sid].run([{"op": "explain", "query": text}])[0]
        if rep.get("error"):
            return rep
        where = (
            f"shard {target}" if mode == M.ROUTED
            else f"all {self.n_shards} shards"
        )
        return {"plan": f"shard:{mode} -> {where}\n{rep.get('plan', '')}"}


# ---------------------------------------------------------------------------
# opening groups
# ---------------------------------------------------------------------------


def open_shard_group(
    manifest_path: str,
    read_only: bool = False,
    registry: MetricsRegistry | None = None,
    max_rows: int = 1000,
) -> ShardGroup:
    """In-process group over a manifest's shard stores (no sockets) — the
    ``api.connect(<manifest>)`` path.  Mutable by default: each shard
    loads as a :class:`~repro.live.delta.LiveStore` chain, so inserts
    route and apply exactly like against a single live store."""
    from repro.kg import persist

    m = persist.load_manifest(manifest_path)
    # a long-lived coordinator holds every shard open; make sure the
    # open_store LRU is not evicting (and re-validating) them in a cycle
    _size, cap = persist.open_store_cache_info()
    if m["n_shards"] + 2 > cap:
        persist.set_open_store_cache_size(m["n_shards"] + 2)
    sessions = []
    for entry in m["shards"]:
        if read_only:
            sessions.append(
                LocalSession(
                    persist.open_store(entry["abs_path"]), read_only=True
                )
            )
        else:
            sessions.append(LocalSession(persist.load_chain(entry["abs_path"])))
    return ShardGroup(
        [_LocalBackend(s) for s in sessions],
        registry=registry,
        max_rows=max_rows,
    )


def connect_shard_group(
    addresses: "list[str]",
    retry_s: float = 0.0,
    timeout: float = 30.0,
    registry: MetricsRegistry | None = None,
    max_rows: int = 1000,
) -> ShardGroup:
    """Group over already-running shard servers (``"host:port"`` each)."""
    backends = []
    for addr in addresses:
        host, _, port = addr.rpartition(":")
        backends.append(
            _SocketBackend(
                ShardLink(
                    host or "127.0.0.1", int(port),
                    timeout=timeout, retry_s=retry_s,
                )
            )
        )
    return ShardGroup(backends, registry=registry, max_rows=max_rows)


def spawn_shard_servers(
    manifest_path: str,
    read_only: bool = False,
    registry: MetricsRegistry | None = None,
):
    """Start one in-process :class:`~repro.serve.server.KGServer` per
    shard store (port 0 each) and return ``(servers, addresses)`` — the
    coordinator's self-hosting path, exercising the real wire protocol
    without separate shard processes."""
    from repro.kg import persist
    from repro.live.delta import LiveStore
    from repro.serve.server import KGServer

    m = persist.load_manifest(manifest_path)
    _size, cap = persist.open_store_cache_info()
    if m["n_shards"] + 2 > cap:
        persist.set_open_store_cache_size(m["n_shards"] + 2)
    servers = []
    for entry in m["shards"]:
        if read_only:
            served = persist.open_store(entry["abs_path"])
            kg_path = None
        else:
            store = persist.open_store(entry["abs_path"])
            served = LiveStore(store)
            kg_path = entry["abs_path"]
        servers.append(
            KGServer(
                served,
                port=0,
                log=False,
                registry=registry,
                read_only=read_only,
                kg_path=kg_path,
            ).start()
        )
    return servers, [f"{s.host}:{s.port}" for s in servers]


# ---------------------------------------------------------------------------
# the api.Session face
# ---------------------------------------------------------------------------


class ShardSession(Session):
    """A :class:`repro.api.Session` over a :class:`ShardGroup` — what
    ``api.connect()`` returns for a shard-manifest target.  Error replies
    surface as the same typed hierarchy every other session raises."""

    def __init__(self, group: ShardGroup):
        self.group = group

    @staticmethod
    def _raise_on_error(reply: dict) -> dict:
        if reply.get("error"):
            raise error_from_reply(reply)
        return reply

    def query(self, text: str, limit: int | None = None) -> QueryResult:
        from repro.api import _check_limit

        _check_limit(limit)
        r = self._raise_on_error(self.group.execute_query(text, limit=limit))
        return QueryResult(
            vars=tuple(r.get("vars", ())),
            rows=_tuple_rows(r.get("rows", ())),
            n_total=int(r.get("n_total", 0)),
            agg_vars=tuple(r.get("agg_vars", ())),
            latency_ms=float(r.get("latency_ms", 0.0)),
            batch_size=int(r.get("batch_size", 1)),
            raw=r,
        )

    def explain(self, text: str) -> str:
        return self._raise_on_error(self.group.explain(text))["plan"]

    def insert(self, triples) -> dict:
        from repro.api import _check_triples

        return self._raise_on_error(
            self.group.mutate("insert", _check_triples(triples))
        )

    def delete(self, triples) -> dict:
        from repro.api import _check_triples

        return self._raise_on_error(
            self.group.mutate("delete", _check_triples(triples))
        )

    def compact(self) -> dict:
        return self._raise_on_error(self.group.mutate("compact"))

    def metrics(self) -> dict:
        return {"metrics": self.group.registry.snapshot(), "signatures": {}}

    def close(self) -> None:
        self.group.close()


# ---------------------------------------------------------------------------
# the NDJSON server face
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Pending:
    item: _Item
    req_id: object
    reply: "callable"
    t_enq_ns: int
    op: str = "query"
    triples: list | None = None


class Coordinator:
    """A drop-in :class:`~repro.serve.server.KGServer` lookalike whose
    store is a shard group: same wire protocol, same per-signature
    micro-batching (a gathered group scatters as ONE pipelined batch per
    shard), same mutation-barrier ordering."""

    def __init__(
        self,
        group: ShardGroup,
        host: str = "127.0.0.1",
        port: int = 0,
        max_batch: int = 4096,
        linger_ms: float = 2.0,
        log: bool = True,
        servers: list | None = None,
    ):
        self.group = group
        self.registry = group.registry
        self.max_batch = max_batch
        self.linger_s = linger_ms / 1e3
        self.log = log
        self._servers = servers or []  # spawned in-process shard servers
        self._sig_examples: dict[str, str] = {}
        self._queue: queue.Queue[_Pending] = queue.Queue()
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(128)
        self.host, self.port = self._sock.getsockname()[:2]

    @classmethod
    def from_manifest(
        cls,
        manifest_path: str,
        host: str = "127.0.0.1",
        port: int = 0,
        read_only: bool = False,
        wire_shards: bool = True,
        registry: MetricsRegistry | None = None,
        max_rows: int = 1000,
        **kw,
    ) -> "Coordinator":
        """Self-hosting start: spawn the manifest's shards behind real
        NDJSON servers (``wire_shards=True``, the production shape) or
        open them in-process (False — fewer moving parts for tests)."""
        if wire_shards:
            servers, addresses = spawn_shard_servers(
                manifest_path, read_only=read_only, registry=registry
            )
            group = connect_shard_group(
                addresses, registry=registry, max_rows=max_rows
            )
            return cls(group, host=host, port=port, servers=servers, **kw)
        group = open_shard_group(
            manifest_path, read_only=read_only,
            registry=registry, max_rows=max_rows,
        )
        return cls(group, host=host, port=port, **kw)

    # -- lifecycle (mirrors KGServer) ---------------------------------------

    def start(self) -> "Coordinator":
        for target in (self._accept_loop, self._dispatch_loop):
            t = threading.Thread(target=target, daemon=True)
            t.start()
            self._threads.append(t)
        if self.log:
            print(
                f"[serve] listening on {self.host}:{self.port} "
                f"(coordinator, {self.group.n_shards} shards)",
                file=sys.stderr,
                flush=True,
            )
        return self

    def stop(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        for t in self._threads:
            t.join(timeout=2.0)
        for s in self._servers:
            s.stop()
        self.group.close()

    def serve_forever(self) -> None:
        self.start()
        try:
            while not self._stop.is_set():
                time.sleep(0.2)
        except KeyboardInterrupt:
            pass
        finally:
            self.stop()

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._sock.accept()
            except OSError:
                return
            threading.Thread(
                target=self._client_loop, args=(conn,), daemon=True
            ).start()

    def _client_loop(self, conn: socket.socket) -> None:
        wlock = threading.Lock()

        def send(obj: dict) -> None:
            data = (json.dumps(obj) + "\n").encode("utf-8")
            with wlock:
                try:
                    conn.sendall(data)
                except OSError:
                    pass

        try:
            rfile = conn.makefile("r", encoding="utf-8")
            for line in rfile:
                line = line.strip()
                if not line:
                    continue
                try:
                    req = json.loads(line)
                except json.JSONDecodeError as e:
                    self.registry.inc("shard.errors")
                    send({"error": f"bad json: {e}", "code": "bad_request"})
                    continue
                try:
                    self._handle(req, send)
                except Exception as e:  # noqa: BLE001 — keep the socket alive
                    self.registry.inc("shard.errors")
                    rid = req.get("id") if isinstance(req, dict) else None
                    send({"id": rid, "error": f"{type(e).__name__}: {e}",
                          "code": "internal"})
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def stats_dict(self) -> dict:
        reg = self.registry
        queries = reg.counter("shard.queries").value
        batches = reg.counter("shard.batches").value
        return {
            "queries": queries,
            "batches": batches,
            "errors": reg.counter("shard.errors").value,
            "mean_batch": queries / batches if batches else 0.0,
            "n_shards": self.group.n_shards,
            "routed": reg.counter("shard.routed").value,
            "scattered": reg.counter("shard.scattered").value,
            "decomposed": reg.counter("shard.decomposed").value,
            "shard_requests": reg.counter("shard.shard_requests").value,
        }

    def _handle(self, req: dict, send) -> None:
        op = req.get("op")
        if op == "ping":
            send({"ok": True, "id": req.get("id")})
            return
        if op == "stats":
            send({"id": req.get("id"), **self.stats_dict()})
            return
        if op == "metrics":
            send({
                "id": req.get("id"),
                "metrics": self.registry.snapshot(),
                "signatures": dict(self._sig_examples),
            })
            return
        if op == "explain":
            reply = self.group.explain(req.get("query") or "")
            send({"id": req.get("id"), **reply})
            return
        if op in ("insert", "delete", "compact"):
            triples = req.get("triples")
            if op != "compact" and (
                not isinstance(triples, list)
                or not triples
                or not all(
                    isinstance(t, list) and len(t) == 3
                    and all(isinstance(x, str) for x in t)
                    for t in triples
                )
            ):
                self.registry.inc("shard.errors")
                send({
                    "id": req.get("id"),
                    "error": "'triples' must be a non-empty list of "
                             "[s, p, o] term-string triples",
                    "code": "bad_request",
                })
                return
            self._queue.put(_Pending(
                item=_Item(text="", limit=None),
                req_id=req.get("id"),
                reply=send,
                t_enq_ns=time.perf_counter_ns(),
                op=op,
                triples=triples,
            ))
            return
        text = req.get("query")
        if not isinstance(text, str):
            self.registry.inc("shard.errors")
            send({"id": req.get("id"), "error": "missing 'query'",
                  "code": "bad_request"})
            return
        limit = req.get("limit")
        if limit is not None and (
            not isinstance(limit, int) or isinstance(limit, bool) or limit < 0
        ):
            self.registry.inc("shard.errors")
            send({"id": req.get("id"),
                  "error": "'limit' must be a non-negative integer",
                  "code": "bad_request"})
            return
        item = _Item(text=text, limit=limit)
        try:
            item.q = algebra.parse_select(text)
        except ValueError as e:
            item.error = {"error": str(e), "code": "parse"}
        self._queue.put(_Pending(
            item=item,
            req_id=req.get("id"),
            reply=send,
            t_enq_ns=time.perf_counter_ns(),
        ))

    def _drain(self) -> "list[_Pending]":
        try:
            first = self._queue.get(timeout=0.1)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.linger_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self._queue.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            batch = self._drain()
            if not batch:
                continue
            queries: "list[_Pending]" = []
            for p in batch:
                if p.op == "query":
                    queries.append(p)
                    continue
                self._flush_queries(queries)
                queries = []
                self._apply_mutation(p)
            self._flush_queries(queries)

    def _flush_queries(self, pending: "list[_Pending]") -> None:
        if not pending:
            return
        reg = self.registry
        groups: "dict[object, list[_Pending]]" = {}
        for p in pending:
            key = p.item.q.signature() if p.item.q is not None else ("<bad>",)
            groups.setdefault(key, []).append(p)
        for group in groups.values():
            t0 = time.perf_counter_ns()
            first_q = group[0].item.q
            if first_q is not None:
                label = track_sig(
                    self._sig_examples,
                    f"x{self.group.n_shards}:{hash(first_q.signature()) & 0xFFFFFF:06x}",
                    group[0].item.text,
                )
            replies = self.group.execute_query_group([p.item for p in group])
            lat_ms = (time.perf_counter_ns() - t0) / 1e6
            reg.inc("shard.queries", len(group))
            reg.inc("shard.batches")
            reg.observe("shard.exec_ms", lat_ms)
            if first_q is not None:
                reg.observe(f"shard.exec_ms.sig={label}", lat_ms)
            for p, reply in zip(group, replies):
                if reply.get("error"):
                    reg.inc("shard.errors")
                p.reply({"id": p.req_id, **reply})

    def _apply_mutation(self, p: _Pending) -> None:
        reply = self.group.mutate(p.op, p.triples)
        if reply.get("error"):
            self.registry.inc("shard.errors")
        p.reply({"id": p.req_id, **reply})
