"""Sharded KG ingestion — rendered triples -> N ``.kgz`` stores + manifest.

The parent partitions the rendered triples by subject hash
(:mod:`repro.shard.partition`), then builds and saves each shard store —
serially in-process by default, or across ``workers`` *spawned* worker
processes (``--shard-workers`` on the ``rdfize`` CLI).  Each worker
encodes with its **own per-shard term dictionary** (term ids are ranks of
rendered terms, so no cross-shard id coordination is needed — rendered
terms are the shared key space).  The ``Pool`` join is the barrier: only
after every shard store is on disk does the parent merge the workers'
term statistics into the manifest's ``dictionary`` section and write the
manifest, so a manifest on disk always names complete, loadable shards.

Workers are plain (triples, path) -> stats functions at module top level
(picklable under the spawn start method, which keeps them clear of the
parent's jax/device state).
"""

from __future__ import annotations

import multiprocessing
import os

from repro.kg import persist
from repro.shard.partition import PARTITION_SPEC, partition_triples


def shard_paths(manifest_path: str, n_shards: int) -> "list[str]":
    """The shard store filenames a manifest at ``manifest_path`` governs:
    ``kg.shards.json`` -> ``kg.shard0.kgz`` ... next to it."""
    base = os.path.basename(manifest_path)
    for suffix in (".shards.json", ".json"):
        if base.endswith(suffix):
            base = base[: -len(suffix)]
            break
    return [f"{base}.shard{i}.kgz" for i in range(n_shards)]


def _build_shard(job: "tuple[list, str]") -> dict:
    """Build one shard store from its triple bucket and save it.  Runs in
    a worker process (or inline for the serial path)."""
    bucket, path = job
    from repro.kg.store import TripleStore

    store = TripleStore.from_ntriples(bucket)
    sid = persist.save(store, path)
    return {
        "n_triples": store.n_triples,
        "n_terms": store.n_terms,
        "snapshot_id": sid,
        "generation": 0,
    }


def ingest_sharded(
    triples,
    manifest_path: str,
    n_shards: int,
    workers: int = 0,
) -> dict:
    """Partition rendered ``(s, p, o)`` triples into ``n_shards`` stores
    next to ``manifest_path``, build/save them (``workers`` > 1 fans the
    builds across spawned processes), and write the manifest once every
    shard is durable.  Returns the manifest dict (as loaded, with
    relative shard paths)."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    triples = [tuple(t) for t in triples]
    buckets = partition_triples(triples, n_shards)
    out_dir = os.path.dirname(os.path.abspath(manifest_path))
    os.makedirs(out_dir, exist_ok=True)
    rel_paths = shard_paths(manifest_path, n_shards)
    jobs = [
        (bucket, os.path.join(out_dir, rel))
        for bucket, rel in zip(buckets, rel_paths)
    ]
    if workers > 1 and n_shards > 1:
        # spawn, not fork: the parent may hold jax device state that must
        # not leak into the children
        ctx = multiprocessing.get_context("spawn")
        with ctx.Pool(min(workers, n_shards)) as pool:
            stats = pool.map(_build_shard, jobs)  # the barrier
    else:
        stats = [_build_shard(job) for job in jobs]
    # barrier passed: every shard .kgz exists; merge the per-shard term
    # dictionaries' stats and only now publish the manifest
    union_terms = set()
    for s, p, o in triples:
        union_terms.add(s)
        union_terms.add(p)
        union_terms.add(o)
    manifest = {
        "format": persist.MANIFEST_FORMAT,
        "n_shards": n_shards,
        "partition": dict(PARTITION_SPEC),
        "shards": [
            {"path": rel, **st} for rel, st in zip(rel_paths, stats)
        ],
        "dictionary": {
            "n_terms_union": len(union_terms),
            "n_terms_shards": sum(st["n_terms"] for st in stats),
            "n_triples": sum(st["n_triples"] for st in stats),
        },
    }
    persist.save_manifest(manifest_path, manifest)
    return manifest


def shard_store(
    store, manifest_path: str, n_shards: int, workers: int = 0
) -> dict:
    """Partition an already-built :class:`~repro.kg.store.TripleStore`
    into a sharded KG on disk (the ``rdfize --shards N`` tail end)."""
    triples = [
        (
            store.decode_term(int(store.s[i])),
            store.decode_term(int(store.p[i])),
            store.decode_term(int(store.o[i])),
        )
        for i in range(store.n_triples)
    ]
    return ingest_sharded(triples, manifest_path, n_shards, workers=workers)
