"""Sharded KG ingestion — rendered triples -> N ``.kgz`` stores + manifest.

Two parallel axes, both using *spawned* worker processes (clear of the
parent's jax/device state; every worker payload is a plain picklable
tuple at module top level):

**Shard-store builds** (:func:`ingest_sharded`): the parent partitions
rendered triples by subject hash (:mod:`repro.shard.partition`), then
builds and saves each shard store — serially in-process by default, or
across ``workers`` processes.  Each worker encodes with its **own
per-shard term dictionary** (term ids are ranks of rendered terms, so no
cross-shard id coordination is needed — rendered terms are the shared key
space).  The ``Pool`` join is the barrier: only after every shard store
is on disk does the parent merge the workers' term statistics into the
manifest's ``dictionary`` section and write the manifest, so a manifest
on disk always names complete, loadable shards.

**Group-parallel KG creation** (:func:`ingest_mapping_sharded`): the
mapping planner's rule groups (:mod:`repro.rml.plan`) are independent by
construction — disjoint in predicates and sources — so each group's
sub-KG can be built in its own process from a sub-document of just that
group's triples maps (plus any rule-less OJM parents).  The union of the
groups' rendered triples is exactly the monolithic KG: predicates never
cross groups, and duplicate elimination is per-predicate.  Rendered
triples are the exchange format between the two stages for the same
reason they are the cross-shard key space: they are engine- and
dictionary-independent.
"""

from __future__ import annotations

import multiprocessing
import os

from repro.kg import persist
from repro.shard.partition import PARTITION_SPEC, partition_triples


def shard_paths(manifest_path: str, n_shards: int) -> "list[str]":
    """The shard store filenames a manifest at ``manifest_path`` governs:
    ``kg.shards.json`` -> ``kg.shard0.kgz`` ... next to it."""
    base = os.path.basename(manifest_path)
    for suffix in (".shards.json", ".json"):
        if base.endswith(suffix):
            base = base[: -len(suffix)]
            break
    return [f"{base}.shard{i}.kgz" for i in range(n_shards)]


def _build_shard(job: "tuple[list, str]") -> dict:
    """Build one shard store from its triple bucket and save it.  Runs in
    a worker process (or inline for the serial path)."""
    bucket, path = job
    from repro.kg.store import TripleStore

    store = TripleStore.from_ntriples(bucket)
    sid = persist.save(store, path)
    return {
        "n_triples": store.n_triples,
        "n_terms": store.n_terms,
        "snapshot_id": sid,
        "generation": 0,
    }


def ingest_sharded(
    triples,
    manifest_path: str,
    n_shards: int,
    workers: int = 0,
) -> dict:
    """Partition rendered ``(s, p, o)`` triples into ``n_shards`` stores
    next to ``manifest_path``, build/save them (``workers`` > 1 fans the
    builds across spawned processes), and write the manifest once every
    shard is durable.  Returns the manifest dict (as loaded, with
    relative shard paths)."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    triples = [tuple(t) for t in triples]
    buckets = partition_triples(triples, n_shards)
    out_dir = os.path.dirname(os.path.abspath(manifest_path))
    os.makedirs(out_dir, exist_ok=True)
    rel_paths = shard_paths(manifest_path, n_shards)
    jobs = [
        (bucket, os.path.join(out_dir, rel))
        for bucket, rel in zip(buckets, rel_paths)
    ]
    if workers > 1 and n_shards > 1:
        # spawn, not fork: the parent may hold jax device state that must
        # not leak into the children
        ctx = multiprocessing.get_context("spawn")
        with ctx.Pool(min(workers, n_shards)) as pool:
            stats = pool.map(_build_shard, jobs)  # the barrier
    else:
        stats = [_build_shard(job) for job in jobs]
    # barrier passed: every shard .kgz exists; merge the per-shard term
    # dictionaries' stats and only now publish the manifest
    union_terms = set()
    for s, p, o in triples:
        union_terms.add(s)
        union_terms.add(p)
        union_terms.add(o)
    manifest = {
        "format": persist.MANIFEST_FORMAT,
        "n_shards": n_shards,
        "partition": dict(PARTITION_SPEC),
        "shards": [
            {"path": rel, **st} for rel, st in zip(rel_paths, stats)
        ],
        "dictionary": {
            "n_terms_union": len(union_terms),
            "n_terms_shards": sum(st["n_terms"] for st in stats),
            "n_triples": sum(st["n_triples"] for st in stats),
        },
    }
    persist.save_manifest(manifest_path, manifest)
    return manifest


def _store_triples(store) -> "list[tuple[str, str, str]]":
    """Render a TripleStore back to ``(s, p, o)`` term-string tuples — the
    dictionary-independent form both sharding stages exchange."""
    return [
        (
            store.decode_term(int(store.s[i])),
            store.decode_term(int(store.p[i])),
            store.decode_term(int(store.o[i])),
        )
        for i in range(store.n_triples)
    ]


def shard_store(
    store, manifest_path: str, n_shards: int, workers: int = 0
) -> dict:
    """Partition an already-built :class:`~repro.kg.store.TripleStore`
    into a sharded KG on disk (the ``rdfize --shards N`` tail end)."""
    return ingest_sharded(
        _store_triples(store), manifest_path, n_shards, workers=workers
    )


def _build_group_triples(job: "tuple[str, list, str, dict]"):
    """Build one rule group's sub-KG and render it.  Runs in a spawned
    worker process: parses the mapping text, restricts the document to the
    group's triples maps, runs the engine, and returns the rendered
    triples plus the group's per-predicate statistics."""
    mapping_text, tm_names, data_root, engine_opts = job
    from repro.core.executor import create_kg
    from repro.rml import parser
    from repro.rml.model import MappingDocument

    doc = parser.parse(mapping_text)
    sub = MappingDocument(
        triples_maps={n: doc.triples_maps[n] for n in tm_names}
    )
    result = create_kg(sub, data_root=data_root, **engine_opts)
    return _store_triples(result.to_store()), result.stats


def ingest_mapping_sharded(
    mapping_text: str,
    data_root: str,
    manifest_path: str,
    n_shards: int,
    workers: int,
    engine_opts: dict | None = None,
):
    """Group-parallel sharded KG creation: build each mapping-plan rule
    group's sub-KG in its own spawned process, union the rendered triples
    (groups are predicate-disjoint, so the union *is* the monolithic KG),
    then hash-partition into ``n_shards`` stores via
    :func:`ingest_sharded` with the same worker pool size.

    Returns ``(manifest, stats, n_triples)`` where ``stats`` merges the
    groups' per-predicate statistics back into mapping order — identical
    to a monolithic run's stats, since each group is self-contained.
    """
    from repro.rml import parser
    from repro.rml.plan import build_plan

    engine_opts = dict(engine_opts or {})
    doc = parser.parse(mapping_text)
    mplan = build_plan(doc)
    jobs = []
    for g in mplan.groups:
        names = list(g.triples_maps)
        for pk in g.pjtt_keys:  # rule-less OJM parents still define PJTTs
            parent = pk.split("\x1f")[0]
            if parent not in names:
                names.append(parent)
        jobs.append((mapping_text, names, data_root, engine_opts))
    if workers > 1 and len(jobs) > 1:
        ctx = multiprocessing.get_context("spawn")
        with ctx.Pool(min(workers, len(jobs))) as pool:
            built = pool.map(_build_group_triples, jobs)
    else:
        built = [_build_group_triples(job) for job in jobs]
    triples: list = []
    group_stats: dict = {}
    for trips, stats in built:
        triples.extend(trips)
        group_stats.update(stats)  # predicates never cross groups
    stats = {
        pred: group_stats[pred]
        for pred in mplan.exec_plan.by_predicate
        if pred in group_stats
    }
    manifest = ingest_sharded(
        triples, manifest_path, n_shards, workers=workers
    )
    return manifest, stats, len(set(triples))
