"""Deterministic scatter/gather merge — shard answers -> the unsharded answer.

Three dispatch modes, chosen per query by :func:`choose_dispatch`:

* ``routed`` — every pattern shares one *constant* subject
  (:func:`repro.serve.plan.routing_subject`): all solution triples live on
  ``shard_of_term(subject)``, so the coordinator forwards the query to
  exactly one shard and passes its reply through untouched.
* ``scatter`` — all patterns (required + UNION arms + OPTIONAL groups)
  share one subject *slot* (:func:`repro.serve.plan.colocated_subjects`):
  every solution's triples share a subject and therefore a shard, so the
  per-shard answers are disjoint and their union is the unsharded bag.
  The query scatters to all shards and the merge below re-sorts,
  re-deduplicates (DISTINCT), re-aggregates (GROUP BY / COUNT) and
  re-applies ORDER BY / LIMIT.
* ``decompose`` — anything else (e.g. subject-object chains): a solution's
  triples may span shards, so whole-query scatter would silently drop
  cross-shard joins.  Instead each *pattern* scatters on its own (a single
  pattern's matches partition cleanly — each matching triple lives on
  exactly one shard) and the host combines the per-pattern solutions with
  the oracle's own algebra tail
  (:func:`repro.serve.oracle.combine_pattern_solutions`).

Why the merge can reproduce the engine's ordering byte-for-byte: term ids
are *ranks of rendered term strings*, so sorting merged rows by rendered
term (``_default_cell_key``) IS global term-id order, even though each
shard ranks only its own dictionary.  The ORDER BY passes reuse the
oracle's value-typed key (``_orderby_cell_key``) — the same total order
``values.order_rank`` realizes on device.

Per-shard LIMIT is kept for plain / DISTINCT / ORDER BY scatter (the
global top-k under a shared total order is contained in the union of
per-shard top-k), but **stripped for aggregates** — a shard-side LIMIT
would cut whole groups out of the partial counts the merge re-sums.
"""

from __future__ import annotations

import dataclasses

from repro.serve import algebra as A
from repro.serve import plan as P
from repro.serve.oracle import (
    _default_cell_key,
    _orderby_cell_key,
    combine_pattern_solutions,
)
from repro.shard.partition import shard_of_term

# decode cap for sub-queries whose merge needs COMPLETE shard rows
# (aggregate partials; DISTINCT without LIMIT; decomposed patterns)
BIG_LIMIT = 1 << 30

ROUTED = "routed"
SCATTER = "scatter"
DECOMPOSE = "decompose"


def choose_dispatch(q: A.SelectQuery, n_shards: int):
    """``(mode, target_shard)`` for a parsed query; ``target_shard`` is
    only set for ``routed``.  One shard degenerates to routed-to-0."""
    if n_shards <= 1:
        return ROUTED, 0
    subject = P.routing_subject(q)
    if subject is not None:
        return ROUTED, shard_of_term(subject, n_shards)
    if P.colocated_subjects(q):
        return SCATTER, None
    return DECOMPOSE, None


def _is_agg(q: A.SelectQuery) -> bool:
    return q.agg is not None or bool(q.group_by)


def scatter_query(q: A.SelectQuery) -> A.SelectQuery:
    """The per-shard sub-query for scatter mode.  Aggregates ship with
    ORDER BY / LIMIT stripped: the merge re-sums partial groups, and a
    shard-local LIMIT would truncate groups *before* their partials
    exist.  Everything else ships verbatim — per-shard LIMIT is safe
    under the shared total order (see module docstring)."""
    if _is_agg(q):
        return dataclasses.replace(q, order_by=(), limit=None)
    return q


def scatter_decode_limit(q: A.SelectQuery, reply_cap: int) -> int:
    """Rows the coordinator must decode *per shard* for an exact merge.
    ``reply_cap`` is the most rows the final answer will carry (the
    request's ``limit`` or the coordinator's ``max_rows``)."""
    if _is_agg(q):
        return BIG_LIMIT  # every partial group, always
    if q.distinct:
        # n_total = min(#distinct, LIMIT) needs the full per-shard
        # distinct row set (cross-shard duplicates collapse at the
        # merge, so shard counts cannot simply be summed)
        return q.limit if q.limit is not None else BIG_LIMIT
    # plain rows: global top-k ⊆ union of per-shard top-k, and n_total
    # comes from summing shard totals — decoded rows only need the cap
    return reply_cap


def _sorted_rows(q: A.SelectQuery, rows: list[tuple]) -> list[tuple]:
    """The oracle/engine ordering: default deterministic sort (rendered
    term = term-id order) as the base, then the stable ORDER BY passes,
    last key first."""
    out_vars = q.out_vars()
    rows.sort(key=lambda r: tuple(_default_cell_key(c) for c in r))
    for var, asc in reversed(q.order_by):
        i = out_vars.index(var)
        rows.sort(key=lambda r: _orderby_cell_key(r[i]), reverse=not asc)
    return rows


def merge_scatter(
    q: A.SelectQuery, shard_replies: "list[tuple[list[tuple], int]]"
) -> "tuple[list[tuple], int]":
    """Merge scatter-mode shard answers into ``(rows, n_total)`` equal to
    the unsharded engine's.  ``shard_replies`` holds each shard's
    ``(rows, n_total)`` for :func:`scatter_query`'s sub-query, decoded to
    at least :func:`scatter_decode_limit` rows."""
    if _is_agg(q):
        out_vars = q.out_vars()
        alias = q.agg.alias if q.agg is not None else None
        ai = out_vars.index(alias) if alias is not None else None
        # partial groups re-sum by their non-aggregate key cells; a
        # GROUP BY without COUNT is pure key dedup.  The global
        # aggregate (no GROUP BY) sums every shard's single row — each
        # shard reports its own count, zero included, under key ().
        groups: dict[tuple, int] = {}
        for rows, _n in shard_replies:
            for r in rows:
                if ai is None:
                    groups.setdefault(tuple(r), 0)
                else:
                    key = tuple(c for j, c in enumerate(r) if j != ai)
                    groups[key] = groups.get(key, 0) + int(r[ai])
        merged: list[tuple] = []
        for key, cnt in groups.items():
            if ai is None:
                merged.append(key)
            else:
                row = list(key)
                row.insert(ai, cnt)
                merged.append(tuple(row))
        merged = _sorted_rows(q, merged)
        n_total = len(merged)
        if q.limit is not None:
            n_total = min(n_total, q.limit)
            merged = merged[: q.limit]
        return merged, n_total

    merged = [tuple(r) for rows, _n in shard_replies for r in rows]
    if q.distinct:
        merged = _sorted_rows(q, list(dict.fromkeys(merged)))
        n_total = len(merged)
        if q.limit is not None:
            n_total = min(n_total, q.limit)
            merged = merged[: q.limit]
        return merged, n_total

    # plain: shard solution bags are disjoint, so totals sum exactly;
    # each shard already clipped its own total at LIMIT, and
    # min(sum of clipped, LIMIT) still equals min(true total, LIMIT)
    merged = _sorted_rows(q, merged)
    n_total = sum(n for _rows, n in shard_replies)
    if q.limit is not None:
        n_total = min(n_total, q.limit)
        merged = merged[: q.limit]
    return merged, n_total


# ---------------------------------------------------------------------------
# decomposed dispatch — per-pattern scatter + host-side combine
# ---------------------------------------------------------------------------


def decompose_queries(
    q: A.SelectQuery,
) -> "list[tuple[A.SelectQuery, str | None]]":
    """One single-pattern sub-query per ``q.all_patterns()`` entry, paired
    with its routing subject (the pattern's constant subject, or None to
    scatter).  A fully-constant pattern becomes a COUNT probe — the store
    dedupes triples, so it matches at most once and presence is all the
    combine needs."""
    out = []
    for pat in q.all_patterns():
        subject = pat.slots[0] if not pat.slots[0].startswith("?") else None
        if pat.variables:
            sub = A.SelectQuery(patterns=(pat,), select=tuple(pat.variables))
        else:
            sub = A.SelectQuery(
                patterns=(pat,),
                select=("?__present",),
                agg=A.Count(var=None, alias="?__present"),
            )
        out.append((sub, subject))
    return out


def pattern_rows_to_solutions(
    sub: A.SelectQuery, shard_rows: "list[list[tuple]]"
) -> "list[dict[str, str]]":
    """Gathered single-pattern rows -> the solution mappings
    :func:`combine_pattern_solutions` consumes.  Each matching triple
    lives on exactly one shard, so concatenation is the exact match set —
    no cross-shard duplicates to collapse."""
    if sub.agg is not None:  # the fully-constant COUNT probe
        present = any(int(r[0]) > 0 for rows in shard_rows for r in rows)
        return [{}] if present else []
    vars_ = sub.select or ()
    return [
        {v: c for v, c in zip(vars_, r) if c is not None}
        for rows in shard_rows
        for r in rows
    ]


def combine_decomposed(
    q: A.SelectQuery, pattern_sols: "list[list[dict[str, str]]]"
) -> "tuple[list[tuple], int]":
    """Host-side algebra tail over gathered per-pattern solutions; LIMIT
    re-applied here so ``n_total`` still reports the pre-LIMIT count the
    engine would (clipped at LIMIT, matching ``BatchResult.n``)."""
    full = combine_pattern_solutions(
        dataclasses.replace(q, limit=None), pattern_sols
    )
    n_total = len(full)
    if q.limit is not None:
        n_total = min(n_total, q.limit)
        full = full[: q.limit]
    return full, n_total
