"""Checkpoint/restore with elastic resharding — the fault-tolerance backbone.

Format: one directory per step containing
  * ``manifest.json``  — pytree structure, leaf shapes/dtypes, step, config
  * ``arrays.npz``     — every leaf, fully materialized (addressable)

Restore is *elastic*: arrays are loaded host-side and re-placed with
``jax.device_put`` under the CURRENT mesh's NamedSharding, so a checkpoint
written on a (16,16) mesh restores onto (2,16,16), onto a shrunken failover
mesh, or onto a single CPU process (this container) without conversion.
Writes are atomic (tmp dir + rename) so a crash mid-write never corrupts
the latest checkpoint; ``background=True`` hands the serialization to a
writer thread (training continues).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, tree, step: int, extra: dict | None = None, background: bool = False):
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(l) for l in leaves]  # device -> host copy NOW

    def _write():
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(
            os.path.join(tmp, "arrays.npz"),
            **{f"leaf_{i}": l for i, l in enumerate(host_leaves)},
        )
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            # human-auditable structure descriptor (restore matches by the
            # caller-provided like_tree, not by this string)
            "treedef": str(treedef),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)

    if background:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def restore(path: str, like_tree, shardings=None):
    """Load a checkpoint into the structure of ``like_tree``.

    ``shardings``: optional pytree of NamedSharding/None matching like_tree;
    leaves are placed with device_put (elastic resharding).  Returns
    (tree, step).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like_tree)
    assert len(leaves) == manifest["n_leaves"], (
        f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves)}"
    )
    loaded = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert tuple(arr.shape) == tuple(ref.shape), f"leaf {i} shape mismatch"
        loaded.append(arr.astype(ref.dtype))
    if shardings is not None:
        shard_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "spec")
        )
        loaded = [
            jax.device_put(a, s) if s is not None else jax.device_put(a)
            for a, s in zip(loaded, shard_leaves)
        ]
    else:
        loaded = [jax.device_put(a) for a in loaded]
    return jax.tree.unflatten(treedef, loaded), manifest["step"]


def latest_step_dir(root: str) -> str | None:
    if not os.path.isdir(root):
        return None
    steps = [d for d in os.listdir(root) if d.startswith("step_") and not d.endswith(".tmp")]
    if not steps:
        return None
    return os.path.join(root, max(steps, key=lambda s: int(s.split("_")[1])))
