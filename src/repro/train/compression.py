"""Gradient compression: int8 quantization with error feedback.

Distributed-optimization trick for the DP all-reduce (DESIGN.md §4): under
GSPMD the gradient all-reduce happens inside the jitted step, so the
compression is expressed as quantize -> dequantize around the point where
the DP reduction occurs; error feedback (residual carried between steps)
keeps SGD convergence (Seide et al., 1-bit SGD; Karimireddy et al. EF-SGD).

The compressed representation is what would travel on the wire at the
reduce; the dry-run's collective-bytes analysis reflects it when enabled.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_leaf(g: jnp.ndarray):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, error_fb=None):
    """Quantize each gradient leaf to int8 (+fp32 scale), dequantize, and
    carry the quantization error to the next step (error feedback).

    Returns (grads', error_fb').  error_fb=None initializes zeros.
    """
    if error_fb is None:
        error_fb = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads
        )

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(g32)
        deq = _dequantize_leaf(q, scale)
        return deq, g32 - deq

    flat = jax.tree.map(leaf, grads, error_fb)
    new_grads = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, new_err
