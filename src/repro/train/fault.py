"""Fault tolerance & straggler mitigation for the training driver.

On a real fleet the failure signals are XLA device errors, host heartbeats,
and preemption notices; in this container they surface as exceptions from
the jitted step.  The policy layer is hardware-independent:

* ``RetryPolicy``  — a step that raises is retried after restoring the last
  checkpoint; repeated failures back off and finally re-raise (at which
  point an external supervisor would reschedule the job on fresh capacity —
  the checkpoint's elastic restore handles a changed mesh, see
  checkpoint.py).
* ``StragglerDetector`` — EWMA of step wall-time; a step exceeding
  ``k x EWMA`` is flagged.  On multi-host fleets the flag triggers (a) a
  preemptive checkpoint and (b) marking the slow host for replacement; here
  it is surfaced through the metrics stream and the log.
"""

from __future__ import annotations

import dataclasses
import logging
import time

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1      # EWMA coefficient
    threshold: float = 3.0  # k x EWMA -> straggler
    warmup_steps: int = 5   # compile-time steps excluded
    _ewma: float = 0.0
    _seen: int = 0

    def observe(self, dt: float) -> bool:
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return False
        if self._ewma == 0.0:
            self._ewma = dt
            return False
        is_straggler = dt > self.threshold * self._ewma
        self._ewma = (1 - self.alpha) * self._ewma + self.alpha * dt
        if is_straggler:
            log.warning(
                "straggler step: %.3fs vs EWMA %.3fs (>%.1fx)",
                dt, self._ewma, self.threshold,
            )
        return is_straggler


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_s: float = 1.0

    def run(self, fn, on_failure=None):
        """Run fn(); on exception call on_failure(attempt, exc) (restore
        hook) and retry with exponential backoff."""
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except Exception as exc:  # noqa: BLE001 — deliberate catch-all
                if attempt == self.max_retries:
                    raise
                log.error("step failed (%s); retry %d/%d",
                          exc, attempt + 1, self.max_retries)
                if on_failure is not None:
                    on_failure(attempt, exc)
                time.sleep(self.backoff_s * (2 ** attempt))
        raise RuntimeError("unreachable")
