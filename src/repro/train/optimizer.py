"""Sharded AdamW — fp32 moments over arbitrary-dtype (bf16) params.

Moments inherit the parameter PartitionSpecs (plus whatever extra data-axis
sharding the spec tree carries — that is the ZeRO-1 layout, DESIGN.md §4).
Pure functions over pytrees; no optax dependency.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any  # pytree like params, fp32
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float | None = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))

    def state_specs(self, param_specs) -> AdamWState:
        """PartitionSpec tree for the optimizer state (mirrors params)."""
        from jax.sharding import PartitionSpec as P

        return AdamWState(
            step=P(), m=param_specs, v=jax.tree.map(lambda s: s, param_specs)
        )

    def update(self, grads, state: AdamWState, params):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip is not None:
            gnorm = jnp.sqrt(
                sum(jnp.sum(g * g) for g in jax.tree.leaves(g32))
            )
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-12))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        else:
            gnorm = jnp.float32(0.0)
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t

        new_m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g, state.m, g32)
        new_v = jax.tree.map(
            lambda v, g: self.b2 * v + (1 - self.b2) * g * g, state.v, g32
        )

        def upd(p, m, v):
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, new_m, new_v)
        return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm
