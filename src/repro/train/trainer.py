"""Train-step factory: grad, optional microbatch accumulation, optional
gradient compression, AdamW update — one jit-able function.

``make_train_step(loss_fn, opt)`` returns
    step(params, opt_state, *batch) -> (params', opt_state', metrics)
with donated params/opt_state (callers pass donate_argnums=(0, 1) to jit).

Microbatching: ``grad_accum > 1`` scans over a leading microbatch axis the
caller adds to the batch arrays — activation memory drops by the factor,
FLOPs unchanged.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.train import compression
from repro.train.optimizer import AdamW


def make_train_step(
    loss_fn: Callable,
    opt: AdamW,
    grad_accum: int = 1,
    compress: bool = False,
    unroll_accum: bool = False,
):
    """``unroll_accum`` replaces the microbatch lax.scan with a Python loop —
    used by the dry-run cost variants so XLA cost_analysis sees every
    microbatch (a scan body is counted once regardless of trip count)."""

    def grads_of(params, *batch):
        return jax.value_and_grad(loss_fn)(params, *batch)

    def step(params, opt_state, *batch, error_fb=None):
        if grad_accum == 1:
            loss, grads = grads_of(params, *batch)
        elif unroll_accum:
            loss = jnp.float32(0.0)
            grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            for i in range(grad_accum):
                micro = tuple(b[i] for b in batch)
                l, g = grads_of(params, *micro)
                loss = loss + l
                grads = jax.tree.map(jnp.add, grads, g)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            def body(acc, micro):
                l, g = grads_of(params, *micro)
                return (
                    (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)),
                    None,
                )
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero), batch)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        if compress:
            grads, error_fb = compression.compress_decompress(grads, error_fb)

        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        if compress:
            return params, opt_state, metrics, error_fb
        return params, opt_state, metrics

    return step
