"""Jitted plan executor — a whole query (batch) in one fused dispatch.

The old BGP path materialized every binding table on host between joins;
here the full operator tree — range scans, sorted-merge joins, OPTIONAL
backfill, UNION concat, filters, group/count, distinct/sort/order/limit —
lowers to *one* jitted function.  Binding tables stay on device as
power-of-two padded int32 columns with a packed-valid-prefix row count;
``-1`` is the unbound sentinel a ``LeftJoin`` (or a partial ``UNION``
arm) backfills for maybe-unbound variables.

Shapes must be static under jit, so every operator has a *capacity* (scan
rows, join fan-out ``M``, join output rows, union/backfill concat rows).
Capacities start from the planner's estimates and are corrected by a
feedback loop: the compiled pipeline returns, alongside the results, the
*exact* size each point needed; if anything was truncated the executor
re-runs once with capacities bumped to ``next_pow2(needed)`` (growth is
monotone, so the loop terminates; capacities are remembered per query
signature, so a serving workload converges to exactly one dispatch per
batch).  Power-of-two padding everywhere bounds the number of distinct
compiled shapes to O(log n) per signature.

The plan is a DAG, not a tree — UNION arms share the required subtree and
an OPTIONAL bind-join chain shares its tagged left side — so node
evaluation is memoized per trace: shared work is computed once per
dispatch.  GROUP BY counts with a device segment-sum over the key-sorted
table; ORDER BY sorts by the store's value-typed ``order_rank`` side
table (count columns by their integer value) with a term-id tie-break.

Batching: the single-query pipeline is ``vmap``-ed over the batch axis, so
*many same-shape queries execute per dispatch* — constants (term ids, rank
bounds) are the only per-query data.  This is the server's hot path.
"""

from __future__ import annotations

import dataclasses
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashset import next_pow2
from repro.kg.query import _lex_search
from repro.kg.store import ORDERS, TripleStore
from repro.obs import get_registry, get_tracer
from repro.serve import algebra as A
from repro.serve import fastpath as FP
from repro.serve import plan as P
from repro.serve.values import value_table

I32_MAX = np.int32(np.iinfo(np.int32).max)
UNBOUND = np.int32(-1)
_MAX_GROW_ROUNDS = 12
_FP_UNSET = object()  # fast-path cache sentinel (None = ineligible plan)


def plan_label(sig: tuple) -> str:
    """A short, process-stable label for a plan signature — what dispatch
    spans and per-signature latency histograms are tagged with (the raw
    signature tuple is too bulky for a metric name)."""
    return f"{zlib.crc32(repr(sig).encode('utf-8')) & 0xFFFFFFFF:08x}"


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatchResult:
    """Padded, deterministically ordered solution tables for a whole batch."""

    store: TripleStore
    vars: tuple[str, ...]
    cols: dict[str, np.ndarray]   # int32[B, C] each (C >= max count)
    counts: np.ndarray            # int64[B]
    # aggregate output columns (COUNT aliases): their cells are plain
    # integers, not term ids — ``rows`` returns them as ints
    agg_vars: tuple[str, ...] = ()

    def n(self, i: int) -> int:
        return int(self.counts[i])

    def ids(self, i: int) -> list[tuple[int, ...]]:
        """Query ``i``'s rows as raw int tuples (term ids, -1 = unbound;
        counts stay counts)."""
        k = self.n(i)
        return [
            tuple(int(self.cols[v][i, r]) for v in self.vars) for r in range(k)
        ]

    def rows(self, i: int, limit: int | None = None) -> list[tuple]:
        """Query ``i``'s rows decoded to rendered terms (None = unbound);
        aggregate columns come back as plain ints."""
        k = self.n(i)
        if limit is not None:
            k = min(k, limit)
        out = []
        for r in range(k):
            row = []
            for v in self.vars:
                x = int(self.cols[v][i, r])
                if v in self.agg_vars:
                    row.append(x)
                elif x < 0:
                    row.append(None)
                else:
                    row.append(self.store.decode_term(x))
            out.append(tuple(row))
        return out


# ---------------------------------------------------------------------------
# traced operators (single query; vmapped over the batch by the compiler)
# ---------------------------------------------------------------------------


def _pack_bound(q0, q1, q2, bits: int):
    """Pack a (possibly wildcarded) query bound into the store's split
    63-bit key space (see ``TripleStore.device_keys``): fields are shifted
    +1 so ``-1`` packs below every real id and ``I32_MAX`` clamps to the
    all-ones field above every id.  Returns int32 ``(hi, lo)`` with the
    low word sign-bit-biased, matching the store's key columns."""

    def f(x):
        # clip BEFORE the +1: I32_MAX + 1 would wrap in int32
        return jnp.clip(
            jnp.asarray(x), -1, (1 << bits) - 2
        ).astype(jnp.uint32) + jnp.uint32(1)

    f0, f1, f2 = f(q0), f(q1), f(q2)
    hi = (f0 << (2 * bits - 32)) | (f1 >> (32 - bits))
    lo = ((f1 & jnp.uint32((1 << (32 - bits)) - 1)) << bits) | f2
    return (
        hi.astype(jnp.int32),
        jax.lax.bitcast_convert_type(lo ^ jnp.uint32(0x80000000), jnp.int32),
    )


def _lex_search2(khi, klo, qhi, qlo, upper: bool, rounds: int,
                 lo_init=None, hi_init=None):
    """Binary search on the split-key pair: count of rows lex-< (or <= for
    ``upper``) the query bound.  ``rounds`` covers the widest possible
    [lo_init, hi_init) window (the full store by default; a seeded search
    passes a primary-term row range and correspondingly few rounds)."""
    n = khi.shape[0]
    if lo_init is None:
        lo_i = jnp.zeros(jnp.shape(qhi), jnp.int32)
        hi_i = jnp.full(jnp.shape(qhi), n, jnp.int32)
    else:
        lo_i = jnp.broadcast_to(lo_init, jnp.shape(qhi))
        hi_i = jnp.broadcast_to(hi_init, jnp.shape(qhi))

    def body(_, state):
        lo_i, hi_i = state
        mid = lo_i + ((hi_i - lo_i) >> 1)
        g = jnp.clip(mid, 0, max(n - 1, 0))
        mhi, mlo = khi[g], klo[g]
        tail = (mlo <= qlo) if upper else (mlo < qlo)
        before = (mhi < qhi) | ((mhi == qhi) & tail)
        open_ = lo_i < hi_i
        return (
            jnp.where(open_ & before, mid + 1, lo_i),
            jnp.where(open_ & ~before, mid, hi_i),
        )

    lo_i, _ = jax.lax.fori_loop(0, rounds, body, (lo_i, hi_i))
    return lo_i


def _range_search(
    keys, c0, c1, c2, lo_q, hi_q, bits: int, rounds: int,
    primary_q=None, prim_start=None, prim_rounds: int | None = None,
):
    """(start, end) of the rows inside [lo_q, hi_q] — a 2-column split-key
    binary search when the store's ids fit the packed fields, else the
    general 3-column lexicographic search.  With a bound primary term
    (``primary_q``), the bisection is *seeded* to that term's row range
    (``prim_start``) and runs only ``prim_rounds`` rounds — for a bound
    subject that is the subject's degree, not the store size."""
    if keys is not None:
        khi, klo = keys
        qhi_l, qlo_l = _pack_bound(*lo_q, bits)
        qhi_h, qlo_h = _pack_bound(*hi_q, bits)
        if primary_q is not None:
            T = prim_start.shape[0] - 1
            g0 = jnp.clip(primary_q, 0, max(T - 1, 0))
            lo0 = prim_start[g0]
            hi0 = prim_start[g0 + 1]
            lo = _lex_search2(
                khi, klo, qhi_l, qlo_l, False, prim_rounds, lo0, hi0
            )
            hi = _lex_search2(
                khi, klo, qhi_h, qlo_h, True, prim_rounds, lo0, hi0
            )
            # a negative primary (unknown constant / padded row) is empty
            ok = primary_q >= 0
            zero = jnp.zeros_like(lo)
            return jnp.where(ok, lo, zero), jnp.where(ok, hi, zero)
        lo = _lex_search2(khi, klo, qhi_l, qlo_l, upper=False, rounds=rounds)
        hi = _lex_search2(khi, klo, qhi_h, qlo_h, upper=True, rounds=rounds)
        return lo, hi
    lo = _lex_search(c0, c1, c2, lo_q[0], lo_q[1], lo_q[2], upper=False)
    hi = _lex_search(c0, c1, c2, hi_q[0], hi_q[1], hi_q[2], upper=True)
    return lo, hi


def _compact(cols: dict, mask, cap: int):
    """Scatter masked rows to a packed prefix of a ``cap``-row table.
    Returns (cols, valid_count, total_wanted) — ``total_wanted`` feeds the
    capacity feedback when it exceeds ``cap``."""
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    keep = mask & (pos < cap)
    idx = jnp.where(keep, pos, cap)  # cap is out-of-range: dropped
    out = {
        v: jnp.full(cap, UNBOUND, jnp.int32).at[idx].set(c, mode="drop")
        for v, c in cols.items()
    }
    total = jnp.sum(mask.astype(jnp.int32))
    return out, jnp.minimum(total, cap), total


def _sort_perm(cols: dict, order: tuple[str, ...], n, cap: int):
    """Permutation sorting the valid prefix lexicographically by ``order``
    columns (invalid rows, keyed all-I32_MAX, sort last; real ids are far
    below it).  One variadic ``lax.sort`` pass over all key columns.  Term
    ids are dense ranks of rendered terms, so this order is identical
    across stores of the same graph."""
    valid = jnp.arange(cap) < n
    keys = [jnp.where(valid, cols[v], I32_MAX) for v in order]
    payload = jnp.arange(cap, dtype=jnp.int32)
    out = jax.lax.sort(
        tuple(keys) + (payload,), num_keys=len(keys), is_stable=True
    )
    return out[-1], valid


class _Lowerer:
    """Builds the traced single-query pipeline for one (plan, caps)."""

    def __init__(
        self,
        plan: P.Plan,
        caps: dict[str, int],
        store_n: int,
        key_bits: int,
        packed: bool,
        prim_rounds: dict[int, int] | None = None,
        order_is_tid: bool = False,
        overlay: bool = False,
        delta_cap: int = 1,
        delta_rounds: int = 1,
    ):
        self.plan = plan
        self.caps = caps
        self.store_n = store_n
        self.key_bits = key_bits
        self.packed = packed
        self.order_is_tid = order_is_tid
        self.rounds = max(1, int(store_n).bit_length())
        self.prim_rounds = prim_rounds or {}
        # live-overlay second scan arm (see repro.live.delta.OverlayView):
        # every reader range-scans the base index AND the re-sorted delta
        # index, rank-selects non-tombstoned base rows through per-order
        # alive prefix sums, and emits base matches then delta matches
        self.overlay = overlay
        self.delta_cap = delta_cap
        self.delta_rounds = delta_rounds
        self.scan_index = {s.node_id: i for i, s in enumerate(plan.scans)}
        self.needed: dict[str, jnp.ndarray] = {}
        # the column sequence each node's rows are known to be sorted by
        # (empty when unknown) — lets the tail skip redundant sorts
        self._sorted: dict[int, tuple[str, ...]] = {}
        # per-trace node memo: the plan is a DAG (shared union/optional
        # subtrees) and every shared node must be computed exactly once
        self._memo: dict[int, tuple] = {}
        # bound during trace
        self.scan_cols: dict[int, tuple] = {}
        self.scan_keys: dict[int, jnp.ndarray | None] = {}
        self.scan_prim: dict[int, jnp.ndarray | None] = {}
        self.dscan_cols: dict[int, tuple] = {}
        self.dscan_keys: dict[int, jnp.ndarray | None] = {}
        self.alive: dict[int, jnp.ndarray] = {}
        self.dn = None
        self.vt_arrays: tuple | None = None
        self.consts = None
        self.fops = None
        self.qvalid = None
        self.qlimit = None

    def _search_args(self, node):
        """Per-reader seeding operands (packed path only)."""
        if not self.packed:
            return {}
        return {
            "prim_start": self.scan_prim[node.node_id],
            "prim_rounds": self.prim_rounds[node.node_id],
        }

    # -- scans ---------------------------------------------------------------

    def _scan(self, node: P.Scan):
        cap = self.caps.get(f"scan{node.node_id}", 1)
        c0, c1, c2 = self.scan_cols[node.node_id]
        q = self.consts[self.scan_index[node.node_id]]
        perm3 = ORDERS[node.order]
        lo_q, hi_q = [], []
        for j in range(3):
            pos = perm3[j]
            if pos in node.const_slots:
                lo_q.append(q[pos])
                hi_q.append(q[pos])
            else:
                lo_q.append(jnp.int32(-1))
                hi_q.append(I32_MAX)
        primary_q = q[perm3[0]] if perm3[0] in node.const_slots else None
        lo, hi = _range_search(
            self.scan_keys[node.node_id], c0, c1, c2,
            lo_q, hi_q, self.key_bits, self.rounds,
            primary_q=primary_q if self.packed else None,
            **self._search_args(node),
        )
        by_pos = {perm3[j]: (c0, c1, c2)[j] for j in range(3)}
        if self.overlay:
            # base rows are counted through the alive prefix sums (masking
            # tombstones), the delta index is range-scanned with the same
            # bounds, and output rows are base matches then delta matches
            A = self.alive[node.node_id]
            nb = A[hi] - A[lo]
            dc0, dc1, dc2 = self.dscan_cols[node.node_id]
            dlo, dhi = _range_search(
                self.dscan_keys[node.node_id], dc0, dc1, dc2,
                lo_q, hi_q, self.key_bits, self.delta_rounds,
            )
            # clamp to the live delta rows: the wildcard upper bound packs
            # level with the pad rows' sentinel id, so pads fall in range
            dlo = jnp.minimum(dlo, self.dn)
            nd = jnp.minimum(dhi, self.dn) - dlo
            count = jnp.where(self.qvalid, nb + nd, 0)
        else:
            count = jnp.where(self.qvalid, hi - lo, 0)
        if not node.out_vars:  # all-constant pattern: pure existence filter
            return {}, jnp.minimum(count, 1)
        self.needed[f"scan{node.node_id}"] = count
        # rows come out in index order: sorted by the variable positions in
        # the order's (primary, secondary, tertiary) sequence — except under
        # an overlay, where delta matches append after the base run (the
        # tail determinism sort restores output order)
        var_by_pos = dict(node.var_slots)
        self._sorted[node.node_id] = () if self.overlay else tuple(
            var_by_pos[pos] for pos in perm3 if pos in var_by_pos
        )
        if self.overlay:
            j = jnp.arange(cap, dtype=jnp.int32)
            in_base = j < nb
            # rank-select the (A[lo]+j)-th live base row: the smallest
            # sorted position r with alive-prefix A[r+1] past that rank
            rb = jnp.clip(
                jnp.searchsorted(
                    A, A[lo] + j + 1, side="left"
                ).astype(jnp.int32) - 1,
                0, self.store_n - 1,
            )
            rd = jnp.clip(dlo + (j - nb), 0, self.delta_cap - 1)
            dby_pos = {perm3[k]: (dc0, dc1, dc2)[k] for k in range(3)}

            def gather(pos):
                return jnp.where(
                    in_base, by_pos[pos][rb], dby_pos[pos][rd]
                )

            valid = j < count
            cols = {v: gather(pos) for pos, v in node.var_slots}
            if node.eq_pairs:
                pat_vals = {pos: gather(pos) for pos in range(3)}
                for pa, pb in node.eq_pairs:
                    valid = valid & (pat_vals[pa] == pat_vals[pb])
                return _compact(cols, valid, cap)[:2]
            cols = {v: jnp.where(valid, c, UNBOUND) for v, c in cols.items()}
            return cols, jnp.minimum(count, cap)
        r = jnp.clip(lo + jnp.arange(cap, dtype=jnp.int32), 0, self.store_n - 1)
        valid = jnp.arange(cap) < count
        cols = {v: by_pos[pos][r] for pos, v in node.var_slots}
        if node.eq_pairs:
            pat_vals = {pos: by_pos[pos][r] for pos in range(3)}
            for pa, pb in node.eq_pairs:
                valid = valid & (pat_vals[pa] == pat_vals[pb])
            return _compact(cols, valid, cap)[:2]
        cols = {v: jnp.where(valid, c, UNBOUND) for v, c in cols.items()}
        return cols, jnp.minimum(count, cap)

    # -- joins ---------------------------------------------------------------

    def _bind_join(self, node: P.BindJoin):
        """Index nested-loop join: each left row's bound variables extend
        the bound prefix of the pattern's range scan — the pattern is
        never materialized independently."""
        lcols, ln = self._eval(node.left)
        cl = len(next(iter(lcols.values())))
        c0, c1, c2 = self.scan_cols[node.node_id]
        q = self.consts[self.scan_index[node.node_id]]
        perm3 = ORDERS[node.order]
        bound_by_pos = {pos: lcols[v] for pos, v in node.bound_slots}
        lvalid = jnp.arange(cl) < ln
        lo_q, hi_q = [], []
        for j in range(3):
            pos = perm3[j]
            if pos in node.const_slots:
                lo_q.append(jnp.broadcast_to(q[pos], (cl,)))
                hi_q.append(jnp.broadcast_to(q[pos], (cl,)))
            elif pos in bound_by_pos:
                # left-bound variable: an exact key for this row's lookup
                lo_q.append(bound_by_pos[pos])
                hi_q.append(bound_by_pos[pos])
            else:
                lo_q.append(jnp.full(cl, -1, jnp.int32))
                hi_q.append(jnp.full(cl, I32_MAX, jnp.int32))
        ppos = perm3[0]
        if ppos in node.const_slots:
            primary_q = jnp.broadcast_to(q[ppos], (cl,))
        else:  # bind-join orders put a bound slot first by construction
            primary_q = bound_by_pos[ppos]
        lo, hi = _range_search(
            self.scan_keys[node.node_id], c0, c1, c2,
            lo_q, hi_q, self.key_bits, self.rounds,
            primary_q=primary_q if self.packed else None,
            **self._search_args(node),
        )
        if self.overlay:
            # merged per-row match count: live base rows (alive-prefix
            # masked) plus delta rows in the same bounds — the second
            # range-scan arm, per left row
            A = self.alive[node.node_id]
            nb = A[hi] - A[lo]
            dc0, dc1, dc2 = self.dscan_cols[node.node_id]
            dlo, dhi = _range_search(
                self.dscan_keys[node.node_id], dc0, dc1, dc2,
                lo_q, hi_q, self.key_bits, self.delta_rounds,
            )
            dlo = jnp.minimum(dlo, self.dn)
            nd = jnp.minimum(dhi, self.dn) - dlo
            cnt = jnp.where(lvalid, nb + nd, 0)
        else:
            A = nb = dlo = None
            cnt = jnp.where(lvalid, hi - lo, 0)

        left_sorted = self._sorted.get(node.left.node_id, ())
        # expansion preserves left row order and emits each row's matches
        # in index order, so sortedness extends iff the left rows were
        # totally ordered (sorted by every left column) — and the index
        # order claim fails under an overlay (delta matches append after
        # the base run per left row)
        if set(left_sorted) >= set(node.left.out_vars):
            free_by_pos = dict(node.free_slots)
            self._sorted[node.node_id] = () if self.overlay else (
                left_sorted + tuple(
                    free_by_pos[pos] for pos in perm3 if pos in free_by_pos
                )
            )
        if node.kind == "left" and node.free_slots:
            # backfill rows append after the matches: order lost
            self._sorted[node.node_id] = ()

        if not node.free_slots:  # pure (anti-)semijoin: no new bindings
            self._sorted[node.node_id] = left_sorted
            if node.kind == "left":
                return lcols, ln
            return _compact(lcols, lvalid & (cnt > 0), cl)[:2]

        by_pos = {perm3[j]: (c0, c1, c2)[j] for j in range(3)}
        dby_pos = (
            {
                perm3[j]: self.dscan_cols[node.node_id][j]
                for j in range(3)
            }
            if self.overlay
            else None
        )
        cap = self.caps[f"bindC{node.node_id}"]
        if node.eq_pairs:
            return self._bind_join_grid(
                node, lcols, lvalid, lo, cnt, by_pos, cap,
                A=A, nb=nb, dlo=dlo, dby_pos=dby_pos,
            )
        # packed expansion: out row j belongs to the left row whose count
        # prefix-sum passes j (a log-width searchsorted), so matches land
        # directly packed — no (rows x fan-out) grid, no fan-out capacity,
        # no compaction pass
        cl = lvalid.shape[0]
        cum = jnp.cumsum(cnt)
        total = cum[cl - 1]
        j = jnp.arange(cap, dtype=jnp.int32)
        rowidx = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
        rowc = jnp.clip(rowidx, 0, cl - 1)
        prev = jnp.where(rowc > 0, cum[rowc - 1], 0)
        k = j - prev  # match index within this left row's merged run
        if self.overlay:
            nb_r = nb[rowc]
            in_base = k < nb_r
            rb = jnp.clip(
                jnp.searchsorted(
                    A, A[lo[rowc]] + k + 1, side="left"
                ).astype(jnp.int32) - 1,
                0, self.store_n - 1,
            )
            rd = jnp.clip(dlo[rowc] + (k - nb_r), 0, self.delta_cap - 1)
        else:
            r = jnp.clip(lo[rowc] + k, 0, self.store_n - 1)
        valid_out = j < jnp.minimum(total, cap)
        out_vals = {}
        for v in node.out_vars:
            if v in lcols:
                vals = lcols[v][rowc]
            else:
                pos = next(p for p, fv in node.free_slots if fv == v)
                if self.overlay:
                    vals = jnp.where(
                        in_base, by_pos[pos][rb], dby_pos[pos][rd]
                    )
                else:
                    vals = by_pos[pos][r]
            out_vals[v] = jnp.where(valid_out, vals, UNBOUND)
        if node.kind == "left":
            # backfill: left rows with no match append after the matches,
            # their free variables staying at the unbound sentinel
            un = lvalid & (cnt == 0)
            upos_raw = total + jnp.cumsum(un.astype(jnp.int32)) - 1
            upos = jnp.where(un & (upos_raw < cap), upos_raw, cap)
            for v in node.out_vars:
                if v in lcols:
                    out_vals[v] = (
                        out_vals[v].at[upos].set(lcols[v], mode="drop")
                    )
            total = total + jnp.sum(un.astype(jnp.int32))
        self.needed[f"bindC{node.node_id}"] = total
        return out_vals, jnp.minimum(total, cap)

    def _bind_join_grid(
        self, node, lcols, lvalid, lo, cnt, by_pos, cap,
        A=None, nb=None, dlo=None, dby_pos=None,
    ):
        """Grid expansion fallback for patterns with repeated free
        variables: pair validity depends on the gathered values, so the
        (rows x fan-out) grid plus a compaction pass is unavoidable.
        Under an overlay the grid covers each row's merged run — the
        first ``nb`` slots rank-select live base rows, the rest gather
        from the delta index."""
        cl = lvalid.shape[0]
        m = self.caps[f"bindM{node.node_id}"]
        self.needed[f"bindM{node.node_id}"] = jnp.max(cnt, initial=0)
        offs = jnp.arange(m, dtype=jnp.int32)
        if self.overlay:
            in_base = offs[None, :] < nb[:, None]
            rb = jnp.clip(
                jnp.searchsorted(
                    A, A[lo][:, None] + offs[None, :] + 1, side="left"
                ).astype(jnp.int32) - 1,
                0, self.store_n - 1,
            )
            rd = jnp.clip(
                dlo[:, None] + (offs[None, :] - nb[:, None]),
                0, self.delta_cap - 1,
            )

            def grid(pos):
                return jnp.where(
                    in_base, by_pos[pos][rb], dby_pos[pos][rd]
                )

        else:
            ridx = jnp.clip(lo[:, None] + offs[None, :], 0, self.store_n - 1)

            def grid(pos):
                return by_pos[pos][ridx]

        within = offs[None, :] < cnt[:, None]
        pairmask = within & lvalid[:, None]
        for pa, pb in node.eq_pairs:
            pairmask = pairmask & (grid(pa) == grid(pb))
        out_vals = {}
        for v in node.out_vars:
            if v in lcols:
                mat = jnp.broadcast_to(lcols[v][:, None], (cl, m))
            else:
                pos = next(p for p, fv in node.free_slots if fv == v)
                mat = grid(pos)
            out_vals[v] = mat.reshape(-1)
        flat_mask = pairmask.reshape(-1)
        if node.kind == "left":
            matched = jnp.sum(pairmask.astype(jnp.int32), axis=1)
            unmatched = lvalid & (matched == 0)
            for v in node.out_vars:
                tail = (
                    lcols[v]
                    if v in lcols
                    else jnp.full(cl, UNBOUND, jnp.int32)
                )
                out_vals[v] = jnp.concatenate([out_vals[v], tail])
            flat_mask = jnp.concatenate([flat_mask, unmatched])
        cols, n, total = _compact(out_vals, flat_mask, cap)
        self.needed[f"bindC{node.node_id}"] = total
        return cols, n

    def _join(self, node: P.Join):
        lcols, ln = self._eval(node.left)
        rcols, rn = self._eval(node.right)
        # zero-variable sides are existence filters: scale the other side
        if not node.left.out_vars and node.kind == "inner":
            return rcols, jnp.where(ln > 0, rn, 0)
        if not node.right.out_vars:
            if node.kind == "inner":
                return lcols, jnp.where(rn > 0, ln, 0)
            return lcols, ln  # OPTIONAL {} with no vars binds nothing
        if node.build_right:
            build_cols, bn, probe_cols, pn = rcols, rn, lcols, ln
        else:
            build_cols, bn, probe_cols, pn = lcols, ln, rcols, rn
        cb = len(next(iter(build_cols.values())))
        cp = len(next(iter(probe_cols.values()))) if probe_cols else 1
        cap = self.caps[f"joinC{node.node_id}"]
        pvalid = jnp.arange(cp) < pn

        if node.shared:
            m = self.caps[f"joinM{node.node_id}"]
            key = node.shared[0]
            bk = jnp.where(
                jnp.arange(cb) < bn, build_cols[key], I32_MAX
            )
            order = jnp.argsort(bk, stable=True)
            skeys = bk[order]
            pk = jnp.where(pvalid, probe_cols[key], -3)
            start = jnp.searchsorted(skeys, pk, side="left").astype(jnp.int32)
            end = jnp.searchsorted(skeys, pk, side="right").astype(jnp.int32)
            cnt = end - start
            self.needed[f"joinM{node.node_id}"] = jnp.max(
                jnp.where(pvalid, cnt, 0), initial=0
            )
        else:  # cross join: every valid probe row spans the whole build side
            m = cb
            order = jnp.arange(cb, dtype=jnp.int32)
            start = jnp.zeros(cp, jnp.int32)
            cnt = jnp.where(pvalid, bn, 0).astype(jnp.int32)

        offs = jnp.arange(m, dtype=jnp.int32)
        bidx = start[:, None] + offs[None, :]
        within = offs[None, :] < cnt[:, None]
        brow = order[jnp.clip(bidx, 0, cb - 1)]
        pairmask = within & pvalid[:, None]
        for v in node.shared[1:]:
            pairmask = pairmask & (
                build_cols[v][brow] == probe_cols[v][:, None]
            )

        out_vals: dict[str, jnp.ndarray] = {}
        for v in node.out_vars:
            if probe_cols and v in probe_cols:
                mat = jnp.broadcast_to(probe_cols[v][:, None], (cp, m))
            else:
                mat = build_cols[v][brow]
            out_vals[v] = mat.reshape(-1)
        flat_mask = pairmask.reshape(-1)

        if node.kind == "left":
            # unmatched-row backfill: preserved left rows with the optional
            # side's variables left at the unbound sentinel
            matched = jnp.sum(pairmask.astype(jnp.int32), axis=1)
            unmatched = pvalid & (matched == 0)
            cat_vals = {}
            for v in node.out_vars:
                if probe_cols and v in probe_cols:
                    tail = probe_cols[v]
                else:
                    tail = jnp.full(cp, UNBOUND, jnp.int32)
                cat_vals[v] = jnp.concatenate([out_vals[v], tail])
            flat_mask = jnp.concatenate([flat_mask, unmatched])
            out_vals = cat_vals

        cols, n, total = _compact(out_vals, flat_mask, cap)
        self.needed[f"joinC{node.node_id}"] = total
        return cols, n

    # -- union / optional-chain provenance ------------------------------------

    def _union(self, node: P.UnionNode):
        """Fused concat-with-provenance: every arm's packed rows scatter
        into one output table at that arm's running offset (arm-major
        order — a row's provenance is its arm's offset range); variables
        an arm does not bind stay at the unbound sentinel."""
        arm_results = [self._eval(a) for a in node.arms]
        cap = self.caps[f"unionC{node.node_id}"]
        out = {v: jnp.full(cap, UNBOUND, jnp.int32) for v in node.out_vars}
        offset = jnp.int32(0)
        for acols, an in arm_results:
            acap = len(next(iter(acols.values()))) if acols else 1
            j = jnp.arange(acap, dtype=jnp.int32)
            pos = offset + j
            keep = (j < an) & (pos < cap)
            idx = jnp.where(keep, pos, cap)
            for v in node.out_vars:
                if v in acols:
                    out[v] = out[v].at[idx].set(acols[v], mode="drop")
            offset = offset + an.astype(jnp.int32)
        self.needed[f"unionC{node.node_id}"] = offset
        self._sorted[node.node_id] = ()
        return out, jnp.minimum(offset, cap)

    def _tag_rows(self, node: P.TagRows):
        """Append the packed row index as a synthetic column — the
        provenance an OPTIONAL bind-join chain joins back on.  Row ids are
        strictly increasing, so any known sort sequence extends by them."""
        cols, n = self._eval(node.child)
        cap = len(next(iter(cols.values()))) if cols else 1
        out = dict(cols)
        out[node.var] = jnp.arange(cap, dtype=jnp.int32)
        self._sorted[node.node_id] = (
            self._sorted.get(node.child.node_id, ()) + (node.var,)
        )
        return out, n

    def _left_finish(self, node: P.LeftFinish):
        """Finish a multi-pattern OPTIONAL chain: the chain's packed rows
        are the matches; left rows whose row id never reached the chain
        output append after them with the group's variables unbound."""
        lcols, ln = self._eval(node.left)
        rcols, rn = self._eval(node.right)
        capL = len(next(iter(lcols.values())))
        capR = len(next(iter(rcols.values())))
        cap = self.caps[f"leftC{node.node_id}"]
        lvalid = jnp.arange(capL) < ln
        rvalid = jnp.arange(capR) < rn
        rid = rcols[node.rowid]
        matched = (
            jnp.zeros(capL, bool)
            .at[jnp.where(rvalid, rid, capL)]
            .set(True, mode="drop")
        )
        unmatched = lvalid & ~matched
        out = {v: jnp.full(cap, UNBOUND, jnp.int32) for v in node.out_vars}
        jr = jnp.arange(capR, dtype=jnp.int32)
        idx_r = jnp.where(rvalid & (jr < cap), jr, cap)
        for v in node.out_vars:
            if v in rcols:
                out[v] = out[v].at[idx_r].set(rcols[v], mode="drop")
        upos_raw = rn + jnp.cumsum(unmatched.astype(jnp.int32)) - 1
        upos = jnp.where(unmatched & (upos_raw < cap), upos_raw, cap)
        for v in node.out_vars:
            if v in lcols:
                out[v] = out[v].at[upos].set(lcols[v], mode="drop")
        total = rn + jnp.sum(unmatched.astype(jnp.int32))
        self.needed[f"leftC{node.node_id}"] = total
        self._sorted[node.node_id] = ()
        return out, jnp.minimum(total, cap)

    # -- filters -------------------------------------------------------------

    def _gather_side(self, array, ids):
        return array[jnp.clip(ids, 0, array.shape[0] - 1)]

    def _cmp(self, c: P.LCmp, cols: dict, cap: int):
        is_lit, is_num, str_rank, num_rank = self.vt_arrays[:4]

        def var_ids(o: P.LOperand):
            if o.var in cols:
                return cols[o.var]
            return jnp.full(cap, UNBOUND, jnp.int32)  # never-bound variable

        def rank_of(o: P.LOperand, table, okmask):
            ids = var_ids(o)
            ok = (ids >= 0) & self._gather_side(okmask, ids)
            return self._gather_side(table, ids), ok

        op = c.op
        if c.mode in ("num", "str"):
            table, okmask = (
                (num_rank, is_num) if c.mode == "num" else (str_rank, is_lit)
            )
            rank, ok = rank_of(c.lhs, table, okmask)
            lo = self.fops[c.rhs.slot]
            hi = self.fops[c.rhs.slot + 1]
            present = lo < hi
            if op == "<":
                return ok & (rank < lo)
            if op == "<=":
                return ok & (rank < hi)
            if op == ">":
                return ok & (rank >= hi)
            if op == ">=":
                return ok & (rank >= lo)
            if op == "=":
                return ok & present & (rank == lo)
            return ok & ~(present & (rank == lo))  # !=
        if c.mode == "term":
            x = var_ids(c.lhs)
            if c.rhs.kind == "var":
                y = var_ids(c.rhs)
                both = (x >= 0) & (y >= 0)
                return both & ((x == y) if op == "=" else (x != y))
            cid = self.fops[c.rhs.slot]
            bound = x >= 0
            return bound & ((x == cid) if op == "=" else (x != cid))
        # mode 'vv': ordering between two variables — numeric when both
        # numeric, else literal-body order when both literals, else false
        x, y = var_ids(c.lhs), var_ids(c.rhs)
        bound = (x >= 0) & (y >= 0)
        xn = self._gather_side(num_rank, x)
        yn = self._gather_side(num_rank, y)
        xs = self._gather_side(str_rank, x)
        ys = self._gather_side(str_rank, y)
        both_num = self._gather_side(is_num, x) & self._gather_side(is_num, y)
        both_lit = self._gather_side(is_lit, x) & self._gather_side(is_lit, y)

        def rel(a, b):
            if op == "<":
                return a < b
            if op == "<=":
                return a <= b
            if op == ">":
                return a > b
            return a >= b

        return bound & jnp.where(
            both_num, rel(xn, yn), both_lit & rel(xs, ys)
        )

    def _expr(self, e: P.LExpr, cols: dict, cap: int):
        if isinstance(e, P.LCmp):
            return self._cmp(e, cols, cap)
        if isinstance(e, P.LBound):
            if e.var in cols:
                return cols[e.var] >= 0
            return jnp.zeros(cap, bool)
        if isinstance(e, P.LNot):
            return ~self._expr(e.expr, cols, cap)
        if isinstance(e, P.LAnd):
            return self._expr(e.lhs, cols, cap) & self._expr(e.rhs, cols, cap)
        return self._expr(e.lhs, cols, cap) | self._expr(e.rhs, cols, cap)

    def _filter(self, node: P.Filter):
        cols, n = self._eval(node.child)
        self._sorted[node.node_id] = self._sorted.get(node.child.node_id, ())
        if not cols:  # zero-variable table: expr sees only unbound vars
            cap = 1
            keep = self._expr(node.expr, cols, cap)
            return cols, jnp.where(keep[0], n, 0)
        cap = len(next(iter(cols.values())))
        mask = self._expr(node.expr, cols, cap) & (jnp.arange(cap) < n)
        return _compact(cols, mask, cap)[:2]

    # -- tail ----------------------------------------------------------------

    def _already_ordered(self, node) -> bool:
        """True when the child's known sort sequence already starts with
        this node's output columns — the determinism sort is a no-op."""
        child_sorted = self._sorted.get(node.child.node_id, ())
        return child_sorted[: len(node.out_vars)] == node.out_vars

    def _project(self, node: P.Project):
        cols, n = self._eval(node.child)
        child_sorted = self._sorted.get(node.child.node_id, ())
        kept = []
        for v in child_sorted:  # dropping a sort column cuts the sequence
            if v not in node.out_vars:
                break
            kept.append(v)
        self._sorted[node.node_id] = tuple(kept)
        cap = len(next(iter(cols.values()))) if cols else 1
        out = {}
        for v in node.out_vars:
            out[v] = cols[v] if v in cols else jnp.full(cap, UNBOUND, jnp.int32)
        return out, n

    def _group(self, node: P.Group):
        """GROUP BY + COUNT via a device segment-sum: sort by the key
        columns, find segment boundaries, count each segment's
        contributions (1 per row for COUNT(*), boundness of the argument
        for COUNT(?v)), and emit one packed row per segment — output rows
        are unique in the key tuple, so they come out sorted by it."""
        cols, n = self._eval(node.child)
        cap = len(next(iter(cols.values()))) if cols else 1
        valid = jnp.arange(cap) < n
        if node.count_var is None:
            contrib = valid.astype(jnp.int32)
        else:
            cv = cols.get(node.count_var)
            contrib = (
                jnp.zeros(cap, jnp.int32)
                if cv is None
                else (valid & (cv >= 0)).astype(jnp.int32)
            )
        if not node.keys:
            # the global group: exactly one row, even over zero solutions
            total = jnp.sum(contrib)
            out = {
                v: jnp.zeros(1, jnp.int32).at[0].set(total)
                for v in node.out_vars  # validation: only the alias
            }
            self._sorted[node.node_id] = node.out_vars
            return out, jnp.int32(1)
        key_cols = {
            k: cols.get(k, jnp.full(cap, UNBOUND, jnp.int32))
            for k in node.keys
        }
        perm, _ = _sort_perm(key_cols, node.keys, n, cap)
        skeys = {k: c[perm] for k, c in key_cols.items()}
        svalid = valid[perm]
        scontrib = contrib[perm]
        same_prev = jnp.ones(cap, bool)
        for k in node.keys:
            c = skeys[k]
            same_prev = same_prev & jnp.concatenate(
                [jnp.zeros(1, bool), c[1:] == c[:-1]]
            )
        boundary = svalid & ~same_prev
        gid_raw = jnp.cumsum(boundary.astype(jnp.int32)) - 1
        gid = jnp.where(svalid, gid_raw, cap)
        counts = jnp.zeros(cap, jnp.int32).at[gid].add(scontrib, mode="drop")
        n_groups = jnp.sum(boundary.astype(jnp.int32))
        bidx = jnp.where(boundary, gid_raw, cap)
        out = {}
        for v in node.out_vars:
            if v == node.alias:
                out[v] = counts
            else:  # a selected group key: its value at each segment head
                out[v] = (
                    jnp.full(cap, UNBOUND, jnp.int32)
                    .at[bidx]
                    .set(skeys[v], mode="drop")
                )
        # output rows are unique in the full key tuple and sorted by it,
        # so any column extension of the key sequence stays sorted
        seq: list[str] = []
        for k in node.keys:
            if k not in node.out_vars:
                break
            seq.append(k)
        if len(seq) == len(node.keys):
            seq += [v for v in node.out_vars if v not in seq]
        self._sorted[node.node_id] = tuple(seq)
        return out, n_groups

    def _distinct(self, node: P.Distinct):
        cols, n = self._eval(node.child)
        self._sorted[node.node_id] = node.out_vars
        if not cols:
            return cols, jnp.minimum(n, 1)
        cap = len(next(iter(cols.values())))
        if self._already_ordered(node):
            sorted_cols, svalid = cols, jnp.arange(cap) < n
        else:
            perm, valid = _sort_perm(cols, node.out_vars, n, cap)
            sorted_cols = {v: c[perm] for v, c in cols.items()}
            svalid = valid[perm]
        same_prev = jnp.ones(cap, bool)
        for v in node.out_vars:
            c = sorted_cols[v]
            same_prev = same_prev & jnp.concatenate(
                [jnp.zeros(1, bool), c[1:] == c[:-1]]
            )
        keep = svalid & ~same_prev
        return _compact(sorted_cols, keep, cap)[:2]

    def _sort(self, node: P.Sort):
        cols, n = self._eval(node.child)
        self._sorted[node.node_id] = node.out_vars
        if not cols:
            return cols, n
        if self._already_ordered(node):
            return cols, n
        cap = len(next(iter(cols.values())))
        perm, valid = _sort_perm(cols, node.out_vars, n, cap)
        return {v: c[perm] for v, c in cols.items()}, n

    def _order_by(self, node: P.OrderBy):
        """Value-typed ORDER BY: term columns key on ``order_rank`` (the
        store-wide value order permutation), count columns on their raw
        integer value; descending keys negate; every output column
        tie-breaks in term-id order so the result stays deterministic.
        Elided when the child's tracked sortedness already realizes the
        requested order (possible only when value order == term-id
        order, or when every key is a count column)."""
        cols, n = self._eval(node.child)
        self._sorted[node.node_id] = ()
        if not cols:
            return cols, n
        cap = len(next(iter(cols.values())))
        keyvars = tuple(v for v, _, _ in node.keys)
        desired = keyvars + tuple(
            v for v in node.out_vars if v not in keyvars
        )
        elidable = all(asc for _, asc, _ in node.keys) and (
            self.order_is_tid
            or all(is_count for _, _, is_count in node.keys)
        )
        child_sorted = self._sorted.get(node.child.node_id, ())
        if elidable and child_sorted[: len(desired)] == desired:
            self._sorted[node.node_id] = child_sorted
            return cols, n
        valid = jnp.arange(cap) < n
        order_rank = self.vt_arrays[4]
        keys = []
        for v, asc, is_count in node.keys:
            c = cols.get(v, jnp.full(cap, UNBOUND, jnp.int32))
            if is_count:
                k = c
            else:
                # unbound (-1) keys below every rank: unbound-first
                # ascending, unbound-last descending
                k = jnp.where(
                    c >= 0, self._gather_side(order_rank, c), jnp.int32(-1)
                )
            if not asc:
                k = -k
            keys.append(jnp.where(valid, k, I32_MAX))
        for v in node.out_vars:  # term-id tie-break: determinism
            c = cols.get(v, jnp.full(cap, UNBOUND, jnp.int32))
            keys.append(jnp.where(valid, c, I32_MAX))
        payload = jnp.arange(cap, dtype=jnp.int32)
        out = jax.lax.sort(
            tuple(keys) + (payload,), num_keys=len(keys), is_stable=True
        )
        perm = out[-1]
        return {v: c[perm] for v, c in cols.items()}, n

    # -- dispatch ------------------------------------------------------------

    def _eval(self, node: P.Node):
        hit = self._memo.get(node.node_id)
        if hit is not None:
            return hit
        res = self._eval_inner(node)
        self._memo[node.node_id] = res
        return res

    def _eval_inner(self, node: P.Node):
        if isinstance(node, P.Scan):
            return self._scan(node)
        if isinstance(node, P.BindJoin):
            return self._bind_join(node)
        if isinstance(node, P.Join):
            return self._join(node)
        if isinstance(node, P.UnionNode):
            return self._union(node)
        if isinstance(node, P.TagRows):
            return self._tag_rows(node)
        if isinstance(node, P.LeftFinish):
            return self._left_finish(node)
        if isinstance(node, P.Filter):
            return self._filter(node)
        if isinstance(node, P.Project):
            return self._project(node)
        if isinstance(node, P.Group):
            return self._group(node)
        if isinstance(node, P.Distinct):
            return self._distinct(node)
        if isinstance(node, P.Sort):
            return self._sort(node)
        if isinstance(node, P.OrderBy):
            return self._order_by(node)
        if isinstance(node, P.Limit):
            cols, n = self._eval(node.child)
            self._sorted[node.node_id] = self._sorted.get(
                node.child.node_id, ()
            )
            # the limit value is per-query runtime data (plan sharing);
            # -1 marks a padded batch row, where the count is 0 anyway
            return cols, jnp.where(
                self.qlimit >= 0, jnp.minimum(n, self.qlimit), n
            )
        raise TypeError(f"unknown plan node {node!r}")

    def run(
        self, scan_cols_flat, scan_keys_flat, scan_prim_flat,
        dscan_cols_flat, dscan_keys_flat, alive_flat, dn,
        vt_arrays, consts, fops, qvalid, qlimit,
    ):
        self.scan_cols = {
            s.node_id: scan_cols_flat[3 * i : 3 * i + 3]
            for i, s in enumerate(self.plan.scans)
        }
        self.scan_keys = {
            s.node_id: scan_keys_flat[i] if self.packed else None
            for i, s in enumerate(self.plan.scans)
        }
        self.scan_prim = {
            s.node_id: scan_prim_flat[i] if self.packed else None
            for i, s in enumerate(self.plan.scans)
        }
        self.dscan_cols = {
            s.node_id: dscan_cols_flat[3 * i : 3 * i + 3]
            for i, s in enumerate(self.plan.scans)
        }
        self.dscan_keys = {
            s.node_id: dscan_keys_flat[i] if self.packed else None
            for i, s in enumerate(self.plan.scans)
        }
        self.alive = {
            s.node_id: alive_flat[i] for i, s in enumerate(self.plan.scans)
        }
        self.dn = dn
        self.vt_arrays = vt_arrays
        self.consts = consts
        self.fops = fops
        self.qvalid = qvalid
        self.qlimit = qlimit
        self.needed = {}
        self._memo = {}
        cols, n = self._eval(self.plan.root)
        out_cols = tuple(cols.get(v) for v in self.plan.root.out_vars)
        return out_cols, n, dict(self.needed)


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------


def _initial_caps(plan: P.Plan, floors: dict[str, int]) -> dict[str, int]:
    caps: dict[str, int] = {}
    seen: set[int] = set()

    def walk(node: P.Node) -> None:
        if node.node_id in seen:  # the plan is a DAG: visit shared subtrees once
            return
        seen.add(node.node_id)
        if isinstance(node, P.Scan):
            if node.out_vars:
                caps[f"scan{node.node_id}"] = next_pow2(max(node.est, 1))
            return
        if isinstance(node, P.BindJoin):
            walk(node.left)
            if node.free_slots:
                if node.eq_pairs:  # grid fallback needs the fan-out cap
                    caps[f"bindM{node.node_id}"] = 8
                caps[f"bindC{node.node_id}"] = next_pow2(
                    min(max(node.est, 16), 1 << 22)
                )
            return
        if isinstance(node, P.Join):
            walk(node.left)
            walk(node.right)
            if node.right.out_vars and (
                node.left.out_vars or node.kind == "left"
            ):
                if node.shared:
                    caps[f"joinM{node.node_id}"] = 8
                # clamp the initial guess: a mis-estimated cross join must
                # not allocate a giant table up front (feedback grows it to
                # the exact need if the result really is that large)
                caps[f"joinC{node.node_id}"] = next_pow2(
                    min(max(node.est, 16), 1 << 22)
                )
            return
        if isinstance(node, P.UnionNode):
            for arm in node.arms:
                walk(arm)
            caps[f"unionC{node.node_id}"] = next_pow2(
                min(max(node.est, 16), 1 << 22)
            )
            return
        if isinstance(node, P.LeftFinish):
            walk(node.left)
            walk(node.right)
            caps[f"leftC{node.node_id}"] = next_pow2(
                min(max(node.est, 16), 1 << 22)
            )
            return
        for c in P._children(node):
            walk(c)

    walk(plan.root)
    for k, v in floors.items():
        if k in caps:
            caps[k] = max(caps[k], v)
    return caps


class Executor:
    """Per-store query executor: plan cache, capacity memory, compiled
    pipeline cache.  Get one via :func:`get_executor`."""

    #: route eligible small batches through the fused scan-join chain
    #: (``repro.serve.fastpath``); tests flip this off to force the
    #: general pipeline for equivalence checks
    fastpath_enabled = True

    def __init__(self, store: TripleStore):
        self.store = store
        self._plans: dict[tuple, P.Plan] = {}
        self._floors: dict[tuple, dict[str, int]] = {}
        self._compiled: dict[tuple, callable] = {}
        self._fastpaths: dict[tuple, "FP.SigFastPath | None"] = {}
        self.dispatches = 0  # total jitted pipeline dispatches (for tests)

    # -- plans ---------------------------------------------------------------

    def plan(self, q: A.SelectQuery) -> P.Plan:
        sig = q.signature()
        plan = self._plans.get(sig)
        if plan is None:
            plan = P.plan_query(self.store, q)
            self._plans[sig] = plan
        return plan

    # -- compilation ---------------------------------------------------------

    def _get_compiled(
        self, plan: P.Plan, caps: dict[str, int], bpad: int,
        ov: tuple[int, bool] | None = None,
    ):
        """``ov`` switches on the overlay arm: ``(delta row capacity,
        delta index packable)`` — part of the cache key, so pure-read
        pipelines never carry overlay code."""
        key = (plan.sig, tuple(sorted(caps.items())), bpad, ov)
        fn = self._compiled.get(key)
        if fn is not None:
            # signature-memo hit: this (plan, capacities, batch-pad) shape
            # re-dispatches without tracing a new pipeline
            get_registry().inc("exec.pipeline_cache_hit")
        else:
            get_registry().inc("exec.pipeline_cache_miss")
            if self.store.n_triples == 0:
                # overlay over an empty base: dummy single-row base
                # operands, every base range comes out empty
                base_packed = True
                prim_rounds = {s.node_id: 1 for s in plan.scans}
            else:
                base_packed = self.store.device_keys("spo") is not None
                prim_rounds = (
                    {
                        s.node_id: self.store.primary_rounds(s.order)
                        for s in plan.scans
                    }
                    if base_packed
                    else None
                )
            # a combined term table that overflows the packed key fields
            # forces both arms onto the 3-column lexicographic fallback
            packed = base_packed and (ov is None or ov[1])
            if not packed:
                prim_rounds = None
            order_is_tid = (
                value_table(self.store).order_is_tid
                if plan.needs_values and ov is None
                else False  # overlay term ids append out of rendered order
            )
            delta_cap = ov[0] if ov else 1
            lowerer = _Lowerer(
                plan,
                caps,
                max(self.store.n_triples, 1),
                self.store.KEY_BITS,
                packed,
                prim_rounds,
                order_is_tid,
                overlay=ov is not None,
                delta_cap=delta_cap,
                delta_rounds=max(1, int(delta_cap).bit_length()),
            )

            def single(
                scan_cols_flat, scan_keys_flat, scan_prim_flat,
                dscan_cols_flat, dscan_keys_flat, alive_flat, dn,
                vt_arrays, consts, fops, qvalid, qlimit,
            ):
                return lowerer.run(
                    scan_cols_flat, scan_keys_flat, scan_prim_flat,
                    dscan_cols_flat, dscan_keys_flat, alive_flat, dn,
                    vt_arrays, consts, fops, qvalid, qlimit,
                )

            fn = jax.jit(
                jax.vmap(
                    single,
                    in_axes=(None,) * 8 + (0, 0, 0, 0),
                )
            )
            self._compiled[key] = fn
        return fn

    # -- execution -----------------------------------------------------------

    def execute(
        self, plan: P.Plan, queries: list[A.SelectQuery], view=None
    ) -> BatchResult:
        """Run signature-equal ``queries`` as one micro-batch: encode each
        query's constants, then dispatch through :meth:`execute_encoded`.
        ``view`` (a :class:`repro.live.delta.OverlayView` over this
        executor's store) answers over ``base ⊕ delta``; an inactive view
        (empty overlay) takes the pure-read fast path untouched."""
        act = view is not None and view.active
        enc = view if act else self.store
        bsz = len(queries)
        consts = np.full((bsz, len(plan.scans), 3), -2, np.int32)
        fops = np.zeros((bsz, max(plan.n_filter_ops, 1)), np.int32)
        vt = value_table(enc) if plan.has_filters else None
        for i, q in enumerate(queries):
            consts[i] = P.encode_scan_consts(enc, plan, q)
            if plan.n_filter_ops:
                fops[i] = P.encode_filter_ops(enc, vt, q.filters)
        limits = np.asarray(
            [-1 if q.limit is None else q.limit for q in queries], np.int32
        )
        return self.execute_encoded(
            plan, consts, fops, limits, view=view if act else None
        )

    def execute_encoded(
        self,
        plan: P.Plan,
        consts: np.ndarray,
        fops: np.ndarray | None = None,
        limits: np.ndarray | None = None,
        view=None,
    ) -> BatchResult:
        """The pre-encoded hot path (the benchmark's unit of work): run a
        ``[B, n_scans, 3]`` int32 constants batch (``-1`` variable slot,
        ``-2`` unknown constant) plus optional ``[B, n_filter_ops]`` filter
        operands, padded to a power-of-two batch, re-dispatching only when
        a capacity was exceeded.  ``view`` (an *active* overlay view whose
        constants/filter operands were encoded against it) adds the second
        scan arm; its results decode against the view's combined terms."""
        store = self.store
        out_vars = plan.root.out_vars
        bsz = consts.shape[0]
        if store.n_triples == 0 and view is None:
            if plan.global_agg_alias is not None:
                # a global COUNT answers one zero row even over nothing
                lim = (
                    np.full(bsz, -1, np.int64)
                    if limits is None
                    else np.asarray(limits, np.int64)[:bsz]
                )
                counts = np.where(lim >= 0, np.minimum(lim, 1), 1)
                return BatchResult(
                    store=store,
                    vars=out_vars,
                    cols={v: np.zeros((bsz, 1), np.int32) for v in out_vars},
                    counts=counts.astype(np.int64),
                    agg_vars=plan.agg_vars,
                )
            return BatchResult(
                store=store,
                vars=out_vars,
                cols={v: np.full((bsz, 1), -1, np.int32) for v in out_vars},
                counts=np.zeros(bsz, np.int64),
                agg_vars=plan.agg_vars,
            )
        if (
            view is None
            and self.fastpath_enabled
            and bsz <= FP.MAX_BATCH
        ):
            fp = self._fastpaths.get(plan.sig, _FP_UNSET)
            if fp is _FP_UNSET:
                fp = FP.build(self, plan)
                self._fastpaths[plan.sig] = fp
            if fp is not None:
                res = fp.dispatch(consts, limits, bsz)
                if res is not None:  # None: outgrew the small-batch regime
                    fcols, counts = res
                    return BatchResult(
                        store=store,
                        vars=out_vars,
                        cols=dict(zip(out_vars, fcols)),
                        counts=counts,
                        agg_vars=plan.agg_vars,
                    )
        bpad = next_pow2(max(bsz, 1))
        if fops is None:
            fops = np.zeros((bsz, max(plan.n_filter_ops, 1)), np.int32)
        if limits is None:
            limits = np.full(bsz, -1, np.int32)
        if bpad > bsz:
            consts = np.concatenate(
                [consts, np.full((bpad - bsz, len(plan.scans), 3), -2, np.int32)]
            )
            fops = np.concatenate(
                [fops, np.zeros((bpad - bsz, fops.shape[1]), np.int32)]
            )
            limits = np.concatenate(
                [limits, np.full(bpad - bsz, -1, np.int32)]
            )
        qvalid = np.zeros(bpad, bool)
        qvalid[:bsz] = True
        enc = view if view is not None else store
        vt = value_table(enc) if plan.needs_values else None

        n_scans = len(plan.scans)
        z = jnp.zeros(1, jnp.int32)
        if store.n_triples == 0:
            # empty base under an active overlay: single-row dummies keep
            # every gather in range; the alive prefix sums (length 1) make
            # every base range empty
            scan_cols_flat = (z,) * (3 * n_scans)
            scan_keys_flat = ((z, z),) * n_scans
            scan_prim_flat = (z,) * n_scans
        else:
            scan_cols_flat = tuple(
                c for s in plan.scans for c in store.device_cols(s.order)
            )
            if store.device_keys("spo") is not None:
                scan_keys_flat = tuple(
                    store.device_keys(s.order) for s in plan.scans
                )
                scan_prim_flat = tuple(
                    store.device_primary_starts(s.order) for s in plan.scans
                )
            else:
                scan_keys_flat = ((z, z),) * n_scans
                scan_prim_flat = (z,) * n_scans
        if view is not None:
            ov_packed = view.delta.device_keys("spo") is not None
            ov = (view.delta.n_triples, ov_packed)
            dscan_cols_flat = tuple(
                c for s in plan.scans for c in view.delta.device_cols(s.order)
            )
            if ov_packed:
                dscan_keys_flat = tuple(
                    view.delta.device_keys(s.order) for s in plan.scans
                )
            else:
                dscan_keys_flat = ((z, z),) * n_scans
            alive_flat = tuple(view.alive(s.order) for s in plan.scans)
            dn_j = jnp.asarray(view.n_delta, jnp.int32)
        else:
            ov = None
            dscan_cols_flat = (z,) * (3 * n_scans)
            dscan_keys_flat = ((z, z),) * n_scans
            alive_flat = (z,) * n_scans
            dn_j = jnp.asarray(0, jnp.int32)
        if plan.needs_values:
            vt_arrays = (
                vt.is_lit, vt.is_num, vt.str_rank, vt.num_rank, vt.order_rank
            )
        else:
            z = jnp.zeros(1, bool)
            zi = jnp.zeros(1, jnp.int32)
            vt_arrays = (z, z, zi, zi, zi)

        floors = self._floors.setdefault(plan.sig, {})
        caps = _initial_caps(plan, floors)
        consts_j = jnp.asarray(consts)
        fops_j = jnp.asarray(fops)
        qvalid_j = jnp.asarray(qvalid)
        qlimit_j = jnp.asarray(limits)
        reg = get_registry()
        tracer = get_tracer()
        label = plan_label(plan.sig)
        reg.inc("exec.batches")
        reg.inc("exec.queries", bsz)
        for round_i in range(_MAX_GROW_ROUNDS):
            t0 = time.perf_counter_ns()
            fn = self._get_compiled(plan, caps, bpad, ov)
            out_cols, n, needed = fn(
                scan_cols_flat, scan_keys_flat, scan_prim_flat,
                dscan_cols_flat, dscan_keys_flat, alive_flat, dn_j,
                vt_arrays, consts_j, fops_j, qvalid_j, qlimit_j,
            )
            self.dispatches += 1
            grown = False
            for k, arr in needed.items():
                want = int(np.max(np.asarray(arr)))
                if want > caps[k]:
                    caps[k] = next_pow2(want)
                    floors[k] = max(floors.get(k, 0), caps[k])
                    grown = True
                    # grow-only buffer growth: remembered per signature, so
                    # a steady workload stops paying this re-dispatch
                    reg.inc("exec.cap_growth")
            t1 = time.perf_counter_ns()
            reg.inc("exec.dispatches")
            reg.observe("exec.dispatch_ms", (t1 - t0) / 1e6)
            if round_i > 0:
                reg.inc("exec.redispatches")
            if tracer.enabled:
                tracer.add_complete(
                    "redispatch" if round_i > 0 else "dispatch",
                    "exec", t0, t1,
                    plan=label, batch=bsz, round=round_i,
                    grown=grown,
                )
            if not grown:
                break
        else:
            raise RuntimeError(
                "executor capacity feedback did not converge "
                f"(caps={caps}) — pathological query?"
            )
        counts = np.asarray(n)[:bsz].astype(np.int64)
        cols = {
            v: np.asarray(c)[:bsz]
            for v, c in zip(out_vars, out_cols)
        } if out_cols else {}
        return BatchResult(
            store=enc, vars=out_vars, cols=cols, counts=counts,
            agg_vars=plan.agg_vars,
        )

    def solve(self, q: A.SelectQuery) -> BatchResult:
        return self.execute(self.plan(q), [q])

    def warmup(self, top_k: int = 2) -> int:
        """Pre-trace the dominant interactive shapes — the 1-, 2- and
        3-pattern star chains anchored on the store's ``top_k`` most
        frequent predicates — at batch pad 1, so a freshly started
        server answers its first small-batch query without paying a jit
        compile.  Returns the number of signatures warmed (compilation
        happens as a side effect of actually executing each shape
        once; the capacity floors learned here persist too)."""
        store = self.store
        if store.n_triples == 0:
            return 0
        prim = np.asarray(store.indexes["pos"].cols[0])
        preds, cnts = np.unique(prim, return_counts=True)
        top = [
            store.decode_term(int(p))
            for p in preds[np.argsort(cnts)[::-1][: max(top_k, 1)]]
        ]
        texts = []
        for p in top:
            texts.append(f"SELECT * WHERE {{ ?s {p} ?o }}")
        if len(top) >= 2:
            p0, p1 = top[0], top[1]
            texts.append(
                f"SELECT * WHERE {{ ?s {p0} ?o0 . ?s {p1} ?o1 }}"
            )
            texts.append(
                "SELECT * WHERE { "
                + f"?s {p0} ?o0 . ?s {p1} ?o1 . ?s {p0} ?o2 "
                + "}"
            )
        warmed = 0
        for text in texts:
            try:
                q = A.parse_select(text)
                self.execute(self.plan(q), [q])
                warmed += 1
            except Exception:  # a shape the store can't serve: skip it
                continue
        return warmed


def get_executor(store: TripleStore) -> Executor:
    ex = getattr(store, "_serve_executor", None)
    if ex is None:
        ex = Executor(store)
        store._serve_executor = ex
    return ex


def solve_select(store: TripleStore, q: A.SelectQuery) -> BatchResult:
    """One-shot convenience: plan + execute a single query."""
    return get_executor(store).solve(q)
