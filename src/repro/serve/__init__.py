"""``repro.serve`` — SPARQL-lite query algebra + batching query server.

The serving half of the KG lifecycle, layered over ``repro.kg`` stores:

* :mod:`repro.serve.algebra` — the query IR (``SelectQuery``: BGP +
  UNION + OPTIONAL + FILTER + projection / GROUP BY + COUNT / DISTINCT /
  ORDER BY / LIMIT) and its parser.
* :mod:`repro.serve.plan`    — cost-based planner: index-measured scan
  cardinalities, greedy connected join ordering, filter pushdown.
* :mod:`repro.serve.exec`    — the jitted executor: a whole plan (and a
  whole batch of same-shape queries) runs as one fused device dispatch;
  bindings never materialize on host between joins.
* :mod:`repro.serve.values`  — literal value side tables (numeric/string
  ranks) decoded once per store for FILTER evaluation on term ids.
* :mod:`repro.serve.server`  — long-lived socket server micro-batching
  concurrent clients by plan signature; :mod:`repro.serve.client` talks to
  it (newline-delimited JSON).
* :mod:`repro.serve.oracle`  — the naive full-algebra oracle anchoring the
  tests.

Entry point: ``python -m repro.launch.serve --kg out.kgz``.
"""

from repro.serve.algebra import Count, SelectQuery, parse_select
from repro.serve.exec import BatchResult, Executor, get_executor, solve_select
from repro.serve.oracle import oracle_select
from repro.serve.plan import Plan, plan_query

__all__ = [
    "BatchResult",
    "Count",
    "Executor",
    "Plan",
    "SelectQuery",
    "get_executor",
    "oracle_select",
    "parse_select",
    "plan_query",
    "solve_select",
]
