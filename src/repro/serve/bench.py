"""Serving throughput for the fused query pipeline (``BENCH_serve.json``).

Six query classes over the paper's testbed store, each at batch sizes
1 / 64 / 4096 through the pre-encoded executor hot path (the same unit of
work ``repro.kg.bench`` measures for single patterns, so the numbers are
directly comparable to ``BENCH_kg.json``):

* ``single``     — ``?s <p> <o>`` point lookups;
* ``bgp3``       — a 3-pattern star BGP anchored at a selective constant
  (two sorted-merge joins fused into the dispatch);
* ``opt_filter`` — 2 required patterns + ``OPTIONAL`` + ``FILTER`` (join,
  left-join backfill and side-table filtering in one dispatch);
* ``union``      — an anchored pattern joined with a 2-arm ``UNION``
  (shared required scan, fused concat-with-provenance);
* ``orderby``    — an anchored 2-pattern BGP under ``ORDER BY DESC``
  (value-typed rank sort on device);
* ``groupcount`` — an anchored 3-pattern BGP under ``GROUP BY`` +
  ``COUNT`` (key sort + segment-sum in the same dispatch).

Every query is derived from an existing triple, so every query has at
least one answer.  Constants vary per query; the plan (and the compiled
pipeline) is shared per class — exactly the server's steady state.

A separate ``smallbatch`` section times the interactive regime: the
``single`` and ``bgp3`` classes at batch 1 / 8 / 64, where dispatches
route through the fused scan-join fast path (``repro.serve.fastpath``).
Its ``latency_p99_ms`` leaves are what the CI regression gate watches
for the per-dispatch constant, and ``fastpath_dispatches`` records the
routing share so a silently disabled fast path is visible in the report.

An empty store yields the zero-query report (:func:`empty_report`) —
sections exist, counts are zero — instead of erroring, so ``--bench``
CLI paths and CI never need ad-hoc guards.
"""

from __future__ import annotations

import time

import numpy as np

from repro.kg.store import TripleStore
from repro.obs import Histogram
from repro.serve import algebra as A
from repro.serve import plan as P
from repro.serve.exec import Executor, get_executor

BATCH_SIZES = (1, 64, 4096)

CLASS_NAMES = ("single", "bgp3", "opt_filter", "union", "orderby", "groupcount")

# the interactive regime: the small-batch fast path's own section
# (single and bgp3 are chain-eligible; batch sizes bracket the
# fast path's routing window)
SMALLBATCH_SIZES = (1, 8, 64)
SMALLBATCH_CLASSES = ("single", "bgp3")


def empty_report(
    store: TripleStore, batch_sizes: tuple[int, ...] = BATCH_SIZES
) -> dict:
    """The zero-query report for an empty store: every class/batch section
    present with zero counts, so downstream consumers (CI gate, json
    diffing) see the same shape as a real run."""
    zero = {
        "n_queries": 0,
        "n_batches": 0,
        "wall_s": 0.0,
        "queries_per_s": 0.0,
        "warm_matches": 0,
        "latency_p50_ms": 0.0,
        "latency_p99_ms": 0.0,
        "latency_max_ms": 0.0,
    }
    return {
        "n_triples": int(store.n_triples),
        "n_terms": int(store.n_terms),
        "empty_store": True,
        "classes": {
            name: {
                "query": None,
                "batches": {str(b): dict(zero) for b in batch_sizes},
            }
            for name in CLASS_NAMES
        },
        "smallbatch": {
            name: {
                "query": None,
                "batches": {str(b): dict(zero) for b in SMALLBATCH_SIZES},
            }
            for name in SMALLBATCH_CLASSES
        },
    }


def _workload_preds(store: TripleStore) -> list[int]:
    """Predicate term ids sorted by frequency (most common last)."""
    ids, counts = np.unique(store.p, return_counts=True)
    return [int(t) for t in ids[np.argsort(counts)]]


def _anchor_pool(store: TripleStore, p0: int, seed: int) -> np.ndarray:
    """Object ids of ``p0`` triples — each anchors a non-empty query."""
    rows = np.nonzero(store.p == p0)[0]
    rng = np.random.default_rng(seed)
    return store.o[rows[rng.integers(0, len(rows), 1 << 16)]]


def _classes(store: TripleStore):
    """(name, representative query text, anchor scan pattern_pos)."""
    preds = _workload_preds(store)
    if len(preds) < 3:
        raise ValueError("serve bench needs >= 3 predicates in the store")
    p0, p1, p2 = preds[0], preds[1], preds[2]
    t0, t1, t2 = (store.decode_term(p) for p in (p0, p1, p2))
    some_o = store.decode_term(int(_anchor_pool(store, p0, 0)[0]))
    return p0, [
        ("single", f"?s {t0} {some_o}"),
        ("bgp3", f"?m {t0} {some_o} . ?m {t1} ?b . ?m {t2} ?c"),
        (
            "opt_filter",
            f"SELECT * WHERE {{ ?m {t0} {some_o} . ?m {t1} ?b "
            f'OPTIONAL {{ ?m {t2} ?c }} FILTER(?b != "@none@") }}',
        ),
        (
            "union",
            f"SELECT * WHERE {{ ?m {t0} {some_o} "
            f"{{ ?m {t1} ?b }} UNION {{ ?m {t2} ?b }} }}",
        ),
        (
            "orderby",
            f"SELECT ?m ?b WHERE {{ ?m {t0} {some_o} . ?m {t1} ?b }} "
            "ORDER BY DESC(?b)",
        ),
        (
            "groupcount",
            f"SELECT ?b (COUNT(?c) AS ?n) WHERE {{ ?m {t0} {some_o} . "
            f"?m {t1} ?b . ?m {t2} ?c }} GROUP BY ?b",
        ),
    ]


def _encoded_batches(
    executor: Executor,
    qtext: str,
    p0: int,
    batch: int,
    n_batches: int,
    seed: int,
):
    """Pre-encode ``n_batches`` constants batches: the representative
    query's encoding tiled, with the anchor object id varied per query."""
    store = executor.store
    q = A.parse_select(qtext)
    plan = executor.plan(q)
    rep = P.encode_scan_consts(store, plan, q)
    # the anchor scan is the one reading pattern 0 (the only pattern whose
    # object slot holds a constant anchored at p0)
    anchor_scan = next(
        i for i, s in enumerate(plan.scans) if s.pattern_pos == 0
    )
    fops = None
    if plan.n_filter_ops:
        from repro.serve.values import value_table

        f1 = P.encode_filter_ops(store, value_table(store), q.filters)
        fops = np.tile(f1, (batch, 1))
    pool = _anchor_pool(store, p0, seed)
    batches = []
    for b in range(n_batches):
        consts = np.tile(rep, (batch, 1, 1))
        consts[:, anchor_scan, 2] = pool[b * batch : (b + 1) * batch]
        batches.append(consts)
    return plan, batches, fops


def bench_serve(
    store: TripleStore,
    batch_sizes: tuple[int, ...] = BATCH_SIZES,
    target_queries: int = 50_000,
    seed: int = 0,
) -> dict:
    """Time every query class at every batch size; returns a json-ready
    report keyed ``{class: {batch: {queries_per_s, ...}}}``.  Empty
    stores report zero-query sections instead of erroring."""
    if store.n_triples == 0:
        return empty_report(store, batch_sizes)
    executor = get_executor(store)
    p0, classes = _classes(store)
    report: dict = {
        "n_triples": int(store.n_triples),
        "n_terms": int(store.n_terms),
        "classes": {},
    }
    for name, qtext in classes:
        per_batch = {}
        for batch in batch_sizes:
            n_batches = max(1, min(target_queries // batch, 64))
            plan, batches, fops = _encoded_batches(
                executor, qtext, p0, batch, n_batches, seed
            )
            # warm-up: compile + let the capacity feedback converge
            total = 0
            for consts in batches[: max(2, n_batches // 8)]:
                total += int(
                    executor.execute_encoded(plan, consts, fops).counts.sum()
                )
            # per-dispatch latency lands in an obs histogram: p50/p99 are
            # what the CI tail-latency gate consumes (<= 6.25% bucket
            # error, far inside the 50% gate threshold)
            lat = Histogram()
            t0 = time.perf_counter()
            for consts in batches:
                d0 = time.perf_counter_ns()
                executor.execute_encoded(plan, consts, fops)
                lat.observe((time.perf_counter_ns() - d0) / 1e6)
            dt = time.perf_counter() - t0
            n_queries = n_batches * batch
            per_batch[str(batch)] = {
                "n_queries": n_queries,
                "n_batches": n_batches,
                "wall_s": dt,
                "queries_per_s": n_queries / dt,
                "warm_matches": total,
                "latency_p50_ms": lat.percentile(50),
                "latency_p99_ms": lat.percentile(99),
                "latency_max_ms": lat.max,
            }
        report["classes"][name] = {"query": qtext, "batches": per_batch}

    # the interactive regime: per-dispatch p50/p99 at batch 1/8/64 for
    # the chain-eligible classes, where the small-batch fast path (one
    # fused scan-join launch, packed per-query staging row) carries the
    # dispatch.  Many more batches than the throughput loop above, so
    # the p99 is a real tail, and the fastpath share is recorded so a
    # routing regression (fast path silently disabled) shows up in the
    # report, not just in the latency gate.
    from repro.obs import get_registry

    reg = get_registry()
    by_name = dict(classes)
    report["smallbatch"] = {}
    for name in SMALLBATCH_CLASSES:
        qtext = by_name[name]
        per_batch = {}
        for batch in SMALLBATCH_SIZES:
            n_batches = max(16, min(2048 // batch, 256))
            plan, batches, fops = _encoded_batches(
                executor, qtext, p0, batch, n_batches, seed
            )
            total = 0
            for consts in batches[: max(2, n_batches // 8)]:
                total += int(
                    executor.execute_encoded(plan, consts, fops).counts.sum()
                )
            fp0 = reg.counter("exec.fastpath_dispatches").value
            lat = Histogram()
            t0 = time.perf_counter()
            for consts in batches:
                d0 = time.perf_counter_ns()
                executor.execute_encoded(plan, consts, fops)
                lat.observe((time.perf_counter_ns() - d0) / 1e6)
            dt = time.perf_counter() - t0
            n_queries = n_batches * batch
            per_batch[str(batch)] = {
                "n_queries": n_queries,
                "n_batches": n_batches,
                "wall_s": dt,
                "queries_per_s": n_queries / dt,
                "warm_matches": total,
                "fastpath_dispatches":
                    reg.counter("exec.fastpath_dispatches").value - fp0,
                "latency_p50_ms": lat.percentile(50),
                "latency_p99_ms": lat.percentile(99),
                "latency_max_ms": lat.max,
            }
        report["smallbatch"][name] = {"query": qtext, "batches": per_batch}
    return report
