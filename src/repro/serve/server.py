"""Long-lived batching query server over one open ``.kgz`` store.

Wire protocol (newline-delimited JSON over a local TCP socket; one JSON
object per line, one response line per request, ``id`` echoed back):

    -> {"id": 1, "query": "SELECT ?g WHERE { ?m <p> ?g } LIMIT 5"}
    <- {"id": 1, "vars": ["?g"], "rows": [["<g0>"]], "n_total": 12,
        "batch_size": 3, "latency_ms": 1.9}

    -> {"id": 2, "query": "...", "limit": 10}     # decode at most 10 rows
       (without "limit", decoded rows are capped at ``max_rows`` — 1000 by
       default; "n_total" always reports the full solution count)

    -> {"id": 3, "query": "SELECT ?g (COUNT(*) AS ?n) WHERE { ?m <p> ?g }
                           GROUP BY ?g ORDER BY DESC(?n)"}
    <- {"id": 3, "vars": ["?g", "?n"], "agg_vars": ["?n"],
        "rows": [["<g1>", 7], ["<g0>", 3]], ...}
       (aggregate columns listed in "agg_vars" carry JSON numbers, not
       rendered terms; UNION / ORDER BY answers look like plain rows)
    -> {"op": "ping"}                              <- {"ok": true}
    -> {"op": "stats"}                             <- running counters
    -> {"op": "explain", "query": "..."}           <- the planned operator tree

Mutation ops (served stores wrapped in a :class:`repro.live.delta.LiveStore`;
rejected with ``"code": "read_only"`` on a read-only or plain store):

    -> {"id": 4, "op": "insert", "triples": [["<s>", "<p>", "\"o\""]]}
    <- {"id": 4, "inserted": 1, "n_total": 101, "generation": 3,
        "delta_fraction": 0.01}
    -> {"id": 5, "op": "delete", "triples": [["<s>", "<p>", "\"o\""]]}
    <- {"id": 5, "deleted": 1, "tombstoned": 0, ...}
    -> {"id": 6, "op": "compact"}
    <- {"id": 6, "compacted": true, "compact_ms": 12.3, "persisted": true,
        "n_total": 100, "generation": 4}

Errors come back as ``{"id": ..., "error": "...", "code": "..."}`` where
``code`` is one of ``parse`` (bad query text), ``bad_request`` (malformed
request: missing ``query``, bad ``limit``/``triples``, bad json),
``read_only`` (mutation on a read-only store) or ``internal`` (handler
failure) — :mod:`repro.api.errors` maps them to typed exceptions.
``rows`` hold rendered N-Triples terms with ``null`` for unbound
(OPTIONAL-miss) variables.

Batching: connection threads only parse and enqueue; a single dispatcher
thread drains the queue (a short linger lets concurrent clients pile up),
groups in-flight requests by plan *signature* — the structural identity of
a query with constants abstracted — and executes every group as ONE
batched device dispatch through the fused ``repro.serve.exec`` pipeline.

Mutations serialize on the same dispatcher thread, between query groups:
each query group captures one copy-on-write overlay snapshot
(``LiveStore.view()``) before dispatch, so an in-flight micro-batch never
observes a half-applied mutation; requests that arrived before a mutation
execute against the pre-mutation snapshot.  ``compact`` swaps in the
rebuilt base store (and rewrites the served ``.kgz`` in place when the
server owns a path).

Observability: every request's queue-wait and execute time land in
``repro.obs`` latency histograms (global plus per plan signature), the
``stats`` op keeps its original flat-counter shape (now read from the
registry, whose single lock makes the accept/client/dispatch-thread
updates atomic — the old hand-rolled ``ServerStats`` counters raced), and
the ``metrics`` op returns the full registry snapshot:

    -> {"op": "metrics"}
    <- {"id": ..., "metrics": {"counters": ..., "gauges": ...,
        "histograms": {"serve.queue_wait_ms": {"count": ..., "p50": ...,
        "p99": ...}, ...}}, "signatures": {"<sig>": "<example query>"}}

With tracing enabled (``--trace`` on ``repro.launch.serve``) each request
also records ``queue_wait`` / ``dispatch`` / ``redispatch`` spans into the
Chrome-trace ring buffer.
"""

from __future__ import annotations

import dataclasses
import json
import queue
import socket
import sys
import threading
import time

from repro.kg.store import TripleStore
from repro.live.delta import LiveStore
from repro.obs import MetricsRegistry, get_registry, get_tracer
from repro.serve import algebra
from repro.serve.exec import Executor, get_executor, plan_label
from repro.serve.values import value_table


# per-signature observability is bounded: beyond this many distinct plan
# signatures, new ones collapse into one "overflow" bucket so an
# adversarial (or just very heterogeneous) query stream cannot grow the
# `metrics` snapshot and the legend without bound
MAX_TRACKED_SIGS = 64


def track_sig(examples: dict[str, str], label: str, text: str) -> str:
    """Register ``label`` in the signature legend (first example query
    wins) and return the label to tag metrics with — ``"overflow"`` once
    the legend is full.  Shared by the server and the shard coordinator."""
    if label in examples:
        return label
    if len(examples) >= MAX_TRACKED_SIGS:
        return "overflow"
    examples[label] = text
    return label


@dataclasses.dataclass
class _Pending:
    query: algebra.SelectQuery | None
    text: str
    req_id: object
    limit: int | None
    reply: "callable"
    t_enq_ns: int
    op: str = "query"
    triples: list | None = None


class _AdaptiveLinger:
    """Pick the micro-batch linger window from the live arrival rate.

    The fixed window trades every request's latency for batch size even
    when nobody else is queuing — the worst deal exactly where the
    small-batch fast path matters (interactive, batch-1 traffic).  This
    tracks an EWMA of the inter-arrival gap and sizes the window by the
    *expected coalesce gain*:

    * no rate estimate yet (cold start) → the full configured window,
      the previous fixed behavior;
    * expected arrivals within a full window below ``min_expected`` →
      zero linger: dispatch immediately, nobody was going to share the
      batch anyway;
    * otherwise scale the window with the fraction of a full batch
      (``full_batch``) a max-length linger would collect, floored at
      the executor's observed p50 execute time (batching finer than one
      dispatch can't help — requests pile up behind the dispatch
      regardless) and capped at the configured maximum.

    Arrival observation is a single EWMA update per request (connection
    threads; GIL-atomic enough — the window only needs to be roughly
    right).  Unit-testable deterministically via ``observe_arrival`` /
    ``window_s``.
    """

    def __init__(
        self,
        max_s: float,
        registry: MetricsRegistry,
        full_batch: int = 64,
        alpha: float = 0.2,
        min_expected: float = 1.5,
    ):
        self.max_s = max_s
        self.registry = registry
        self.full_batch = full_batch
        self.alpha = alpha
        self.min_expected = min_expected
        self._last_ns: int | None = None
        self._gap_s: float | None = None  # EWMA inter-arrival gap

    def observe_arrival(self, t_ns: int) -> None:
        last = self._last_ns
        self._last_ns = t_ns
        if last is None:
            return
        gap = max((t_ns - last) / 1e9, 1e-9)
        g = self._gap_s
        self._gap_s = gap if g is None else (1 - self.alpha) * g + self.alpha * gap

    def window_s(self) -> float:
        g = self._gap_s
        if g is None or self.max_s <= 0:
            return self.max_s
        expected = self.max_s / g  # arrivals a full linger would see
        if expected < self.min_expected:
            return 0.0
        w = self.max_s * min(1.0, expected / self.full_batch)
        p50_ms = self.registry.histogram("serve.exec_ms").percentile(50)
        if p50_ms:
            w = max(w, min(self.max_s, p50_ms / 1e3))
        return min(w, self.max_s)


class KGServer:
    """Serve one store — immutable, or mutable when wrapped in a
    :class:`LiveStore`; see the module docstring for protocol."""

    def __init__(
        self,
        store: TripleStore | LiveStore,
        host: str = "127.0.0.1",
        port: int = 0,
        max_batch: int = 4096,
        linger_ms: float = 2.0,
        max_rows: int = 1000,
        log: bool = True,
        registry: MetricsRegistry | None = None,
        read_only: bool = False,
        kg_path: str | None = None,
        warmup: bool = False,
        adaptive_linger: bool = True,
    ):
        if isinstance(store, LiveStore):
            self.live: LiveStore | None = store
            store = store.base
        else:
            self.live = None
        self.store = store
        self.read_only = read_only or self.live is None
        self.kg_path = kg_path  # compact rewrites this .kgz in place
        self.executor: Executor = get_executor(store)
        # build the value-typed rank side tables (FILTER / ORDER BY keys)
        # on device now, at server store-load time, so no client ever pays
        # the per-term decode loop on the first filtered or ordered query
        value_table(store)
        self.max_batch = max_batch
        self.max_rows = max_rows
        self.linger_s = linger_ms / 1e3
        self.log = log
        # the process-global registry by default (so the `metrics` op also
        # surfaces executor/stream metrics); tests pass their own
        self.registry = registry if registry is not None else get_registry()
        # adaptive micro-batch window: linger_ms is the MAXIMUM; the live
        # arrival rate shrinks it (to zero for sparse interactive traffic)
        self._linger = _AdaptiveLinger(
            max_s=self.linger_s, registry=self.registry, full_batch=max(
                1, min(self.max_batch, 64)
            ),
        )
        self._adaptive = adaptive_linger
        if warmup:
            # pre-trace the dominant small-batch shapes so the first
            # interactive query after start pays no jit compile
            self.executor.warmup()
        # plan-signature label -> an example query text, so the `metrics`
        # op's per-signature histograms are interpretable
        self._sig_examples: dict[str, str] = {}
        self._queue: queue.Queue[_Pending] = queue.Queue()
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._last_log = 0.0
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(128)
        self.host, self.port = self._sock.getsockname()[:2]

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "KGServer":
        for target in (self._accept_loop, self._dispatch_loop):
            t = threading.Thread(target=target, daemon=True)
            t.start()
            self._threads.append(t)
        if self.log:
            src = self.live if self.live is not None else self.store
            mode = "read-only" if self.read_only else "live"
            print(
                f"[serve] listening on {self.host}:{self.port} ({mode}) — "
                f"{src.n_triples} triples, {src.n_terms} terms",
                file=sys.stderr,
                flush=True,
            )
        return self

    def stop(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        for t in self._threads:
            t.join(timeout=2.0)

    def serve_forever(self) -> None:
        self.start()
        try:
            while not self._stop.is_set():
                time.sleep(0.2)
        except KeyboardInterrupt:
            pass
        finally:
            self.stop()

    # -- accept / per-connection ---------------------------------------------

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._sock.accept()
            except OSError:
                return  # socket closed
            t = threading.Thread(
                target=self._client_loop, args=(conn,), daemon=True
            )
            t.start()

    def _client_loop(self, conn: socket.socket) -> None:
        wlock = threading.Lock()

        def send(obj: dict) -> None:
            data = (json.dumps(obj) + "\n").encode("utf-8")
            with wlock:
                try:
                    conn.sendall(data)
                except OSError:
                    pass

        try:
            rfile = conn.makefile("r", encoding="utf-8")
            for line in rfile:
                line = line.strip()
                if not line:
                    continue
                try:
                    req = json.loads(line)
                except json.JSONDecodeError as e:
                    self.registry.inc("serve.errors")
                    send({"error": f"bad json: {e}", "code": "bad_request"})
                    continue
                try:
                    self._handle(req, send)
                except Exception as e:  # noqa: BLE001 — never drop the socket
                    self.registry.inc("serve.errors")
                    rid = req.get("id") if isinstance(req, dict) else None
                    send({"id": rid, "error": f"{type(e).__name__}: {e}",
                          "code": "internal"})
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def stats_dict(self) -> dict:
        """The ``stats`` op's original flat-counter shape, read from the
        registry (one lock: the counters are mutually consistent)."""
        queries = self.registry.counter("serve.queries").value
        batches = self.registry.counter("serve.batches").value
        exec_s = self.registry.counter("serve.exec_s").value
        return {
            "queries": queries,
            "batches": batches,
            "errors": self.registry.counter("serve.errors").value,
            "busiest_batch": self.registry.gauge("serve.busiest_batch").value,
            "mean_batch": queries / batches if batches else 0.0,
            "exec_queries_per_s": queries / exec_s if exec_s else 0.0,
        }

    def _handle(self, req: dict, send) -> None:
        op = req.get("op")
        if op == "ping":
            send({"ok": True, "id": req.get("id")})
            return
        if op == "stats":
            send({"id": req.get("id"), **self.stats_dict()})
            return
        if op == "metrics":
            send({
                "id": req.get("id"),
                "metrics": self.registry.snapshot(),
                "signatures": dict(self._sig_examples),
            })
            return
        if op in ("insert", "delete", "compact"):
            self._enqueue_mutation(op, req, send)
            return
        text = req.get("query")
        if not isinstance(text, str):
            self.registry.inc("serve.errors")
            send({"id": req.get("id"), "error": "missing 'query'",
                  "code": "bad_request"})
            return
        try:
            q = algebra.parse_select(text)
        except ValueError as e:
            self.registry.inc("serve.errors")
            send({"id": req.get("id"), "error": str(e), "code": "parse"})
            return
        if op == "explain":
            plan = self.executor.plan(q)
            send({"id": req.get("id"), "plan": plan.explain()})
            return
        limit = req.get("limit")
        if limit is not None and (
            not isinstance(limit, int) or isinstance(limit, bool) or limit < 0
        ):
            self.registry.inc("serve.errors")
            send({"id": req.get("id"),
                  "error": "'limit' must be a non-negative integer",
                  "code": "bad_request"})
            return
        t_enq = time.perf_counter_ns()
        self._linger.observe_arrival(t_enq)
        self._queue.put(
            _Pending(
                query=q,
                text=text,
                req_id=req.get("id"),
                limit=limit,
                reply=send,
                t_enq_ns=t_enq,
            )
        )

    def _enqueue_mutation(self, op: str, req: dict, send) -> None:
        """Validate a mutation request on the connection thread; apply it
        on the dispatcher thread (one writer, serialized with queries)."""
        if self.read_only:
            # structured rejection — a read-only server keeps serving
            # queries, it never crashes the dispatch thread on a write
            self.registry.inc("serve.errors")
            self.registry.inc("live.rejected")
            send({
                "id": req.get("id"),
                "error": "store is read-only: mutation rejected",
                "code": "read_only",
            })
            return
        triples = None
        if op in ("insert", "delete"):
            triples = req.get("triples")
            if (
                not isinstance(triples, list)
                or not triples
                or not all(
                    isinstance(t, list)
                    and len(t) == 3
                    and all(isinstance(x, str) for x in t)
                    for t in triples
                )
            ):
                self.registry.inc("serve.errors")
                send({
                    "id": req.get("id"),
                    "error": "'triples' must be a non-empty list of "
                             "[s, p, o] term-string triples",
                    "code": "bad_request",
                })
                return
        t_enq = time.perf_counter_ns()
        self._linger.observe_arrival(t_enq)
        self._queue.put(
            _Pending(
                query=None,
                text="",
                req_id=req.get("id"),
                limit=None,
                reply=send,
                t_enq_ns=t_enq,
                op=op,
                triples=triples,
            )
        )

    # -- the micro-batching dispatcher ----------------------------------------

    def _drain(self) -> list[_Pending]:
        """Block for the first request, then linger briefly so concurrent
        clients coalesce into one batch."""
        try:
            first = self._queue.get(timeout=0.1)
        except queue.Empty:
            return []
        batch = [first]
        linger = (
            self._linger.window_s() if self._adaptive else self.linger_s
        )
        deadline = time.perf_counter() + linger
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self._queue.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            batch = self._drain()
            if not batch:
                continue
            # queries batch freely between mutations, but a mutation is an
            # ordering barrier: everything enqueued before it executes
            # against the pre-mutation snapshot, everything after sees it
            queries: list[_Pending] = []
            for p in batch:
                if p.op == "query":
                    queries.append(p)
                    continue
                self._flush_queries(queries)
                queries = []
                self._apply_mutation(p)
            self._flush_queries(queries)

    def _flush_queries(self, pending: list[_Pending]) -> None:
        if not pending:
            return
        groups: dict[tuple, list[_Pending]] = {}
        for p in pending:
            groups.setdefault(p.query.signature(), []).append(p)
        for group in groups.values():
            self._run_group(group)

    def _apply_mutation(self, p: _Pending) -> None:
        """Apply one insert/delete/compact on the dispatcher thread.  The
        overlay mutates copy-on-write: query groups snapshot a view before
        dispatch, so nothing in flight sees a half-applied change."""
        live = self.live
        reg = self.registry
        try:
            if p.op == "insert":
                added = live.insert([tuple(t) for t in p.triples])
                reg.inc("live.inserts", added)
                reply = {"id": p.req_id, "inserted": added}
            elif p.op == "delete":
                deleted, tombstoned = live.delete(
                    [tuple(t) for t in p.triples]
                )
                reg.inc("live.deletes", deleted)
                reg.inc("live.tombstone_hits", tombstoned)
                reply = {
                    "id": p.req_id,
                    "deleted": deleted,
                    "tombstoned": tombstoned,
                }
            else:  # compact
                t0 = time.perf_counter_ns()
                new_base = live.compact()
                # swap the served base copy-on-write: executor and value
                # tables rebuild against the new store before any later
                # query group runs
                self.store = new_base
                self.executor = get_executor(new_base)
                value_table(new_base)
                compact_ms = (time.perf_counter_ns() - t0) / 1e6
                reg.inc("live.compactions")
                reg.observe("live.compact_ms", compact_ms)
                reply = {
                    "id": p.req_id,
                    "compacted": True,
                    "compact_ms": round(compact_ms, 3),
                }
                if self.kg_path is not None:
                    from repro.kg import persist

                    persist.save(
                        new_base, self.kg_path, generation=live.generation
                    )
                    reply["persisted"] = True
            reg.gauge("live.delta_fraction").set(live.delta_fraction)
            reply["n_total"] = live.n_triples
            reply["generation"] = live.generation
            reply["delta_fraction"] = round(live.delta_fraction, 6)
            p.reply(reply)
        except Exception as e:  # noqa: BLE001 — a bad write must not kill serving
            reg.inc("serve.errors")
            p.reply({"id": p.req_id, "error": f"{type(e).__name__}: {e}",
                          "code": "internal"})

    def _run_group(self, group: list[_Pending]) -> None:
        reg = self.registry
        tracer = get_tracer()
        t0_ns = time.perf_counter_ns()
        # queue wait: enqueue -> dispatch pickup, per request (what batching
        # linger + a busy dispatcher cost the client, separate from compute)
        for p in group:
            reg.observe("serve.queue_wait_ms", (t0_ns - p.t_enq_ns) / 1e6)
            if tracer.enabled:
                tracer.add_complete(
                    "queue_wait", "serve", p.t_enq_ns, t0_ns, req=p.req_id
                )
        try:
            plan = self.executor.plan(group[0].query)
            label = track_sig(
                self._sig_examples, plan_label(plan.sig), group[0].text
            )
            # snapshot the overlay (copy-on-write): this group answers over
            # exactly the mutations applied before it, whatever lands next
            view = self.live.view() if self.live is not None else None
            with tracer.span(
                "dispatch", cat="serve", plan=label, batch=len(group)
            ):
                result = self.executor.execute(
                    plan, [p.query for p in group], view=view
                )
        except Exception as e:  # noqa: BLE001 — a bad query must not kill serving
            reg.inc("serve.errors", len(group))
            for p in group:
                p.reply({"id": p.req_id, "error": f"{type(e).__name__}: {e}",
                          "code": "internal"})
            return
        dt = (time.perf_counter_ns() - t0_ns) / 1e9
        lat_ms = dt * 1e3
        reg.inc("serve.queries", len(group))
        reg.inc("serve.batches")
        reg.gauge("serve.busiest_batch").set_max(len(group))
        reg.inc("serve.exec_s", dt)
        reg.observe("serve.exec_ms", lat_ms)
        reg.observe(f"serve.exec_ms.sig={label}", lat_ms)
        for p in group:
            # the client-visible request latency: queue wait + execute
            reg.observe(
                "serve.request_ms", (time.perf_counter_ns() - p.t_enq_ns) / 1e6
            )
        for i, p in enumerate(group):
            # decoding runs on the dispatcher thread: cap undeclared row
            # counts so one huge answer cannot stall every other batch
            # (n_total still reports the full solution count)
            limit = p.limit if p.limit is not None else self.max_rows
            reply = {
                "id": p.req_id,
                "vars": list(result.vars),
                "rows": [list(r) for r in result.rows(i, limit=limit)],
                "n_total": result.n(i),
                "batch_size": len(group),
                "latency_ms": round(lat_ms, 3),
            }
            if result.agg_vars:
                # aggregate (COUNT) columns: their row cells are JSON
                # numbers, not rendered terms — name them for the client
                reply["agg_vars"] = list(result.agg_vars)
            p.reply(reply)
        now = time.perf_counter()
        if self.log and now - self._last_log > 1.0:
            self._last_log = now
            print(
                f"[serve] batch={len(group)} {lat_ms:.1f}ms "
                f"({len(group) / dt:.0f} q/s in-batch; "
                f"totals: {reg.counter('serve.queries').value} queries, "
                f"{reg.counter('serve.batches').value} batches)",
                file=sys.stderr,
                flush=True,
            )
