"""The small-batch dispatch fast path.

The general executor (``serve/exec.py``) compiles the whole algebra
tree and ships ~30 operand leaves per dispatch; that constant is noise
at batch 4096 and dominant at batch 1.  For the plan shapes that carry
interactive traffic — a ``Scan → BindJoin*`` chain of up to three
pattern readers (see :func:`repro.serve.plan.fastpath_chain`) — this
module dispatches through :mod:`repro.kernels.scan_join` instead, with
every per-dispatch cost stripped:

* the chain is resolved at build time into a static
  :class:`~repro.kernels.scan_join.ChainSpec` (index orders, constant /
  left-bound / wildcard sources, projection columns), so dispatch does
  no plan walking;
* per-query inputs are written into **grow-only staging buffers** kept
  per batch pad — no per-dispatch allocation — and donated to the
  compiled function on accelerator backends;
* the per-capacity ``needed`` dict (one device→host sync per operator
  in the general path) collapses to a single ``[n_readers]`` max
  vector reduced on device;
* on backends that compile Pallas natively the whole batch runs as one
  fused ``grid=(batch,)`` kernel; CPU hosts use the jitted vmapped
  reference formulation of the same chain math.

The capacity-feedback contract is shared with the general executor:
the same ``scan{id}`` / ``bindC{id}`` capacity names against the same
per-signature floors (``Executor._floors``), the same grow-and-retry
loop, counters, and trace spans — so a signature that warms up through
either path stays warm through both, and tests that count dispatches
see identical behavior.  Overlay (live-store) views and batches over
:data:`MAX_BATCH` never come here; ``execute_encoded`` routes them to
the general pipeline unchanged.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.hashset import next_pow2
from repro.kernels import scan_join as K
from repro.kg.store import ORDERS
from repro.obs import get_registry, get_tracer
from repro.serve import plan as P

# batches this small dispatch through the fused chain; larger ones are
# the general pipeline's amortized regime
MAX_BATCH = 64
# a chain stage that wants more rows than this belongs to the general
# path (same clamp as the planner's initial-capacity guess)
_CAP_LIMIT = 1 << 22
_MAX_GROW_ROUNDS = 12


def build(ex, plan: P.Plan) -> "SigFastPath | None":
    """Resolve ``plan`` into a :class:`SigFastPath`, or None when the
    plan (or the store — unpacked keys, empty base) needs the general
    executor.  Called once per plan signature and cached by the
    executor."""
    readers = P.fastpath_chain(plan)
    if readers is None:
        return None
    store = ex.store
    if store.n_triples == 0 or store.device_keys("spo") is None:
        return None
    col_of: dict[str, int] = {}
    rspecs: list[K.ReaderSpec] = []
    cap_names: list[str] = []
    base_caps: list[int] = []
    for r in readers:
        perm3 = ORDERS[r.order]
        if isinstance(r, P.Scan):
            var_by_pos = dict(r.var_slots)
            bound_by_pos: dict[int, int] = {}
        else:
            var_by_pos = dict(r.free_slots)
            bound_by_pos = {}
            for pos, v in r.bound_slots:
                col = col_of.get(v)
                if col is None:  # planner invariant violated: punt
                    return None
                bound_by_pos[pos] = col
        consts = set(r.const_slots)
        src: list[tuple[str, int]] = []
        out: list[tuple[int, int]] = []
        for j in range(3):
            pos = perm3[j]
            if pos in consts:
                src.append(("c", pos))
            elif pos in bound_by_pos:
                src.append(("b", bound_by_pos[pos]))
            elif pos in var_by_pos:
                col = col_of.setdefault(var_by_pos[pos], len(col_of))
                src.append(("w", 0))
                out.append((j, col))
            else:
                src.append(("w", 0))
        if src[0][0] == "w" and isinstance(r, P.BindJoin):
            # bind-join orders put a bound slot first by construction;
            # anything else is a shape the chain math doesn't seed
            return None
        rspecs.append(
            K.ReaderSpec(
                src=tuple(src),
                out=tuple(out),
                prim_rounds=store.primary_rounds(r.order),
            )
        )
        # identical capacity names and initial guesses to the general
        # path's _initial_caps: the per-signature floors are shared
        if isinstance(r, P.Scan):
            cap_names.append(f"scan{r.node_id}")
            base_caps.append(next_pow2(max(r.est, 1)))
        else:
            cap_names.append(f"bindC{r.node_id}")
            base_caps.append(next_pow2(min(max(r.est, 16), _CAP_LIMIT)))
    spec = K.ChainSpec(
        readers=tuple(rspecs),
        n_cols=len(col_of),
        out_cols=tuple(col_of.get(v, -1) for v in plan.root.out_vars),
        key_bits=store.KEY_BITS,
        rounds=max(1, int(store.n_triples).bit_length()),
        store_n=store.n_triples,
    )
    operands: list = []
    for r in readers:
        khi, klo = store.device_keys(r.order)
        c0, c1, c2 = store.device_cols(r.order)
        operands += [khi, klo, c0, c1, c2, store.device_primary_starts(r.order)]
    return SigFastPath(ex, plan, spec, tuple(operands), tuple(cap_names),
                       tuple(base_caps))


class SigFastPath:
    """One plan signature's resolved fast path: the static chain spec,
    the store operand tuple, grow-only staging buffers per batch pad,
    and the compiled-function cache per (batch pad, capacities)."""

    def __init__(self, ex, plan, spec, operands, cap_names, base_caps):
        from repro.serve.exec import plan_label

        self.ex = ex
        self.plan = plan
        self.spec = spec
        self.operands = operands
        self.cap_names = cap_names
        self.base_caps = base_caps
        self.label = plan_label(plan.sig)
        self._staging: dict[int, np.ndarray] = {}
        self._compiled: dict[tuple, callable] = {}
        # one fused kernel on native-Pallas backends; the jitted vmapped
        # reference chain on CPU (where Pallas only interprets)
        self._use_kernel = compat.pallas_native()

    def _get_fn(self, bpad: int, caps: tuple[int, ...]):
        key = (bpad, caps)
        fn = self._compiled.get(key)
        reg = get_registry()
        if fn is not None:
            reg.inc("exec.pipeline_cache_hit")
            return fn
        reg.inc("exec.pipeline_cache_miss")
        reg.inc("exec.fastpath_compiles")
        batched = K.make_batched(
            self.spec, caps, use_kernel=self._use_kernel, interpret=False
        )
        if self._use_kernel:
            # donate the per-query device buffer: its storage is dead
            # after the call (the host staging buffer persists)
            fn = jax.jit(batched, donate_argnums=(len(self.operands),))
        else:  # CPU jit does not implement donation (warns per call)
            fn = jax.jit(batched)
        self._compiled[key] = fn
        return fn

    def dispatch(self, consts: np.ndarray, limits, bsz: int):
        """Run the batch; returns a ``(out_cols, counts)`` pair of numpy
        results, or None when capacity feedback outgrew the fast path
        (the caller re-runs on the general pipeline; the shared floors
        carry the growth over)."""
        ex = self.ex
        reg = get_registry()
        tracer = get_tracer()
        n_readers = len(self.spec.readers)
        w = K.qrow_width(n_readers)
        bpad = next_pow2(max(bsz, 1))
        qbuf = self._staging.get(bpad)
        if qbuf is None:
            # grow-only staging: one packed [bpad, 3R+2] row matrix per
            # batch pad, reused forever (pad rows: -2 consts so every
            # scan misses, valid 0, limit -1)
            qbuf = np.empty((bpad, w), np.int32)
            qbuf[:, : 3 * n_readers] = -2
            qbuf[:, 3 * n_readers] = 0
            qbuf[:, 3 * n_readers + 1] = -1
            self._staging[bpad] = qbuf
        qbuf[:bsz, : 3 * n_readers] = consts[:bsz].reshape(bsz, -1)
        qbuf[bsz:, : 3 * n_readers] = -2
        qbuf[:bsz, 3 * n_readers] = 1
        qbuf[bsz:, 3 * n_readers] = 0
        qbuf[:bsz, 3 * n_readers + 1] = -1 if limits is None else limits[:bsz]
        qbuf[bsz:, 3 * n_readers + 1] = -1

        floors = ex._floors.setdefault(self.plan.sig, {})
        caps = [
            max(base, floors.get(nm, 0))
            for nm, base in zip(self.cap_names, self.base_caps)
        ]
        label = self.label
        reg.inc("exec.batches")
        reg.inc("exec.queries", bsz)
        for round_i in range(_MAX_GROW_ROUNDS):
            t0 = time.perf_counter_ns()
            fn = self._get_fn(bpad, tuple(caps))
            outs, n, needed_max = fn(*self.operands, jnp.asarray(qbuf))
            ex.dispatches += 1
            need = np.asarray(needed_max)
            grown = False
            overgrown = False
            for i, nm in enumerate(self.cap_names):
                want = int(need[i])
                if want > caps[i]:
                    caps[i] = next_pow2(want)
                    floors[nm] = max(floors.get(nm, 0), caps[i])
                    grown = True
                    reg.inc("exec.cap_growth")
                    if caps[i] > _CAP_LIMIT:
                        overgrown = True
            t1 = time.perf_counter_ns()
            reg.inc("exec.dispatches")
            reg.inc("exec.fastpath_dispatches")
            reg.observe("exec.dispatch_ms", (t1 - t0) / 1e6)
            if round_i > 0:
                reg.inc("exec.redispatches")
            if tracer.enabled:
                tracer.add_complete(
                    "redispatch" if round_i > 0 else "dispatch",
                    "exec", t0, t1,
                    plan=label, batch=bsz, round=round_i,
                    grown=grown, fast=True,
                )
            if overgrown:
                # result too large for the small-batch regime: the grown
                # floors transfer to the general path, which re-runs
                return None
            if not grown:
                break
        else:
            raise RuntimeError(
                "executor capacity feedback did not converge "
                f"(caps={dict(zip(self.cap_names, caps))}) — "
                "pathological query?"
            )
        counts = np.asarray(n)[:bsz].astype(np.int64)
        cols = tuple(np.asarray(c)[:bsz] for c in outs)
        return cols, counts
