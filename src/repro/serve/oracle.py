"""Naive full-algebra oracle — the tests' ground truth for ``repro.serve``.

Extends the BGP-only set-scan oracle (``repro.kg.query.oracle_solve``) to
the whole SPARQL-lite algebra: UNION, OPTIONAL, FILTER, projection,
GROUP BY + COUNT, DISTINCT, ORDER BY and LIMIT.  Everything is quadratic,
string-based Python over the *decoded* triple list — it deliberately
shares no code with the indexed, jitted engine (same philosophy as the kg
oracle), except the single number-parsing rule
(:func:`repro.serve.values.parse_number`), which is a semantic
definition, not an implementation detail.

Rows come back deterministically ordered — sorted by rendered term per
column, unbound (``None``) first, COUNT columns by integer value — which
is exactly the engine's term-id order, because term ids are ranks of
rendered term strings.  ``ORDER BY`` sorts by the value-typed total order
(unbound < IRIs < numeric literals by value < other literals by body,
ties by rendered term), descending keys fully reversed, with the default
deterministic order as the tie-break — mirroring the engine's
``order_rank`` side table.
"""

from __future__ import annotations

from repro.data.terms import unescape_literal
from repro.kg.query import TriplePattern
from repro.kg.store import TripleStore
from repro.serve import algebra as A
from repro.serve.values import parse_number


def _decoded_triples(store: TripleStore) -> list[tuple[str, str, str]]:
    rt = getattr(store, "rendered_triples", None)
    if rt is not None:  # a LiveStore: its surviving base ⊕ delta triples
        return list(rt())
    return [
        (
            store.decode_term(int(store.s[i])),
            store.decode_term(int(store.p[i])),
            store.decode_term(int(store.o[i])),
        )
        for i in range(store.n_triples)
    ]


def match_pattern(
    triples: list[tuple[str, str, str]], pat: TriplePattern
) -> list[dict[str, str]]:
    """One pattern against every triple: the solution mappings (variable ->
    rendered term), one per matching triple."""
    out = []
    for t in triples:
        env: dict[str, str] | None = {}
        for term, value in zip(pat.slots, t):
            if term.startswith("?"):
                if env.get(term, value) != value:
                    env = None
                    break
                env[term] = value
            elif term != value:
                env = None
                break
        if env is not None:
            out.append(env)
    return out


def _join_envs(
    solutions: list[dict[str, str]], rows: list[dict[str, str]]
) -> list[dict[str, str]]:
    """Pairwise compatible merge (the brute-force conjunctive join)."""
    return [
        {**env, **row}
        for env in solutions
        for row in rows
        if all(env.get(v, row[v]) == row[v] for v in row)
    ]


def _is_literal(term: str | None) -> bool:
    return term is not None and term.startswith('"')


def _body(term: str) -> str:
    return unescape_literal(term[1:-1])


def _numeric(term: str | None) -> float | None:
    if not _is_literal(term):
        return None
    return parse_number(_body(term))


def _operand_term(op: A.Operand, env: dict[str, str]) -> str | None:
    if isinstance(op, A.Var):
        return env.get(op.name)
    if isinstance(op, A.TermConst):
        return op.term
    raise TypeError(op)


def _eval_cmp(c: A.Cmp, env: dict[str, str]) -> bool:
    import operator

    rel = {
        "<": operator.lt, "<=": operator.le, ">": operator.gt,
        ">=": operator.ge, "=": operator.eq, "!=": operator.ne,
    }[c.op]
    # numeric comparison: any NumConst operand
    if isinstance(c.lhs, A.NumConst) or isinstance(c.rhs, A.NumConst):
        def num(op: A.Operand) -> float | None:
            if isinstance(op, A.NumConst):
                return op.value
            return _numeric(_operand_term(op, env))

        lv, rv = num(c.lhs), num(c.rhs)
        return lv is not None and rv is not None and rel(lv, rv)
    if c.op in ("=", "!="):
        # term identity (both sides must be bound; type errors are false)
        lt = _operand_term(c.lhs, env)
        rt = _operand_term(c.rhs, env)
        return lt is not None and rt is not None and rel(lt, rt)
    if isinstance(c.lhs, A.TermConst) or isinstance(c.rhs, A.TermConst):
        # string-order comparison against a quoted literal constant
        def body(op: A.Operand) -> str | None:
            t = _operand_term(op, env)
            return _body(t) if _is_literal(t) else None

        lb, rb = body(c.lhs), body(c.rhs)
        return lb is not None and rb is not None and rel(lb, rb)
    # var-vs-var ordering: numeric when both numeric, else literal-body
    # order when both are literals, else false
    lt = _operand_term(c.lhs, env)
    rt = _operand_term(c.rhs, env)
    ln, rn = _numeric(lt), _numeric(rt)
    if ln is not None and rn is not None:
        return rel(ln, rn)
    if _is_literal(lt) and _is_literal(rt):
        return rel(_body(lt), _body(rt))
    return False


def _eval_expr(e: A.Expr, env: dict[str, str]) -> bool:
    if isinstance(e, A.Cmp):
        return _eval_cmp(e, env)
    if isinstance(e, A.Bound):
        return env.get(e.var.name) is not None
    if isinstance(e, A.Not):
        return not _eval_expr(e.expr, env)
    if isinstance(e, A.And):
        return _eval_expr(e.lhs, env) and _eval_expr(e.rhs, env)
    if isinstance(e, A.Or):
        return _eval_expr(e.lhs, env) or _eval_expr(e.rhs, env)
    raise TypeError(e)


def _default_cell_key(cell):
    """The engine's per-column deterministic order: unbound first, then
    rendered-term (= term id) order; COUNT cells are plain ints and order
    by value.  Columns are homogeneous, so the mixed tuple never compares
    int against str within one column."""
    if cell is None:
        return (0, 0.0, "")
    if isinstance(cell, int):
        return (1, float(cell), "")
    return (1, 0.0, cell)


def _orderby_cell_key(cell):
    """The value-typed ORDER BY total order (``values.order_rank``):
    unbound < IRIs (rendered) < numeric literals (value, rendered tie) <
    other literals (body, rendered tie); COUNT cells by integer value."""
    if cell is None:
        return (-1, 0.0, ())
    if isinstance(cell, int):
        return (0, float(cell), ())
    if not _is_literal(cell):
        return (0, 0.0, (cell,))
    v = _numeric(cell)
    if v is not None:
        return (1, v, (cell,))
    return (2, 0.0, (_body(cell), cell))


def combine_pattern_solutions(
    q: A.SelectQuery, pattern_sols: "list[list[dict[str, str]]]"
) -> list[tuple]:
    """Everything past per-pattern matching: join the required BGP, fold
    UNION arms, left-join OPTIONAL groups, filter, group/aggregate,
    project, dedupe, order and limit.  ``pattern_sols`` holds each
    pattern's solution mappings, aligned with ``q.all_patterns()`` order.

    Factored out of :func:`oracle_select` because the shard coordinator
    reuses it: a query whose patterns do not share one subject cannot be
    answered by scattering the whole query (a solution's triples may span
    shards), but each *pattern's* matches partition cleanly — so the
    coordinator gathers per-pattern solutions from every shard and
    combines them here, host-side, with exactly the oracle's semantics."""
    it = iter(pattern_sols)
    sols: list[dict[str, str]] = [{}]
    for _pat in q.patterns:
        sols = _join_envs(sols, next(it))
    if q.unions:
        arm_sols: list[dict[str, str]] = []
        for arm in q.unions:
            asols: list[dict[str, str]] = [{}]
            for _pat in arm:
                asols = _join_envs(asols, next(it))
            arm_sols.extend(asols)
        sols = _join_envs(sols, arm_sols)
    for group in q.optionals:
        gsols: list[dict[str, str]] = [{}]
        for _pat in group:
            gsols = _join_envs(gsols, next(it))
        joined: list[dict[str, str]] = []
        for env in sols:
            hits = [
                g
                for g in gsols
                if all(env.get(v, g[v]) == g[v] for v in g)
            ]
            if hits:
                joined.extend({**env, **g} for g in hits)
            else:
                joined.append(env)
        sols = joined
    sols = [
        env for env in sols if all(_eval_expr(f, env) for f in q.filters)
    ]
    out_vars = q.out_vars()
    if q.agg is not None or q.group_by:
        groups: dict[tuple, list[dict[str, str]]] = {}
        for env in sols:
            key = tuple(env.get(k) for k in q.group_by)
            groups.setdefault(key, []).append(env)
        if not q.group_by and not groups:
            groups[()] = []  # the global group: one row over zero solutions
        alias = q.agg.alias if q.agg else None
        cvar = q.agg.var if q.agg else None
        rows = []
        for key, members in groups.items():
            by_key = dict(zip(q.group_by, key))
            row = []
            for v in out_vars:
                if v == alias:
                    row.append(
                        len(members)
                        if cvar is None
                        else sum(1 for m in members if m.get(cvar) is not None)
                    )
                else:
                    row.append(by_key.get(v))
            rows.append(tuple(row))
    else:
        rows = [tuple(env.get(v) for v in out_vars) for env in sols]
        if q.distinct:
            rows = list(dict.fromkeys(rows))
    # the default deterministic order doubles as the ORDER BY tie-break
    rows.sort(key=lambda r: tuple(_default_cell_key(c) for c in r))
    if q.order_by:
        # stable sorts applied last key first realize the multi-direction
        # lexicographic order (exactly the engine's variadic key sort)
        for var, asc in reversed(q.order_by):
            i = out_vars.index(var)
            rows.sort(key=lambda r: _orderby_cell_key(r[i]), reverse=not asc)
    if q.limit is not None:
        rows = rows[: q.limit]
    return rows


def oracle_select(store: TripleStore, q: A.SelectQuery) -> list[tuple]:
    """Evaluate ``q`` naively; rows are tuples of rendered terms (``None``
    for unbound, plain ints for COUNT columns) over ``q.out_vars()``,
    deterministically sorted, with GROUP BY / DISTINCT / ORDER BY / LIMIT
    applied — directly comparable to ``BatchResult.rows(i)``."""
    triples = _decoded_triples(store)
    return combine_pattern_solutions(
        q, [match_pattern(triples, pat) for pat in q.all_patterns()]
    )
