"""Naive full-algebra oracle — the tests' ground truth for ``repro.serve``.

Extends the BGP-only set-scan oracle (``repro.kg.query.oracle_solve``) to
the whole SPARQL-lite algebra: OPTIONAL, FILTER, projection, DISTINCT and
LIMIT.  Everything is quadratic, string-based Python over the *decoded*
triple list — it deliberately shares no code with the indexed, jitted
engine (same philosophy as the kg oracle), except the single
number-parsing rule (:func:`repro.serve.values.parse_number`), which is a
semantic definition, not an implementation detail.

Rows come back deterministically ordered — sorted by rendered term per
column, unbound (``None``) first — which is exactly the engine's term-id
order, because term ids are ranks of rendered term strings.
"""

from __future__ import annotations

from repro.data.terms import unescape_literal
from repro.kg.query import TriplePattern
from repro.kg.store import TripleStore
from repro.serve import algebra as A
from repro.serve.values import parse_number


def _decoded_triples(store: TripleStore) -> list[tuple[str, str, str]]:
    return [
        (
            store.decode_term(int(store.s[i])),
            store.decode_term(int(store.p[i])),
            store.decode_term(int(store.o[i])),
        )
        for i in range(store.n_triples)
    ]


def _match_bgp(
    triples: list[tuple[str, str, str]], patterns: tuple[TriplePattern, ...]
) -> list[dict[str, str]]:
    """Brute-force conjunctive matching: every pattern against every triple,
    then pairwise compatible merge."""

    def match_one(pat: TriplePattern) -> list[dict[str, str]]:
        out = []
        for t in triples:
            env: dict[str, str] | None = {}
            for term, value in zip(pat.slots, t):
                if term.startswith("?"):
                    if env.get(term, value) != value:
                        env = None
                        break
                    env[term] = value
                elif term != value:
                    env = None
                    break
            if env is not None:
                out.append(env)
        return out

    solutions: list[dict[str, str]] = [{}]
    for pat in patterns:
        rows = match_one(pat)
        solutions = [
            {**env, **row}
            for env in solutions
            for row in rows
            if all(env.get(v, row[v]) == row[v] for v in row)
        ]
    return solutions


def _is_literal(term: str | None) -> bool:
    return term is not None and term.startswith('"')


def _body(term: str) -> str:
    return unescape_literal(term[1:-1])


def _numeric(term: str | None) -> float | None:
    if not _is_literal(term):
        return None
    return parse_number(_body(term))


def _operand_term(op: A.Operand, env: dict[str, str]) -> str | None:
    if isinstance(op, A.Var):
        return env.get(op.name)
    if isinstance(op, A.TermConst):
        return op.term
    raise TypeError(op)


def _eval_cmp(c: A.Cmp, env: dict[str, str]) -> bool:
    import operator

    rel = {
        "<": operator.lt, "<=": operator.le, ">": operator.gt,
        ">=": operator.ge, "=": operator.eq, "!=": operator.ne,
    }[c.op]
    # numeric comparison: any NumConst operand
    if isinstance(c.lhs, A.NumConst) or isinstance(c.rhs, A.NumConst):
        def num(op: A.Operand) -> float | None:
            if isinstance(op, A.NumConst):
                return op.value
            return _numeric(_operand_term(op, env))

        lv, rv = num(c.lhs), num(c.rhs)
        return lv is not None and rv is not None and rel(lv, rv)
    if c.op in ("=", "!="):
        # term identity (both sides must be bound; type errors are false)
        lt = _operand_term(c.lhs, env)
        rt = _operand_term(c.rhs, env)
        return lt is not None and rt is not None and rel(lt, rt)
    if isinstance(c.lhs, A.TermConst) or isinstance(c.rhs, A.TermConst):
        # string-order comparison against a quoted literal constant
        def body(op: A.Operand) -> str | None:
            t = _operand_term(op, env)
            return _body(t) if _is_literal(t) else None

        lb, rb = body(c.lhs), body(c.rhs)
        return lb is not None and rb is not None and rel(lb, rb)
    # var-vs-var ordering: numeric when both numeric, else literal-body
    # order when both are literals, else false
    lt = _operand_term(c.lhs, env)
    rt = _operand_term(c.rhs, env)
    ln, rn = _numeric(lt), _numeric(rt)
    if ln is not None and rn is not None:
        return rel(ln, rn)
    if _is_literal(lt) and _is_literal(rt):
        return rel(_body(lt), _body(rt))
    return False


def _eval_expr(e: A.Expr, env: dict[str, str]) -> bool:
    if isinstance(e, A.Cmp):
        return _eval_cmp(e, env)
    if isinstance(e, A.Bound):
        return env.get(e.var.name) is not None
    if isinstance(e, A.Not):
        return not _eval_expr(e.expr, env)
    if isinstance(e, A.And):
        return _eval_expr(e.lhs, env) and _eval_expr(e.rhs, env)
    if isinstance(e, A.Or):
        return _eval_expr(e.lhs, env) or _eval_expr(e.rhs, env)
    raise TypeError(e)


def oracle_select(store: TripleStore, q: A.SelectQuery) -> list[tuple]:
    """Evaluate ``q`` naively; rows are tuples of rendered terms (``None``
    for unbound) over ``q.out_vars()``, deterministically sorted, with
    DISTINCT and LIMIT applied — directly comparable to
    ``BatchResult.rows(i)``."""
    triples = _decoded_triples(store)
    sols = _match_bgp(triples, q.patterns)
    for group in q.optionals:
        gsols = _match_bgp(triples, group)
        joined: list[dict[str, str]] = []
        for env in sols:
            hits = [
                g
                for g in gsols
                if all(env.get(v, g[v]) == g[v] for v in g)
            ]
            if hits:
                joined.extend({**env, **g} for g in hits)
            else:
                joined.append(env)
        sols = joined
    sols = [
        env for env in sols if all(_eval_expr(f, env) for f in q.filters)
    ]
    out_vars = q.out_vars()
    rows = [tuple(env.get(v) for v in out_vars) for env in sols]
    if q.distinct:
        rows = list(dict.fromkeys(rows))
    rows.sort(key=lambda r: tuple("" if t is None else t for t in r))
    if q.limit is not None:
        rows = rows[: q.limit]
    return rows
