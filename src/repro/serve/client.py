"""Minimal client for the :mod:`repro.serve.server` wire protocol.

This is the raw transport under :class:`repro.api.RemoteSession` —
new code should use ``repro.api.connect("host:port")`` and get the
unified :class:`~repro.api.QueryResult` surface; this module stays for
callers that want the wire dicts verbatim.

Answers are plain dicts off the wire: ``vars`` / ``rows`` / ``n_total``.
Aggregate (COUNT) columns are listed in the answer's ``agg_vars`` and
their row cells are JSON numbers; every other cell is a rendered
N-Triples term, ``None`` when unbound (an OPTIONAL miss or a UNION arm
that does not bind the variable).  Error replies raise the typed
:mod:`repro.api.errors` hierarchy (all ``RuntimeError`` subclasses)."""

from __future__ import annotations

import json
import socket
import time


class Client:
    """One connection; requests are correlated by an auto-incremented id
    (the server answers every request with exactly one line)."""

    def __init__(self, host: str, port: int, timeout: float = 30.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._rfile = self._sock.makefile("r", encoding="utf-8")
        self._next_id = 0

    def close(self) -> None:
        try:
            self._rfile.close()
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "Client":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _roundtrip(self, req: dict) -> dict:
        from repro.api.errors import ProtocolError, error_from_reply

        self._next_id += 1
        req = {"id": self._next_id, **req}
        self._sock.sendall((json.dumps(req) + "\n").encode("utf-8"))
        line = self._rfile.readline()
        if not line:
            # ProtocolError is also a ConnectionError — old callers
            # that caught that still do
            raise ProtocolError("server closed the connection")
        resp = json.loads(line)
        if resp.get("error"):
            # the typed repro.api.errors hierarchy, keyed by the reply's
            # structured "code" (every class is a RuntimeError and the
            # message keeps the "server error: ..." prefix)
            raise error_from_reply(resp)
        return resp

    def query(self, text: str, limit: int | None = None) -> dict:
        req: dict = {"query": text}
        if limit is not None:
            req["limit"] = limit
        return self._roundtrip(req)

    def explain(self, text: str) -> str:
        return self._roundtrip({"op": "explain", "query": text})["plan"]

    def ping(self) -> bool:
        return bool(self._roundtrip({"op": "ping"}).get("ok"))

    def stats(self) -> dict:
        return self._roundtrip({"op": "stats"})

    def insert(self, triples) -> dict:
        """Insert rendered ``(s, p, o)`` term-string triples; the answer
        reports ``inserted`` / ``n_total`` / ``generation`` (raises on a
        read-only server)."""
        return self._roundtrip(
            {"op": "insert", "triples": [list(t) for t in triples]}
        )

    def delete(self, triples) -> dict:
        """Delete triples; the answer reports ``deleted`` (how many were
        present and removed) and ``tombstoned`` (how many were base rows,
        now masked until compaction)."""
        return self._roundtrip(
            {"op": "delete", "triples": [list(t) for t in triples]}
        )

    def compact(self) -> dict:
        """Merge the overlay into a fresh base store; the answer reports
        ``compact_ms`` and, when the server owns the ``.kgz`` path,
        ``persisted``."""
        return self._roundtrip({"op": "compact"})

    def metrics(self) -> dict:
        """The server's full metrics snapshot: ``{"metrics": {"counters":
        ..., "gauges": ..., "histograms": ...}, "signatures": {...}}`` —
        latency histograms carry ``count``/``sum``/``max``/``p50``/``p90``
        /``p99`` (see ``repro.obs.metrics``)."""
        return self._roundtrip({"op": "metrics"})


def connect(
    host: str, port: int, retry_s: float = 0.0, timeout: float = 30.0
) -> Client:
    """Connect, optionally retrying for ``retry_s`` seconds (the CI smoke
    path: the server may still be loading its snapshot)."""
    deadline = time.monotonic() + retry_s
    while True:
        try:
            return Client(host, port, timeout=timeout)
        except OSError:
            if time.monotonic() >= deadline:
                raise
            time.sleep(0.1)
