"""SPARQL-lite query algebra — the IR between the wire and the planner.

A query is a :class:`SelectQuery`: a required basic graph pattern, an
optional multi-arm ``UNION`` block (each arm itself a BGP), zero or more
``OPTIONAL`` groups (each itself a BGP), zero or more ``FILTER``
expressions, a projection (``SELECT ?a ?b`` / ``SELECT *`` / aggregate
``(COUNT(?v) AS ?n)``), and optional ``DISTINCT`` / ``GROUP BY`` /
``ORDER BY`` / ``LIMIT n`` modifiers.  The planner (``repro.serve.plan``)
turns it into an operator tree — ``Scan`` / ``Join`` / ``LeftJoin`` /
``Union`` / ``Filter`` / ``Project`` / ``Group`` / ``Distinct`` /
``OrderBy`` / ``Limit`` — and the executor (``repro.serve.exec``) lowers
that tree to one fused jitted dispatch.

Semantics of the new operators over our untyped plain literals:

* ``{ A } UNION { B } [UNION { C } ...]`` — bag union of the arms' solution
  mappings, joined with the required BGP; a variable an arm does not bind
  is unbound in that arm's rows.  Variables bound in *some but not all*
  arms may not be re-used by OPTIONAL groups (plan-time error — joining on
  a maybe-unbound column needs SPARQL's full compatibility semantics).
* ``ORDER BY ?a DESC(?b)`` — *value-typed* ordering, not term-id order:
  unbound < IRIs (by rendered term) < numeric literals (by numeric value)
  < other literals (by raw body), ties broken by rendered term; ``DESC``
  reverses the whole key (so unbound sorts last).  Keys must be projected
  variables; remaining columns tie-break in term-id order, so results stay
  deterministic.
* ``GROUP BY ?g`` + ``(COUNT(?v) AS ?n)`` / ``(COUNT(*) AS ?n)`` — one row
  per distinct group-key tuple; ``COUNT(?v)`` counts rows where ``?v`` is
  bound, ``COUNT(*)`` counts all rows.  Every selected non-aggregate
  variable must be a group key.  An aggregate without ``GROUP BY`` is one
  global group (one row even over zero solutions).  Count values travel as
  plain integers, not terms — see ``BatchResult.agg_vars``.

Filter expressions cover the serving-relevant SPARQL core: comparisons
(``<  <=  >  >=  =  !=``) between variables and constants, ``bound(?x)``,
``!``, ``&&`` and ``||``.  Semantics over our untyped plain literals:

* an *unquoted number* operand compares numerically — a term participates
  iff its literal body parses as a float (else the comparison errors out to
  false, as SPARQL type errors do);
* a *quoted literal* operand compares by raw literal body (codepoint
  order) for the ordering operators, and by term identity for ``=``/``!=``;
* an ``<iri>`` operand compares by term identity (``=``/``!=`` only);
* variable-vs-variable ordering compares numerically when both terms are
  numeric, by literal body when both are literals, else false;
* any comparison over an unbound variable (a ``LeftJoin`` miss or a
  partial UNION arm) is false — only ``bound()`` / ``!bound()`` observe
  unboundness.

Everything here is host-side structure; no jax.  The structural
*signature* of a query (constants abstracted away) is what the server
batches on — see :func:`SelectQuery.signature`.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Union

from repro.data.terms import canonical_term, unescape_literal
from repro.kg.query import TriplePattern, parse_bgp

# ---------------------------------------------------------------------------
# filter expression IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Var:
    name: str  # includes the '?'


@dataclasses.dataclass(frozen=True)
class NumConst:
    value: float


@dataclasses.dataclass(frozen=True)
class TermConst:
    term: str  # canonical rendered N-Triples term: <iri> or "literal"

    @property
    def is_literal(self) -> bool:
        return self.term.startswith('"')

    @property
    def body(self) -> str:
        """Raw (unescaped) literal body; only valid for literals."""
        return unescape_literal(self.term[1:-1])


Operand = Union[Var, NumConst, TermConst]

CMP_OPS = ("<=", ">=", "!=", "<", ">", "=")  # longest-match order


@dataclasses.dataclass(frozen=True)
class Cmp:
    op: str
    lhs: Operand
    rhs: Operand


@dataclasses.dataclass(frozen=True)
class Bound:
    var: Var


@dataclasses.dataclass(frozen=True)
class Not:
    expr: "Expr"


@dataclasses.dataclass(frozen=True)
class And:
    lhs: "Expr"
    rhs: "Expr"


@dataclasses.dataclass(frozen=True)
class Or:
    lhs: "Expr"
    rhs: "Expr"


Expr = Union[Cmp, Bound, Not, And, Or]


def expr_variables(e: Expr) -> tuple[str, ...]:
    """Variables an expression mentions, in first-appearance order."""
    out: dict[str, None] = {}

    def walk(x) -> None:
        if isinstance(x, Cmp):
            for side in (x.lhs, x.rhs):
                if isinstance(side, Var):
                    out.setdefault(side.name)
        elif isinstance(x, Bound):
            out.setdefault(x.var.name)
        elif isinstance(x, Not):
            walk(x.expr)
        elif isinstance(x, (And, Or)):
            walk(x.lhs)
            walk(x.rhs)

    walk(e)
    return tuple(out)


def _expr_signature(e: Expr):
    """Structure with constant *values* abstracted (kinds kept — a numeric
    and a string comparison lower differently)."""
    if isinstance(e, Cmp):
        def opsig(x):
            if isinstance(x, Var):
                return ("var", x.name)
            if isinstance(x, NumConst):
                return ("num",)
            return ("lit",) if x.is_literal else ("iri",)

        return ("cmp", e.op, opsig(e.lhs), opsig(e.rhs))
    if isinstance(e, Bound):
        return ("bound", e.var.name)
    if isinstance(e, Not):
        return ("not", _expr_signature(e.expr))
    return (
        "and" if isinstance(e, And) else "or",
        _expr_signature(e.lhs),
        _expr_signature(e.rhs),
    )


# ---------------------------------------------------------------------------
# the query
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Count:
    """``COUNT(?v)`` / ``COUNT(*)`` with its ``AS ?alias`` output name."""

    var: str | None  # None = COUNT(*)
    alias: str


@dataclasses.dataclass(frozen=True)
class SelectQuery:
    patterns: tuple[TriplePattern, ...]                   # required BGP
    optionals: tuple[tuple[TriplePattern, ...], ...] = ()
    filters: tuple[Expr, ...] = ()
    select: tuple[str, ...] | None = None                 # None = SELECT *
    distinct: bool = False
    limit: int | None = None
    unions: tuple[tuple[TriplePattern, ...], ...] = ()    # UNION arms (0 or >= 2)
    group_by: tuple[str, ...] = ()
    agg: Count | None = None                              # one COUNT, or None
    order_by: tuple[tuple[str, bool], ...] = ()           # (var, ascending)

    def scope(self) -> tuple[str, ...]:
        """All in-scope variables — required BGP first, then UNION arms,
        then optionals, in first-appearance order."""
        out: dict[str, None] = {}
        for pat in self.patterns:
            for v in pat.variables:
                out.setdefault(v)
        for arm in self.unions:
            for pat in arm:
                for v in pat.variables:
                    out.setdefault(v)
        for group in self.optionals:
            for pat in group:
                for v in pat.variables:
                    out.setdefault(v)
        return tuple(out)

    def union_always_vars(self) -> frozenset[str]:
        """Variables bound by *every* UNION arm — always bound in the
        union block's rows, so downstream joins may key on them."""
        if not self.unions:
            return frozenset()
        sets = [
            {v for pat in arm for v in pat.variables} for arm in self.unions
        ]
        return frozenset(set.intersection(*sets))

    def union_partial_vars(self) -> frozenset[str]:
        """Variables bound in some but not all UNION arms — maybe-unbound
        after the union, like OPTIONAL-only variables."""
        if not self.unions:
            return frozenset()
        all_vars = {v for arm in self.unions for pat in arm for v in pat.variables}
        return frozenset(all_vars - self.union_always_vars())

    def out_vars(self) -> tuple[str, ...]:
        """The projected variable list (``SELECT *`` = full scope); for
        aggregate queries the COUNT alias appears at its SELECT position."""
        return self.scope() if self.select is None else self.select

    def all_patterns(self) -> tuple[TriplePattern, ...]:
        """Required + union-arm + optional patterns flattened, in source
        order — the index space ``plan.Scan.pattern_pos`` refers to."""
        flat = list(self.patterns)
        for arm in self.unions:
            flat.extend(arm)
        for group in self.optionals:
            flat.extend(group)
        return tuple(flat)

    def signature(self):
        """Hashable structural identity with constants abstracted: queries
        with equal signatures share a plan, a compiled pipeline, and a
        server micro-batch — only their constant ids differ."""

        def patsig(p: TriplePattern):
            return tuple(t if t.startswith("?") else "<const>" for t in p.slots)

        return (
            tuple(patsig(p) for p in self.patterns),
            tuple(tuple(patsig(p) for p in a) for a in self.unions),
            tuple(tuple(patsig(p) for p in g) for g in self.optionals),
            tuple(_expr_signature(f) for f in self.filters),
            self.select,
            self.distinct,
            self.group_by,
            (self.agg.var, self.agg.alias) if self.agg else None,
            self.order_by,
            # only limit *presence* is structural: the value rides along as
            # a per-query runtime operand, so LIMIT 5 and LIMIT 50 share a
            # plan, a compiled pipeline, and a server micro-batch
            self.limit is not None,
        )


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------

_TOKEN = re.compile(
    r"""\s*(?:
        (?P<var>\?[A-Za-z_]\w*)
      | (?P<iri><[^>]*>)
      | (?P<lit>"(?:[^"\\]|\\.)*")
      | (?P<num>[+-]?(?:\d+\.\d*|\.\d+|\d+)(?:[eE][+-]?\d+)?)
      | (?P<word>[A-Za-z]\w*)
      | (?P<op><=|>=|!=|&&|\|\||[<>=!(){}.*])
    )""",
    re.VERBOSE,
)

class _Tokens:
    def __init__(self, text: str):
        self.text = text
        self.toks: list[tuple[str, str]] = []
        pos = 0
        while pos < len(text):
            m = _TOKEN.match(text, pos)
            if not m:
                if text[pos:].strip():
                    raise ValueError(f"cannot tokenize query at: {text[pos:pos+40]!r}")
                break
            pos = m.end()
            self.toks.append((m.lastgroup, m.group().strip()))
        self.i = 0

    def peek(self) -> tuple[str, str] | None:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> tuple[str, str]:
        t = self.peek()
        if t is None:
            raise ValueError("unexpected end of query")
        self.i += 1
        return t

    def take_word(self, word: str) -> bool:
        t = self.peek()
        if t and t[0] == "word" and t[1].lower() == word:
            self.i += 1
            return True
        return False

    def expect(self, kind: str, value: str | None = None) -> str:
        k, v = self.next()
        if k != kind or (value is not None and v != value):
            raise ValueError(f"expected {value or kind}, got {v!r}")
        return v

    def expect_var(self, what: str) -> str:
        k, v = self.next()
        if k != "var":
            raise ValueError(f"{what} takes a variable, got {v!r}")
        return v


def _parse_operand(tk: _Tokens) -> Operand:
    kind, v = tk.next()
    if kind == "var":
        return Var(v)
    if kind == "num":
        return NumConst(float(v))
    if kind == "iri":
        return TermConst(canonical_term(v))
    if kind == "lit":
        return TermConst(canonical_term(v))
    raise ValueError(f"expected a variable or constant in FILTER, got {v!r}")


def _parse_unary(tk: _Tokens) -> Expr:
    t = tk.peek()
    if t and t[1] == "!":
        tk.next()
        return Not(_parse_unary(tk))
    if t and t[1] == "(":
        tk.next()
        e = _parse_expr(tk)
        tk.expect("op", ")")
        return e
    if t and t[0] == "word" and t[1].lower() == "bound":
        tk.next()
        tk.expect("op", "(")
        v = tk.expect_var("bound()")
        tk.expect("op", ")")
        return Bound(Var(v))
    lhs = _parse_operand(tk)
    t = tk.peek()
    if not t or t[1] not in CMP_OPS:
        raise ValueError(f"expected a comparison operator after {lhs}")
    op = tk.next()[1]
    rhs = _parse_operand(tk)
    if not (isinstance(lhs, Var) or isinstance(rhs, Var)):
        raise ValueError("FILTER comparison needs at least one variable")
    if op in ("<", "<=", ">", ">="):
        for side in (lhs, rhs):
            if isinstance(side, TermConst) and not side.is_literal:
                raise ValueError("IRIs only support = / != comparisons")
    return Cmp(op, lhs, rhs)


def _parse_and(tk: _Tokens) -> Expr:
    e = _parse_unary(tk)
    while (t := tk.peek()) and t[1] == "&&":
        tk.next()
        e = And(e, _parse_unary(tk))
    return e


def _parse_expr(tk: _Tokens) -> Expr:
    e = _parse_and(tk)
    while (t := tk.peek()) and t[1] == "||":
        tk.next()
        e = Or(e, _parse_and(tk))
    return e


def _parse_triple(tk: _Tokens) -> TriplePattern:
    slots = []
    for _ in range(3):
        kind, v = tk.next()
        if kind == "var":
            slots.append(v)
        elif kind in ("iri", "lit"):
            slots.append(canonical_term(v))
        else:
            raise ValueError(f"expected a term in a triple pattern, got {v!r}")
    t = tk.peek()
    if t and t[1] == ".":
        tk.next()
    return TriplePattern(*slots)


def _parse_braced_bgp(tk: _Tokens, what: str) -> tuple[TriplePattern, ...]:
    """``{ triple* }`` — a UNION arm (already past the opening brace when
    called for the first arm; this helper expects the brace)."""
    tk.expect("op", "{")
    pats: list[TriplePattern] = []
    while (u := tk.peek()) and u[1] != "}":
        pats.append(_parse_triple(tk))
    tk.expect("op", "}")
    if not pats:
        raise ValueError(f"empty {what}")
    return tuple(pats)


def _parse_group(tk: _Tokens):
    patterns: list[TriplePattern] = []
    unions: tuple[tuple[TriplePattern, ...], ...] = ()
    optionals: list[tuple[TriplePattern, ...]] = []
    filters: list[Expr] = []
    while (t := tk.peek()) and t[1] != "}":
        if t[0] == "word" and t[1].lower() == "optional":
            tk.next()
            optionals.append(_parse_braced_bgp(tk, "OPTIONAL group"))
        elif t[0] == "word" and t[1].lower() == "filter":
            tk.next()
            tk.expect("op", "(")
            filters.append(_parse_expr(tk))
            tk.expect("op", ")")
        elif t[1] == "{":
            arms = [_parse_braced_bgp(tk, "UNION arm")]
            while tk.take_word("union"):
                arms.append(_parse_braced_bgp(tk, "UNION arm"))
            if len(arms) < 2:
                raise ValueError(
                    "a braced group must be a UNION of two or more arms"
                )
            if unions:
                raise ValueError("at most one UNION block per query")
            unions = tuple(arms)
        else:
            patterns.append(_parse_triple(tk))
    return tuple(patterns), unions, tuple(optionals), tuple(filters)


def _parse_select_clause(tk: _Tokens):
    """The projection: ``*``, or a mix of variables and one
    ``(COUNT(?v|*) AS ?alias)`` aggregate."""
    if (t := tk.peek()) and t[1] == "*":
        tk.next()
        return None, None
    names: list[str] = []
    agg: Count | None = None
    while (t := tk.peek()):
        if t[0] == "var":
            names.append(tk.next()[1])
        elif t[1] == "(":
            tk.next()
            if not tk.take_word("count"):
                raise ValueError("only (COUNT(...) AS ?x) aggregates are supported")
            tk.expect("op", "(")
            if (u := tk.peek()) and u[1] == "*":
                tk.next()
                cvar = None
            else:
                cvar = tk.expect_var("COUNT()")
            tk.expect("op", ")")
            if not tk.take_word("as"):
                raise ValueError("COUNT(...) needs AS ?alias")
            alias = tk.expect_var("AS")
            tk.expect("op", ")")
            if agg is not None:
                raise ValueError("at most one COUNT aggregate per query")
            agg = Count(var=cvar, alias=alias)
            names.append(alias)
        else:
            break
    if not names:
        raise ValueError("SELECT needs a variable list or *")
    return tuple(dict.fromkeys(names)), agg


def parse_select(text: str) -> SelectQuery:
    """Parse a SPARQL-lite query.  Two accepted forms:

    * ``SELECT [DISTINCT] ?a ?b|(COUNT(?v|*) AS ?n)|* WHERE { ... }
      [GROUP BY ?g ...] [ORDER BY ?a|ASC(?a)|DESC(?a) ...] [LIMIT n]``
      where the group holds triple patterns, one ``{ ... } UNION { ... }``
      block, ``OPTIONAL { ... }`` blocks and ``FILTER (...)`` expressions;
    * a bare BGP (``'?s <p> ?o . ?o <q> "v"'``) — shorthand for
      ``SELECT * WHERE { ... }``.
    """
    stripped = text.lstrip()
    if not re.match(r"(?i)select\b", stripped):
        return SelectQuery(patterns=tuple(parse_bgp(text)))
    tk = _Tokens(text)
    tk.take_word("select")
    distinct = tk.take_word("distinct")
    select, agg = _parse_select_clause(tk)
    if not tk.take_word("where"):
        raise ValueError("expected WHERE")
    tk.expect("op", "{")
    patterns, unions, optionals, filters = _parse_group(tk)
    tk.expect("op", "}")
    group_by: tuple[str, ...] = ()
    if tk.take_word("group"):
        if not tk.take_word("by"):
            raise ValueError("expected BY after GROUP")
        names: list[str] = []
        while (t := tk.peek()) and t[0] == "var":
            names.append(tk.next()[1])
        if not names:
            raise ValueError("GROUP BY needs at least one variable")
        group_by = tuple(dict.fromkeys(names))
    order_by: list[tuple[str, bool]] = []
    if tk.take_word("order"):
        if not tk.take_word("by"):
            raise ValueError("expected BY after ORDER")
        while (t := tk.peek()):
            if t[0] == "var":
                order_by.append((tk.next()[1], True))
            elif t[0] == "word" and t[1].lower() in ("asc", "desc"):
                asc = tk.next()[1].lower() == "asc"
                tk.expect("op", "(")
                v = tk.expect_var("ASC()/DESC()")
                tk.expect("op", ")")
                order_by.append((v, asc))
            else:
                break
        if not order_by:
            raise ValueError("ORDER BY needs at least one key")
    limit = None
    if tk.take_word("limit"):
        kind, v = tk.next()
        if kind != "num" or not re.fullmatch(r"\d+", v):
            raise ValueError(f"LIMIT takes a non-negative integer, got {v!r}")
        limit = int(v)
    if tk.peek() is not None:
        raise ValueError(f"trailing tokens after query: {tk.peek()[1]!r}")
    if not patterns and not unions:
        raise ValueError(
            "the required group needs at least one triple pattern or a UNION"
        )
    q = SelectQuery(
        patterns=patterns,
        optionals=optionals,
        filters=filters,
        select=select,
        distinct=distinct,
        limit=limit,
        unions=unions,
        group_by=group_by,
        agg=agg,
        order_by=tuple(order_by),
    )
    _validate(q)
    return q


def _validate(q: SelectQuery) -> None:
    """Plan-time rejections (an error here beats silently wrong answers):

    * optional groups may not join on variables that are maybe-unbound —
      bound only in *other* optional groups, or in some-but-not-all UNION
      arms — because that needs SPARQL's full compatibility semantics,
      which the fused pipeline deliberately does not implement;
    * aggregate queries must project only group keys and the COUNT alias;
    * ORDER BY keys must be projected variables.
    """
    required = set()
    for pat in q.patterns:
        required.update(pat.variables)
    always_bound = required | set(q.union_always_vars())
    partial_union = set(q.union_partial_vars())
    seen_optional: set[str] = set()
    for group in q.optionals:
        gvars = {v for pat in group for v in pat.variables}
        clash = (gvars & (seen_optional | partial_union)) - always_bound
        if clash:
            raise ValueError(
                "OPTIONAL groups may not share variables that are unbound in "
                f"the required pattern: {sorted(clash)}"
            )
        seen_optional |= gvars - always_bound
    scope = set(q.scope())
    if q.agg is not None or q.group_by:
        if q.select is None:
            raise ValueError("GROUP BY / aggregates need an explicit SELECT list")
        if q.distinct:
            raise ValueError("DISTINCT cannot be combined with GROUP BY / COUNT")
        alias = q.agg.alias if q.agg else None
        if alias is not None and alias in scope:
            raise ValueError(
                f"COUNT alias {alias} collides with an in-scope variable"
            )
        if alias is not None and alias in q.group_by:
            raise ValueError(f"COUNT alias {alias} cannot be a GROUP BY key")
        for v in q.select:
            if v != alias and v not in q.group_by:
                raise ValueError(
                    f"selected variable {v} must be a GROUP BY key "
                    "(or the COUNT alias)"
                )
    out = set(q.out_vars())
    for v, _asc in q.order_by:
        if v not in out:
            raise ValueError(f"ORDER BY key {v} is not a projected variable")


# ---------------------------------------------------------------------------
# serializer — SelectQuery -> parseable query text
# ---------------------------------------------------------------------------


def _operand_text(op: Operand) -> str:
    if isinstance(op, Var):
        return op.name
    if isinstance(op, NumConst):
        return repr(op.value)
    return op.term


def expr_text(e: Expr) -> str:
    """Serialize a filter expression back to FILTER grammar.  Fully
    parenthesized, so ``parse_select(to_text(q))`` rebuilds the same tree
    regardless of precedence."""
    if isinstance(e, Cmp):
        return f"{_operand_text(e.lhs)} {e.op} {_operand_text(e.rhs)}"
    if isinstance(e, Bound):
        return f"bound({e.var.name})"
    if isinstance(e, Not):
        return f"!({expr_text(e.expr)})"
    op = "&&" if isinstance(e, And) else "||"
    return f"({expr_text(e.lhs)}) {op} ({expr_text(e.rhs)})"


def _bgp_text(pats) -> str:
    return " . ".join(" ".join(p.slots) for p in pats)


def to_text(q: SelectQuery) -> str:
    """Serialize a query back to SPARQL-lite text; round-trips through
    :func:`parse_select` to an equal :class:`SelectQuery`.  The shard
    coordinator uses this to rewrite queries before scattering them
    (e.g. an aggregate query shards with ORDER BY / LIMIT stripped, so
    partial groups stay complete for the re-aggregating merge)."""
    sel = "*"
    if q.select is not None:
        parts = []
        for v in q.select:
            if q.agg is not None and v == q.agg.alias:
                cv = q.agg.var if q.agg.var is not None else "*"
                parts.append(f"(COUNT({cv}) AS {q.agg.alias})")
            else:
                parts.append(v)
        sel = " ".join(parts)
    body = []
    if q.patterns:
        body.append(_bgp_text(q.patterns))
    if q.unions:
        body.append(
            " UNION ".join("{ " + _bgp_text(arm) + " }" for arm in q.unions)
        )
    for group in q.optionals:
        body.append("OPTIONAL { " + _bgp_text(group) + " }")
    for f in q.filters:
        body.append(f"FILTER({expr_text(f)})")
    text = "SELECT "
    if q.distinct:
        text += "DISTINCT "
    text += sel + " WHERE { " + " ".join(body) + " }"
    if q.group_by:
        text += " GROUP BY " + " ".join(q.group_by)
    if q.order_by:
        keys = " ".join(
            (f"ASC({v})" if asc else f"DESC({v})") for v, asc in q.order_by
        )
        text += " ORDER BY " + keys
    if q.limit is not None:
        text += f" LIMIT {q.limit}"
    return text
