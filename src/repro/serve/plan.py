"""Cost-based planner — SelectQuery -> operator tree for the fused executor.

Generalizes the join-ordering logic that used to live inline in
``repro.kg.query.solve``: every scan's cardinality is *measured* from the
SPO/POS/OSP index statistics (a pattern is a contiguous range of one sort
order, so its exact count is two binary searches — the cheapest perfect
estimator there is), and the required BGP is folded greedily smallest-first
while always preferring a scan *connected* to the accumulated scope; a
disconnected scan cross-joins only when no connected one remains.

Placement rules:

* filters are pushed to the earliest point where every eventually-bound
  variable they mention is in scope (a filter over union- or optional-only
  variables waits until after that ``Union`` / ``LeftJoin``);
* ``UNION`` arms each fold *onto the shared required subtree* — the
  required scans are planned once and the executor evaluates them once
  (its node memo turns the plan tree into a DAG), so arms never re-scan
  the shared part; arm costs sum into the ``Union`` concat capacity;
* single-pattern ``OPTIONAL`` groups bind-join with unmatched-row
  backfill; multi-pattern groups are planned as *bind-join chains off the
  required scope*: the left rows are tagged with a synthetic row id, the
  group's patterns chain as inner (bind) joins anchored on the left
  bindings — the group is never materialized on its own — and a final
  ``LeftFinish`` appends the unmatched left rows with the group's
  variables unbound;
* aggregation (``GROUP BY`` + ``COUNT``) places after all joins and
  filters: ``Group`` sorts by the key columns and segment-counts on
  device, replacing the ``Project`` tail;
* the tail is ``Project|Group -> Distinct | Sort | OrderBy -> Limit`` —
  ``ORDER BY`` sorts by *value-typed* rank keys (``serve/values.py``) with
  a full term-id tie-break, so results stay deterministic; without it the
  engine sorts final binding tables by term id (and, because term ids are
  ranks of rendered terms, identically across eager / streamed /
  ``.kgz``-roundtripped stores).  The executor elides either sort when
  the pipeline's tracked sortedness already matches.

The plan is structure-only: constants live in per-query operand vectors
(:func:`encode_scan_consts` / :func:`encode_filter_ops`), so one plan (and
one compiled pipeline) serves every query with the same
:meth:`~repro.serve.algebra.SelectQuery.signature` — the unit the server
micro-batches on.
"""

from __future__ import annotations

import dataclasses
from typing import Union as TUnion

import numpy as np

from repro.kg.query import _ORDER_FOR_MASK, TriplePattern, match_counts
from repro.kg.store import TripleStore
from repro.serve import algebra as A
from repro.serve.values import ValueTable

# ---------------------------------------------------------------------------
# lowered filter expressions (constants -> operand-vector slots)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LOperand:
    kind: str          # 'var' | 'const'
    var: str | None    # for kind == 'var'
    slot: int          # start index into the filter-operand vector
    width: int         # ints this operand occupies (0 for vars)


@dataclasses.dataclass(frozen=True)
class LCmp:
    op: str            # normalized: constants only ever on the rhs
    mode: str          # 'num' | 'str' | 'term' | 'vv'
    lhs: LOperand
    rhs: LOperand


@dataclasses.dataclass(frozen=True)
class LBound:
    var: str


@dataclasses.dataclass(frozen=True)
class LNot:
    expr: "LExpr"


@dataclasses.dataclass(frozen=True)
class LAnd:
    lhs: "LExpr"
    rhs: "LExpr"


@dataclasses.dataclass(frozen=True)
class LOr:
    lhs: "LExpr"
    rhs: "LExpr"


LExpr = TUnion[LCmp, LBound, LNot, LAnd, LOr]

_FLIP = {"<": ">", ">": "<", "<=": ">=", ">=": "<=", "=": "=", "!=": "!="}


def _cmp_mode(c: A.Cmp) -> str:
    if isinstance(c.lhs, A.NumConst) or isinstance(c.rhs, A.NumConst):
        return "num"
    if c.op in ("=", "!="):
        return "term"
    if isinstance(c.lhs, A.TermConst) or isinstance(c.rhs, A.TermConst):
        return "str"
    return "vv"  # var-vs-var ordering: numeric if both numeric, else string


def _operand_width(op: A.Operand, mode: str) -> int:
    if isinstance(op, A.Var):
        return 0
    if mode in ("num", "str"):
        return 2  # (lo, hi) rank bounds
    return 1      # term id


def _lower_expr(e: A.Expr, cursor: list[int]) -> LExpr:
    if isinstance(e, A.Cmp):
        op, lhs, rhs = e.op, e.lhs, e.rhs
        if not isinstance(lhs, A.Var):  # normalize: constant to the rhs
            op, lhs, rhs = _FLIP[op], rhs, lhs
        mode = _cmp_mode(e)

        def low(x: A.Operand) -> LOperand:
            w = _operand_width(x, mode)
            slot = cursor[0]
            cursor[0] += w
            return LOperand(
                kind="var" if isinstance(x, A.Var) else "const",
                var=x.name if isinstance(x, A.Var) else None,
                slot=slot,
                width=w,
            )

        return LCmp(op=op, mode=mode, lhs=low(lhs), rhs=low(rhs))
    if isinstance(e, A.Bound):
        return LBound(e.var.name)
    if isinstance(e, A.Not):
        return LNot(_lower_expr(e.expr, cursor))
    if isinstance(e, A.And):
        return LAnd(_lower_expr(e.lhs, cursor), _lower_expr(e.rhs, cursor))
    return LOr(_lower_expr(e.lhs, cursor), _lower_expr(e.rhs, cursor))


def encode_filter_ops(
    store: TripleStore, vt: ValueTable | None, filters: tuple[A.Expr, ...]
) -> np.ndarray:
    """Per-query filter constants -> one int32 operand vector, in the same
    depth-first order :func:`_lower_expr` assigned slots (signature-equal
    queries produce identically-shaped vectors)."""
    out: list[int] = []

    def enc_operand(x: A.Operand, mode: str) -> None:
        if isinstance(x, A.Var):
            return
        assert vt is not None
        if mode == "num":
            assert isinstance(x, A.NumConst)
            out.extend(vt.num_bounds(x.value))
        elif mode == "str":
            assert isinstance(x, A.TermConst)
            out.extend(vt.str_bounds(x.body))
        else:  # term identity
            assert isinstance(x, A.TermConst)
            tid = store.term_id(x.term)
            out.append(-2 if tid is None else tid)

    def walk(e: A.Expr) -> None:
        if isinstance(e, A.Cmp):
            op, lhs, rhs = e.op, e.lhs, e.rhs
            if not isinstance(lhs, A.Var):
                lhs, rhs = rhs, lhs
            mode = _cmp_mode(e)
            enc_operand(lhs, mode)
            enc_operand(rhs, mode)
        elif isinstance(e, A.Not):
            walk(e.expr)
        elif isinstance(e, (A.And, A.Or)):
            walk(e.lhs)
            walk(e.rhs)

    for f in filters:
        walk(f)
    return np.asarray(out, np.int32)


# ---------------------------------------------------------------------------
# plan nodes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scan:
    node_id: int
    pattern_pos: int                         # index into query.all_patterns()
    order: str                               # spo | pos | osp
    const_slots: tuple[int, ...]             # triple positions bound by consts
    var_slots: tuple[tuple[int, str], ...]   # (position, var) first occurrences
    eq_pairs: tuple[tuple[int, int], ...]    # repeated-var position pairs
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class Join:
    node_id: int
    left: "Node"
    right: "Node"
    shared: tuple[str, ...]
    kind: str                                # 'inner' | 'left'
    build_right: bool                        # which side the sorted build is
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class BindJoin:
    """Index nested-loop join: instead of scanning the pattern
    independently and merge-joining, each left-side row *binds* its shared
    variables into the pattern's range scan (they become part of the bound
    prefix of the index lookup).  This is what makes an anchored star BGP
    cheap — the unanchored pattern is never materialized."""

    node_id: int
    left: "Node"
    pattern_pos: int
    order: str
    const_slots: tuple[int, ...]
    bound_slots: tuple[tuple[int, str], ...]  # (position, left-bound var)
    free_slots: tuple[tuple[int, str], ...]   # (position, newly bound var)
    eq_pairs: tuple[tuple[int, int], ...]     # repeated free-var positions
    kind: str                                 # 'inner' | 'left'
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class UnionNode:
    """Bag union of the arms' solution tables: a fused concat preserving
    arm order (a row's provenance is its arm's offset range).  Arms share
    the required subtree — the executor's node memo evaluates it once."""

    node_id: int
    arms: tuple["Node", ...]
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class TagRows:
    """Append a synthetic row-id column (the packed row index) — the
    provenance a multi-pattern OPTIONAL chain joins back on."""

    node_id: int
    child: "Node"
    var: str                                 # synthetic, never a query var
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class LeftFinish:
    """Finish a multi-pattern OPTIONAL planned as a bind-join chain:
    ``right`` is the inner chain ``TagRows(left) |x| p1 |x| p2 ...`` — its
    rows are the matches, carrying every left column — and left rows whose
    row id never reached the chain output are appended with the group's
    variables left unbound."""

    node_id: int
    left: "Node"                             # the TagRows node
    right: "Node"                            # the inner chain
    rowid: str
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class Filter:
    node_id: int
    child: "Node"
    expr: LExpr
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class Project:
    node_id: int
    child: "Node"
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class Group:
    """GROUP BY + COUNT: sort by the key columns, segment-count on device.
    ``keys == ()`` is the global group (always exactly one output row)."""

    node_id: int
    child: "Node"
    keys: tuple[str, ...]
    count_var: str | None                    # COUNT(?v) argument; None = *
    alias: str | None                        # None = no COUNT selected
    out_vars: tuple[str, ...]                # the SELECT order
    est: int


@dataclasses.dataclass(frozen=True)
class Distinct:
    node_id: int
    child: "Node"
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class Sort:
    node_id: int
    child: "Node"
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class OrderBy:
    """Value-typed ORDER BY: each key column sorts by the store's
    ``order_rank`` side table (count columns by their integer value),
    descending keys negated; the remaining output columns tie-break in
    term-id order so the result is still deterministic."""

    node_id: int
    child: "Node"
    keys: tuple[tuple[str, bool, bool], ...]  # (var, ascending, is_count)
    out_vars: tuple[str, ...]
    est: int


@dataclasses.dataclass(frozen=True)
class Limit:
    node_id: int
    child: "Node"
    n: int
    out_vars: tuple[str, ...]
    est: int


Node = TUnion[
    Scan, BindJoin, Join, UnionNode, TagRows, LeftFinish, Filter,
    Project, Group, Distinct, Sort, OrderBy, Limit,
]


@dataclasses.dataclass(frozen=True)
class Plan:
    sig: tuple
    root: Node
    # pattern readers (Scan | BindJoin) in pipeline order; reader i takes
    # constants row i of the per-query consts matrix
    scans: tuple[TUnion[Scan, BindJoin], ...]
    n_filter_ops: int
    has_filters: bool
    # the value side tables are needed for filters and for ORDER BY over
    # term (non-count) columns
    needs_values: bool = False
    agg_vars: tuple[str, ...] = ()
    # a global COUNT (aggregate without GROUP BY) answers one row even
    # over an empty store — the empty-store shortcut needs to know
    global_agg_alias: str | None = None

    def explain(self, indent: str = "") -> str:
        """Human-readable operator tree (cost annotations included).  The
        plan is a DAG — union arms and optional chains share subtrees — so
        a subtree already printed shows as one ``(shared ...)`` line
        instead of being expanded again (also keeps explain linear, not
        exponential, in the number of optional groups)."""
        lines: list[str] = []
        seen: set[int] = set()

        def walk(node: Node, depth: int) -> None:
            pad = indent + "  " * depth
            if node.node_id in seen:
                lines.append(
                    f"{pad}(shared {type(node).__name__} "
                    f"node#{node.node_id} — expanded above)"
                )
                return
            seen.add(node.node_id)
            if isinstance(node, Scan):
                lines.append(
                    f"{pad}Scan[{node.order}] pattern#{node.pattern_pos} "
                    f"vars={list(node.out_vars)} est={node.est}"
                )
                return
            name = type(node).__name__
            extra = ""
            if isinstance(node, Join):
                extra = (
                    f" {node.kind} on={list(node.shared) or 'x'} "
                    f"build={'right' if node.build_right else 'left'}"
                )
            if isinstance(node, BindJoin):
                extra = (
                    f" {node.kind} pattern#{node.pattern_pos}[{node.order}] "
                    f"bind={[v for _, v in node.bound_slots]} "
                    f"+{[v for _, v in node.free_slots]}"
                )
            if isinstance(node, UnionNode):
                extra = f" arms={len(node.arms)}"
            if isinstance(node, TagRows):
                extra = f" +{node.var}"
            if isinstance(node, LeftFinish):
                extra = f" rowid={node.rowid}"
            if isinstance(node, Group):
                count = (
                    f" count({node.count_var or '*'}) as {node.alias}"
                    if node.alias
                    else ""
                )
                extra = f" by={list(node.keys) or 'all'}{count}"
            if isinstance(node, OrderBy):
                extra = " " + ",".join(
                    f"{'+' if asc else '-'}{v}" for v, asc, _ in node.keys
                )
            if isinstance(node, Limit):
                extra = f" n={node.n}"
            lines.append(f"{pad}{name}{extra} est={node.est}")
            for child in _children(node):
                walk(child, depth + 1)

        walk(self.root, 0)
        return "\n".join(lines)


def _children(node: Node) -> tuple[Node, ...]:
    if isinstance(node, Scan):
        return ()
    if isinstance(node, Join):
        return (node.left, node.right)
    if isinstance(node, BindJoin):
        return (node.left,)
    if isinstance(node, UnionNode):
        return node.arms
    if isinstance(node, LeftFinish):
        return (node.left, node.right)
    return (node.child,)


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------


def _scan_estimates(
    store: TripleStore, patterns: tuple[TriplePattern, ...]
) -> list[int]:
    """Exact per-pattern cardinalities from the index statistics.  A pattern
    holding a constant the store has never seen is 0 without touching the
    index."""
    ids = np.full((len(patterns), 3), -1, np.int32)
    resolvable = np.ones(len(patterns), bool)
    for i, pat in enumerate(patterns):
        for j, term in enumerate(pat.slots):
            if term.startswith("?"):
                continue
            tid = store.term_id(term)
            if tid is None:
                resolvable[i] = False
            else:
                ids[i, j] = tid
    ests = np.zeros(len(patterns), np.int64)
    live = np.nonzero(resolvable)[0]
    if len(live) and store.n_triples:
        ests[live] = match_counts(store, ids[live])
    return [int(e) for e in ests]


class _Builder:
    def __init__(self) -> None:
        self._next = 0

    def nid(self) -> int:
        n = self._next
        self._next += 1
        return n

    def scan(self, pattern_pos: int, pat: TriplePattern, est: int) -> Scan:
        const_slots, var_slots, eq_pairs = [], [], []
        first: dict[str, int] = {}
        for pos, term in enumerate(pat.slots):
            if not term.startswith("?"):
                const_slots.append(pos)
            elif term in first:
                eq_pairs.append((first[term], pos))
            else:
                first[term] = pos
                var_slots.append((pos, term))
        mask = tuple(not t.startswith("?") for t in pat.slots)
        return Scan(
            node_id=self.nid(),
            pattern_pos=pattern_pos,
            order=_ORDER_FOR_MASK[mask],
            const_slots=tuple(const_slots),
            var_slots=tuple(var_slots),
            eq_pairs=tuple(eq_pairs),
            out_vars=tuple(v for _, v in var_slots),
            est=est,
        )

    def join(self, left: Node, right: Node, kind: str) -> Join:
        shared = tuple(v for v in left.out_vars if v in right.out_vars)
        out = left.out_vars + tuple(
            v for v in right.out_vars if v not in left.out_vars
        )
        if shared:
            est = max(left.est, right.est)
        else:
            est = left.est * max(right.est, 1) if kind == "left" else (
                left.est * right.est
            )
        # the sorted build side is the smaller one; LeftJoin must probe with
        # the (preserved) left side, so its build is always the right
        build_right = True if kind == "left" else right.est <= left.est
        return Join(
            node_id=self.nid(),
            left=left,
            right=right,
            shared=shared,
            kind=kind,
            build_right=build_right,
            out_vars=out,
            est=max(int(est), 0),
        )

    def bind_join(self, left: Node, scan: Scan, kind: str) -> BindJoin:
        """Rewrite ``left JOIN scan`` as an index nested-loop join: the
        scan's variables already bound on the left become part of the
        index lookup's bound prefix."""
        const_slots = list(scan.const_slots)
        bound_slots, free_slots, eq_pairs = [], [], []
        first_free: dict[str, int] = {}
        for pos, v in scan.var_slots:
            if v in left.out_vars:
                bound_slots.append((pos, v))
            elif v in first_free:
                eq_pairs.append((first_free[v], pos))
            else:
                first_free[v] = pos
                free_slots.append((pos, v))
        # a repeated variable whose first slot is bound binds every slot
        for pa, pb in scan.eq_pairs:
            var = next(v for p, v in scan.var_slots if p == pa)
            if var in left.out_vars:
                bound_slots.append((pb, var))
            else:
                eq_pairs.append((pa, pb))
        mask = tuple(
            pos in const_slots or any(p == pos for p, _ in bound_slots)
            for pos in range(3)
        )
        return BindJoin(
            node_id=self.nid(),
            left=left,
            pattern_pos=scan.pattern_pos,
            order=_ORDER_FOR_MASK[mask],
            const_slots=tuple(const_slots),
            bound_slots=tuple(bound_slots),
            free_slots=tuple(free_slots),
            eq_pairs=tuple(eq_pairs),
            kind=kind,
            out_vars=left.out_vars + tuple(v for _, v in free_slots),
            est=max(left.est, 16),
        )

    def combine(self, left: Node, scan: Scan, kind: str = "inner") -> Node:
        """Pick the physical join: a scan sharing variables with the
        accumulated scope bind-joins when its independent cardinality
        exceeds the left side's (never materialize the big unanchored
        side); otherwise the sorted-merge join over both materialized
        sides wins."""
        shared = [v for v in scan.out_vars if v in left.out_vars]
        if (
            shared
            and left.out_vars
            and (kind == "left" or scan.est > left.est)
        ):
            return self.bind_join(left, scan, kind)
        return self.join(left, scan, kind)

    def union(self, arms: list[Node]) -> UnionNode:
        out: dict[str, None] = {}
        for a in arms:
            for v in a.out_vars:
                out.setdefault(v)
        return UnionNode(
            node_id=self.nid(),
            arms=tuple(arms),
            out_vars=tuple(out),
            est=max(sum(a.est for a in arms), 0),
        )

    def filter(self, child: Node, expr: LExpr) -> Filter:
        return Filter(
            node_id=self.nid(),
            child=child,
            expr=expr,
            out_vars=child.out_vars,
            est=child.est,
        )


def _fold_onto(b: _Builder, node: Node, scans: list[Scan], attach=None) -> Node:
    """Greedy smallest-first fold of ``scans`` onto an accumulated ``node``,
    preferring connected scans; optionally calls ``attach(node) -> node``
    after every step so filters apply as soon as their variables are in
    scope."""
    remaining = sorted(scans, key=lambda s: (s.est, s.node_id))
    while remaining:
        i = next(
            (
                j
                for j, s in enumerate(remaining)
                if not s.out_vars or not node.out_vars
                or any(v in node.out_vars for v in s.out_vars)
            ),
            0,  # nothing connected: cross-join the smallest remaining
        )
        node = b.combine(node, remaining.pop(i))
        if attach is not None:
            node = attach(node)
    return node


def _fold_bgp(b: _Builder, scans: list[Scan], attach=None) -> Node:
    """Greedy smallest-first fold of a whole BGP."""
    remaining = sorted(scans, key=lambda s: (s.est, s.node_id))
    node: Node = remaining.pop(0)
    if attach is not None:
        node = attach(node)
    return _fold_onto(b, node, remaining, attach)


def plan_query(store: TripleStore, q: A.SelectQuery) -> Plan:
    """Build the operator tree for ``q`` over ``store``.  Cardinalities come
    from the representative query's constants; signature-equal queries reuse
    the plan (the executor's capacity feedback absorbs the variance)."""
    b = _Builder()
    flat = q.all_patterns()
    ests = _scan_estimates(store, flat)

    # lower filters once (slot assignment is query-structure-deterministic)
    cursor = [0]
    lowered = tuple(_lower_expr(f, cursor) for f in q.filters)
    n_filter_ops = cursor[0]
    eventually_bound = set(q.scope())
    required_vars = {v for pat in q.patterns for v in pat.variables}
    pending = [
        (e, tuple(A.expr_variables(f))) for e, f in zip(lowered, q.filters)
    ]

    def ready(filter_vars: tuple[str, ...], scope: tuple[str, ...]) -> bool:
        return all(
            (v in scope) or (v not in eventually_bound) for v in filter_vars
        )

    def attach_required(node: Node) -> Node:
        # inside the required fold only filters that never touch union- or
        # optional-bound variables may run (those can still add
        # rows/bindings these filters must see)
        changed = True
        while changed:
            changed = False
            for i, (expr, fvars) in enumerate(pending):
                if all(
                    v in required_vars or v not in eventually_bound
                    for v in fvars
                ) and ready(fvars, node.out_vars):
                    node = b.filter(node, expr)
                    pending.pop(i)
                    changed = True
                    break
        return node

    def attach_ready(node: Node) -> Node:
        # filters whose variables just became bound attach now
        for i in range(len(pending) - 1, -1, -1):
            expr, fvars = pending[i]
            if ready(fvars, node.out_vars):
                node = b.filter(node, expr)
                pending.pop(i)
        return node

    node: Node | None = None
    if q.patterns:
        required_scans = [
            b.scan(pos, pat, ests[pos])
            for pos, pat in enumerate(q.patterns)
        ]
        node = _fold_bgp(b, required_scans, attach=attach_required)

    pos0 = len(q.patterns)
    if q.unions:
        arm_nodes: list[Node] = []
        for arm in q.unions:
            ascans = [
                b.scan(pos0 + k, pat, ests[pos0 + k])
                for k, pat in enumerate(arm)
            ]
            pos0 += len(arm)
            if node is None:
                arm_nodes.append(_fold_bgp(b, ascans))
            else:
                # shared-scan reuse: every arm folds onto the SAME required
                # subtree object; the executor memoizes it per dispatch
                arm_nodes.append(_fold_onto(b, node, ascans))
        node = b.union(arm_nodes)
        node = attach_ready(node)
    assert node is not None  # parse_select guarantees patterns or unions

    for group in q.optionals:
        gscans = [
            b.scan(pos0 + k, pat, ests[pos0 + k])
            for k, pat in enumerate(group)
        ]
        pos0 += len(group)
        if len(gscans) == 1:
            # the common OPTIONAL shape: one pattern, bind-joined with
            # unmatched-row backfill (never materialized on its own)
            node = b.combine(node, gscans[0], "left")
        else:
            # multi-pattern group: a bind-join chain off the required
            # scope — tag left rows, chain the group's patterns as inner
            # joins anchored on the left bindings, then append unmatched
            # left rows (group variables unbound)
            rowid = f"@row{node.node_id}"
            tagged = TagRows(
                node_id=b.nid(),
                child=node,
                var=rowid,
                out_vars=node.out_vars + (rowid,),
                est=node.est,
            )
            chain = _fold_onto(b, tagged, gscans)
            gvars = tuple(
                v for v in chain.out_vars
                if v not in tagged.out_vars
            )
            node = LeftFinish(
                node_id=b.nid(),
                left=tagged,
                right=chain,
                rowid=rowid,
                out_vars=node.out_vars + gvars,
                est=max(chain.est + node.est, 0),
            )
        node = attach_ready(node)

    # any filter still pending mentions only never-bound variables
    for expr, _ in pending:
        node = b.filter(node, expr)

    out_vars = q.out_vars()
    agg_vars = (q.agg.alias,) if q.agg else ()
    if q.agg is not None or q.group_by:
        node = Group(
            node_id=b.nid(),
            child=node,
            keys=q.group_by,
            count_var=q.agg.var if q.agg else None,
            alias=q.agg.alias if q.agg else None,
            out_vars=out_vars,
            est=node.est if q.group_by else 1,
        )
    else:
        node = Project(
            node_id=b.nid(), child=node, out_vars=out_vars, est=node.est
        )
        if q.distinct:
            node = Distinct(
                node_id=b.nid(), child=node, out_vars=out_vars, est=node.est
            )
    if q.order_by:
        node = OrderBy(
            node_id=b.nid(),
            child=node,
            keys=tuple((v, asc, v in agg_vars) for v, asc in q.order_by),
            out_vars=out_vars,
            est=node.est,
        )
    elif not q.distinct:
        # Distinct leaves rows sorted; otherwise sort explicitly so results
        # are deterministically ordered by term id (count columns by value);
        # the executor elides it when the tracked sortedness already matches
        node = Sort(node_id=b.nid(), child=node, out_vars=out_vars, est=node.est)
    if q.limit is not None:
        node = Limit(
            node_id=b.nid(),
            child=node,
            n=q.limit,
            out_vars=out_vars,
            est=min(node.est, q.limit),
        )
    # pattern readers must be listed in pipeline (fold) order for the
    # consts matrix; recover that order from the tree — which is a DAG
    # where union arms / optional chains share subtrees, so visit each
    # node once
    ordered: list[TUnion[Scan, BindJoin]] = []
    seen: set[int] = set()

    def collect(n: Node) -> None:
        if n.node_id in seen:
            return
        seen.add(n.node_id)
        for c in _children(n):
            collect(c)
        if isinstance(n, (Scan, BindJoin)):
            ordered.append(n)

    collect(node)
    term_order_keys = bool(q.order_by) and any(
        v not in agg_vars for v, _ in q.order_by
    )
    return Plan(
        sig=q.signature(),
        root=node,
        scans=tuple(ordered),
        n_filter_ops=n_filter_ops,
        has_filters=bool(q.filters),
        needs_values=bool(q.filters) or term_order_keys,
        agg_vars=agg_vars,
        global_agg_alias=(
            q.agg.alias if (q.agg is not None and not q.group_by) else None
        ),
    )


FASTPATH_MAX_READERS = 3


def fastpath_chain(plan: Plan) -> tuple | None:
    """Structural eligibility for the small-batch fused scan-join fast
    path (``repro.serve.fastpath``): a pure ``Scan → BindJoin*`` chain of
    at most :data:`FASTPATH_MAX_READERS` readers — inner joins only, no
    repeated-variable patterns, no filters / aggregates / DISTINCT /
    ORDER BY / UNION / OPTIONAL — under the standard ``Project → Sort
    [→ Limit]`` tail.  Returns the reader nodes in pipeline order (they
    must coincide with ``plan.scans`` so consts rows line up), or None
    when the plan needs the general executor."""
    if plan.has_filters or plan.n_filter_ops or plan.agg_vars:
        return None
    node = plan.root
    if isinstance(node, Limit):
        node = node.child
    if not isinstance(node, Sort):
        return None
    node = node.child
    if not isinstance(node, Project):
        return None
    node = node.child
    readers: list = []
    while isinstance(node, BindJoin):
        if node.kind != "inner" or node.eq_pairs or not node.free_slots:
            return None
        readers.append(node)
        node = node.left
    if not isinstance(node, Scan):
        return None
    if node.eq_pairs or not node.out_vars:
        return None
    readers.append(node)
    readers.reverse()
    if len(readers) > FASTPATH_MAX_READERS:
        return None
    if tuple(r.node_id for r in readers) != tuple(
        s.node_id for s in plan.scans
    ):
        return None
    return tuple(readers)


def encode_scan_consts(
    store: TripleStore, plan: Plan, q: A.SelectQuery
) -> np.ndarray:
    """Per-query constant term ids, one (s, p, o) row per plan scan: ``-1``
    marks a variable slot, ``-2`` a constant the store has never seen (its
    range scan comes back empty).  ``store`` may be any store-like object
    with ``term_id`` — in particular a live ``OverlayView``, whose combined
    term table resolves overlay-only constants (planning itself always
    runs on the base store; the executor's capacity feedback absorbs the
    delta rows the estimates never saw)."""
    flat = q.all_patterns()
    out = np.full((len(plan.scans), 3), -1, np.int32)
    for i, scan in enumerate(plan.scans):
        pat = flat[scan.pattern_pos]
        for pos in scan.const_slots:
            tid = store.term_id(pat.slots[pos])
            out[i, pos] = -2 if tid is None else tid
    return out


# ---------------------------------------------------------------------------
# shard routing — which shards can answer a query (repro.shard coordinator)
# ---------------------------------------------------------------------------


def routing_subject(q: A.SelectQuery) -> str | None:
    """The rendered constant subject every pattern of ``q`` is anchored on,
    or ``None``.  When every pattern (required, UNION arms, OPTIONAL
    groups) reads the *same constant* subject, every solution's matched
    triples share that subject — so under subject-hash partitioning the
    whole query lives on exactly one shard and the coordinator routes it
    there instead of scattering."""
    subjects = {p.slots[0] for p in q.all_patterns()}
    if len(subjects) == 1:
        s = next(iter(subjects))
        if not s.startswith("?"):
            return s
    return None


def colocated_subjects(q: A.SelectQuery) -> bool:
    """True when every solution of ``q`` matches triples that all share one
    subject value — the condition under which scatter/gather is exact:
    each solution is found on the one shard holding that subject, and on
    no other (so the gathered union is the unsharded bag).  Holds for a
    single pattern (one triple per solution) and for star shapes where
    every pattern reads the same subject variable or the same constant.
    Chains (``?s <p> ?o . ?o <q> ?r``) join across subjects and are NOT
    colocated — the coordinator answers them by gathering each pattern's
    matches and combining host-side instead."""
    pats = q.all_patterns()
    if len(pats) <= 1:
        return True
    return len({p.slots[0] for p in pats}) == 1
