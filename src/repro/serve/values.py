"""Literal-value side tables — FILTER / ORDER BY semantics over term ids.

The executor never touches strings at query time; comparisons and
value-typed ordering run on dense *rank* tables built once per store
(cached on the store object; ``KGServer`` constructs them eagerly at
server store-load so no client pays the cost on its first query):

* ``num_rank[t]`` — rank of term ``t``'s numeric value among the store's
  distinct numeric literal values (``-1`` if the term is not a numeric
  literal).  Equal values share a rank, so rank comparisons are exactly
  value comparisons — no float precision leaves the host (device arrays
  are int32, immune to the f64->f32 demotion a value table would suffer).
* ``str_rank[t]`` — rank of the raw (unescaped) literal body among the
  store's distinct literal bodies (``-1`` for non-literals); codepoint
  order, the SPARQL ``STR()`` comparison our lite semantics uses.
* ``is_num`` / ``is_lit`` — participation masks (SPARQL type errors make a
  comparison false, they never crash).
* ``order_rank[t]`` — the ``ORDER BY`` total order: IRIs (by rendered
  term) < numeric literals (by value) < other literals (by raw body),
  ties broken by rendered term (= term id), so the order is a permutation
  and identical across stores of the same graph.  Built *on device*: the
  int32 class/rank/tie keys are lexsorted with jax and scattered back —
  only the string/number extraction stays on host.

Constants are resolved to rank *bounds* on the host at plan/encode time
with a binary search over the kept sorted-unique tables, so a constant
absent from the store still compares correctly (it falls between ranks).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.data.encoder import render_template
from repro.kg.store import TripleStore


@dataclasses.dataclass(frozen=True)
class ValueTable:
    # device (jnp) arrays, one entry per term id
    is_lit: jnp.ndarray      # bool[T]
    is_num: jnp.ndarray      # bool[T]
    str_rank: jnp.ndarray    # int32[T], -1 for non-literals
    num_rank: jnp.ndarray    # int32[T], -1 for non-numerics
    order_rank: jnp.ndarray  # int32[T], a permutation (the ORDER BY key)
    # True when order_rank is the identity: value order == term-id order,
    # so an ORDER BY over already-term-id-sorted rows can be elided
    order_is_tid: bool
    # host tables for constant rank lookup
    str_uniq: np.ndarray     # object[Us]  sorted distinct literal bodies
    num_uniq: np.ndarray     # float64[Un] sorted distinct numeric values

    def num_bounds(self, value: float) -> tuple[int, int]:
        """``(lo, hi)`` ranks such that a term compares to ``value`` as its
        ``num_rank`` compares to the bounds: ``< value`` iff ``rank < lo``,
        ``== value`` iff ``lo <= rank < hi``, ``> value`` iff ``rank >= hi``."""
        lo = int(np.searchsorted(self.num_uniq, value, side="left"))
        hi = int(np.searchsorted(self.num_uniq, value, side="right"))
        return lo, hi

    def str_bounds(self, body: str) -> tuple[int, int]:
        lo = int(np.searchsorted(self.str_uniq, body, side="left"))
        hi = int(np.searchsorted(self.str_uniq, body, side="right"))
        return lo, hi


def literal_body(store: TripleStore, term_id: int) -> str | None:
    """Raw (unescaped) literal body of a term, ``None`` for IRIs."""
    pat = store.dictionary.decode_scalar(int(store.term_pat[term_id]))
    kind, pattern = pat.split(":", 1)
    if kind != "lit":
        return None
    if "{}" not in pattern:
        return pattern
    return render_template(
        pattern, store.dictionary.decode_scalar(int(store.term_val[term_id]))
    )


def parse_number(body: str) -> float | None:
    """The one number-parsing rule shared by engine and oracle."""
    try:
        v = float(body)
    except ValueError:
        return None
    return v if np.isfinite(v) else None


def value_table(store: TripleStore) -> ValueTable:
    """Build (or fetch the cached) side tables for a store."""
    cached = getattr(store, "_value_table", None)
    if cached is not None:
        return cached
    T = store.n_terms
    is_lit = np.zeros(T, bool)
    bodies = np.empty(T, object)
    numvals = np.full(T, np.nan)
    for t in range(T):
        body = literal_body(store, t)
        if body is None:
            continue
        is_lit[t] = True
        bodies[t] = body
        v = parse_number(body)
        if v is not None:
            numvals[t] = v
    str_rank = np.full(T, -1, np.int32)
    if is_lit.any():
        str_uniq, inv = np.unique(bodies[is_lit], return_inverse=True)
        str_rank[is_lit] = inv.astype(np.int32)
    else:
        str_uniq = np.empty(0, object)
    is_num = ~np.isnan(numvals)
    num_rank = np.full(T, -1, np.int32)
    if is_num.any():
        num_uniq, inv = np.unique(numvals[is_num], return_inverse=True)
        num_rank[is_num] = inv.astype(np.int32)
    else:
        num_uniq = np.empty(0, np.float64)
    # the ORDER BY total order, built on device from int32 keys: class
    # (iri < numeric < other literal), the within-class value rank, term id
    # as the tie-break.  order_rank[perm[i]] = i makes it a permutation.
    tid = np.arange(T, dtype=np.int32)
    cls = np.where(~is_lit, 0, np.where(is_num, 1, 2)).astype(np.int32)
    within = np.where(
        ~is_lit, tid, np.where(is_num, num_rank, str_rank)
    ).astype(np.int32)
    perm = jnp.lexsort((jnp.asarray(tid), jnp.asarray(within), jnp.asarray(cls)))
    arange = jnp.arange(T, dtype=jnp.int32)
    order_rank = jnp.zeros(T, jnp.int32).at[perm].set(arange)
    order_is_tid = bool(jnp.all(perm == arange))
    table = ValueTable(
        is_lit=jnp.asarray(is_lit),
        is_num=jnp.asarray(is_num),
        str_rank=jnp.asarray(str_rank),
        num_rank=jnp.asarray(num_rank),
        order_rank=order_rank,
        order_is_tid=order_is_tid,
        str_uniq=str_uniq,
        num_uniq=num_uniq,
    )
    store._value_table = table
    return table
