"""Logical plan nodes — what a Dataset *will* do, recorded lazily.

The plan is a linear chain ``Read -> (Project | MapBlocks | Encode)* ->
Batch?``.  Nothing here executes; :mod:`repro.stream.physical` lowers the
chain by (a) rewriting a leading ``Read -> Project(pushdown=True)`` pair
into the datasource itself — the reader then never materializes a pruned
column (see :func:`repro.stream.physical.pushdown_projection`) — and
(b) fusing all consecutive per-block transforms into one operator so a
block makes a single pass through Python per stage boundary.

``Project`` carries the planner-relevant policy in two fields:

* ``fill`` — ``""`` union-fills columns missing from a block (the right
  semantics for heterogeneous JSON records and glob shards); ``None`` is
  *strict* and raises ``KeyError`` on a missing column, which is what the
  mapping planner (:mod:`repro.rml.plan`) demands for fixed-schema
  sources — a missing mapped column is a typo, not heterogeneity, and
  must fail loudly rather than fabricate empty-string terms.
* ``pushdown`` — opt-in marker set by planner-driven projections; only a
  marked Project is pushed into the reader, so ad-hoc Dataset users (and
  the planner-off reference path) keep the read-everything behavior.

``Encode`` is the one stateful node: its dictionary is shared and
append-only, so ids are stable across blocks and across overflow replays.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.stream.block import Block
from repro.stream.datasource import Datasource


@dataclasses.dataclass(frozen=True)
class LogicalOp:
    pass


@dataclasses.dataclass(frozen=True)
class Read(LogicalOp):
    source: Datasource


@dataclasses.dataclass(frozen=True)
class Project(LogicalOp):
    columns: tuple[str, ...]
    fill: str | None = ""  # None -> strict (KeyError on missing column)
    pushdown: bool = False  # planner-driven: push into the datasource


@dataclasses.dataclass(frozen=True)
class MapBlocks(LogicalOp):
    fn: Callable[[Block], Block]


@dataclasses.dataclass(frozen=True)
class Encode(LogicalOp):
    """Incremental dictionary encoding: every non-integer column of each
    block is replaced by its int32 id column.  The dictionary is shared and
    append-only, so ids are stable across blocks and across replays."""

    dictionary: object  # repro.data.encoder.Dictionary (duck-typed: .encode)
    columns: tuple[str, ...] | None = None  # None -> all string columns

    def apply(self, block: Block) -> Block:
        out = {}
        for name, col in block.columns.items():
            wanted = self.columns is None or name in self.columns
            if wanted and not np.issubdtype(col.dtype, np.integer):
                out[name] = self.dictionary.encode(col)
            else:
                out[name] = col
        return Block(out)


@dataclasses.dataclass(frozen=True)
class Batch(LogicalOp):
    """Re-chunk the stream to exactly ``rows`` rows per block (final block
    may be short — the consumer pads it and carries a validity mask)."""

    rows: int
