"""The lazy Dataset API over partitioned Blocks.

A Dataset is an immutable logical plan; every transform returns a new
Dataset and nothing reads the source until :meth:`iter_blocks` runs the
lowered physical plan.  Iteration is repeatable — each call re-executes the
plan from the source — which is what lets the KG engine replay a predicate
(after a PTT overflow) without caching source data in memory.

    ds = (read_csv("child.csv", block_rows=8192)
          .project("MUTATION_ID", "GENE_NAME")
          .encode(dictionary)
          .batch(8192))
    for block in ds.iter_blocks():      # int32 blocks, bounded prefetch
        ...
"""

from __future__ import annotations

from typing import Callable, Iterator

import numpy as np

from repro.stream import physical
from repro.stream.block import Block
from repro.stream.datasource import Datasource, TableDatasource, make_datasource
from repro.stream.logical import Batch, Encode, LogicalOp, MapBlocks, Project, Read

DEFAULT_BLOCK_ROWS = 1 << 14


def _check_block_rows(rows: int) -> int:
    if rows < 1:
        raise ValueError(f"block_rows must be >= 1, got {rows}")
    return rows


class Dataset:
    def __init__(self, plan: tuple[LogicalOp, ...]):
        self._plan = plan

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_source(cls, source: Datasource) -> "Dataset":
        return cls((Read(source),))

    @classmethod
    def from_table(
        cls, columns: dict[str, np.ndarray], block_rows: int = DEFAULT_BLOCK_ROWS
    ) -> "Dataset":
        return cls.from_source(
            TableDatasource(columns=columns, block_rows=_check_block_rows(block_rows))
        )

    # -- lazy transforms (each returns a new Dataset) ------------------------

    def _with(self, op: LogicalOp) -> "Dataset":
        return Dataset(self._plan + (op,))

    def project(
        self, *columns: str, fill: str | None = "", pushdown: bool = False
    ) -> "Dataset":
        """Project to ``columns``; ``fill`` is the value for columns absent
        from a block (``None`` -> strict KeyError).  ``pushdown=True``
        marks the projection for the physical rewrite that pushes it into
        the datasource (planner-driven reads; see
        :func:`repro.stream.physical.pushdown_projection`)."""
        return self._with(
            Project(columns=tuple(columns), fill=fill, pushdown=pushdown)
        )

    def map_blocks(self, fn: Callable[[Block], Block]) -> "Dataset":
        return self._with(MapBlocks(fn=fn))

    def encode(self, dictionary, columns: tuple[str, ...] | None = None) -> "Dataset":
        return self._with(Encode(dictionary=dictionary, columns=columns))

    def batch(self, rows: int) -> "Dataset":
        return self._with(Batch(rows=_check_block_rows(rows)))

    # -- execution -----------------------------------------------------------

    def iter_blocks(self, prefetch: int = 2) -> Iterator[Block]:
        return physical.execute(self._plan, prefetch=prefetch)

    def count(self) -> int:
        if len(self._plan) == 1 and isinstance(self._plan[0], Read):
            counter = getattr(self._plan[0].source, "count_rows", None)
            if counter is not None:  # row count without building cell arrays
                return counter()
        return sum(b.n_rows for b in self.iter_blocks())

    def materialize(self) -> Block:
        """Concatenate every block — eager escape hatch for small data."""
        return Block.concat(list(self.iter_blocks()))

    def take(self, n: int) -> Block:
        out: list[Block] = []
        got = 0
        for block in self.iter_blocks():
            out.append(block)
            got += block.n_rows
            if got >= n:
                break
        whole = Block.concat(out)
        return whole.slice(0, min(n, whole.n_rows))

    def schema(self) -> tuple[str, ...]:
        for block in self.iter_blocks(prefetch=0):
            return block.schema
        return ()


def read_csv(
    path: str, block_rows: int = DEFAULT_BLOCK_ROWS, delimiter: str = ","
) -> Dataset:
    fmt = "tsv" if delimiter == "\t" else "csv"
    return Dataset.from_source(
        make_datasource(
            path, fmt, _check_block_rows(block_rows), delimiter=delimiter
        )
    )


def read_json(
    path: str, block_rows: int = DEFAULT_BLOCK_ROWS, iterator: str | None = None
) -> Dataset:
    return Dataset.from_source(
        make_datasource(path, "json", _check_block_rows(block_rows), iterator)
    )


def read_source(
    path: str,
    fmt: str = "csv",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    iterator: str | None = None,
) -> Dataset:
    """Format-dispatching reader; glob patterns become sharded multi-file
    sources (one shard per file, heterogeneous schemas unioned on project)."""
    return Dataset.from_source(
        make_datasource(path, fmt, _check_block_rows(block_rows), iterator)
    )
