"""Physical plan: lowering + pipelined execution with bounded prefetch.

Lowering fuses each run of per-block logical ops (Project / MapBlocks /
Encode) into a single :class:`FusedMapOperator`; ``Batch`` becomes a
:class:`RebatchOperator`.  Execution is a chain of generators with the read
stage handed off to a background thread through a bounded queue, so disk I/O
and parsing overlap the jitted compute of the consumer — the classic
two-stage pipeline — while the queue bound keeps at most
``prefetch + 1`` blocks in flight.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

from repro.stream.block import Block
from repro.stream.datasource import Datasource
from repro.stream.logical import Batch, Encode, LogicalOp, MapBlocks, Project, Read

_DONE = object()


class _Prefetcher:
    """Background-thread handoff with a bounded queue and clean shutdown.
    The pump thread starts lazily on first consumption, so an iterator that
    is created but never drained holds no thread and no open file."""

    def __init__(self, it: Iterator[Block], capacity: int):
        self._q: queue.Queue = queue.Queue(maxsize=max(capacity, 1))
        self._stop = threading.Event()
        self._started = False
        self._thread = threading.Thread(target=self._pump, args=(it,), daemon=True)

    def _pump(self, it: Iterator[Block]) -> None:
        try:
            for item in it:
                if not self._put((False, item)):
                    return
            self._put((False, _DONE))
        except BaseException as exc:  # propagate to the consumer
            self._put((True, exc))

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self):
        try:
            if not self._started:
                self._started = True
                self._thread.start()
            while True:
                is_err, item = self._q.get()
                if is_err:
                    raise item
                if item is _DONE:
                    return
                yield item
        finally:
            self.close()

    def close(self) -> None:
        self._stop.set()


def _read_blocks(source: Datasource) -> Iterator[Block]:
    for task in source.read_tasks():
        yield from task.read()


def _fused(fns: list[Callable[[Block], Block]], it: Iterator[Block]) -> Iterator[Block]:
    for block in it:
        for fn in fns:
            block = fn(block)
        yield block


def _rebatch(rows: int, it: Iterator[Block]) -> Iterator[Block]:
    pending: list[Block] = []
    n = 0
    for block in it:
        if block.n_rows == 0:
            continue
        if not pending and block.n_rows == rows:  # fast path: already sized
            yield block
            continue
        pending.append(block)
        n += block.n_rows
        while n >= rows:
            take, filled, acc = [], 0, []
            for b in pending:
                need = rows - filled
                if need == 0:
                    acc.append(b)
                elif b.n_rows <= need:
                    take.append(b)
                    filled += b.n_rows
                else:
                    take.append(b.slice(0, need))
                    acc.append(b.slice(need, b.n_rows))
                    filled = rows
            yield Block.concat(take) if len(take) > 1 else take[0]
            pending = acc
            n -= rows
    if pending:
        yield Block.concat(pending) if len(pending) > 1 else pending[0]


def _op_fn(op: LogicalOp) -> Callable[[Block], Block]:
    if isinstance(op, Project):
        cols, fill = op.columns, op.fill
        return lambda b: b.select(cols, fill)
    if isinstance(op, MapBlocks):
        return op.fn
    if isinstance(op, Encode):
        return op.apply
    raise TypeError(f"not a per-block op: {op!r}")


def execute(plan: tuple[LogicalOp, ...], prefetch: int = 2) -> Iterator[Block]:
    """Lower the logical plan and run it as a pipelined block iterator."""
    if not plan or not isinstance(plan[0], Read):
        raise ValueError("logical plan must start with a Read")
    it: Iterator[Block] = _read_blocks(plan[0].source)
    if prefetch > 0:  # overlap I/O + parsing with downstream compute
        it = iter(_Prefetcher(it, prefetch))
    fns: list[Callable[[Block], Block]] = []
    for op in plan[1:]:
        if isinstance(op, Batch):
            if fns:
                it = _fused(fns, it)
                fns = []
            it = _rebatch(op.rows, it)
        else:
            fns.append(_op_fn(op))
    if fns:
        it = _fused(fns, it)
    return it
