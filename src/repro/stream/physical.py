"""Physical plan: lowering + pipelined execution with bounded prefetch.

Lowering first applies :func:`pushdown_projection` — a planner-marked
``Read -> Project`` prefix collapses into the datasource so pruned columns
are never materialized — then fuses each run of per-block logical ops
(Project / MapBlocks / Encode) into a single :class:`FusedMapOperator`;
``Batch`` becomes a :class:`RebatchOperator`.  Execution is a chain of generators with the read
stage handed off to a background thread through a bounded queue, so disk I/O
and parsing overlap the jitted compute of the consumer — the classic
two-stage pipeline — while the queue bound keeps at most
``prefetch + 1`` blocks in flight.

Every stage reports into ``repro.obs``: per-block read / project / encode /
batch timings (``stream.<stage>_ms`` histograms), rows and bytes per stage
(``stream.<stage>_rows`` / ``stream.read_bytes`` counters), prefetch queue
depth (``stream.prefetch_depth`` gauge) and consumer starvation
(``stream.prefetch_wait_ms``).  With tracing enabled each block also
records a span, so an ingestion run exports as a flame graph of the
pipeline.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator

from repro.obs import get_registry, get_tracer
from repro.stream.block import Block
from repro.stream.datasource import Datasource
from repro.stream.logical import Batch, Encode, LogicalOp, MapBlocks, Project, Read

_DONE = object()


def _block_nbytes(block: Block) -> int:
    """Buffer bytes across columns (object columns count pointer width —
    a cheap, consistent per-stage traffic proxy, not a deep string size)."""
    return sum(c.nbytes for c in block.columns.values())


class _Prefetcher:
    """Background-thread handoff with a bounded queue and clean shutdown.
    The pump thread starts lazily on first consumption, so an iterator that
    is created but never drained holds no thread and no open file."""

    def __init__(self, it: Iterator[Block], capacity: int):
        self._q: queue.Queue = queue.Queue(maxsize=max(capacity, 1))
        self._stop = threading.Event()
        self._started = False
        self._thread = threading.Thread(target=self._pump, args=(it,), daemon=True)

    def _pump(self, it: Iterator[Block]) -> None:
        try:
            for item in it:
                if not self._put((False, item)):
                    return
            self._put((False, _DONE))
        except BaseException as exc:  # propagate to the consumer
            self._put((True, exc))

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self):
        reg = get_registry()
        try:
            if not self._started:
                self._started = True
                self._thread.start()
            while True:
                t0 = time.perf_counter_ns()
                is_err, item = self._q.get()
                # time blocked on the producer: >0 means the consumer
                # starves (I/O-bound), ~0 means the queue stays full
                # (compute-bound) — the tuning signal for `prefetch`
                reg.observe(
                    "stream.prefetch_wait_ms",
                    (time.perf_counter_ns() - t0) / 1e6,
                )
                reg.gauge("stream.prefetch_depth").set(self._q.qsize())
                if is_err:
                    raise item
                if item is _DONE:
                    return
                yield item
        finally:
            self.close()

    def close(self) -> None:
        self._stop.set()


def _read_blocks(source: Datasource) -> Iterator[Block]:
    reg = get_registry()
    tracer = get_tracer()
    for task in source.read_tasks():
        it = iter(task.read())
        while True:
            t0 = time.perf_counter_ns()
            try:
                block = next(it)
            except StopIteration:
                break
            t1 = time.perf_counter_ns()
            reg.observe("stream.read_ms", (t1 - t0) / 1e6)
            reg.inc("stream.read_blocks")
            reg.inc("stream.read_rows", block.n_rows)
            reg.inc("stream.read_bytes", _block_nbytes(block))
            if tracer.enabled:
                tracer.add_complete(
                    "read_block", "stream", t0, t1, rows=block.n_rows
                )
            yield block


def _fused(
    fns: list[tuple[str, Callable[[Block], Block]]], it: Iterator[Block]
) -> Iterator[Block]:
    reg = get_registry()
    tracer = get_tracer()
    for block in it:
        for name, fn in fns:
            t0 = time.perf_counter_ns()
            block = fn(block)
            t1 = time.perf_counter_ns()
            reg.observe(f"stream.{name}_ms", (t1 - t0) / 1e6)
            reg.inc(f"stream.{name}_rows", block.n_rows)
            if tracer.enabled:
                tracer.add_complete(
                    name, "stream", t0, t1, rows=block.n_rows
                )
        yield block


def _rebatch(rows: int, it: Iterator[Block]) -> Iterator[Block]:
    reg = get_registry()

    def emit(blocks_or_block) -> Block:
        t0 = time.perf_counter_ns()
        out = (
            Block.concat(blocks_or_block)
            if isinstance(blocks_or_block, list)
            else blocks_or_block
        )
        reg.observe("stream.batch_ms", (time.perf_counter_ns() - t0) / 1e6)
        reg.inc("stream.batch_blocks")
        reg.inc("stream.batch_rows", out.n_rows)
        return out

    pending: list[Block] = []
    n = 0
    for block in it:
        if block.n_rows == 0:
            continue
        if not pending and block.n_rows == rows:  # fast path: already sized
            yield emit(block)
            continue
        pending.append(block)
        n += block.n_rows
        while n >= rows:
            take, filled, acc = [], 0, []
            for b in pending:
                need = rows - filled
                if need == 0:
                    acc.append(b)
                elif b.n_rows <= need:
                    take.append(b)
                    filled += b.n_rows
                else:
                    take.append(b.slice(0, need))
                    acc.append(b.slice(need, b.n_rows))
                    filled = rows
            yield emit(take if len(take) > 1 else take[0])
            pending = acc
            n -= rows
    if pending:
        yield emit(pending if len(pending) > 1 else pending[0])


def _op_fn(op: LogicalOp) -> tuple[str, Callable[[Block], Block]]:
    """(metric stage name, per-block fn) for a fusable logical op."""
    if isinstance(op, Project):
        cols, fill = op.columns, op.fill
        return "project", lambda b: b.select(cols, fill)
    if isinstance(op, MapBlocks):
        return "map", op.fn
    if isinstance(op, Encode):
        return "encode", op.apply
    raise TypeError(f"not a per-block op: {op!r}")


def pushdown_projection(plan: tuple[LogicalOp, ...]) -> tuple[LogicalOp, ...]:
    """Rewrite a leading ``Read -> Project(pushdown=True)`` pair so the
    datasource itself materializes only the projected columns.

    Strict projections (``fill=None``) are *replaced* by the reader when
    the source accepts strict pushdown — a missing mapped column then
    raises ``KeyError`` at read time, before a single row is built.
    Tolerant (union-fill) projections keep the ``Project`` node: the
    pruned reader emits whatever subset of the columns each shard/record
    has, and the Project still fills the gaps.  Sources without a
    ``with_columns`` hook (or that decline — e.g. strict pushdown into a
    per-record-schema JSON source) leave the plan untouched.
    """
    if (
        len(plan) < 2
        or not isinstance(plan[0], Read)
        or not isinstance(plan[1], Project)
        or not plan[1].pushdown
        or not plan[1].columns
    ):
        return plan
    prj = plan[1]
    hook = getattr(plan[0].source, "with_columns", None)
    if hook is None:
        return plan
    strict = prj.fill is None
    pushed = hook(prj.columns, strict)
    if pushed is None:
        return plan
    rest = plan[2:] if strict else plan[1:]
    return (Read(pushed),) + tuple(rest)


def execute(plan: tuple[LogicalOp, ...], prefetch: int = 2) -> Iterator[Block]:
    """Lower the logical plan and run it as a pipelined block iterator."""
    plan = pushdown_projection(plan)
    if not plan or not isinstance(plan[0], Read):
        raise ValueError("logical plan must start with a Read")
    it: Iterator[Block] = _read_blocks(plan[0].source)
    if prefetch > 0:  # overlap I/O + parsing with downstream compute
        it = iter(_Prefetcher(it, prefetch))
    fns: list[tuple[str, Callable[[Block], Block]]] = []
    for op in plan[1:]:
        if isinstance(op, Batch):
            if fns:
                it = _fused(fns, it)
                fns = []
            it = _rebatch(op.rows, it)
        else:
            fns.append(_op_fn(op))
    if fns:
        it = _fused(fns, it)
    return it
