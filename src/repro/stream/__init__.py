"""``repro.stream`` — block-based streaming ingestion for out-of-core KG creation.

The subsystem follows the lazy-Dataset / partitioned-Block shape of modern
streaming data engines: a :class:`Dataset` records a *logical plan*
(``read -> project -> map -> encode -> batch``) and only touches data when
iterated, at which point the plan is lowered to a pipelined *physical plan*
(fused per-block operators behind a bounded prefetch queue).  Sources are
read in fixed-row chunks, so no full source column is ever materialized on
the host — the architectural prerequisite for the engine scaling past RAM.
"""

from repro.stream.block import Block
from repro.stream.dataset import (
    DEFAULT_BLOCK_ROWS,
    Dataset,
    read_csv,
    read_json,
    read_source,
)
from repro.stream.datasource import (
    CSVDatasource,
    Datasource,
    GlobDatasource,
    JSONDatasource,
    ReadTask,
)

__all__ = [
    "Block",
    "Dataset",
    "DEFAULT_BLOCK_ROWS",
    "read_csv",
    "read_json",
    "read_source",
    "Datasource",
    "CSVDatasource",
    "JSONDatasource",
    "GlobDatasource",
    "ReadTask",
]
