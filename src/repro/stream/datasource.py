"""Chunked datasources: CSV/TSV, JSON(-lines), and glob-sharded multi-file.

A :class:`Datasource` exposes ``read_tasks()`` — one :class:`ReadTask` per
shard (file).  A ReadTask is a zero-arg callable yielding Blocks of at most
``block_rows`` rows; the physical executor runs the tasks in order behind a
bounded prefetch queue.  Re-invoking a task re-reads the shard, which is what
lets the engine replay a predicate after a hash-table overflow without ever
caching the source in memory.

Every source also supports **projection pushdown**: ``with_columns(keep,
strict)`` returns a copy that materializes only the ``keep`` columns — a
pruned CSV column is never even accumulated into a cell list, let alone a
numpy array.  ``strict=True`` (fixed-schema sources only) makes a missing
kept column a ``KeyError`` *at read time*, replacing the downstream strict
``Project``; sources whose schema is per-record or per-shard (JSON, globs)
refuse strict pushdown and prune tolerantly, leaving the union-fill
``Project`` in place.  The physical executor applies the rewrite when a
plan starts ``Read -> Project(pushdown=True)`` (see
:func:`repro.stream.physical.pushdown_projection`).
"""

from __future__ import annotations

import csv
import dataclasses
import glob as _glob
import json
import os
from typing import Callable, Iterator, Protocol

import numpy as np

from repro.data.sources import expand_iterator
from repro.stream.block import Block


@dataclasses.dataclass(frozen=True)
class ReadTask:
    read: Callable[[], Iterator[Block]]
    name: str = ""


class Datasource(Protocol):
    def read_tasks(self) -> list[ReadTask]: ...


def _plan_metrics(kept: int, pruned: int) -> None:
    """Account a pushed-down projection at the point it actually takes
    effect (the reader has seen the real schema)."""
    from repro.obs import get_registry

    reg = get_registry()
    reg.inc("plan.columns_kept", kept)
    reg.inc("plan.columns_pruned", pruned)


@dataclasses.dataclass(frozen=True)
class CSVDatasource:
    """Streaming CSV/TSV reader: never holds more than one block of rows.

    Rows shorter than the header are right-padded with ""; extra cells
    beyond the header are dropped (the eager loader crashes on both).
    With ``keep`` set only those columns are accumulated — pruned cells
    are skipped before any list/array is built, which is where the
    planner's wide-source pushdown win comes from.
    """

    path: str
    block_rows: int
    delimiter: str = ","
    keep: tuple[str, ...] | None = None  # projection pushdown
    strict: bool = True  # keep column missing from header -> KeyError

    def with_columns(self, keep, strict: bool) -> "CSVDatasource":
        return dataclasses.replace(self, keep=tuple(keep), strict=strict)

    def read_tasks(self) -> list[ReadTask]:
        return [ReadTask(read=self._blocks, name=self.path)]

    def _blocks(self) -> Iterator[Block]:
        with open(self.path, newline="", encoding="utf-8") as f:
            reader = csv.reader(f, delimiter=self.delimiter)
            header = next(reader, None)
            if header is None:
                return
            if self.keep is None:
                names = list(header)
                idxs = list(range(len(header)))
            else:
                pos = {h: i for i, h in enumerate(header)}
                if self.strict:
                    missing = [c for c in self.keep if c not in pos]
                    if missing:
                        raise KeyError(
                            f"columns {missing} not in header of {self.path!r}"
                        )
                names = [c for c in self.keep if c in pos]
                idxs = [pos[c] for c in names]
                _plan_metrics(len(names), len(header) - len(names))
            cols: list[list[str]] = [[] for _ in names]
            n = 0
            for row in reader:
                w = len(row)
                for out, i in enumerate(idxs):
                    cols[out].append(row[i] if i < w else "")
                n += 1
                if n == self.block_rows:
                    yield Block(
                        {h: np.array(c, dtype=object) for h, c in zip(names, cols)}
                    )
                    cols = [[] for _ in names]
                    n = 0
            if n:
                yield Block(
                    {h: np.array(c, dtype=object) for h, c in zip(names, cols)}
                )

    def count_rows(self) -> int:
        """Row count without building cell arrays (cheap sizing pre-pass)."""
        with open(self.path, newline="", encoding="utf-8") as f:
            reader = csv.reader(f, delimiter=self.delimiter)
            if next(reader, None) is None:
                return 0
            return sum(1 for _ in reader)


@dataclasses.dataclass(frozen=True)
class JSONDatasource:
    """JSON-lines (streamed line-by-line) or a top-level array (parsed in one
    go — JSON arrays aren't incrementally parseable with the stdlib — but
    still emitted and processed block-at-a-time downstream)."""

    path: str
    block_rows: int
    iterator: str | None = None
    keep: tuple[str, ...] | None = None  # tolerant projection pushdown

    def with_columns(self, keep, strict: bool) -> "JSONDatasource | None":
        if strict:
            # per-record schemas: strictness is a whole-stream property the
            # executor's union validation pass owns, not a read-time check
            return None
        return dataclasses.replace(self, keep=tuple(keep))

    def read_tasks(self) -> list[ReadTask]:
        return [ReadTask(read=self._blocks, name=self.path)]

    def _blocks(self) -> Iterator[Block]:
        with open(self.path, encoding="utf-8") as f:
            head = f.read(1)
            f.seek(0)
            if head == "[":
                records = json.load(f)
                yield from self._chunk(iter(records))
            else:
                yield from self._chunk(
                    json.loads(line) for line in f if line.strip()
                )

    def _chunk(self, parsed) -> Iterator[Block]:
        buf: list = []
        for rec in parsed:
            rows = expand_iterator(rec, self.iterator)
            if self.keep is not None:
                # pre-fill with "" so a record carrying none of the kept
                # keys still contributes a row (the union-fill Project
                # downstream would have produced exactly this block)
                rows = [
                    {k: r.get(k, "") for k in self.keep} for r in rows
                ]
            buf.extend(rows)
            while len(buf) >= self.block_rows:
                yield Block.from_records(buf[: self.block_rows])
                buf = buf[self.block_rows :]
        if buf:
            yield Block.from_records(buf)

    def count_rows(self) -> int:
        """Record count without building columns."""
        n = 0
        with open(self.path, encoding="utf-8") as f:
            head = f.read(1)
            f.seek(0)
            if head == "[":
                parsed = iter(json.load(f))
            else:
                parsed = (json.loads(line) for line in f if line.strip())
            for rec in parsed:
                n += len(expand_iterator(rec, self.iterator))
        return n


@dataclasses.dataclass(frozen=True)
class GlobDatasource:
    """Multi-file source: one shard (ReadTask) per matching file, in sorted
    path order.  Shards may have heterogeneous schemas; downstream
    ``project`` fills the union with empty strings."""

    pattern: str
    block_rows: int
    fmt: str = "csv"
    iterator: str | None = None
    delimiter: str | None = None
    keep: tuple[str, ...] | None = None  # tolerant pushdown into each shard

    def with_columns(self, keep, strict: bool) -> "GlobDatasource | None":
        if strict:
            return None  # shards may have heterogeneous schemas
        return dataclasses.replace(self, keep=tuple(keep))

    def read_tasks(self) -> list[ReadTask]:
        return [t for s in self._shards() for t in s.read_tasks()]

    def count_rows(self) -> int:
        return sum(s.count_rows() for s in self._shards())

    def _shards(self) -> list["Datasource"]:
        paths = sorted(_glob.glob(self.pattern))
        if not paths:
            # a typo'd path must fail loudly like the eager loader's open(),
            # not produce an empty KG
            raise FileNotFoundError(f"no files match source glob {self.pattern!r}")
        shards = [
            make_datasource(
                path, self.fmt, self.block_rows, self.iterator, self.delimiter
            )
            for path in paths
        ]
        if self.keep is not None:
            shards = [
                s.with_columns(self.keep, strict=False) or s for s in shards
            ]
        return shards


@dataclasses.dataclass(frozen=True)
class TableDatasource:
    """In-memory columnar table, chunked — the ``tables=`` bypass used by
    tests and by callers that already hold the data."""

    columns: dict[str, np.ndarray]
    block_rows: int
    keep: tuple[str, ...] | None = None
    strict: bool = True

    def with_columns(self, keep, strict: bool) -> "TableDatasource":
        return dataclasses.replace(self, keep=tuple(keep), strict=strict)

    def read_tasks(self) -> list[ReadTask]:
        return [ReadTask(read=self._blocks, name="<table>")]

    def _view(self) -> dict[str, np.ndarray]:
        if self.keep is None:
            return self.columns
        if self.strict:
            missing = [c for c in self.keep if c not in self.columns]
            if missing:
                raise KeyError(f"columns {missing} not in table source")
        view = {c: self.columns[c] for c in self.keep if c in self.columns}
        _plan_metrics(len(view), len(self.columns) - len(view))
        return view

    def _blocks(self) -> Iterator[Block]:
        view = self._view()
        for start in range(0, self.count_rows(), self.block_rows):
            yield Block(
                {k: v[start : start + self.block_rows] for k, v in view.items()}
            )

    def count_rows(self) -> int:
        if not self.columns:
            return 0
        return len(next(iter(self.columns.values())))


_GLOB_CHARS = ("*", "?", "[")


def is_sharded_path(path: str) -> bool:
    """True when ``path`` is a glob pattern (and not a literal file that
    happens to contain glob metacharacters, e.g. ``data[v2]/child.csv``)."""
    return any(c in path for c in _GLOB_CHARS) and not os.path.exists(path)


def make_datasource(
    path: str,
    fmt: str,
    block_rows: int,
    iterator: str | None = None,
    delimiter: str | None = None,
) -> Datasource:
    """fmt + path -> datasource; glob patterns shard into per-file tasks."""
    if is_sharded_path(path):
        return GlobDatasource(
            pattern=path, block_rows=block_rows, fmt=fmt, iterator=iterator,
            delimiter=delimiter,
        )
    if fmt == "csv":
        return CSVDatasource(
            path=path, block_rows=block_rows, delimiter=delimiter or ","
        )
    if fmt == "tsv":
        return CSVDatasource(
            path=path, block_rows=block_rows, delimiter=delimiter or "\t"
        )
    if fmt == "json":
        return JSONDatasource(path=path, block_rows=block_rows, iterator=iterator)
    raise ValueError(f"unsupported source format {fmt!r}")
