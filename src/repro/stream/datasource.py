"""Chunked datasources: CSV/TSV, JSON(-lines), and glob-sharded multi-file.

A :class:`Datasource` exposes ``read_tasks()`` — one :class:`ReadTask` per
shard (file).  A ReadTask is a zero-arg callable yielding Blocks of at most
``block_rows`` rows; the physical executor runs the tasks in order behind a
bounded prefetch queue.  Re-invoking a task re-reads the shard, which is what
lets the engine replay a predicate after a hash-table overflow without ever
caching the source in memory.
"""

from __future__ import annotations

import csv
import dataclasses
import glob as _glob
import json
import os
from typing import Callable, Iterator, Protocol

import numpy as np

from repro.data.sources import expand_iterator
from repro.stream.block import Block


@dataclasses.dataclass(frozen=True)
class ReadTask:
    read: Callable[[], Iterator[Block]]
    name: str = ""


class Datasource(Protocol):
    def read_tasks(self) -> list[ReadTask]: ...


@dataclasses.dataclass(frozen=True)
class CSVDatasource:
    """Streaming CSV/TSV reader: never holds more than one block of rows.

    Rows shorter than the header are right-padded with ""; extra cells
    beyond the header are dropped (the eager loader crashes on both).
    """

    path: str
    block_rows: int
    delimiter: str = ","

    def read_tasks(self) -> list[ReadTask]:
        return [ReadTask(read=self._blocks, name=self.path)]

    def _blocks(self) -> Iterator[Block]:
        with open(self.path, newline="", encoding="utf-8") as f:
            reader = csv.reader(f, delimiter=self.delimiter)
            header = next(reader, None)
            if header is None:
                return
            width = len(header)
            cols: list[list[str]] = [[] for _ in header]
            n = 0
            for row in reader:
                for i in range(width):
                    cols[i].append(row[i] if i < len(row) else "")
                n += 1
                if n == self.block_rows:
                    yield Block(
                        {h: np.array(c, dtype=object) for h, c in zip(header, cols)}
                    )
                    cols = [[] for _ in header]
                    n = 0
            if n:
                yield Block(
                    {h: np.array(c, dtype=object) for h, c in zip(header, cols)}
                )

    def count_rows(self) -> int:
        """Row count without building cell arrays (cheap sizing pre-pass)."""
        with open(self.path, newline="", encoding="utf-8") as f:
            reader = csv.reader(f, delimiter=self.delimiter)
            if next(reader, None) is None:
                return 0
            return sum(1 for _ in reader)


@dataclasses.dataclass(frozen=True)
class JSONDatasource:
    """JSON-lines (streamed line-by-line) or a top-level array (parsed in one
    go — JSON arrays aren't incrementally parseable with the stdlib — but
    still emitted and processed block-at-a-time downstream)."""

    path: str
    block_rows: int
    iterator: str | None = None

    def read_tasks(self) -> list[ReadTask]:
        return [ReadTask(read=self._blocks, name=self.path)]

    def _blocks(self) -> Iterator[Block]:
        with open(self.path, encoding="utf-8") as f:
            head = f.read(1)
            f.seek(0)
            if head == "[":
                records = json.load(f)
                yield from self._chunk(iter(records))
            else:
                yield from self._chunk(
                    json.loads(line) for line in f if line.strip()
                )

    def _chunk(self, parsed) -> Iterator[Block]:
        buf: list = []
        for rec in parsed:
            buf.extend(expand_iterator(rec, self.iterator))
            while len(buf) >= self.block_rows:
                yield Block.from_records(buf[: self.block_rows])
                buf = buf[self.block_rows :]
        if buf:
            yield Block.from_records(buf)

    def count_rows(self) -> int:
        """Record count without building columns."""
        n = 0
        with open(self.path, encoding="utf-8") as f:
            head = f.read(1)
            f.seek(0)
            if head == "[":
                parsed = iter(json.load(f))
            else:
                parsed = (json.loads(line) for line in f if line.strip())
            for rec in parsed:
                n += len(expand_iterator(rec, self.iterator))
        return n


@dataclasses.dataclass(frozen=True)
class GlobDatasource:
    """Multi-file source: one shard (ReadTask) per matching file, in sorted
    path order.  Shards may have heterogeneous schemas; downstream
    ``project`` fills the union with empty strings."""

    pattern: str
    block_rows: int
    fmt: str = "csv"
    iterator: str | None = None
    delimiter: str | None = None

    def read_tasks(self) -> list[ReadTask]:
        return [t for s in self._shards() for t in s.read_tasks()]

    def count_rows(self) -> int:
        return sum(s.count_rows() for s in self._shards())

    def _shards(self) -> list["Datasource"]:
        paths = sorted(_glob.glob(self.pattern))
        if not paths:
            # a typo'd path must fail loudly like the eager loader's open(),
            # not produce an empty KG
            raise FileNotFoundError(f"no files match source glob {self.pattern!r}")
        return [
            make_datasource(
                path, self.fmt, self.block_rows, self.iterator, self.delimiter
            )
            for path in paths
        ]


@dataclasses.dataclass(frozen=True)
class TableDatasource:
    """In-memory columnar table, chunked — the ``tables=`` bypass used by
    tests and by callers that already hold the data."""

    columns: dict[str, np.ndarray]
    block_rows: int

    def read_tasks(self) -> list[ReadTask]:
        return [ReadTask(read=self._blocks, name="<table>")]

    def _blocks(self) -> Iterator[Block]:
        for start in range(0, self.count_rows(), self.block_rows):
            yield Block(
                {k: v[start : start + self.block_rows] for k, v in self.columns.items()}
            )

    def count_rows(self) -> int:
        if not self.columns:
            return 0
        return len(next(iter(self.columns.values())))


_GLOB_CHARS = ("*", "?", "[")


def is_sharded_path(path: str) -> bool:
    """True when ``path`` is a glob pattern (and not a literal file that
    happens to contain glob metacharacters, e.g. ``data[v2]/child.csv``)."""
    return any(c in path for c in _GLOB_CHARS) and not os.path.exists(path)


def make_datasource(
    path: str,
    fmt: str,
    block_rows: int,
    iterator: str | None = None,
    delimiter: str | None = None,
) -> Datasource:
    """fmt + path -> datasource; glob patterns shard into per-file tasks."""
    if is_sharded_path(path):
        return GlobDatasource(
            pattern=path, block_rows=block_rows, fmt=fmt, iterator=iterator,
            delimiter=delimiter,
        )
    if fmt == "csv":
        return CSVDatasource(
            path=path, block_rows=block_rows, delimiter=delimiter or ","
        )
    if fmt == "tsv":
        return CSVDatasource(
            path=path, block_rows=block_rows, delimiter=delimiter or "\t"
        )
    if fmt == "json":
        return JSONDatasource(path=path, block_rows=block_rows, iterator=iterator)
    raise ValueError(f"unsupported source format {fmt!r}")
