"""The Block: a fixed-row columnar chunk, the unit of streaming execution.

Every dataset is a sequence of Blocks; operators transform one Block at a
time, so peak host memory is O(block_rows), not O(table_rows).  Columns are
1-D numpy arrays of equal length — ``object`` (string) columns straight off
a reader, ``int32`` columns once dictionary-encoded.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping

import numpy as np


@dataclasses.dataclass(frozen=True)
class Block:
    columns: dict[str, np.ndarray]

    @property
    def n_rows(self) -> int:
        if not self.columns:
            return 0
        return len(next(iter(self.columns.values())))

    @property
    def schema(self) -> tuple[str, ...]:
        return tuple(self.columns)

    def select(self, names: Iterable[str], fill: str | None = "") -> "Block":
        """Project to ``names``.  Absent columns are filled with ``fill`` so
        heterogeneous shards (multi-file JSON, ragged records) line up;
        ``fill=None`` makes the projection strict (KeyError on a missing
        column — the right mode for fixed-schema sources, where a missing
        name is a mapping typo, not heterogeneity)."""
        n = self.n_rows
        out = {}
        for name in names:
            col = self.columns.get(name)
            if col is None:
                if fill is None:
                    raise KeyError(
                        f"column {name!r} not in block "
                        f"(available: {list(self.columns)})"
                    )
                col = np.full(n, fill, dtype=object)
            out[name] = col
        return Block(out)

    def slice(self, start: int, end: int) -> "Block":
        return Block({k: v[start:end] for k, v in self.columns.items()})

    @staticmethod
    def concat(blocks: list["Block"]) -> "Block":
        """Column union across blocks (heterogeneous shards fill missing
        cells with "", matching :meth:`select`)."""
        if not blocks:
            return Block({})
        names: dict[str, None] = {}
        for b in blocks:
            for k in b.columns:
                names.setdefault(k, None)
        return Block(
            {
                k: np.concatenate(
                    [
                        b.columns.get(k, np.full(b.n_rows, "", dtype=object))
                        for b in blocks
                    ]
                )
                for k in names
            }
        )

    @staticmethod
    def from_records(records: list[Mapping]) -> "Block":
        """Rows -> columns with key union across records; missing cells are
        empty strings.  Delegates to the eager loader's helper so streamed
        and eager JSON ingestion share one definition of record semantics."""
        from repro.data.sources import records_to_columns

        return Block(records_to_columns(records))
