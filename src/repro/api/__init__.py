"""One query API over every store transport.

``connect(target)`` returns a :class:`Session` with the same seven
methods — ``query`` / ``explain`` / ``insert`` / ``delete`` /
``compact`` / ``metrics`` / ``close`` — whether the target is

* an in-process store object (:class:`~repro.kg.store.TripleStore` or
  :class:`~repro.live.delta.LiveStore`),
* a ``.kgz`` snapshot path (full or delta chain; opened mutable by
  default, immutable with ``read_only=True``), or
* a running :mod:`repro.serve.server` at ``"host:port"``.

``query`` always answers with a :class:`QueryResult`; failures always
raise the typed :mod:`repro.api.errors` hierarchy (same classes both
sides of the wire).  A local session runs the same planner/executor
pipeline the server runs — including the small-batch fast path — so
results, ordering, and error semantics are identical across transports;
the tests assert this parity property directly.

Migration: ``repro.kg.query.solve`` / ``solve_text`` and
``repro.serve.client.Client`` remain as thin shims over this module —
existing callers keep working; new code should ``connect`` here.
"""

from __future__ import annotations

import dataclasses
import os
import re
import time

from repro.api.errors import (  # noqa: F401 — the API's error surface
    BadRequestError,
    KGError,
    ProtocolError,
    QueryParseError,
    ReadOnlyError,
    ServerError,
    error_from_reply,
)

_HOST_PORT = re.compile(r"^(?P<host>[\w.\-]+):(?P<port>\d{1,5})$")


@dataclasses.dataclass
class QueryResult:
    """One query's decoded answer, identical across transports.

    ``rows`` are tuples of rendered N-Triples terms in ``vars`` order,
    ``None`` for unbound (OPTIONAL-miss / UNION-arm) cells, plain ints
    for aggregate (COUNT) columns — the ones named in ``agg_vars``.
    ``n_total`` reports the full solution count even when a ``limit``
    capped the decoded rows.  ``raw`` carries the wire reply on a remote
    session (None locally)."""

    vars: tuple[str, ...]
    rows: list[tuple]
    n_total: int
    agg_vars: tuple[str, ...] = ()
    latency_ms: float = 0.0
    batch_size: int = 1
    raw: dict | None = None

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def to_dict(self) -> dict:
        """The wire-reply shape (what a remote server would answer)."""
        d = {
            "vars": list(self.vars),
            "rows": [list(r) for r in self.rows],
            "n_total": self.n_total,
            "batch_size": self.batch_size,
            "latency_ms": round(self.latency_ms, 3),
        }
        if self.agg_vars:
            d["agg_vars"] = list(self.agg_vars)
        return d


class Session:
    """The transport-independent surface; ``connect`` hands back one of
    the two concrete sessions below."""

    def query(self, text: str, limit: int | None = None) -> QueryResult:
        raise NotImplementedError

    def explain(self, text: str) -> str:
        raise NotImplementedError

    def insert(self, triples) -> dict:
        raise NotImplementedError

    def delete(self, triples) -> dict:
        raise NotImplementedError

    def compact(self) -> dict:
        raise NotImplementedError

    def metrics(self) -> dict:
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _check_limit(limit) -> None:
    if limit is not None and (
        not isinstance(limit, int) or isinstance(limit, bool) or limit < 0
    ):
        raise BadRequestError("'limit' must be a non-negative integer")


def _check_triples(triples) -> list[tuple]:
    ts = [tuple(t) for t in triples] if isinstance(triples, (list, tuple)) else None
    if (
        not ts
        or not all(
            len(t) == 3 and all(isinstance(x, str) for x in t) for t in ts
        )
    ):
        raise BadRequestError(
            "'triples' must be a non-empty list of [s, p, o] "
            "term-string triples"
        )
    return ts


class LocalSession(Session):
    """In-process execution over a store object — the same fused
    planner/executor pipeline (and small-batch fast path) the server
    dispatches through, at batch size 1.  Mutations need a
    :class:`~repro.live.delta.LiveStore`; over a plain
    :class:`~repro.kg.store.TripleStore` (or with ``read_only=True``)
    they raise :class:`ReadOnlyError` exactly like a read-only server."""

    def __init__(self, store, read_only: bool = False):
        self.store = store
        # a live store carries (base, view); a plain TripleStore is
        # immutable by construction — same duck test as kg.query.solve
        self._live = store if (
            hasattr(store, "view") and hasattr(store, "base")
        ) else None
        self.read_only = read_only or self._live is None

    def _base(self):
        return self._live.base if self._live is not None else self.store

    def _parse(self, text: str):
        from repro.serve import algebra

        if not isinstance(text, str):
            raise BadRequestError("missing 'query'")
        try:
            return algebra.parse_select(text)
        except ValueError as e:
            raise QueryParseError(str(e)) from e

    def execute(self, q):
        """Low-level single-query execute: the parsed
        :class:`~repro.serve.algebra.SelectQuery` through the planner/
        executor (overlay view captured for a live store), answered as
        the raw padded :class:`~repro.serve.exec.BatchResult`.  This is
        the one local execution path — ``query`` and the legacy
        ``kg.query.solve`` shim both come through here."""
        from repro.serve.exec import get_executor

        ex = get_executor(self._base())
        view = self._live.view() if self._live is not None else None
        return ex.execute(ex.plan(q), [q], view=view)

    def query(
        self, text: str, limit: int | None = None, *, parsed=None
    ) -> QueryResult:
        """``parsed`` short-circuits the parse with an already-built
        :class:`~repro.serve.algebra.SelectQuery` — the shard fan-out
        hands each in-process backend the query it parsed once for
        routing, instead of re-parsing the text on every shard."""
        _check_limit(limit)
        q = parsed if parsed is not None else self._parse(text)
        t0 = time.perf_counter_ns()
        res = self.execute(q)
        lat_ms = (time.perf_counter_ns() - t0) / 1e6
        return QueryResult(
            vars=tuple(res.vars),
            rows=res.rows(0, limit=limit),
            n_total=res.n(0),
            agg_vars=tuple(res.agg_vars),
            latency_ms=lat_ms,
        )

    def explain(self, text: str) -> str:
        from repro.serve.exec import get_executor

        q = self._parse(text)
        return get_executor(self._base()).plan(q).explain()

    def _writable(self):
        if self.read_only:
            raise ReadOnlyError("store is read-only: mutation rejected")
        return self._live

    def insert(self, triples) -> dict:
        live = self._writable()
        added = live.insert(_check_triples(triples))
        return {
            "inserted": added,
            "n_total": live.n_triples,
            "generation": live.generation,
        }

    def delete(self, triples) -> dict:
        live = self._writable()
        deleted, tombstoned = live.delete(_check_triples(triples))
        return {
            "deleted": deleted,
            "tombstoned": tombstoned,
            "n_total": live.n_triples,
            "generation": live.generation,
        }

    def compact(self) -> dict:
        live = self._writable()
        t0 = time.perf_counter_ns()
        live.compact()
        return {
            "compacted": True,
            "compact_ms": round((time.perf_counter_ns() - t0) / 1e6, 3),
            "n_total": live.n_triples,
            "generation": live.generation,
        }

    def metrics(self) -> dict:
        from repro.obs import get_registry

        return {"metrics": get_registry().snapshot(), "signatures": {}}


class RemoteSession(Session):
    """A socket client to a running server, answers normalized into the
    same :class:`QueryResult` / typed-error surface as a local session.
    (The transport lives in :mod:`repro.serve.client`, imported lazily —
    ``repro.api`` stays importable below the serve layer.)"""

    def __init__(
        self, host: str, port: int, retry_s: float = 0.0, timeout: float = 30.0
    ):
        from repro.serve.client import connect as _wire_connect

        self._c = _wire_connect(host, port, retry_s=retry_s, timeout=timeout)

    def query(self, text: str, limit: int | None = None) -> QueryResult:
        resp = self._c.query(text, limit=limit)
        return QueryResult(
            vars=tuple(resp.get("vars", ())),
            rows=[tuple(r) for r in resp.get("rows", ())],
            n_total=int(resp.get("n_total", 0)),
            agg_vars=tuple(resp.get("agg_vars", ())),
            latency_ms=float(resp.get("latency_ms", 0.0)),
            batch_size=int(resp.get("batch_size", 1)),
            raw=resp,
        )

    def explain(self, text: str) -> str:
        return self._c.explain(text)

    def insert(self, triples) -> dict:
        return self._c.insert(triples)

    def delete(self, triples) -> dict:
        return self._c.delete(triples)

    def compact(self) -> dict:
        return self._c.compact()

    def metrics(self) -> dict:
        return self._c.metrics()

    def close(self) -> None:
        self._c.close()


def connect(
    target,
    read_only: bool = False,
    retry_s: float = 0.0,
    timeout: float = 30.0,
) -> Session:
    """Open a :class:`Session` on anything query-shaped.

    * a store object → :class:`LocalSession` over it as-is;
    * ``"host:port"`` (when no such file exists) → :class:`RemoteSession`
      (``retry_s`` keeps retrying the TCP connect — the CI smoke path);
    * a shard-manifest path (``rdfize --shards N`` output) →
      :class:`~repro.shard.coordinator.ShardSession` over every shard
      store, with scatter/gather merging that answers byte-identically
      to the unsharded store;
    * a ``.kgz`` path → :class:`LocalSession`; mutable
      (:class:`~repro.live.delta.LiveStore` over the loaded chain, delta
      snapshots replayed) unless ``read_only=True``, which opens the
      immutable cached store.
    """
    if not isinstance(target, (str, os.PathLike)):
        if not (hasattr(target, "n_triples") and hasattr(target, "decode_term")):
            raise BadRequestError(
                f"cannot connect to {type(target).__name__}: expected a "
                "store object, a .kgz path, a shard manifest, or 'host:port'"
            )
        return LocalSession(target, read_only=read_only)
    target = os.fspath(target)
    m = _HOST_PORT.match(target)
    if m and not os.path.exists(target):
        return RemoteSession(
            m.group("host"), int(m.group("port")),
            retry_s=retry_s, timeout=timeout,
        )
    from repro.kg import persist

    if persist.is_manifest(target):
        from repro.shard.coordinator import ShardSession, open_shard_group

        return ShardSession(open_shard_group(target, read_only=read_only))
    if read_only:
        return LocalSession(persist.open_store(target), read_only=True)
    return LocalSession(persist.load_chain(target))


def _peek_schemas(plan, data_root: str) -> "dict[str, tuple[str, ...]]":
    """Header peek for fixed-schema CSV/TSV sources on disk, so the
    explain tree can show *pruned* columns, not just kept ones.  Sources
    that are missing, globbed, or schemaless (JSON) are simply omitted —
    explain must work before the data exists."""
    import csv

    from repro.rml.model import parse_source_key
    from repro.stream.datasource import is_sharded_path

    schemas: dict[str, tuple[str, ...]] = {}
    for skey in plan.sources:
        fmt, path, _ = parse_source_key(skey)
        if fmt not in ("csv", "tsv") or is_sharded_path(path):
            continue
        full = path if os.path.isabs(path) else os.path.join(data_root, path)
        if not os.path.exists(full):
            continue
        with open(full, newline="", encoding="utf-8") as f:
            delim = "\t" if fmt == "tsv" else ","
            header = next(csv.reader(f, delimiter=delim), None)
        if header:
            schemas[skey] = tuple(header)
    return schemas


def explain_mapping(mapping, data_root: str = ".") -> str:
    """Render the mapping planner's decisions as a stable human-readable
    tree — per-source kept/pruned columns, factored shared terms, join
    indexes, and the rule-group execution DAG — without running the
    engine.  ``mapping`` is a :class:`~repro.rml.model.MappingDocument`
    or a path to an RML ``.ttl`` file; when the CSV/TSV sources exist
    under ``data_root`` their headers are peeked so pruned columns are
    listed explicitly.  This is ``rdfize --explain-mapping``."""
    from repro.rml import parser
    from repro.rml.plan import build_plan, render_explain

    if isinstance(mapping, (str, os.PathLike)):
        doc = parser.parse_file(os.fspath(mapping))
    else:
        doc = mapping
    plan = build_plan(doc)
    return render_explain(plan, schemas=_peek_schemas(plan, data_root))
