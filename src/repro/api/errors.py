"""The query API's typed error hierarchy.

One set of exceptions for both transports: a local :class:`~repro.api.Session`
raises them directly, a remote one maps the server's structured wire
errors (``{"id": ..., "error": "...", "code": "..."}``) through
:func:`error_from_reply`.  Every class subclasses :class:`KGError`, which
subclasses ``RuntimeError`` — callers that predate the hierarchy (and
matched on ``RuntimeError`` / the ``"server error: ..."`` message) keep
working unchanged.

Wire error codes (documented in the README wire-protocol section):

========== ==========================  =====================================
code       exception                   meaning
========== ==========================  =====================================
parse      QueryParseError             the query text failed to parse
bad_request BadRequestError            malformed request (missing ``query``,
                                       bad ``limit``/``triples``, bad json)
read_only  ReadOnlyError               mutation op on a read-only store
internal   ServerError                 unexpected failure inside a handler
(none)     ServerError                 pre-code servers / unknown failures
========== ==========================  =====================================

``ProtocolError`` is client-side only: the transport itself broke (the
server hung up mid-request, or answered something that isn't a reply).
"""

from __future__ import annotations


class KGError(RuntimeError):
    """Base of every query-API error; ``code`` is the structured wire
    code when one applies (None for purely local failures)."""

    code: str | None = None

    def __init__(self, message: str, code: str | None = None):
        super().__init__(message)
        if code is not None:
            self.code = code


class QueryParseError(KGError):
    """The query text is not valid SPARQL-lite."""

    code = "parse"


class BadRequestError(KGError):
    """A structurally malformed request (missing ``query``, a negative
    ``limit``, non-triple ``triples``, unparseable json)."""

    code = "bad_request"


class ReadOnlyError(KGError):
    """A mutation (insert/delete/compact) against a read-only store."""

    code = "read_only"


class ServerError(KGError):
    """The server failed while handling the request (or answered an
    error without a structured code)."""

    code = "internal"


class ProtocolError(KGError, ConnectionError):
    """The wire transport itself broke: connection closed mid-request,
    or a reply that violates the protocol.  (Also a ``ConnectionError``
    for callers that predate the hierarchy.)"""

    code = "protocol"


_BY_CODE: dict[str, type[KGError]] = {
    cls.code: cls
    for cls in (QueryParseError, BadRequestError, ReadOnlyError, ServerError)
}


def error_from_reply(resp: dict) -> KGError:
    """The typed exception for an error reply off the wire.  The message
    keeps the historical ``"server error: ..."`` prefix — existing
    callers match on it."""
    code = resp.get("code")
    cls = _BY_CODE.get(code, ServerError)
    err = cls(f"server error: {resp.get('error')}")
    if code is not None:
        err.code = code
    return err
