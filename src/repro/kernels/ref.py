"""Pure-jnp oracles for every kernel in this package."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import hashing, hashset, naive


def hash_mix_ref(words: list[jnp.ndarray], salt: int = 0):
    """Oracle for the hash_mix kernel: the reference mixer itself."""
    return hashing.mix64(words, salt=salt)


def bucket_dedup_ref(
    keys_hi: jnp.ndarray,  # uint32[n_parts, part_len]
    keys_lo: jnp.ndarray,
    table_hi: jnp.ndarray,  # uint32[n_parts, cap]
    table_lo: jnp.ndarray,
    valid: jnp.ndarray,     # bool[n_parts, part_len]
):
    """Per-partition open-addressing insert via the reference HashSet.

    Partitions are independent, so the oracle simply folds the batched
    insert over the partition axis.
    """
    out_hi, out_lo, out_new = [], [], []
    for p in range(keys_hi.shape[0]):
        res = hashset.insert_masked(
            hashset.HashSet(table_hi[p], table_lo[p]),
            keys_hi[p],
            keys_lo[p],
            valid[p],
        )
        out_hi.append(res.table.hi)
        out_lo.append(res.table.lo)
        out_new.append(res.is_new)
    return (
        jnp.stack(out_hi),
        jnp.stack(out_lo),
        jnp.stack(out_new),
    )


def nested_join_ref(
    parent_keys: jnp.ndarray,
    parent_subjects: jnp.ndarray,
    child_keys: jnp.ndarray,
    max_matches: int,
):
    """Oracle for the blocked nested-loop join kernel."""
    r = naive.nested_loop_join(parent_keys, parent_subjects, child_keys, max_matches)
    return r.subjects, r.valid
