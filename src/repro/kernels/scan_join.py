"""Fused scan–join chain for small query batches (the serving fast path).

The general ``repro.serve.exec`` pipeline compiles a whole algebra tree
and amortizes its per-dispatch constant over thousands of same-signature
queries; at batch 1–64 that constant (operand marshalling, a ~30-leaf
pytree, one device→host sync per capacity counter) dominates.  This
module implements the dominant plan shapes — a ``Scan`` followed by up
to two inner ``BindJoin`` s under the standard ``Project → Sort →
Limit`` tail — as ONE fused unit with a deliberately tiny calling
convention:

* per reader: the packed split keys, the three index columns, and the
  primary-term row starts (all persistent store arrays);
* per query: one ``(n_readers, 3)`` int32 constants row, a validity
  flag, and a limit — packed into a single ``[batch, qrow_width]``
  matrix so each dispatch pays exactly one host→device transfer;
* out: the projected/sorted/limited binding columns, the row counts,
  and a single ``[n_stages]`` *max-needed* vector — one tiny transfer
  replaces the general path's per-capacity ``needed`` dict sync.

The chain math (:func:`chain_query`) is written once in pure jnp and
launched two ways:

* :func:`make_batched` with ``use_kernel=False`` — ``vmap`` over the
  batch, jitted by the caller.  This is the production path on CPU
  hosts (CI) where Pallas kernels only run interpreted.
* ``use_kernel=True`` — a Pallas kernel with ``grid=(batch,)``: every
  program runs one query's whole chain (binary-search range scans plus
  bind-join expansion) in a single kernel launch, following the
  ``bucket_dedup`` idiom (full-array operands, one output row block per
  program).  Selected when :func:`repro.compat.pallas_native` reports a
  backend that compiles Pallas natively; on CPU it is validated against
  the reference path under ``interpret=True`` in the tests.

All semantics match the general executor operator for operator: the
same packed-bound encoding (``-1`` wildcard packs below every real id,
``-2`` unknown constants produce empty ranges), the same seeded
primary-term bisection, the same packed cumsum/searchsorted bind-join
expansion, and the same stable full-column sort — so the fast path is
row-for-row identical to the general pipeline (property-tested).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

I32_MAX = np.int32(np.iinfo(np.int32).max)
UNBOUND = np.int32(-1)


# ---------------------------------------------------------------------------
# packed split-key binary search (the canonical definitions; the general
# executor re-exports these)
# ---------------------------------------------------------------------------


def pack_bound(q0, q1, q2, bits: int):
    """Pack a (possibly wildcarded) query bound into the store's split
    63-bit key space (see ``TripleStore.device_keys``): fields are shifted
    +1 so ``-1`` packs below every real id and ``I32_MAX`` clamps to the
    all-ones field above every id.  Returns int32 ``(hi, lo)`` with the
    low word sign-bit-biased, matching the store's key columns."""

    def f(x):
        # clip BEFORE the +1: I32_MAX + 1 would wrap in int32
        return jnp.clip(
            jnp.asarray(x), -1, (1 << bits) - 2
        ).astype(jnp.uint32) + jnp.uint32(1)

    f0, f1, f2 = f(q0), f(q1), f(q2)
    hi = (f0 << (2 * bits - 32)) | (f1 >> (32 - bits))
    lo = ((f1 & jnp.uint32((1 << (32 - bits)) - 1)) << bits) | f2
    return (
        hi.astype(jnp.int32),
        jax.lax.bitcast_convert_type(lo ^ jnp.uint32(0x80000000), jnp.int32),
    )


def lex_search2(khi, klo, qhi, qlo, upper: bool, rounds: int,
                lo_init=None, hi_init=None):
    """Binary search on the split-key pair: count of rows lex-< (or <= for
    ``upper``) the query bound.  ``rounds`` covers the widest possible
    [lo_init, hi_init) window (the full store by default; a seeded search
    passes a primary-term row range and correspondingly few rounds)."""
    n = khi.shape[0]
    if lo_init is None:
        lo_i = jnp.zeros(jnp.shape(qhi), jnp.int32)
        hi_i = jnp.full(jnp.shape(qhi), n, jnp.int32)
    else:
        lo_i = jnp.broadcast_to(lo_init, jnp.shape(qhi))
        hi_i = jnp.broadcast_to(hi_init, jnp.shape(qhi))

    def body(_, state):
        lo_i, hi_i = state
        mid = lo_i + ((hi_i - lo_i) >> 1)
        g = jnp.clip(mid, 0, max(n - 1, 0))
        mhi, mlo = khi[g], klo[g]
        tail = (mlo <= qlo) if upper else (mlo < qlo)
        before = (mhi < qhi) | ((mhi == qhi) & tail)
        open_ = lo_i < hi_i
        return (
            jnp.where(open_ & before, mid + 1, lo_i),
            jnp.where(open_ & ~before, mid, hi_i),
        )

    lo_i, _ = jax.lax.fori_loop(0, rounds, body, (lo_i, hi_i))
    return lo_i


# ---------------------------------------------------------------------------
# the static chain description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReaderSpec:
    """One pattern reader, resolved to its index order: ``src[j]`` says
    where index-order position ``j`` s bound comes from — ``('c', pos)``
    a constant from the reader's consts row, ``('b', col)`` a chain
    binding column, ``('w', 0)`` wildcard — and ``out`` lists the
    wildcard positions that bind new chain columns."""

    src: tuple[tuple[str, int], tuple[str, int], tuple[str, int]]
    out: tuple[tuple[int, int], ...]       # (index-order pos j, chain col)
    prim_rounds: int                       # seeded-bisection rounds


@dataclasses.dataclass(frozen=True)
class ChainSpec:
    """The whole fused chain: readers in pipeline order (reader ``i``
    reads constants row ``i``), the chain column count, and the
    projection ``out_cols`` (chain column per output variable, ``-1``
    for a selected variable no pattern ever binds)."""

    readers: tuple[ReaderSpec, ...]
    n_cols: int
    out_cols: tuple[int, ...]
    key_bits: int
    rounds: int                            # full-store bisection rounds
    store_n: int                           # base rows (>= 1)


def _reader_range(spec: ChainSpec, r: ReaderSpec, khi, klo, prim_start,
                  lo_q, hi_q, primary_q):
    """(start, end) rows inside the reader's bound range; seeded to the
    primary term's row range when the primary is bound."""
    qhi_l, qlo_l = pack_bound(*lo_q, spec.key_bits)
    qhi_h, qlo_h = pack_bound(*hi_q, spec.key_bits)
    if primary_q is None:
        lo = lex_search2(khi, klo, qhi_l, qlo_l, False, spec.rounds)
        hi = lex_search2(khi, klo, qhi_h, qlo_h, True, spec.rounds)
        return lo, hi
    T = prim_start.shape[0] - 1
    g0 = jnp.clip(primary_q, 0, max(T - 1, 0))
    lo0 = prim_start[g0]
    hi0 = prim_start[g0 + 1]
    lo = lex_search2(khi, klo, qhi_l, qlo_l, False, r.prim_rounds, lo0, hi0)
    hi = lex_search2(khi, klo, qhi_h, qlo_h, True, r.prim_rounds, lo0, hi0)
    # a negative primary (unknown constant / padded row / unmatched left
    # binding) is an empty range
    ok = primary_q >= 0
    zero = jnp.zeros_like(lo)
    return jnp.where(ok, lo, zero), jnp.where(ok, hi, zero)


def _bounds(r: ReaderSpec, consts_r, cols, shape):
    """The reader's (lo, hi) bound triples in index order, plus the
    primary operand (None = wildcard primary, full-store search)."""
    lo_q, hi_q = [], []
    for kind, arg in r.src:
        if kind == "c":
            v = jnp.broadcast_to(consts_r[arg], shape)
            lo_q.append(v)
            hi_q.append(v)
        elif kind == "b":
            v = cols[arg]
            lo_q.append(v)
            hi_q.append(v)
        else:
            lo_q.append(jnp.broadcast_to(jnp.int32(-1), shape))
            hi_q.append(jnp.broadcast_to(I32_MAX, shape))
    kind, arg = r.src[0]
    if kind == "c":
        primary_q = jnp.broadcast_to(consts_r[arg], shape)
    elif kind == "b":
        primary_q = cols[arg]
    else:
        primary_q = None
    return lo_q, hi_q, primary_q


# ---------------------------------------------------------------------------
# one query's whole chain (pure jnp — shared by both launch strategies)
# ---------------------------------------------------------------------------


def chain_query(
    spec: ChainSpec,
    caps: tuple[int, ...],
    operands: tuple,
    consts_q,       # int32[n_readers, 3]
    qvalid_q,       # bool scalar (False for batch-pad rows)
    qlimit_q,       # int32 scalar, -1 = no limit
):
    """Run the fused chain for one query.  ``operands[i]`` is reader
    ``i``'s ``(khi, klo, c0, c1, c2, prim_start)``; ``caps[i]`` its
    output capacity.  Returns ``(out_cols, n, needed)`` where ``needed``
    is the exact per-stage row requirement (the capacity feedback)."""
    cols: list = [None] * spec.n_cols

    r0 = spec.readers[0]
    khi, klo, c0, c1, c2, prim = operands[0]
    lo_q, hi_q, primary_q = _bounds(r0, consts_q[0], cols, ())
    lo, hi = _reader_range(spec, r0, khi, klo, prim, lo_q, hi_q, primary_q)
    count = jnp.where(qvalid_q, hi - lo, 0)
    needed = [count]
    cap = caps[0]
    r = jnp.clip(lo + jnp.arange(cap, dtype=jnp.int32), 0, spec.store_n - 1)
    valid = jnp.arange(cap) < count
    by_j = (c0, c1, c2)
    for j, col in r0.out:
        cols[col] = jnp.where(valid, by_j[j][r], UNBOUND)
    n = jnp.minimum(count, cap)

    for k in range(1, len(spec.readers)):
        rk = spec.readers[k]
        khi, klo, c0, c1, c2, prim = operands[k]
        cl = caps[k - 1]
        lo_q, hi_q, primary_q = _bounds(rk, consts_q[k], cols, (cl,))
        lo, hi = _reader_range(
            spec, rk, khi, klo, prim, lo_q, hi_q, primary_q
        )
        cnt = jnp.where(jnp.arange(cl) < n, hi - lo, 0)
        # packed expansion (same as the general executor): out row j
        # belongs to the left row whose count prefix-sum passes j
        cum = jnp.cumsum(cnt)
        total = cum[cl - 1]
        cap = caps[k]
        j = jnp.arange(cap, dtype=jnp.int32)
        rowidx = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
        rowc = jnp.clip(rowidx, 0, cl - 1)
        prev = jnp.where(rowc > 0, cum[rowc - 1], 0)
        kk = j - prev
        rr = jnp.clip(lo[rowc] + kk, 0, spec.store_n - 1)
        valid_out = j < jnp.minimum(total, cap)
        new_cols: list = [None] * spec.n_cols
        for col in range(spec.n_cols):
            if cols[col] is not None:
                new_cols[col] = jnp.where(
                    valid_out, cols[col][rowc], UNBOUND
                )
        by_j = (c0, c1, c2)
        for jj, col in rk.out:
            new_cols[col] = jnp.where(valid_out, by_j[jj][rr], UNBOUND)
        cols = new_cols
        needed.append(total)
        n = jnp.minimum(total, cap)

    # tail: Project -> Sort -> Limit, exactly the general pipeline's.
    # Sorting by EVERY output column makes the table a pure function of
    # the row multiset, so the direct variadic key sort reproduces the
    # general path's permutation sort row for row.
    cap = caps[-1]
    outs = []
    for col in spec.out_cols:
        if col >= 0 and cols[col] is not None:
            outs.append(cols[col])
        else:
            outs.append(jnp.full(cap, UNBOUND, jnp.int32))
    valid = jnp.arange(cap) < n
    if outs:
        keys = tuple(jnp.where(valid, c, I32_MAX) for c in outs)
        sorted_cols = jax.lax.sort(keys, num_keys=len(keys), is_stable=True)
        outs = [jnp.where(valid, c, UNBOUND) for c in sorted_cols]
    n = jnp.where(qlimit_q >= 0, jnp.minimum(n, qlimit_q), n)
    return tuple(outs), n, jnp.stack(needed)


# ---------------------------------------------------------------------------
# launch strategies
# ---------------------------------------------------------------------------


def qrow_width(n_readers: int) -> int:
    """Width of the packed per-query row: the flattened ``(n_readers, 3)``
    constants, the validity flag, and the limit.  One int32 matrix is the
    fast path's ENTIRE per-dispatch transfer — one host→device put
    instead of three (the generic device-put machinery, not the copy,
    is the batch-1 cost)."""
    return 3 * n_readers + 2


def _split_args(spec: ChainSpec, args):
    n_ops = 6 * len(spec.readers)
    operands = tuple(args[6 * i : 6 * i + 6] for i in range(len(spec.readers)))
    return operands, args[n_ops]


def _unpack_qrow(spec: ChainSpec, qrow):
    """Split one packed per-query row into (consts[R, 3], valid, limit)."""
    R = len(spec.readers)
    return qrow[: 3 * R].reshape(R, 3), qrow[3 * R] != 0, qrow[3 * R + 1]


def pallas_scan_join(
    spec: ChainSpec,
    caps: tuple[int, ...],
    *args,
    interpret: bool = True,
):
    """The Pallas launch: ``grid=(batch,)``, one program per query, the
    whole chain (range searches + bind-join expansion + tail) in one
    kernel.  Store operands are full-array inputs; per-query rows are
    ``(1, ...)`` blocks indexed by the program id; outputs are one row
    block per program.  ``interpret=True`` validates on CPU."""
    from jax.experimental import pallas as pl

    operands, qbuf = _split_args(spec, args)
    B = qbuf.shape[0]
    n_readers = len(spec.readers)
    n_out = len(spec.out_cols)
    cap = caps[-1]

    def kernel(*refs):
        in_refs = refs[: 6 * n_readers + 1]
        out_refs = refs[6 * n_readers + 1 :]
        ops = tuple(
            tuple(in_refs[6 * i + t][...] for t in range(6))
            for i in range(n_readers)
        )
        consts_q, qvalid_q, qlimit_q = _unpack_qrow(
            spec, in_refs[6 * n_readers][0]
        )
        outs, n, needed = chain_query(
            spec, caps, ops, consts_q, qvalid_q, qlimit_q
        )
        for t in range(n_out):
            out_refs[t][0] = outs[t]
        out_refs[n_out][0] = n
        out_refs[n_out + 1][0] = needed

    def full(arr):
        shape = arr.shape
        return pl.BlockSpec(shape, lambda b, _s=len(shape): (0,) * _s)

    in_specs = [full(a) for pack in operands for a in pack]
    in_specs += [
        pl.BlockSpec((1, qrow_width(n_readers)), lambda b: (b, 0)),
    ]
    out_specs = [pl.BlockSpec((1, cap), lambda b: (b, 0)) for _ in range(n_out)]
    out_specs += [
        pl.BlockSpec((1,), lambda b: (b,)),
        pl.BlockSpec((1, n_readers), lambda b: (b, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, cap), jnp.int32) for _ in range(n_out)
    ]
    out_shape += [
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B, n_readers), jnp.int32),
    ]
    res = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*[a for pack in operands for a in pack], qbuf)
    outs = tuple(res[:n_out])
    n = res[n_out]
    needed = res[n_out + 1]
    return outs, n, jnp.max(needed, axis=0)


def make_batched(
    spec: ChainSpec,
    caps: tuple[int, ...],
    use_kernel: bool = False,
    interpret: bool = True,
):
    """A jit-able batched entry point for one (chain, capacities) shape.

    Takes the flat argument list ``(*reader operands, qbuf[B,
    qrow_width])`` — the packed per-query rows, see :func:`qrow_width` —
    and returns ``(out_cols, n, needed_max)`` with ``needed_max``
    reduced over the batch on device — the caller syncs ONE tiny vector
    to drive capacity feedback."""
    if use_kernel:

        def batched(*args):
            return pallas_scan_join(
                spec, caps, *args, interpret=interpret
            )

        return batched

    def batched(*args):
        operands, qbuf = _split_args(spec, args)

        def single(qrow):
            consts_q, qvalid_q, qlimit_q = _unpack_qrow(spec, qrow)
            return chain_query(
                spec, caps, operands, consts_q, qvalid_q, qlimit_q
            )

        outs, n, needed = jax.vmap(single)(qbuf)
        return outs, n, jnp.max(needed, axis=0)

    return batched
