"""Pallas kernel: fused 64-bit triple-key mixing.

Elementwise VPU work: W int32 word-lanes are folded into a (hi, lo) uint32
pair per element (the PTT key).  Fusing the W-word mix into one kernel makes
a single HBM pass over the operand block instead of XLA's per-op traffic.

Grid: 1-D over element blocks.  Block shape (W, block_n) in VMEM; the word
count W is static so the fold is fully unrolled inside the kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashing

BLOCK_N = 4096


def _kernel(words_ref, hi_ref, lo_ref, *, n_words: int, salt: int):
    w = words_ref[...]  # (W, block)
    hi, lo = hashing.mix64([w[i] for i in range(n_words)], salt=salt)
    hi_ref[...] = hi
    lo_ref[...] = lo


def hash_mix(
    words: jnp.ndarray, salt: int = 0, block_n: int = BLOCK_N, interpret: bool = True
):
    """words: int32/uint32[W, n] -> (hi, lo) uint32[n].

    ``interpret=True`` runs the kernel body on CPU (this container); pass
    False on a real TPU.
    """
    n_words, n = words.shape
    pad = (-n) % block_n
    wp = jnp.pad(words, ((0, 0), (0, pad)))
    grid = (wp.shape[1] // block_n,)
    hi, lo = pl.pallas_call(
        lambda wr, hr, lr: _kernel(wr, hr, lr, n_words=n_words, salt=salt),
        grid=grid,
        in_specs=[pl.BlockSpec((n_words, block_n), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((wp.shape[1],), jnp.uint32),
            jax.ShapeDtypeStruct((wp.shape[1],), jnp.uint32),
        ],
        interpret=interpret,
    )(wp.astype(jnp.uint32))
    return hi[:n], lo[:n]
