"""Pallas TPU kernels for the paper's compute hot spots.

The paper optimizes two operations: duplicate elimination (PTT insert) and
the join (PJTT index join vs the naive nested loop).  Three kernels cover
them (each with a pure-jnp oracle in ``ref.py`` and a jitted public wrapper
in ``ops.py``):

* ``hash_mix``     — fused 64-bit triple-key mixing (elementwise, VPU).
* ``bucket_dedup`` — radix-partitioned open-addressing dedup-insert: keys are
  pre-partitioned by high hash bits so each partition's table slice fits in
  VMEM; the kernel runs the probe/claim loop entirely on-chip (one HBM pass
  over keys + one over the table, vs per-probe HBM touches for a naive port).
* ``nested_join``  — the paper's *baseline* nested-loop join as a blocked
  all-pairs kernel (child block resident in VMEM, parent tiles streamed).

``scan_join.py`` serves the query side: the fused scan/bind-join chain
behind the small-batch dispatch fast path (``repro.serve.fastpath``) —
one ``grid=(batch,)`` launch covering binary-search range scans and
bind-join expansion for 1–3 pattern plans, with a vmapped pure-jnp
reference formulation of the same chain math for CPU hosts.

Kernels target TPU (BlockSpec VMEM tiling) and are validated on CPU with
``interpret=True`` against the oracles across shape/dtype sweeps.
"""
