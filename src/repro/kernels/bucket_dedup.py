"""Pallas kernel: radix-partitioned open-addressing dedup-insert.

The TPU-native PTT insert (DESIGN.md §6.1).  A naive port of the paper's
hash table touches HBM per probe; instead the key stream is pre-partitioned
by a radix of the key hash so that partition p only ever probes table slice
p.  The kernel then runs the *entire* probe/claim loop with both the key
block and its table slice resident in VMEM:

    HBM traffic = one pass over the keys + one pass over the table slices
                  (vs Θ(probes) random HBM touches).

Grid: one step per partition.  Blocks: keys (1, part_len), table (1, cap).
The in-kernel algorithm is exactly ``hashset._insert_impl`` (same
arbitration, same first-wins semantics) applied to the VMEM-resident slice,
so the kernel is bit-identical to the reference oracle by construction —
asserted over shape sweeps in tests.

The table aliases input->output (in-place update, no copy).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashset


def _kernel(khi_ref, klo_ref, valid_ref, thi_ref, tlo_ref,
            out_thi_ref, out_tlo_ref, is_new_ref, ovf_ref):
    khi = khi_ref[0]
    klo = klo_ref[0]
    valid = valid_ref[0] != 0
    table = hashset.HashSet(thi_ref[0], tlo_ref[0])
    res = hashset.insert_masked(table, khi, klo, valid)
    out_thi_ref[0] = res.table.hi
    out_tlo_ref[0] = res.table.lo
    is_new_ref[0] = res.is_new.astype(jnp.uint32)
    ovf_ref[0, 0] = res.overflowed.astype(jnp.uint32)


def bucket_dedup(
    keys_hi: jnp.ndarray,   # uint32[n_parts, part_len]
    keys_lo: jnp.ndarray,
    valid: jnp.ndarray,     # bool[n_parts, part_len]
    table_hi: jnp.ndarray,  # uint32[n_parts, cap]
    table_lo: jnp.ndarray,
    interpret: bool = True,
):
    """Returns (table_hi', table_lo', is_new bool[n_parts, part_len],
    overflow bool[n_parts])."""
    n_parts, part_len = keys_hi.shape
    cap = table_hi.shape[1]
    grid = (n_parts,)
    row = lambda i: (i, 0)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, part_len), row),
            pl.BlockSpec((1, part_len), row),
            pl.BlockSpec((1, part_len), row),
            pl.BlockSpec((1, cap), row),
            pl.BlockSpec((1, cap), row),
        ],
        out_specs=[
            pl.BlockSpec((1, cap), row),
            pl.BlockSpec((1, cap), row),
            pl.BlockSpec((1, part_len), row),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_parts, cap), jnp.uint32),
            jax.ShapeDtypeStruct((n_parts, cap), jnp.uint32),
            jax.ShapeDtypeStruct((n_parts, part_len), jnp.uint32),
            jax.ShapeDtypeStruct((n_parts, 1), jnp.uint32),
        ],
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(keys_hi, keys_lo, valid.astype(jnp.uint32), table_hi, table_lo)
    thi, tlo, is_new, ovf = out
    return thi, tlo, is_new != 0, (ovf[:, 0] != 0)
