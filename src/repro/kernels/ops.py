"""Jitted public wrappers for the Pallas kernels.

``radix_dedup_insert`` is the production entry point for the PTT insert: it
owns the radix partitioning (keys -> partition of their hash, so duplicates
always meet in the same VMEM-resident table slice), invokes the bucket_dedup
kernel, and un-permutes the verdicts back to the caller's layout.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.hashing import EMPTY
from repro.kernels import bucket_dedup as _bucket
from repro.kernels import hash_mix as _mix
from repro.kernels import nested_join as _join

PART_SLACK = 4


class RadixTable(NamedTuple):
    """PTT physically laid out as (n_parts, cap_per_part) radix slices."""

    hi: jnp.ndarray
    lo: jnp.ndarray

    @property
    def n_parts(self) -> int:
        return self.hi.shape[0]


def make_radix_table(capacity_total: int, n_parts: int) -> RadixTable:
    cap = 1 << max(int(capacity_total / n_parts) - 1, 1).bit_length()
    return RadixTable(
        hi=jnp.full((n_parts, cap), EMPTY, jnp.uint32),
        lo=jnp.full((n_parts, cap), EMPTY, jnp.uint32),
    )


def _partition_of(key_hi: jnp.ndarray, n_parts: int) -> jnp.ndarray:
    # distinct salt from the hashset slot bits (key_lo) and the distributed
    # owner bits (0xA5A5A5A5)
    return (hashing.fmix32(key_hi ^ jnp.uint32(0x51ED270B)) % jnp.uint32(n_parts)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def radix_dedup_insert(
    table: RadixTable,
    key_hi: jnp.ndarray,
    key_lo: jnp.ndarray,
    valid: jnp.ndarray,
    interpret: bool = True,
):
    """Map-side combine -> partition -> kernel insert -> un-permute.

    Radix partitioning routes every copy of a key to the same partition, so
    under the paper's high-duplicate workloads a single hot key could
    overflow its partition.  The combiner (an intra-batch first-occurrence
    dedup, the shuffle-side analogue of MapReduce map-combine) forwards only
    one representative per distinct key; partition load is then governed by
    the *distinct*-key hash distribution, which is uniform.  In-batch
    duplicates inherit ``is_new=False`` from first-wins semantics directly.

    Returns (table', is_new bool[n], overflow bool[]).
    """
    from repro.core import naive as _naive

    n = key_hi.shape[0]
    n_parts = table.n_parts
    rep = _naive.sort_dedup_masked(key_hi, key_lo, valid).uniq_mask  # combiner
    part = _partition_of(key_hi, n_parts)
    part_len = max(PART_SLACK * ((n + n_parts - 1) // n_parts), 8)

    # bin representative lanes into (n_parts, part_len), overflow detected
    pv = jnp.where(rep, part, n_parts)
    order = jnp.argsort(pv, stable=True)
    sorted_part = pv[order]
    starts = jnp.searchsorted(sorted_part, jnp.arange(n_parts + 1, dtype=pv.dtype))
    rank = jnp.arange(n, dtype=jnp.int32) - starts[sorted_part].astype(jnp.int32)
    ok = (sorted_part < n_parts) & (rank < part_len)
    dest = jnp.where(ok, sorted_part.astype(jnp.int32) * part_len + rank, -1)
    bin_ovf = jnp.any((sorted_part < n_parts) & (rank >= part_len))

    send_index = jnp.full((n_parts * part_len,), -1, jnp.int32)
    send_index = send_index.at[jnp.where(ok, dest, n_parts * part_len)].set(
        order.astype(jnp.int32), mode="drop"
    )
    safe = jnp.clip(send_index, 0, n - 1)
    khi = jnp.where(send_index >= 0, key_hi[safe], jnp.uint32(EMPTY)).reshape(
        n_parts, part_len
    )
    klo = jnp.where(send_index >= 0, key_lo[safe], jnp.uint32(EMPTY)).reshape(
        n_parts, part_len
    )
    kval = (send_index >= 0).reshape(n_parts, part_len)

    thi, tlo, is_new_p, ovf_p = _bucket.bucket_dedup(
        khi, klo, kval, table.hi, table.lo, interpret=interpret
    )

    dest_by_lane = jnp.full((n,), -1, jnp.int32).at[order].set(dest)
    flat = is_new_p.reshape(-1)
    safe_d = jnp.clip(dest_by_lane, 0, flat.shape[0] - 1)
    # only representatives can be new; in-batch duplicates are False by the
    # combiner's first-wins ordering
    is_new = jnp.where(dest_by_lane >= 0, flat[safe_d], False) & rep & valid
    return (
        RadixTable(hi=thi, lo=tlo),
        is_new,
        jnp.any(ovf_p) | bin_ovf,
    )


@partial(jax.jit, static_argnames=("salt", "interpret"))
def fused_hash_mix(words: jnp.ndarray, salt: int = 0, interpret: bool = True):
    """words int32[W, n] -> (hi, lo) uint32[n] via the Pallas mixer."""
    return _mix.hash_mix(words, salt=salt, interpret=interpret)


@partial(jax.jit, static_argnames=("max_matches", "interpret"))
def blocked_nested_join(
    parent_keys, parent_subjects, child_keys, max_matches: int, interpret: bool = True
):
    """The naive-baseline join at full blocked throughput."""
    return _join.nested_join(
        parent_keys, parent_subjects, child_keys, max_matches, interpret=interpret
    )
