"""Pallas kernel: blocked nested-loop join (the paper's naive OJM baseline).

All-pairs equality join between child and parent join keys, shaped like a
GEMM: a block of child keys stays resident in VMEM while parent tiles are
streamed through the second grid dimension.  Matched parent subjects are
packed left-to-right (parent order) into a padded (m, K) output — the same
padded-ragged layout as the PJTT probe, so engine paths are interchangeable.

Grid: (child_blocks, parent_tiles); parent tiles iterate innermost, so the
output block and the per-row fill cursor act as sequential accumulators
(revision pattern: out index_map ignores the tile dim).

Comparisons = |child| × |parent| — the Θ(N_parent·N_child) the paper ascribes
to the naive engine; the kernel merely executes it at peak, it cannot beat
the PJTT's asymptotics (that is the paper's whole point).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 256
BLOCK_N = 1024
_PAD = -1  # python int: Pallas kernels may not capture traced constants


def _kernel(ck_ref, pk_ref, ps_ref, out_ref, cnt_ref, *, max_matches: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref[...], jnp.int32(_PAD))
        cnt_ref[...] = jnp.zeros_like(cnt_ref[...])

    ck = ck_ref[...]          # (bm,)
    pk = pk_ref[...]          # (bn,)
    ps = ps_ref[...]          # (bn,)
    bm, bn = ck.shape[0], pk.shape[0]
    K = max_matches

    eq = ck[:, None] == pk[None, :]               # (bm, bn) all-pairs compare
    rank = jnp.cumsum(eq, axis=1) - 1              # match rank within tile
    cur = cnt_ref[...]                             # (bm,) fill cursor
    col = cur[:, None] + rank
    write = eq & (col >= 0) & (col < K)

    out = out_ref[...]
    rows = jnp.broadcast_to(jnp.arange(bm)[:, None], (bm, bn))
    cols = jnp.where(write, col, K)                # K -> dropped
    out = out.at[rows, cols].set(
        jnp.broadcast_to(ps[None, :], (bm, bn)), mode="drop"
    )
    out_ref[...] = out
    cnt_ref[...] = cur + jnp.sum(eq, axis=1, dtype=jnp.int32)


def nested_join(
    parent_keys: jnp.ndarray,      # int32[n]  (>= 0; -1 reserved for padding)
    parent_subjects: jnp.ndarray,  # int32[n]
    child_keys: jnp.ndarray,       # int32[m]
    max_matches: int,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    interpret: bool = True,
):
    """Returns (subjects int32[m, K], valid bool[m, K], truncated bool[])."""
    n = parent_keys.shape[0]
    m = child_keys.shape[0]
    pad_m = (-m) % block_m
    pad_n = (-n) % block_n
    ck = jnp.pad(child_keys, (0, pad_m), constant_values=-1)
    pk = jnp.pad(parent_keys, (0, pad_n), constant_values=-1)
    ps = jnp.pad(parent_subjects, (0, pad_n), constant_values=-1)
    grid = (ck.shape[0] // block_m, pk.shape[0] // block_n)

    subjects, counts = pl.pallas_call(
        lambda *refs: _kernel(*refs, max_matches=max_matches),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, max_matches), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ck.shape[0], max_matches), jnp.int32),
            jax.ShapeDtypeStruct((ck.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(ck, pk, ps)

    subjects = subjects[:m]
    counts = counts[:m]
    offs = jnp.arange(max_matches, dtype=jnp.int32)[None, :]
    valid = (offs < counts[:, None]) & (subjects != jnp.int32(_PAD))
    truncated = jnp.any(counts > max_matches)
    return subjects, valid, truncated
