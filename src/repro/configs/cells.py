"""Cell builders: (architecture x input shape x mesh) -> lowerable spec.

A *cell* is one dry-run unit: a jit-able function plus fully-sharded
ShapeDtypeStruct arguments (no allocation).  ``jax.jit(fn).lower(*args)``
must succeed on the production meshes for every cell — that is deliverable
(e).  Shardings ride on the ShapeDtypeStructs via NamedSharding.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import recsys, transformer
from repro.models.gnn import common as gnn_common
from repro.models.gnn import equiformer, gat, meshgraphnet, nequip
from repro.train.optimizer import AdamW
from repro.train.trainer import make_train_step

KEY = jax.random.PRNGKey(0)


@dataclasses.dataclass
class CellSpec:
    name: str              # "<arch>/<shape>"
    kind: str              # train | prefill | decode | serve | retrieval
    fn: Callable           # to be jitted
    args: tuple            # pytrees of ShapeDtypeStruct (sharding attached)
    donate: tuple = ()
    note: str = ""


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=_ns(mesh, spec))


def _attach(shapes_tree, specs_tree, mesh):
    """Attach NamedShardings to an eval_shape'd pytree."""
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=_ns(mesh, p)),
        shapes_tree,
        specs_tree,
    )


def _dp(mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _all_axes(mesh):
    return tuple(mesh.axis_names)


# ----------------------------------------------------------------------- LM


def lm_train_cell(
    cfg: transformer.LMConfig, mesh, batch: int, seq: int,
    unroll_accum: bool = False,
) -> CellSpec:
    dp = _dp(mesh)
    opt = AdamW(lr=1e-4, weight_decay=0.1)
    accum = cfg.microbatches

    def loss(params, tokens, labels):
        return transformer.loss_fn(cfg, params, tokens, labels, dp)

    step = make_train_step(loss, opt, grad_accum=accum, unroll_accum=unroll_accum)

    pshape = jax.eval_shape(partial(transformer.init, cfg=cfg), KEY)
    pspecs = transformer.param_specs(cfg)
    params = _attach(pshape, pspecs, mesh)
    oshape = jax.eval_shape(opt.init, pshape)
    ostate = _attach(oshape, opt.state_specs(pspecs), mesh)
    if accum > 1:
        # microbatch accumulation: (accum, B/accum, S), scanned by the step
        tokens = _sds((accum, batch // accum, seq), jnp.int32, mesh, P(None, dp, None))
        labels = _sds((accum, batch // accum, seq), jnp.int32, mesh, P(None, dp, None))
    else:
        tokens = _sds((batch, seq), jnp.int32, mesh, P(dp, None))
        labels = _sds((batch, seq), jnp.int32, mesh, P(dp, None))
    return CellSpec(
        name=f"{cfg.name}/train",
        kind="train",
        fn=step,
        args=(params, ostate, tokens, labels),
        donate=(0, 1),
    )


def lm_prefill_cell(
    cfg: transformer.LMConfig, mesh, batch: int, seq: int,
    unroll_accum: bool = False,
) -> CellSpec:
    dp = _dp(mesh)

    def fn(params, tokens):
        return transformer.prefill(cfg, params, tokens, dp, unroll_chunks=unroll_accum)

    pshape = jax.eval_shape(partial(transformer.init, cfg=cfg), KEY)
    params = _attach(pshape, transformer.param_specs(cfg), mesh)
    tokens = _sds((batch, seq), jnp.int32, mesh, P(dp, None))
    return CellSpec(
        name=f"{cfg.name}/prefill", kind="prefill", fn=fn, args=(params, tokens)
    )


def lm_decode_cell(
    cfg: transformer.LMConfig, mesh, batch: int, ctx_len: int,
    serve_layout: bool = False,
) -> CellSpec:
    dp = _dp(mesh)
    # batch=1 (long_500k) cannot shard over the data axes; the serve-resident
    # TP layout REPLICATES the (tiny) token batch so weights never move —
    # each device contributes its 1/256 column slice and activations psum
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    cache_dp = dp if batch % dp_size == 0 else None
    # serve-resident TP: activations replicated (weights never move), but
    # the KV cache STAYS (batch->data, length->model) sharded
    bdp = None if serve_layout else cache_dp

    def fn(params, cache, tokens, pos):
        return transformer.decode_step(cfg, params, cache, tokens, pos, bdp)

    pshape = jax.eval_shape(partial(transformer.init, cfg=cfg), KEY)
    params = _attach(
        pshape, transformer.param_specs(cfg, serve=serve_layout), mesh
    )
    cshape = jax.eval_shape(partial(transformer.make_cache, cfg, batch, ctx_len))
    cache = _attach(cshape, transformer.cache_specs(cfg, cache_dp), mesh)
    tokens = _sds((batch, 1), jnp.int32, mesh, P(bdp, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=_ns(mesh, P()))
    note = ""
    if cfg.window is not None and ctx_len > cfg.window:
        note = f"SWA ring buffer: cache is O(window={cfg.window}), logical ctx {ctx_len}"
    return CellSpec(
        name=f"{cfg.name}/decode",
        kind="decode",
        fn=fn,
        args=(params, cache, tokens, pos),
        donate=(1,),
        note=note,
    )


# ---------------------------------------------------------------------- GNN

GNN_FAMILIES = {
    "gat-cora": (gat, gat.GATConfig),
    "meshgraphnet": (meshgraphnet, meshgraphnet.MGNConfig),
    "nequip": (nequip, nequip.NequIPConfig),
    "equiformer-v2": (equiformer, equiformer.EquiformerConfig),
}


def _graph_batch_sds(mesh, n, e, d_feat, task, n_graphs, edge_spec, node_spec):
    f32, i32 = jnp.float32, jnp.int32
    if task == "node_cls":
        labels = _sds((n,), i32, mesh, node_spec)
        lmask = _sds((n,), jnp.bool_, mesh, node_spec)
    else:
        labels = _sds((n_graphs,), f32, mesh, P())
        lmask = _sds((n_graphs,), jnp.bool_, mesh, P())
    return gnn_common.GraphBatch(
        node_feat=_sds((n, d_feat), f32, mesh, node_spec),
        positions=_sds((n, 3), f32, mesh, node_spec),
        edge_src=_sds((e,), i32, mesh, edge_spec),
        edge_dst=_sds((e,), i32, mesh, edge_spec),
        node_mask=_sds((n,), jnp.bool_, mesh, node_spec),
        edge_mask=_sds((e,), jnp.bool_, mesh, edge_spec),
        labels=labels,
        graph_id=_sds((n,), i32, mesh, node_spec),
        label_mask=lmask,
    )


def gnn_train_cell(
    arch: str, cfg, mesh, *, n, e, d_feat, task, n_classes=0, n_graphs=1,
    shard_edges=False, shape_name="",
) -> CellSpec:
    module, _ = GNN_FAMILIES[arch]
    opt = AdamW(lr=1e-3)

    def loss(params, batch):
        return module.loss_fn(params, cfg, batch, n_graphs)

    step = make_train_step(loss, opt)
    pshape = jax.eval_shape(partial(module.init, cfg=cfg), KEY)
    # GNN params are replicated (they are small next to graph data)
    params = _attach(pshape, jax.tree.map(lambda _: P(), pshape), mesh)
    oshape = jax.eval_shape(opt.init, pshape)
    ostate = _attach(oshape, jax.tree.map(lambda _: P(), oshape), mesh)
    if shard_edges:
        # pad the edge axis to the dp-axes product (padded edges masked);
        # channels take the 'model' axis inside the models (channel_shard)
        e = -(-e // 512) * 512
    edge_spec = P(_dp(mesh)) if shard_edges else P()
    node_spec = P()
    batch = _graph_batch_sds(
        mesh, n, e, d_feat, task, n_graphs, edge_spec, node_spec
    )
    return CellSpec(
        name=f"{arch}/{shape_name}",
        kind="train",
        fn=step,
        args=(params, ostate, batch),
        donate=(0, 1),
    )


# -------------------------------------------------------------------- recsys


def recsys_train_cell(cfg: recsys.WideDeepConfig, mesh, batch: int) -> CellSpec:
    dp = _dp(mesh)
    opt = AdamW(lr=1e-3)

    def loss(params, sp, de, y):
        return recsys.loss_fn(params, cfg, sp, de, y)

    step = make_train_step(loss, opt)
    pshape = jax.eval_shape(partial(recsys.init, cfg=cfg), KEY)
    params = _attach(pshape, recsys.param_specs(cfg), mesh)
    oshape = jax.eval_shape(opt.init, pshape)
    ostate = _attach(oshape, AdamW().state_specs(recsys.param_specs(cfg)), mesh)
    sp = _sds((batch, cfg.n_sparse, cfg.bag_size), jnp.int32, mesh, P(dp, None, None))
    de = _sds((batch, cfg.n_dense), jnp.float32, mesh, P(dp, None))
    y = _sds((batch,), jnp.int32, mesh, P(dp))
    return CellSpec(
        name=f"{cfg.name}/train", kind="train", fn=step,
        args=(params, ostate, sp, de, y), donate=(0, 1),
    )


def recsys_serve_cell(cfg: recsys.WideDeepConfig, mesh, batch: int, shape_name: str) -> CellSpec:
    dp = _dp(mesh)

    def fn(params, sp, de):
        return recsys.forward(params, cfg, sp, de)

    pshape = jax.eval_shape(partial(recsys.init, cfg=cfg), KEY)
    params = _attach(pshape, recsys.param_specs(cfg), mesh)
    sp = _sds((batch, cfg.n_sparse, cfg.bag_size), jnp.int32, mesh, P(dp, None, None))
    de = _sds((batch, cfg.n_dense), jnp.float32, mesh, P(dp, None))
    return CellSpec(
        name=f"{cfg.name}/{shape_name}", kind="serve", fn=fn, args=(params, sp, de)
    )


def recsys_retrieval_cell(
    cfg: recsys.WideDeepConfig, mesh, n_candidates: int
) -> CellSpec:
    def fn(params, sp, de, cand):
        return recsys.retrieval_scores(params, cfg, sp, de, cand)

    pshape = jax.eval_shape(partial(recsys.init, cfg=cfg), KEY)
    params = _attach(pshape, recsys.param_specs(cfg), mesh)
    sp = _sds((1, cfg.n_sparse, cfg.bag_size), jnp.int32, mesh, P())
    de = _sds((1, cfg.n_dense), jnp.float32, mesh, P())
    n_dev = mesh.devices.size
    n_candidates = -(-n_candidates // n_dev) * n_dev  # pad to the mesh size
    cand = _sds(
        (n_candidates, cfg.mlp[-1]), jnp.float32, mesh, P(_all_axes(mesh), None)
    )
    return CellSpec(
        name=f"{cfg.name}/retrieval_cand", kind="retrieval", fn=fn,
        args=(params, sp, de, cand),
    )


# ------------------------------------------------------------------ rdfizer


def rdfizer_shuffle_cell(mesh, n_keys: int) -> CellSpec:
    """The paper's own workload as a dry-run cell: one distributed
    shuffle-dedup step (PTT insert) across the whole mesh."""
    from repro.core import distributed

    axes = _all_axes(mesh)
    n_shards = mesh.devices.size
    cap = 1 << 22  # per-shard table slots

    table = distributed.ShardedPTT(
        hi=_sds((n_shards, cap), jnp.uint32, mesh, P(axes)),
        lo=_sds((n_shards, cap), jnp.uint32, mesh, P(axes)),
    )
    khi = _sds((n_keys,), jnp.uint32, mesh, P(axes))
    klo = _sds((n_keys,), jnp.uint32, mesh, P(axes))
    valid = _sds((n_keys,), jnp.bool_, mesh, P(axes))

    def fn(thi, tlo, hi, lo, v):
        t, is_new, ovf = distributed.distributed_insert(
            mesh, distributed.ShardedPTT(thi, tlo), hi, lo, v
        )
        return t.hi, t.lo, jnp.sum(is_new), ovf

    return CellSpec(
        name="rdfizer/shuffle_dedup", kind="rdfizer", fn=fn,
        args=(table.hi, table.lo, khi, klo, valid), donate=(0, 1),
        note="the paper's PTT insert at mesh scale",
    )
