"""gat-cora — GNN: 2 layers, 8 hidden, 8 heads, attention aggregation
[arXiv:1710.10903]."""

import dataclasses

from repro.models.gnn.gat import GATConfig


def config() -> GATConfig:
    return GATConfig(n_layers=2, d_hidden=8, n_heads=8)


def smoke_config() -> GATConfig:
    return dataclasses.replace(config(), d_in=32)
