"""mixtral-8x7b — MoE LM: 32L, d_model 4096, 32H GQA(kv=8), d_ff 14336,
8 experts top-2, sliding-window attention (4096) [arXiv:2401.04088].

The SWA window is what makes the long_500k decode cell sub-quadratic: the
KV cache is a ring buffer of 4096 slots regardless of logical position."""

import dataclasses

import jax.numpy as jnp

from repro.models.transformer import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="mixtral-8x7b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv=8,
        head_dim=128,
        d_ff=14336,
        vocab=32000,
        moe=True,
        n_experts=8,
        top_k=2,
        window=4096,
        microbatches=4,
        gated_act="silu",
        rope_theta=1_000_000.0,
    )


def smoke_config() -> LMConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=96, vocab=512, n_experts=4, top_k=2, window=8,
        dtype=jnp.float32, sequence_parallel=False, attn_chunk=None, microbatches=1,
    )
