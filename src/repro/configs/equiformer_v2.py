"""equiformer-v2 — SO(2)-eSCN equivariant graph attention: 12 layers,
128 channels, l_max 6, m_max 2, 8 heads [arXiv:2306.12059]."""

import dataclasses

from repro.models.gnn.equiformer import EquiformerConfig


def config() -> EquiformerConfig:
    return EquiformerConfig(
        n_layers=12, channels=128, l_max=6, m_max=2, n_heads=8
    )


def smoke_config() -> EquiformerConfig:
    return dataclasses.replace(
        config(), n_layers=2, channels=16, l_max=3, n_heads=4
    )
