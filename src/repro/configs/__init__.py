from repro.configs.registry import ARCHS, get_arch, list_cells  # noqa: F401
