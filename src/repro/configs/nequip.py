"""nequip — E(3)-equivariant GNN: 5 layers, 32 channels, l_max 2, 8 RBFs,
cutoff 5 [arXiv:2101.03164]."""

import dataclasses

from repro.models.gnn.nequip import NequIPConfig


def config() -> NequIPConfig:
    return NequIPConfig(n_layers=5, channels=32, l_max=2, n_rbf=8, cutoff=5.0)


def smoke_config() -> NequIPConfig:
    return dataclasses.replace(config(), n_layers=2, channels=8)
