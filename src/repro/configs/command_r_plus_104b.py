"""command-r-plus-104b — dense LM: 64L, d_model 12288, 96H GQA(kv=8),
d_ff 33792, vocab 256000, no bias, tied embeddings
[hf:CohereForAI/c4ai-command-r-plus]."""

import dataclasses

import jax.numpy as jnp

from repro.models.transformer import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="command-r-plus-104b",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv=8,
        head_dim=128,
        d_ff=33792,
        vocab=256000,
        microbatches=8,
        gated_act="silu",
        tie_embeddings=True,
        rope_theta=75_000_000.0,
    )


def smoke_config() -> LMConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=96, n_heads=6, n_kv=2, head_dim=16,
        d_ff=192, vocab=512, dtype=jnp.float32, sequence_parallel=False, attn_chunk=None, microbatches=1,
    )
