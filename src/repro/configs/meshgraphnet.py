"""meshgraphnet — GNN: 15 layers, d_hidden 128, sum aggregator, 2-layer MLPs
[arXiv:2010.03409]."""

import dataclasses

from repro.models.gnn.meshgraphnet import MGNConfig


def config() -> MGNConfig:
    return MGNConfig(n_layers=15, d_hidden=128, mlp_layers=2)


def smoke_config() -> MGNConfig:
    return dataclasses.replace(config(), n_layers=3, d_hidden=32, d_in=16)
