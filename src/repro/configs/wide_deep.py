"""wide-deep — recsys: 40 sparse fields, embed_dim 32, MLP 1024-512-256,
concat interaction [arXiv:1606.07792].  Vocab per field: 2^20 = 1,048,576
(hash-bucketed; power of two divides every production mesh)."""

import dataclasses

from repro.models.recsys import WideDeepConfig


def config() -> WideDeepConfig:
    return WideDeepConfig(
        n_sparse=40, embed_dim=32, vocab_per_field=1 << 20,
        n_dense=13, mlp=(1024, 512, 256),
    )


def dedup_config() -> WideDeepConfig:
    """The paper-technique variant: PTT-style dedup-gather on the id
    stream (cap = 1/4 of the stream, the duplicate-heavy regime)."""
    return dataclasses.replace(config(), dedup_cap=None)  # cap set per-batch


def smoke_config() -> WideDeepConfig:
    return dataclasses.replace(
        config(), n_sparse=6, vocab_per_field=1000, mlp=(64, 32, 16)
    )
