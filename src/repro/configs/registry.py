"""Architecture registry: every (arch x shape) cell of the assignment.

10 architectures x their 4 shapes = 40 cells.  ``long_500k`` is runnable
only for mixtral-8x7b (sliding-window attention -> O(window) cache); the
four pure full-attention LMs record a skip with a reason, per the
assignment ("skip for pure full-attention archs and note in DESIGN.md").
An extra ``rdfizer/shuffle_dedup`` cell lowers the paper's own operator at
mesh scale.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.configs import (
    cells,
    command_r_plus_104b,
    dbrx_132b,
    equiformer_v2,
    gat_cora,
    gemma_2b,
    meshgraphnet,
    mixtral_8x7b,
    nequip,
    qwen2_5_3b,
    wide_deep,
)

# ---- LM shapes (assignment values)
LM_TRAIN = dict(batch=256, seq=4096)
LM_PREFILL = dict(batch=32, seq=32768)
LM_DECODE = dict(batch=128, ctx=32768)
LM_LONG = dict(batch=1, ctx=524288)

# ---- GNN shapes (assignment values)
GNN_SHAPES = {
    "full_graph_sm": dict(n=2708, e=10556, d_feat=1433, task="node_cls",
                          n_classes=7, n_graphs=1, shard_edges=False),
    # fanout 15-10 over 1024 seeds: node table 1024+15,360+153,600 (padded),
    # edges 15,360+153,600; features are reddit-like (602 dims, 41 classes)
    "minibatch_lg": dict(n=169984, e=168960, d_feat=602, task="node_cls",
                         n_classes=41, n_graphs=1, shard_edges=False),
    "ogb_products": dict(n=2449029, e=61859140, d_feat=100, task="node_cls",
                         n_classes=47, n_graphs=1, shard_edges=True),
    "molecule": dict(n=128 * 30, e=128 * 64, d_feat=16, task="graph_reg",
                     n_classes=0, n_graphs=128, shard_edges=False),
}

# ---- recsys shapes
RECSYS_SHAPES = {
    "train_batch": 65536,
    "serve_p99": 512,
    "serve_bulk": 262144,
    "retrieval_cand": 1_000_000,
}


@dataclasses.dataclass
class ArchEntry:
    name: str
    family: str                      # lm | gnn | recsys
    config: Callable
    smoke_config: Callable
    shapes: tuple[str, ...]
    skips: dict[str, str]


def _lm_entry(mod, name, long_ok: bool, long_reason: str = "") -> ArchEntry:
    skips = {}
    if not long_ok:
        skips["long_500k"] = long_reason
    return ArchEntry(
        name=name, family="lm", config=mod.config, smoke_config=mod.smoke_config,
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
        skips=skips,
    )


_FULL_ATTN = (
    "pure full-attention arch: 524k-token full-attention decode has no "
    "sub-quadratic path; skipped per assignment (see DESIGN.md §5)"
)

ARCHS: dict[str, ArchEntry] = {
    "qwen2.5-3b": _lm_entry(qwen2_5_3b, "qwen2.5-3b", False, _FULL_ATTN),
    "gemma-2b": _lm_entry(gemma_2b, "gemma-2b", False, _FULL_ATTN),
    "command-r-plus-104b": _lm_entry(
        command_r_plus_104b, "command-r-plus-104b", False, _FULL_ATTN
    ),
    "dbrx-132b": _lm_entry(dbrx_132b, "dbrx-132b", False, _FULL_ATTN),
    "mixtral-8x7b": _lm_entry(mixtral_8x7b, "mixtral-8x7b", True),
    "gat-cora": ArchEntry(
        "gat-cora", "gnn", gat_cora.config, gat_cora.smoke_config,
        tuple(GNN_SHAPES), {},
    ),
    "meshgraphnet": ArchEntry(
        "meshgraphnet", "gnn", meshgraphnet.config, meshgraphnet.smoke_config,
        tuple(GNN_SHAPES), {},
    ),
    "nequip": ArchEntry(
        "nequip", "gnn", nequip.config, nequip.smoke_config,
        tuple(GNN_SHAPES), {},
    ),
    "equiformer-v2": ArchEntry(
        "equiformer-v2", "gnn", equiformer_v2.config, equiformer_v2.smoke_config,
        tuple(GNN_SHAPES), {},
    ),
    "wide-deep": ArchEntry(
        "wide-deep", "recsys", wide_deep.config, wide_deep.smoke_config,
        tuple(RECSYS_SHAPES), {},
    ),
}


def get_arch(name: str) -> ArchEntry:
    return ARCHS[name]


def list_cells(include_skips: bool = False):
    """All (arch, shape) cells; skipped ones flagged with their reason."""
    out = []
    for a in ARCHS.values():
        for s in a.shapes:
            reason = a.skips.get(s)
            if reason and not include_skips:
                out.append((a.name, s, reason))
            else:
                out.append((a.name, s, reason))
    return out


def build_cell(
    arch: str, shape: str, mesh, n_layers_override: int | None = None
) -> cells.CellSpec | str:
    """Build the lowerable CellSpec for one cell, or return the skip reason.

    ``n_layers_override`` (LM family only) builds an unrolled L-layer variant
    — the dry-run compiles L=1 and L=2 to extrapolate true per-layer cost,
    because XLA cost_analysis counts a scan body once regardless of trip
    count (see launch/dryrun.py).
    """
    entry = get_arch(arch)
    if shape in entry.skips:
        return entry.skips[shape]
    cfg = entry.config()
    if n_layers_override is not None and entry.family == "lm":
        cfg = dataclasses.replace(
            cfg, n_layers=n_layers_override, scan_layers=False
        )

    if entry.family == "lm":
        if shape == "train_4k":
            return cells.lm_train_cell(
                cfg, mesh, **LM_TRAIN,
                unroll_accum=n_layers_override is not None,
            )
        if shape == "prefill_32k":
            return cells.lm_prefill_cell(
                cfg, mesh, **LM_PREFILL,
                unroll_accum=n_layers_override is not None,
            )
        if shape == "decode_32k":
            return cells.lm_decode_cell(cfg, mesh, LM_DECODE["batch"], LM_DECODE["ctx"])
        if shape == "long_500k":
            return cells.lm_decode_cell(cfg, mesh, LM_LONG["batch"], LM_LONG["ctx"])

    if entry.family == "gnn":
        p = dict(GNN_SHAPES[shape])
        cfg = dataclasses.replace(
            cfg,
            d_in=p["d_feat"],
            **(
                {"n_classes": p["n_classes"], "task": p["task"]}
                if hasattr(cfg, "task")
                else {}
            ),
        )
        if p["shard_edges"]:
            # full-batch-large: channel sharding + bf16 activations/params
            import jax.numpy as jnp

            cfg = dataclasses.replace(cfg, channel_shard=True, dtype=jnp.bfloat16)
        return cells.gnn_train_cell(
            arch, cfg, mesh,
            n=p["n"], e=p["e"], d_feat=p["d_feat"], task=p["task"],
            n_classes=p["n_classes"], n_graphs=p["n_graphs"],
            shard_edges=p["shard_edges"], shape_name=shape,
        )

    if entry.family == "recsys":
        if shape == "train_batch":
            return cells.recsys_train_cell(cfg, mesh, RECSYS_SHAPES[shape])
        if shape == "retrieval_cand":
            return cells.recsys_retrieval_cell(cfg, mesh, RECSYS_SHAPES[shape])
        return cells.recsys_serve_cell(cfg, mesh, RECSYS_SHAPES[shape], shape)

    raise ValueError(f"unknown cell {arch}/{shape}")


def build_extra_cells(mesh):
    """Cells beyond the 40: the paper's own operator at mesh scale."""
    return [cells.rdfizer_shuffle_cell(mesh, n_keys=1 << 24)]
