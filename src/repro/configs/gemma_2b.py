"""gemma-2b — dense LM: 18L, d_model 2048, 8H MQA(kv=1), head_dim 256,
d_ff 16384, vocab 256000, GeGLU, tied embeddings [arXiv:2403.08295]."""

import dataclasses

import jax.numpy as jnp

from repro.models.transformer import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="gemma-2b",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv=1,
        head_dim=256,
        d_ff=16384,
        vocab=256000,
        microbatches=2,
        gated_act="gelu",
        tie_embeddings=True,
    )


def smoke_config() -> LMConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=1, head_dim=16,
        d_ff=128, vocab=512, dtype=jnp.float32, sequence_parallel=False, attn_chunk=None, microbatches=1,
    )
