"""qwen2.5-3b — dense LM: 36L, d_model 2048, 16H GQA(kv=2), d_ff 11008,
vocab 151936, QKV bias [hf:Qwen/Qwen2.5-3B]."""

import dataclasses

import jax.numpy as jnp

from repro.models.transformer import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="qwen2.5-3b",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv=2,
        head_dim=128,
        d_ff=11008,
        vocab=151936,
        qkv_bias=True,
        gated_act="silu",
        rope_theta=1_000_000.0,
    )


def smoke_config() -> LMConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=512, dtype=jnp.float32, sequence_parallel=False, attn_chunk=None, microbatches=1,
    )
