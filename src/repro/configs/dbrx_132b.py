"""dbrx-132b — MoE LM: 40L, d_model 6144, 48H GQA(kv=8), d_ff 10752/expert,
16 experts top-4 (fine-grained), vocab 100352 [hf:databricks/dbrx-base]."""

import dataclasses

import jax.numpy as jnp

from repro.models.transformer import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="dbrx-132b",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv=8,
        head_dim=128,
        d_ff=10752,
        vocab=100352,
        moe=True,
        n_experts=16,
        top_k=4,
        microbatches=8,
        gated_act="silu",
        rope_theta=500_000.0,
    )


def smoke_config() -> LMConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=96, vocab=512, n_experts=4, top_k=2,
        dtype=jnp.float32, sequence_parallel=False, attn_chunk=None, microbatches=1,
    )
