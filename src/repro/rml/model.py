"""RML mapping model — the ⟨O, S, M⟩ data-integration system of the paper.

A :class:`MappingDocument` is the set M of mapping rules; each
:class:`TriplesMap` groups rules sharing a subject; each
:class:`PredicateObjectMap` is one rule and classifies (paper §III.iii) to
exactly one physical operator:

* plain object map                        -> SOM
* parentTriplesMap, same logical source   -> ORM
* parentTriplesMap + joinCondition        -> OJM
"""

from __future__ import annotations

import dataclasses
import re
from typing import Literal

_PLACEHOLDER = re.compile(r"\{([^{}]+)\}")


@dataclasses.dataclass(frozen=True)
class LogicalSource:
    path: str
    fmt: Literal["csv", "tsv", "json"] = "csv"
    iterator: str | None = None  # JSONPath-ish iterator for json sources


def source_key(src: LogicalSource) -> str:
    """Canonical logical-source identity string.  The JSON iterator is part
    of the identity: two maps over the same file with different iterators
    are different sources (they yield different record streams)."""
    key = f"{src.fmt}:{src.path}"
    if src.iterator:
        key += f"\x1f{src.iterator}"
    return key


def parse_source_key(key: str) -> tuple[str, str, str | None]:
    """Inverse of :func:`source_key`: -> (fmt, path, iterator)."""
    fmt, rest = key.split(":", 1)
    path, _, iterator = rest.partition("\x1f")
    return fmt, path, iterator or None


@dataclasses.dataclass(frozen=True)
class TermMap:
    """rr:template / rml:reference / rr:constant term map."""

    template: str | None = None
    reference: str | None = None
    constant: str | None = None

    def __post_init__(self):
        n = sum(x is not None for x in (self.template, self.reference, self.constant))
        if n != 1:
            raise ValueError("TermMap needs exactly one of template/reference/constant")

    @property
    def kind(self) -> str:
        if self.template is not None:
            return "template"
        if self.reference is not None:
            return "reference"
        return "constant"

    @property
    def columns(self) -> tuple[str, ...]:
        """Source columns this term reads (template placeholders or the
        reference column; constants read none)."""
        if self.template is not None:
            return tuple(_PLACEHOLDER.findall(self.template))
        if self.reference is not None:
            return (self.reference,)
        return ()

    @property
    def pattern(self) -> str:
        """Canonical string pattern identifying the term *template*; the
        per-row value slots in via dictionary-encoded ids (DESIGN.md §2)."""
        if self.template is not None:
            return _PLACEHOLDER.sub("{}", self.template)
        if self.reference is not None:
            return "{}"  # raw literal value
        return self.constant  # type: ignore[return-value]

    @property
    def key(self) -> tuple[str, tuple[str, ...]]:
        """``(pattern, columns)`` — the term map's evaluation identity.
        Two term maps with the same key over the same logical source
        produce identical per-row values, which is what lets the mapping
        planner (:mod:`repro.rml.plan`) factor them into one FunMap-style
        common subexpression evaluated once per source scan."""
        return (self.pattern, self.columns)


@dataclasses.dataclass(frozen=True)
class JoinCondition:
    child: str   # column of the child logical source
    parent: str  # column of the parent logical source


@dataclasses.dataclass(frozen=True)
class RefObjectMap:
    parent_triples_map: str
    join: JoinCondition | None = None  # None -> ORM (same source), else OJM


@dataclasses.dataclass(frozen=True)
class PredicateObjectMap:
    predicate: str  # constant predicate IRI
    object_map: TermMap | RefObjectMap


@dataclasses.dataclass(frozen=True)
class TriplesMap:
    name: str
    source: LogicalSource
    subject: TermMap
    subject_class: str | None = None
    poms: tuple[PredicateObjectMap, ...] = ()


@dataclasses.dataclass(frozen=True)
class MappingDocument:
    triples_maps: dict[str, TriplesMap]

    def classify(self, tm: TriplesMap, pom: PredicateObjectMap) -> str:
        """-> 'SOM' | 'ORM' | 'OJM' per the paper's operator-selection rule."""
        om = pom.object_map
        if isinstance(om, TermMap):
            return "SOM"
        parent = self.triples_maps[om.parent_triples_map]
        if om.join is None:
            if parent.source != tm.source:
                raise ValueError(
                    f"ORM {tm.name}->{parent.name} requires a shared logical source"
                )
            return "ORM"
        return "OJM"

    def validate(self) -> None:
        for tm in self.triples_maps.values():
            for pom in tm.poms:
                self.classify(tm, pom)
