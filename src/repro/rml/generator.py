"""Testbed generator — the paper's COSMIC-derived benchmark datasets.

The paper builds six datasets from the COSMIC coding point-mutation table:
{10K, 100K, 1M} rows × {25%, 75%} duplicate rate, *each duplicated value
repeated 20 times*, plus mapping files with 1..5 predicate-object maps of
each operator type (SOM / ORM / OJM).  COSMIC requires a license, so we
generate schema-faithful synthetic tables with exactly those statistical
controls; the engine never looks at the string content, only at the
dictionary-encoded structure, so the performance profile is preserved.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.rml.model import (
    JoinCondition,
    LogicalSource,
    MappingDocument,
    PredicateObjectMap,
    RefObjectMap,
    TermMap,
    TriplesMap,
)

BASE = "http://repro.org/"
COLUMNS = (
    "MUTATION_ID",
    "GENE_NAME",
    "ACCESSION_NUMBER",   # the ENST join column of the motivating example
    "GENOMIC_MUTATION_ID",
    "MUTATION_CDS",
    "MUTATION_AA",
    "OMIXCORE_SCORE",
)
PARENT_COLUMNS = ("ACCESSION_NUMBER", "EXON_ID", "EXON_START", "EXON_END")
DUP_GROUP = 20  # the paper: each duplicated value repeated 20 times


@dataclasses.dataclass
class Testbed:
    child: dict[str, np.ndarray]          # the main (child) table
    parent: dict[str, np.ndarray] | None  # second source for OJM testbeds
    doc: MappingDocument
    name: str

    def write(self, out_dir: str) -> str:
        os.makedirs(out_dir, exist_ok=True)
        _write_csv(os.path.join(out_dir, "child.csv"), self.child)
        if self.parent is not None:
            _write_csv(os.path.join(out_dir, "parent.csv"), self.parent)
        return out_dir


def _write_csv(path: str, table: dict[str, np.ndarray]) -> None:
    cols = list(table)
    n = len(table[cols[0]])
    with open(path, "w", encoding="utf-8") as f:
        f.write(",".join(cols) + "\n")
        for i in range(n):
            f.write(",".join(str(table[c][i]) for c in cols) + "\n")


def _dup_rows(n_rows: int, dup_rate: float, rng: np.random.Generator) -> np.ndarray:
    """Row-identity vector of length n_rows where ``dup_rate`` of the rows are
    duplicates, occurring in groups of DUP_GROUP (paper's construction)."""
    n_dup = int(round(n_rows * dup_rate))
    n_groups = max(n_dup // DUP_GROUP, 1) if n_dup else 0
    n_uniq = n_rows - n_dup + n_groups  # each group contributes one original
    ids = np.arange(n_uniq, dtype=np.int64)
    extra = []
    if n_groups:
        group_ids = rng.choice(n_uniq, size=n_groups, replace=False)
        reps = np.full(n_groups, DUP_GROUP - 1, dtype=np.int64)
        # distribute the remainder so total length is exactly n_rows
        rem = n_dup - n_groups * (DUP_GROUP - 1)
        i = 0
        while rem > 0:
            reps[i % n_groups] += 1
            rem -= 1
            i += 1
        while rem < 0:
            reps[i % n_groups] -= 1
            rem += 1
            i += 1
        extra = np.repeat(group_ids, reps)
    out = np.concatenate([ids, extra]) if len(extra) else ids
    rng.shuffle(out)
    return out[:n_rows]


def make_child_table(
    n_rows: int, dup_rate: float, seed: int = 0, n_enst_pool: int | None = None
) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    row_id = _dup_rows(n_rows, dup_rate, rng)
    n_enst = n_enst_pool or max(n_rows // 16, 4)
    enst_of_row = rng.integers(0, n_enst, size=row_id.max() + 1)
    table = {}
    for col in COLUMNS:
        if col == "ACCESSION_NUMBER":
            table[col] = np.array(
                [f"ENST{enst_of_row[r]:011d}" for r in row_id], dtype=object
            )
        elif col == "OMIXCORE_SCORE":
            score = (row_id % 1000) / 1000.0
            table[col] = np.array([f"{s:.3f}" for s in score], dtype=object)
        else:
            table[col] = np.array([f"{col}_{r}" for r in row_id], dtype=object)
    return table


def make_parent_table(
    n_rows: int, dup_rate: float, seed: int = 1, n_enst_pool: int | None = None
) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    row_id = _dup_rows(n_rows, dup_rate, rng)
    n_enst = n_enst_pool or max(n_rows // 16, 4)
    enst_of_row = rng.integers(0, n_enst, size=row_id.max() + 1)
    table = {}
    for col in PARENT_COLUMNS:
        if col == "ACCESSION_NUMBER":
            table[col] = np.array(
                [f"ENST{enst_of_row[r]:011d}" for r in row_id], dtype=object
            )
        else:
            table[col] = np.array([f"{col}_{r}" for r in row_id], dtype=object)
    return table


def _subject(template_col: str = "MUTATION_ID") -> TermMap:
    return TermMap(template=f"{BASE}mutation/{{{template_col}}}")


def make_som_testbed(
    n_rows: int, dup_rate: float, n_poms: int = 1, seed: int = 0
) -> Testbed:
    """SOM mapping: n_poms predicate-object maps with column references."""
    obj_cols = [c for c in COLUMNS if c != "MUTATION_ID"][:n_poms]
    poms = tuple(
        PredicateObjectMap(
            predicate=f"{BASE}vocab/{c.lower()}", object_map=TermMap(reference=c)
        )
        for c in obj_cols
    )
    tm = TriplesMap(
        name="TriplesMap1",
        source=LogicalSource(path="child.csv"),
        subject=_subject(),
        subject_class=f"{BASE}vocab/Mutation",
        poms=poms,
    )
    return Testbed(
        child=make_child_table(n_rows, dup_rate, seed),
        parent=None,
        doc=MappingDocument({"TriplesMap1": tm}),
        name=f"som{n_poms}-{n_rows}-{int(dup_rate*100)}",
    )


def make_orm_testbed(
    n_rows: int, dup_rate: float, n_poms: int = 1, seed: int = 0
) -> Testbed:
    """ORM mapping: child references parent maps over the SAME source."""
    src = LogicalSource(path="child.csv")
    maps: dict[str, TriplesMap] = {}
    poms = []
    ref_cols = [c for c in COLUMNS if c != "MUTATION_ID"][:n_poms]
    for i, col in enumerate(ref_cols):
        pname = f"ParentMap{i+1}"
        maps[pname] = TriplesMap(
            name=pname,
            source=src,
            subject=TermMap(template=f"{BASE}{col.lower()}/{{{col}}}"),
            subject_class=f"{BASE}vocab/{col.title()}",
        )
        poms.append(
            PredicateObjectMap(
                predicate=f"{BASE}vocab/has_{col.lower()}",
                object_map=RefObjectMap(parent_triples_map=pname, join=None),
            )
        )
    maps["TriplesMap1"] = TriplesMap(
        name="TriplesMap1",
        source=src,
        subject=_subject(),
        subject_class=f"{BASE}vocab/Mutation",
        poms=tuple(poms),
    )
    return Testbed(
        child=make_child_table(n_rows, dup_rate, seed),
        parent=None,
        doc=MappingDocument(maps),
        name=f"orm{n_poms}-{n_rows}-{int(dup_rate*100)}",
    )


def make_ojm_testbed(
    n_rows: int,
    dup_rate: float,
    n_poms: int = 1,
    seed: int = 0,
    parent_rows: int | None = None,
) -> Testbed:
    """OJM mapping: joins to parent maps over a DIFFERENT source on the ENST
    accession column (the motivating example's join)."""
    parent_rows = parent_rows or n_rows
    # join-key pool sized for ~4 matches per child row (keeps |N_p| = Θ(4·n))
    n_pool = max(min(n_rows, parent_rows) // 4, 4)
    child_src = LogicalSource(path="child.csv")
    parent_src = LogicalSource(path="parent.csv")
    maps: dict[str, TriplesMap] = {}
    poms = []
    for i in range(n_poms):
        pname = f"ExonMap{i+1}"
        maps[pname] = TriplesMap(
            name=pname,
            source=parent_src,
            subject=TermMap(template=f"{BASE}exon{i+1}/{{EXON_ID}}"),
            subject_class=f"{BASE}vocab/Exon",
        )
        poms.append(
            PredicateObjectMap(
                predicate=f"{BASE}vocab/in_exon_{i+1}",
                object_map=RefObjectMap(
                    parent_triples_map=pname,
                    join=JoinCondition(
                        child="ACCESSION_NUMBER", parent="ACCESSION_NUMBER"
                    ),
                ),
            )
        )
    maps["TriplesMap1"] = TriplesMap(
        name="TriplesMap1",
        source=child_src,
        subject=_subject(),
        subject_class=f"{BASE}vocab/Mutation",
        poms=tuple(poms),
    )
    return Testbed(
        child=make_child_table(n_rows, dup_rate, seed, n_enst_pool=n_pool),
        parent=make_parent_table(parent_rows, dup_rate, seed + 1, n_enst_pool=n_pool),
        doc=MappingDocument(maps),
        name=f"ojm{n_poms}-{n_rows}-{int(dup_rate*100)}",
    )


def make_testbed(
    kind: str, n_rows: int, dup_rate: float, n_poms: int = 1, seed: int = 0
) -> Testbed:
    if kind == "SOM":
        return make_som_testbed(n_rows, dup_rate, n_poms, seed)
    if kind == "ORM":
        return make_orm_testbed(n_rows, dup_rate, n_poms, seed)
    if kind == "OJM":
        return make_ojm_testbed(n_rows, dup_rate, n_poms, seed)
    raise ValueError(f"unknown testbed kind {kind!r}")
