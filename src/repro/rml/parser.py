"""Parser for the RML turtle subset used by the paper's mappings.

Supports the constructs exercised in the paper's Figure 1 / testbeds:
``@prefix``, triples maps with ``rml:logicalSource``, ``rr:subjectMap``
(template + class), ``rr:predicateObjectMap`` with plain object maps
(``rr:template`` / ``rml:reference`` / ``rr:constant``), referencing object
maps (``rr:parentTriplesMap``), and ``rr:joinCondition`` (``rr:child`` /
``rr:parent``).  Blank-node property lists, ``;``/``,`` lists, IRIs,
prefixed names and string literals are handled by a small recursive-descent
parser — enough to round-trip every mapping in the bundled testbeds.
"""

from __future__ import annotations

import re

from repro.rml.model import (
    JoinCondition,
    LogicalSource,
    MappingDocument,
    PredicateObjectMap,
    RefObjectMap,
    TermMap,
    TriplesMap,
)

_TOKEN = re.compile(
    r"""
    (?P<comment>\#[^\n]*)
  | (?P<iri><[^>]*>)
  | (?P<string>"(?:[^"\\]|\\.)*")
  | (?P<punct>[\[\];,.])
  | (?P<prefixed>[A-Za-z_][\w\-]*:[\w\-./#]*)
  | (?P<kw>@prefix|a)
  | (?P<ws>\s+)
""",
    re.VERBOSE,
)


def _tokenize(text: str) -> list[str]:
    toks, pos = [], 0
    while pos < len(text):
        m = _TOKEN.match(text, pos)
        if not m:
            raise SyntaxError(f"RML parse error at: {text[pos:pos+40]!r}")
        pos = m.end()
        if m.lastgroup in ("ws", "comment"):
            continue
        toks.append(m.group())
    return toks


class _Parser:
    def __init__(self, toks: list[str]):
        self.toks = toks
        self.i = 0
        self.prefixes: dict[str, str] = {}

    def peek(self) -> str | None:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> str:
        tok = self.toks[self.i]
        self.i += 1
        return tok

    def expect(self, tok: str) -> None:
        got = self.next()
        if got != tok:
            raise SyntaxError(f"expected {tok!r}, got {got!r}")

    # -- term expansion -----------------------------------------------------
    def expand(self, tok: str) -> str:
        if tok.startswith("<") and tok.endswith(">"):
            return tok[1:-1]
        if tok.startswith('"') and tok.endswith('"'):
            return tok[1:-1].encode().decode("unicode_escape")
        if ":" in tok:
            pfx, local = tok.split(":", 1)
            if pfx in self.prefixes:
                return self.prefixes[pfx] + local
        return tok

    # -- grammar ------------------------------------------------------------
    def parse(self) -> MappingDocument:
        maps: dict[str, TriplesMap] = {}
        while self.peek() is not None:
            if self.peek() == "@prefix":
                self.next()
                name = self.next().rstrip(":")
                iri = self.expand(self.next())
                self.expect(".")
                self.prefixes[name] = iri
            else:
                tm = self.parse_triples_map()
                maps[tm.name] = tm
        doc = MappingDocument(triples_maps=maps)
        doc.validate()
        return doc

    def parse_triples_map(self) -> TriplesMap:
        name_tok = self.next()
        name = name_tok[1:-1] if name_tok.startswith("<") else name_tok
        name = name.lstrip("#")
        props = self.parse_property_list()
        self.expect(".")
        return self.build_triples_map(name, props)

    def parse_property_list(self) -> list[tuple[str, object]]:
        """predicate object (',' object)* (';' predicate ...)*"""
        props: list[tuple[str, object]] = []
        while True:
            nxt = self.peek()
            if nxt in (None, ".", "]"):
                break
            pred_tok = self.next()
            pred = "rdf:type" if pred_tok == "a" else pred_tok
            while True:
                obj = self.parse_object()
                props.append((pred, obj))
                if self.peek() == ",":
                    self.next()
                    continue
                break
            if self.peek() == ";":
                self.next()
                continue
            break
        return props

    def parse_object(self):
        tok = self.peek()
        if tok == "[":
            self.next()
            inner = self.parse_property_list()
            self.expect("]")
            return inner
        return self.next()

    # -- model construction ---------------------------------------------------
    def _get(self, props, *keys):
        out = []
        for p, v in props:
            local = p.split(":", 1)[-1].lstrip("<").rstrip(">").split("#")[-1].split("/")[-1]
            if local in keys:
                out.append(v)
        return out

    def build_term_map(self, props) -> TermMap:
        tpl = self._get(props, "template")
        ref = self._get(props, "reference")
        const = self._get(props, "constant")
        if tpl:
            return TermMap(template=self.expand(tpl[0]))
        if ref:
            return TermMap(reference=self.expand(ref[0]))
        if const:
            return TermMap(constant=self.expand(const[0]))
        raise SyntaxError(f"term map without template/reference/constant: {props}")

    def build_triples_map(self, name: str, props) -> TriplesMap:
        ls_props = self._get(props, "logicalSource")[0]
        src_tok = self._get(ls_props, "source")[0]
        fmt = "csv"
        rf = self._get(ls_props, "referenceFormulation")
        if rf and "JSON" in str(rf[0]).upper():
            fmt = "json"
        elif rf and "TSV" in str(rf[0]).upper():
            fmt = "tsv"  # ql:TSV — tab-delimited, same reader, different split
        iterator = None
        it = self._get(ls_props, "iterator")
        if it:
            iterator = self.expand(it[0])
        source = LogicalSource(path=self.expand(src_tok), fmt=fmt, iterator=iterator)

        sm_props = self._get(props, "subjectMap")[0]
        subject = self.build_term_map(sm_props)
        cls = self._get(sm_props, "class")
        subject_class = self.expand(cls[0]) if cls else None

        poms = []
        for pom_props in self._get(props, "predicateObjectMap"):
            pred = self.expand(self._get(pom_props, "predicate")[0])
            om_entries = self._get(pom_props, "objectMap")
            if not om_entries:
                raise SyntaxError(f"predicateObjectMap without objectMap in {name}")
            om_props = om_entries[0]
            parent = self._get(om_props, "parentTriplesMap")
            if parent:
                pname = str(parent[0])
                pname = (pname[1:-1] if pname.startswith("<") else pname).lstrip("#")
                join = None
                jc = self._get(om_props, "joinCondition")
                if jc:
                    child = self.expand(self._get(jc[0], "child")[0])
                    par = self.expand(self._get(jc[0], "parent")[0])
                    join = JoinCondition(child=child, parent=par)
                obj: TermMap | RefObjectMap = RefObjectMap(
                    parent_triples_map=pname, join=join
                )
            else:
                obj = self.build_term_map(om_props)
            poms.append(PredicateObjectMap(predicate=pred, object_map=obj))

        return TriplesMap(
            name=name,
            source=source,
            subject=subject,
            subject_class=subject_class,
            poms=tuple(poms),
        )


def parse(text: str) -> MappingDocument:
    return _Parser(_tokenize(text)).parse()


def parse_file(path: str) -> MappingDocument:
    with open(path, encoding="utf-8") as f:
        return parse(f.read())
