from repro.rml.model import (  # noqa: F401
    JoinCondition,
    LogicalSource,
    MappingDocument,
    PredicateObjectMap,
    RefObjectMap,
    TermMap,
    TriplesMap,
)
