"""Mapping-level planner: projection pushdown, shared-term factoring, rule groups.

The per-operator planner (:mod:`repro.core.planner`) decides *how* each
mapping rule runs (SOM / ORM / OJM / CLASS, PJTT reuse, PTT sizing).  This
module plans one level above the operators — across the whole mapping
document — reproducing the paper's own follow-up optimizations:

* **Projection pushdown** (MapSDI, arxiv 1909.01032).  For every logical
  source, the exact set of columns any rule references — subject / object
  templates, ``rml:reference`` columns, join child/parent columns — is
  computed up front (:class:`SourcePlan`), so the streamed read can push a
  strict ``Project`` into the datasource and never materialize or encode
  an unused column.  Fixed-schema sources (single-file CSV/TSV, the
  ``tables=`` bypass) project *strictly*: a mapped column missing from the
  source fails loudly at read time instead of fabricating empty strings.

* **Shared-term factoring** (FunMap, arxiv 2008.13482).  Term maps with the
  same ``(source, columns)`` evaluation identity — a subject template shared
  by every predicate-object map of a triples map, a join key probed by
  several rules and by the PJTT sizing pass — are factored into
  :class:`SharedTerm` common subexpressions the executor evaluates once per
  source scan and serves from an int32 cache thereafter.

* **Rule groups** ("Scaling Up", arxiv 2207.xxx lineage).  Rules are
  partitioned by union–find into independently executable
  :class:`RuleGroup` s: two rules land in the same group iff they share a
  logical source, share a predicate (PTT dedup state is per predicate, so
  same-predicate rules are *not* independent), or are linked by a join
  dependency (an OJM rule and its parent map).  The groups form the
  execution DAG ``create_kg`` runs group-by-group — sequentially in one
  process, and as the scheduling unit for ``rdfize --shards N
  --shard-workers M`` multi-process builds, where each worker can create a
  whole group's triples with no cross-worker coordination.

The plan never changes *what* is produced — the executor's output is
byte-identical with the planner on or off (property-tested) — only how
many columns are read, how many times a term is evaluated, and in what
grouping the rules run.  :meth:`MappingPlan.explain` renders the whole
thing as the stable tree behind ``rdfize --explain-mapping`` and
:func:`repro.api.explain_mapping`.
"""

from __future__ import annotations

import dataclasses

from repro.rml.model import MappingDocument


@dataclasses.dataclass(frozen=True)
class SourcePlan:
    """Column requirements of one logical source across every rule.

    ``columns`` is the exact referenced set (sorted); ``strict`` says the
    projection may be pushed into the reader in strict mode (missing
    column -> KeyError at read time) because the source has one fixed
    schema.  Union-fill sources (JSON records, glob-sharded files) stay
    tolerant and are validated by the executor's schema-union pass.
    """

    source_key: str
    columns: tuple[str, ...]
    strict: bool
    n_ops: int  # planned ops reading this source (incl. PJTT builds)


@dataclasses.dataclass(frozen=True)
class SharedTerm:
    """One factored common subexpression: an encoded term-value column
    with a ``(source_key, columns)`` identity that two or more evaluation
    sites share.  ``patterns`` lists the distinct term templates rendered
    from it (the encoded value column depends only on the columns; the
    pattern slots in as a dictionary id)."""

    source_key: str
    columns: tuple[str, ...]
    n_uses: int
    patterns: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class RuleGroup:
    """One independently-executable partition of the mapping rules.

    Groups are closed over source sharing, predicate sharing, and join
    dependencies, so executing a group touches only its ``sources``,
    builds only its ``pjtt_keys``, and emits only its ``predicates`` —
    no state crosses a group boundary, which is what makes groups both
    sequentially reorderable and safe to run in separate processes.
    """

    index: int
    op_indices: tuple[int, ...]  # indices into the op plan, original order
    triples_maps: tuple[str, ...]
    predicates: tuple[str, ...]  # in first-op order (stable)
    sources: tuple[str, ...]
    pjtt_keys: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class MappingPlan:
    """The document-level plan: op plan + projections + factoring + DAG."""

    exec_plan: object  # repro.core.planner.ExecutionPlan
    sources: dict[str, SourcePlan]
    shared: dict[tuple[str, tuple[str, ...]], SharedTerm]
    groups: tuple[RuleGroup, ...]

    def group_of_predicate(self, predicate: str) -> RuleGroup:
        for g in self.groups:
            if predicate in g.predicates:
                return g
        raise KeyError(predicate)

    def explain(self, schemas: dict[str, tuple[str, ...]] | None = None) -> str:
        """Stable human-readable tree (the ``--explain-mapping`` surface).

        ``schemas`` optionally maps source_key -> full column tuple (e.g.
        peeked CSV headers) so pruned columns can be named; without it the
        tree shows kept columns only.
        """
        return render_explain(self, schemas or {})


# --------------------------------------------------------------------------
# plan construction
# --------------------------------------------------------------------------


class _UnionFind:
    def __init__(self):
        self.parent: dict = {}

    def find(self, x):
        p = self.parent.setdefault(x, x)
        while p != x:
            self.parent[x] = p = self.parent.setdefault(p, p)
            x, p = p, self.parent[p]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def _is_strict_source(source_key: str) -> bool:
    """Strict (fixed-schema) iff single-file CSV/TSV — mirrors the
    executor's ``fill_of`` policy; JSON and glob-sharded paths union-fill."""
    from repro.rml.model import parse_source_key
    from repro.stream.datasource import is_sharded_path

    fmt, path, _ = parse_source_key(source_key)
    return fmt in ("csv", "tsv") and not is_sharded_path(path)


def build_plan(doc: MappingDocument) -> MappingPlan:
    """Plan the whole mapping document (pure analysis, no I/O)."""
    from repro.core import planner

    exec_plan = planner.plan(doc)
    ops = exec_plan.ops

    # ---- per-evaluation-site term tuples: (source_key, columns) -> uses
    uses: dict[tuple[str, tuple[str, ...]], int] = {}
    patterns: dict[tuple[str, tuple[str, ...]], set] = {}

    def use(skey: str, cols: tuple[str, ...], pattern: str | None = None):
        if not cols:
            return  # constant terms read nothing and need no cache
        k = (skey, tuple(cols))
        uses[k] = uses.get(k, 0) + 1
        if pattern is not None:
            patterns.setdefault(k, set()).add(pattern)

    refcols: dict[str, set] = {}
    n_ops_per_src: dict[str, int] = {}
    for op in ops:
        cols = refcols.setdefault(op.source_key, set())
        n_ops_per_src[op.source_key] = n_ops_per_src.get(op.source_key, 0) + 1
        cols.update(op.subj_columns)
        use(op.source_key, op.subj_columns, op.subj_pattern)
        if op.kind == "OJM":
            cols.add(op.join_child_column)
            use(op.source_key, (op.join_child_column,))
        else:
            cols.update(op.obj_columns)
            use(op.source_key, op.obj_columns, op.obj_pattern)
    for psrc, pcol, ppat, pcols in exec_plan.pjtt_builds.values():
        cols = refcols.setdefault(psrc, set())
        n_ops_per_src[psrc] = n_ops_per_src.get(psrc, 0) + 1
        cols.add(pcol)
        cols.update(pcols)
        use(psrc, (pcol,))
        use(psrc, tuple(pcols), ppat)

    sources = {
        skey: SourcePlan(
            source_key=skey,
            columns=tuple(sorted(cols)),
            strict=_is_strict_source(skey),
            n_ops=n_ops_per_src.get(skey, 0),
        )
        for skey, cols in sorted(refcols.items())
    }

    shared = {
        k: SharedTerm(
            source_key=k[0],
            columns=k[1],
            n_uses=n,
            patterns=tuple(sorted(patterns.get(k, ()))),
        )
        for k, n in sorted(uses.items())
        if n >= 2
    }

    # ---- rule groups: union-find over ops.  Edges: shared source, shared
    # predicate (PTT dedup state is per predicate), join dependency
    # (child op <-> parent source).
    uf = _UnionFind()
    for i, op in enumerate(ops):
        uf.union(("op", i), ("src", op.source_key))
        uf.union(("op", i), ("pred", op.predicate))
        if op.kind == "OJM":
            uf.union(("op", i), ("src", op.parent_source_key))

    roots: dict = {}
    members: dict = {}
    for i in range(len(ops)):
        r = uf.find(("op", i))
        roots.setdefault(r, len(roots))
        members.setdefault(r, []).append(i)
    # order groups by their first op (document order) for a stable DAG
    ordered = sorted(members.values(), key=lambda idxs: idxs[0])

    groups = []
    for gi, idxs in enumerate(ordered):
        tms, preds, srcs, pkeys = [], [], [], []
        for i in idxs:
            op = ops[i]
            if op.triples_map not in tms:
                tms.append(op.triples_map)
            if op.predicate not in preds:
                preds.append(op.predicate)
            if op.source_key not in srcs:
                srcs.append(op.source_key)
            if op.kind == "OJM":
                if op.parent_source_key not in srcs:
                    srcs.append(op.parent_source_key)
                if op.pjtt_key not in pkeys:
                    pkeys.append(op.pjtt_key)
        groups.append(
            RuleGroup(
                index=gi,
                op_indices=tuple(idxs),
                triples_maps=tuple(tms),
                predicates=tuple(preds),
                sources=tuple(srcs),
                pjtt_keys=tuple(pkeys),
            )
        )

    return MappingPlan(
        exec_plan=exec_plan,
        sources=sources,
        shared=shared,
        groups=tuple(groups),
    )


# --------------------------------------------------------------------------
# explain rendering
# --------------------------------------------------------------------------


def _shorten(iri: str) -> str:
    return iri.rsplit("/", 1)[-1].rsplit("#", 1)[-1] or iri


def render_explain(
    plan: MappingPlan, schemas: dict[str, tuple[str, ...]]
) -> str:
    """The ``--explain-mapping`` tree.  Deliberately stable: sorted sources
    and shared terms, document-ordered groups and rules — tests and docs
    pin substrings of this output."""
    ops = plan.exec_plan.ops
    lines = [
        f"mapping plan: {len(ops)} rules over {len(plan.sources)} sources "
        f"-> {len(plan.groups)} groups "
        f"({len(plan.shared)} shared terms factored)"
    ]
    for g in plan.groups:
        last_g = g.index == len(plan.groups) - 1
        gpfx = "└─" if last_g else "├─"
        cpfx = "   " if last_g else "│  "
        lines.append(
            f"{gpfx} group {g.index}: "
            f"{len(g.op_indices)} rules, maps [{', '.join(g.triples_maps)}]"
        )
        sections: list[tuple[str, list[str]]] = []
        src_lines = []
        for skey in sorted(g.sources):
            sp = plan.sources[skey]
            kept = ", ".join(sp.columns)
            schema = schemas.get(skey)
            if schema:
                pruned = [c for c in schema if c not in sp.columns]
                detail = (
                    f"kept {len(sp.columns)}/{len(schema)} columns"
                    f" [{kept}]"
                )
                if pruned:
                    detail += f" pruned [{', '.join(pruned)}]"
            else:
                detail = f"kept [{kept}]"
            mode = "strict" if sp.strict else "union-fill"
            src_lines.append(f"source {skey} ({mode}): {detail}")
        sections.append(("sources", src_lines))

        fac = [
            s
            for k, s in sorted(plan.shared.items())
            if k[0] in g.sources
        ]
        if fac:
            sections.append(
                (
                    "factored terms",
                    [
                        f"{s.source_key} [{', '.join(s.columns)}] "
                        f"x{s.n_uses} uses"
                        for s in fac
                    ],
                )
            )
        if g.pjtt_keys:
            sections.append(
                (
                    "join indexes",
                    [
                        "PJTT "
                        + pk.replace("\x1f", " on ")
                        for pk in g.pjtt_keys
                    ],
                )
            )
        rule_lines = []
        for i in g.op_indices:
            op = ops[i]
            extra = ""
            if op.kind == "OJM":
                extra = (
                    f" (join {op.join_child_column} = "
                    f"{op.parent_join_column})"
                )
            rule_lines.append(
                f"{op.kind:5s} {op.triples_map} -> "
                f"{_shorten(op.predicate)}{extra}"
            )
        sections.append(("rules", rule_lines))

        for si, (title, items) in enumerate(sections):
            last_s = si == len(sections) - 1
            spfx = "└─" if last_s else "├─"
            ipfx = "   " if last_s else "│  "
            lines.append(f"{cpfx}{spfx} {title}")
            for ii, item in enumerate(items):
                leaf = "└─" if ii == len(items) - 1 else "├─"
                lines.append(f"{cpfx}{ipfx}{leaf} {item}")
    return "\n".join(lines)
