"""Serialize a MappingDocument back to RML turtle (round-trips the parser)."""

from __future__ import annotations

from repro.rml.model import MappingDocument, RefObjectMap, TermMap, TriplesMap

_PREFIXES = """\
@prefix rr: <http://www.w3.org/ns/r2rml#> .
@prefix rml: <http://semweb.mmlab.be/ns/rml#> .
@prefix ql: <http://semweb.mmlab.be/ns/ql#> .

"""


def _term(om: TermMap, indent: str) -> str:
    if om.template is not None:
        return f'{indent}rr:template "{om.template}"'
    if om.reference is not None:
        return f'{indent}rml:reference "{om.reference}"'
    return f'{indent}rr:constant "{om.constant}"'


def _triples_map(tm: TriplesMap) -> str:
    ql = "ql:JSONPath" if tm.source.fmt == "json" else "ql:CSV"
    lines = [f"<#{tm.name}> a rr:TriplesMap ;"]
    src = f'    rml:logicalSource [ rml:source "{tm.source.path}" ; rml:referenceFormulation {ql}'
    if tm.source.iterator:
        src += f' ; rml:iterator "{tm.source.iterator}"'
    lines.append(src + " ] ;")
    subj = f"    rr:subjectMap [ {_term(tm.subject, '').strip()}"
    if tm.subject_class:
        subj += f" ; rr:class <{tm.subject_class}>"
    lines.append(subj + " ]" + (" ;" if tm.poms else " ."))
    for i, pom in enumerate(tm.poms):
        last = i == len(tm.poms) - 1
        om = pom.object_map
        if isinstance(om, RefObjectMap):
            inner = f"rr:parentTriplesMap <#{om.parent_triples_map}>"
            if om.join is not None:
                inner += (
                    f' ; rr:joinCondition [ rr:child "{om.join.child}" ;'
                    f' rr:parent "{om.join.parent}" ]'
                )
        else:
            inner = _term(om, "").strip()
        lines.append(
            f"    rr:predicateObjectMap [ rr:predicate <{pom.predicate}> ;"
            f" rr:objectMap [ {inner} ] ]" + (" ." if last else " ;")
        )
    return "\n".join(lines) + "\n"


def to_turtle(doc: MappingDocument) -> str:
    return _PREFIXES + "\n".join(_triples_map(tm) for tm in doc.triples_maps.values())


def write_turtle(doc: MappingDocument, path: str) -> None:
    with open(path, "w", encoding="utf-8") as f:
        f.write(to_turtle(doc))
