"""The three physical RML operators: SOM, ORM, OJM (+ naive counterparts).

Each operator consumes dictionary-encoded columns (int32 value ids) and a
:class:`StaticTripleParams` describing the term templates of the rule, and
produces the candidate triple keys together with duplicate-elimination
results.  The *optimized* path threads a PTT through the call (incremental
dedup, the paper's contribution); the *naive* path returns raw keys so the
executor can perform the paper's generate-all + sort-dedup baseline.

Operator selection (paper §III.iii):
  join condition present            -> OJM  (PJTT index join)
  reference to parent, same source  -> ORM  (self-join, Θ(1) subject access)
  otherwise                         -> SOM
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import hashing, naive, pjtt, ptt


@dataclasses.dataclass(frozen=True)
class StaticTripleParams:
    """Static (compile-time) identity of a predicate-object rule."""

    subj_tmpl: int  # template id of the child subject term
    pred_id: int    # term id of the (constant) predicate
    obj_tmpl: int   # template id of the object term


class OpResult(NamedTuple):
    ptt: ptt.PTT
    is_new: jnp.ndarray      # bool[...]  triples to emit
    overflowed: jnp.ndarray  # bool[]


# ---------------------------------------------------------------- optimized


def som(
    table: ptt.PTT,
    subj_vals: jnp.ndarray,
    obj_vals: jnp.ndarray,
    p: StaticTripleParams,
) -> OpResult:
    """Simple Object Map: object value read straight from the source column
    (or a constant broadcast by the caller).  Cost: |N_p| + 2|S_p|."""
    r = ptt.insert_triples(
        table, p.subj_tmpl, subj_vals, p.pred_id, p.obj_tmpl, obj_vals
    )
    return OpResult(r.ptt, r.is_new, r.overflowed)


def orm(
    table: ptt.PTT,
    subj_vals: jnp.ndarray,
    parent_subj_vals: jnp.ndarray,
    p: StaticTripleParams,
) -> OpResult:
    """Object Reference Map: the object is the *parent map's subject term*
    applied to the same row (same logical source -> Θ(1) access, no join).
    ``p.obj_tmpl`` must be the parent's subject template id."""
    r = ptt.insert_triples(
        table, p.subj_tmpl, subj_vals, p.pred_id, p.obj_tmpl, parent_subj_vals
    )
    return OpResult(r.ptt, r.is_new, r.overflowed)


class OjmResult(NamedTuple):
    ptt: ptt.PTT
    is_new: jnp.ndarray        # bool[m, K]
    subjects: jnp.ndarray      # int32[m, K]   matched parent subject values
    valid: jnp.ndarray         # bool[m, K]
    truncated: jnp.ndarray     # bool[]
    overflowed: jnp.ndarray    # bool[]


def ojm(
    table: ptt.PTT,
    index,  # PJTTSorted | PJTTHash
    child_subj_vals: jnp.ndarray,
    child_join_keys: jnp.ndarray,
    p: StaticTripleParams,
    max_matches: int,
) -> OjmResult:
    """Object Join Map: index join through the PJTT, then PTT dedup.
    Cost: 2|N_parent| + |N_child| + |N_p| + 2|S_p| (paper §III.iv)."""
    if isinstance(index, pjtt.PJTTSorted):
        pr = pjtt.probe_sorted(index, child_join_keys, max_matches)
    else:
        pr = pjtt.probe_hash(index, child_join_keys, max_matches)
    m, K = pr.subjects.shape
    subj = jnp.broadcast_to(child_subj_vals[:, None], (m, K)).reshape(-1)
    obj = pr.subjects.reshape(-1)
    r = ptt.insert_triples(
        table,
        p.subj_tmpl,
        subj,
        p.pred_id,
        p.obj_tmpl,
        obj,
        valid=pr.valid.reshape(-1),
    )
    return OjmResult(
        ptt=r.ptt,
        is_new=r.is_new.reshape(m, K),
        subjects=pr.subjects,
        valid=pr.valid,
        truncated=pr.truncated,
        overflowed=r.overflowed,
    )


# -------------------------------------------------------------------- naive


class NaiveKeys(NamedTuple):
    key_hi: jnp.ndarray
    key_lo: jnp.ndarray
    valid: jnp.ndarray


def naive_som_keys(
    subj_vals: jnp.ndarray, obj_vals: jnp.ndarray, p: StaticTripleParams
) -> NaiveKeys:
    """Generate ALL candidate triple keys (duplicates included) — the naive
    engine defers duplicate elimination to a final sort pass."""
    hi, lo = hashing.triple_key(
        p.subj_tmpl, subj_vals, p.pred_id, p.obj_tmpl, obj_vals
    )
    return NaiveKeys(hi, lo, jnp.ones(subj_vals.shape, dtype=bool))


def naive_ojm_keys(
    parent_keys: jnp.ndarray,
    parent_subjects: jnp.ndarray,
    child_subj_vals: jnp.ndarray,
    child_join_keys: jnp.ndarray,
    p: StaticTripleParams,
    max_matches: int,
) -> tuple[NaiveKeys, jnp.ndarray, jnp.ndarray]:
    """Nested-loop join (|N_parent|·|N_child| comparisons) producing all
    result triples with duplicates.  Returns (keys, subjects, truncated)."""
    jr = naive.nested_loop_join(
        parent_keys, parent_subjects, child_join_keys, max_matches
    )
    m, K = jr.subjects.shape
    subj = jnp.broadcast_to(child_subj_vals[:, None], (m, K)).reshape(-1)
    obj = jr.subjects.reshape(-1)
    hi, lo = hashing.triple_key(p.subj_tmpl, subj, p.pred_id, p.obj_tmpl, obj)
    return (
        NaiveKeys(hi, lo, jr.valid.reshape(-1)),
        jr.subjects,
        jr.truncated,
    )


def naive_dedup(keys: NaiveKeys) -> naive.SortDedupResult:
    """The Θ(N log N) merge-sort duplicate elimination of the baseline."""
    return naive.sort_dedup_masked(keys.key_hi, keys.key_lo, keys.valid)
