"""64-bit hashing on TPU-native 32-bit lanes.

TPUs have no native 64-bit integer datapath (XLA emulates ``s64`` with pairs
of ``u32`` ops), and jax defaults to ``x64`` disabled.  We therefore represent
a 64-bit hash as an explicit pair of ``uint32`` arrays ``(hi, lo)`` and build
the mixing functions from 32-bit arithmetic.  This *is* the TPU-native
adaptation of the paper's hash keys (DESIGN.md §2): every RDF triple is
collapsed to a 64-bit key ``h(subject, predicate, object)`` and all duplicate
elimination happens on those keys.

The mixer is murmur3's 32-bit finalizer applied per-lane with cross-lane
feedback, which gives full 64-bit avalanche for our purposes (validated by
collision tests in ``tests/test_hashing.py``).
"""

from __future__ import annotations

import jax.numpy as jnp

# Sentinel marking an empty hash-set slot.  ``mix64`` never returns the
# sentinel pair (it is explicitly remapped), so EMPTY is unambiguous.
EMPTY: int = 0xFFFFFFFF

# plain ints (not jnp arrays): Pallas kernels may not capture traced
# constants, so these are materialized inline as u32 literals at trace time
_M3_C1 = 0x85EBCA6B
_M3_C2 = 0xC2B2AE35
_GOLDEN = 0x9E3779B9  # 2^32 / phi — Weyl increment


def _u32(x) -> jnp.ndarray:
    return jnp.asarray(x).astype(jnp.uint32)


def fmix32(h: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer: full avalanche on a uint32 lane."""
    h = _u32(h)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_M3_C1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_M3_C2)
    h = h ^ (h >> 16)
    return h


def combine32(acc: jnp.ndarray, word: jnp.ndarray) -> jnp.ndarray:
    """Fold one uint32 word into a running accumulator (boost::hash_combine
    style, with the murmur finalizer as the mixer)."""
    acc = _u32(acc)
    word = fmix32(_u32(word))
    return fmix32(acc ^ (word + jnp.uint32(_GOLDEN) + (acc << 6) + (acc >> 2)))


def mix64(words, salt: int = 0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Hash a sequence of int32/uint32 arrays (broadcastable) to a 64-bit key
    expressed as ``(hi, lo)`` uint32 pairs.

    Two independent accumulator lanes are seeded differently and each absorbs
    every word; the lanes are cross-mixed at the end so hi and lo are not
    correlated.  The EMPTY/EMPTY sentinel pair is remapped to keep it
    reserved for "unoccupied slot".
    """
    hi = fmix32(jnp.uint32(0x243F6A88 ^ (salt & 0xFFFFFFFF)))  # pi fractional
    lo = fmix32(jnp.uint32(0x13198A2E ^ ((salt >> 32) & 0xFFFFFFFF)))
    for w in words:
        w = _u32(w)
        hi = combine32(hi, w)
        lo = combine32(lo, w ^ jnp.uint32(_GOLDEN))
    # cross-lane avalanche — sequential (lo2 absorbs the *mixed* hi2) so the
    # (hi, lo) -> (hi2, lo2) map is a bijection on the full 64-bit state.  A
    # parallel xor of shifted lanes is NOT: (h ^ (l>>1), l ^ (h<<1)) has a
    # 2^31-element kernel (any dh with top bit clear and dl == dh<<1), which
    # collapses the key space to ~33 effective bits and silently drops
    # triples at the paper's 100K/1M benchmark scale.
    hi2 = fmix32(hi ^ (lo >> 1))
    lo2 = fmix32(lo ^ hi2)
    # keep the sentinel reserved
    is_sent = (hi2 == jnp.uint32(EMPTY)) & (lo2 == jnp.uint32(EMPTY))
    lo2 = jnp.where(is_sent, jnp.uint32(EMPTY - 1), lo2)
    return hi2, lo2


def triple_key(
    subj_tmpl, subj_val, pred_id, obj_tmpl, obj_val
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """64-bit identity of an RDF triple from its dictionary-encoded parts.

    ``*_tmpl`` are term-template ids (static per mapping rule), ``*_val`` the
    per-row value ids, ``pred_id`` the predicate's term id.  This is the PTT
    hash key of the paper, computed vectorized on device.
    """
    return mix64([subj_tmpl, subj_val, pred_id, obj_tmpl, obj_val])
