"""Streaming executor — the paper's engine loop, batched and jitted.

Pipeline per run (paper Fig. 2):

  RML doc --plan--> physical ops --stream--> jitted operator steps
       sources -> columnar load -> dictionary encode -> fixed-shape batches
  PTT/PJTT state threads through the jitted steps (donated buffers);
  the Knowledge Graph Creator appends the ``is_new`` triples incrementally.

Engines:
  * ``optimized`` — the SDM-RDFizer operators (PTT incremental dedup, PJTT
    index join).
  * ``naive``     — SDM-RDFizer⁻: generate everything, nested-loop joins,
    one merge-sort dedup per predicate at the end.

Both produce identical knowledge graphs (asserted in tests); they differ
only in operation count / wall-time, which is the paper's claim.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, naive, pjtt, planner
from repro.core import hashset
from repro.core.hashset import next_pow2
from repro.data import pipeline
from repro.data.encoder import Dictionary, join_columns
from repro.data.sources import SourceCache
from repro.data.terms import render_term
from repro.rml.model import MappingDocument


# --------------------------------------------------------------------------
# jitted steps (module scope: one compilation per shape, shared across ops)
# --------------------------------------------------------------------------


@partial(jax.jit, donate_argnums=(0, 1))
def _dedup_step(hi, lo, subj_tmpl, subj_vals, pred_id, obj_tmpl, obj_vals, valid):
    """SOM/ORM/CLASS step: triple keys -> masked PTT insert."""
    khi, klo = hashing.triple_key(subj_tmpl, subj_vals, pred_id, obj_tmpl, obj_vals)
    res = hashset.insert_masked(hashset.HashSet(hi, lo), khi, klo, valid)
    return res.table.hi, res.table.lo, res.is_new, res.overflowed


@partial(jax.jit, static_argnums=(8,), donate_argnums=(0, 1))
def _ojm_sorted_step(
    hi, lo, skeys, ssubj, subj_tmpl, subj_vals, pred_id, obj_tmpl, max_matches,
    child_keys, valid,
):
    """OJM step, sorted PJTT: probe spans -> expand -> masked PTT insert."""
    pr = pjtt.probe_sorted(pjtt.PJTTSorted(skeys, ssubj), child_keys, max_matches)
    m, K = pr.subjects.shape
    subj = jnp.broadcast_to(subj_vals[:, None], (m, K)).reshape(-1)
    obj = pr.subjects.reshape(-1)
    v = (pr.valid & valid[:, None]).reshape(-1)
    khi, klo = hashing.triple_key(subj_tmpl, subj, pred_id, obj_tmpl, obj)
    res = hashset.insert_masked(hashset.HashSet(hi, lo), khi, klo, v)
    return (
        res.table.hi, res.table.lo,
        res.is_new.reshape(m, K), pr.subjects, v.reshape(m, K),
        res.overflowed, pr.truncated,
    )


@partial(jax.jit, static_argnums=(10,), donate_argnums=(0, 1))
def _ojm_hash_step(
    hi, lo, tkey, tstart, tcount, ssubj, subj_tmpl, subj_vals, pred_id, obj_tmpl,
    max_matches, child_keys, valid,
):
    """OJM step, hash PJTT."""
    pr = pjtt.probe_hash(
        pjtt.PJTTHash(tkey, tstart, tcount, ssubj), child_keys, max_matches
    )
    m, K = pr.subjects.shape
    subj = jnp.broadcast_to(subj_vals[:, None], (m, K)).reshape(-1)
    obj = pr.subjects.reshape(-1)
    v = (pr.valid & valid[:, None]).reshape(-1)
    khi, klo = hashing.triple_key(subj_tmpl, subj, pred_id, obj_tmpl, obj)
    res = hashset.insert_masked(hashset.HashSet(hi, lo), khi, klo, v)
    return (
        res.table.hi, res.table.lo,
        res.is_new.reshape(m, K), pr.subjects, v.reshape(m, K),
        res.overflowed, pr.truncated,
    )


@jax.jit
def _naive_keys_step(subj_tmpl, subj_vals, pred_id, obj_tmpl, obj_vals):
    return hashing.triple_key(subj_tmpl, subj_vals, pred_id, obj_tmpl, obj_vals)


@partial(jax.jit, static_argnums=(2,))
def _naive_join_step(parent_keys, parent_subjects, max_matches, child_keys):
    return naive.nested_loop_join(parent_keys, parent_subjects, child_keys, max_matches)


@jax.jit
def _naive_dedup(khi, klo, valid):
    return naive.sort_dedup_masked(khi, klo, valid)


@jax.jit
def _build_sorted(keys, subjects):
    return pjtt.build_sorted(keys, subjects)


@jax.jit
def _build_hash(keys, subjects):
    return pjtt.build_hash(keys, subjects)


@jax.jit
def _span_stats(skeys, child_keys):
    s = jnp.searchsorted(skeys, child_keys, side="left")
    e = jnp.searchsorted(skeys, child_keys, side="right")
    cnt = e - s
    return jnp.sum(cnt), jnp.max(cnt)


# --------------------------------------------------------------------------
# results
# --------------------------------------------------------------------------


@dataclasses.dataclass
class PredicateStats:
    """Per-predicate cost accounting, mirroring the paper's φ expressions."""

    kind: str
    n_candidates: int = 0   # |N_p|
    n_unique: int = 0       # |S_p|
    n_parent: int = 0
    n_child: int = 0

    def phi_optimized(self) -> float:
        base = self.n_candidates + 2 * self.n_unique
        if self.kind == "OJM":
            return 2 * self.n_parent + self.n_child + base
        return base

    def phi_naive(self) -> float:
        n = max(self.n_candidates, 1)
        base = self.n_candidates + self.n_unique + n * np.log2(n)
        if self.kind == "OJM":
            return self.n_parent * self.n_child + base
        return base


@dataclasses.dataclass
class KGResult:
    """The created knowledge graph, term-id form + dictionaries for decode."""

    dictionary: Dictionary
    # predicate -> dict of parallel int32 arrays
    triples: dict[str, dict[str, np.ndarray]]
    stats: dict[str, PredicateStats]
    wall_time_s: float = 0.0
    engine: str = "optimized"

    @property
    def n_triples(self) -> int:
        return sum(len(t["subj_val"]) for t in self.triples.values())

    def iter_ntriples(self):
        d = self.dictionary
        for pred, t in self.triples.items():
            for i in range(len(t["subj_val"])):
                s = _render(d, int(t["subj_pat"][i]), int(t["subj_val"][i]))
                o = _render(d, int(t["obj_pat"][i]), int(t["obj_val"][i]))
                yield f"{s} <{pred}> {o} ."

    def write_ntriples(self, path: str) -> int:
        n = 0
        with open(path, "w", encoding="utf-8") as f:
            for line in self.iter_ntriples():
                f.write(line + "\n")
                n += 1
        return n

    def sorted_ntriples(self) -> list[str]:
        """Rendered triples in sorted order — the engine-independent identity
        (dictionary ids differ between eager and streamed runs, rendered
        strings do not)."""
        return sorted(self.iter_ntriples())

    def to_store(self):
        """Servable form: a queryable, persistable ``repro.kg.TripleStore``
        built array-at-a-time over these int32 columns (works identically
        for eager and streamed runs)."""
        from repro.kg.store import TripleStore

        return TripleStore.from_kg(self.dictionary, self.triples)

    def as_set(self) -> set[tuple]:
        """Exact triple identity set (for engine-equivalence assertions)."""
        out = set()
        for pred, t in self.triples.items():
            for i in range(len(t["subj_val"])):
                out.add(
                    (
                        pred,
                        int(t["subj_pat"][i]),
                        int(t["subj_val"][i]),
                        int(t["obj_pat"][i]),
                        int(t["obj_val"][i]),
                    )
                )
        return out


def _plan_gauges(mplan) -> None:
    """Publish the mapping plan's shape into ``repro.obs`` (plan.* rows
    in the metrics catalog)."""
    from repro.obs import get_registry

    reg = get_registry()
    reg.gauge("plan.groups").set(len(mplan.groups))
    reg.gauge("plan.sources").set(len(mplan.sources))
    reg.gauge("plan.shared_terms").set(len(mplan.shared))
    reg.gauge("plan.rules").set(len(mplan.exec_plan.ops))


def _sources_by_key(doc: MappingDocument) -> dict:
    """planner source_key -> LogicalSource (keys match the planned ops)."""
    return {
        planner.source_key(tm.source): tm.source
        for tm in doc.triples_maps.values()
    }


# shared with the repro.kg decode path: full N-Triples escaping, not just `"`
_render = render_term


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------


@dataclasses.dataclass
class EngineConfig:
    engine: str = "optimized"        # optimized | naive
    join_strategy: str = "sorted"    # sorted | hash
    batch_size: int = 1 << 16
    load_factor: float = 0.6
    max_matches: int | None = None   # None -> derived from true max span
    # streaming ingestion (repro.stream): block-at-a-time, out-of-core
    stream: bool = False
    block_rows: int = 1 << 14
    prefetch_blocks: int = 2
    # mapping-level planning (repro.rml.plan): projection pushdown into
    # the streamed read, FunMap-style shared-term factoring, and
    # group-by-group rule execution.  Output is byte-identical either
    # way (property-tested); False keeps the unplanned reference path.
    mapping_plan: bool = True


class Engine:
    def __init__(self, config: EngineConfig | None = None):
        self.config = config or EngineConfig()

    # -- helpers -------------------------------------------------------------

    def _term_values(
        self, dct: Dictionary, table: dict[str, np.ndarray], columns: tuple[str, ...]
    ) -> np.ndarray:
        if not columns:  # constant term: single id 0 slot (value unused)
            n = len(next(iter(table.values()))) if table else 0
            return np.zeros(n, dtype=np.int32)
        return dct.encode(join_columns([table[c] for c in columns]))

    def run(
        self,
        doc: MappingDocument,
        data_root: str = ".",
        tables: dict[str, dict[str, np.ndarray]] | None = None,
    ) -> KGResult:
        """Create the knowledge graph.  ``tables`` optionally bypasses disk:
        maps source key ('csv:child.csv') -> columnar dict."""
        t0 = time.perf_counter()
        cfg = self.config
        mplan = None
        if cfg.mapping_plan:
            from repro.rml.plan import build_plan

            mplan = build_plan(doc)
            _plan_gauges(mplan)
        if cfg.stream:
            if cfg.engine != "optimized":
                raise ValueError(
                    "stream=True supports only the optimized engine "
                    "(the naive engine materializes everything by design)"
                )
            if cfg.block_rows < 1:
                raise ValueError(f"block_rows must be >= 1, got {cfg.block_rows}")
            return self._run_stream(doc, data_root, tables, t0, mplan=mplan)
        exec_plan = mplan.exec_plan if mplan is not None else planner.plan(doc)
        dct = Dictionary()
        cache = SourceCache(data_root)
        sources_by_key = _sources_by_key(doc)

        def get_table(source_key: str):
            if tables is not None and source_key in tables:
                return tables[source_key]
            from repro.rml.model import LogicalSource

            src = sources_by_key.get(source_key)
            if src is None:
                fmt, path, iterator = planner.parse_source_key(source_key)
                src = LogicalSource(path=path, fmt=fmt, iterator=iterator)
            return cache.get(src)

        # ---- encode the value columns each op needs (once per column set)
        value_cache: dict[tuple, np.ndarray] = {}

        def values_for(source_key: str, columns: tuple[str, ...]) -> np.ndarray:
            key = (source_key, columns)
            if key not in value_cache:
                value_cache[key] = self._term_values(
                    dct, get_table(source_key), columns
                )
            return value_cache[key]

        # ---- build PJTTs once per (parent map, join column)
        indexes: dict[str, tuple] = {}
        parent_meta: dict[str, tuple[int, np.ndarray]] = {}
        for pkey, (psrc, pcol, _ppat, pcols) in exec_plan.pjtt_builds.items():
            pkeys = values_for(psrc, (pcol,))
            psubj = values_for(psrc, pcols)
            kd = jnp.asarray(pkeys)
            sd = jnp.asarray(psubj)
            if cfg.engine == "naive":
                indexes[pkey] = (kd, sd)  # raw arrays for the nested loop
            elif cfg.join_strategy == "hash":
                indexes[pkey] = _build_hash(kd, sd)
            else:
                indexes[pkey] = _build_sorted(kd, sd)
            parent_meta[pkey] = (len(pkeys), np.asarray(pkeys))

        # ---- per-predicate candidate estimate -> PTT capacity
        stats: dict[str, PredicateStats] = {}
        pred_candidates: dict[str, int] = {}
        op_spans: dict[int, tuple[int, int]] = {}  # op idx -> (|N_p|, max span)
        for pred, op_idxs in exec_plan.by_predicate.items():
            total = 0
            kind = exec_plan.ops[op_idxs[0]].kind
            for i in op_idxs:
                op = exec_plan.ops[i]
                n_child = len(values_for(op.source_key, op.subj_columns))
                if op.kind == "OJM":
                    # exact |N_p| and max span from the sorted parent keys;
                    # sizes the PTT and the padded-ragged probe width
                    skeys = jnp.sort(
                        jnp.asarray(
                            values_for(op.parent_source_key, (op.parent_join_column,))
                        )
                    )
                    ck = jnp.asarray(
                        values_for(op.source_key, (op.join_child_column,))
                    )
                    tot, mx = _span_stats(skeys, ck)
                    op_spans[i] = (int(tot), int(mx))
                    total += int(tot)
                else:
                    op_spans[i] = (n_child, 1)
                    total += n_child
            pred_candidates[pred] = total
            stats[pred] = PredicateStats(kind=kind)

        # ---- run the ops: group-by-group along the mapping plan's DAG
        # when planning is on (groups are disjoint in predicates and
        # sources, so this only reorders work), else one flat pass
        triples_out: dict[str, dict[str, list[np.ndarray]]] = {}
        if mplan is not None:
            schedule = [
                (g, [(p, exec_plan.by_predicate[p]) for p in g.predicates])
                for g in mplan.groups
            ]
        else:
            schedule = [(None, list(exec_plan.by_predicate.items()))]
        from repro import obs

        for g, pred_items in schedule:
            span_args = {"group": g.index} if g is not None else {}
            with obs.span("plan_group", cat="plan", **span_args):
                if cfg.engine == "optimized":
                    self._run_optimized(
                        exec_plan, values_for, indexes, pred_candidates,
                        op_spans, stats, triples_out, dct,
                        pred_items=pred_items,
                    )
                else:
                    self._run_naive(
                        exec_plan, values_for, indexes, op_spans, stats,
                        triples_out, dct, pred_items=pred_items,
                    )

        # emit in the op plan's predicate order regardless of group
        # scheduling: the written KG is byte-identical planner-on/off
        final = {
            pred: {
                k: np.concatenate(v) if v else np.zeros(0, np.int32)
                for k, v in triples_out[pred].items()
            }
            for pred in exec_plan.by_predicate
        }
        return KGResult(
            dictionary=dct,
            triples=final,
            stats=stats,
            wall_time_s=time.perf_counter() - t0,
            engine=cfg.engine,
        )

    # -- shared per-batch step (eager and streamed paths) ----------------------

    def _consume_batch(
        self, op, spat, pid, opat, hi, lo, batch, index, K, out, st,
    ):
        """Push one fixed-shape padded batch through the jitted step for
        ``op``; appends emitted triples to ``out`` and accumulates ``st``.
        Returns ``(hi, lo, overflowed)``."""
        valid = jnp.asarray(batch.valid)
        sv = jnp.asarray(batch.arrays["subj"])
        if op.kind == "OJM":
            ck = jnp.asarray(batch.arrays["jkey"])
            if isinstance(index, pjtt.PJTTSorted):
                hi, lo, is_new, psubj, v, ovf, trunc = _ojm_sorted_step(
                    hi, lo, index.skeys, index.ssubj, spat, sv, pid,
                    opat, K, ck, valid,
                )
            else:
                hi, lo, is_new, psubj, v, ovf, trunc = _ojm_hash_step(
                    hi, lo, index.tkey, index.tstart, index.tcount,
                    index.ssubj, spat, sv, pid, opat, K, ck, valid,
                )
            if bool(trunc):
                raise RuntimeError(
                    f"PJTT span exceeded max_matches={K}; "
                    "re-run with a larger max_matches"
                )
            is_new_np = np.asarray(is_new)
            v_np = np.asarray(v)
            st.n_candidates += int(v_np.sum())
            emit = is_new_np & v_np
            rows, ks = np.nonzero(emit)
            sv_np = np.asarray(batch.arrays["subj"])
            ps_np = np.asarray(psubj)
            out["subj_val"].append(sv_np[rows].astype(np.int32))
            out["obj_val"].append(ps_np[rows, ks].astype(np.int32))
            n_emit = len(rows)
        else:
            ov = jnp.asarray(batch.arrays["obj"])
            hi, lo, is_new, ovf = _dedup_step(
                hi, lo, spat, sv, pid, opat, ov, valid
            )
            is_new_np = np.asarray(is_new)
            st.n_candidates += int(batch.valid.sum())
            rows = np.nonzero(is_new_np & batch.valid)[0]
            out["subj_val"].append(batch.arrays["subj"][rows].astype(np.int32))
            out["obj_val"].append(batch.arrays["obj"][rows].astype(np.int32))
            n_emit = len(rows)
        out["subj_pat"].append(np.full(n_emit, spat, np.int32))
        out["obj_pat"].append(np.full(n_emit, opat, np.int32))
        st.n_unique += n_emit
        return hi, lo, bool(ovf)

    # -- optimized engine ------------------------------------------------------

    def _run_optimized(
        self, exec_plan, values_for, indexes, pred_candidates, op_spans,
        stats, triples_out, dct: Dictionary, pred_items=None,
    ):
        cfg = self.config
        if pred_items is None:
            pred_items = exec_plan.by_predicate.items()
        for pred, op_idxs in pred_items:
            cap = next_pow2(int(pred_candidates[pred] / cfg.load_factor) + 16)
            while True:  # overflow -> double capacity and replay the predicate
                table = hashset.make(cap)
                hi, lo = table.hi, table.lo
                out = {k: [] for k in ("subj_pat", "subj_val", "obj_pat", "obj_val")}
                st = stats[pred]
                st.n_candidates = st.n_unique = st.n_parent = st.n_child = 0
                overflow = False
                for i in op_idxs:
                    op = exec_plan.ops[i]
                    pid = np.int32(dct.encode_scalar(op.predicate))
                    spat = np.int32(dct.encode_scalar(op.subj_pattern))
                    opat = np.int32(dct.encode_scalar(op.obj_pattern))
                    subj_vals = values_for(op.source_key, op.subj_columns)
                    cols = {"subj": subj_vals}
                    if op.kind == "OJM":
                        cols["jkey"] = values_for(
                            op.source_key, (op.join_child_column,)
                        )
                    elif op.kind in ("SOM", "ORM"):
                        cols["obj"] = values_for(op.source_key, op.obj_columns)
                    else:  # CLASS: constant object
                        cols["obj"] = np.zeros_like(subj_vals)

                    n = len(subj_vals)
                    bs = min(cfg.batch_size, pipeline.pick_batch_size(n))
                    K = 1
                    if op.kind == "OJM":
                        tot, mx = op_spans[i]
                        K = cfg.max_matches or max(int(mx), 1)
                        st.n_parent += (
                            len(values_for(op.parent_source_key, (op.parent_join_column,)))
                        )
                        st.n_child += n
                    idx = indexes[op.pjtt_key] if op.kind == "OJM" else None
                    for batch in pipeline.batches(cols, bs):
                        hi, lo, ovf = self._consume_batch(
                            op, spat, pid, opat, hi, lo, batch, idx, K, out, st
                        )
                        if ovf:
                            overflow = True
                            break
                    if overflow:
                        break
                if not overflow:
                    triples_out[pred] = out
                    break
                cap *= 2  # replay this predicate with a bigger table

    # -- streamed optimized engine (repro.stream) ------------------------------

    def _run_stream(self, doc, data_root, tables, t0, mplan=None) -> KGResult:
        """Out-of-core KG creation.  Every source flows block-at-a-time
        through a lazy ``read -> project -> derive -> encode -> batch``
        Dataset; only dictionary-encoded int32 ids (and the PJTT indexes
        built from them) outlive a block, so host memory is bounded by
        O(block_rows) per raw column regardless of source size.  Sized like
        the eager engine (exact span stats, streamed), with the same
        overflow-replay fallback — a replay re-reads the source rather than
        re-using a cached table.

        With a :class:`~repro.rml.plan.MappingPlan` (``mapping_plan=True``)
        three planner-driven optimizations engage, none of which changes
        the produced KG: projections are pushed into the readers (pruned
        columns never materialize), shared term columns are evaluated once
        per source scan and served from an int32 cache, and the rule
        groups run as a DAG — each group's factored cache and PJTT indexes
        live only for that group."""
        import os

        from repro import obs
        from repro.stream import Dataset, read_source
        from repro.stream.block import Block
        from repro.stream.datasource import is_sharded_path

        cfg = self.config
        exec_plan = mplan.exec_plan if mplan is not None else planner.plan(doc)
        reg = obs.get_registry()
        dct = Dictionary()
        block_rows = cfg.block_rows
        # block_rows bounds I/O granularity; batch_size still bounds the
        # jitted device batch (a block is split into padded batches if the
        # user asked for a smaller device shape)
        device_rows = min(cfg.batch_size, block_rows)
        prefetch = cfg.prefetch_blocks
        sources_by_key = _sources_by_key(doc)

        def resolve(source_key: str) -> tuple[str, str, str | None]:
            """source_key -> (fmt, absolute path, iterator)."""
            src = sources_by_key.get(source_key)
            if src is not None:
                fmt, path, iterator = src.fmt, src.path, src.iterator
            else:
                fmt, path, iterator = planner.parse_source_key(source_key)
            if not os.path.isabs(path):
                path = os.path.join(data_root, path)
            return fmt, path, iterator

        def dataset_for(source_key: str) -> Dataset:
            if tables is not None and source_key in tables:
                return Dataset.from_table(tables[source_key], block_rows=block_rows)
            fmt, path, iterator = resolve(source_key)
            return read_source(
                path, fmt=fmt, block_rows=block_rows, iterator=iterator
            )

        def fill_of(source_key: str) -> str | None:
            """Projection fill policy: "" (union-fill) for genuinely
            heterogeneous sources — JSON records and glob-sharded files —
            matching the eager loader's key-union; None (strict KeyError,
            matching the eager engine's table[c]) for fixed-schema
            single-file CSV/TSV and the tables bypass, where a missing
            column is a mapping typo."""
            if tables is not None and source_key in tables:
                return None
            fmt, path, _ = resolve(source_key)
            if fmt == "json":
                return ""
            return "" if is_sharded_path(path) else None

        def derived(block: Block, columns: tuple) -> np.ndarray:
            """String value column for a (possibly multi-column) term; a
            constant term is int32 zeros, which Encode passes through."""
            if not columns:
                return np.zeros(block.n_rows, dtype=np.int32)
            return join_columns([block.columns[c] for c in columns])

        def op_dataset(op) -> Dataset:
            if op.kind == "OJM":
                extra: tuple = (op.join_child_column,)
            elif op.kind in ("SOM", "ORM"):
                extra = tuple(op.obj_columns)
            else:
                extra = ()
            needed = tuple(dict.fromkeys(tuple(op.subj_columns) + extra))

            def to_term_columns(block: Block) -> Block:
                cols = {"subj": derived(block, op.subj_columns)}
                if op.kind == "OJM":
                    cols["jkey"] = block.columns[op.join_child_column]
                elif op.kind in ("SOM", "ORM"):
                    cols["obj"] = derived(block, op.obj_columns)
                else:  # CLASS: constant object
                    cols["obj"] = np.zeros(block.n_rows, dtype=np.int32)
                return Block(cols)

            # all-constant ops read no columns; skip the projection entirely
            # (a zero-column block would lose its row count) and let
            # to_term_columns derive zeros from the raw block's n_rows
            ds = dataset_for(op.source_key)
            if needed:
                ds = ds.project(*needed, fill=fill_of(op.source_key))
            return ds.map_blocks(to_term_columns).encode(dct).batch(block_rows)

        # ---- referenced-column validation for union-fill sources: a column
        # absent from EVERY record is a mapping typo (the eager engine's
        # table[c] raises on it); fill-mode projection would otherwise
        # silently emit ""-term triples.  The scan also yields row counts,
        # sparing these sources the sizing count pass below.
        refcols: dict[str, set] = {}
        for op in exec_plan.ops:
            cols = refcols.setdefault(op.source_key, set())
            cols.update(op.subj_columns)
            if op.kind == "OJM":
                cols.add(op.join_child_column)
            else:
                cols.update(op.obj_columns)
        for psrc_, pcol_, _ppat_, pcols_ in exec_plan.pjtt_builds.values():
            cols = refcols.setdefault(psrc_, set())
            cols.add(pcol_)
            cols.update(pcols_)
        row_counts: dict[str, int] = {}
        for skey, cols in refcols.items():
            if not cols or fill_of(skey) is None:
                continue
            seen: set = set()
            n = 0
            for block in dataset_for(skey).iter_blocks(prefetch):
                seen |= set(block.schema)
                n += block.n_rows
            row_counts[skey] = n
            missing = cols - seen
            if missing:
                raise KeyError(
                    f"columns {sorted(missing)} not present in any record of "
                    f"source {skey!r}"
                )

        # ---- planner-on state: the factored shared-term cache.  Keyed
        # (source_key, columns) like the eager path's value cache, holding
        # the dictionary-encoded int32 value column of a term evaluated by
        # >= 2 sites.  Filled per group, freed when the group completes.
        value_cache: dict[tuple, np.ndarray] = {}

        def build_factored(group) -> None:
            """One streaming pass per source with shared terms: evaluate
            and encode every factored term column of the group (FunMap's
            pre-materialization, scoped to the group's lifetime)."""
            per_src: dict[str, list[tuple]] = {}
            for (skey, colset), _sh in mplan.shared.items():
                if skey in group.sources:
                    per_src.setdefault(skey, []).append(colset)
            for skey, colsets in sorted(per_src.items()):
                union_raw = tuple(
                    dict.fromkeys(c for cols in colsets for c in cols)
                )
                ds = dataset_for(skey).project(
                    *union_raw, fill=fill_of(skey), pushdown=True
                )
                chunks: dict[tuple, list] = {cols: [] for cols in colsets}
                n = 0
                for block in ds.iter_blocks(prefetch):
                    for cols in colsets:
                        chunks[cols].append(dct.encode(derived(block, cols)))
                    n += block.n_rows
                for cols in colsets:
                    value_cache[(skey, cols)] = (
                        np.concatenate(chunks[cols])
                        if chunks[cols]
                        else np.zeros(0, np.int32)
                    )
                row_counts[skey] = n

        def op_blocks(op):
            """Planner-on block stream for one op: slots whose term column
            is in the factored cache are sliced from it; remaining slots
            stream the source with the projection pushed into the read."""
            slots: list[tuple[str, tuple]] = [("subj", tuple(op.subj_columns))]
            if op.kind == "OJM":
                slots.append(("jkey", (op.join_child_column,)))
            elif op.kind in ("SOM", "ORM"):
                slots.append(("obj", tuple(op.obj_columns)))
            else:  # CLASS: constant object
                slots.append(("obj", ()))
            cached: dict[str, np.ndarray] = {}
            uncached: list[tuple[str, tuple]] = []
            for name, cols in slots:
                if not cols:
                    continue  # constant slot: zeros derived per block
                arr = value_cache.get((op.source_key, cols))
                if arr is not None:
                    cached[name] = arr
                else:
                    uncached.append((name, cols))
            if not uncached:
                # fully factored (or all-constant): no re-read at all
                if cached:
                    length = len(next(iter(cached.values())))
                else:
                    length = row_counts.get(op.source_key)
                    if length is None:
                        length = dataset_for(op.source_key).count()
                        row_counts[op.source_key] = length
                for start in range(0, length, block_rows):
                    end = min(start + block_rows, length)
                    cols_out = {}
                    for name, _cols in slots:
                        if name in cached:
                            cols_out[name] = cached[name][start:end]
                        else:
                            cols_out[name] = np.zeros(end - start, np.int32)
                    reg.inc("plan.factored_rows", (end - start) * len(cached))
                    yield Block(cols_out)
                return
            needed = tuple(
                dict.fromkeys(c for _n, cols in uncached for c in cols)
            )
            ds = dataset_for(op.source_key).project(
                *needed, fill=fill_of(op.source_key), pushdown=True
            )
            offset = 0
            for block in ds.iter_blocks(prefetch):
                m = block.n_rows
                cols_out = {}
                for name, cols in slots:
                    if name in cached:
                        cols_out[name] = cached[name][offset:offset + m]
                    elif not cols:
                        cols_out[name] = np.zeros(m, np.int32)
                    else:
                        cols_out[name] = dct.encode(derived(block, cols))
                if cached:
                    reg.inc("plan.factored_rows", m * len(cached))
                offset += m
                yield Block(cols_out)

        # ---- PJTT builds: stream the parent once; retain only int32 ids
        indexes: dict[str, tuple] = {}
        parent_counts: dict[str, int] = {}
        sorted_parent_keys: dict[str, np.ndarray] = {}

        def build_pjtts(pjtt_items) -> None:
            for pkey, (psrc, pcol, _ppat, pcols) in pjtt_items:
                kc = value_cache.get((psrc, (pcol,)))
                sc = value_cache.get((psrc, tuple(pcols)))
                if kc is not None and (sc is not None or not pcols):
                    # both columns already factored: build from the cache
                    pkeys = kc
                    psubj = (
                        sc if sc is not None else np.zeros(len(kc), np.int32)
                    )
                    reg.inc("plan.factored_rows", 2 * len(pkeys))
                else:
                    needed = tuple(dict.fromkeys((pcol,) + tuple(pcols)))

                    def to_index_columns(
                        block: Block, pcol=pcol, pcols=pcols
                    ) -> Block:
                        return Block(
                            {
                                "key": block.columns[pcol],
                                "subj": derived(block, pcols),
                            }
                        )

                    ds = dataset_for(psrc).project(
                        *needed, fill=fill_of(psrc),
                        pushdown=mplan is not None,
                    )
                    ds = ds.map_blocks(to_index_columns).encode(dct)
                    kchunks, schunks = [], []
                    for block in ds.iter_blocks(prefetch):
                        kchunks.append(block.columns["key"])
                        schunks.append(block.columns["subj"])
                    pkeys = (
                        np.concatenate(kchunks) if kchunks
                        else np.zeros(0, np.int32)
                    )
                    psubj = (
                        np.concatenate(schunks) if schunks
                        else np.zeros(0, np.int32)
                    )
                kd, sd = jnp.asarray(pkeys), jnp.asarray(psubj)
                if cfg.join_strategy == "hash":
                    indexes[pkey] = _build_hash(kd, sd)
                else:
                    indexes[pkey] = _build_sorted(kd, sd)
                parent_counts[pkey] = len(pkeys)
                sorted_parent_keys[pkey] = np.sort(np.asarray(pkeys))
                row_counts[psrc] = len(pkeys)

        # ---- sizing pre-pass: exact |N_p| and max span, streamed
        stats: dict[str, PredicateStats] = {}
        pred_candidates: dict[str, int] = {}
        op_spans: dict[int, tuple[int, int]] = {}

        def size_predicates(pred_list) -> None:
            for pred in pred_list:
                op_idxs = exec_plan.by_predicate[pred]
                total = 0
                stats[pred] = PredicateStats(
                    kind=exec_plan.ops[op_idxs[0]].kind
                )
                for i in op_idxs:
                    op = exec_plan.ops[i]
                    if op.kind == "OJM":
                        spk = sorted_parent_keys[op.pjtt_key]
                        ck_all = value_cache.get(
                            (op.source_key, (op.join_child_column,))
                        )
                        if ck_all is not None:
                            # factored child key: span stats with no re-read
                            cnt = np.searchsorted(spk, ck_all, side="right") \
                                - np.searchsorted(spk, ck_all, side="left")
                            tot = int(cnt.sum()) if len(cnt) else 0
                            mx = int(cnt.max()) if len(cnt) else 0
                            row_counts[op.source_key] = len(ck_all)
                        else:
                            tot = mx = n = 0
                            ds = (
                                dataset_for(op.source_key)
                                .project(
                                    op.join_child_column,
                                    fill=fill_of(op.source_key),
                                    pushdown=mplan is not None,
                                )
                                .encode(dct)
                            )
                            for block in ds.iter_blocks(prefetch):
                                ck = block.columns[op.join_child_column]
                                cnt = np.searchsorted(spk, ck, side="right") \
                                    - np.searchsorted(spk, ck, side="left")
                                if len(cnt):
                                    tot += int(cnt.sum())
                                    mx = max(mx, int(cnt.max()))
                                n += block.n_rows
                            row_counts[op.source_key] = n
                        op_spans[i] = (tot, mx)
                        total += tot
                    else:
                        n = row_counts.get(op.source_key)
                        if n is None:
                            n = dataset_for(op.source_key).count()
                            row_counts[op.source_key] = n
                        op_spans[i] = (n, 1)
                        total += n
                pred_candidates[pred] = total

        # ---- run the ops, block-at-a-time
        triples_out: dict[str, dict[str, list[np.ndarray]]] = {}

        def run_predicates(pred_list) -> None:
            for pred in pred_list:
                op_idxs = exec_plan.by_predicate[pred]
                cap = next_pow2(int(pred_candidates[pred] / cfg.load_factor) + 16)
                while True:  # overflow -> double capacity, re-stream
                    table = hashset.make(cap)
                    hi, lo = table.hi, table.lo
                    out = {
                        k: []
                        for k in ("subj_pat", "subj_val", "obj_pat", "obj_val")
                    }
                    st = stats[pred]
                    st.n_candidates = st.n_unique = st.n_parent = st.n_child = 0
                    overflow = False
                    for i in op_idxs:
                        op = exec_plan.ops[i]
                        pid = np.int32(dct.encode_scalar(op.predicate))
                        spat = np.int32(dct.encode_scalar(op.subj_pattern))
                        opat = np.int32(dct.encode_scalar(op.obj_pattern))
                        idx = None
                        K = 1
                        if op.kind == "OJM":
                            idx = indexes[op.pjtt_key]
                            _tot, mx = op_spans[i]
                            K = cfg.max_matches or max(int(mx), 1)
                            st.n_parent += parent_counts[op.pjtt_key]
                            st.n_child += row_counts[op.source_key]
                        blocks = (
                            op_blocks(op)
                            if mplan is not None
                            else op_dataset(op).iter_blocks(prefetch)
                        )
                        for block in blocks:
                            for batch in pipeline.batches(
                                block.columns, device_rows
                            ):
                                hi, lo, ovf = self._consume_batch(
                                    op, spat, pid, opat, hi, lo, batch,
                                    idx, K, out, st,
                                )
                                if ovf:
                                    overflow = True
                                    break
                            if overflow:
                                break
                        if overflow:
                            break
                    if not overflow:
                        triples_out[pred] = out
                        break
                    cap *= 2

        if mplan is None:
            build_pjtts(exec_plan.pjtt_builds.items())
            size_predicates(list(exec_plan.by_predicate))
            run_predicates(list(exec_plan.by_predicate))
        else:
            # group-by-group along the DAG: factored cache and PJTT
            # indexes are built at group entry and freed at group exit
            for g in mplan.groups:
                with obs.span("plan_group", cat="plan", group=g.index,
                              rules=len(g.op_indices)):
                    build_factored(g)
                    build_pjtts(
                        (pk, exec_plan.pjtt_builds[pk]) for pk in g.pjtt_keys
                    )
                    size_predicates(list(g.predicates))
                    run_predicates(list(g.predicates))
                for skey in g.sources:
                    for key in [k for k in value_cache if k[0] == skey]:
                        del value_cache[key]
                for pk in g.pjtt_keys:
                    indexes.pop(pk, None)
                    sorted_parent_keys.pop(pk, None)

        # emit in the op plan's predicate order regardless of group
        # scheduling: the written KG is byte-identical planner-on/off
        final = {
            pred: {
                k: np.concatenate(v) if v else np.zeros(0, np.int32)
                for k, v in triples_out[pred].items()
            }
            for pred in exec_plan.by_predicate
        }
        stats = {pred: stats[pred] for pred in exec_plan.by_predicate}
        return KGResult(
            dictionary=dct,
            triples=final,
            stats=stats,
            wall_time_s=time.perf_counter() - t0,
            engine="stream",
        )

    # -- naive engine ----------------------------------------------------------

    def _run_naive(
        self, exec_plan, values_for, indexes, op_spans, stats, triples_out,
        dct, pred_items=None,
    ):
        cfg = self.config
        if pred_items is None:
            pred_items = exec_plan.by_predicate.items()
        for pred, op_idxs in pred_items:
            khis, klos, valids = [], [], []
            svs, ovs, spats, opats = [], [], [], []
            st = stats[pred]
            for i in op_idxs:
                op = exec_plan.ops[i]
                pid = np.int32(dct.encode_scalar(op.predicate))
                spat = np.int32(dct.encode_scalar(op.subj_pattern))
                opat = np.int32(dct.encode_scalar(op.obj_pattern))
                subj_vals = values_for(op.source_key, op.subj_columns)
                n = len(subj_vals)
                if op.kind == "OJM":
                    pkeys, psubj = indexes[op.pjtt_key]
                    tot, mx = op_spans[i]
                    K = cfg.max_matches or max(int(mx), 1)
                    ck = jnp.asarray(values_for(op.source_key, (op.join_child_column,)))
                    jr = _naive_join_step(pkeys, psubj, K, ck)
                    if bool(jr.truncated):
                        raise RuntimeError("naive join exceeded max_matches")
                    m = n
                    subj = np.broadcast_to(subj_vals[:, None], (m, K)).reshape(-1)
                    obj = np.asarray(jr.subjects).reshape(-1)
                    v = np.asarray(jr.valid).reshape(-1)
                    khi, klo = _naive_keys_step(
                        spat, jnp.asarray(subj), pid, opat, jnp.asarray(obj)
                    )
                    st.n_parent += pkeys.shape[0]
                    st.n_child += n
                else:
                    if op.kind == "CLASS":
                        obj = np.zeros_like(subj_vals)
                    else:
                        obj = values_for(op.source_key, op.obj_columns)
                    subj, v = subj_vals, np.ones(n, bool)
                    khi, klo = _naive_keys_step(
                        spat, jnp.asarray(subj), pid, opat, jnp.asarray(obj)
                    )
                khis.append(np.asarray(khi))
                klos.append(np.asarray(klo))
                valids.append(v)
                svs.append(np.asarray(subj, dtype=np.int32))
                ovs.append(np.asarray(obj, dtype=np.int32))
                spats.append(np.full(len(v), spat, np.int32))
                opats.append(np.full(len(v), opat, np.int32))
            khi = np.concatenate(khis)
            klo = np.concatenate(klos)
            v = np.concatenate(valids)
            st.n_candidates = int(v.sum())
            dd = _naive_dedup(jnp.asarray(khi), jnp.asarray(klo), jnp.asarray(v))
            mask = np.asarray(dd.uniq_mask)
            st.n_unique = int(mask.sum())
            triples_out[pred] = {
                "subj_pat": [np.concatenate(spats)[mask]],
                "subj_val": [np.concatenate(svs)[mask]],
                "obj_pat": [np.concatenate(opats)[mask]],
                "obj_val": [np.concatenate(ovs)[mask]],
            }


def create_kg(
    doc: MappingDocument,
    data_root: str = ".",
    tables=None,
    **config,
) -> KGResult:
    """One-call public API: parse-level document -> knowledge graph."""
    return Engine(EngineConfig(**config)).run(doc, data_root=data_root, tables=tables)
