"""Fixed-capacity open-addressing hash set — the TPU-native PTT core.

The paper's Predicate Tuple Table is a CPU hash table probed one triple at a
time.  The TPU-native equivalent (DESIGN.md §2) is a *batched* insert over a
flat pair of uint32 arrays:

  round r:   slot_r(k) = (base(k) + r * step(k)) mod capacity      (double hash)
    1. gather occupants at every active key's slot
    2. keys whose occupant == key           -> done, duplicate
    3. keys whose occupant is EMPTY         -> try to claim: scatter-min the
       candidate's batch index into an arbitration array; exactly one winner
       per slot.  Winners write their key (unique slots -> plain scatter) and
       are done, new.
    4. losers re-read the slot after the winners' writes: if the new occupant
       equals their key (a same-key twin won), they are done, duplicate;
       otherwise they advance to round r+1.

First-wins semantics of the paper are preserved: two copies of the same key in
one batch elect exactly one winner.  The open-addressing lookup invariant
holds because a key only ever skips slots that are occupied by *other* keys.

Everything is functional: ``insert`` returns a new table.  Use
``jax.jit(..., donate_argnums=...)`` in callers to update in place.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hashing import EMPTY

MAX_PROBE_ROUNDS = 64
_I32_MAX = jnp.iinfo(jnp.int32).max


class HashSet(NamedTuple):
    """State of the set: parallel (hi, lo) key arrays, power-of-two sized."""

    hi: jnp.ndarray  # uint32[capacity]
    lo: jnp.ndarray  # uint32[capacity]

    @property
    def capacity(self) -> int:
        return self.hi.shape[0]


class InsertResult(NamedTuple):
    table: HashSet
    is_new: jnp.ndarray      # bool[n]  True -> key was not present before
    overflowed: jnp.ndarray  # bool[]   some key exhausted MAX_PROBE_ROUNDS


def next_pow2(n: int) -> int:
    n = max(int(n), 2)
    return 1 << (n - 1).bit_length()


def make(capacity: int) -> HashSet:
    """Allocate an empty set.  ``capacity`` is rounded up to a power of two;
    keep load factor <= 0.7 (the planner enforces this)."""
    cap = next_pow2(capacity)
    return HashSet(
        hi=jnp.full((cap,), EMPTY, dtype=jnp.uint32),
        lo=jnp.full((cap,), EMPTY, dtype=jnp.uint32),
    )


def _probe_geometry(key_hi: jnp.ndarray, key_lo: jnp.ndarray, cap: int):
    mask = jnp.uint32(cap - 1)
    base = key_lo & mask
    step = (key_hi | jnp.uint32(1)) & mask  # odd -> coprime with pow2 capacity
    step = step | jnp.uint32(1)
    return base, step, mask


class _S(NamedTuple):
    hi: jnp.ndarray
    lo: jnp.ndarray
    done: jnp.ndarray
    is_new: jnp.ndarray
    rnd: jnp.ndarray


def _insert_impl(
    table: HashSet,
    key_hi: jnp.ndarray,
    key_lo: jnp.ndarray,
    done0: jnp.ndarray,
) -> InsertResult:
    cap = table.capacity
    n = key_hi.shape[0]
    base, step, mask = _probe_geometry(key_hi, key_lo, cap)
    idx = jnp.arange(n, dtype=jnp.int32)

    def cond(s: _S):
        return (~jnp.all(s.done)) & (s.rnd < MAX_PROBE_ROUNDS)

    def body(s: _S) -> _S:
        slot = ((base + s.rnd.astype(jnp.uint32) * step) & mask).astype(jnp.int32)
        occ_hi = s.hi[slot]
        occ_lo = s.lo[slot]
        active = ~s.done
        found = active & (occ_hi == key_hi) & (occ_lo == key_lo)
        empty = active & (occ_hi == jnp.uint32(EMPTY)) & (occ_lo == jnp.uint32(EMPTY))

        # Arbitrate empty-slot claims: scatter-min of the batch index; exactly
        # one winner per slot.  Out-of-range index ``cap`` + mode="drop"
        # silences inactive lanes.
        claim = jnp.full((cap,), _I32_MAX, dtype=jnp.int32)
        claim = claim.at[jnp.where(empty, slot, cap)].min(
            jnp.where(empty, idx, _I32_MAX), mode="drop"
        )
        won = empty & (claim[slot] == idx)

        new_hi = s.hi.at[jnp.where(won, slot, cap)].set(key_hi, mode="drop")
        new_lo = s.lo.at[jnp.where(won, slot, cap)].set(key_lo, mode="drop")

        # Losers re-read: a same-key twin that won this round makes this key
        # a duplicate; without this re-check the twin would be inserted twice.
        lost = active & ~found & ~won
        twin = lost & (new_hi[slot] == key_hi) & (new_lo[slot] == key_lo)

        return _S(
            hi=new_hi,
            lo=new_lo,
            done=s.done | found | won | twin,
            is_new=s.is_new | won,
            rnd=s.rnd + 1,
        )

    init = _S(
        hi=table.hi,
        lo=table.lo,
        done=done0,
        is_new=jnp.zeros((n,), dtype=bool),
        rnd=jnp.int32(0),
    )
    out = jax.lax.while_loop(cond, body, init)
    return InsertResult(
        table=HashSet(hi=out.hi, lo=out.lo),
        is_new=out.is_new,
        overflowed=~jnp.all(out.done),
    )


def insert(table: HashSet, key_hi: jnp.ndarray, key_lo: jnp.ndarray) -> InsertResult:
    """Batched insert of n keys.  Returns the updated table, an ``is_new``
    mask, and an overflow flag (True if any key could not be placed within
    MAX_PROBE_ROUNDS — the caller must rebuild with a larger capacity)."""
    done0 = jnp.zeros((key_hi.shape[0],), dtype=bool)
    return _insert_impl(table, key_hi, key_lo, done0)


def insert_masked(
    table: HashSet, key_hi: jnp.ndarray, key_lo: jnp.ndarray, valid: jnp.ndarray
) -> InsertResult:
    """Insert only lanes where ``valid``; invalid lanes report is_new=False."""
    return _insert_impl(table, key_hi, key_lo, ~valid)


def contains(table: HashSet, key_hi: jnp.ndarray, key_lo: jnp.ndarray) -> jnp.ndarray:
    """Batched membership probe (no mutation)."""
    cap = table.capacity
    n = key_hi.shape[0]
    base, step, mask = _probe_geometry(key_hi, key_lo, cap)

    class _C(NamedTuple):
        done: jnp.ndarray
        found: jnp.ndarray
        rnd: jnp.ndarray

    def cond(s: _C):
        return (~jnp.all(s.done)) & (s.rnd < MAX_PROBE_ROUNDS)

    def body(s: _C) -> _C:
        slot = ((base + s.rnd.astype(jnp.uint32) * step) & mask).astype(jnp.int32)
        occ_hi = table.hi[slot]
        occ_lo = table.lo[slot]
        active = ~s.done
        hit = active & (occ_hi == key_hi) & (occ_lo == key_lo)
        empty = active & (occ_hi == jnp.uint32(EMPTY)) & (occ_lo == jnp.uint32(EMPTY))
        return _C(s.done | hit | empty, s.found | hit, s.rnd + 1)

    init = _C(
        done=jnp.zeros((n,), dtype=bool),
        found=jnp.zeros((n,), dtype=bool),
        rnd=jnp.int32(0),
    )
    out = jax.lax.while_loop(cond, body, init)
    return out.found


def count(table: HashSet) -> jnp.ndarray:
    """Number of occupied slots (= number of distinct keys inserted)."""
    return jnp.sum(
        ~((table.hi == jnp.uint32(EMPTY)) & (table.lo == jnp.uint32(EMPTY)))
    ).astype(jnp.int32)
