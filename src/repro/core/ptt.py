"""Predicate Tuple Table — per-predicate duplicate-elimination table.

A PTT is a :class:`repro.core.hashset.HashSet` over 64-bit triple keys (see
``hashing.triple_key``).  One PTT exists per predicate appearing in any
triples map, exactly as in the paper; the executor owns the ``pred -> PTT``
dictionary and threads table state through the jitted operator calls.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import hashing, hashset


class PTT(NamedTuple):
    table: hashset.HashSet

    @property
    def capacity(self) -> int:
        return self.table.capacity


def make(expected_distinct: int, load_factor: float = 0.6) -> PTT:
    """Size the table for an expected number of distinct triples."""
    return PTT(table=hashset.make(int(expected_distinct / load_factor) + 16))


class TripleInsertResult(NamedTuple):
    ptt: "PTT"
    is_new: jnp.ndarray
    overflowed: jnp.ndarray


def insert_triples(
    ptt: PTT,
    subj_tmpl,
    subj_vals: jnp.ndarray,
    pred_id,
    obj_tmpl,
    obj_vals: jnp.ndarray,
    valid: jnp.ndarray | None = None,
) -> TripleInsertResult:
    """Probe+insert a batch of candidate triples; ``is_new`` marks the ones
    that must be emitted to the knowledge graph (the paper's PTT check)."""
    hi, lo = hashing.triple_key(subj_tmpl, subj_vals, pred_id, obj_tmpl, obj_vals)
    if valid is None:
        res = hashset.insert(ptt.table, hi, lo)
    else:
        res = hashset.insert_masked(ptt.table, hi, lo, valid)
    return TripleInsertResult(
        ptt=PTT(table=res.table), is_new=res.is_new, overflowed=res.overflowed
    )


def distinct_count(ptt: PTT) -> jnp.ndarray:
    return hashset.count(ptt.table)
