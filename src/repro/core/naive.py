"""Naive operator implementations — the paper's SDM-RDFizer⁻ baseline.

The paper defines the baseline precisely (§III.iv):

* SOM/ORM: *generate every triple* (|N_p| of them, duplicates included), then
  run a merge-sort duplicate elimination (Θ(N_p log N_p)) before emitting.
* OJM: a *nested-loop join* (|N_parent| × |N_child| comparisons), then the
  same generate-all + sort-dedup pipeline.

These are implemented faithfully here in pure jnp (the blocked Pallas variant
of the nested loop lives in ``repro.kernels.nested_join``) so that Figures 5/6
of the paper can be reproduced engine-vs-baseline on identical data.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SortDedupResult(NamedTuple):
    uniq_mask: jnp.ndarray  # bool[n]  True on the first occurrence, in the
    #                         ORIGINAL order (scatter-back of the sorted mask)
    n_unique: jnp.ndarray   # int32[]


def sort_dedup(key_hi: jnp.ndarray, key_lo: jnp.ndarray) -> SortDedupResult:
    """Merge-sort duplicate elimination over 64-bit keys (hi, lo lanes).

    Lexicographic order via two stable argsorts; "first occurrence" follows
    original order because the sorts are stable.
    """
    o1 = jnp.argsort(key_lo, stable=True)
    h1, o1b = key_hi[o1], o1
    o2 = jnp.argsort(h1, stable=True)
    order = o1b[o2]
    sh, sl = key_hi[order], key_lo[order]
    first = jnp.concatenate(
        [jnp.array([True]), (sh[1:] != sh[:-1]) | (sl[1:] != sl[:-1])]
    )
    uniq_mask = jnp.zeros_like(first).at[order].set(first)
    return SortDedupResult(uniq_mask=uniq_mask, n_unique=jnp.sum(first).astype(jnp.int32))


def sort_dedup_masked(
    key_hi: jnp.ndarray, key_lo: jnp.ndarray, valid: jnp.ndarray
) -> SortDedupResult:
    """sort_dedup over valid lanes only (invalid lanes are never unique)."""
    # Route invalid lanes to the maximal key so they sort to the end; then
    # intersect the first-occurrence mask with validity.  A valid lane with
    # the same key as an invalid lane is unaffected (invalid keys are remapped
    # to a reserved pattern).
    sent = jnp.uint32(0xFFFFFFFF)
    h = jnp.where(valid, key_hi, sent)
    l = jnp.where(valid, key_lo, sent)
    res = sort_dedup(h, l)
    return SortDedupResult(
        uniq_mask=res.uniq_mask & valid,
        n_unique=jnp.sum(res.uniq_mask & valid).astype(jnp.int32),
    )


class NestedJoinResult(NamedTuple):
    subjects: jnp.ndarray   # int32[m, max_matches]
    valid: jnp.ndarray      # bool[m, max_matches]
    truncated: jnp.ndarray  # bool[]
    # the paper's |N_parent| x |N_child| cost term is derived from the input
    # sizes by the caller (an int here would overflow the int32 jit boundary)


def nested_loop_join(
    parent_keys: jnp.ndarray,
    parent_subjects: jnp.ndarray,
    child_keys: jnp.ndarray,
    max_matches: int,
    block: int = 1024,
) -> NestedJoinResult:
    """All-pairs equality join, blocked over the child axis to bound the
    (m × n) comparison matrix.  Output layout matches ``pjtt.ProbeResult`` so
    the two paths are drop-in interchangeable in the executor."""
    m = child_keys.shape[0]
    pad = (-m) % block
    ck = jnp.pad(child_keys, (0, pad), constant_values=-1)
    mb = ck.shape[0] // block
    ck_blocks = ck.reshape(mb, block)

    def one_block(ckb):
        eq = ckb[:, None] == parent_keys[None, :]          # (block, n)
        # rank of each match along the parent axis
        rank = jnp.cumsum(eq, axis=1) - 1
        cnt = jnp.sum(eq, axis=1)
        # scatter parent subjects into the padded (block, K) output by rank
        K = max_matches
        out = jnp.full((block, K), -1, dtype=jnp.int32)
        rows = jnp.broadcast_to(jnp.arange(block)[:, None], eq.shape)
        cols = jnp.where(eq & (rank < K), rank, K)
        out = out.at[rows, cols].set(
            jnp.broadcast_to(parent_subjects[None, :], eq.shape), mode="drop"
        )
        offs = jnp.arange(K)[None, :]
        valid = (offs < cnt[:, None]) & (out != -1)
        return out, valid, jnp.any(cnt > K)

    outs, valids, truncs = jax.lax.map(one_block, ck_blocks)
    subjects = outs.reshape(mb * block, max_matches)[:m]
    valid = valids.reshape(mb * block, max_matches)[:m]
    return NestedJoinResult(
        subjects=subjects,
        valid=valid,
        truncated=jnp.any(truncs),
    )
