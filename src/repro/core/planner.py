"""Mapping planner: RML document -> ordered physical operator plan.

Responsibilities (paper's "RML Triples Map Syntax Interpreter"):

* classify every predicate-object map to SOM / ORM / OJM,
* emit a CLASS op (rdf:type SOM) per subject map with an rr:class,
* deduplicate PJTT builds — a parent map referenced by several join rules
  builds its index ONCE (one of the paper's headline savings),
* group ops by predicate so PTT capacities can be sized from the total
  candidate count per predicate.

Term patterns are namespaced strings (``iri:`` templates/constants,
``lit:`` literal references) so output materialization knows the term kind.
"""

from __future__ import annotations

import dataclasses

from repro.rml.model import (
    MappingDocument,
    RefObjectMap,
    TermMap,
    TriplesMap,
    parse_source_key,  # noqa: F401  (re-exported: executor calls planner.parse_source_key)
    source_key,
)

RDF_TYPE = "http://www.w3.org/1999/02/22-rdf-syntax-ns#type"


def term_pattern(term: TermMap) -> str:
    """Canonical namespaced pattern string for a term map."""
    if term.template is not None:
        return "iri:" + term.pattern
    if term.reference is not None:
        return "lit:{}"
    c = term.constant or ""
    return ("iri:" if c.startswith(("http://", "https://", "urn:")) else "lit:") + c


@dataclasses.dataclass(frozen=True)
class PlannedOp:
    kind: str                    # SOM | ORM | OJM | CLASS
    triples_map: str
    predicate: str
    source_key: str              # logical source identity (fmt:path)
    subj_pattern: str
    subj_columns: tuple[str, ...]
    obj_pattern: str
    obj_columns: tuple[str, ...]          # SOM: source cols; ORM: parent subj cols
    join_child_column: str | None = None  # OJM only
    pjtt_key: str | None = None           # OJM only: cache key of the index
    parent_source_key: str | None = None
    parent_subj_pattern: str | None = None
    parent_subj_columns: tuple[str, ...] = ()
    parent_join_column: str | None = None


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    ops: tuple[PlannedOp, ...]
    # predicate -> ops generating it (PTT sizing + shared-table bookkeeping)
    by_predicate: dict[str, tuple[int, ...]]
    # pjtt_key -> (parent_source_key, parent_join_column, parent_subj_*)
    pjtt_builds: dict[str, tuple[str, str, str, tuple[str, ...]]]


def _src_key(tm: TriplesMap) -> str:
    return source_key(tm.source)


def plan(doc: MappingDocument) -> ExecutionPlan:
    ops: list[PlannedOp] = []
    pjtt_builds: dict[str, tuple[str, str, str, tuple[str, ...]]] = {}

    for tm in doc.triples_maps.values():
        subj_pat = term_pattern(tm.subject)
        subj_cols = tm.subject.columns
        if tm.subject_class:
            ops.append(
                PlannedOp(
                    kind="CLASS",
                    triples_map=tm.name,
                    predicate=RDF_TYPE,
                    source_key=_src_key(tm),
                    subj_pattern=subj_pat,
                    subj_columns=subj_cols,
                    obj_pattern="iri:" + tm.subject_class,
                    obj_columns=(),
                )
            )
        for pom in tm.poms:
            kind = doc.classify(tm, pom)
            om = pom.object_map
            if kind == "SOM":
                assert isinstance(om, TermMap)
                ops.append(
                    PlannedOp(
                        kind="SOM",
                        triples_map=tm.name,
                        predicate=pom.predicate,
                        source_key=_src_key(tm),
                        subj_pattern=subj_pat,
                        subj_columns=subj_cols,
                        obj_pattern=term_pattern(om),
                        obj_columns=om.columns,
                    )
                )
            elif kind == "ORM":
                assert isinstance(om, RefObjectMap)
                parent = doc.triples_maps[om.parent_triples_map]
                ops.append(
                    PlannedOp(
                        kind="ORM",
                        triples_map=tm.name,
                        predicate=pom.predicate,
                        source_key=_src_key(tm),
                        subj_pattern=subj_pat,
                        subj_columns=subj_cols,
                        obj_pattern=term_pattern(parent.subject),
                        obj_columns=parent.subject.columns,
                    )
                )
            else:  # OJM
                assert isinstance(om, RefObjectMap) and om.join is not None
                parent = doc.triples_maps[om.parent_triples_map]
                pkey = f"{parent.name}\x1f{om.join.parent}"
                pjtt_builds.setdefault(
                    pkey,
                    (
                        _src_key(parent),
                        om.join.parent,
                        term_pattern(parent.subject),
                        parent.subject.columns,
                    ),
                )
                ops.append(
                    PlannedOp(
                        kind="OJM",
                        triples_map=tm.name,
                        predicate=pom.predicate,
                        source_key=_src_key(tm),
                        subj_pattern=subj_pat,
                        subj_columns=subj_cols,
                        obj_pattern=term_pattern(parent.subject),
                        obj_columns=parent.subject.columns,
                        join_child_column=om.join.child,
                        pjtt_key=pkey,
                        parent_source_key=_src_key(parent),
                        parent_subj_pattern=term_pattern(parent.subject),
                        parent_subj_columns=parent.subject.columns,
                        parent_join_column=om.join.parent,
                    )
                )

    by_pred: dict[str, list[int]] = {}
    for i, op in enumerate(ops):
        by_pred.setdefault(op.predicate, []).append(i)
    return ExecutionPlan(
        ops=tuple(ops),
        by_predicate={k: tuple(v) for k, v in by_pred.items()},
        pjtt_builds=pjtt_builds,
    )
