"""Distributed PTT/PJTT — the paper's operators at pod scale.

The hash space is the shard axis (DESIGN.md §4): a triple's *owner* device is
a hash of its 64-bit key, so every device holds a disjoint slice of the PTT
and duplicate elimination is exact with no cross-device races.  The shuffle is
one ``all_to_all`` of int32/uint32 key traffic (tiny next to model training
collectives) followed by a purely local batched insert, plus a second
``all_to_all`` to route the ``is_new`` verdicts back to the producers — the
classic shuffle-join/shuffle-dedup of distributed query engines, expressed in
``shard_map``.

The same shuffle machinery distributes the PJTT: parent (key, subject) pairs
are shuffled by join-key owner, each shard builds a local sorted index, and
OJM probes are shuffled to the owner and answered in place.

All functions are written against an arbitrary axis-name tuple so they run
unchanged on the single-pod ``("data", "model")`` and multi-pod
``("pod", "data", "model")`` production meshes.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import hashing, hashset, pjtt
from repro.core.hashing import EMPTY
from repro.compat import shard_map

# Default slack factor for the fixed-capacity all_to_all bins.  With random
# hash owners the per-bucket load is Binomial(n_local, 1/S); 4x the mean keeps
# the overflow probability negligible for n_local >= 1k.
BIN_SLACK = 4


class ShardedPTT(NamedTuple):
    """PTT whose rows are sharded across every mesh axis (axis 0)."""

    hi: jnp.ndarray  # uint32[n_shards, cap_per_shard]
    lo: jnp.ndarray  # uint32[n_shards, cap_per_shard]


def make_sharded_ptt(mesh, capacity_total: int) -> ShardedPTT:
    n_shards = mesh.devices.size
    cap = hashset.next_pow2(max(capacity_total // n_shards, 8))
    spec = P(tuple(mesh.axis_names))
    shaped = jax.ShapeDtypeStruct((n_shards, cap), jnp.uint32)
    init = jax.jit(
        lambda: jnp.full(shaped.shape, EMPTY, jnp.uint32),
        out_shardings=NamedSharding(mesh, spec),
    )
    return ShardedPTT(hi=init(), lo=init())


def _owner(key_hi: jnp.ndarray, key_lo: jnp.ndarray, n_shards: int) -> jnp.ndarray:
    """Owner shard of a key.  Uses a re-mixed lane so the owner bits are
    independent of the slot bits (key_lo & mask) used inside the local table."""
    return (
        hashing.fmix32(key_hi ^ jnp.uint32(0xA5A5A5A5)) % jnp.uint32(n_shards)
    ).astype(jnp.int32)


def _bin_by_owner(owner, n_shards: int, cap: int, valid):
    """Group lane indices by owner into an (n_shards, cap) routing plan.

    Returns (dest_slot[n] int32 with -1 for overflow/invalid, send_index
    [n_shards*cap] int32 gather map with -1 for empty, overflow flag).
    """
    n = owner.shape[0]
    owner_v = jnp.where(valid, owner, n_shards)  # invalid -> virtual bucket
    order = jnp.argsort(owner_v, stable=True)
    sorted_owner = owner_v[order]
    starts = jnp.searchsorted(sorted_owner, jnp.arange(n_shards + 1, dtype=owner.dtype))
    rank = jnp.arange(n, dtype=jnp.int32) - starts[sorted_owner].astype(jnp.int32)
    ok = (sorted_owner < n_shards) & (rank < cap)
    dest = jnp.where(ok, sorted_owner.astype(jnp.int32) * cap + rank, -1)
    # scatter original lane index into the send buffer
    send_index = jnp.full((n_shards * cap,), -1, dtype=jnp.int32)
    send_index = send_index.at[jnp.where(ok, dest, n_shards * cap)].set(
        order.astype(jnp.int32), mode="drop"
    )
    overflow = jnp.any((sorted_owner < n_shards) & (rank >= cap))
    # dest per ORIGINAL lane (for the route-back un-permute)
    dest_by_lane = jnp.full((n,), -1, jnp.int32).at[order].set(dest)
    return dest_by_lane, send_index, overflow


def _gather_or(x, idx, fill):
    safe = jnp.clip(idx, 0, x.shape[0] - 1)
    return jnp.where(idx >= 0, x[safe], fill)


def distributed_insert(mesh, table: ShardedPTT, key_hi, key_lo, valid):
    """Shuffle-dedup: batched distributed PTT insert.

    ``key_hi/key_lo/valid`` are sharded over axis 0 across the whole mesh
    (one slice per device).  Returns (table', is_new, overflow) with ``is_new``
    aligned to the input layout.  Exactly-one-winner semantics hold globally
    because each key is judged only by its owner shard.
    """
    axes = tuple(mesh.axis_names)
    n_shards = mesh.devices.size

    def fn(thi, tlo, khi, klo, val):
        # local shapes: thi (1, cap_t), khi (n_local,)
        thi, tlo = thi[0], tlo[0]
        khi, klo, val = khi, klo, val
        n_local = khi.shape[0]
        cap = max(BIN_SLACK * ((n_local + n_shards - 1) // n_shards), 1)
        owner = _owner(khi, klo, n_shards)
        dest_by_lane, send_index, ovf_bin = _bin_by_owner(owner, n_shards, cap, val)

        send_hi = _gather_or(khi, send_index, jnp.uint32(EMPTY)).reshape(n_shards, cap)
        send_lo = _gather_or(klo, send_index, jnp.uint32(EMPTY)).reshape(n_shards, cap)

        recv_hi = jax.lax.all_to_all(send_hi, axes, 0, 0).reshape(-1)
        recv_lo = jax.lax.all_to_all(send_lo, axes, 0, 0).reshape(-1)
        recv_valid = ~((recv_hi == jnp.uint32(EMPTY)) & (recv_lo == jnp.uint32(EMPTY)))

        res = hashset.insert_masked(
            hashset.HashSet(thi, tlo), recv_hi, recv_lo, recv_valid
        )
        flags = res.is_new.reshape(n_shards, cap)
        flags_back = jax.lax.all_to_all(flags, axes, 0, 0).reshape(-1)
        # un-permute: lane i sent to flat slot dest_by_lane[i]
        is_new = _gather_or(flags_back, dest_by_lane, False) & val
        ovf = res.overflowed | ovf_bin
        ovf_global = jax.lax.pmax(ovf.astype(jnp.int32), axes) > 0
        return res.table.hi[None], res.table.lo[None], is_new, ovf_global

    spec_t = P(axes)
    spec_b = P(axes)
    out = jax.jit(
        shard_map(
            fn,
            mesh=mesh,
            check_vma=False,
            in_specs=(spec_t, spec_t, spec_b, spec_b, spec_b),
            out_specs=(spec_t, spec_t, spec_b, P()),
        )
    )(table.hi, table.lo, key_hi, key_lo, valid)
    thi, tlo, is_new, ovf = out
    return ShardedPTT(hi=thi, lo=tlo), is_new, ovf


class ShardedPJTT(NamedTuple):
    """Per-shard sorted join index over owner-shuffled parent pairs."""

    skeys: jnp.ndarray  # int32[n_shards, cap]   sorted within shard, -1 pad at END
    ssubj: jnp.ndarray  # int32[n_shards, cap]


_PAD_KEY = jnp.int32(2147483647)  # sorts to the end; never a dictionary id


def build_distributed_pjtt(mesh, parent_keys, parent_subjects):
    """Shuffle parent (key, subject) pairs to their key's owner shard and
    build a local sorted index there.  Bin overflow is reported (skewed keys
    beyond BIN_SLACK× the mean load need a larger slack)."""
    axes = tuple(mesh.axis_names)
    n_shards = mesh.devices.size

    def fn(pk, ps):
        n_local = pk.shape[0]
        valid = pk >= 0
        hi, lo = hashing.mix64([pk])
        owner = _owner(hi, lo, n_shards)
        cap = max(BIN_SLACK * ((n_local + n_shards - 1) // n_shards), 1)
        dest_by_lane, send_index, ovf_bin = _bin_by_owner(owner, n_shards, cap, valid)
        send_k = _gather_or(pk, send_index, _PAD_KEY).reshape(n_shards, cap)
        send_s = _gather_or(ps, send_index, jnp.int32(-1)).reshape(n_shards, cap)
        recv_k = jax.lax.all_to_all(send_k, axes, 0, 0).reshape(-1)
        recv_s = jax.lax.all_to_all(send_s, axes, 0, 0).reshape(-1)
        idx = pjtt.build_sorted(recv_k, recv_s)
        ovf = jax.lax.pmax(ovf_bin.astype(jnp.int32), axes) > 0
        return idx.skeys[None], idx.ssubj[None], ovf

    spec_b = P(axes)
    skeys, ssubj, ovf = jax.jit(
        shard_map(
            fn,
            mesh=mesh,
            check_vma=False,
            in_specs=(spec_b, spec_b),
            out_specs=(spec_b, spec_b, P()),
        )
    )(parent_keys, parent_subjects)
    return ShardedPJTT(skeys=skeys, ssubj=ssubj), ovf


def distributed_ojm_probe(mesh, index: ShardedPJTT, child_keys, max_matches: int):
    """Index-join probe against the distributed PJTT.

    Child keys are shuffled to their owner shard, answered with a padded
    (cap, max_matches) block, and routed back.  Returns (subjects, valid,
    overflow) aligned with the child layout: int32[n, max_matches].
    """
    axes = tuple(mesh.axis_names)
    n_shards = mesh.devices.size

    def fn(sk, ss, ck):
        sk, ss = sk[0], ss[0]
        n_local = ck.shape[0]
        valid = ck >= 0
        hi, lo = hashing.mix64([ck])
        owner = _owner(hi, lo, n_shards)
        cap = max(BIN_SLACK * ((n_local + n_shards - 1) // n_shards), 1)
        dest_by_lane, send_index, ovf_bin = _bin_by_owner(owner, n_shards, cap, valid)
        send_k = _gather_or(ck, send_index, _PAD_KEY).reshape(n_shards, cap)
        recv_k = jax.lax.all_to_all(send_k, axes, 0, 0).reshape(-1)

        # manual span probe: pad probes (and the index's own pad rows, which
        # share _PAD_KEY and so form one huge span) must not count as matches
        # or trigger the truncation flag
        real = recv_k != _PAD_KEY
        s0 = jnp.searchsorted(sk, recv_k, side="left")
        e0 = jnp.searchsorted(sk, recv_k, side="right")
        cnt = jnp.where(real, e0 - s0, 0)
        pr = pjtt._expand_spans(ss, s0, cnt, max_matches)
        trunc = jnp.any(cnt > max_matches)
        subj = jnp.where(pr.valid, pr.subjects, -1)
        subj_back = jax.lax.all_to_all(
            subj.reshape(n_shards, cap, max_matches), axes, 0, 0
        ).reshape(-1, max_matches)
        safe = jnp.clip(dest_by_lane, 0, subj_back.shape[0] - 1)
        out_subj = jnp.where(dest_by_lane[:, None] >= 0, subj_back[safe], -1)
        out_valid = (out_subj >= 0) & valid[:, None]
        ovf = jax.lax.pmax((ovf_bin | trunc).astype(jnp.int32), axes) > 0
        return out_subj, out_valid, ovf

    spec_b = P(axes)
    subs, vals, ovf = jax.jit(
        shard_map(
            fn,
            mesh=mesh,
            check_vma=False,
            in_specs=(spec_b, spec_b, spec_b),
            out_specs=(spec_b, spec_b, P()),
        )
    )(index.skeys, index.ssubj, child_keys)
    return subs, vals, ovf
