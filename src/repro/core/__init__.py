"""The paper's primary contribution: PTT/PJTT physical data structures and
the SOM/ORM/OJM operators, plus the planner/executor that run RML documents
and the distributed (shard_map) variants of the operators."""

from repro.core.executor import Engine, EngineConfig, KGResult, create_kg  # noqa: F401
