"""dedup_gather — the PTT insight applied to embedding/feature lookups.

A batch of gather indices with duplicate rate r fetches the same rows r
times; the paper's |N_p| -> |S_p| saving applies verbatim: deduplicate the
index stream, gather each distinct row once, and scatter results back
through the inverse map.  On TPU this converts HBM gather traffic (and, for
row-sharded tables, cross-device collective traffic) from O(|N|) to O(|S|).

Static shapes force a configured ``unique_cap``; if a batch has more
distinct ids than the cap, the call reports overflow and the caller falls
back to the plain gather (sized so this is rare — recsys/GNN sampling
workloads have heavy-tailed duplicate structure, the regime the paper
targets).

Differentiable: the backward pass is the mirrored scatter-add, so gradient
traffic enjoys the same dedup.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class DedupGatherResult(NamedTuple):
    values: jnp.ndarray      # (n, d) gathered rows (valid iff not overflowed)
    n_unique: jnp.ndarray    # int32[]
    overflowed: jnp.ndarray  # bool[]


@partial(jax.jit, static_argnames=("unique_cap",))
def dedup_gather(table: jnp.ndarray, ids: jnp.ndarray, unique_cap: int):
    """table (V, d); ids int32[n] -> rows (n, d), gathering only the distinct
    ids (up to unique_cap)."""
    n = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]]
    )
    slot = jnp.cumsum(first) - 1                   # group id per sorted lane
    n_unique = slot[-1] + 1
    overflow = n_unique > unique_cap

    uids = jnp.zeros((unique_cap,), ids.dtype).at[
        jnp.where(first & (slot < unique_cap), slot, unique_cap)
    ].set(sorted_ids, mode="drop")
    rows = jnp.take(table, uids, axis=0)           # (cap, d) — the only gather

    group_of_lane = jnp.zeros((n,), slot.dtype).at[order].set(slot)
    out = jnp.take(rows, jnp.clip(group_of_lane, 0, unique_cap - 1), axis=0)
    return DedupGatherResult(
        values=out, n_unique=n_unique.astype(jnp.int32), overflowed=overflow
    )


def gather_maybe_dedup(table, ids, unique_cap: int | None):
    """Plain gather when dedup is disabled (cap None), else dedup_gather
    values (callers check overflow out-of-band in tests/benchmarks)."""
    if unique_cap is None:
        return jnp.take(table, ids, axis=0)
    return dedup_gather(table, ids, unique_cap).values
