"""Predicate Join Tuple Table — the paper's index-join structure, TPU-native.

The paper's PJTT maps ``value(join condition B) -> {subjects of the parent
triples map}`` so that an Object Join Map becomes an index join (one probe per
child row) instead of a nested-loop join.

Join keys and subjects are dictionary-encoded int32 term-value ids (see
``repro.data.encoder``), so the structure is built from flat int32 arrays.
Two interchangeable physical strategies (DESIGN.md §6):

* **sorted** — sort parent ``(key, subject)`` pairs once; a probe is a pair of
  ``searchsorted`` calls yielding a ``[start, end)`` span.  Sequential-access
  friendly; the default on TPU.
* **hash** — an open-addressing int32 map ``key -> (start, count)`` into the
  same sorted subjects array; a probe is an O(1) double-hash loop.

Both return probes in a *padded-ragged* layout: ``(m, max_matches)`` subject
ids plus a validity mask — the TPU-native encoding of the N-M join output.
Duplicate parent ``(key, subject)`` pairs are kept in the span but masked with
a ``-1`` subject so the PJTT behaves as the paper's set semantics.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.hashset import next_pow2

MAX_PROBE_ROUNDS = 64
_KEY_EMPTY = jnp.int32(-1)  # join keys are dictionary ids >= 0
_SUBJ_MASKED = jnp.int32(-1)
_I32_MAX = jnp.iinfo(jnp.int32).max


class PJTTSorted(NamedTuple):
    skeys: jnp.ndarray  # int32[n]  parent join-key values, sorted
    ssubj: jnp.ndarray  # int32[n]  parent subject values, co-sorted; -1 = dup


class PJTTHash(NamedTuple):
    tkey: jnp.ndarray    # int32[cap]  join-key or -1 (empty)
    tstart: jnp.ndarray  # int32[cap]  span start into ssubj
    tcount: jnp.ndarray  # int32[cap]  span length
    ssubj: jnp.ndarray   # int32[n]    sorted subjects; -1 = dup


class ProbeResult(NamedTuple):
    subjects: jnp.ndarray   # int32[m, max_matches]  parent subjects (or junk)
    valid: jnp.ndarray      # bool[m, max_matches]
    truncated: jnp.ndarray  # bool[]  some span exceeded max_matches


def _lexsort_pairs(keys: jnp.ndarray, subjects: jnp.ndarray):
    """Stable sort by (key, subject): two stable argsorts."""
    o1 = jnp.argsort(subjects, stable=True)
    k1, s1 = keys[o1], subjects[o1]
    o2 = jnp.argsort(k1, stable=True)
    return k1[o2], s1[o2]


def _mask_dups(skeys: jnp.ndarray, ssubj: jnp.ndarray) -> jnp.ndarray:
    """After lexsort, mask repeated (key, subject) pairs (set semantics)."""
    prev_same = jnp.concatenate(
        [
            jnp.array([False]),
            (skeys[1:] == skeys[:-1]) & (ssubj[1:] == ssubj[:-1]),
        ]
    )
    return jnp.where(prev_same, _SUBJ_MASKED, ssubj)


def build_sorted(keys: jnp.ndarray, subjects: jnp.ndarray) -> PJTTSorted:
    """Build the sorted-strategy PJTT from parent rows.  Cost: one sort —
    the paper's |N_parent| build term."""
    skeys, ssubj = _lexsort_pairs(keys, subjects)
    return PJTTSorted(skeys=skeys, ssubj=_mask_dups(skeys, ssubj))


def probe_sorted(
    pjtt: PJTTSorted, child_keys: jnp.ndarray, max_matches: int
) -> ProbeResult:
    start = jnp.searchsorted(pjtt.skeys, child_keys, side="left")
    end = jnp.searchsorted(pjtt.skeys, child_keys, side="right")
    return _expand_spans(pjtt.ssubj, start, end - start, max_matches)


def build_hash(keys: jnp.ndarray, subjects: jnp.ndarray) -> PJTTHash:
    """Build the hash-strategy PJTT: group via sort, then insert each unique
    key with its (start, count) span into an open-addressing map."""
    n = keys.shape[0]
    skeys, ssubj0 = _lexsort_pairs(keys, subjects)
    ssubj = _mask_dups(skeys, ssubj0)

    is_start = jnp.concatenate([jnp.array([True]), skeys[1:] != skeys[:-1]])
    seg_id = jnp.cumsum(is_start) - 1  # group index per sorted row
    counts_per_seg = jax.ops.segment_sum(
        jnp.ones((n,), dtype=jnp.int32), seg_id, num_segments=n
    )
    pos = jnp.arange(n, dtype=jnp.int32)
    lane_count = counts_per_seg[seg_id]

    cap = next_pow2(int(n / 0.7) + 1)
    tkey = jnp.full((cap,), _KEY_EMPTY, dtype=jnp.int32)
    tstart = jnp.zeros((cap,), dtype=jnp.int32)
    tcount = jnp.zeros((cap,), dtype=jnp.int32)

    hi, lo = hashing.mix64([skeys])
    maskc = jnp.uint32(cap - 1)
    base = lo & maskc
    step = ((hi | jnp.uint32(1)) & maskc) | jnp.uint32(1)

    class _S(NamedTuple):
        tkey: jnp.ndarray
        tstart: jnp.ndarray
        tcount: jnp.ndarray
        done: jnp.ndarray
        rnd: jnp.ndarray

    def cond(s: _S):
        return (~jnp.all(s.done)) & (s.rnd < MAX_PROBE_ROUNDS)

    def body(s: _S) -> _S:
        slot = ((base + s.rnd.astype(jnp.uint32) * step) & maskc).astype(jnp.int32)
        occ = s.tkey[slot]
        active = ~s.done
        empty = active & (occ == _KEY_EMPTY)
        claim = jnp.full((cap,), _I32_MAX, dtype=jnp.int32)
        claim = claim.at[jnp.where(empty, slot, cap)].min(
            jnp.where(empty, pos, _I32_MAX), mode="drop"
        )
        won = empty & (claim[slot] == pos)
        nkey = s.tkey.at[jnp.where(won, slot, cap)].set(skeys, mode="drop")
        nstart = s.tstart.at[jnp.where(won, slot, cap)].set(pos, mode="drop")
        ncount = s.tcount.at[jnp.where(won, slot, cap)].set(lane_count, mode="drop")
        # keys are unique among active lanes (only span starts are active),
        # so no same-key twin handling is needed here.
        return _S(nkey, nstart, ncount, s.done | won, s.rnd + 1)

    init = _S(tkey, tstart, tcount, ~is_start, jnp.int32(0))
    out = jax.lax.while_loop(cond, body, init)
    return PJTTHash(tkey=out.tkey, tstart=out.tstart, tcount=out.tcount, ssubj=ssubj)


def probe_hash(
    pjtt: PJTTHash, child_keys: jnp.ndarray, max_matches: int
) -> ProbeResult:
    cap = pjtt.tkey.shape[0]
    m = child_keys.shape[0]
    hi, lo = hashing.mix64([child_keys])
    maskc = jnp.uint32(cap - 1)
    base = lo & maskc
    step = ((hi | jnp.uint32(1)) & maskc) | jnp.uint32(1)

    class _S(NamedTuple):
        done: jnp.ndarray
        start: jnp.ndarray
        cnt: jnp.ndarray
        rnd: jnp.ndarray

    def cond(s: _S):
        return (~jnp.all(s.done)) & (s.rnd < MAX_PROBE_ROUNDS)

    def body(s: _S) -> _S:
        slot = ((base + s.rnd.astype(jnp.uint32) * step) & maskc).astype(jnp.int32)
        occ = pjtt.tkey[slot]
        active = ~s.done
        hit = active & (occ == child_keys)
        empty = active & (occ == _KEY_EMPTY)
        return _S(
            done=s.done | hit | empty,
            start=jnp.where(hit, pjtt.tstart[slot], s.start),
            cnt=jnp.where(hit, pjtt.tcount[slot], s.cnt),
            rnd=s.rnd + 1,
        )

    init = _S(
        done=jnp.zeros((m,), dtype=bool),
        start=jnp.zeros((m,), dtype=jnp.int32),
        cnt=jnp.zeros((m,), dtype=jnp.int32),
        rnd=jnp.int32(0),
    )
    out = jax.lax.while_loop(cond, body, init)
    return _expand_spans(pjtt.ssubj, out.start, out.cnt, max_matches)


def _expand_spans(
    ssubj: jnp.ndarray, start: jnp.ndarray, count: jnp.ndarray, max_matches: int
) -> ProbeResult:
    """Expand [start, start+count) spans into a padded (m, K) block."""
    n = ssubj.shape[0]
    offs = jnp.arange(max_matches, dtype=jnp.int32)[None, :]
    idx = start[:, None].astype(jnp.int32) + offs
    within = offs < count[:, None]
    subjects = ssubj[jnp.clip(idx, 0, n - 1)]
    valid = within & (subjects != _SUBJ_MASKED)
    truncated = jnp.any(count > max_matches)
    return ProbeResult(subjects=subjects, valid=valid, truncated=truncated)
