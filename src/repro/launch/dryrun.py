import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh:

    lowered  = jax.jit(fn, donate_argnums=...).lower(*shaped_args)
    compiled = lowered.compile()
    memory_analysis()   -> proves the cell fits per-device HBM
    cost_analysis()     -> FLOPs / bytes for the roofline (§Roofline)
    collective bytes    -> parsed from the compiled HLO text

Results stream into results/dryrun.json incrementally, so re-runs skip
completed cells (--force to redo).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b
    PYTHONPATH=src python -m repro.launch.dryrun --cell qwen2.5-3b/train_4k \
        --mesh multi
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro.launch.mesh import make_production_mesh
from repro.compat import set_mesh

RESULTS = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../..", "results"))

_COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=?"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _tensor_bytes(type_str: str) -> int:
    """Bytes of one HLO shape like 'bf16[256,4096,2048]' (tuples summed)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum *output* shape bytes of every collective op in the HLO module.

    Output-shape accounting: for all-gather the output is the gathered
    (larger) tensor, for reduce-scatter the input is larger — we take the
    max of lhs/result shapes per instruction as 'bytes touched by the
    collective', the quantity the ICI link actually moves (up to the
    algorithm factor, which the roofline treats separately).
    """
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        # type may be a TUPLE with /*index=N*/ comments (shard_map emits
        # multi-operand collectives), so allow anything between '=' and the
        # op token as long as the op token starts the call
        m = re.search(
            r"=\s*(\(?.*?)\s"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
            r"(?:-start)?[.\d]*\(",
            line,
        )
        if not m:
            continue
        if re.search(r"(all-gather|all-to-all|all-reduce|reduce-scatter|collective-permute)-done", line):
            continue  # -done pairs with -start; count once
        kind = m.group(2)
        lhs_bytes = _tensor_bytes(m.group(1))
        args = line[m.end():].split("metadata=")[0]
        arg_bytes = _tensor_bytes(args)
        b = max(lhs_bytes, arg_bytes)
        out[kind] = out.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _compile_costs(spec, mesh) -> dict:
    t0 = time.time()
    with set_mesh(mesh):
        jitted = jax.jit(spec.fn, donate_argnums=spec.donate)
        lowered = jitted.lower(*spec.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
    mem_out = {}
    for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(mem, k, None)
        if v is not None:
            mem_out[k] = int(v)
    return {
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_out,
        "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        "collectives": coll,
    }


def _extrapolate(c1: dict, c2: dict, n_layers: int) -> dict:
    """Per-layer marginal cost from unrolled L=1 and L=2 compiles:
    total(L) = cost(1) + (L-1) * (cost(2) - cost(1)).

    Needed because XLA cost_analysis counts a scan body once regardless of
    trip count; the deployable (scanned) compile provides memory numbers,
    this provides the compute/traffic numbers.
    """
    def ext(a, b):
        return a + (n_layers - 1) * max(b - a, 0.0)

    kinds = set(c1["collectives"]["bytes_by_kind"]) | set(
        c2["collectives"]["bytes_by_kind"]
    )
    coll = {
        k: int(
            ext(
                c1["collectives"]["bytes_by_kind"].get(k, 0),
                c2["collectives"]["bytes_by_kind"].get(k, 0),
            )
        )
        for k in kinds
    }
    return {
        "flops": ext(c1["flops"], c2["flops"]),
        "bytes_accessed": ext(c1["bytes_accessed"], c2["bytes_accessed"]),
        "collectives": {
            "bytes_by_kind": coll,
            "total_bytes": sum(coll.values()),
            "counts": {
                k: int(
                    ext(
                        c1["collectives"]["counts"].get(k, 0),
                        c2["collectives"]["counts"].get(k, 0),
                    )
                )
                for k in kinds
            },
        },
    }


def run_cell(arch: str, shape: str, mesh, mesh_name: str) -> dict:
    from repro.configs import registry

    spec = registry.build_cell(arch, shape, mesh)
    if isinstance(spec, str):
        return {"status": "skipped", "reason": spec}

    base = _compile_costs(spec, mesh)
    out = {
        "status": "ok",
        "mesh": mesh_name,
        "devices": mesh.devices.size,
        "kind": spec.kind,
        "note": spec.note,
        **base,
    }

    entry = registry.get_arch(arch)
    if entry.family == "lm" and entry.config().scan_layers:
        # marginal-layer extrapolation for honest whole-program costs
        s1 = registry.build_cell(arch, shape, mesh, n_layers_override=1)
        s2 = registry.build_cell(arch, shape, mesh, n_layers_override=2)
        c1 = _compile_costs(s1, mesh)
        c2 = _compile_costs(s2, mesh)
        n_layers = entry.config().n_layers
        out["scan_body_once"] = {
            "flops": base["flops"],
            "collectives_total": base["collectives"]["total_bytes"],
        }
        out.update(_extrapolate(c1, c2, n_layers))
        out["cost_method"] = "unrolled L=1/L=2 marginal extrapolation"
    else:
        out["cost_method"] = "direct (no scan)"
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None, help="arch/shape")
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--extra", action="store_true", help="include rdfizer cells")
    ap.add_argument("--out", default=os.path.join(RESULTS, "dryrun.json"))
    args = ap.parse_args()

    from repro.configs import registry

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    cells = []
    for a in registry.ARCHS.values():
        if args.arch and a.name != args.arch:
            continue
        for s in a.shapes:
            cells.append((a.name, s))
    if args.cell:
        arch, shape = args.cell.split("/")
        cells = [(arch, shape)]

    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            key = f"{arch}/{shape}@{mesh_name}"
            if key in results and results[key].get("status") in ("ok", "skipped") and not args.force:
                print(f"[cached] {key}: {results[key]['status']}")
                continue
            print(f"[dryrun] {key} ...", flush=True)
            try:
                res = run_cell(arch, shape, mesh, mesh_name)
            except Exception as e:  # noqa: BLE001
                res = {
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:],
                }
            results[key] = res
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
            stat = res["status"]
            extra = ""
            if stat == "ok":
                mem = res["memory"].get("temp_size_in_bytes", 0) / (1 << 30)
                extra = (
                    f" flops={res['flops']:.3e}"
                    f" temp={mem:.2f}GiB/dev coll={res['collectives']['total_bytes']:.3e}B"
                    f" compile={res['compile_s']}s"
                )
            elif stat == "error":
                extra = " " + res["error"][:200]
            print(f"[dryrun] {key}: {stat}{extra}", flush=True)

    if args.extra:
        for mesh_name, mesh in meshes:
            for spec in registry.build_extra_cells(mesh):
                key = f"{spec.name}@{mesh_name}"
                if key in results and not args.force:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    with set_mesh(mesh):
                        jitted = jax.jit(spec.fn, donate_argnums=spec.donate)
                        lowered = jitted.lower(*spec.args)
                        compiled = lowered.compile()
                        res = {
                            "status": "ok",
                            "mesh": mesh_name,
                            "kind": spec.kind,
                            "flops": float((compiled.cost_analysis() or {}).get("flops", 0)),
                            "collectives": collective_bytes(compiled.as_text()),
                            "memory": {
                                "temp_size_in_bytes": int(
                                    getattr(compiled.memory_analysis(), "temp_size_in_bytes", 0)
                                )
                            },
                        }
                except Exception as e:  # noqa: BLE001
                    res = {"status": "error", "error": f"{type(e).__name__}: {e}"}
                results[key] = res
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                print(f"[dryrun] {key}: {res['status']}", flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        for k, r in results.items():
            if r["status"] == "error":
                print(f"  ERROR {k}: {r['error'][:300]}")


if __name__ == "__main__":
    main()
