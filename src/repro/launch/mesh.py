"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state, so tests and benches keep their single CPU device.
"""

from __future__ import annotations

import jax

from repro.compat import compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    data = n // model
    return compat_make_mesh((data, model), ("data", "model"))
