"""Serving driver: batched decode with a KV cache (smoke-scale).

Demonstrates the full decode path on local devices: prefill the cache from
prompts, then step the batched decode loop; reports tokens/s.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --batch 4 --prompt-len 32 --gen 64
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import registry
    from repro.models import transformer

    entry = registry.get_arch(args.arch)
    if entry.family != "lm":
        raise SystemExit(f"{args.arch} is not an LM")
    cfg = entry.smoke_config()
    print(f"[serve] {cfg.name} smoke ({cfg.param_count()/1e6:.2f}M params), "
          f"window={cfg.window}")

    key = jax.random.PRNGKey(0)
    params = transformer.init(key, cfg)
    max_len = args.prompt_len + args.gen
    cache = transformer.make_cache(cfg, args.batch, max_len)

    decode = jax.jit(
        lambda p, c, t, pos: transformer.decode_step(cfg, p, c, t, pos),
        donate_argnums=(1,),
    )

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len))

    # prefill by stepping the decode cache (smoke scale; production prefill
    # lowers the chunked forward — see the prefill_32k dry-run cells)
    t0 = time.perf_counter()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = decode(
            params, cache, jnp.asarray(prompts[:, i: i + 1]), jnp.int32(i)
        )
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(
            params, cache, tok, jnp.int32(args.prompt_len + i)
        )
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    total = args.batch * (args.gen - 1)
    print(f"[serve] prefill {args.prompt_len} steps in {t_prefill:.2f}s; "
          f"decode {total} tokens in {dt:.2f}s = {total/dt:.1f} tok/s")
    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] sample generation (ids): {gen[0][:16].tolist()} ...")


if __name__ == "__main__":
    main()
