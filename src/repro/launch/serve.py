"""KG query server driver — serve a ``.kgz`` snapshot to concurrent clients.

    # server: load once, micro-batch concurrent clients per dispatch
    PYTHONPATH=src python -m repro.launch.serve --kg out.kgz --port 7077

    # client one-shot (retries the connect while the server warms up)
    PYTHONPATH=src python -m repro.launch.serve --connect 127.0.0.1:7077 \
        --query '?s <http://repro.org/vocab/gene_name> ?o' [--limit 5]

The protocol is newline-delimited JSON (see ``repro.serve.server``); any
language can speak it with a plain TCP socket.  The LM-serving demo that
used to live here is ``examples/serve_lm.py``.
"""

from __future__ import annotations

import argparse
import json
import signal
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kg", default=None, help=".kgz snapshot to serve")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7077,
                    help="0 picks a free port (printed on stderr)")
    ap.add_argument("--max-batch", type=int, default=4096)
    ap.add_argument("--linger-ms", type=float, default=2.0,
                    help="how long the dispatcher waits for concurrent "
                         "clients to coalesce into one batch")
    ap.add_argument("--max-rows", type=int, default=1000,
                    help="decoded rows per answer when the request sets no "
                         "limit (n_total always reports the full count)")
    ap.add_argument("--read-only", action="store_true",
                    help="serve the snapshot immutably: insert/delete/"
                         "compact wire ops come back as structured "
                         "read_only errors instead of mutating")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the start-up plan/signature warm-up (the "
                         "server pre-compiles the common single-pattern "
                         "and star-join shapes so first queries skip jit)")
    ap.add_argument("--bench", action="store_true",
                    help="measure the fused-pipeline query classes over "
                         "--kg and exit (writes the BENCH_serve.json shape; "
                         "an empty store reports zero-query sections)")
    ap.add_argument("--json", default=None,
                    help="with --bench: also write the report to this path")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="client mode: send --query to a running server")
    ap.add_argument("--query", default=None, help="query text (client mode)")
    ap.add_argument("--limit", type=int, default=None,
                    help="max rows decoded per answer (client mode)")
    ap.add_argument("--metrics", action="store_true",
                    help="client mode: fetch the server's full metrics "
                         "snapshot (latency histograms, counters) instead "
                         "of sending a query")
    ap.add_argument("--retry-s", type=float, default=10.0,
                    help="client mode: keep retrying the connect this long")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="server mode: record queue-wait / dispatch / "
                         "redispatch spans and write a Chrome trace-event "
                         "JSON on shutdown (open in Perfetto)")
    args = ap.parse_args()

    if args.connect:
        if not args.query and not args.metrics:
            ap.error("--connect needs --query (or --metrics)")
        from repro import api

        host, _, port = args.connect.rpartition(":")
        target = f"{host or '127.0.0.1'}:{int(port)}"
        with api.connect(target, retry_s=args.retry_s) as s:
            if args.metrics:
                resp = s.metrics()
            else:
                resp = s.query(args.query, limit=args.limit).to_dict()
        print(json.dumps(resp, indent=2))
        return

    if not args.kg:
        ap.error("provide --kg to serve, or --connect/--query for client mode")
    from repro import obs
    from repro.kg.persist import is_manifest, open_store
    from repro.serve.server import KGServer

    if args.trace:
        obs.enable_tracing()

    if is_manifest(args.kg):
        # a shard manifest: spawn the shard servers in-process and front
        # them with the scatter/gather coordinator — same wire protocol,
        # so client mode and every existing tool keep working
        from repro.shard.coordinator import Coordinator

        signal.signal(signal.SIGTERM, signal.default_int_handler)
        coord = Coordinator.from_manifest(
            args.kg,
            host=args.host,
            port=args.port,
            read_only=args.read_only,
            max_rows=args.max_rows,
            max_batch=args.max_batch,
            linger_ms=args.linger_ms,
        )
        try:
            coord.serve_forever()
        finally:
            if args.trace:
                n_ev = obs.save_trace(args.trace)
                print(f"[serve] wrote {n_ev}-event trace to {args.trace}",
                      file=sys.stderr)
        return
    from repro.kg.persist import KIND_DELTA, load_chain, peek_meta
    from repro.live.delta import LiveStore

    _, _, _, kind = peek_meta(args.kg)
    kg_path = None
    if kind == KIND_DELTA:
        # a delta snapshot: resolve its parent chain into a live store
        # (compaction does not rewrite a delta file in place)
        served = load_chain(args.kg)
        store = served.base
    elif args.read_only:
        served = store = open_store(args.kg)
    else:
        store = open_store(args.kg)
        served = LiveStore(store)
        kg_path = args.kg
    print(f"[serve] {store.n_triples} triples, {store.n_terms} terms "
          f"from {args.kg}", file=sys.stderr)
    if args.bench:
        from repro.serve.bench import bench_serve

        report = bench_serve(store)
        print(json.dumps(report, indent=2, sort_keys=True))
        if args.json:
            with open(args.json, "w", encoding="utf-8") as f:
                json.dump(report, f, indent=2, sort_keys=True)
        if args.trace:
            n_ev = obs.save_trace(args.trace)
            print(f"[serve] wrote {n_ev}-event trace to {args.trace}",
                  file=sys.stderr)
        return
    # SIGTERM behaves like ^C so a supervised server (CI smoke, systemd)
    # still flushes its trace on shutdown
    signal.signal(signal.SIGTERM, signal.default_int_handler)
    try:
        KGServer(
            served,
            host=args.host,
            port=args.port,
            max_batch=args.max_batch,
            linger_ms=args.linger_ms,
            max_rows=args.max_rows,
            read_only=args.read_only,
            kg_path=kg_path,
            warmup=not args.no_warmup,
        ).serve_forever()
    finally:
        if args.trace:
            n_ev = obs.save_trace(args.trace)
            print(f"[serve] wrote {n_ev}-event trace to {args.trace}",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
