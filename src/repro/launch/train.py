"""End-to-end training driver with fault tolerance.

Runs any registered architecture at a REDUCED (smoke) configuration on the
local devices — the same code path the production mesh would run, wrapped
in the fault-tolerance substrate: periodic (background) checkpoints, crash
retry with restore, straggler detection.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 200 --batch 8 --seq 128 --ckpt-every 50 --out /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--out", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument(
        "--simulate-failure-at", type=int, default=-1,
        help="raise at this step once, to exercise the retry/restore path",
    )
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.models import transformer
    from repro.train import checkpoint, fault
    from repro.train.optimizer import AdamW
    from repro.train.trainer import make_train_step

    entry = registry.get_arch(args.arch)
    if entry.family != "lm":
        raise SystemExit(
            f"{args.arch} is {entry.family}; this driver trains the LM family"
            " (see examples/ for gnn/recsys end-to-end runs)"
        )
    cfg = entry.smoke_config()
    cfg = dataclasses.replace(cfg, sequence_parallel=False)
    print(f"[train] {cfg.name} smoke config: {cfg.param_count()/1e6:.2f}M params")

    key = jax.random.PRNGKey(0)
    params = transformer.init(key, cfg)
    opt = AdamW(lr=args.lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(
        make_train_step(
            lambda p, t, l: transformer.loss_fn(cfg, p, t, l),
            opt,
            compress=args.compress_grads,
        ),
        donate_argnums=(0, 1),
    )

    start_step = 0
    if args.resume:
        latest = checkpoint.latest_step_dir(args.out)
        if latest:
            (params, opt_state), start_step = checkpoint.restore(
                latest, (params, opt_state)
            )
            print(f"[train] resumed from {latest} at step {start_step}")

    # synthetic LM data: next-token prediction over a fixed random corpus so
    # the loss has real signal (memorization) and must go DOWN
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, cfg.vocab, size=(64, args.seq + 1)).astype(np.int32)

    detector = fault.StragglerDetector()
    policy = fault.RetryPolicy(max_retries=3, backoff_s=0.1)
    state = {"params": params, "opt": opt_state, "err": None}
    failed_once = {"done": False}

    def restore_hook(attempt, exc):
        latest = checkpoint.latest_step_dir(args.out)
        if latest:
            (state["params"], state["opt"]), s = checkpoint.restore(
                latest, (state["params"], state["opt"])
            )
            print(f"[train] restored step {s} after failure: {exc}")

    losses = []
    for step in range(start_step, args.steps):
        idx = rng.integers(0, len(corpus), size=args.batch)
        toks = jnp.asarray(corpus[idx, :-1])
        labels = jnp.asarray(corpus[idx, 1:])

        def do_step():
            if args.simulate_failure_at == step and not failed_once["done"]:
                failed_once["done"] = True
                raise RuntimeError("simulated node failure")
            if args.compress_grads:
                p, o, m, e = step_fn(
                    state["params"], state["opt"], toks, labels,
                    error_fb=state["err"],
                )
                state["err"] = e
            else:
                p, o, m = step_fn(state["params"], state["opt"], toks, labels)
            state["params"], state["opt"] = p, o
            return m

        t0 = time.perf_counter()
        metrics = policy.run(do_step, on_failure=restore_hook)
        detector.observe(time.perf_counter() - t0)
        losses.append(float(metrics["loss"]))

        if step % args.log_every == 0:
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            checkpoint.save(
                os.path.join(args.out, f"step_{step}"),
                (state["params"], state["opt"]),
                step=step,
                background=True,
            )

    checkpoint.save(
        os.path.join(args.out, f"step_{args.steps}"),
        (state["params"], state["opt"]), step=args.steps,
    )
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
