"""Knowledge-graph creation driver — the SDM-RDFizer CLI.

    PYTHONPATH=src python -m repro.launch.rdfize \
        --mapping mappings.ttl --data-root data/ --out kg.nt \
        [--engine optimized|naive] [--join sorted|hash] \
        [--stream] [--block-rows N] [--emit nt|kgz] \
        [--explain-mapping] [--no-mapping-plan]

``--emit kgz`` writes a queryable ``repro.kg`` triple-store snapshot
(dictionary + SPO/POS/OSP indexes) instead of N-Triples text; serve it with
``python -m repro.launch.query --kg out.kgz '?s <p> ?o'``.

``--stream`` runs the optimized engine on the ``repro.stream`` block
subsystem: sources are read in ``--block-rows``-row chunks through a lazy
Dataset plan (read -> project -> encode -> batch) with bounded prefetch, so
the KG can exceed host RAM.  Output is identical to the eager engine.

Every run goes through the mapping-level planner (:mod:`repro.rml.plan`)
unless ``--no-mapping-plan``: projections are pushed into the streamed
reads, shared subject/join templates are evaluated once, and rules execute
group-by-group along the plan's DAG.  ``--explain-mapping`` prints the
planner's decisions as a tree — kept/pruned columns per source, factored
terms, rule groups — and exits without building anything.  With
``--shards N --shard-workers M`` and a multi-group plan, whole rule
groups build in parallel worker processes before the shard stores do.

Mirrors the paper's tool: parse the RML document, plan, execute with the
PTT/PJTT operators, emit N-Triples, print the per-predicate φ statistics.
"""

from __future__ import annotations

import argparse


def _print_stats(stats) -> None:
    for pred, st in stats.items():
        print(
            f"  {st.kind:5s} {pred.rsplit('/', 1)[-1]:30s} "
            f"|N_p|={st.n_candidates:>9d} |S_p|={st.n_unique:>9d} "
            f"phi={int(st.phi_optimized()):>12d} "
            f"phi_naive={int(st.phi_naive()):>14d}"
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mapping", required=True)
    ap.add_argument("--data-root", default=".")
    ap.add_argument("--out", default=None, help="N-Triples output path")
    ap.add_argument("--engine", default="optimized", choices=("optimized", "naive"))
    ap.add_argument("--join", default="sorted", choices=("sorted", "hash"))
    ap.add_argument("--batch-size", type=int, default=1 << 16)
    ap.add_argument("--stream", action="store_true",
                    help="block-streamed out-of-core ingestion (repro.stream)")
    ap.add_argument("--block-rows", type=int, default=1 << 14,
                    help="rows per streamed block (with --stream)")
    ap.add_argument("--explain-mapping", action="store_true",
                    help="print the mapping planner's decisions (kept/"
                         "pruned columns, factored terms, rule groups) "
                         "and exit without building the KG")
    ap.add_argument("--no-mapping-plan", action="store_true",
                    help="disable the mapping-level planner (no "
                         "projection pushdown, no shared-template "
                         "factoring, single flat rule group)")
    ap.add_argument("--emit", default="nt", choices=("nt", "kgz"),
                    help="output format: N-Triples text or a queryable "
                         "repro.kg .kgz snapshot")
    ap.add_argument("--shards", type=int, default=0, metavar="N",
                    help="with --emit kgz: partition the KG by subject "
                         "hash into N shard stores plus a manifest at "
                         "--out (serve it with launch.serve, query it "
                         "with repro.api.connect)")
    ap.add_argument("--shard-workers", type=int, default=0, metavar="M",
                    help="build rule groups, then shard stores, across M "
                         "spawned worker processes (default: serial "
                         "in-process)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Chrome trace-event JSON of the run "
                         "(per-block read/project/encode spans with "
                         "--stream; open in Perfetto / chrome://tracing)")
    args = ap.parse_args()

    from repro import obs
    from repro.core.executor import create_kg
    from repro.rml import parser

    if args.explain_mapping:
        from repro import api

        print(api.explain_mapping(args.mapping, data_root=args.data_root))
        return
    if args.trace:
        obs.enable_tracing()
    with obs.span("parse_mapping", cat="rdfize", path=args.mapping):
        doc = parser.parse_file(args.mapping)
    print(f"[rdfize] {len(doc.triples_maps)} triples maps from {args.mapping}")
    mapping_plan = not args.no_mapping_plan
    mplan = None
    if mapping_plan:
        from repro.rml.plan import build_plan

        mplan = build_plan(doc)
        print(f"[rdfize] plan: {len(mplan.exec_plan.ops)} rules over "
              f"{len(mplan.sources)} sources -> {len(mplan.groups)} "
              f"groups ({len(mplan.shared)} shared terms factored)")
    if args.shards and args.emit != "kgz":
        ap.error("--shards needs --emit kgz (shard stores are .kgz snapshots)")

    group_parallel = (
        args.out is not None
        and args.emit == "kgz"
        and args.shards
        and args.shard_workers > 1
        and mplan is not None
        and len(mplan.groups) > 1
    )
    if group_parallel:
        # whole rule groups are the unit of multiprocess work: each
        # worker builds its group's sub-KG, the parent unions the
        # rendered triples and hash-partitions them into shard stores
        from repro.shard.ingest import ingest_mapping_sharded

        with open(args.mapping, encoding="utf-8") as f:
            mapping_text = f.read()
        with obs.span("create_kg_grouped", cat="rdfize",
                      groups=len(mplan.groups), workers=args.shard_workers):
            manifest, stats, n_triples = ingest_mapping_sharded(
                mapping_text, args.data_root, args.out, args.shards,
                workers=args.shard_workers,
                engine_opts=dict(
                    engine=args.engine, join_strategy=args.join,
                    batch_size=args.batch_size, stream=args.stream,
                    block_rows=args.block_rows,
                ),
            )
        print(f"[rdfize] {n_triples} unique triples "
              f"({len(mplan.groups)} rule groups in parallel)")
        _print_stats(stats)
        sizes = ", ".join(str(s["n_triples"]) for s in manifest["shards"])
        print(f"[rdfize] wrote {n_triples}-triple sharded KG "
              f"({args.shards} shards: {sizes} triples) — manifest "
              f"at {args.out}")
        if args.trace:
            n_ev = obs.save_trace(args.trace)
            print(f"[rdfize] wrote {n_ev}-event trace to {args.trace}")
        return

    with obs.span("create_kg", cat="rdfize", engine=args.engine,
                  stream=args.stream):
        result = create_kg(
            doc,
            data_root=args.data_root,
            engine=args.engine,
            join_strategy=args.join,
            batch_size=args.batch_size,
            stream=args.stream,
            block_rows=args.block_rows,
            mapping_plan=mapping_plan,
        )
    print(f"[rdfize] {result.n_triples} unique triples in "
          f"{result.wall_time_s:.2f}s ({result.engine} engine)")
    _print_stats(result.stats)
    if args.out:
        if args.emit == "kgz" and args.shards:
            from repro.shard.ingest import shard_store

            with obs.span("emit_sharded", cat="rdfize", out=args.out,
                          shards=args.shards):
                store = result.to_store()
                manifest = shard_store(
                    store, args.out, args.shards,
                    workers=args.shard_workers,
                )
            sizes = ", ".join(
                str(s["n_triples"]) for s in manifest["shards"]
            )
            print(f"[rdfize] wrote {store.n_triples}-triple sharded KG "
                  f"({args.shards} shards: {sizes} triples) — manifest "
                  f"at {args.out}")
        elif args.emit == "kgz":
            from repro.kg import persist

            with obs.span("emit_kgz", cat="rdfize", out=args.out):
                store = result.to_store()
                persist.save(store, args.out)
            print(f"[rdfize] wrote {store.n_triples}-triple .kgz snapshot "
                  f"({store.n_terms} terms) to {args.out}")
        else:
            with obs.span("emit_nt", cat="rdfize", out=args.out):
                n = result.write_ntriples(args.out)
            print(f"[rdfize] wrote {n} triples to {args.out}")
    if args.trace:
        n_ev = obs.save_trace(args.trace)
        print(f"[rdfize] wrote {n_ev}-event trace to {args.trace}")


if __name__ == "__main__":
    main()
