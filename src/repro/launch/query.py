"""Query driver — serve answers from a ``.kgz`` triple-store snapshot.

    PYTHONPATH=src python -m repro.launch.query \
        --kg out.kgz '?s <http://repro.org/vocab/gene_name> ?o' [--limit 20]

    # full SPARQL-lite (OPTIONAL / FILTER / DISTINCT / LIMIT)
    PYTHONPATH=src python -m repro.launch.query --kg out.kgz \
        'SELECT ?m ?e WHERE { ?m <http://repro.org/vocab/has_exon> ?e
                              FILTER(?e > 100) } LIMIT 10'

    # serving throughput (batched single-pattern path)
    PYTHONPATH=src python -m repro.launch.query --kg out.kgz --bench

Build the snapshot with ``python -m repro.launch.rdfize ... --emit kgz``;
start the long-lived batching server with ``python -m repro.launch.serve``.
The store is opened through the ``open_store`` cache, so a query phase and
a ``--bench`` phase in one process load and validate the snapshot once.
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kg", required=True, help=".kgz snapshot path")
    ap.add_argument("query", nargs="*",
                    help="SPARQL-lite query, or bare triple pattern(s)")
    ap.add_argument("--limit", type=int, default=None, help="max rows printed")
    ap.add_argument("--explain", action="store_true",
                    help="print the planned operator tree instead of rows")
    ap.add_argument("--bench", action="store_true",
                    help="measure batched single-pattern queries/s")
    ap.add_argument("--bench-queries", type=int, default=50_000)
    ap.add_argument("--bench-batch", type=int, default=4096)
    ap.add_argument("--json", default=None,
                    help="also write the bench report to this path")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Chrome trace-event JSON of the run "
                         "(dispatch / redispatch spans; open in Perfetto)")
    args = ap.parse_args()

    from repro import api, obs
    from repro.kg import persist

    if args.trace:
        obs.enable_tracing()
    if persist.is_manifest(args.kg):
        # a sharded KG: connect() opens every shard behind the
        # scatter/gather session; --bench still needs one store, so
        # point it at shard 0
        manifest = persist.load_manifest(args.kg)
        store = persist.open_store(manifest["shards"][0]["abs_path"])
        session = api.connect(args.kg)
        print(
            f"[query] {manifest['dictionary']['n_triples']} triples across "
            f"{manifest['n_shards']} shards from {args.kg}",
            file=sys.stderr,
        )
    else:
        store = persist.open_store(args.kg)
        print(
            f"[query] {store.n_triples} triples, {store.n_terms} terms "
            f"from {args.kg}",
            file=sys.stderr,
        )
        session = api.connect(store)

    if args.query:
        text = " . ".join(args.query)
        if args.explain:
            print(session.explain(text))
        else:
            result = session.query(text, limit=args.limit)
            print("\t".join(result.vars))
            for row in result:
                # COUNT cells are plain ints, unbound cells are None
                print("\t".join("∅" if t is None else str(t) for t in row))
            shown = (
                f" (showing {len(result)})"
                if len(result) < result.n_total else ""
            )
            print(f"[query] {result.n_total} solutions{shown}",
                  file=sys.stderr)

    if args.bench:
        # an empty graph reports a zero-query section (the guard is unified
        # inside bench_single_pattern, not ad-hoc per CLI)
        from repro.kg.bench import bench_single_pattern

        report = bench_single_pattern(
            store, n_queries=args.bench_queries, batch=args.bench_batch
        )
        print(f"[query] {report['queries_per_s']:.0f} single-pattern queries/s "
              f"({report['n_queries']} queries, batch={report['batch']})",
              file=sys.stderr)
        print(json.dumps(report, indent=2))
        if args.json:
            with open(args.json, "w", encoding="utf-8") as f:
                json.dump(report, f, indent=2)

    if not args.query and not args.bench:
        ap.error("provide a query (or --bench)")

    if args.trace:
        n_ev = obs.save_trace(args.trace)
        print(f"[query] wrote {n_ev}-event trace to {args.trace}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
