"""Query driver — serve answers from a ``.kgz`` triple-store snapshot.

    PYTHONPATH=src python -m repro.launch.query \
        --kg out.kgz '?s <http://repro.org/vocab/gene_name> ?o' [--limit 20]

    # conjunctive BGP: patterns separated by ' . ' inside one argument,
    # or passed as multiple arguments
    PYTHONPATH=src python -m repro.launch.query --kg out.kgz \
        '?m <http://repro.org/vocab/has_exon> ?e . ?e <p> ?v'

    # serving throughput (batched single-pattern path)
    PYTHONPATH=src python -m repro.launch.query --kg out.kgz --bench

Build the snapshot with ``python -m repro.launch.rdfize ... --emit kgz``.
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kg", required=True, help=".kgz snapshot path")
    ap.add_argument("pattern", nargs="*", help="triple pattern(s): ?var <iri> \"literal\"")
    ap.add_argument("--limit", type=int, default=None, help="max rows printed")
    ap.add_argument("--bench", action="store_true",
                    help="measure batched single-pattern queries/s")
    ap.add_argument("--bench-queries", type=int, default=50_000)
    ap.add_argument("--bench-batch", type=int, default=4096)
    ap.add_argument("--json", default=None,
                    help="also write the bench report to this path")
    args = ap.parse_args()

    from repro.kg import decode_bindings, parse_bgp, persist, solve

    store = persist.load(args.kg)
    print(
        f"[query] {store.n_triples} triples, {store.n_terms} terms "
        f"from {args.kg}",
        file=sys.stderr,
    )

    if args.bench:
        if store.n_triples == 0:
            ap.error(f"{args.kg} holds an empty graph: nothing to benchmark")
        from repro.kg.bench import bench_single_pattern

        report = bench_single_pattern(
            store, n_queries=args.bench_queries, batch=args.bench_batch
        )
        print(f"[query] {report['queries_per_s']:.0f} single-pattern queries/s "
              f"({report['n_queries']} queries, batch={report['batch']})",
              file=sys.stderr)
        print(json.dumps(report, indent=2))
        if args.json:
            with open(args.json, "w", encoding="utf-8") as f:
                json.dump(report, f, indent=2)
        return

    if not args.pattern:
        ap.error("provide at least one triple pattern (or --bench)")
    patterns = parse_bgp(" . ".join(args.pattern))
    bindings = solve(store, patterns)
    rows = decode_bindings(store, bindings, limit=args.limit)
    variables = list(bindings.cols)
    print("\t".join(variables))
    for row in rows:
        print("\t".join(row[v] for v in variables))
    shown = f" (showing {len(rows)})" if len(rows) < bindings.n else ""
    print(f"[query] {bindings.n} solutions{shown}", file=sys.stderr)


if __name__ == "__main__":
    main()
