"""Vocab-parallel embedding + logits (Megatron pattern, explicit shard_map).

GSPMD lowers ``jnp.take`` on a vocab-sharded table to an all-gather of the
WHOLE table (measured: 6 GiB/device for command-r's 256k x 12288 table), so
the gather is written explicitly:

  storage   : table (V, d) sharded P('model', 'data')  — vocab over TP,
              embedding dim over DP (FSDP-style, spreads optimizer state)
  embed     : all-gather d-shards over 'data' (transient V/16 x d slice)
              -> masked local take -> psum over 'model'
  logits    : h @ slice^T per model shard -> (B, S, V/16) vocab-sharded
              logits, exactly what the sharded softmax loss wants

Token streams are flattened to (B*S,) and sharded over the dp axes, so any
batch/wave shape whose token count divides the dp product works (chunked
prefill waves, microbatches); tiny decode batches fall back to a replicated
id stream (traffic is negligible there).  Falls back to plain dense ops
when no mesh is active, so smoke tests and CPU examples run unchanged.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.sharding import active_axes, current_mesh, shard_map


def _mesh_ready() -> bool:
    axes = active_axes()
    return "model" in axes and "data" in axes


def _dp_axes() -> tuple:
    return tuple(a for a in active_axes() if a in ("pod", "data"))


def _dp_prod(mesh, dp) -> int:
    return int(np.prod([mesh.shape[a] for a in dp])) if dp else 1


def embed(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """(V, d) table, (B, S) int ids -> (B, S, d)."""
    if not _mesh_ready():
        return jnp.take(table, ids, axis=0)
    mesh = current_mesh()
    n_model = mesh.shape["model"]
    dp = _dp_axes()
    V = table.shape[0]
    v_loc = V // n_model
    b, s = ids.shape
    flat = ids.reshape(-1)
    if flat.shape[0] % _dp_prod(mesh, dp) == 0:
        ids_spec, out_spec = P(dp), P(dp, None)
    else:  # tiny decode batches: replicate the id stream
        ids_spec, out_spec = P(None), P(None, None)

    def fn(tbl, ids_l):
        # tbl: (V/model, d/data); gather the d-shards (FSDP use-gather)
        full = jax.lax.all_gather(tbl, "data", axis=1, tiled=True)
        idx = jax.lax.axis_index("model")
        lo = idx * v_loc
        local = ids_l - lo
        ok = (local >= 0) & (local < v_loc)
        rows = jnp.take(full, jnp.clip(local, 0, v_loc - 1), axis=0)
        rows = jnp.where(ok[..., None], rows, 0)
        return jax.lax.psum(rows, "model")

    out = shard_map(
        fn,
        mesh=mesh,
        in_specs=(P("model", "data"), ids_spec),
        out_specs=out_spec,
        check_vma=False,
    )(table, flat)
    return out.reshape(b, s, table.shape[1])


def tied_logits(table: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """(V, d) table, (B, S, d) hidden -> (B, S, V) logits, vocab-sharded on
    'model' (ready for the sharded-softmax loss)."""
    if not _mesh_ready():
        return h @ table.T
    mesh = current_mesh()
    dp = _dp_axes()
    b, s, d = h.shape
    flat = h.reshape(-1, d)
    if flat.shape[0] % _dp_prod(mesh, dp) == 0:
        h_spec, out_spec = P(dp, None), P(dp, "model")
    else:
        h_spec, out_spec = P(None, None), P(None, "model")

    def fn(tbl, h_l):
        full = jax.lax.all_gather(tbl, "data", axis=1, tiled=True)  # (V/m, d)
        return h_l @ full.T  # (n/dp, V/m)

    out = shard_map(
        fn,
        mesh=mesh,
        in_specs=(P("model", "data"), h_spec),
        out_specs=out_spec,
        check_vma=False,
    )(table, flat)
    return out.reshape(b, s, table.shape[0])
