"""Mixture-of-Experts FFN with sort-based capacity-bounded dispatch.

Top-k routing (mixtral 8e/top-2, dbrx 16e/top-4).  Two execution paths:

* ``_forward_local`` — single-device reference (smoke tests, CPU examples):
  sort (token, choice) pairs by expert, scatter into capacity buffers, run
  one batched GLU over the expert axis, gather back.

* ``_forward_sharded`` — the production path (auto-selected when a mesh
  with 'data'+'model' axes is active), written as an explicit shard_map:
  tokens are dispatched LOCALLY on their data shard (GSPMD cannot shard a
  gather with globally-permuted indices — measured 12 GiB replicated
  dispatch buffers), expert weights are FSDP-gathered over 'data' on use,
  each expert runs tensor-parallel over 'model' (f sharded), and the
  row-parallel output is psum'd back.  Memory per device is
  O(E * cap_local * d) with cap_local = capacity of the LOCAL token slice.

Structural note (DESIGN.md §5): sort-by-key -> contiguous segments ->
process -> scatter back is the PJTT build/probe pattern of the paper's OJM
operator, applied to expert ids instead of join keys; the local-dispatch +
shuffle layout mirrors the distributed PTT's owner-sharding.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.sharding import active_axes, current_mesh, shard_map


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # quantize the FSDP use-gather of expert weights to int8 (per-expert
    # scale), halving the dominant collective of MoE training steps —
    # §Perf hillclimb 1.  Gradients flow through the dequantized weights
    # (straight-through on the scale).
    quantized_gather: bool = False


def init(key, cfg: MoEConfig, dtype):
    kr, ku, kg, kd = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    lim = 1.0 / jnp.sqrt(d)
    return {
        "router": layers.dense_init(kr, d, E, jnp.float32),
        "up": jax.random.uniform(ku, (E, d, f), dtype, -lim, lim),
        "gate": jax.random.uniform(kg, (E, d, f), dtype, -lim, lim),
        "down": jax.random.uniform(kd, (E, f, d), dtype, -lim, lim) * (d / f) ** 0.5,
    }


def _route(p, cfg: MoEConfig, xt):
    """Router: top-k gates + aux loss terms.  xt (n, d)."""
    E, k = cfg.n_experts, cfg.top_k
    logits = layers.dense(p["router"], xt.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum(axis=1), axis=0)
    aux = E * jnp.sum(me * fe)
    return gate_vals, gate_idx.astype(jnp.int32), aux


def _dispatch_compute_combine(cfg: MoEConfig, xt, gate_vals, gate_idx, w_gate, w_up, w_down):
    """Sort-dispatch n tokens into (E, cap, d) buffers, run the batched GLU
    with the given (possibly f-sharded) weights, combine.  Pure jnp."""
    n, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = int(cfg.capacity_factor * k * n / E + 1)

    flat_e = gate_idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=jnp.int32))
    pos = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = pos < cap
    tok = (order // k).astype(jnp.int32)

    xe = jnp.zeros((E, cap, d), xt.dtype)
    slot = jnp.where(keep, pos, cap)
    xe = xe.at[sorted_e, slot].set(xt[tok], mode="drop")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", xe, w_up
    )
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)           # (E, cap, d) partial

    y_sorted = jnp.where(keep[:, None], ye[sorted_e, jnp.clip(pos, 0, cap - 1)], 0)
    y = jnp.zeros_like(y_sorted).at[order].set(y_sorted)
    return jnp.sum(
        y.reshape(n, k, d) * gate_vals[..., None].astype(xt.dtype), axis=1
    )


def _forward_local(p, cfg: MoEConfig, x):
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gate_vals, gate_idx, aux = _route(p, cfg, xt)
    out = _dispatch_compute_combine(
        cfg, xt, gate_vals, gate_idx, p["gate"], p["up"], p["down"]
    )
    return out.reshape(b, s, d), aux


def _forward_sharded(p, cfg: MoEConfig, x):
    mesh = current_mesh()
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    b, s, d = x.shape

    def gather(w, axis):
        """FSDP use-gather; optionally int8-quantized on the wire."""
        if not cfg.quantized_gather:
            return jax.lax.all_gather(w, "data", axis=axis, tiled=True)
        scale = jnp.max(jnp.abs(w), axis=(1, 2), keepdims=True).astype(
            jnp.float32
        ) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
        qg = jax.lax.all_gather(q, "data", axis=axis, tiled=True)
        return (qg.astype(jnp.float32) * scale).astype(w.dtype)

    def body(xt, router, w_gate, w_up, w_down):
        # xt: (n_local, d) — this shard's tokens; weights: local slices
        gate_vals, gate_idx, aux = _route({"router": router}, cfg, xt)
        # FSDP use-gather of the expert weights' d (and down's d) shards
        wg = gather(w_gate, 1)   # (E, d, f/m)
        wu = gather(w_up, 1)
        wd = gather(w_down, 2)   # (E, f/m, d)
        y_partial = _dispatch_compute_combine(
            cfg, xt, gate_vals, gate_idx, wg, wu, wd
        )
        # row-parallel combine over the f shards
        y = jax.lax.psum(y_partial, "model")
        aux = jax.lax.pmean(aux, dp + ("model",))
        return y, aux

    xt = x.reshape(b * s, d)
    import numpy as _np

    dp_prod = int(_np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if xt.shape[0] % dp_prod == 0:
        x_spec, y_spec = P(dp, None), P(dp, None)
    else:  # tiny decode batches: replicate the token stream
        x_spec, y_spec = P(None, None), P(None, None)
    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            x_spec,
            {"w": P(None, None)},
            P(None, "data", "model"),
            P(None, "data", "model"),
            P(None, "model", "data"),
        ),
        out_specs=(y_spec, P()),
        check_vma=False,
    )(xt, p["router"], p["gate"], p["up"], p["down"])
    return y.reshape(b, s, d), aux


def forward(p, cfg: MoEConfig, x):
    """x: (B, S, d) -> ((B, S, d), aux_loss).  Auto-selects the shard_map
    production path when a ('data', 'model') mesh is active."""
    axes = active_axes()
    if "model" in axes and "data" in axes:
        return _forward_sharded(p, cfg, x)
    return _forward_local(p, cfg, x)
