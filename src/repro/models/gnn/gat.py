"""GAT (Velickovic et al., arXiv:1710.10903) — attention aggregator GNN.

Assigned config (gat-cora): 2 layers, 8 hidden units, 8 heads, ELU,
attention-softmax aggregation over incoming edges (SDDMM -> segment-softmax
-> SpMM regime, realized with gather + segment ops).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.gnn import common


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    task: str = "node_cls"  # node_cls | graph_reg
    channel_shard: bool = False
    dtype: Any = jnp.float32

    @property
    def out_dim(self) -> int:
        return self.n_classes if self.task == "node_cls" else 1


def init(key, cfg: GATConfig):
    ps = {}
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        k1, k2, key = jax.random.split(key, 3)
        last = i == cfg.n_layers - 1
        d_out = cfg.out_dim if last else cfg.d_hidden
        heads = 1 if last else cfg.n_heads
        ps[f"layer{i}"] = {
            "proj": layers.dense_init(k1, d_in, heads * d_out, cfg.dtype),
            "attn_src": jax.random.normal(k2, (heads, d_out), cfg.dtype) * 0.1,
            "attn_dst": jax.random.normal(k2, (heads, d_out), cfg.dtype) * 0.1,
        }
        d_in = d_out * heads if not last else d_out
    return ps


def forward(params, cfg: GATConfig, batch: common.GraphBatch):
    x = batch.node_feat.astype(cfg.dtype)
    for i in range(cfg.n_layers):
        p = params[f"layer{i}"]
        last = i == cfg.n_layers - 1
        d_out = cfg.out_dim if last else cfg.d_hidden
        heads = 1 if last else cfg.n_heads
        h = layers.dense(p["proj"], x).reshape(-1, heads, d_out)
        a_src = jnp.sum(h * p["attn_src"], axis=-1)  # (N, H)
        a_dst = jnp.sum(h * p["attn_dst"], axis=-1)
        e = jax.nn.leaky_relu(
            common.gather_src(a_src, batch) + common.gather_dst(a_dst, batch),
            0.2,
        )
        alpha = common.edge_softmax(e, batch)        # (E, H)
        msgs = common.gather_src(h, batch) * alpha[..., None]
        agg = common.scatter_sum(msgs, batch)        # (N, H, d_out)
        x = agg.reshape(-1, heads * d_out)
        if not last:
            x = jax.nn.elu(x)
            if cfg.channel_shard and (heads * d_out) % 16 == 0:
                x = common.shard_channels(x)
    return x  # (N, n_classes) for last layer with 1 head


def loss_fn(params, cfg: GATConfig, batch: common.GraphBatch, n_graphs: int = 1):
    out = forward(params, cfg, batch)
    if cfg.task == "node_cls":
        return common.node_ce_loss(out, batch)
    pred = common.graph_readout(out[:, 0], batch, n_graphs)
    return common.graph_mse_loss(pred, batch)
