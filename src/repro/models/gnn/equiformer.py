"""EquiformerV2 (Liao et al., arXiv:2306.12059) — equivariant graph attention
with eSCN-style SO(2) convolutions.

The eSCN insight (the paper's O(L^6) -> O(L^3) reduction): rotate each edge's
source features into a frame where the edge direction is z-hat; in that frame
the SH of the edge direction is nonzero only at m=0, so the full SO(3) tensor
product collapses to independent per-m SO(2) convolutions, truncated at
``m_max`` (assigned: l_max=6, m_max=2).  Attention weights come from the
invariant (m=0) channel; messages are rotated back and aggregated.

Features are stored flattened: (N, C, (l_max+1)^2).  The Wigner rotations use
``so3.wigner_d_from_rot`` (CG recursion, device-side and differentiable).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.gnn import common, so3


@dataclasses.dataclass(frozen=True)
class EquiformerConfig:
    name: str = "equiformer-v2"
    n_layers: int = 12
    channels: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 8
    cutoff: float = 5.0
    d_in: int = 16
    task: str = "graph_reg"  # graph_reg | node_cls
    n_classes: int = 0
    channel_shard: bool = False  # constrain channels over the model axis
    remat: bool = True
    dtype: Any = jnp.float32

    @property
    def n_sph(self) -> int:
        return (self.l_max + 1) ** 2


def _m_blocks(cfg: EquiformerConfig):
    """eSCN m-blocks: for each m in 0..m_max the list of flattened irrep
    indices (+m and -m components per l >= m)."""
    blocks = []
    for m in range(cfg.m_max + 1):
        plus = [l * l + l + m for l in range(max(m, 0), cfg.l_max + 1) if l >= m]
        minus = [l * l + l - m for l in range(max(m, 1), cfg.l_max + 1) if l >= m]
        blocks.append((np.array(plus), np.array(minus)))
    return blocks


def init(key, cfg: EquiformerConfig):
    C, H = cfg.channels, cfg.n_heads
    blocks = _m_blocks(cfg)
    k, key = jax.random.split(key)
    ps: dict = {"embed": layers.dense_init(k, cfg.d_in, C, cfg.dtype)}
    for i in range(cfg.n_layers):
        blk: dict = {}
        for m, (plus, minus) in enumerate(blocks):
            nl = len(plus)  # number of l's participating at this m
            k1, k2, key = jax.random.split(key, 3)
            # SO(2) conv: mixes channels x l at fixed m; two weight mats for
            # the (+m, -m) rotation-pair structure
            blk[f"so2_{m}_r"] = layers.dense_init(k1, C * nl, C * nl, cfg.dtype)
            if m > 0:
                blk[f"so2_{m}_i"] = layers.dense_init(k2, C * nl, C * nl, cfg.dtype)
        k1, k2, k3, k4, key = jax.random.split(key, 5)
        blk["radial"] = layers.mlp_init(k1, (cfg.n_rbf, C, C), cfg.dtype)
        blk["attn"] = layers.mlp_init(k2, (2 * C, C, H), cfg.dtype)
        blk["val_head"] = layers.dense_init(k3, C, C, cfg.dtype)
        blk["out"] = layers.dense_init(k4, C, C, cfg.dtype)
        k1, key = jax.random.split(key)
        blk["ffn"] = {
            "lin1": layers.dense_init(k1, C, 2 * C, cfg.dtype),
            "lin2": layers.dense_init(jax.random.split(k1)[0], 2 * C, C, cfg.dtype),
        }
        ps[f"layer{i}"] = blk
    k1, key = jax.random.split(key)
    out_dim = cfg.n_classes if cfg.task == "node_cls" else 1
    ps["readout"] = layers.mlp_init(k1, (C, C, out_dim), cfg.dtype)
    return ps


def _so2_conv(p, cfg, x_rot):
    """x_rot: (E, C, n_sph) in the edge-aligned frame.  Per-m SO(2) conv:
    (y_+m + i y_-m) = W (x_+m + i x_-m) with W complex -> two real mats."""
    E = x_rot.shape[0]
    C = cfg.channels
    out = jnp.zeros_like(x_rot)
    for m, (plus, minus) in enumerate(_m_blocks(cfg)):
        nl = len(plus)
        xp = x_rot[:, :, plus].reshape(E, C * nl)
        if m == 0:
            yp = layers.dense(p["so2_0_r"], xp)
            out = out.at[:, :, plus].set(yp.reshape(E, C, nl))
        else:
            xm = x_rot[:, :, minus].reshape(E, C * nl)
            wr, wi = p[f"so2_{m}_r"], p[f"so2_{m}_i"]
            yp = layers.dense(wr, xp) - layers.dense(wi, xm)
            ym = layers.dense(wi, xp) + layers.dense(wr, xm)
            out = out.at[:, :, plus].set(yp.reshape(E, C, nl))
            out = out.at[:, :, minus].set(ym.reshape(E, C, nl))
    return out  # m > m_max components are zeroed (eSCN truncation)


def _rotate(feats, Ds, inverse: bool):
    """Apply block-diagonal Wigner rotation to (E, C, n_sph)."""
    outs = []
    for l, D in enumerate(Ds):
        sl = feats[:, :, l * l:(l + 1) * (l + 1)]
        eq = "eab,ecb->eca" if inverse else "eba,ecb->eca"
        outs.append(jnp.einsum(eq, D, sl))
    return jnp.concatenate(outs, axis=-1)


def forward(params, cfg: EquiformerConfig, batch: common.GraphBatch, n_graphs: int = 1):
    C, H = cfg.channels, cfg.n_heads
    n = batch.n_nodes
    x = jnp.zeros((n, C, cfg.n_sph), cfg.dtype)
    x = x.at[:, :, 0].set(layers.dense(params["embed"], batch.node_feat.astype(cfg.dtype)))

    _, dist, unit = common.edge_vectors(batch)
    rbf = common.bessel_rbf(dist, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)
    R = so3.rot_to_align_z(unit.astype(jnp.float32))
    Ds = [d.astype(cfg.dtype) for d in so3.wigner_d_from_rot(cfg.l_max, R)]

    def layer(p, x):
        src = common.gather_src(x, batch)             # (E, C, n_sph)
        if cfg.channel_shard:
            src = common.shard_channels(src)
        rot = _rotate(src, Ds, inverse=False)         # edge frame
        if cfg.channel_shard:
            rot = common.shard_channels(rot)
        conv = _so2_conv(p, cfg, rot)
        if cfg.channel_shard:
            conv = common.shard_channels(conv)
        radial = layers.mlp(p["radial"], rbf)         # (E, C)
        conv = conv * radial[..., None]

        # attention from invariants (m=0 of conv + dst scalars)
        inv = conv[:, :, 0]                           # (E, C)
        dst_scal = common.gather_dst(x[:, :, 0], batch)
        logits = layers.mlp(p["attn"], jnp.concatenate([inv, dst_scal], -1))
        alpha = common.edge_softmax(logits, batch)    # (E, H)
        # head-structured value weighting
        vals = layers.dense(p["val_head"], conv.transpose(0, 2, 1)).transpose(0, 2, 1)
        vals = vals.reshape(vals.shape[0], H, C // H, cfg.n_sph)
        vals = vals * alpha[:, :, None, None].astype(vals.dtype)
        msg = vals.reshape(vals.shape[0], C, cfg.n_sph)
        msg = _rotate(msg, Ds, inverse=True)          # back to global frame
        if cfg.channel_shard:
            msg = common.shard_channels(msg)
        agg = common.scatter_sum(msg, batch)
        x = x + jnp.einsum("ncm,cd->ndm", agg, p["out"]["w"])

        # equivariant FFN: per-l linear with scalar-gated nonlinearity
        h = jnp.einsum("ncm,cd->ndm", x, p["ffn"]["lin1"]["w"])
        gate = jax.nn.silu(h[:, :, 0])[..., None]
        h = h * gate
        x = x + jnp.einsum("ncm,cd->ndm", h, p["ffn"]["lin2"]["w"])
        if cfg.channel_shard:
            x = common.shard_channels(x)
        return x

    if cfg.remat:
        layer = jax.checkpoint(layer)
    for i in range(cfg.n_layers):
        x = layer(params[f"layer{i}"], x)

    out = layers.mlp(params["readout"], x[:, :, 0])
    if cfg.task == "node_cls":
        return out
    return common.graph_readout(out[:, 0], batch, n_graphs)


def loss_fn(params, cfg: EquiformerConfig, batch, n_graphs: int = 1):
    out = forward(params, cfg, batch, n_graphs)
    if cfg.task == "node_cls":
        return common.node_ce_loss(out, batch)
    return common.graph_mse_loss(out, batch)
