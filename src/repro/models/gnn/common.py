"""Shared GNN substrate: graph batches, segment ops, message passing.

JAX has no sparse message-passing primitive (BCOO only) — per the
assignment, SpMM/SDDMM-style aggregation is implemented with
``jax.ops.segment_sum``/``segment_max`` over an edge-index scatter.  This
module IS that part of the system.

Static-shape convention: graphs are padded to fixed (N, E); padded edges
carry ``edge_mask=False`` (src/dst clipped into range) and every aggregation
masks them out explicitly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.models.sharding import active_axes


def shard_channels(x: jnp.ndarray):
    """Constrain the feature axis of a node/edge tensor to the 'model' axis
    (channel sharding for full-batch-large graphs).  The leading (node/edge)
    axis stays UNCONSTRAINED so edge tensors keep their dp sharding.  No-op
    without a mesh."""
    if "model" not in active_axes():
        return x
    U = P.UNCONSTRAINED
    spec = [U] * (x.ndim - 1) + ["model"]
    if x.ndim == 3:  # irreps tensors (N/E, C, m): channels are axis 1
        spec = [U, "model", None]
    return jax.lax.with_sharding_constraint(x, P(*spec))


class GraphBatch(NamedTuple):
    node_feat: jnp.ndarray   # (N, F) float
    positions: jnp.ndarray   # (N, 3) float
    edge_src: jnp.ndarray    # (E,) int32
    edge_dst: jnp.ndarray    # (E,) int32
    node_mask: jnp.ndarray   # (N,) bool
    edge_mask: jnp.ndarray   # (E,) bool
    labels: jnp.ndarray      # (N,) int32 node labels | (G,) float targets
    graph_id: jnp.ndarray    # (N,) int32 graph membership (0 when single)
    label_mask: jnp.ndarray  # (N,) or (G,) bool — which labels count

    @property
    def n_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def n_edges(self) -> int:
        return self.edge_src.shape[0]


def gather_src(x: jnp.ndarray, batch: GraphBatch) -> jnp.ndarray:
    return jnp.take(x, batch.edge_src, axis=0)


def gather_dst(x: jnp.ndarray, batch: GraphBatch) -> jnp.ndarray:
    return jnp.take(x, batch.edge_dst, axis=0)


def _mask_messages(msgs: jnp.ndarray, batch: GraphBatch) -> jnp.ndarray:
    m = batch.edge_mask
    return msgs * m.reshape((-1,) + (1,) * (msgs.ndim - 1)).astype(msgs.dtype)


def scatter_sum(msgs: jnp.ndarray, batch: GraphBatch) -> jnp.ndarray:
    """Aggregate edge messages at their destination (masked)."""
    return jax.ops.segment_sum(
        _mask_messages(msgs, batch), batch.edge_dst, num_segments=batch.n_nodes
    )


def scatter_mean(msgs: jnp.ndarray, batch: GraphBatch) -> jnp.ndarray:
    s = scatter_sum(msgs, batch)
    deg = jax.ops.segment_sum(
        batch.edge_mask.astype(msgs.dtype), batch.edge_dst,
        num_segments=batch.n_nodes,
    )
    return s / jnp.maximum(deg, 1.0).reshape((-1,) + (1,) * (msgs.ndim - 1))


def edge_softmax(logits: jnp.ndarray, batch: GraphBatch) -> jnp.ndarray:
    """Softmax over incoming edges per destination node (GAT)."""
    neg = jnp.asarray(-1e30, logits.dtype)
    logits = jnp.where(
        batch.edge_mask.reshape((-1,) + (1,) * (logits.ndim - 1)), logits, neg
    )
    mx = jax.ops.segment_max(logits, batch.edge_dst, num_segments=batch.n_nodes)
    ex = jnp.exp(logits - jnp.take(mx, batch.edge_dst, axis=0))
    ex = _mask_messages(ex, batch)
    den = jax.ops.segment_sum(ex, batch.edge_dst, num_segments=batch.n_nodes)
    return ex / jnp.maximum(jnp.take(den, batch.edge_dst, axis=0), 1e-20)


def graph_readout(node_scalars: jnp.ndarray, batch: GraphBatch, n_graphs: int):
    """Sum-pool node scalars per graph (energy-style readout)."""
    vals = node_scalars * batch.node_mask.astype(node_scalars.dtype)
    return jax.ops.segment_sum(vals, batch.graph_id, num_segments=n_graphs)


def node_ce_loss(logits: jnp.ndarray, batch: GraphBatch) -> jnp.ndarray:
    """Masked node-classification cross entropy."""
    mask = batch.label_mask & batch.node_mask
    labels = jnp.where(mask, batch.labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[:, None], axis=-1
    )[:, 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def graph_mse_loss(pred: jnp.ndarray, batch: GraphBatch) -> jnp.ndarray:
    """Per-graph regression MSE (labels are (G,) targets)."""
    err = (pred.astype(jnp.float32) - batch.labels.astype(jnp.float32)) ** 2
    m = batch.label_mask.astype(jnp.float32)
    return jnp.sum(err * m) / jnp.maximum(jnp.sum(m), 1)


def edge_vectors(batch: GraphBatch, eps: float = 1e-9):
    """(vec, dist, unit) per edge from node positions."""
    vec = gather_dst(batch.positions, batch) - gather_src(batch.positions, batch)
    dist = jnp.linalg.norm(vec, axis=-1, keepdims=True)
    unit = vec / jnp.maximum(dist, eps)
    return vec, dist[..., 0], unit


def bessel_rbf(dist: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """Sine Bessel radial basis with smooth cosine cutoff (NequIP/DimeNet)."""
    d = jnp.clip(dist, 1e-6, cutoff)
    n = jnp.arange(1, n_rbf + 1, dtype=d.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d[..., None] / cutoff) / d[..., None]
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist, 0, cutoff) / cutoff) + 1.0)
    return basis * env[..., None]
