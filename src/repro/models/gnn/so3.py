"""SO(3) machinery: real spherical harmonics, Clebsch-Gordan coefficients,
Wigner-D matrices — the substrate for NequIP (E(3) tensor products, l<=2)
and EquiformerV2 (eSCN SO(2) convolutions, l<=6).

Conventions: real spherical harmonics WITHOUT the Condon-Shortley phase,
flattened irrep index ``idx(l, m) = l*l + l + m``; the l=1 basis is then
exactly proportional to (y, z, x).

Coupling coefficients are *solved numerically* on the host (float64) from
the defining intertwiner equation ``(D1 (x) D2) W = W D3`` using Wigner-D
matrices extracted from the spherical harmonics themselves (least squares
over random directions).  This makes every coefficient table consistent
with ``sph_harm`` by construction — no phase-convention bookkeeping.
SO(3) multiplicity is 1, so W is unique up to sign/scale; it is normalized
to unit Frobenius norm with a deterministic sign.

Validated by tests/test_so3.py: SH orthonormality, CG equivariance,
D(R1 R2) = D(R1) D(R2), SH equivariance under rotations.
"""

from __future__ import annotations

from functools import lru_cache
from math import factorial, sqrt

import jax.numpy as jnp
import numpy as np


def n_coeffs(l_max: int) -> int:
    return (l_max + 1) ** 2


def sph_harm(l_max: int, vecs, xp=jnp):
    """Real spherical harmonics for unit vectors.

    vecs: (..., 3) -> (..., (l_max+1)^2).  Evaluated in Cartesian form (no
    trig): A_m + i B_m = (x + i y)^m and the semi-normalized associated
    Legendre recurrence in z, so poles are exact.  ``xp=np`` runs the same
    computation on the host in float64 (used by the coefficient solver).
    """
    x, y, z = vecs[..., 0], vecs[..., 1], vecs[..., 2]

    # A_m = Re (x+iy)^m, B_m = Im (x+iy)^m  (pure polynomials in x, y)
    A = [xp.ones_like(z), x]
    B = [xp.zeros_like(z), y]
    for m in range(2, l_max + 1):
        A.append(A[m - 1] * x - B[m - 1] * y)
        B.append(B[m - 1] * x + A[m - 1] * y)

    # shat[(l, m)] = P_l^m(z) / (1-z^2)^(m/2) (no Condon-Shortley phase)
    shat: dict[tuple[int, int], object] = {}
    for m in range(0, l_max + 1):
        mm = 1.0
        for k in range(1, m + 1):
            mm *= 2 * k - 1  # (2m-1)!!
        shat[(m, m)] = xp.full(z.shape, mm, getattr(z, "dtype", None))
        if m + 1 <= l_max:
            shat[(m + 1, m)] = z * (2 * m + 1) * shat[(m, m)]
        for l in range(m + 2, l_max + 1):
            shat[(l, m)] = (
                (2 * l - 1) * z * shat[(l - 1, m)] - (l + m - 1) * shat[(l - 2, m)]
            ) / (l - m)

    ys = []
    for l in range(0, l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            nlm = sqrt(
                (2 * l + 1) / (4 * np.pi) * factorial(l - am) / factorial(l + am)
            )
            if m > 0:
                val = sqrt(2.0) * nlm * shat[(l, am)] * A[am]
            elif m < 0:
                val = sqrt(2.0) * nlm * shat[(l, am)] * B[am]
            else:
                val = nlm * shat[(l, 0)]
            ys.append(val)
    return xp.stack(ys, axis=-1)


# --------------------------------------------------- host-side coefficients


def _rand_rot(rng: np.random.Generator) -> np.ndarray:
    q = rng.normal(size=4)
    q /= np.linalg.norm(q)
    w, x, y, z = q
    return np.array(
        [
            [1 - 2 * (y * y + z * z), 2 * (x * y - z * w), 2 * (x * z + y * w)],
            [2 * (x * y + z * w), 1 - 2 * (x * x + z * z), 2 * (y * z - x * w)],
            [2 * (x * z - y * w), 2 * (y * z + x * w), 1 - 2 * (x * x + y * y)],
        ]
    )


def _wigner_np(l: int, R: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """D^l(R) extracted from the SH themselves: Y_l(Rv) = D Y_l(v) solved in
    least squares over random directions (exact up to float64 rounding)."""
    k = 4 * l + 12
    v = rng.normal(size=(k, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    sl = slice(l * l, (l + 1) * (l + 1))
    Y0 = sph_harm(l, v, xp=np)[:, sl]
    YR = sph_harm(l, v @ R.T, xp=np)[:, sl]
    Dt, *_ = np.linalg.lstsq(Y0, YR, rcond=None)
    return Dt.T


@lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis coupling tensor W (2l1+1, 2l2+1, 2l3+1) with
    (D1 (x) D2) W = W D3, solved from the intertwiner null space."""
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return np.zeros((d1, d2, d3))
    rng = np.random.default_rng(1234 + 97 * l1 + 13 * l2 + l3)
    rows = []
    for _ in range(3):
        R = _rand_rot(rng)
        D1 = _wigner_np(l1, R, rng)
        D2 = _wigner_np(l2, R, rng)
        D3 = _wigner_np(l3, R, rng)
        # textbook intertwiner in matrix form (rows (a,b), cols c):
        #   (D1 (x) D2) M = M D3
        # which gives the contraction property the models rely on:
        #   einsum('abc,a,b->c', W, D1 x, D2 y) = D3 einsum('abc,a,b->c', W, x, y)
        A = np.kron(np.kron(D1, D2), np.eye(d3)) - np.kron(np.eye(d1 * d2), D3.T)
        rows.append(A)
    A = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(A)
    w = vt[-1]
    assert s[-1] < 1e-8 and (len(s) < 2 or s[-2] > 1e-4), (
        f"CG({l1},{l2},{l3}): unexpected intertwiner spectrum {s[-3:]}"
    )
    W = w.reshape(d1, d2, d3)
    W /= np.linalg.norm(W)
    # deterministic sign: first entry with |.| > 1e-6 positive
    flat = W.reshape(-1)
    idx = np.argmax(np.abs(flat) > 1e-6)
    if flat[idx] < 0:
        W = -W
    return W


@lru_cache(maxsize=None)
def _cg_stack_matrix(l: int) -> np.ndarray:
    """Isometry C: ((2l-1)*3, 2l+1) mapping (l-1) (x) 1 -> l, columns
    orthonormalized (used by the Wigner-D recursion).  W^T W = c I by Schur,
    so normalizing one global scale suffices."""
    W = real_cg(l - 1, 1, l).reshape((2 * l - 1) * 3, 2 * l + 1)
    return W / np.linalg.norm(W[:, 0])


def wigner_d_from_rot(l_max: int, R: jnp.ndarray) -> list[jnp.ndarray]:
    """Real Wigner-D matrices for rotation matrices R (..., 3, 3).

    Returns [D^0, ..., D^l_max], D^l of shape (..., 2l+1, 2l+1), via the CG
    recursion D^l = C^T (D^{l-1} (x) D^1) C.  D^1 is R conjugated into the
    real-SH (y, z, x) ordering.  Pure jnp -> device-side & differentiable.
    """
    batch = R.shape[:-2]
    perm = np.array([[0, 1, 0], [0, 0, 1], [1, 0, 0]], dtype=np.float64)
    Pm = jnp.asarray(perm, R.dtype)
    D1 = jnp.einsum("ij,...jk,lk->...il", Pm, R, Pm)
    Ds = [jnp.ones(batch + (1, 1), R.dtype), D1]
    for l in range(2, l_max + 1):
        C = jnp.asarray(_cg_stack_matrix(l), R.dtype)
        prev = Ds[l - 1]
        kron = jnp.einsum("...ab,...cd->...acbd", prev, D1).reshape(
            batch + ((2 * l - 1) * 3, (2 * l - 1) * 3)
        )
        Ds.append(jnp.einsum("ia,...ij,jb->...ab", C, kron, C))
    return Ds


def rot_to_align_z(vec: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    """Rotation R (..., 3, 3) with R @ v_hat = z_hat, deterministic frame."""
    v = vec / jnp.clip(jnp.linalg.norm(vec, axis=-1, keepdims=True), eps, None)
    ref = jnp.where(
        (jnp.abs(v[..., 0:1]) < 0.9),
        jnp.broadcast_to(jnp.asarray([1.0, 0.0, 0.0], v.dtype), v.shape),
        jnp.broadcast_to(jnp.asarray([0.0, 1.0, 0.0], v.dtype), v.shape),
    )
    b = jnp.cross(v, ref)
    b = b / jnp.clip(jnp.linalg.norm(b, axis=-1, keepdims=True), eps, None)
    c = jnp.cross(v, b)
    return jnp.stack([b, c, v], axis=-2)  # rows: (x', y', z'=v)
