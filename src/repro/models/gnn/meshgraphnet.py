"""MeshGraphNet (Pfaff et al., arXiv:2010.03409) — encode-process-decode.

Assigned config: 15 processor layers, d_hidden=128, 2-layer MLPs
(LayerNorm-terminated), sum aggregation, residual edge/node updates.
Edge inputs are (relative position, distance) per the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.gnn import common


@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_in: int = 16
    remat: bool = True
    channel_shard: bool = False  # shard hidden channels over 'model'
    out_dim: int = 1          # per-graph regression target
    task: str = "graph_reg"   # graph_reg | node_cls
    n_classes: int = 0
    dtype: Any = jnp.float32


def _mlp_ln_init(key, d_in, d_hidden, d_out, n_layers, dtype):
    dims = (d_in,) + (d_hidden,) * (n_layers - 1) + (d_out,)
    k1, k2 = jax.random.split(key)
    return {"mlp": layers.mlp_init(k1, dims, dtype), "ln": layers.layernorm_init(d_out, dtype)}


def _mlp_ln(p, x, shard: bool = False):
    if not shard:
        return layers.layernorm(p["ln"], layers.mlp(p["mlp"], x))
    # channel-sharded variant: constrain after every dense so GSPMD lowers
    # the sharded-contraction matmuls to reduce-scatter instead of
    # materializing full-width outputs (ogb_products-scale graphs)
    n = len(p["mlp"])
    import jax

    for i in range(n):
        x = layers.dense(p["mlp"][f"fc{i}"], x)
        if i < n - 1:
            x = jax.nn.relu(x)
        x = common.shard_channels(x)
    return common.shard_channels(layers.layernorm(p["ln"], x))


def init(key, cfg: MGNConfig):
    ken, kee, kd, key = jax.random.split(key, 4)
    d = cfg.d_hidden
    ps = {
        "node_enc": _mlp_ln_init(ken, cfg.d_in, d, d, cfg.mlp_layers, cfg.dtype),
        "edge_enc": _mlp_ln_init(kee, 4, d, d, cfg.mlp_layers, cfg.dtype),
    }
    for i in range(cfg.n_layers):
        k1, k2, key = jax.random.split(key, 3)
        ps[f"block{i}"] = {
            "edge": _mlp_ln_init(k1, 3 * d, d, d, cfg.mlp_layers, cfg.dtype),
            "node": _mlp_ln_init(k2, 2 * d, d, d, cfg.mlp_layers, cfg.dtype),
        }
    out_d = cfg.n_classes if cfg.task == "node_cls" else cfg.out_dim
    ps["decoder"] = {
        "mlp": layers.mlp_init(kd, (d, d, out_d), cfg.dtype)
    }
    return ps


def forward(params, cfg: MGNConfig, batch: common.GraphBatch, n_graphs: int = 1):
    vec, dist, _ = common.edge_vectors(batch)
    ef = jnp.concatenate([vec, dist[:, None]], axis=-1).astype(cfg.dtype)
    v = _mlp_ln(params["node_enc"], batch.node_feat.astype(cfg.dtype),
                shard=cfg.channel_shard)
    e = _mlp_ln(params["edge_enc"], ef, shard=cfg.channel_shard)
    def block(p, v, e):
        cs = cfg.channel_shard
        e_in = jnp.concatenate(
            [e, common.gather_src(v, batch), common.gather_dst(v, batch)], axis=-1
        )
        if cs:
            e_in = common.shard_channels(e_in)
        e = e + _mlp_ln(p["edge"], e_in, shard=cs)
        agg = common.scatter_sum(e, batch)
        v = v + _mlp_ln(p["node"], jnp.concatenate([v, agg], axis=-1), shard=cs)
        if cs:
            v = common.shard_channels(v)
            e = common.shard_channels(e)
        return v, e

    if cfg.remat:
        block = jax.checkpoint(block)
    for i in range(cfg.n_layers):
        v, e = block(params[f"block{i}"], v, e)
    out = layers.mlp(params["decoder"]["mlp"], v)
    if cfg.task == "node_cls":
        return out  # (N, n_classes)
    return common.graph_readout(out[:, 0], batch, n_graphs)  # (G,)


def loss_fn(params, cfg: MGNConfig, batch: common.GraphBatch, n_graphs: int = 1):
    out = forward(params, cfg, batch, n_graphs)
    if cfg.task == "node_cls":
        return common.node_ce_loss(out, batch)
    return common.graph_mse_loss(out, batch)
