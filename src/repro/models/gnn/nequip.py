"""NequIP (Batzner et al., arXiv:2101.03164) — E(3)-equivariant interatomic
potential via Clebsch-Gordan tensor products.

Assigned config: 5 interaction layers, 32 channels, l_max=2, 8 Bessel RBFs,
cutoff 5 Å.  Node features are a dict of irreps ``l -> (N, C, 2l+1)``.  Each
interaction: message = sum over CG paths (l_in (x) l_sh -> l_out) of
``w_path(r_ij) * W[l_in, l_sh, l_out] f_src Y(r_hat)``, aggregated with
segment_sum, followed by per-l self-interaction linear layers and a gated
nonlinearity (scalars gate the l>0 irreps).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.gnn import common, so3


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    channels: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_in: int = 16           # raw node feature width (species embedding etc.)
    task: str = "graph_reg"  # graph_reg | node_cls
    n_classes: int = 0
    remat: bool = True
    channel_shard: bool = False
    dtype: Any = jnp.float32

    @property
    def paths(self):
        out = []
        for l1 in range(self.l_max + 1):
            for l2 in range(self.l_max + 1):
                for l3 in range(self.l_max + 1):
                    if abs(l1 - l2) <= l3 <= l1 + l2:
                        out.append((l1, l2, l3))
        return out


def init(key, cfg: NequIPConfig):
    C = cfg.channels
    k_embed, key = jax.random.split(key)
    ps: dict = {"embed": layers.dense_init(k_embed, cfg.d_in, C, cfg.dtype)}
    for i in range(cfg.n_layers):
        blk: dict = {}
        for (l1, l2, l3) in cfg.paths:
            k1, key = jax.random.split(key)
            # radial MLP: rbf -> C path weights (per channel)
            blk[f"radial_{l1}_{l2}_{l3}"] = layers.mlp_init(
                k1, (cfg.n_rbf, 16, C), cfg.dtype
            )
        for l in range(cfg.l_max + 1):
            k1, k2, key = jax.random.split(key, 3)
            blk[f"self_{l}"] = layers.dense_init(k1, C, C, cfg.dtype)
            blk[f"out_{l}"] = layers.dense_init(k2, C, C, cfg.dtype)
        k1, key = jax.random.split(key)
        blk["gate"] = layers.dense_init(k1, C, C * cfg.l_max, cfg.dtype)
        ps[f"layer{i}"] = blk
    k1, k2, key = jax.random.split(key, 3)
    out_dim = cfg.n_classes if cfg.task == "node_cls" else 1
    ps["readout"] = layers.mlp_init(k1, (C, 16, out_dim), cfg.dtype)
    return ps


def _apply_lin(p, feat):
    """Per-l linear over the channel axis: (N, C, M) -> (N, C', M)."""
    return jnp.einsum("ncm,cd->ndm", feat, p["w"])


def forward(params, cfg: NequIPConfig, batch: common.GraphBatch, n_graphs: int = 1):
    C = cfg.channels
    n = batch.n_nodes
    # initial irreps: scalars from node features; higher l start at zero
    feats = {
        0: layers.dense(params["embed"], batch.node_feat.astype(cfg.dtype))[..., None]
    }
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n, C, 2 * l + 1), cfg.dtype)

    _, dist, unit = common.edge_vectors(batch)
    sh = so3.sph_harm(cfg.l_max, unit).astype(cfg.dtype)  # (E, (L+1)^2)
    rbf = common.bessel_rbf(dist, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)

    def layer(p, feats):
        msgs = {l: 0.0 for l in range(cfg.l_max + 1)}
        src = {l: common.gather_src(feats[l], batch) for l in feats}
        for (l1, l2, l3) in cfg.paths:
            w = layers.mlp(p[f"radial_{l1}_{l2}_{l3}"], rbf)       # (E, C)
            cg = jnp.asarray(so3.real_cg(l1, l2, l3), cfg.dtype)    # (m1, m2, m3)
            y = sh[:, l2 * l2:(l2 + 1) * (l2 + 1)]                  # (E, m2)
            m = jnp.einsum("eca,eb,abd->ecd", src[l1], y, cg)
            if cfg.channel_shard:
                m = common.shard_channels(m)
            msgs[l3] = msgs[l3] + m * w[..., None]
        agg = {
            l: common.scatter_sum(jnp.asarray(msgs[l]), batch) for l in msgs
        }
        new = {}
        for l in range(cfg.l_max + 1):
            new[l] = _apply_lin(p[f"self_{l}"], feats[l]) + _apply_lin(
                p[f"out_{l}"], agg[l]
            )
            if cfg.channel_shard:
                new[l] = common.shard_channels(new[l])
        # gated nonlinearity: scalars -> silu; l>0 scaled by sigmoid gates
        scal = new[0][..., 0]
        gates = jax.nn.sigmoid(layers.dense(p["gate"], scal))       # (N, C*l_max)
        out_feats = {0: jax.nn.silu(scal)[..., None]}
        for l in range(1, cfg.l_max + 1):
            g = gates[:, (l - 1) * C: l * C]
            out_feats[l] = new[l] * g[..., None]
        if cfg.channel_shard:
            out_feats = {l: common.shard_channels(f) for l, f in out_feats.items()}
        return out_feats

    if cfg.remat:
        layer = jax.checkpoint(layer)
    for i in range(cfg.n_layers):
        feats = layer(params[f"layer{i}"], feats)
    out = layers.mlp(params["readout"], feats[0][..., 0])
    if cfg.task == "node_cls":
        return out  # (N, n_classes) invariant node logits
    return common.graph_readout(out[:, 0], batch, n_graphs)


def loss_fn(params, cfg: NequIPConfig, batch, n_graphs: int = 1):
    out = forward(params, cfg, batch, n_graphs)
    if cfg.task == "node_cls":
        return common.node_ce_loss(out, batch)
    return common.graph_mse_loss(out, batch)
