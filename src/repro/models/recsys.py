"""Wide & Deep (Cheng et al., arXiv:1606.07792) — the assigned recsys arch.

40 sparse fields, embed_dim 32, deep MLP 1024-512-256, concat interaction.
The embedding LOOKUP is the hot path (assignment note): JAX has no
EmbeddingBag, so it is built here from ``jnp.take`` + ``segment_sum``, with
the paper-derived ``dedup_gather`` as a first-class optimization for
duplicate-heavy id streams (DESIGN.md §5).

Sharding: the stacked embedding table (F, V, D) and the wide table (F, V)
are row-sharded over ('data','model') on the vocab axis; the MLP is
replicated; the batch is sharded over ('pod','data').
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.dedup_gather import gather_maybe_dedup
from repro.models import layers
from repro.models.sharding import active_axes, current_mesh, shard_map


@dataclasses.dataclass(frozen=True)
class WideDeepConfig:
    name: str = "wide-deep"
    n_sparse: int = 40
    embed_dim: int = 32
    vocab_per_field: int = 1_000_000
    n_dense: int = 13
    mlp: tuple[int, ...] = (1024, 512, 256)
    # multi-hot bag size per field (1 = one-hot); EmbeddingBag sums the bag
    bag_size: int = 1
    dedup_cap: int | None = None  # PTT-style unique-gather cap (None = off)
    dtype: Any = jnp.float32


def init(key, cfg: WideDeepConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    F, V, D = cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim
    d_in = F * D + cfg.n_dense
    return {
        "embed": jax.random.normal(k1, (F, V, D), cfg.dtype) * 0.01,
        "wide": jax.random.normal(k2, (F, V), cfg.dtype) * 0.01,
        "mlp": layers.mlp_init(k3, (d_in, *cfg.mlp), cfg.dtype),
        "head": layers.dense_init(k4, cfg.mlp[-1], 1, cfg.dtype, bias=True),
    }


def param_specs(cfg: WideDeepConfig):
    mlp_specs = {
        f"fc{i}": {"w": P(None, None), "b": P(None)} for i in range(len(cfg.mlp))
    }
    return {
        # vocab over 'model' only: the shard_map lookup needs the full row
        # range per model shard (335 MB/device for 40 x 2^20 x 32 fp32)
        "embed": P(None, "model", None),
        "wide": P(None, "model"),
        "mlp": mlp_specs,
        "head": {"w": P(None, None), "b": P(None)},
    }


def _local_dedup(flat_ids: jnp.ndarray, cap: int):
    """Sort-based first-occurrence dedup (the PTT combiner, local to the
    shard).  Returns (unique_ids[cap], group_of_lane[n])."""
    n = flat_ids.shape[0]
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    first = jnp.concatenate([jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    slot = jnp.cumsum(first) - 1
    uids = jnp.zeros((cap,), flat_ids.dtype).at[
        jnp.where(first & (slot < cap), slot, cap)
    ].set(sorted_ids, mode="drop")
    group = jnp.zeros((n,), slot.dtype).at[order].set(jnp.clip(slot, 0, cap - 1))
    return uids, group


def _vocab_parallel_rows(table3, flat_ids, cfg: WideDeepConfig, mesh, dp):
    """shard_map row fetch: table (F, V, D) vocab-sharded on 'model', ids
    sharded over dp.  Local masked take + psum('model'); with ``dedup_cap``
    the shard's id stream is deduplicated FIRST, so only |S| rows ride the
    psum (the paper's |N| -> |S| saving on the wire)."""
    V = cfg.vocab_per_field
    n_model = mesh.shape["model"]
    v_loc = V // n_model

    def body(tbl, ids):
        # tbl: (F, V/m, D); ids: (n_local,) global flat ids = f*V + v
        idx = jax.lax.axis_index("model")
        lo = idx * v_loc

        def fetch(lookup_ids):
            f = lookup_ids // V
            v = lookup_ids % V - lo
            ok = (v >= 0) & (v < v_loc)
            rows = tbl[f, jnp.clip(v, 0, v_loc - 1)]
            rows = jnp.where(ok[..., None], rows, 0)
            return jax.lax.psum(rows, "model")

        if cfg.dedup_cap is not None:
            uids, group = _local_dedup(ids, cfg.dedup_cap)
            urows = fetch(uids)              # (cap, D) — the only psum
            return jnp.take(urows, group, axis=0)
        return fetch(ids)

    import numpy as _np

    dp_prod = int(_np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if flat_ids.shape[0] % dp_prod == 0:
        ids_spec, out_spec = P(dp), P(dp, None)
    else:  # tiny batches (retrieval_cand B=1): replicate the id stream
        ids_spec, out_spec = P(None), P(None, None)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None, "model", None), ids_spec),
        out_specs=out_spec,
        check_vma=False,
    )(table3, flat_ids)


def _fetch_rows(params_key, params, cfg: WideDeepConfig, sparse_ids):
    """(B, F, G) ids -> (B*F*G, D) rows via the vocab-parallel path when a
    mesh is active, else plain (optionally dedup'd) gather."""
    B, F, G = sparse_ids.shape
    V = cfg.vocab_per_field
    table3 = params[params_key]
    if table3.ndim == 2:  # wide table (F, V) -> (F, V, 1)
        table3 = table3[..., None]
    global_ids = (
        sparse_ids + (jnp.arange(F, dtype=sparse_ids.dtype) * V)[None, :, None]
    ).reshape(-1)
    axes = active_axes()
    if "model" in axes and "data" in axes:
        mesh = current_mesh()
        dp = tuple(a for a in axes if a in ("pod", "data"))
        return _vocab_parallel_rows(table3, global_ids, cfg, mesh, dp)
    flat_table = table3.reshape(F * V, -1)
    return gather_maybe_dedup(flat_table, global_ids, cfg.dedup_cap)


def embedding_bag(params, cfg: WideDeepConfig, sparse_ids: jnp.ndarray):
    """sparse_ids int32 (B, F, bag) -> (B, F*D) summed bag embeddings.

    JAX's EmbeddingBag: row fetch + reshape-sum.  With ``dedup_cap`` set the
    id stream is deduplicated first (the PTT optimization) — one fetch (and
    one unit of cross-shard traffic) per *distinct* (field, id) pair.
    """
    B, F, G = sparse_ids.shape
    D = cfg.embed_dim
    rows = _fetch_rows("embed", params, cfg, sparse_ids)
    return rows.reshape(B, F, G, D).sum(axis=2).reshape(B, F * D)


def wide_logit(params, cfg: WideDeepConfig, sparse_ids: jnp.ndarray):
    B, F, G = sparse_ids.shape
    w = _fetch_rows("wide", params, cfg, sparse_ids)
    return w.reshape(B, F * G).sum(axis=-1)


def forward(params, cfg: WideDeepConfig, sparse_ids, dense_feats):
    """-> logits (B,).  sparse_ids (B, F, bag), dense_feats (B, n_dense)."""
    deep_in = jnp.concatenate(
        [embedding_bag(params, cfg, sparse_ids), dense_feats.astype(cfg.dtype)],
        axis=-1,
    )
    deep = layers.mlp(params["mlp"], deep_in, final_act=True)
    deep_logit = layers.dense(params["head"], deep)[:, 0]
    return deep_logit + wide_logit(params, cfg, sparse_ids)


def loss_fn(params, cfg: WideDeepConfig, sparse_ids, dense_feats, labels):
    """Binary cross-entropy (CTR objective)."""
    logits = forward(params, cfg, sparse_ids, dense_feats).astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def user_tower(params, cfg: WideDeepConfig, sparse_ids, dense_feats):
    """Deep-tower representation (B, mlp[-1]) for retrieval scoring."""
    deep_in = jnp.concatenate(
        [embedding_bag(params, cfg, sparse_ids), dense_feats.astype(cfg.dtype)],
        axis=-1,
    )
    return layers.mlp(params["mlp"], deep_in, final_act=True)


def retrieval_scores(params, cfg: WideDeepConfig, sparse_ids, dense_feats, candidates):
    """Score one query against a candidate matrix (n_cand, mlp[-1]) — a
    batched dot, NOT a loop (assignment note).  Returns (B, n_cand)."""
    u = user_tower(params, cfg, sparse_ids, dense_feats)   # (B, d)
    return u @ candidates.T.astype(u.dtype)
