"""Shared neural-net layers: pure-pytree params + apply functions.

No flax/haiku dependency: params are nested dicts of jnp arrays, created by
``init_*`` functions and consumed by ``apply``-style functions.  This keeps
``jax.eval_shape`` trivially usable for allocation-free dry-runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False):
    scale = 1.0 / np.sqrt(d_in)
    p = {"w": jax.random.uniform(key, (d_in, d_out), dtype, -scale, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * p["scale"]


def layernorm_init(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * p["scale"] + p["bias"]


def embedding_init(key, vocab: int, dim: int, dtype):
    return {"table": jax.random.normal(key, (vocab, dim), dtype) * 0.02}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def mlp_init(key, dims: tuple[int, ...], dtype, bias: bool = True):
    """Plain ReLU MLP: dims = (in, h1, ..., out)."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"fc{i}": dense_init(keys[i], dims[i], dims[i + 1], dtype, bias=bias)
        for i in range(len(dims) - 1)
    }


def mlp(p, x, act=jax.nn.relu, final_act: bool = False):
    n = len(p)
    for i in range(n):
        x = dense(p[f"fc{i}"], x)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def glu_init(key, d_model: int, d_ff: int, dtype):
    """Gated linear unit block (SwiGLU/GeGLU share the structure)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "up": dense_init(k1, d_model, d_ff, dtype),
        "gate": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def glu(p, x, act=jax.nn.silu):
    return dense(p["down"], act(dense(p["gate"], x)) * dense(p["up"], x))


def cross_entropy_loss(logits, labels, ignore_index: int = -100):
    """Token-level mean CE; logits (..., V) fp32-accumulated."""
    logits = logits.astype(jnp.float32)
    mask = labels != ignore_index
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
