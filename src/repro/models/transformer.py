"""Transformer LM family: dense + MoE, GQA/MQA, SWA, GeGLU/SwiGLU, RoPE.

One parametric implementation covers the five assigned LM architectures.
Layers are stacked (leading L axis per parameter leaf) and executed with
``lax.scan`` + per-layer ``jax.checkpoint`` (remat), so compile time and
activation memory are O(1) in depth.

Sharding (DESIGN.md §4): Megatron TP on the ``model`` axis (QKV/up column-
parallel, O/down row-parallel, vocab-parallel embedding, expert-parallel
MoE), batch on (``pod``, ``data``), optional sequence parallelism on the
residual stream.  ``param_specs``/``act_spec`` centralize the rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import attention, layers, moe, vocab_parallel
from repro.models.sharding import constrain


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    # variants
    qkv_bias: bool = False
    gated_act: str = "silu"          # silu -> SwiGLU, gelu -> GeGLU
    window: int | None = None        # sliding-window attention (mixtral)
    tie_embeddings: bool = False     # gemma
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"
    # execution
    dtype: Any = jnp.bfloat16
    remat: bool = True
    sequence_parallel: bool = True
    scan_layers: bool = True
    attn_chunk: int | None = 1024   # flash-style KV chunking (None = dense)
    microbatches: int = 1           # grad-accum splits for the train step
    moe_quant_gather: bool = False  # int8 FSDP gathers (§Perf hillclimb)

    @property
    def attn(self) -> attention.AttnConfig:
        return attention.AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv=self.n_kv,
            head_dim=self.head_dim,
            rope_theta=self.rope_theta,
            qkv_bias=self.qkv_bias,
            window=self.window,
            chunk=self.attn_chunk,
        )

    @property
    def moe_cfg(self) -> moe.MoEConfig:
        return moe.MoEConfig(
            d_model=self.d_model,
            d_ff=self.d_ff,
            n_experts=self.n_experts,
            top_k=self.top_k,
            quantized_gather=self.moe_quant_gather,
        )

    def param_count(self) -> int:
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        attn = d * (self.n_heads + 2 * self.n_kv) * self.head_dim + (
            self.n_heads * self.head_dim
        ) * d
        if self.moe:
            ffn = 3 * d * f * self.n_experts + d * self.n_experts
        else:
            ffn = 3 * d * f
        emb = V * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + 2 * d) + emb + d

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k experts)."""
        if not self.moe:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        dense = self.param_count() - L * 3 * d * f * self.n_experts
        return dense + L * 3 * d * f * self.top_k


# ------------------------------------------------------------------ params


def _norm_init(cfg, dtype):
    return (
        layers.rmsnorm_init(cfg.d_model, dtype)
        if cfg.norm == "rmsnorm"
        else layers.layernorm_init(cfg.d_model, dtype)
    )


def _norm(cfg, p, x):
    return layers.rmsnorm(p, x) if cfg.norm == "rmsnorm" else layers.layernorm(p, x)


def init_layer(key, cfg: LMConfig):
    ka, kf = jax.random.split(key)
    p = {
        "ln1": _norm_init(cfg, cfg.dtype),
        "attn": attention.init(ka, cfg.attn, cfg.dtype),
        "ln2": _norm_init(cfg, cfg.dtype),
    }
    if cfg.moe:
        p["moe"] = moe.init(kf, cfg.moe_cfg, cfg.dtype)
    else:
        p["ffn"] = layers.glu_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def init(key, cfg: LMConfig):
    ke, kl, ko = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)
    if cfg.scan_layers:
        blocks = jax.vmap(lambda k: init_layer(k, cfg))(lkeys)
    else:
        blocks = [init_layer(k, cfg) for k in lkeys]
    p = {
        "embed": layers.embedding_init(ke, cfg.vocab, cfg.d_model, cfg.dtype),
        "blocks": blocks,
        "ln_f": _norm_init(cfg, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(ko, cfg.d_model, cfg.vocab, cfg.dtype)
    return p


# --------------------------------------------------------------- sharding


def param_specs(cfg: LMConfig, serve: bool = False):
    """PartitionSpec tree matching ``init``.  Layer leaves have a leading L
    axis when scanned (unsharded).

    Training (default): Megatron TP on 'model' + FSDP over 'data' on the
    other dim (spreads optimizer state, ZeRO-style) — weights are gathered
    over 'data' on use.

    ``serve=True`` (dense archs): column/row-parallel over the FLATTENED
    ('data','model') axis — weights stay fully resident (1/256 each, no
    optimizer state at serving time) and no per-token FSDP gather happens;
    the only collectives are tiny activation psums (§Perf hillclimb 2).
    """
    lead = (None,) if cfg.scan_layers else ()

    def lp(*spec):  # layer param: prepend the (unsharded) scan axis
        return P(*(lead + spec))

    if serve and not cfg.moe:
        flat = ("data", "model")
        attn_specs = {
            "q": {"w": lp(None, flat)},
            "k": {"w": lp(None, flat)},
            "v": {"w": lp(None, flat)},
            "o": {"w": lp(flat, None)},
        }
        if cfg.qkv_bias:
            for n in ("q", "k", "v"):
                attn_specs[n]["b"] = lp(flat)
        norm_spec = (
            {"scale": lp(None)}
            if cfg.norm == "rmsnorm"
            else {"scale": lp(None), "bias": lp(None)}
        )
        block = {
            "ln1": norm_spec,
            "attn": attn_specs,
            "ln2": norm_spec,
            "ffn": {
                "up": {"w": lp(None, flat)},
                "gate": {"w": lp(None, flat)},
                "down": {"w": lp(flat, None)},
            },
        }
        specs = {
            "embed": {"table": P("model", "data")},
            "blocks": block if cfg.scan_layers else [block] * cfg.n_layers,
            "ln_f": {"scale": P(None)}
            if cfg.norm == "rmsnorm"
            else {"scale": P(None), "bias": P(None)},
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = {"w": P(None, flat)}
        return specs

    attn_specs = {
        "q": {"w": lp("data", "model")},
        "k": {"w": lp("data", "model")},
        "v": {"w": lp("data", "model")},
        "o": {"w": lp("model", "data")},
    }
    if cfg.qkv_bias:
        for n in ("q", "k", "v"):
            attn_specs[n]["b"] = lp("model")
    norm_spec = (
        {"scale": lp(None)}
        if cfg.norm == "rmsnorm"
        else {"scale": lp(None), "bias": lp(None)}
    )
    block = {"ln1": norm_spec, "attn": attn_specs, "ln2": norm_spec}
    if cfg.moe:
        # TP inside each expert (experts counts 8/16 do not always divide the
        # model axis): up/gate column-parallel on d_ff, down row-parallel,
        # d_model dim FSDP-sharded over data
        block["moe"] = {
            "router": {"w": lp(None, None)},
            "up": lp(None, "data", "model"),
            "gate": lp(None, "data", "model"),
            "down": lp(None, "model", "data"),
        }
    else:
        block["ffn"] = {
            "up": {"w": lp("data", "model")},
            "gate": {"w": lp("data", "model")},
            "down": {"w": lp("model", "data")},
        }
    if not cfg.scan_layers:
        blocks = [block] * cfg.n_layers
    else:
        blocks = block
    specs = {
        "embed": {"table": P("model", "data")},  # vocab_parallel storage layout
        "blocks": blocks,
        "ln_f": {"scale": P(None)} if cfg.norm == "rmsnorm" else {"scale": P(None), "bias": P(None)},
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = {"w": P("data", "model")}
    return specs


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch dim: ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# ----------------------------------------------------------------- forward


def _block_forward(cfg: LMConfig, p, x, positions, dp):
    # Megatron sequence parallelism: the residual stream lives seq-sharded
    # on 'model'; activations are all-gathered before the TP matmuls and the
    # TP outputs return seq-sharded.  The explicit constraints keep GSPMD
    # from resolving the SP<->TP conflict by gathering WEIGHTS (measured:
    # full f32 ffn matrices per device without them).
    if cfg.sequence_parallel:
        x = constrain(x, P(dp, "model", None))
    h = _norm(cfg, p["ln1"], x)
    if cfg.sequence_parallel:
        h = constrain(h, P(dp, None, None))  # gather seq for attention
    h, _ = attention.forward(p["attn"], cfg.attn, h, positions)
    if cfg.sequence_parallel:
        h = constrain(h, P(dp, "model", None))  # reduce-scatter back
    x = x + h
    h = _norm(cfg, p["ln2"], x)
    if cfg.sequence_parallel:
        h = constrain(h, P(dp, None, None))
    if cfg.moe:
        h, aux = moe.forward(p["moe"], cfg.moe_cfg, h)
    else:
        h = layers.glu(
            p["ffn"],
            h,
            act=jax.nn.silu if cfg.gated_act == "silu" else jax.nn.gelu,
        )
        aux = jnp.float32(0.0)
    if cfg.sequence_parallel:
        h = constrain(h, P(dp, "model", None))
    return x + h, aux


def forward(cfg: LMConfig, params, tokens, dp=("data",)):
    """tokens (B, S) -> final hidden states (B, S, d)."""
    b, s = tokens.shape
    x = vocab_parallel.embed(params["embed"]["table"], tokens)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    block = lambda p, x: _block_forward(cfg, p, x, positions, dp)
    if cfg.remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable
        )
    if cfg.scan_layers:
        def scan_body(x, lp):
            y, aux = block(lp, x)
            return y, aux
        x, _ = jax.lax.scan(scan_body, x, params["blocks"])
    else:
        for lp in params["blocks"]:
            x, _ = block(lp, x)
    return _norm(cfg, params["ln_f"], x)


def logits_fn(cfg: LMConfig, params, h):
    if cfg.tie_embeddings:
        return vocab_parallel.tied_logits(params["embed"]["table"], h)
    return layers.dense(params["lm_head"], h)


def loss_fn(cfg: LMConfig, params, tokens, labels, dp=("data",)):
    h = forward(cfg, params, tokens, dp)
    logits = logits_fn(cfg, params, h)
    logits = constrain(logits, P(dp, None, "model"))
    return layers.cross_entropy_loss(logits, labels)


# ------------------------------------------------------------------ decode


def make_cache(cfg: LMConfig, batch: int, max_len: int):
    """Stacked per-layer KV cache, leading L axis.  SWA archs cap the length
    at the window (ring buffer) — this makes long_500k decode O(window)."""
    length = min(max_len, cfg.window) if cfg.window is not None else max_len
    kv_shape = (cfg.n_layers, batch, length, cfg.n_kv, cfg.head_dim)
    return attention.KVCache(
        k=jnp.zeros(kv_shape, cfg.dtype),
        v=jnp.zeros(kv_shape, cfg.dtype),
        pos=jnp.full((cfg.n_layers, batch, length), -1, jnp.int32),
    )


def cache_specs(cfg: LMConfig, dp=("data",)):
    """Sharding of the decode cache: batch over dp, *length* over model.

    KV-head counts (1–8) do not divide the 16-way model axis, and sharding
    the time axis is the split-KV decode layout anyway: each model shard
    attends over its slice of history and XLA turns the softmax reduction
    into the flash-decoding-style partial-max/partial-sum combine."""
    return attention.KVCache(
        k=P(None, dp, "model", None, None),
        v=P(None, dp, "model", None, None),
        pos=P(None, dp, "model"),
    )


def decode_step(cfg: LMConfig, params, cache, tokens, position, dp=("data",)):
    """One decode step.  tokens (B, 1); position scalar int32.
    Returns (logits (B, 1, V), cache')."""
    x = vocab_parallel.embed(params["embed"]["table"], tokens)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)

    def body(x, scan_in):
        lp, lcache = scan_in
        h, new_cache = attention.decode_step(
            lp["attn"], cfg.attn, lcache, _norm(cfg, lp["ln1"], x), position
        )
        x = x + h
        # serve-resident TP (dp=None): pin activations replicated so the
        # resident column/row-parallel weights never get gathered
        x = constrain(x, P(dp, None, None))
        if cfg.moe:
            h, _ = moe.forward(lp["moe"], cfg.moe_cfg, _norm(cfg, lp["ln2"], x))
        else:
            h = layers.glu(
                lp["ffn"],
                _norm(cfg, lp["ln2"], x),
                act=jax.nn.silu if cfg.gated_act == "silu" else jax.nn.gelu,
            )
        return x + h, new_cache

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    else:
        new_layers = []
        for i, lp in enumerate(params["blocks"]):
            x, nc = body(x, (lp, jax.tree.map(lambda t: t[i], cache)))
            new_layers.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
    h = _norm(cfg, params["ln_f"], x)
    return logits_fn(cfg, params, h), new_cache


def prefill(cfg: LMConfig, params, tokens, dp=("data",), unroll_chunks=False):
    """Prefill pass: full forward, returns last-position logits (B, 1, V).

    ``microbatches > 1`` processes the request batch in waves (batch-chunked
    prefill): batch elements are independent, so results are exact and peak
    activation/MoE-dispatch memory drops by the factor.  ``unroll_chunks``
    replaces the scan with a Python loop for the dry-run cost variants.
    """
    b = tokens.shape[0]
    mb = cfg.microbatches
    if mb > 1 and b % mb == 0:
        tb = tokens.reshape(mb, b // mb, tokens.shape[1])

        def one(t):
            h = forward(cfg, params, t, dp)
            return logits_fn(cfg, params, h[:, -1:, :])

        if unroll_chunks:
            outs = jnp.stack([one(tb[i]) for i in range(mb)])
        else:
            _, outs = jax.lax.scan(lambda _, t: (None, one(t)), None, tb)
        return outs.reshape(b, 1, -1)
    h = forward(cfg, params, tokens, dp)
    return logits_fn(cfg, params, h[:, -1:, :])
