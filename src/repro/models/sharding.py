"""Mesh-aware sharding helpers.

``constrain`` applies ``with_sharding_constraint`` only when a mesh context
carrying the referenced axes is active, so the same model code runs on a
single CPU device (smoke tests), under ``jax.set_mesh`` (dry-run/production),
and inside ``jax.eval_shape``.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _flatten_axes(spec: P):
    for part in spec:
        if part is None:
            continue
        if isinstance(part, (tuple, list)):
            yield from part
        else:
            yield part


# the version shims live in repro.compat (dependency-neutral); re-exported
# here because model code reaches for them alongside constrain/active_axes
from repro.compat import current_mesh, set_mesh, shard_map  # noqa: F401


def active_axes() -> tuple:
    mesh = current_mesh()
    return tuple(mesh.axis_names) if not mesh.empty else ()


def constrain(x, spec: P):
    axes = set(active_axes())
    if not axes:
        return x
    if not set(_flatten_axes(spec)) <= axes:
        # drop the axes the current mesh does not have
        spec = P(
            *(
                tuple(a for a in part if a in axes) or None
                if isinstance(part, (tuple, list))
                else (part if part in axes else None)
                for part in spec
            )
        )
    return jax.lax.with_sharding_constraint(x, spec)
