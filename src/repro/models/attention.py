"""Attention: GQA / MQA, RoPE, sliding windows, KV-cache decode.

Covers the five assigned LM archs: qwen2.5 (GQA kv=2 + QKV bias), gemma
(MQA kv=1, head_dim 256), command-r-plus (GQA kv=8, no bias), dbrx (GQA
kv=8), mixtral (GQA kv=8 + sliding-window 4096).

``long_500k`` decode relies on the sliding window: the KV cache is a ring
buffer of ``window`` slots, so cache memory is O(window), independent of the
logical position — the sub-quadratic path (DESIGN.md §5).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    window: int | None = None  # sliding-window size; None -> full causal
    # KV-chunked online-softmax attention (flash-style): never materializes
    # the (S, T) score matrix.  None -> dense scores (fine for short seqs).
    chunk: int | None = None


def init(key, cfg: AttnConfig, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": layers.dense_init(kq, cfg.d_model, cfg.n_heads * cfg.head_dim, dtype, cfg.qkv_bias),
        "k": layers.dense_init(kk, cfg.d_model, cfg.n_kv * cfg.head_dim, dtype, cfg.qkv_bias),
        "v": layers.dense_init(kv, cfg.d_model, cfg.n_kv * cfg.head_dim, dtype, cfg.qkv_bias),
        "o": layers.dense_init(ko, cfg.n_heads * cfg.head_dim, cfg.d_model, dtype, False),
    }


def rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (np.arange(0, half) * 2.0 / d))
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    ang = ang[..., None, :]  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _split_heads(x, n, d):
    return x.reshape(*x.shape[:-1], n, d)


def _sdpa(q, k, v, mask, scale):
    """q (B,S,Hq,D), k/v (B,T,Hkv,D) with GQA head grouping."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q = q.reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", q, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return out.reshape(b, s, hq, d)


def _chunked_sdpa(q, k, v, pos_q, pos_k, window, scale, chunk):
    """Flash-style attention: lax.scan over KV chunks with the online-softmax
    (running max / denominator / accumulator) recurrence.  Peak memory is
    O(S * chunk) per head group instead of O(S * T); the backward pass
    recomputes per-chunk via jax.checkpoint (the flash backward).
    """
    b, s, hq, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    q5 = q.reshape(b, s, hkv, g, d)

    pad = (-t) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_k = jnp.pad(pos_k, ((0, 0), (0, pad)), constant_values=-1)
    nc = k.shape[1] // chunk
    ks = k.reshape(b, nc, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nc, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    ps = pos_k.reshape(b, nc, chunk).transpose(1, 0, 2)

    neg = jnp.float32(-1e30)

    @jax.checkpoint
    def body(carry, xs):
        m, l, acc = carry                       # (b,hkv,g,s) f32 x2, +(...,d)
        kc, vc, pc = xs
        logits = (
            jnp.einsum("bshgd,bchd->bhgsc", q5, kc).astype(jnp.float32) * scale
        )                                        # (b,hkv,g,s,chunk)
        valid = (pc[:, None, :] >= 0) & (pc[:, None, :] <= pos_q[:, :, None])
        if window is not None:
            valid &= pc[:, None, :] > pos_q[:, :, None] - window
        valid = valid[:, None, None, :, :]       # (b,1,1,s,chunk)
        lmax = jnp.max(jnp.where(valid, logits, neg), axis=-1)
        new_m = jnp.maximum(m, lmax)
        p = jnp.where(valid, jnp.exp(logits - new_m[..., None]), 0.0)
        alpha = jnp.exp(m - new_m)
        new_l = l * alpha + jnp.sum(p, axis=-1)
        new_acc = acc * alpha[..., None] + jnp.einsum(
            "bhgsc,bchd->bhgsd", p.astype(vc.dtype), vc
        ).astype(jnp.float32)
        return (new_m, new_l, new_acc), None

    m0 = jnp.full((b, hkv, g, s), neg, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, ps))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, d).astype(q.dtype)


def forward(p, cfg: AttnConfig, x, positions):
    """Full (training / prefill) pass.  Returns (out, (k, v)) so callers can
    seed a decode cache from the prefill."""
    b, s, _ = x.shape
    q = _split_heads(layers.dense(p["q"], x), cfg.n_heads, cfg.head_dim)
    k = _split_heads(layers.dense(p["k"], x), cfg.n_kv, cfg.head_dim)
    v = _split_heads(layers.dense(p["v"], x), cfg.n_kv, cfg.head_dim)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    scale = 1.0 / np.sqrt(cfg.head_dim)
    if cfg.chunk is not None and s > cfg.chunk:
        out = _chunked_sdpa(
            q, k, v, positions, positions, cfg.window, scale, cfg.chunk
        )
    else:
        ti = positions[:, :, None]  # queries
        tj = positions[:, None, :]  # keys
        mask = tj <= ti
        if cfg.window is not None:
            mask &= tj > ti - cfg.window
        out = _sdpa(q, k, v, mask, scale)
    out = layers.dense(p["o"], out.reshape(b, s, -1))
    return out, (k, v)


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, T, n_kv, D); T = max_len (full) or window (SWA)
    v: jnp.ndarray
    # positions currently stored in each slot, -1 = empty: (B, T)
    pos: jnp.ndarray

    @classmethod
    def zeros(cls, batch: int, length: int, cfg: AttnConfig, dtype):
        return cls(
            k=jnp.zeros((batch, length, cfg.n_kv, cfg.head_dim), dtype),
            v=jnp.zeros((batch, length, cfg.n_kv, cfg.head_dim), dtype),
            pos=jnp.full((batch, length), -1, jnp.int32),
        )


def decode_step(p, cfg: AttnConfig, cache: KVCache, x, position):
    """One-token decode.  x: (B, 1, d_model); position: scalar int32 (the
    logical index of the new token).  The cache slot is ``position`` for full
    attention and ``position % window`` for sliding-window (ring buffer)."""
    b = x.shape[0]
    q = _split_heads(layers.dense(p["q"], x), cfg.n_heads, cfg.head_dim)
    k = _split_heads(layers.dense(p["k"], x), cfg.n_kv, cfg.head_dim)
    v = _split_heads(layers.dense(p["v"], x), cfg.n_kv, cfg.head_dim)
    posb = jnp.broadcast_to(position[None], (b,)) if position.ndim == 0 else position
    q = rope(q, posb[:, None], cfg.rope_theta)
    k = rope(k, posb[:, None], cfg.rope_theta)

    slot = posb % cache.k.shape[1] if cfg.window is not None else posb
    ck = jax.vmap(lambda c, kk, s: jax.lax.dynamic_update_slice_in_dim(c, kk, s, 0))(
        cache.k, k, slot
    )
    cv = jax.vmap(lambda c, vv, s: jax.lax.dynamic_update_slice_in_dim(c, vv, s, 0))(
        cache.v, v, slot
    )
    cpos = jax.vmap(lambda c, s, pp: c.at[s].set(pp))(cache.pos, slot, posb)

    # attend over every filled slot that is causally visible
    visible = (cpos >= 0) & (cpos <= posb[:, None])
    if cfg.window is not None:
        visible &= cpos > (posb[:, None] - cfg.window)
    mask = visible[:, None, :]  # (B, 1, T)
    out = _sdpa(q, ck, cv, mask, 1.0 / np.sqrt(cfg.head_dim))
    out = layers.dense(p["o"], out.reshape(b, 1, -1))
    return out, KVCache(k=ck, v=cv, pos=cpos)
