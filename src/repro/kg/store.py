"""Immutable dictionary-encoded triple store — the servable KG artifact.

The engine's :class:`~repro.core.executor.KGResult` is write-only: per
predicate, parallel ``(pattern id, value id)`` int32 columns.  A
:class:`TripleStore` re-keys those pairs into a dense *term id* space (one
int32 id per distinct RDF term — subject, predicate, or object alike) and
holds the graph as three int32 columns ``(s, p, o)`` plus three sorted
permutation indexes:

* **SPO** — triples lexsorted by (subject, predicate, object)
* **POS** — by (predicate, object, subject)
* **OSP** — by (object, subject, predicate)

Every one of the 8 triple-pattern bound-position masks is a contiguous row
range of exactly one of these orders, so a pattern match is a pair of
(vectorized, jittable) lexicographic binary searches — see ``repro.kg.query``.
The permutations are built with jax stable argsorts; construction from a
``KGResult`` is array-at-a-time over the existing int32 columns.  Term
*identity* is the rendered RDF term, not the engine encoding: distinct
(pattern, value) pairs that render to the same term (a constant object map
``lit:hello`` vs. a reference column holding ``hello``) are collapsed to one
term id during construction — each distinct term is rendered exactly once for
that, and never again during query (decode happens only at output time).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.encoder import Dictionary
from repro.data.terms import render_term

# index order -> the (primary, secondary, tertiary) triple positions
ORDERS: dict[str, tuple[int, int, int]] = {
    "spo": (0, 1, 2),
    "pos": (1, 2, 0),
    "osp": (2, 0, 1),
}


@jax.jit
def _lexsort3(k0: jnp.ndarray, k1: jnp.ndarray, k2: jnp.ndarray) -> jnp.ndarray:
    """Permutation sorting rows lexicographically by (k0, k1, k2): three
    stable argsorts, least-significant key first."""
    o = jnp.argsort(k2, stable=True)
    o = o[jnp.argsort(k1[o], stable=True)]
    return o[jnp.argsort(k0[o], stable=True)]


class Index(NamedTuple):
    """One sort order: ``perm`` maps sorted rank -> row id; ``cols`` are the
    (primary, secondary, tertiary) term-id columns in sorted order."""

    order: str
    perm: np.ndarray                                    # int32[n]
    cols: tuple[np.ndarray, np.ndarray, np.ndarray]     # int32[n] each


def _pack(pat: np.ndarray, val: np.ndarray) -> np.ndarray:
    """(pattern id, value id) int32 pairs -> one int64 key (ids are >= 0)."""
    return (pat.astype(np.int64) << 32) | val.astype(np.int64)


def encode_rendered_term(dictionary: Dictionary, term: str) -> tuple[int, int]:
    """Rendered N-Triples term -> ``(pattern id, value id)`` under the same
    scheme :meth:`TripleStore.from_ntriples` uses — shared with the live
    overlay's dictionary append so overlay terms decode/compare exactly
    like base terms."""
    from repro.data.terms import unescape_literal

    if term.startswith("<"):
        kind, body = "iri", term[1:-1]
    else:
        kind, body = "lit", unescape_literal(term[1:-1])
    if "{}" in body:
        # a literal '{}' would read as a template slot: route the
        # body through the value side of the (pattern, value) pair
        if "\x1f" in body:
            raise ValueError(
                f"term body mixes '{{}}' and the multi-column "
                f"separator; not representable: {term!r}"
            )
        return (
            dictionary.encode_scalar(f"{kind}:{{}}"),
            dictionary.encode_scalar(body),
        )
    # slotless pattern: render_term never reads the value id —
    # point it at the pattern string to stay in range
    pid = dictionary.encode_scalar(f"{kind}:{body}")
    return pid, pid


@dataclasses.dataclass
class TripleStore:
    dictionary: Dictionary
    term_pat: np.ndarray   # int32[T]  term id -> pattern id
    term_val: np.ndarray   # int32[T]  term id -> value id
    s: np.ndarray          # int32[n]  term ids
    p: np.ndarray
    o: np.ndarray
    indexes: dict[str, Index]

    # lazy caches (device copies of index columns; rendered-term lookup)
    _dev: dict = dataclasses.field(default_factory=dict, repr=False)
    _term_ids: dict[str, int] | None = dataclasses.field(default=None, repr=False)

    # -- construction --------------------------------------------------------

    @classmethod
    def from_kg(
        cls, dictionary: Dictionary, triples: dict[str, dict[str, np.ndarray]]
    ) -> "TripleStore":
        """Build from engine output (``KGResult.dictionary`` /
        ``KGResult.triples``); each distinct term is rendered once to
        canonicalize term identity by rendered string."""
        spat, sval, ppairs, opat, oval = [], [], [], [], []
        for pred, t in triples.items():
            n = len(t["subj_val"])
            spat.append(np.asarray(t["subj_pat"], np.int32))
            sval.append(np.asarray(t["subj_val"], np.int32))
            opat.append(np.asarray(t["obj_pat"], np.int32))
            oval.append(np.asarray(t["obj_val"], np.int32))
            # a predicate is a constant-iri term: pattern "iri:<pred>", value 0
            pid = dictionary.encode_scalar(f"iri:{pred}")
            ppairs.append(np.full(n, np.int64(pid) << 32, np.int64))

        def cat(chunks, dtype=np.int32):
            return (
                np.concatenate(chunks).astype(dtype)
                if chunks else np.zeros(0, dtype)
            )

        skey = _pack(cat(spat), cat(sval))
        pkey = cat(ppairs, np.int64)
        okey = _pack(cat(opat), cat(oval))
        n = len(skey)
        uniq, inv = np.unique(
            np.concatenate([skey, pkey, okey]), return_inverse=True
        )
        term_pat = (uniq >> 32).astype(np.int32)
        term_val = (uniq & 0x7FFFFFFF).astype(np.int32)
        # Term identity is the *rendered* term: distinct encodings can render
        # to the same RDF term (constant 'lit:hello' vs. reference 'lit:{}'
        # over the value 'hello'), and leaving them as separate ids makes
        # constant-bound queries match only one encoding and breaks variable
        # unification across encodings in BGP joins.  Collapse colliding
        # encodings to one canonical id (ids come out sorted by rendered
        # string) and drop the duplicate triples the merge exposes.
        rendered = np.array(
            [
                render_term(dictionary, int(p), int(v))
                for p, v in zip(term_pat, term_val)
            ]
        )
        uniq_rendered, first, remap = np.unique(
            rendered, return_index=True, return_inverse=True
        )
        term_pat = term_pat[first]
        term_val = term_val[first]
        inv = remap[inv].astype(np.int32)
        trip = np.unique(
            np.stack([inv[:n], inv[n : 2 * n], inv[2 * n :]], axis=1), axis=0
        )
        store = cls.build(
            dictionary, term_pat, term_val,
            trip[:, 0], trip[:, 1], trip[:, 2],
        )
        # term id i IS the rank of its rendered string in uniq_rendered, so
        # the reverse map term_id() would otherwise re-render lazily is
        # already in hand — seed it
        store._term_ids = {str(r): i for i, r in enumerate(uniq_rendered)}
        return store

    @classmethod
    def from_ntriples(
        cls, triples: "list[tuple[str, str, str]]"
    ) -> "TripleStore":
        """Build a store from rendered N-Triples terms (``<iri>`` /
        ``'"literal"'`` strings) — the test/tooling path for small ad-hoc
        graphs.  Term ids come out as ranks of the canonical rendered term,
        exactly like :meth:`from_kg`, so two stores of the same graph use
        identical ids regardless of how they were built."""
        from repro.data.terms import canonical_term

        canon = sorted(
            {
                tuple(canonical_term(t) for t in trip)
                for trip in triples
            }
        )
        terms = sorted({t for trip in canon for t in trip})
        dictionary = Dictionary()
        term_pat = np.zeros(len(terms), np.int32)
        term_val = np.zeros(len(terms), np.int32)
        for i, term in enumerate(terms):
            term_pat[i], term_val[i] = encode_rendered_term(dictionary, term)
        tid = {t: i for i, t in enumerate(terms)}
        cols = np.asarray(
            [[tid[s], tid[p], tid[o]] for s, p, o in canon], np.int32
        ).reshape(-1, 3)
        store = cls.build(
            dictionary, term_pat, term_val, cols[:, 0], cols[:, 1], cols[:, 2]
        )
        store._term_ids = dict(tid)
        return store

    @classmethod
    def build(
        cls, dictionary, term_pat, term_val, s, p, o,
        perms: dict[str, np.ndarray] | None = None,
    ) -> "TripleStore":
        """Assemble the store; sort the three permutations with jax unless
        ``perms`` provides them (the ``.kgz`` load path — gather only)."""
        cols = (s, p, o)
        indexes: dict[str, Index] = {}
        for order, (a, b, c) in ORDERS.items():
            if perms is not None:
                perm = perms[order]
            else:
                perm = np.asarray(
                    _lexsort3(
                        jnp.asarray(cols[a]), jnp.asarray(cols[b]),
                        jnp.asarray(cols[c]),
                    ),
                    dtype=np.int32,
                )
            indexes[order] = Index(
                order=order,
                perm=perm,
                cols=(cols[a][perm], cols[b][perm], cols[c][perm]),
            )
        return cls(
            dictionary=dictionary,
            term_pat=np.asarray(term_pat, np.int32),
            term_val=np.asarray(term_val, np.int32),
            s=np.asarray(s, np.int32), p=np.asarray(p, np.int32),
            o=np.asarray(o, np.int32),
            indexes=indexes,
        )

    # -- basics --------------------------------------------------------------

    @property
    def n_triples(self) -> int:
        return len(self.s)

    @property
    def n_terms(self) -> int:
        return len(self.term_pat)

    def device_cols(self, order: str) -> tuple:
        """Index columns as device arrays (cached) for the jitted scans."""
        if order not in self._dev:
            self._dev[order] = tuple(
                jnp.asarray(c) for c in self.indexes[order].cols
            )
        return self._dev[order]

    # term ids must fit KEY_BITS for the packed range-search keys; beyond
    # that the executor falls back to the 3-column lexicographic scan
    KEY_BITS = 21

    def device_keys(self, order: str):
        """The index's (primary, secondary, tertiary) columns packed into
        one *sorted* 63-bit key per row, split into two int32 device
        columns ``(hi, lo)`` — jax runs without x64, so the key ships as a
        pair; the low word carries the unsigned->signed bias (XOR of the
        sign bit) to keep int32 comparisons order-preserving.  Fields are
        shifted +1 so the ``-1`` wildcard packs below every real id.  A
        lexicographic range scan becomes a 2-column binary search (one
        round per bit of the row count, 2 gathers per round, vs 32x3 for
        the general scan).  ``None`` when term ids overflow the fields."""
        if self.n_terms >= (1 << self.KEY_BITS) - 2:
            return None
        cache_key = f"keys_{order}"
        if cache_key not in self._dev:
            c0, c1, c2 = self.indexes[order].cols
            b = self.KEY_BITS
            packed = (
                ((c0.astype(np.int64) + 1) << (2 * b))
                | ((c1.astype(np.int64) + 1) << b)
                | (c2.astype(np.int64) + 1)
            )
            khi = (packed >> 32).astype(np.int32)
            klo = (
                (packed & 0xFFFFFFFF).astype(np.uint32)
                ^ np.uint32(0x80000000)
            ).view(np.int32)
            self._dev[cache_key] = (jnp.asarray(khi), jnp.asarray(klo))
        return self._dev[cache_key]

    def device_primary_starts(self, order: str):
        """``starts[t] .. starts[t+1]`` is the row range whose *primary*
        column equals term ``t`` — seeds a range search so it bisects only
        that term's rows (e.g. one subject's few triples) instead of the
        whole index."""
        cache_key = f"prim_{order}"
        if cache_key not in self._dev:
            c0 = self.indexes[order].cols[0]
            starts = np.searchsorted(
                c0, np.arange(self.n_terms + 1)
            ).astype(np.int32)
            self._dev[cache_key] = jnp.asarray(starts)
        return self._dev[cache_key]

    def primary_rounds(self, order: str) -> int:
        """Bisection rounds that cover the widest primary-term row range of
        this index (static per store: it sizes the jitted search loop)."""
        cache_key = f"prim_rounds_{order}"
        cached = self._dev.get(cache_key)
        if cached is None:
            starts = np.asarray(self.device_primary_starts(order))
            widest = int(np.max(np.diff(starts))) if self.n_terms else 1
            cached = max(1, widest.bit_length())
            self._dev[cache_key] = cached
        return cached

    def spo_row(self, s: int, p: int, o: int) -> int | None:
        """Row id holding the id-triple ``(s, p, o)``, ``None`` when the
        store does not contain it — a host-side bisect over the sorted SPO
        index (the live overlay's duplicate/tombstone resolution path)."""
        idx = self.indexes["spo"]
        c0, c1, c2 = idx.cols
        lo = int(np.searchsorted(c0, s, side="left"))
        hi = int(np.searchsorted(c0, s, side="right"))
        lo2 = lo + int(np.searchsorted(c1[lo:hi], p, side="left"))
        hi2 = lo + int(np.searchsorted(c1[lo:hi], p, side="right"))
        j = lo2 + int(np.searchsorted(c2[lo2:hi2], o, side="left"))
        if j < hi2 and int(c2[j]) == o:
            return int(idx.perm[j])
        return None

    # -- term decode / encode ------------------------------------------------

    def decode_term(self, term_id: int) -> str:
        return render_term(
            self.dictionary, int(self.term_pat[term_id]), int(self.term_val[term_id])
        )

    def term_id(self, rendered: str) -> int | None:
        """Rendered N-Triples term string -> term id (None if absent).  The
        reverse map is rendered once, lazily, on first constant lookup."""
        if self._term_ids is None:
            self._term_ids = {
                self.decode_term(i): i for i in range(self.n_terms)
            }
        return self._term_ids.get(rendered)

    def iter_ntriples(self):
        """Render in SPO index order (deterministic, sorted by term id)."""
        perm = self.indexes["spo"].perm
        for row in perm:
            yield (
                f"{self.decode_term(self.s[row])} "
                f"{self.decode_term(self.p[row])} "
                f"{self.decode_term(self.o[row])} ."
            )
