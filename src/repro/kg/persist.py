"""``.kgz`` snapshots — build the store once, serve it many times.

A snapshot is a plain (uncompressed) NumPy ``.npz`` archive; every member is
a flat array, so the format is mmap-friendly and versioned:

==============  =========  ==================================================
member          dtype      contents
==============  =========  ==================================================
``meta``        int64[2]   (format version, n_triples)
``dict_blob``   uint8      all dictionary strings, utf-8, concatenated
``dict_off``    int64      end offset of each string into ``dict_blob``
``term_pat``    int32[T]   term id -> pattern id
``term_val``    int32[T]   term id -> value id
``s  p  o``     int32[n]   triple columns, term ids
``perm_spo``    int32[n]   sorted permutations (likewise ``perm_pos``,
                           ``perm_osp``) — load gathers, never re-sorts
==============  =========  ==================================================
"""

from __future__ import annotations

import numpy as np

from repro.data.encoder import Dictionary
from repro.kg.store import ORDERS, TripleStore

FORMAT_VERSION = 1


def save(store: TripleStore, path: str) -> None:
    strings = store.dictionary.strings()
    encoded = [s.encode("utf-8") for s in strings]
    blob = np.frombuffer(b"".join(encoded), dtype=np.uint8)
    off = np.cumsum([len(e) for e in encoded], dtype=np.int64)
    members = {
        "meta": np.asarray([FORMAT_VERSION, store.n_triples], np.int64),
        "dict_blob": blob,
        "dict_off": off,
        "term_pat": store.term_pat,
        "term_val": store.term_val,
        "s": store.s,
        "p": store.p,
        "o": store.o,
    }
    for order in ORDERS:
        members[f"perm_{order}"] = store.indexes[order].perm
    with open(path, "wb") as f:
        np.savez(f, **members)


def load(path: str) -> TripleStore:
    with np.load(path) as z:
        version, _n = (int(x) for x in z["meta"])
        if version != FORMAT_VERSION:
            raise ValueError(
                f"{path}: kgz format v{version}, this build reads v{FORMAT_VERSION}"
            )
        blob = z["dict_blob"].tobytes()
        off = z["dict_off"]
        start = 0
        strings = []
        for end in off:
            strings.append(blob[start:end].decode("utf-8"))
            start = int(end)
        return TripleStore.build(
            Dictionary.from_strings(strings),
            z["term_pat"], z["term_val"],
            z["s"], z["p"], z["o"],
            perms={order: z[f"perm_{order}"] for order in ORDERS},
        )
