"""``.kgz`` snapshots — build the store once, serve it many times.

A snapshot is a plain (uncompressed) NumPy ``.npz`` archive; every member is
a flat array, so the format is mmap-friendly and versioned.  v3 adds
**lineage**: every snapshot carries a content-derived snapshot id, a parent
id, a monotonically increasing *generation* counter, and a *kind* bit that
distinguishes a full store from a **delta** snapshot (the net overlay of a
:class:`repro.live.delta.LiveStore` — new terms plus inserted and
tombstoned id-triples — resolved against its parent by :func:`load_chain`).

Full snapshot (kind 0):

==============  =========  ==================================================
member          dtype      contents
==============  =========  ==================================================
``meta``        int64[4]   (format version, n_triples, generation, kind=0)
``lineage``     int64[2]   (snapshot id, parent snapshot id; 0 = none)
``dict_blob``   uint8      all dictionary strings, utf-8, concatenated
``dict_off``    int64      end offset of each string into ``dict_blob``
``term_pat``    int32[T]   term id -> pattern id
``term_val``    int32[T]   term id -> value id
``s  p  o``     int32[n]   triple columns, term ids
``perm_spo``    int32[n]   sorted permutations (likewise ``perm_pos``,
                           ``perm_osp``) — load gathers, never re-sorts
==============  =========  ==================================================

Delta snapshot (kind 1, written by :func:`save_delta`; one-hop chains —
a delta always references a *full* parent):

===============  =========  =================================================
``meta``         int64[4]   (format version, n inserted, generation, kind=1)
``lineage``      int64[2]   (snapshot id, REQUIRED parent snapshot id)
``parent``       uint8      parent path, utf-8 (relative paths resolve
                            against the delta file's directory)
``term_base``    int64[1]   parent n_terms the overlay ids start at
``terms_blob``   uint8      overlay terms (rendered), utf-8, concatenated
``terms_off``    int64      end offsets into ``terms_blob``
``ins_s/p/o``    int32      inserted id-triples (sorted)
``del_s/p/o``    int32      tombstoned base id-triples (sorted)
===============  =========  =================================================

Snapshots are written with a deterministic zip encoder (fixed timestamps,
stored entries, insertion order), so *equal stores produce byte-identical
files* — the property the live compaction guarantee (`compacted ==
from-scratch rebuild`) is asserted against.
"""

from __future__ import annotations

import os
import zipfile
import zlib
from collections import OrderedDict

import numpy as np

from repro.data.encoder import Dictionary
from repro.kg.store import ORDERS, TripleStore

# v2: term ids are canonical by *rendered* term — v1 snapshots may hold the
# same RDF term under multiple encoding-keyed ids (and duplicate rendered
# triples), which yields wrong query answers, so they are rejected.
# v3: meta grew (generation, kind) and a lineage member; v2 files still
# load (generation 0, no lineage).
FORMAT_VERSION = 3
_MIN_VERSION = 2

KIND_FULL = 0
KIND_DELTA = 1


def _write_npz(path: str, members: "dict[str, np.ndarray]") -> None:
    """``np.savez`` look-alike with *deterministic* bytes: fixed zip
    timestamps, no compression, member order = dict insertion order.
    ``np.load`` reads the result unchanged."""
    with zipfile.ZipFile(path, "w", zipfile.ZIP_STORED, allowZip64=True) as zf:
        for name, arr in members.items():
            info = zipfile.ZipInfo(name + ".npy", date_time=(1980, 1, 1, 0, 0, 0))
            with zf.open(info, "w", force_zip64=True) as f:
                np.lib.format.write_array(
                    f, np.asarray(arr), allow_pickle=False
                )


def _crc_chain(h: int, arrays) -> int:
    for a in arrays:
        h = zlib.crc32(np.ascontiguousarray(a).tobytes(), h)
    return h


def content_id(store: TripleStore, generation: int) -> int:
    """Content-derived snapshot id: a crc32 chain over the id columns and
    term tables, tagged with the generation in the low 20 bits.  Collisions
    only weaken the lineage *check* (load_chain cross-validates n_terms
    too); they cannot corrupt data."""
    h = _crc_chain(
        0, (store.s, store.p, store.o, store.term_pat, store.term_val)
    )
    return (h << 20) | (generation & 0xFFFFF)


def save(
    store: TripleStore, path: str, *, generation: int = 0, parent_id: int = 0
) -> int:
    """Write a full snapshot; returns (and attaches to the store) its
    snapshot id.  ``generation`` counts mutations/compactions along the
    store's lineage; ``parent_id`` links a compacted store to the snapshot
    it grew out of."""
    sid = content_id(store, generation)
    strings = store.dictionary.strings()
    encoded = [s.encode("utf-8") for s in strings]
    blob = np.frombuffer(b"".join(encoded), dtype=np.uint8)
    off = np.cumsum([len(e) for e in encoded], dtype=np.int64)
    members = {
        "meta": np.asarray(
            [FORMAT_VERSION, store.n_triples, generation, KIND_FULL], np.int64
        ),
        "lineage": np.asarray([sid, parent_id], np.int64),
        "dict_blob": blob,
        "dict_off": off,
        "term_pat": store.term_pat,
        "term_val": store.term_val,
        "s": store.s,
        "p": store.p,
        "o": store.o,
    }
    for order in ORDERS:
        members[f"perm_{order}"] = store.indexes[order].perm
    _write_npz(path, members)
    store._kgz_generation = generation
    store._snapshot_id = sid
    return sid


def _pack_strings(strings) -> "tuple[np.ndarray, np.ndarray]":
    encoded = [s.encode("utf-8") for s in strings]
    blob = np.frombuffer(b"".join(encoded), dtype=np.uint8)
    off = np.cumsum([len(e) for e in encoded], dtype=np.int64)
    return blob, off


def _unpack_strings(blob: np.ndarray, off: np.ndarray) -> "list[str]":
    raw = blob.tobytes()
    out, start = [], 0
    for end in off:
        out.append(raw[start:end].decode("utf-8"))
        start = int(end)
    return out


def save_delta(live, path: str, parent_path: str) -> int:
    """Write a ``LiveStore``'s *net* overlay as a delta snapshot chained to
    the parent full snapshot at ``parent_path`` (which must already have
    been saved/loaded so its snapshot id is known).  Chains are one hop:
    a delta always references a full snapshot, and the overlay it records
    is the live store's entire current overlay."""
    base = live.base
    parent_sid = getattr(base, "_snapshot_id", None)
    if parent_sid is None:
        raise ValueError(
            "save_delta: parent store has no snapshot id — "
            "save/load the parent .kgz first"
        )
    ins = sorted(live._inserted)
    dels = sorted(live._tomb)
    ins_cols = np.asarray(ins, np.int32).reshape(-1, 3)
    del_cols = np.asarray(dels, np.int32).reshape(-1, 3)
    terms_blob, terms_off = _pack_strings(live._new_terms)
    sid = (
        _crc_chain(0, (ins_cols, del_cols, terms_blob)) << 20
    ) | (live.generation & 0xFFFFF)
    members = {
        "meta": np.asarray(
            [FORMAT_VERSION, len(ins), live.generation, KIND_DELTA], np.int64
        ),
        "lineage": np.asarray([sid, parent_sid], np.int64),
        "parent": np.frombuffer(parent_path.encode("utf-8"), dtype=np.uint8),
        "term_base": np.asarray([base.n_terms], np.int64),
        "terms_blob": terms_blob,
        "terms_off": terms_off,
        "ins_s": ins_cols[:, 0].copy(),
        "ins_p": ins_cols[:, 1].copy(),
        "ins_o": ins_cols[:, 2].copy(),
        "del_s": del_cols[:, 0].copy(),
        "del_p": del_cols[:, 1].copy(),
        "del_o": del_cols[:, 2].copy(),
    }
    _write_npz(path, members)
    return sid


def peek_meta(path: str) -> "tuple[int, int, int, int]":
    """``(format version, n, generation, kind)`` without loading the store
    (v2 files report generation 0, kind full)."""
    with np.load(path) as z:
        meta = z["meta"]
    version = int(meta[0])
    n = int(meta[1])
    generation = int(meta[2]) if len(meta) > 2 else 0
    kind = int(meta[3]) if len(meta) > 3 else KIND_FULL
    return version, n, generation, kind


def load_chain(path: str):
    """Open a snapshot as a :class:`repro.live.delta.LiveStore`: a full
    snapshot becomes a live store with an empty overlay; a delta snapshot
    resolves its parent (path stored in the file, relative to the delta
    file's directory), verifies the lineage (parent snapshot id and term
    count must match what the delta recorded), and replays the overlay."""
    from repro.live.delta import LiveStore

    version, _, generation, kind = peek_meta(path)
    if not (_MIN_VERSION <= version <= FORMAT_VERSION):
        raise ValueError(
            f"{path}: kgz format v{version}, this build reads "
            f"v{_MIN_VERSION}..v{FORMAT_VERSION}"
        )
    if kind == KIND_FULL:
        return LiveStore(open_store(path))
    with np.load(path) as z:
        parent_rel = z["parent"].tobytes().decode("utf-8")
        parent_sid = int(z["lineage"][1])
        term_base = int(z["term_base"][0])
        new_terms = _unpack_strings(z["terms_blob"], z["terms_off"])
        ins = np.stack([z["ins_s"], z["ins_p"], z["ins_o"]], axis=1)
        dels = np.stack([z["del_s"], z["del_p"], z["del_o"]], axis=1)
    parent_path = parent_rel
    if not os.path.isabs(parent_path):
        parent_path = os.path.join(os.path.dirname(path) or ".", parent_path)
    base = open_store(parent_path)
    if getattr(base, "_snapshot_id", None) != parent_sid:
        raise ValueError(
            f"{path}: parent snapshot id mismatch — {parent_path} is not "
            "the snapshot this delta was chained to"
        )
    if base.n_terms != term_base:
        raise ValueError(
            f"{path}: parent has {base.n_terms} terms, delta expects "
            f"{term_base} — lineage mismatch"
        )
    live = LiveStore(base)
    live._apply_snapshot(new_terms, ins, dels, generation)
    return live


_OPEN_STORES: OrderedDict[tuple, TripleStore] = OrderedDict()
# Sized for a sharded serving group: a coordinator keeps every shard of a
# manifest open at once (plus a generation or two of compaction rewrites),
# so the cap must comfortably exceed the largest expected shard count — a
# cap smaller than N shards would evict-thrash on every scatter.  Long-
# lived coordinators opening many shard *generations* stay bounded: old
# generations fall off the LRU tail instead of leaking.
_OPEN_STORES_MAX = 16


def set_open_store_cache_size(max_stores: int) -> None:
    """Resize the :func:`open_store` LRU (evicting oldest entries now if
    shrinking).  A coordinator serving ``N`` shards should ensure the cap
    is at least ``N`` + headroom; :mod:`repro.shard.coordinator` calls
    this when a manifest names more shards than the current cap."""
    global _OPEN_STORES_MAX
    if max_stores < 1:
        raise ValueError("open_store cache needs room for at least 1 store")
    _OPEN_STORES_MAX = max_stores
    while len(_OPEN_STORES) > _OPEN_STORES_MAX:
        _OPEN_STORES.popitem(last=False)


def open_store_cache_info() -> "tuple[int, int]":
    """``(resident stores, cap)`` — test/diagnostic surface."""
    return len(_OPEN_STORES), _OPEN_STORES_MAX


def open_store(path: str) -> TripleStore:
    """Cached :func:`load`: the validated store (with its device index
    copies, lazy term maps, value tables and compiled query pipelines) is
    keyed by ``(realpath, mtime_ns, size, generation)``, so repeated
    CLI/server phases — and every client of a long-lived process — reuse
    one open store instead of re-reading and re-validating the snapshot.
    A rewritten file changes the key and reloads; the generation component
    catches a same-second same-size rewrite (mtime_ns granularity is
    filesystem-dependent, and compaction rewrites in place), and the LRU
    cap (:func:`set_open_store_cache_size`) bounds resident stores — every
    rewrite generation makes a *new* key, so without eviction a long-lived
    coordinator would accumulate one dead store per compaction."""
    st = os.stat(path)
    try:
        _, _, generation, _ = peek_meta(path)
    except Exception:
        generation = -1  # unreadable meta: let load() raise the real error
    key = (os.path.realpath(path), st.st_mtime_ns, st.st_size, generation)
    store = _OPEN_STORES.get(key)
    if store is None:
        store = load(path)
        _OPEN_STORES[key] = store
        while len(_OPEN_STORES) > _OPEN_STORES_MAX:
            _OPEN_STORES.popitem(last=False)
    else:
        _OPEN_STORES.move_to_end(key)
    return store


def load(path: str) -> TripleStore:
    with np.load(path) as z:
        meta = z["meta"]
        version, n = int(meta[0]), int(meta[1])
        if not (_MIN_VERSION <= version <= FORMAT_VERSION):
            raise ValueError(
                f"{path}: kgz format v{version}, this build reads "
                f"v{_MIN_VERSION}..v{FORMAT_VERSION}"
            )
        generation = int(meta[2]) if len(meta) > 2 else 0
        kind = int(meta[3]) if len(meta) > 3 else KIND_FULL
        if kind != KIND_FULL:
            raise ValueError(
                f"{path}: delta snapshot; open it with load_chain()"
            )
        raw = z["dict_blob"]
        off = z["dict_off"]
        # a corrupted offset table would silently misalign every decoded
        # string while all downstream id-range checks still pass
        if (int(off[-1]) if len(off) else 0) != len(raw) or (
            len(off) and (off[0] < 0 or np.any(np.diff(off) < 0))
        ):
            raise ValueError(
                f"{path}: dictionary offsets corrupted "
                "— truncated or corrupted snapshot"
            )
        strings = _unpack_strings(raw, off)
        s, p, o = z["s"], z["p"], z["o"]
        if not (len(s) == len(p) == len(o) == n):
            raise ValueError(
                f"{path}: triple columns disagree with meta n_triples={n} "
                "— truncated or corrupted snapshot"
            )
        term_pat, term_val = z["term_pat"], z["term_val"]
        if len(term_pat) != len(term_val):
            raise ValueError(
                f"{path}: term_pat/term_val lengths disagree "
                "— truncated or corrupted snapshot"
            )
        # out-of-range ids would decode garbage terms (Python negative
        # indexing wraps silently) rather than fail
        for name, col, hi in (
            ("s", s, len(term_pat)),
            ("p", p, len(term_pat)),
            ("o", o, len(term_pat)),
            ("term_pat", term_pat, len(strings)),
            ("term_val", term_val, len(strings)),
        ):
            if len(col) and (col.min() < 0 or col.max() >= hi):
                raise ValueError(
                    f"{path}: {name} ids out of range [0, {hi}) "
                    "— truncated or corrupted snapshot"
                )
        perms = {}
        for order in ORDERS:
            perm = z[f"perm_{order}"]
            # a bad permutation (wrong length, out-of-range, or repeated row)
            # would gather garbage and answer queries silently wrong; bound
            # the values before bincount so a huge bogus entry raises here
            # instead of allocating a giant count array
            if len(perm) != n or (
                n
                and (
                    perm.min() < 0
                    or perm.max() >= n
                    or not np.array_equal(
                        np.bincount(perm, minlength=n), np.ones(n, np.int64)
                    )
                )
            ):
                raise ValueError(
                    f"{path}: perm_{order} is not a permutation of {n} rows "
                    "— truncated or corrupted snapshot"
                )
            perms[order] = perm
        sid = int(z["lineage"][0]) if version >= 3 else None
        store = TripleStore.build(
            Dictionary.from_strings(strings),
            term_pat, term_val, s, p, o, perms=perms,
        )
    # load gathers instead of re-sorting, so verify each gathered index really
    # is lexicographically non-decreasing (cheap vectorized spot-check)
    for order, idx in store.indexes.items():
        c0, c1, c2 = idx.cols
        sorted_ok = np.all(
            (c0[:-1] < c0[1:])
            | (
                (c0[:-1] == c0[1:])
                & (
                    (c1[:-1] < c1[1:])
                    | ((c1[:-1] == c1[1:]) & (c2[:-1] <= c2[1:]))
                )
            )
        )
        if not bool(sorted_ok):
            raise ValueError(
                f"{path}: index {order} is not sorted — corrupted snapshot"
            )
    store._kgz_generation = generation
    store._snapshot_id = sid if sid is not None else content_id(
        store, generation
    )
    return store


# ---------------------------------------------------------------------------
# shard manifests — one JSON file naming N partitioned .kgz shard stores
# ---------------------------------------------------------------------------

# A sharded KG is N ordinary full .kgz snapshots plus one JSON manifest:
#
#     {"format": "repro.shard/1",
#      "n_shards": 2,
#      "partition": {"by": "subject", "hash": "crc32"},
#      "shards": [{"path": "kg.shard0.kgz", "n_triples": 61,
#                  "n_terms": 40, "snapshot_id": 123, "generation": 0}, ...],
#      "dictionary": {"n_terms_union": 71, "n_terms_shards": 78,
#                     "n_triples": 120}}
#
# ``partition`` pins the assignment rule: triple -> shard by
# crc32(rendered subject term) % n_shards (repro.shard.partition).  Term
# ids are ranks of rendered terms and therefore build-dependent, so the
# *rendered subject* — the stable content the id ranks — is what hashes;
# a coordinator can route a bound-subject query without any shared id
# space.  Each shard keeps its own term dictionary (rows cross the merge
# as rendered terms, whose sort order IS global term-id order);
# ``dictionary`` records the union/per-shard term totals the ingestion
# barrier merged.  Shard paths are stored relative to the manifest.

MANIFEST_FORMAT = "repro.shard/1"


def save_manifest(path: str, manifest: dict) -> None:
    import json

    if manifest.get("format") != MANIFEST_FORMAT:
        raise ValueError(
            f"manifest format must be {MANIFEST_FORMAT!r}, "
            f"got {manifest.get('format')!r}"
        )
    with open(path, "w", encoding="utf-8") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")


def load_manifest(path: str) -> dict:
    """Read and validate a shard manifest; shard entries gain an
    ``abs_path`` resolved against the manifest's directory."""
    import json

    with open(path, encoding="utf-8") as f:
        m = json.load(f)
    if not isinstance(m, dict) or m.get("format") != MANIFEST_FORMAT:
        raise ValueError(f"{path}: not a {MANIFEST_FORMAT} shard manifest")
    shards = m.get("shards")
    if not isinstance(shards, list) or len(shards) != m.get("n_shards"):
        raise ValueError(
            f"{path}: manifest shards disagree with n_shards="
            f"{m.get('n_shards')}"
        )
    part = m.get("partition", {})
    if part.get("by") != "subject" or part.get("hash") != "crc32":
        raise ValueError(
            f"{path}: unsupported partition spec {part!r} — this build "
            "reads subject/crc32 manifests"
        )
    base = os.path.dirname(os.path.abspath(path))
    for entry in shards:
        p = entry["path"]
        entry["abs_path"] = p if os.path.isabs(p) else os.path.join(base, p)
    return m


def is_manifest(path: str) -> bool:
    """Cheap sniff: does ``path`` name a shard manifest (vs a .kgz zip)?
    Reads only the first bytes — a .kgz starts with the zip magic, a
    manifest is a JSON object carrying the format marker."""
    try:
        with open(path, "rb") as f:
            head = f.read(4096)
    except OSError:
        return False
    if not head.lstrip()[:1] == b"{":
        return False
    return MANIFEST_FORMAT.encode() in head
