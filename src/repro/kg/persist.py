"""``.kgz`` snapshots — build the store once, serve it many times.

A snapshot is a plain (uncompressed) NumPy ``.npz`` archive; every member is
a flat array, so the format is mmap-friendly and versioned:

==============  =========  ==================================================
member          dtype      contents
==============  =========  ==================================================
``meta``        int64[2]   (format version, n_triples)
``dict_blob``   uint8      all dictionary strings, utf-8, concatenated
``dict_off``    int64      end offset of each string into ``dict_blob``
``term_pat``    int32[T]   term id -> pattern id
``term_val``    int32[T]   term id -> value id
``s  p  o``     int32[n]   triple columns, term ids
``perm_spo``    int32[n]   sorted permutations (likewise ``perm_pos``,
                           ``perm_osp``) — load gathers, never re-sorts
==============  =========  ==================================================
"""

from __future__ import annotations

import os
from collections import OrderedDict

import numpy as np

from repro.data.encoder import Dictionary
from repro.kg.store import ORDERS, TripleStore

# v2: term ids are canonical by *rendered* term — v1 snapshots may hold the
# same RDF term under multiple encoding-keyed ids (and duplicate rendered
# triples), which yields wrong query answers, so they are rejected
FORMAT_VERSION = 2


def save(store: TripleStore, path: str) -> None:
    strings = store.dictionary.strings()
    encoded = [s.encode("utf-8") for s in strings]
    blob = np.frombuffer(b"".join(encoded), dtype=np.uint8)
    off = np.cumsum([len(e) for e in encoded], dtype=np.int64)
    members = {
        "meta": np.asarray([FORMAT_VERSION, store.n_triples], np.int64),
        "dict_blob": blob,
        "dict_off": off,
        "term_pat": store.term_pat,
        "term_val": store.term_val,
        "s": store.s,
        "p": store.p,
        "o": store.o,
    }
    for order in ORDERS:
        members[f"perm_{order}"] = store.indexes[order].perm
    with open(path, "wb") as f:
        np.savez(f, **members)


_OPEN_STORES: OrderedDict[tuple, TripleStore] = OrderedDict()
_OPEN_STORES_MAX = 4


def open_store(path: str) -> TripleStore:
    """Cached :func:`load`: the validated store (with its device index
    copies, lazy term maps, value tables and compiled query pipelines) is
    keyed by ``(realpath, mtime, size)``, so repeated CLI/server phases —
    and every client of a long-lived process — reuse one open store
    instead of re-reading and re-validating the snapshot.  A rewritten
    file changes the key and reloads; a small LRU bounds resident stores."""
    st = os.stat(path)
    key = (os.path.realpath(path), st.st_mtime_ns, st.st_size)
    store = _OPEN_STORES.get(key)
    if store is None:
        store = load(path)
        _OPEN_STORES[key] = store
        while len(_OPEN_STORES) > _OPEN_STORES_MAX:
            _OPEN_STORES.popitem(last=False)
    else:
        _OPEN_STORES.move_to_end(key)
    return store


def load(path: str) -> TripleStore:
    with np.load(path) as z:
        version, n = (int(x) for x in z["meta"])
        if version != FORMAT_VERSION:
            raise ValueError(
                f"{path}: kgz format v{version}, this build reads v{FORMAT_VERSION}"
            )
        raw = z["dict_blob"]
        off = z["dict_off"]
        # a corrupted offset table would silently misalign every decoded
        # string while all downstream id-range checks still pass
        if (int(off[-1]) if len(off) else 0) != len(raw) or (
            len(off) and (off[0] < 0 or np.any(np.diff(off) < 0))
        ):
            raise ValueError(
                f"{path}: dictionary offsets corrupted "
                "— truncated or corrupted snapshot"
            )
        blob = raw.tobytes()
        start = 0
        strings = []
        for end in off:
            strings.append(blob[start:end].decode("utf-8"))
            start = int(end)
        s, p, o = z["s"], z["p"], z["o"]
        if not (len(s) == len(p) == len(o) == n):
            raise ValueError(
                f"{path}: triple columns disagree with meta n_triples={n} "
                "— truncated or corrupted snapshot"
            )
        term_pat, term_val = z["term_pat"], z["term_val"]
        if len(term_pat) != len(term_val):
            raise ValueError(
                f"{path}: term_pat/term_val lengths disagree "
                "— truncated or corrupted snapshot"
            )
        # out-of-range ids would decode garbage terms (Python negative
        # indexing wraps silently) rather than fail
        for name, col, hi in (
            ("s", s, len(term_pat)),
            ("p", p, len(term_pat)),
            ("o", o, len(term_pat)),
            ("term_pat", term_pat, len(strings)),
            ("term_val", term_val, len(strings)),
        ):
            if len(col) and (col.min() < 0 or col.max() >= hi):
                raise ValueError(
                    f"{path}: {name} ids out of range [0, {hi}) "
                    "— truncated or corrupted snapshot"
                )
        perms = {}
        for order in ORDERS:
            perm = z[f"perm_{order}"]
            # a bad permutation (wrong length, out-of-range, or repeated row)
            # would gather garbage and answer queries silently wrong; bound
            # the values before bincount so a huge bogus entry raises here
            # instead of allocating a giant count array
            if len(perm) != n or (
                n
                and (
                    perm.min() < 0
                    or perm.max() >= n
                    or not np.array_equal(
                        np.bincount(perm, minlength=n), np.ones(n, np.int64)
                    )
                )
            ):
                raise ValueError(
                    f"{path}: perm_{order} is not a permutation of {n} rows "
                    "— truncated or corrupted snapshot"
                )
            perms[order] = perm
        store = TripleStore.build(
            Dictionary.from_strings(strings),
            term_pat, term_val, s, p, o, perms=perms,
        )
    # load gathers instead of re-sorting, so verify each gathered index really
    # is lexicographically non-decreasing (cheap vectorized spot-check)
    for order, idx in store.indexes.items():
        c0, c1, c2 = idx.cols
        sorted_ok = np.all(
            (c0[:-1] < c0[1:])
            | (
                (c0[:-1] == c0[1:])
                & (
                    (c1[:-1] < c1[1:])
                    | ((c1[:-1] == c1[1:]) & (c2[:-1] <= c2[1:]))
                )
            )
        )
        if not bool(sorted_ok):
            raise ValueError(
                f"{path}: index {order} is not sorted — corrupted snapshot"
            )
    return store
