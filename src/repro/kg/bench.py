"""Query-serving throughput measurement (shared by the CLI ``--bench`` mode
and ``benchmarks/run.py``).

The workload is single triple patterns derived from the store's own content
(every query has at least one answer): a mix of the four most common serving
masks — ``(s, p, ?)``, ``(?, p, o)``, ``(s, ?, ?)``, ``(?, ?, o)`` — executed
through the batched many-queries-per-dispatch path, which is the number that
matters for serving, not per-query Python overhead."""

from __future__ import annotations

import time

import numpy as np

from repro.kg.query import match_counts
from repro.kg.store import TripleStore
from repro.obs import Histogram

_MASKS = ((1, 1, 0), (0, 1, 1), (1, 0, 0), (0, 0, 1))


def make_workload(store: TripleStore, n_queries: int, seed: int = 0) -> np.ndarray:
    """int32[n_queries, 3] patterns in (s, p, o) term ids, -1 = wildcard."""
    if store.n_triples == 0:
        raise ValueError("cannot build a query workload over an empty graph")
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, store.n_triples, n_queries)
    spo = np.stack([store.s[rows], store.p[rows], store.o[rows]], axis=1)
    mask = np.asarray(_MASKS, np.int32)[rng.integers(0, len(_MASKS), n_queries)]
    return np.where(mask == 1, spo, np.int32(-1)).astype(np.int32)


def bench_single_pattern(
    store: TripleStore,
    n_queries: int = 50_000,
    batch: int = 4096,
    seed: int = 0,
) -> dict:
    """Time the batched single-pattern path; returns a json-ready report.
    An empty store reports a zero-query section instead of erroring, so
    the ``--bench`` CLI paths need no ad-hoc guards."""
    if store.n_triples == 0:
        return {
            "n_triples": 0,
            "n_terms": int(store.n_terms),
            "n_queries": 0,
            "batch": int(batch),
            "total_matches": 0,
            "wall_s": 0.0,
            "queries_per_s": 0.0,
            "latency_p50_ms": 0.0,
            "latency_p99_ms": 0.0,
            "empty_store": True,
        }
    workload = make_workload(store, n_queries, seed)
    # warm-up: compile every (mask-group, batch-shape) once
    total = 0
    for start in range(0, n_queries, batch):
        total += int(match_counts(store, workload[start : start + batch]).sum())
    lat = Histogram()  # per-dispatch latency -> p50/p99 for the CI gate
    t0 = time.perf_counter()
    for start in range(0, n_queries, batch):
        d0 = time.perf_counter_ns()
        match_counts(store, workload[start : start + batch])
        lat.observe((time.perf_counter_ns() - d0) / 1e6)
    dt = time.perf_counter() - t0
    return {
        "n_triples": int(store.n_triples),
        "n_terms": int(store.n_terms),
        "n_queries": int(n_queries),
        "batch": int(batch),
        "total_matches": total,
        "wall_s": dt,
        "queries_per_s": n_queries / dt,
        "latency_p50_ms": lat.percentile(50),
        "latency_p99_ms": lat.percentile(99),
        "latency_max_ms": lat.max,
    }
