"""Triple-pattern matching and BGP evaluation over a :class:`TripleStore`.

Single pattern
--------------
A pattern binds a subset of (s, p, o); each of the 8 bound-position masks is
a contiguous range of exactly one sort order (SPO / POS / OSP), found with a
*lexicographic binary search* over the three sorted int32 columns — jitted,
vectorized over a whole batch of queries, so the serving path answers many
patterns per dispatch (`match_counts`).  Wildcard positions take ``-1`` for
the lower bound and ``INT32_MAX`` for the upper (term ids are dense and
strictly between the two).

BGP (conjunctive) queries
-------------------------
`solve` delegates to the ``repro.serve`` planner/executor — the one query
path: the BGP becomes a :class:`~repro.serve.algebra.SelectQuery`, the
cost-based planner orders scans by index-measured cardinality preferring
connected joins, and the jitted executor runs the whole plan (range scans
feeding sorted-merge joins on padded binding tables) as one fused device
dispatch; bindings never materialize on host between joins.  Rows come
back deterministically ordered by term id — and term ids are ranks of
rendered terms, so the order is identical across eager / streamed /
``.kgz``-roundtripped stores.  Term ids decode to strings only at output
(`decode_bindings`).

Correctness is anchored by `oracle_solve`, a naive Python set-scan over the
same store, used by the tests as the reference semantics (the full-algebra
extension lives in ``repro.serve.oracle``).
"""

from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashset import next_pow2
from repro.kg.store import ORDERS, TripleStore
from repro.data.terms import canonical_term

I32_MAX = np.int32(np.iinfo(np.int32).max)

# bound-position mask (s, p, o) -> index order whose sort prefix covers it
_ORDER_FOR_MASK = {
    (False, False, False): "spo",
    (True, False, False): "spo",
    (True, True, False): "spo",
    (True, True, True): "spo",
    (False, True, False): "pos",
    (False, True, True): "pos",
    (False, False, True): "osp",
    (True, False, True): "osp",
}


# --------------------------------------------------------------------------
# pattern parsing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    """One pattern; each slot is a variable name (``"?x"``) or a constant
    rendered-term string (``"<iri>"`` / ``'"literal"'``)."""

    s: str
    p: str
    o: str

    @property
    def slots(self) -> tuple[str, str, str]:
        return (self.s, self.p, self.o)

    @property
    def variables(self) -> tuple[str, ...]:
        return tuple(dict.fromkeys(t for t in self.slots if t.startswith("?")))


_PAT_TOKEN = re.compile(
    r'\s*(?P<var>\?[A-Za-z_]\w*)'
    r'|\s*(?P<iri><[^>]*>)'
    r'|\s*(?P<lit>"(?:[^"\\]|\\.)*")'
    r'|\s*(?P<dot>\.)'
)


def parse_bgp(text: str) -> list[TriplePattern]:
    """Parse ``'?s <p> ?o . ?o <q> "v"'`` into patterns (the ``.`` separator
    between patterns is optional; a trailing ``.`` is allowed)."""
    terms: list[str] = []
    patterns: list[TriplePattern] = []

    def flush():
        if not terms:
            return
        if len(terms) != 3:
            raise ValueError(
                f"triple pattern needs 3 terms, got {len(terms)}: {terms}"
            )
        s, p, o = terms
        patterns.append(
            TriplePattern(
                s if s.startswith("?") else canonical_term(s),
                p if p.startswith("?") else canonical_term(p),
                o if o.startswith("?") else canonical_term(o),
            )
        )
        terms.clear()

    pos = 0
    while pos < len(text):
        m = _PAT_TOKEN.match(text, pos)
        if not m:
            if text[pos:].strip():
                raise ValueError(f"cannot parse pattern at: {text[pos:pos+40]!r}")
            break
        pos = m.end()
        if m.lastgroup == "dot":
            flush()
        else:
            terms.append(m.group().strip())
            if len(terms) == 3:
                flush()
    flush()
    if not patterns:
        raise ValueError("empty basic graph pattern")
    return patterns


# --------------------------------------------------------------------------
# jitted lexicographic range scan
# --------------------------------------------------------------------------


def _lex_search(c0, c1, c2, q0, q1, q2, upper: bool):
    """Vectorized lexicographic binary search: for each query tuple, the
    count of sorted rows lex-< (lower bound) or lex-<= (upper bound) the
    tuple.  32 rounds cover any int32-indexable column."""
    n = c0.shape[0]
    lo = jnp.zeros(q0.shape, jnp.int32)
    hi = jnp.full(q0.shape, n, jnp.int32)

    def body(_, state):
        lo, hi = state
        # overflow-safe midpoint: lo + hi can exceed int32 at n > 2^30 rows
        mid = lo + ((hi - lo) >> 1)
        g = jnp.clip(mid, 0, max(n - 1, 0))
        m0, m1, m2 = c0[g], c1[g], c2[g]
        tail = (m2 <= q2) if upper else (m2 < q2)
        before = (m0 < q0) | ((m0 == q0) & ((m1 < q1) | ((m1 == q1) & tail)))
        open_ = lo < hi
        return (
            jnp.where(open_ & before, mid + 1, lo),
            jnp.where(open_ & ~before, mid, hi),
        )

    lo, _ = jax.lax.fori_loop(0, 32, body, (lo, hi))
    return lo


@jax.jit
def _lex_range(c0, c1, c2, lo0, lo1, lo2, hi0, hi1, hi2):
    """(start, end) row ranges for a batch of bound-prefix queries: a lower
    and an upper lexicographic search, the upper with INT32_MAX filling the
    wildcard slots (term ids are dense, strictly below it)."""
    return (
        _lex_search(c0, c1, c2, lo0, lo1, lo2, upper=False),
        _lex_search(c0, c1, c2, hi0, hi1, hi2, upper=True),
    )


def _query_bounds(ids_primary_order: np.ndarray):
    """int32[m, 3] columns in *index order* with -1 wildcards -> the six
    lower/upper query columns."""
    q = ids_primary_order
    wild = q < 0
    lo = np.where(wild, np.int32(-1), q).astype(np.int32)
    hi = np.where(wild, I32_MAX, q).astype(np.int32)
    return lo, hi


def match_ranges(
    store: TripleStore, patterns_spo: np.ndarray
) -> tuple[np.ndarray, np.ndarray, list[str]]:
    """Batch of patterns as int32[m, 3] term ids in (s, p, o) order with -1
    for wildcards -> per-pattern (start, end) ranges plus the index order
    each range refers to.  Queries are grouped by *index order* — the
    wildcard bound encoding already distinguishes masks within an order, so
    a mixed batch takes at most 3 jitted dispatches (a homogeneous serving
    batch is exactly one)."""
    q = np.asarray(patterns_spo, np.int32).reshape(-1, 3)
    m = len(q)
    starts = np.zeros(m, np.int64)
    ends = np.zeros(m, np.int64)
    bound = q >= 0
    orders = [_ORDER_FOR_MASK[tuple(bool(x) for x in row)] for row in bound]
    if len(store.s) == 0:
        # empty graph: every range is (0, 0) — the jitted search cannot
        # gather from zero-length index columns
        return starts, ends, orders
    orders_arr = np.asarray(orders)
    for order in sorted(set(orders)):
        sel = np.nonzero(orders_arr == order)[0]
        a, b, c = (q[sel][:, i] for i in ORDERS[order])
        qcols = np.stack([a, b, c], axis=1)
        # pad each group to a power-of-two batch so mixed batches compile
        # O(log batch) shapes total, not one per group size; pad rows are
        # all-wildcard queries whose results are sliced away
        k = len(sel)
        npad = next_pow2(max(k, 1))
        if npad > k:
            qcols = np.concatenate(
                [qcols, np.full((npad - k, 3), -1, np.int32)]
            )
        lo, hi = _query_bounds(qcols)
        c0, c1, c2 = store.device_cols(order)
        lo_i, hi_i = _lex_range(
            c0, c1, c2,
            jnp.asarray(lo[:, 0]), jnp.asarray(lo[:, 1]), jnp.asarray(lo[:, 2]),
            jnp.asarray(hi[:, 0]), jnp.asarray(hi[:, 1]), jnp.asarray(hi[:, 2]),
        )
        starts[sel] = np.asarray(lo_i)[:k]
        ends[sel] = np.asarray(hi_i)[:k]
    return starts, ends, orders


def match_counts(store: TripleStore, patterns_spo: np.ndarray) -> np.ndarray:
    """Result cardinality per pattern — the batched serving/bench path."""
    starts, ends, _ = match_ranges(store, patterns_spo)
    return (ends - starts).astype(np.int64)


def match_pattern(store: TripleStore, spo_ids) -> np.ndarray:
    """One pattern (term ids, None = wildcard) -> matching row ids into
    ``store.s/p/o`` (host array, variable length)."""
    q = np.asarray(
        [[-1 if t is None else int(t) for t in spo_ids]], np.int32
    )
    starts, ends, orders = match_ranges(store, q)
    idx = store.indexes[orders[0]]
    return idx.perm[int(starts[0]) : int(ends[0])]


# --------------------------------------------------------------------------
# binding tables + BGP evaluation (delegated to the repro.serve pipeline)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Bindings:
    """Encoded solution table: one int32 term-id column per variable.  A
    zero-variable table (all-constant pattern) is a pure existence filter
    and carries only its row count (0 or 1)."""

    cols: dict[str, np.ndarray]
    n: int

    @property
    def variables(self) -> tuple[str, ...]:
        return tuple(self.cols)


def solve(store: TripleStore, patterns: list[TriplePattern]) -> Bindings:
    """Conjunctive BGP evaluation — a shim over the unified query API:
    :class:`repro.api.LocalSession` resolves the store (plain or live,
    overlay view captured per call) and runs the same fused jitted
    planner/executor pipeline the query server dispatches through.
    Kept for callers that want *encoded* (term-id) binding tables; new
    code should use ``repro.api.connect``.  (Lazy import: ``api`` layers
    on ``kg``, not the other way around.)"""
    from repro.api import LocalSession
    from repro.serve.algebra import SelectQuery

    res = LocalSession(store).execute(SelectQuery(patterns=tuple(patterns)))
    n = int(res.counts[0])
    cols = {
        v: np.asarray(res.cols[v][0, :n], np.int32) for v in res.vars
    }
    return Bindings(cols, n)


def solve_text(store: TripleStore, text: str) -> Bindings:
    return solve(store, parse_bgp(text))


def decode_bindings(
    store: TripleStore, b: Bindings, limit: int | None = None
) -> list[dict[str, str]]:
    """Term-id table -> rendered rows; the only string-producing step."""
    n = b.n if limit is None else min(b.n, limit)
    return [
        {v: store.decode_term(int(c[i])) for v, c in b.cols.items()}
        for i in range(n)
    ]


def binding_set(store: TripleStore, b: Bindings) -> set[tuple]:
    """Canonical comparable form: a set of ((var, rendered term), ...) rows
    sorted by variable name — what the tests compare against the oracle."""
    out = set()
    for i in range(b.n):
        out.add(
            tuple(
                (v, store.decode_term(int(b.cols[v][i])))
                for v in sorted(b.cols)
            )
        )
    return out


# --------------------------------------------------------------------------
# reference oracle — naive Python set scan (the tests' ground truth)
# --------------------------------------------------------------------------


def oracle_solve(store: TripleStore, patterns: list[TriplePattern]) -> set[tuple]:
    """Evaluate the BGP by brute force over the decoded triple list: match
    every pattern against every triple, then natural-join the per-pattern
    binding sets pairwise.  Quadratic and string-based on purpose — it
    shares no code with the indexed engine."""
    triples = [
        (
            store.decode_term(int(store.s[i])),
            store.decode_term(int(store.p[i])),
            store.decode_term(int(store.o[i])),
        )
        for i in range(store.n_triples)
    ]

    def match_one(pat: TriplePattern) -> list[dict[str, str]]:
        out = []
        for t in triples:
            env: dict[str, str] = {}
            for term, value in zip(pat.slots, t):
                if term.startswith("?"):
                    if env.get(term, value) != value:
                        env = None  # type: ignore[assignment]
                        break
                    env[term] = value
                elif term != value:
                    env = None  # type: ignore[assignment]
                    break
            if env is not None:
                out.append(env)
        return out

    solutions = [dict()]  # type: list[dict[str, str]]
    for pat in patterns:
        rows = match_one(pat)
        merged = []
        for env in solutions:
            for row in rows:
                if all(env.get(v, row[v]) == row[v] for v in row):
                    merged.append({**env, **row})
        solutions = merged
    return {
        tuple(sorted(env.items())) for env in solutions
    }
