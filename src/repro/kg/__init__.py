"""``repro.kg`` — queryable, persistable triple store over engine output.

The creation engine (``repro.core.executor``) stops at a write-only
:class:`KGResult`; this subsystem turns that into a *servable* artifact:

* :mod:`repro.kg.store`   — immutable dictionary-encoded int32 ``(s, p, o)``
  columns with SPO/POS/OSP sorted permutation indexes (jax stable sorts).
* :mod:`repro.kg.query`   — jitted lexicographic range scans for single
  triple patterns (batched, many queries per dispatch); conjunctive BGP
  evaluation delegates to the ``repro.serve`` planner + fused jitted
  executor (one query path, shared with the query server).
* :mod:`repro.kg.persist` — versioned ``.kgz`` npz snapshots (build once,
  serve many times) and the ``open_store`` cache for long-lived processes.

Term rendering (full N-Triples escaping) lives in :mod:`repro.data.terms`,
shared with the engine's N-Triples dump and re-exported here.

Entry points: ``KGResult.to_store()`` and ``python -m repro.launch.query``.
"""

from repro.kg.query import (
    Bindings,
    TriplePattern,
    binding_set,
    decode_bindings,
    match_counts,
    match_pattern,
    oracle_solve,
    parse_bgp,
    solve,
    solve_text,
)
from repro.kg.persist import load, open_store, save
from repro.kg.store import TripleStore
from repro.data.terms import escape_literal, render_term, unescape_literal

__all__ = [
    "Bindings",
    "TriplePattern",
    "TripleStore",
    "binding_set",
    "decode_bindings",
    "escape_literal",
    "load",
    "match_counts",
    "open_store",
    "match_pattern",
    "oracle_solve",
    "parse_bgp",
    "render_term",
    "save",
    "solve",
    "solve_text",
    "unescape_literal",
]
