"""Mutable delta overlay over the immutable sorted :class:`TripleStore`.

A :class:`LiveStore` makes a served KG writable without giving up the
fused single-dispatch query path:

* **Insert log** — inserted triples live in a small append set, encoded
  with the same dense term-id scheme as the base: term ids ``< base
  n_terms`` are base ids, new terms take the next ids in an append-only
  overlay (their strings interned into the *shared* base dictionary, which
  is append-only, so base decode is untouched).
* **Tombstones** — deletes of base triples record the base *row id*; the
  row stays in the sorted indexes but every query masks it out.
* **OverlayView** — an immutable snapshot the executor queries: the insert
  log re-sorted into a real (power-of-two padded) delta ``TripleStore``
  over the combined term table, plus per-order *alive prefix sums* over
  the base (``alive[r]`` = live base rows before sorted position ``r``).
  ``repro.serve.exec`` runs a second range-scan arm against the delta
  index in the same jitted dispatch and rank-selects the alive base rows,
  so answers over ``base ⊕ delta`` stay batch-fused and deterministic.
  Views are copy-on-write: mutations build a fresh view, in-flight query
  batches keep the one they captured.
* **Compaction** — :meth:`LiveStore.compact` rebuilds the base from the
  surviving rendered triples via :meth:`TripleStore.from_ntriples`.  That
  full canonical rebuild is what makes the snapshot guarantee hold: a
  compacted store is *byte-identical* (via :func:`repro.kg.persist.save`)
  to a from-scratch build of the same triple set, no matter how the
  pre-compaction base was constructed (eager, streamed, ``.kgz`` chain).

Ordering caveat: overlay term ids are appended after the base ids, so
while live answers are deterministic (the executor's determinism sort
runs on the view's ids), they are only in canonical rendered order once
no overlay term is involved — compaction restores canonical ids.

Layering: ``live`` sits above ``kg`` and below ``serve`` consumers, but
the executor never imports it (the view is duck-typed); ``live`` imports
``serve`` only lazily inside :meth:`LiveStore.solve`.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.hashset import next_pow2
from repro.data.terms import canonical_term
from repro.kg.store import TripleStore, encode_rendered_term

_I32_MAX = int(np.iinfo(np.int32).max)


class OverlayView:
    """One immutable ``base ⊕ delta`` snapshot (see the module docstring).

    Duck-types the store surface the executor, constant encoder, value
    tables and oracle consume: ``n_triples`` / ``n_terms`` / ``term_pat``
    / ``term_val`` / ``dictionary`` / ``decode_term`` / ``term_id``.
    """

    def __init__(
        self,
        base: TripleStore,
        new_terms: tuple[str, ...],
        new_ids: dict[str, int],
        inserted: "set[tuple[int, int, int]]",
        tomb_rows: "list[int]",
    ):
        self.base = base
        self.dictionary = base.dictionary
        self._new_terms = tuple(new_terms)
        self._new_ids = dict(new_ids)
        t0 = base.n_terms
        if self._new_terms:
            extra_pat = np.zeros(len(self._new_terms), np.int32)
            extra_val = np.zeros(len(self._new_terms), np.int32)
            for i, term in enumerate(self._new_terms):
                extra_pat[i], extra_val[i] = encode_rendered_term(
                    base.dictionary, term
                )
            self.term_pat = np.concatenate([base.term_pat, extra_pat])
            self.term_val = np.concatenate([base.term_val, extra_val])
        else:
            self.term_pat = base.term_pat
            self.term_val = base.term_val

        ins = sorted(inserted)
        self.n_delta = len(ins)
        self.dead = np.zeros(base.n_triples, bool)
        if tomb_rows:
            self.dead[np.asarray(tomb_rows, np.int64)] = True
        self.n_dead = int(self.dead.sum())
        self.active = bool(self.n_delta or self.n_dead)

        # the delta index: the insert log as a real TripleStore over the
        # combined term table, padded to a pow2 row capacity so delta
        # growth within a bucket reuses the compiled pipelines.  Pad rows
        # carry the maximum representable id — they sort (and pack) above
        # every real row, and the executor clamps its delta ranges to the
        # live count ``n_delta``, which excludes exactly them.
        cap = next_pow2(max(self.n_delta, 1))
        n_comb = len(self.term_pat)
        if n_comb < (1 << TripleStore.KEY_BITS) - 2:
            pad_id = (1 << TripleStore.KEY_BITS) - 2
        else:
            pad_id = _I32_MAX
        cols = np.full((cap, 3), pad_id, np.int32)
        if ins:
            cols[: self.n_delta] = np.asarray(ins, np.int32)
        self.delta = TripleStore.build(
            base.dictionary, self.term_pat, self.term_val,
            cols[:, 0].copy(), cols[:, 1].copy(), cols[:, 2].copy(),
        )
        self._alive: dict[str, jnp.ndarray] = {}

    # -- store-like surface ---------------------------------------------------

    @property
    def n_terms(self) -> int:
        return len(self.term_pat)

    @property
    def n_triples(self) -> int:
        """The *live* triple count (base minus tombstones plus delta)."""
        return self.base.n_triples - self.n_dead + self.n_delta

    def decode_term(self, term_id: int) -> str:
        t = int(term_id)
        if t < self.base.n_terms:
            return self.base.decode_term(t)
        return self._new_terms[t - self.base.n_terms]

    def term_id(self, rendered: str) -> int | None:
        t = self.base.term_id(rendered)
        if t is None:
            t = self._new_ids.get(rendered)
        return t

    # -- executor operands ----------------------------------------------------

    def alive(self, order: str) -> jnp.ndarray:
        """int32[n_base+1] prefix sums of non-tombstoned rows in ``order``'s
        sorted sequence: ``alive[hi] - alive[lo]`` is a range's live count,
        and rank-select over it materializes the j-th live row."""
        a = self._alive.get(order)
        if a is None:
            perm = self.base.indexes[order].perm
            live = (~self.dead[perm]).astype(np.int64)
            a = jnp.asarray(
                np.concatenate(
                    [np.zeros(1, np.int64), np.cumsum(live)]
                ).astype(np.int32)
            )
            self._alive[order] = a
        return a


class LiveStore:
    """A mutable store: an immutable base plus the current overlay.

    Mutations (:meth:`insert` / :meth:`delete` / :meth:`compact`) bump
    ``generation`` and invalidate the cached view; :meth:`view` snapshots
    the overlay for query execution.  Thread-safety is the caller's
    contract — the server serializes mutations on its dispatcher thread.
    """

    def __init__(self, base: TripleStore):
        self.base = base
        self.generation = int(getattr(base, "_kgz_generation", 0))
        self._new_terms: list[str] = []
        self._new_ids: dict[str, int] = {}
        self._inserted: set[tuple[int, int, int]] = set()
        self._tomb: dict[tuple[int, int, int], int] = {}  # id-triple -> base row
        self._view: OverlayView | None = None

    # -- basics ---------------------------------------------------------------

    @property
    def n_triples(self) -> int:
        return self.base.n_triples - len(self._tomb) + len(self._inserted)

    @property
    def n_terms(self) -> int:
        return self.base.n_terms + len(self._new_terms)

    @property
    def n_delta(self) -> int:
        return len(self._inserted)

    @property
    def n_tombstones(self) -> int:
        return len(self._tomb)

    @property
    def delta_fraction(self) -> float:
        """Overlay pressure: (inserts + tombstones) / live triples — the
        signal a compaction policy (and the ``live.delta_fraction`` gauge)
        watches."""
        return (self.n_delta + self.n_tombstones) / max(self.n_triples, 1)

    def decode_term(self, term_id: int) -> str:
        t = int(term_id)
        if t < self.base.n_terms:
            return self.base.decode_term(t)
        return self._new_terms[t - self.base.n_terms]

    def term_id(self, rendered: str) -> int | None:
        return self._resolve(canonical_term(rendered))

    # -- term interning -------------------------------------------------------

    def _resolve(self, rendered: str) -> int | None:
        t = self.base.term_id(rendered)
        if t is None:
            t = self._new_ids.get(rendered)
        return t

    def _intern(self, rendered: str) -> int:
        t = self._resolve(rendered)
        if t is None:
            t = self.base.n_terms + len(self._new_terms)
            self._new_ids[rendered] = t
            self._new_terms.append(rendered)
        return t

    def _touch(self) -> None:
        self._view = None
        self.generation += 1

    # -- mutations ------------------------------------------------------------

    def insert(self, triples) -> int:
        """Insert rendered ``(s, p, o)`` term-string triples; returns how
        many were actually added (duplicates of live triples are skipped;
        inserting a tombstoned base triple resurrects it)."""
        added = 0
        tn = self.base.n_terms
        for s, p, o in triples:
            trip = (
                self._intern(canonical_term(s)),
                self._intern(canonical_term(p)),
                self._intern(canonical_term(o)),
            )
            if trip in self._tomb:
                del self._tomb[trip]
                added += 1
                continue
            if trip in self._inserted:
                continue
            if (
                trip[0] < tn and trip[1] < tn and trip[2] < tn
                and self.base.spo_row(*trip) is not None
            ):
                continue
            self._inserted.add(trip)
            added += 1
        if added:
            self._touch()
        return added

    def delete(self, triples) -> tuple[int, int]:
        """Delete rendered triples; returns ``(deleted, tombstoned)`` —
        deleting a delta-inserted triple just removes it from the insert
        log, deleting a base triple adds a tombstone, deleting an absent
        triple is a no-op."""
        deleted = tombstoned = 0
        tn = self.base.n_terms
        for s, p, o in triples:
            ids = tuple(
                self._resolve(canonical_term(t)) for t in (s, p, o)
            )
            if any(t is None for t in ids):
                continue
            if ids in self._inserted:
                self._inserted.discard(ids)
                deleted += 1
                continue
            if ids in self._tomb:
                continue
            if ids[0] < tn and ids[1] < tn and ids[2] < tn:
                row = self.base.spo_row(*ids)
                if row is not None:
                    self._tomb[ids] = row
                    deleted += 1
                    tombstoned += 1
        if deleted:
            self._touch()
        return deleted, tombstoned

    # -- snapshots ------------------------------------------------------------

    def view(self) -> OverlayView:
        """The current immutable query snapshot (cached until a mutation)."""
        if self._view is None:
            self._view = OverlayView(
                self.base,
                tuple(self._new_terms),
                self._new_ids,
                self._inserted,
                list(self._tomb.values()),
            )
        return self._view

    def _id_to_rendered(self) -> list[str]:
        base = self.base
        if base._term_ids is None:  # force the reverse map, then invert it
            base._term_ids = {
                base.decode_term(i): i for i in range(base.n_terms)
            }
        out: list[str | None] = [None] * self.n_terms
        for s, i in base._term_ids.items():
            out[i] = s
        for k, s in enumerate(self._new_terms):
            out[base.n_terms + k] = s
        return out

    def rendered_triples(self) -> list[tuple[str, str, str]]:
        """The live triple set as rendered term strings (surviving base
        rows plus the insert log) — the oracle's and compaction's input."""
        id2s = self._id_to_rendered()
        base = self.base
        keep = np.ones(base.n_triples, bool)
        if self._tomb:
            keep[np.fromiter(
                self._tomb.values(), np.int64, len(self._tomb)
            )] = False
        out = [
            (id2s[int(a)], id2s[int(b)], id2s[int(c)])
            for a, b, c in zip(base.s[keep], base.p[keep], base.o[keep])
        ]
        out += [
            (id2s[a], id2s[b], id2s[c]) for a, b, c in sorted(self._inserted)
        ]
        return out

    def compact(self) -> TripleStore:
        """Merge the overlay into a fresh canonical base and reset the
        overlay.  Always a full canonical rebuild — that is the byte-
        identity guarantee: ``save(compact())`` equals ``save`` of a
        from-scratch :meth:`TripleStore.from_ntriples` of the same triple
        set (term ids = ranks of rendered terms, deterministic snapshot
        writer), regardless of how the previous base was built."""
        new = TripleStore.from_ntriples(self.rendered_triples())
        self.base = new
        self._new_terms = []
        self._new_ids = {}
        self._inserted = set()
        self._tomb = {}
        self._view = None
        self.generation += 1
        return new

    def _apply_snapshot(self, new_terms, ins, dels, generation: int) -> None:
        """Rehydrate the overlay from a delta snapshot (see
        :func:`repro.kg.persist.load_chain`): intern the recorded overlay
        terms in order (their ids must come out exactly where the snapshot
        encoded them), replay inserted id-triples and re-resolve tombstoned
        id-triples against the parent's SPO index."""
        t0 = self.base.n_terms
        for k, term in enumerate(new_terms):
            t = self._intern(term)
            if t != t0 + k:
                raise ValueError(
                    f"delta snapshot: overlay term {term!r} resolves to id "
                    f"{t}, expected {t0 + k} — lineage mismatch"
                )
        n_all = self.n_terms
        for row in np.asarray(ins, np.int64).reshape(-1, 3):
            trip = (int(row[0]), int(row[1]), int(row[2]))
            if any(t < 0 or t >= n_all for t in trip):
                raise ValueError(
                    "delta snapshot: inserted term ids out of range "
                    "— truncated or corrupted snapshot"
                )
            self._inserted.add(trip)
        for row in np.asarray(dels, np.int64).reshape(-1, 3):
            trip = (int(row[0]), int(row[1]), int(row[2]))
            base_row = self.base.spo_row(*trip)
            if base_row is None:
                raise ValueError(
                    "delta snapshot: tombstoned triple not present in the "
                    "parent store — lineage mismatch"
                )
            self._tomb[trip] = base_row
        self.generation = int(generation)
        self._view = None

    # -- query convenience ----------------------------------------------------

    def solve(self, q):
        """Plan + execute one query (text or ``SelectQuery``) over the
        current ``base ⊕ delta`` snapshot through the fused executor."""
        from repro.serve import algebra
        from repro.serve.exec import get_executor

        if isinstance(q, str):
            q = algebra.parse_select(q)
        ex = get_executor(self.base)
        return ex.execute(ex.plan(q), [q], view=self.view())
