"""Live-store benchmark (``BENCH_live.json``): the mutable write path,
fused overlay queries at increasing delta fractions, and compaction.

Three axes over one testbed store:

* ``write``      — ``insert`` / ``delete`` rows/s through the overlay log
  (wire-batch sized calls: dict interning, dup/tombstone resolution, view
  invalidation — everything the server's mutation barrier pays except the
  socket);
* ``query``      — batched single-pattern + 2-pattern-join throughput and
  latency through the fused ``base ⊕ delta`` executor arm at delta
  fractions 0 (pure-read fast path), ~1% and ~10% (overlay scan + alive
  rank-select + provenance merge in the dispatch);
* ``compaction`` — one overlay merge back into a canonical sorted store.

Queries are filter-free on purpose: a filtered query forces a per-view
value-table rebuild (O(terms) host work) that would swamp the fused
dispatch being measured here.  ``queries_per_s`` / ``latency_p99_ms``
leaves are gated in CI by ``benchmarks/compare.py``; ``rows_per_s`` is
reported but not gated (see ``benchmarks/README.md``)."""

from __future__ import annotations

import time

import numpy as np

from repro.kg.store import TripleStore
from repro.live.delta import LiveStore
from repro.obs import Histogram
from repro.serve import algebra as A
from repro.serve.exec import get_executor

WRITE_CHUNK = 64  # triples per insert/delete call — a wire-batch worth


def _rendered_rows(store: TripleStore, rows: np.ndarray) -> list:
    return [
        (
            store.decode_term(int(store.s[r])),
            store.decode_term(int(store.p[r])),
            store.decode_term(int(store.o[r])),
        )
        for r in rows
    ]


def _fresh_triples(store: TripleStore, rows: np.ndarray) -> list:
    """Triples guaranteed absent from the base: existing rows re-anchored
    at new subject IRIs, so inserts grow the overlay term table too."""
    return [
        (f"<http://live.bench/s{i}>", p, o)
        for i, (_, p, o) in enumerate(_rendered_rows(store, rows))
    ]


def _mutate_to_fraction(
    live: LiveStore, frac: float, rng: np.random.Generator
) -> None:
    """Insert/delete until ``delta_fraction`` is roughly ``frac`` (half
    inserts, half tombstones)."""
    if frac <= 0:
        return
    base = live.base
    k = max(1, int(base.n_triples * frac / 2))
    ins_rows = rng.choice(base.n_triples, size=k, replace=False)
    del_rows = rng.choice(base.n_triples, size=k, replace=False)
    live.insert(_fresh_triples(base, ins_rows))
    live.delete(_rendered_rows(base, del_rows))


def _time_queries(
    live: LiveStore, qtexts: list[str], batch: int, n_batches: int
) -> dict:
    ex = get_executor(live.base)
    queries = [A.parse_select(t) for t in qtexts]
    lat = Histogram()
    total = n_q = 0
    t_all = 0.0
    for q in queries:
        plan = ex.plan(q)
        qb = [q] * batch
        view = live.view()
        # warm-up: compile this (plan, caps, overlay) pipeline and let the
        # capacity feedback converge, so recompiles stay out of the tail
        for _ in range(4):
            ex.execute(plan, qb, view=view)
        t0 = time.perf_counter()
        for _ in range(n_batches):
            d0 = time.perf_counter_ns()
            res = ex.execute(plan, qb, view=view)
            lat.observe((time.perf_counter_ns() - d0) / 1e6)
            total += int(res.counts.sum())
        t_all += time.perf_counter() - t0
        n_q += n_batches * batch
    return {
        "n_queries": n_q,
        "wall_s": t_all,
        "queries_per_s": n_q / t_all,
        "warm_matches": total,
        "latency_p50_ms": lat.percentile(50),
        "latency_p99_ms": lat.percentile(99),
        "latency_max_ms": lat.max,
    }


def bench_live(
    store: TripleStore,
    batch: int = 256,
    n_batches: int = 32,
    n_write: int = 2048,
    seed: int = 0,
) -> dict:
    """Time the live write path, overlay queries at delta fractions
    0 / ~1% / ~10%, and one compaction over ``store``.  Returns the
    json-ready ``BENCH_live.json`` shape."""
    rng = np.random.default_rng(seed)
    # the two most common predicates plus a selective object anchor shape
    # the query classes (the same scheme repro.serve.bench uses, minus
    # filters); unanchored scans would swamp the overlay arm under sheer
    # match volume
    ids, counts = np.unique(store.p, return_counts=True)
    by_freq = ids[np.argsort(counts)]
    p0, p1 = (int(p) for p in by_freq[-2:])
    t0_, t1_ = (store.decode_term(p) for p in (p0, p1))
    some_o = store.decode_term(int(store.o[np.nonzero(store.p == p0)[0][0]]))
    qtexts = [
        f"SELECT ?s WHERE {{ ?s {t0_} {some_o} }}",
        f"SELECT ?m ?b WHERE {{ ?m {t0_} {some_o} . ?m {t1_} ?b }}",
    ]

    report: dict = {
        "n_triples": int(store.n_triples),
        "n_terms": int(store.n_terms),
    }

    # --- write path -------------------------------------------------------
    live = LiveStore(store)
    n_write = min(n_write, store.n_triples)
    fresh = _fresh_triples(
        store, rng.choice(store.n_triples, size=n_write, replace=False)
    )
    t0 = time.perf_counter()
    for i in range(0, n_write, WRITE_CHUNK):
        live.insert(fresh[i : i + WRITE_CHUNK])
    dt_ins = time.perf_counter() - t0
    doomed = _rendered_rows(
        store, rng.choice(store.n_triples, size=n_write, replace=False)
    )
    t0 = time.perf_counter()
    for i in range(0, n_write, WRITE_CHUNK):
        live.delete(doomed[i : i + WRITE_CHUNK])
    dt_del = time.perf_counter() - t0
    report["write"] = {
        "insert": {
            "rows": n_write,
            "wall_s": dt_ins,
            "rows_per_s": n_write / dt_ins,
        },
        "delete": {
            "rows": n_write,
            "wall_s": dt_del,
            "rows_per_s": n_write / dt_del,
        },
    }

    # --- query path at increasing delta fractions -------------------------
    report["query"] = {}
    for label, frac in (("delta0", 0.0), ("delta1pct", 0.01),
                        ("delta10pct", 0.10)):
        lv = LiveStore(store)
        _mutate_to_fraction(lv, frac, np.random.default_rng(seed + 1))
        r = _time_queries(lv, qtexts, batch, n_batches)
        r["delta_fraction"] = lv.delta_fraction
        report["query"][label] = r

    # --- compaction -------------------------------------------------------
    lv = LiveStore(store)
    _mutate_to_fraction(lv, 0.10, np.random.default_rng(seed + 2))
    t0 = time.perf_counter()
    compacted = lv.compact()
    report["compaction"] = {
        "compact_ms": (time.perf_counter() - t0) * 1e3,
        "triples": int(compacted.n_triples),
    }
    return report
