"""``repro.live`` — mutable delta overlays over the immutable TripleStore.

See :mod:`repro.live.delta` for the design.
"""

from repro.live.delta import LiveStore, OverlayView

__all__ = ["LiveStore", "OverlayView"]
