"""Distributed operators under a real multi-device mesh.

Runs in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps its single CPU device (per the assignment:
only the dry-run may see many devices).
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os, json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import hashing, distributed
from collections import defaultdict

from repro.compat import compat_make_mesh

mesh = compat_make_mesh((4, 2), ("data", "model"))
sh = NamedSharding(mesh, P(("data", "model")))
rng = np.random.default_rng(0)
out = {}

# --- shuffle-dedup vs exact oracle, two batches
vals = rng.integers(0, 3000, size=16384).astype(np.int32)
hi, lo = hashing.mix64([jnp.asarray(vals)])
hi_np, lo_np = np.asarray(hi), np.asarray(lo)
seen, oracle = set(), []
for h, l in zip(hi_np.tolist(), lo_np.tolist()):
    oracle.append((h, l) not in seen); seen.add((h, l))
table = distributed.make_sharded_ptt(mesh, 16384)
got = []
for i in range(2):
    sl = slice(i * 8192, (i + 1) * 8192)
    table, is_new, ovf = distributed.distributed_insert(
        mesh, table,
        jax.device_put(hi_np[sl], sh), jax.device_put(lo_np[sl], sh),
        jax.device_put(np.ones(8192, bool), sh))
    assert not bool(ovf)
    got.extend(np.asarray(is_new).tolist())
out["dedup_exact"] = got == oracle
out["distinct"] = (int(np.sum(got)), len(seen))

# --- distributed PJTT + OJM probe vs python join
pk = rng.integers(0, 500, size=8192).astype(np.int32)
ps = rng.integers(0, 100000, size=8192).astype(np.int32)
ck = rng.integers(0, 700, size=8192).astype(np.int32)
idx, ovf = distributed.build_distributed_pjtt(
    mesh, jax.device_put(pk, sh), jax.device_put(ps, sh))
assert not bool(ovf)
subs, valid, ovf2 = distributed.distributed_ojm_probe(
    mesh, idx, jax.device_put(ck, sh), 128)
assert not bool(ovf2)
subs, valid = np.asarray(subs), np.asarray(valid)
d = defaultdict(set)
for k, s in zip(pk.tolist(), ps.tolist()):
    d[k].add(s)
out["join_exact"] = all(
    set(subs[i][valid[i]].tolist()) == d.get(k, set())
    for i, k in enumerate(ck.tolist()))
print(json.dumps(out))
"""


@pytest.mark.slow
def test_distributed_operators_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["dedup_exact"] is True
    assert out["distinct"][0] == out["distinct"][1]
    assert out["join_exact"] is True


def test_main_process_sees_one_device():
    """Guard: the test/bench environment must NOT be polluted with the
    512-device dry-run flag (assignment requirement)."""
    import jax

    assert len(jax.devices()) == 1
