"""Tests for repro.obs — metrics substrate and dispatch tracing.

Property tests (hypothesis, or the seeded shim when it isn't installed)
pin the histogram's accuracy contract: bucketed quantiles are within one
bucket's relative error (``1/SUBBUCKETS``) of the exact nearest-rank
sample quantile at any magnitude, and merging is associative.  The trace
tests are golden: the export must be valid Chrome trace-event JSON with
properly nested spans.
"""

from __future__ import annotations

import json
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback for slim images
    from _hypothesis_shim import given, settings, st

from repro.obs import (
    SUBBUCKETS,
    Histogram,
    MetricsRegistry,
    Tracer,
    bucket_bounds,
    bucket_index,
    get_registry,
    get_tracer,
)

# one bucket's relative width — the histogram's accuracy contract
REL_ERR = 1.0 / SUBBUCKETS


def _values(seed: int, n: int, lo=1e-6, hi=1e4) -> np.ndarray:
    """Log-uniform latency samples spanning 1µs..10s (in ms units)."""
    rng = np.random.default_rng(seed)
    return np.exp(rng.uniform(np.log(lo), np.log(hi), size=n))


# ---------------------------------------------------------------- buckets


@given(m=st.integers(1, 1000), e=st.integers(-20, 13))
@settings(max_examples=200, deadline=None)
def test_bucket_containment(m, e):
    # every positive value lands in a bucket containing it, whose width
    # is at most 1/SUBBUCKETS of its magnitude
    v = (m / 1000.0) * 2.0**e
    idx = bucket_index(v)
    lo, hi = bucket_bounds(idx)
    assert lo < v <= hi or np.isclose(v, lo), (v, lo, hi)
    assert hi / lo <= 1.0 + REL_ERR + 1e-12


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 400))
@settings(max_examples=30, deadline=None)
def test_percentile_vs_numpy(seed, n):
    # bucketed nearest-rank quantile is within one bucket's relative
    # error of numpy's exact inverted-CDF quantile, across 10 orders of
    # magnitude in one histogram
    vals = _values(seed, n)
    h = Histogram()
    for v in vals:
        h.observe(float(v))
    for q in (50.0, 90.0, 99.0):
        exact = float(np.percentile(vals, q, method="inverted_cdf"))
        est = h.percentile(q)
        assert exact <= est <= exact * (1.0 + REL_ERR) + 1e-12, (
            q, exact, est, n,
        )


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_merge_associative(seed):
    vals = _values(seed, 300)
    parts = np.array_split(vals, 3)
    hs = []
    for part in parts:
        h = Histogram()
        for v in part:
            h.observe(float(v))
        hs.append(h)
    a, b, c = hs
    left = Histogram.merged(Histogram.merged(a, b), c)
    right = Histogram.merged(a, Histogram.merged(b, c))
    assert left.buckets == right.buckets
    assert left.count == right.count == len(vals)
    # merging equals observing everything into one histogram
    whole = Histogram()
    for v in vals:
        whole.observe(float(v))
    assert left.buckets == whole.buckets
    assert left.percentile(99) == whole.percentile(99)


def test_empty_histogram():
    h = Histogram()
    assert h.percentile(50) is None and h.percentile(99) is None
    d = h.to_dict()
    assert d["count"] == 0 and d["p50"] is None and d["p99"] is None
    # merging an empty histogram is the identity
    other = Histogram()
    other.observe(3.0)
    before = dict(other.buckets)
    other.merge(h)
    assert other.buckets == before and other.count == 1
    assert Histogram.from_dict(d).percentile(50) is None


def test_zero_and_negative_observations():
    h = Histogram()
    for v in (0.0, -1.5, 0.0):
        h.observe(v)
    assert h.count == 3 and h.zero == 3 and h.buckets == {}
    # all mass at zero: every quantile is 0.0, not None
    assert h.percentile(50) == 0.0 and h.percentile(99) == 0.0
    h.observe(8.0)
    assert h.percentile(50) == 0.0  # rank 2 of 4 still in the zero bucket
    assert h.percentile(99) >= 8.0


def test_to_dict_roundtrips_through_json():
    h = Histogram()
    for v in _values(7, 123):
        h.observe(float(v))
    d = json.loads(json.dumps(h.to_dict()))
    back = Histogram.from_dict(d)
    assert back.buckets == h.buckets
    assert back.count == h.count and back.max == h.max
    for q in (50, 90, 99):
        assert back.percentile(q) == h.percentile(q)


# --------------------------------------------------------------- registry


def test_registry_create_on_first_touch_and_snapshot():
    reg = MetricsRegistry()
    reg.inc("a.b", 2)
    reg.inc("a.b", 3)
    reg.gauge("g").set_max(5)
    reg.gauge("g").set_max(1)  # running max keeps 5
    reg.observe("h.ms", 2.0)
    snap = reg.snapshot()
    assert snap["counters"] == {"a.b": 5}
    assert snap["gauges"] == {"g": 5}
    assert snap["histograms"]["h.ms"]["count"] == 1
    json.dumps(snap)  # the wire-op payload must be JSON-ready
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_registry_concurrent_updates_exact():
    # the regression the old hand-rolled ServerStats had: unlocked
    # += from accept/client/dispatch threads drops increments
    reg = MetricsRegistry()
    n_threads, n_iter = 8, 2000

    def work():
        for _ in range(n_iter):
            reg.inc("c")
            reg.observe("h", 1.0)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("c").value == n_threads * n_iter
    assert reg.histogram("h").count == n_threads * n_iter


def test_global_registry_is_a_singleton():
    assert get_registry() is get_registry()


# ------------------------------------------------------------------ trace


def test_tracer_disabled_records_nothing():
    tr = Tracer()
    with tr.span("x", cat="t"):
        pass
    tr.add_complete("y", "t", 0, 10)
    assert tr.export()["traceEvents"] == []


def test_trace_export_is_valid_chrome_trace_with_nested_spans():
    tr = Tracer()
    tr.enable()
    with tr.span("outer", cat="test", plan="deadbeef"):
        with tr.span("inner", cat="test", round=0):
            pass
    doc = json.loads(json.dumps(tr.export()))  # must survive JSON
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    assert [e["name"] for e in evs] == ["inner", "outer"]  # exit order
    for e in evs:
        assert e["ph"] == "X"
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0
        assert e["pid"] and e["tid"]
    inner, outer = evs
    # proper nesting: the inner span's interval sits inside the outer's
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["args"]["plan"] == "deadbeef"
    assert inner["args"]["round"] == 0


def test_trace_retroactive_span_and_ring_capacity():
    tr = Tracer(capacity=4)
    tr.enable()
    for i in range(10):
        tr.add_complete("ev", "t", i * 1000, i * 1000 + 500, i=i)
    doc = tr.export()
    evs = doc["traceEvents"]
    assert len(evs) == 4  # ring keeps only the newest spans
    assert [e["args"]["i"] for e in evs] == [6, 7, 8, 9]
    assert doc["otherData"]["dropped_events"] == 6
    tr.clear()
    assert tr.export()["traceEvents"] == []
    assert tr.export()["otherData"]["dropped_events"] == 0


def test_tracer_span_records_on_exception():
    tr = Tracer()
    tr.enable()
    with pytest.raises(ValueError):
        with tr.span("failing", cat="t"):
            raise ValueError("boom")
    evs = tr.export()["traceEvents"]
    assert [e["name"] for e in evs] == ["failing"]


def test_global_tracer_is_a_singleton():
    assert get_tracer() is get_tracer()
