"""The small-batch fast path: fused scan-join chain vs the general
executor (bit-identical rows over property-generated queries), overlay
fallback, the Pallas kernel formulation vs the vmapped reference,
signature warm-up, and the adaptive micro-batch linger."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # test image without hypothesis: seeded-example fallback
    from _hypothesis_shim import given, settings, st

from repro.kernels import scan_join as K
from repro.kg.store import TripleStore
from repro.live.delta import LiveStore
from repro.obs import get_registry
from repro.serve import fastpath as FP
from repro.serve import parse_select
from repro.serve.exec import get_executor
from repro.serve.server import _AdaptiveLinger

SUBS = [f"<http://ex/s{i}>" for i in range(5)]
PREDS = [f"<http://ex/p{i}>" for i in range(3)]
OBJS = SUBS[:2] + ['"1"', '"2"', '"10"', '"abc"', '""']


def rand_store(seed: int, n_triples: int) -> TripleStore:
    rng = np.random.default_rng(seed)
    triples = {
        (
            SUBS[rng.integers(0, len(SUBS))],
            PREDS[rng.integers(0, len(PREDS))],
            OBJS[rng.integers(0, len(OBJS))],
        )
        for _ in range(n_triples)
    }
    return TripleStore.from_ntriples(sorted(triples))


# chain-eligible shapes (Scan → BindJoin* with sort/project/limit on
# top); the templates close over predicate/object constants
CHAIN_TEMPLATES = [
    lambda p, o: f"SELECT * WHERE {{ ?s {p[0]} ?o }}",
    lambda p, o: f"SELECT * WHERE {{ ?s {p[0]} {o[0]} }}",
    lambda p, o: f"SELECT * WHERE {{ ?s ?p ?o }}",
    lambda p, o: f"SELECT ?o WHERE {{ ?s {p[0]} ?o }} LIMIT 2",
    lambda p, o: f"SELECT * WHERE {{ ?s {p[0]} ?a . ?s {p[1]} ?b }}",
    lambda p, o: (
        f"SELECT ?s ?c WHERE {{ ?s {p[0]} ?a . ?s {p[1]} ?b . "
        f"?s {p[0]} ?c }} LIMIT 5"
    ),
    lambda p, o: f"SELECT * WHERE {{ {o[0]} {p[0]} ?o }}",
]


def _both_paths(ex, qtext, n_queries=1):
    """Rows from the fast path and the forced-general path for the same
    micro-batch; asserts the fast path actually took the batch."""
    q = parse_select(qtext)
    plan = ex.plan(q)
    qs = [q] * n_queries
    reg = get_registry()
    before = reg.counter("exec.fastpath_dispatches").value
    ex.fastpath_enabled = True
    fast = ex.execute(plan, qs)
    took_fast = reg.counter("exec.fastpath_dispatches").value > before
    ex.fastpath_enabled = False
    try:
        gen = ex.execute(plan, qs)
    finally:
        ex.fastpath_enabled = True
    return fast, gen, took_fast


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    n=st.integers(0, 30),
    t=st.integers(0, len(CHAIN_TEMPLATES) - 1),
    bsz=st.sampled_from([1, 3]),
)
def test_fastpath_matches_general(seed, n, t, bsz):
    rng = np.random.default_rng(seed + 1)
    store = rand_store(seed, n)
    ex = get_executor(store)
    p = [PREDS[rng.integers(0, len(PREDS))] for _ in range(2)]
    o = [SUBS[rng.integers(0, len(SUBS))]]
    qtext = CHAIN_TEMPLATES[t](p, o)
    fast, gen, took_fast = _both_paths(ex, qtext, n_queries=bsz)
    for i in range(bsz):
        assert fast.n(i) == gen.n(i), qtext
        assert fast.rows(i) == gen.rows(i), qtext
    # an eligible chain over a non-empty packed store must route fast
    # (star templates are eligible only when the planner picked bind
    # joins, which depends on the per-store cardinality estimates)
    from repro.serve import plan as P

    if (
        store.n_triples > 0
        and store.device_keys("spo") is not None
        and P.fastpath_chain(ex.plan(parse_select(qtext))) is not None
    ):
        assert took_fast, qtext


def test_ineligible_shapes_fall_back():
    store = rand_store(2, 40)
    ex = get_executor(store)
    reg = get_registry()
    for qtext in (
        "SELECT * WHERE { ?s <http://ex/p0> ?o FILTER(?o > 1) }",
        "SELECT * WHERE { { ?s <http://ex/p0> ?o } UNION "
        "{ ?s <http://ex/p1> ?o } }",
        "SELECT ?s (COUNT(*) AS ?n) WHERE { ?s ?p ?o } GROUP BY ?s",
    ):
        q = parse_select(qtext)
        before = reg.counter("exec.fastpath_dispatches").value
        ex.execute(ex.plan(q), [q])
        assert reg.counter("exec.fastpath_dispatches").value == before, qtext


def test_overlay_falls_back_to_general():
    """A live store's overlay view never takes the fast path (PR 7
    semantics: fused overlay queries run the general pipeline) but the
    answers still reflect the mutations."""
    store = rand_store(4, 30)
    live = LiveStore(store)
    ex = get_executor(store)
    q = parse_select("SELECT * WHERE { ?s <http://ex/p0> ?o }")
    plan = ex.plan(q)
    base_n = ex.execute(plan, [q]).n(0)
    live.insert([("<http://ex/new>", "<http://ex/p0>", '"live"')])
    reg = get_registry()
    before = reg.counter("exec.fastpath_dispatches").value
    res = ex.execute(plan, [q], view=live.view())
    assert reg.counter("exec.fastpath_dispatches").value == before
    assert res.n(0) == base_n + 1
    assert ("<http://ex/new>", '"live"') in res.rows(0)


def test_kernel_matches_reference():
    """The Pallas grid kernel (interpret mode on CPU) and the vmapped
    reference compute bit-identical outputs from one ChainSpec."""
    # skew predicate cardinalities so the planner anchors on the rare
    # p0 and bind-joins the common p1 (scan.est > left.est): a genuine
    # 2-reader chain, not a merge join
    triples = [(f"<http://ex/s{i}>", "<http://ex/p1>", f'"v{i % 7}"')
               for i in range(40)]
    triples += [(f"<http://ex/s{i}>", "<http://ex/p0>", '"anchor"')
                for i in range(5)]
    store = TripleStore.from_ntriples(sorted(set(triples)))
    ex = get_executor(store)
    q = parse_select(
        "SELECT * WHERE { ?s <http://ex/p0> ?a . ?s <http://ex/p1> ?b }"
    )
    plan = ex.plan(q)
    fp = FP.build(ex, plan)
    assert fp is not None and len(fp.spec.readers) == 2
    caps = tuple(max(c, 64) for c in fp.base_caps)
    ref = K.make_batched(fp.spec, caps, use_kernel=False)
    ker = K.make_batched(fp.spec, caps, use_kernel=True, interpret=True)
    rng = np.random.default_rng(0)
    bsz = 4
    w = K.qrow_width(len(fp.spec.readers))
    qbuf = np.full((bsz, w), -1, np.int32)
    for i in range(bsz):
        consts = np.full((len(fp.spec.readers), 3), -2, np.int32)
        # vary the subject anchor: valid ids, an unknown id, wildcards
        consts[:, 0] = [-2, 0, int(rng.integers(0, store.n_terms)),
                        10 ** 6][i % 4]
        qbuf[i, : 3 * len(fp.spec.readers)] = consts.reshape(-1)
        qbuf[i, 3 * len(fp.spec.readers)] = 1
        qbuf[i, 3 * len(fp.spec.readers) + 1] = -1
    r_outs, r_n, r_need = ref(*fp.operands, qbuf)
    k_outs, k_n, k_need = ker(*fp.operands, qbuf)
    assert np.array_equal(np.asarray(r_n), np.asarray(k_n))
    assert np.array_equal(np.asarray(r_need), np.asarray(k_need))
    for a, b in zip(r_outs, k_outs):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_warmup_precompiles_signatures():
    store = rand_store(8, 50)
    ex = get_executor(store)
    n_warmed = ex.warmup()
    assert n_warmed >= 1
    reg = get_registry()
    compiles = reg.counter("exec.fastpath_compiles").value
    # the exact shapes warmup ran: a batch-1 single-pattern query on the
    # store's top predicate must hit the compiled-function cache
    pos = store.indexes["pos"]
    preds, counts = np.unique(np.asarray(pos.cols[0]), return_counts=True)
    p0 = store.decode_term(int(preds[np.argmax(counts)]))
    q = parse_select(f"SELECT * WHERE {{ ?s {p0} ?o }}")
    res = ex.execute(ex.plan(q), [q])
    assert res.n(0) > 0
    assert reg.counter("exec.fastpath_compiles").value == compiles


def test_adaptive_linger_windows():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    lg = _AdaptiveLinger(max_s=0.002, registry=reg, full_batch=64)
    # cold start: no rate estimate yet -> the full configured window
    assert lg.window_s() == 0.002
    t = 0
    lg.observe_arrival(t)
    assert lg.window_s() == 0.002  # one arrival: still no gap estimate
    # sparse traffic (1 request/s): nobody will share the batch -> zero
    for _ in range(5):
        t += 1_000_000_000
        lg.observe_arrival(t)
    assert lg.window_s() == 0.0
    # a dense burst (50 µs gaps): linger, scaled by expected batch share
    for _ in range(200):
        t += 50_000
        lg.observe_arrival(t)
    w = lg.window_s()
    assert 0.0 < w <= 0.002
    expected = 0.002 / lg._gap_s
    assert w == pytest.approx(0.002 * min(1.0, expected / 64), rel=1e-6)
    # the exec-time floor: batching finer than one dispatch can't help
    reg.observe("serve.exec_ms", 1.5)
    assert 0.0015 - 1e-9 <= lg.window_s() <= 0.002
