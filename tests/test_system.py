"""End-to-end behaviour tests for the paper's system (deliverable c)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.executor import create_kg
from repro.rml import generator, parser, serializer


def test_motivating_example_flow(tmp_path):
    """Figure 1 of the paper: two heterogeneous sources + join -> KG, via
    files and the parser (the full user path)."""
    tb = generator.make_ojm_testbed(2000, 0.25, n_poms=1)
    tb.write(str(tmp_path))
    serializer.write_turtle(tb.doc, str(tmp_path / "m.ttl"))
    doc = parser.parse_file(str(tmp_path / "m.ttl"))
    res = create_kg(doc, data_root=str(tmp_path))
    assert res.n_triples > 0
    # triples mention both the child subject and the parent subject spaces
    nt = "\n".join(list(res.iter_ntriples())[:2000])
    assert "repro.org/mutation/" in nt and "repro.org/exon1/" in nt


def test_streaming_batches_match_single_batch():
    """The executor's fixed-shape streaming (small batches) must produce the
    same KG as one big batch — the jit-stable incremental path."""
    tb = generator.make_testbed("SOM", 3000, 0.75, n_poms=2, seed=9)
    tables = {"csv:child.csv": tb.child}
    small = create_kg(tb.doc, tables=tables, batch_size=256)
    big = create_kg(tb.doc, tables=tables, batch_size=1 << 16)
    assert small.as_set() == big.as_set()


def test_overflow_retry_rebuilds_bigger_table(monkeypatch):
    """Force a tiny initial PTT and confirm the executor's overflow-replay
    path still produces the exact KG."""
    from repro.core import executor as ex

    tb = generator.make_testbed("SOM", 2000, 0.25, n_poms=1, seed=4)
    tables = {"csv:child.csv": tb.child}
    want = create_kg(tb.doc, tables=tables).as_set()

    orig = ex.next_pow2
    # lie about capacity on first call -> overflow -> doubling loop
    calls = {"n": 0}

    def tiny_first(n):
        calls["n"] += 1
        return 256 if calls["n"] <= 2 else orig(n)

    monkeypatch.setattr(ex, "next_pow2", tiny_first)
    got = create_kg(tb.doc, tables=tables).as_set()
    assert got == want


def test_json_source_equivalent_to_csv(tmp_path):
    """Heterogeneous sources (paper: CSV/JSON/XML): same rows via JSON give
    the same KG."""
    import json as jsonlib

    tb = generator.make_testbed("SOM", 500, 0.25, n_poms=2, seed=2)
    # write CSV
    tb.write(str(tmp_path))
    # write the same table as JSON-lines
    cols = list(tb.child)
    n = len(tb.child[cols[0]])
    with open(tmp_path / "child.json", "w") as f:
        for i in range(n):
            f.write(jsonlib.dumps({c: str(tb.child[c][i]) for c in cols}) + "\n")

    doc_csv = tb.doc
    import dataclasses

    from repro.rml.model import LogicalSource, MappingDocument

    maps = {}
    for name, tm in doc_csv.triples_maps.items():
        maps[name] = dataclasses.replace(
            tm, source=LogicalSource(path="child.json", fmt="json")
        )
    doc_json = MappingDocument(maps)

    r1 = create_kg(doc_csv, data_root=str(tmp_path))
    r2 = create_kg(doc_json, data_root=str(tmp_path))
    assert r1.n_triples == r2.n_triples
    assert set(r1.iter_ntriples()) == set(r2.iter_ntriples())


def test_all_40_cells_are_defined():
    """Deliverable f: 10 archs x 4 shapes, every cell buildable or skipped
    with a reason."""
    from repro.configs import registry

    cells = [(a.name, s) for a in registry.ARCHS.values() for s in a.shapes]
    assert len(cells) == 40
    n_skips = sum(
        1 for a in registry.ARCHS.values() for s in a.shapes if s in a.skips
    )
    assert n_skips == 4  # the four pure-full-attention long_500k cells
    for a in registry.ARCHS.values():
        for s, reason in a.skips.items():
            assert "full-attention" in reason


def test_registry_smoke_configs_are_small():
    from repro.configs import registry

    for a in registry.ARCHS.values():
        cfg = a.smoke_config()
        if a.family == "lm":
            assert cfg.param_count() < 5_000_000
