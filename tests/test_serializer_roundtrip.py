"""Serializer round-trips: ``parse(to_turtle(parse(x)))`` equivalence across
all example mappings — every operator kind and POM width the generator
produces, plus JSON sources with iterators, join conditions, and constant /
template / reference object maps (satellite of the repro.kg PR)."""

import pytest

from repro.rml import generator, parser, serializer
from repro.rml.model import (
    JoinCondition,
    LogicalSource,
    MappingDocument,
    PredicateObjectMap,
    RefObjectMap,
    TermMap,
    TriplesMap,
)


def _assert_roundtrip(doc: MappingDocument) -> None:
    ttl = serializer.to_turtle(doc)
    doc2 = parser.parse(ttl)
    assert doc2.triples_maps == doc.triples_maps
    # fixpoint: serialize -> parse -> serialize -> parse is stable
    assert parser.parse(serializer.to_turtle(doc2)).triples_maps == doc.triples_maps


@pytest.mark.parametrize("kind", ["SOM", "ORM", "OJM"])
@pytest.mark.parametrize("n_poms", [1, 2, 5])
@pytest.mark.parametrize("seed", [0, 3])
def test_generator_testbeds_roundtrip(kind, n_poms, seed):
    tb = generator.make_testbed(kind, 50, 0.25, n_poms=n_poms, seed=seed)
    _assert_roundtrip(tb.doc)


def test_json_iterator_roundtrip():
    """JSON logical sources keep their referenceFormulation and iterator."""
    src = LogicalSource(path="data/items.json", fmt="json", iterator="$.items[*]")
    psrc = LogicalSource(path="data/owners.json", fmt="json", iterator="$.owners[*]")
    maps = {
        "OwnerMap": TriplesMap(
            name="OwnerMap",
            source=psrc,
            subject=TermMap(template="http://ex.org/owner/{oid}"),
            subject_class="http://ex.org/vocab/Owner",
        ),
        "ItemMap": TriplesMap(
            name="ItemMap",
            source=src,
            subject=TermMap(template="http://ex.org/item/{id}"),
            subject_class="http://ex.org/vocab/Item",
            poms=(
                PredicateObjectMap(
                    predicate="http://ex.org/vocab/label",
                    object_map=TermMap(reference="label"),
                ),
                PredicateObjectMap(
                    predicate="http://ex.org/vocab/ownedBy",
                    object_map=RefObjectMap(
                        parent_triples_map="OwnerMap",
                        join=JoinCondition(child="owner_id", parent="oid"),
                    ),
                ),
            ),
        ),
    }
    doc = MappingDocument(maps)
    doc.validate()
    _assert_roundtrip(doc)
    reparsed = parser.parse(serializer.to_turtle(doc))
    item = reparsed.triples_maps["ItemMap"]
    assert item.source.fmt == "json"
    assert item.source.iterator == "$.items[*]"
    join = item.poms[1].object_map.join
    assert join == JoinCondition(child="owner_id", parent="oid")


def test_join_condition_roundtrip_multiple_parents():
    """Several OJM rules against distinct parents with distinct join columns."""
    child = LogicalSource(path="child.csv")
    maps = {}
    poms = []
    for i in range(3):
        pname = f"Parent{i}"
        maps[pname] = TriplesMap(
            name=pname,
            source=LogicalSource(path=f"parent{i}.csv"),
            subject=TermMap(template=f"http://ex.org/p{i}/{{K{i}}}"),
        )
        poms.append(
            PredicateObjectMap(
                predicate=f"http://ex.org/vocab/rel{i}",
                object_map=RefObjectMap(
                    parent_triples_map=pname,
                    join=JoinCondition(child=f"fk{i}", parent=f"K{i}"),
                ),
            )
        )
    maps["Child"] = TriplesMap(
        name="Child",
        source=child,
        subject=TermMap(template="http://ex.org/c/{ID}"),
        poms=tuple(poms),
    )
    doc = MappingDocument(maps)
    doc.validate()
    _assert_roundtrip(doc)


def test_object_map_kinds_roundtrip():
    """template / reference / constant object maps, multi-column templates,
    and a subject map without a class."""
    tm = TriplesMap(
        name="T",
        source=LogicalSource(path="t.tsv", fmt="tsv"),
        subject=TermMap(template="http://ex.org/{A}/{B}"),
        poms=(
            PredicateObjectMap(
                predicate="http://ex.org/vocab/tpl",
                object_map=TermMap(template="http://ex.org/val/{C}"),
            ),
            PredicateObjectMap(
                predicate="http://ex.org/vocab/ref",
                object_map=TermMap(reference="D"),
            ),
            PredicateObjectMap(
                predicate="http://ex.org/vocab/const-iri",
                object_map=TermMap(constant="http://ex.org/thing"),
            ),
            PredicateObjectMap(
                predicate="http://ex.org/vocab/const-lit",
                object_map=TermMap(constant="a plain literal"),
            ),
        ),
    )
    doc = MappingDocument({"T": tm})
    doc.validate()
    ttl = serializer.to_turtle(doc)
    doc2 = parser.parse(ttl)
    # fmt "tsv" has no referenceFormulation of its own (serialized as ql:CSV);
    # everything else must survive exactly
    t2 = doc2.triples_maps["T"]
    assert t2.subject == tm.subject
    assert t2.poms == tm.poms
    assert parser.parse(serializer.to_turtle(doc2)).triples_maps == doc2.triples_maps
