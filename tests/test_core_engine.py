"""Core engine: hash set, PJTT strategies, operators, and the paper's
operation-count (φ) model."""

import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # test image without hypothesis: seeded-example fallback
    from _hypothesis_shim import given, settings, st

from repro.core import hashing, hashset, naive, operators, pjtt, ptt


def _keys(vals):
    return hashing.mix64([jnp.asarray(np.asarray(vals, np.int32))])


# ----------------------------------------------------------------- hash set


@pytest.mark.parametrize("n,n_distinct,batches", [(100, 10, 1), (5000, 500, 5), (333, 7, 3)])
def test_hashset_first_wins_semantics(n, n_distinct, batches):
    rng = np.random.default_rng(n)
    vals = rng.integers(0, n_distinct, size=n).astype(np.int32)
    hi, lo = _keys(vals)
    hi, lo = np.asarray(hi), np.asarray(lo)
    seen, expected = set(), []
    for h, l in zip(hi.tolist(), lo.tolist()):
        expected.append((h, l) not in seen)
        seen.add((h, l))
    table = hashset.make(4 * n)
    got = []
    split = np.array_split(np.arange(n), batches)
    for part in split:
        res = hashset.insert(table, jnp.asarray(hi[part]), jnp.asarray(lo[part]))
        table = res.table
        assert not bool(res.overflowed)
        got.extend(np.asarray(res.is_new).tolist())
    assert got == expected
    assert int(hashset.count(table)) == len(seen)


def test_hashset_overflow_reported():
    table = hashset.make(2)  # capacity 2
    hi, lo = _keys(np.arange(10))
    res = hashset.insert(table, hi, lo)
    assert bool(res.overflowed)


def test_mix64_structured_triple_keys_collision_free():
    """Regression: the final cross-lane mix must be a bijection on the
    64-bit state.  The old parallel shifted-xor had a 2^31-element kernel
    (~33 effective key bits), which produced real collisions — silently
    dropped triples — on COSMIC-style id grids at 100K rows."""
    n = 1 << 21
    ids = jnp.arange(n, dtype=jnp.int32)
    hi, lo = hashing.triple_key(
        jnp.int32(7), ids, jnp.int32(9), jnp.int32(11), ids + jnp.int32(1000003)
    )
    key = (np.asarray(hi).astype(np.uint64) << 32) | np.asarray(lo).astype(
        np.uint64
    )
    assert len(np.unique(key)) == n


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 400), k=st.integers(1, 50), seed=st.integers(0, 999))
def test_hashset_distinct_count_property(n, k, seed):
    vals = np.random.default_rng(seed).integers(0, k, n).astype(np.int32)
    hi, lo = _keys(vals)
    res = hashset.insert(hashset.make(4 * n + 8), hi, lo)
    assert int(np.asarray(res.is_new).sum()) == len(set(vals.tolist()))


# --------------------------------------------------------------------- PJTT


@pytest.mark.parametrize("strategy", ["sorted", "hash"])
@pytest.mark.parametrize("n,m,keys", [(50, 30, 5), (1000, 700, 40), (64, 64, 1)])
def test_pjtt_matches_python_join(strategy, n, m, keys):
    rng = np.random.default_rng(n + m)
    pk = rng.integers(0, keys, n).astype(np.int32)
    ps = rng.integers(0, 10000, n).astype(np.int32)
    ck = rng.integers(0, keys + 2, m).astype(np.int32)
    K = int(np.bincount(pk, minlength=keys).max()) + 1

    if strategy == "sorted":
        idx = pjtt.build_sorted(jnp.asarray(pk), jnp.asarray(ps))
        pr = pjtt.probe_sorted(idx, jnp.asarray(ck), K)
    else:
        idx = pjtt.build_hash(jnp.asarray(pk), jnp.asarray(ps))
        pr = pjtt.probe_hash(idx, jnp.asarray(ck), K)
    assert not bool(pr.truncated)

    from collections import defaultdict

    d = defaultdict(set)
    for k, s in zip(pk.tolist(), ps.tolist()):
        d[k].add(s)
    subs, valid = np.asarray(pr.subjects), np.asarray(pr.valid)
    for i, k in enumerate(ck.tolist()):
        assert set(subs[i][valid[i]].tolist()) == d.get(k, set()), i


def test_pjtt_set_semantics_masks_duplicate_pairs():
    # identical (key, subject) pairs collapse (paper: values are a SET)
    pk = jnp.asarray(np.array([1, 1, 1, 2], np.int32))
    ps = jnp.asarray(np.array([7, 7, 8, 9], np.int32))
    idx = pjtt.build_sorted(pk, ps)
    pr = pjtt.probe_sorted(idx, jnp.asarray(np.array([1], np.int32)), 4)
    got = np.asarray(pr.subjects)[0][np.asarray(pr.valid)[0]]
    assert sorted(got.tolist()) == [7, 8]


def test_pjtt_truncation_flag():
    pk = jnp.zeros(8, jnp.int32)
    ps = jnp.arange(8, dtype=jnp.int32)
    idx = pjtt.build_sorted(pk, ps)
    pr = pjtt.probe_sorted(idx, jnp.zeros(1, jnp.int32), 4)
    assert bool(pr.truncated)


# ---------------------------------------------------------------- operators


def test_som_vs_naive_identical_triples():
    rng = np.random.default_rng(0)
    subj = rng.integers(0, 50, 500).astype(np.int32)
    obj = rng.integers(0, 20, 500).astype(np.int32)
    p = operators.StaticTripleParams(subj_tmpl=1, pred_id=2, obj_tmpl=3)

    table = ptt.make(600)
    r = operators.som(table, jnp.asarray(subj), jnp.asarray(obj), p)
    n_opt = int(np.asarray(r.is_new).sum())

    keys = operators.naive_som_keys(jnp.asarray(subj), jnp.asarray(obj), p)
    dd = operators.naive_dedup(keys)
    assert n_opt == int(dd.n_unique)
    assert n_opt == len({(s, o) for s, o in zip(subj.tolist(), obj.tolist())})


def test_ojm_index_join_vs_nested_loop():
    rng = np.random.default_rng(1)
    pk = rng.integers(0, 20, 200).astype(np.int32)
    psub = rng.integers(0, 500, 200).astype(np.int32)
    ck = rng.integers(0, 22, 150).astype(np.int32)
    csub = rng.integers(0, 100, 150).astype(np.int32)
    K = int(np.bincount(pk).max()) + 1
    p = operators.StaticTripleParams(subj_tmpl=1, pred_id=2, obj_tmpl=3)

    idx = pjtt.build_sorted(jnp.asarray(pk), jnp.asarray(psub))
    r = operators.ojm(
        ptt.make(200 * K), idx, jnp.asarray(csub), jnp.asarray(ck), p, K
    )
    n_opt = int(np.asarray(r.is_new & r.valid).sum())

    keys, _, trunc = operators.naive_ojm_keys(
        jnp.asarray(pk), jnp.asarray(psub), jnp.asarray(csub), jnp.asarray(ck), p, K
    )
    assert not bool(trunc)
    dd = operators.naive_dedup(keys)
    assert n_opt == int(dd.n_unique)

    # python oracle
    pairs = set()
    from collections import defaultdict

    d = defaultdict(set)
    for k, s in zip(pk.tolist(), psub.tolist()):
        d[k].add(s)
    for k, s in zip(ck.tolist(), csub.tolist()):
        for ps_ in d.get(k, ()):
            pairs.add((s, ps_))
    assert n_opt == len(pairs)


# ------------------------------------------------------------------ φ model


def test_phi_model_matches_paper_formulas():
    from repro.core.executor import PredicateStats

    st_ = PredicateStats(kind="SOM", n_candidates=1000, n_unique=250)
    assert st_.phi_optimized() == 1000 + 2 * 250
    assert st_.phi_naive() == pytest.approx(1000 + 250 + 1000 * np.log2(1000))

    stj = PredicateStats(
        kind="OJM", n_candidates=4000, n_unique=1000, n_parent=500, n_child=600
    )
    assert stj.phi_optimized() == 2 * 500 + 600 + 4000 + 2 * 1000
    assert stj.phi_naive() == pytest.approx(
        500 * 600 + 4000 + 1000 + 4000 * np.log2(4000)
    )
    # the paper's claim: orders of magnitude fewer operations
    assert stj.phi_naive() / stj.phi_optimized() > 30
