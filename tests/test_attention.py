"""Attention invariants: chunked (flash-style) == dense, SWA ring buffer,
decode == forward, RoPE shift property."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import attention as A


KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("hq,hkv,window", [(8, 2, None), (8, 8, None), (4, 1, 7), (6, 2, 16)])
@pytest.mark.parametrize("chunk", [5, 16])
def test_chunked_matches_dense(hq, hkv, window, chunk):
    cfg_d = A.AttnConfig(d_model=48, n_heads=hq, n_kv=hkv, head_dim=48 // hq,
                         window=window, chunk=None)
    cfg_c = cfg_d._replace(chunk=chunk)
    p = A.init(KEY, cfg_d, jnp.float32)
    x = jax.random.normal(KEY, (2, 33, 48))
    pos = jnp.broadcast_to(jnp.arange(33)[None], (2, 33))
    o1, _ = A.forward(p, cfg_d, x, pos)
    o2, _ = A.forward(p, cfg_c, x, pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_chunked_gradients_match_dense():
    cfg_d = A.AttnConfig(d_model=32, n_heads=4, n_kv=2, head_dim=8, chunk=None)
    cfg_c = cfg_d._replace(chunk=7)
    p = A.init(KEY, cfg_d, jnp.float32)
    x = jax.random.normal(KEY, (2, 20, 32))
    pos = jnp.broadcast_to(jnp.arange(20)[None], (2, 20))

    def loss(p, cfg):
        o, _ = A.forward(p, cfg, x, pos)
        return jnp.sum(o ** 2)

    g1 = jax.grad(lambda p: loss(p, cfg_d))(p)
    g2 = jax.grad(lambda p: loss(p, cfg_c))(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_swa_ring_buffer_evicts_old_tokens():
    """Tokens beyond the window must not influence decode output."""
    cfg = A.AttnConfig(d_model=32, n_heads=4, n_kv=2, head_dim=8, window=4)
    p = A.init(KEY, cfg, jnp.float32)
    xs = jax.random.normal(KEY, (1, 12, 32))

    # run A: tokens 0..11 sequentially
    cache = A.KVCache.zeros(1, 4, cfg, jnp.float32)
    outs_a = []
    for i in range(12):
        o, cache = A.decode_step(p, cfg, cache, xs[:, i:i+1], jnp.int32(i))
        outs_a.append(o)

    # run B: garbage tokens 0..7, then the SAME tokens 8..11
    cache = A.KVCache.zeros(1, 4, cfg, jnp.float32)
    garbage = jax.random.normal(jax.random.PRNGKey(9), (1, 8, 32))
    for i in range(8):
        _, cache = A.decode_step(p, cfg, cache, garbage[:, i:i+1], jnp.int32(i))
    for i in range(8, 12):
        o, cache = A.decode_step(p, cfg, cache, xs[:, i:i+1], jnp.int32(i))
    # after 4 (window) same tokens, the states coincide
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(outs_a[-1]), atol=1e-5
    )


def test_decode_matches_forward_full_attention():
    cfg = A.AttnConfig(d_model=32, n_heads=4, n_kv=4, head_dim=8)
    p = A.init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 10, 32))
    pos = jnp.broadcast_to(jnp.arange(10)[None], (2, 10))
    full, _ = A.forward(p, cfg, x, pos)
    cache = A.KVCache.zeros(2, 10, cfg, jnp.float32)
    outs = []
    for i in range(10):
        o, cache = A.decode_step(p, cfg, cache, x[:, i:i+1], jnp.int32(i))
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), atol=2e-5
    )


def test_rope_relative_shift_property():
    """RoPE: scores depend only on relative positions — shifting all
    positions by a constant leaves q.k inner products unchanged."""
    q = jax.random.normal(KEY, (1, 6, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 2, 16))
    pos = jnp.arange(6)[None, :]
    def scores(shift):
        qr = A.rope(q, pos + shift, 10000.0)
        kr = A.rope(k, pos + shift, 10000.0)
        return jnp.einsum("bshd,bthd->bhst", qr, kr)
    s0 = scores(0)
    s7 = scores(7)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s7), atol=1e-3)
