"""Data substrate: dictionary encoder, sources, batching, neighbor sampler."""

import numpy as np
import pytest

from repro.data import pipeline, sources
from repro.data.encoder import Dictionary, join_columns, render_template
from repro.data.graphs import CSRGraph, NeighborSampler


def test_dictionary_roundtrip_and_cross_column_equality():
    d = Dictionary()
    a = d.encode(np.array(["x", "y", "x", "z"], dtype=object))
    b = d.encode(np.array(["z", "x"], dtype=object))
    assert a[0] == a[2] == b[1]  # same string -> same id across calls
    assert list(d.decode(b)) == ["z", "x"]


def test_join_columns_and_render_template():
    cols = [np.array(["a", "b"], object), np.array(["1", "2"], object)]
    joined = join_columns(cols)
    assert render_template("http://x/{}/y/{}", joined[0]) == "http://x/a/y/1"
    assert render_template("{}", "plain") == "plain"


def test_csv_json_loaders_agree(tmp_path):
    import json

    rows = [{"A": "1", "B": "foo"}, {"A": "2", "B": "bar,baz"}]
    with open(tmp_path / "t.csv", "w") as f:
        f.write('A,B\n1,foo\n2,"bar,baz"\n')
    with open(tmp_path / "t.json", "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    c = sources.load_csv(str(tmp_path / "t.csv"))
    j = sources.load_json(str(tmp_path / "t.json"))
    assert list(c["B"]) == list(j["B"]) == ["foo", "bar,baz"]


def test_batching_pads_and_masks():
    cols = {"x": np.arange(10, dtype=np.int32)}
    bs = list(pipeline.batches(cols, 4))
    assert len(bs) == 3
    assert bs[-1].valid.sum() == 2
    assert all(len(b.arrays["x"]) == 4 for b in bs)
    recon = np.concatenate([b.arrays["x"][b.valid] for b in bs])
    np.testing.assert_array_equal(recon, np.arange(10))


def test_source_cache_loads_once(tmp_path, monkeypatch):
    with open(tmp_path / "t.csv", "w") as f:
        f.write("A\n1\n2\n")
    calls = {"n": 0}
    orig = sources.load_csv

    def counted(path):
        calls["n"] += 1
        return orig(path)

    monkeypatch.setattr(sources, "load_csv", counted)
    from repro.rml.model import LogicalSource

    cache = sources.SourceCache(str(tmp_path))
    src = LogicalSource(path="t.csv")
    cache.get(src)
    cache.get(src)  # paper: parent sources are never re-uploaded
    assert calls["n"] == 1


def test_neighbor_sampler_shapes_and_dedup():
    g = CSRGraph.random(5000, 12, seed=0)
    s = NeighborSampler(g, (15, 10), seed=1)
    out = s.sample(np.arange(128))
    sizes = s.layer_sizes(128)
    assert len(out["node_ids"]) == sum(sizes)
    assert len(out["edge_src"]) == sum(sizes[1:])
    # all real edges reference in-table local node ids
    es = out["edge_src"][out["edge_mask"]]
    ed = out["edge_dst"][out["edge_mask"]]
    n_real = out["node_mask"].sum()
    assert es.max() < n_real and ed.max() < n_real
    # the dedup actually saves (paper's |N_p| -> |S_p|)
    assert out["dedup_ratio"] > 1.1
    # node table unique
    ids = out["node_ids"][out["node_mask"]]
    assert len(np.unique(ids)) == len(ids)
    # seeds first
    np.testing.assert_array_equal(ids[:128], np.arange(128))


def test_sampler_batch_loss_mask_covers_only_seeds():
    g = CSRGraph.random(2000, 8, seed=3)
    s = NeighborSampler(g, (5, 5), seed=0)
    b = s.batch(np.arange(32), d_feat=16, n_classes=4)
    assert b.label_mask.sum() == 32
