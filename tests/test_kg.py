"""repro.kg: store construction, pattern/BGP queries vs the naive set-scan
oracle, .kgz persistence, batched counts, and N-Triples escaping."""

import re

import numpy as np
import pytest

from repro.core.executor import create_kg
from repro.kg import (
    binding_set,
    decode_bindings,
    escape_literal,
    match_counts,
    match_pattern,
    oracle_solve,
    parse_bgp,
    persist,
    solve,
    unescape_literal,
)
from repro.rml import generator
from repro.rml.model import (
    LogicalSource,
    MappingDocument,
    PredicateObjectMap,
    TermMap,
    TriplesMap,
)


def _tables(tb):
    tables = {"csv:child.csv": tb.child}
    if tb.parent is not None:
        tables["csv:parent.csv"] = tb.parent
    return tables


def _store(kind, n=900, dup=0.5, n_poms=2, seed=7, **cfg):
    tb = generator.make_testbed(kind, n, dup, n_poms=n_poms, seed=seed)
    return create_kg(tb.doc, tables=_tables(tb), **cfg).to_store()


def _some_terms(store):
    """A (subject, predicate, object) of an actual triple, rendered."""
    i = store.n_triples // 3
    return (
        store.decode_term(int(store.s[i])),
        store.decode_term(int(store.p[i])),
        store.decode_term(int(store.o[i])),
    )


def _preds(store):
    return sorted({store.decode_term(int(t)) for t in np.unique(store.p)})


@pytest.mark.parametrize("kind", ["SOM", "ORM", "OJM"])
def test_single_patterns_match_oracle_all_masks(kind):
    store = _store(kind)
    s, p, o = _some_terms(store)
    queries = [
        "?s ?p ?o",
        f"{s} ?p ?o",
        f"?s {p} ?o",
        f"?s ?p {o}",
        f"{s} {p} ?o",
        f"?s {p} {o}",
        f"{s} ?p {o}",
        f"{s} {p} {o}",
    ]
    for q in queries:
        pats = parse_bgp(q)
        assert binding_set(store, solve(store, pats)) == oracle_solve(store, pats), q


@pytest.mark.parametrize("kind", ["SOM", "ORM", "OJM"])
def test_bgp_matches_oracle(kind):
    store = _store(kind, n=600, n_poms=4)
    preds = _preds(store)
    s, p, o = _some_terms(store)
    bgps = [
        f"?m {preds[0]} ?a . ?m {preds[1]} ?b",
        f"?m {preds[0]} ?a . ?m {preds[1]} ?b . ?m {preds[-1]} ?c",
        f"?m ?p ?a . ?m {preds[0]} ?a",       # shared var across slots
        f"?m {preds[0]} ?a . ?x {preds[0]} ?a . ?x {preds[1]} ?b",  # 3-hop
    ]
    if len(preds) >= 4:
        bgps.append(
            f"?m {preds[0]} ?a . ?m {preds[1]} ?b . "
            f"?m {preds[2]} ?c . ?m {preds[3]} ?d"
        )
    for q in bgps:
        pats = parse_bgp(q)
        eng = binding_set(store, solve(store, pats))
        assert eng == oracle_solve(store, pats), q


def test_disconnected_and_late_connecting_bgp():
    """Cross-join semantics for genuinely disconnected patterns, and a BGP
    whose two smallest tables are disconnected until the largest pattern
    connects them (join order must prefer connected tables)."""
    store = _store("ORM", n=60, n_poms=2)
    preds = _preds(store)
    rdf_type = "<http://www.w3.org/1999/02/22-rdf-syntax-ns#type>"
    queries = [
        f"?a {preds[0]} ?x . ?b {preds[1]} ?y",             # disconnected
        f"?a {rdf_type} ?x . ?b {rdf_type} ?y . ?a {preds[0]} ?b",
    ]
    for q in queries:
        pats = parse_bgp(q)
        assert binding_set(store, solve(store, pats)) == oracle_solve(store, pats), q


def test_repeated_variable_within_pattern():
    store = _store("SOM")
    pats = parse_bgp("?x ?p ?x")
    assert binding_set(store, solve(store, pats)) == oracle_solve(store, pats)


def test_unknown_constant_yields_empty():
    store = _store("SOM", n=200)
    pats = parse_bgp('?s <http://nowhere.example/p> ?o')
    b = solve(store, pats)
    assert b.n == 0 and binding_set(store, b) == set()
    assert oracle_solve(store, pats) == set()


@pytest.mark.parametrize("kind", ["SOM", "OJM"])
def test_streamed_store_answers_match_eager(kind):
    """Stores built from eager and streamed runs answer identically (term
    ids differ between the runs; decoded bindings must not)."""
    tb = generator.make_testbed(kind, 700, 0.5, n_poms=2, seed=5)
    eager = create_kg(tb.doc, tables=_tables(tb)).to_store()
    streamed = create_kg(
        tb.doc, tables=_tables(tb), stream=True, block_rows=128
    ).to_store()
    assert streamed.n_triples == eager.n_triples
    preds = _preds(eager)
    assert preds == _preds(streamed)
    for q in ["?s ?p ?o", f"?s {preds[0]} ?o",
              f"?m {preds[0]} ?a . ?m {preds[-1]} ?b"]:
        pats = parse_bgp(q)
        assert binding_set(streamed, solve(streamed, pats)) == binding_set(
            eager, solve(eager, pats)
        ), q


@pytest.mark.parametrize("source", ["eager", "stream"])
def test_kgz_roundtrip_preserves_answers(tmp_path, source):
    tb = generator.make_testbed("OJM", 500, 0.5, n_poms=2, seed=2)
    kg = create_kg(tb.doc, tables=_tables(tb), stream=source == "stream")
    store = kg.to_store()
    path = str(tmp_path / "kg.kgz")
    persist.save(store, path)
    loaded = persist.load(path)
    assert loaded.n_triples == store.n_triples
    assert list(loaded.iter_ntriples()) == list(store.iter_ntriples())
    preds = _preds(store)
    for q in ["?s ?p ?o", f"?m {preds[0]} ?a . ?m {preds[-1]} ?b"]:
        pats = parse_bgp(q)
        assert binding_set(loaded, solve(loaded, pats)) == oracle_solve(store, pats)


def test_kgz_version_check(tmp_path):
    store = _store("SOM", n=50)
    path = str(tmp_path / "kg.kgz")
    persist.save(store, path)
    with np.load(path) as z:
        members = {k: z[k] for k in z.files}
    members["meta"] = np.asarray([999, store.n_triples], np.int64)
    with open(path, "wb") as f:
        np.savez(f, **members)
    with pytest.raises(ValueError, match="format v999"):
        persist.load(path)


def test_batched_counts_match_individual_matches():
    store = _store("ORM", n=400, n_poms=3)
    rng = np.random.default_rng(0)
    rows = rng.integers(0, store.n_triples, 128)
    spo = np.stack([store.s[rows], store.p[rows], store.o[rows]], axis=1)
    masks = np.asarray(
        [(1, 1, 0), (0, 1, 1), (1, 0, 0), (0, 0, 1), (1, 0, 1), (0, 1, 0),
         (1, 1, 1), (0, 0, 0)],
        np.int32,
    )[rng.integers(0, 8, 128)]
    queries = np.where(masks == 1, spo, np.int32(-1)).astype(np.int32)
    counts = match_counts(store, queries)
    for q, c in zip(queries, counts):
        ids = [None if t < 0 else int(t) for t in q]
        assert len(match_pattern(store, ids)) == c
        assert c >= 1  # every query was derived from an existing triple


# --------------------------------------------------------------------------
# N-Triples escaping (satellite regression)
# --------------------------------------------------------------------------

HOSTILE = [
    'plain',
    'has "quotes" inside',
    'back\\slash',
    'line\nbreak',
    'carriage\rreturn',
    'tab\there',
    'mixed \\ "x" \n\t\r end',
    'control\x01char and del\x7f',
]


def _hostile_kg():
    table = {
        "ID": np.array([f"r{i}" for i in range(len(HOSTILE))], dtype=object),
        "VAL": np.array(HOSTILE, dtype=object),
    }
    tm = TriplesMap(
        name="T",
        source=LogicalSource(path="t.csv"),
        subject=TermMap(template="http://ex.org/r/{ID}"),
        poms=(
            PredicateObjectMap(
                predicate="http://ex.org/v", object_map=TermMap(reference="VAL")
            ),
        ),
    )
    doc = MappingDocument({"T": tm})
    return create_kg(doc, tables={"csv:t.csv": table})


def test_ntriples_escaping_hostile_literals(tmp_path):
    kg = _hostile_kg()
    out = tmp_path / "kg.nt"
    n = kg.write_ntriples(str(out))
    assert n == len(HOSTILE)
    lines = out.read_text(encoding="utf-8").splitlines()
    # one triple per line: raw newlines/CRs must have been escaped away
    assert len(lines) == len(HOSTILE)
    ntriple = re.compile(
        r'^<[^<>"{}|^`\\\x00-\x20]*> <[^<>"{}|^`\\\x00-\x20]*> '
        r'"(?:[^"\\\n\r\x00-\x1f]|\\[tbnrf"\'\\]|\\u[0-9A-Fa-f]{4})*" \.$'
    )
    for line in lines:
        assert ntriple.match(line), f"invalid N-Triples line: {line!r}"
    joined = "\n".join(lines)
    assert '\\"quotes\\"' in joined
    assert "back\\\\slash" in joined
    assert "line\\nbreak" in joined
    assert "tab\\there" in joined
    assert "control\\u0001char" in joined


def test_escape_unescape_roundtrip():
    for s in HOSTILE:
        assert unescape_literal(escape_literal(s)) == s


def test_kg_decode_shares_escaping_and_queries_hostile_literals():
    """The kg decode path renders the same escaped terms, and an escaped
    literal constant in a query resolves to the right subject."""
    store = _hostile_kg().to_store()
    rendered = {t for line in store.iter_ntriples() for t in [line]}
    assert any("line\\nbreak" in line for line in rendered)
    pats = parse_bgp('?s <http://ex.org/v> "line\\nbreak"')
    rows = decode_bindings(store, solve(store, pats))
    assert rows == [{"?s": "<http://ex.org/r/r3>"}]
    assert binding_set(store, solve(store, pats)) == oracle_solve(store, pats)
