"""repro.kg: store construction, pattern/BGP queries vs the naive set-scan
oracle, .kgz persistence, batched counts, and N-Triples escaping."""

import re

import numpy as np
import pytest

from repro.core.executor import create_kg
from repro.kg import (
    binding_set,
    decode_bindings,
    escape_literal,
    match_counts,
    match_pattern,
    oracle_solve,
    parse_bgp,
    persist,
    solve,
    unescape_literal,
)
from repro.rml import generator
from repro.rml.model import (
    LogicalSource,
    MappingDocument,
    PredicateObjectMap,
    TermMap,
    TriplesMap,
)


def _tables(tb):
    tables = {"csv:child.csv": tb.child}
    if tb.parent is not None:
        tables["csv:parent.csv"] = tb.parent
    return tables


def _store(kind, n=900, dup=0.5, n_poms=2, seed=7, **cfg):
    tb = generator.make_testbed(kind, n, dup, n_poms=n_poms, seed=seed)
    return create_kg(tb.doc, tables=_tables(tb), **cfg).to_store()


def _some_terms(store):
    """A (subject, predicate, object) of an actual triple, rendered."""
    i = store.n_triples // 3
    return (
        store.decode_term(int(store.s[i])),
        store.decode_term(int(store.p[i])),
        store.decode_term(int(store.o[i])),
    )


def _preds(store):
    return sorted({store.decode_term(int(t)) for t in np.unique(store.p)})


@pytest.mark.parametrize("kind", ["SOM", "ORM", "OJM"])
def test_single_patterns_match_oracle_all_masks(kind):
    store = _store(kind)
    s, p, o = _some_terms(store)
    queries = [
        "?s ?p ?o",
        f"{s} ?p ?o",
        f"?s {p} ?o",
        f"?s ?p {o}",
        f"{s} {p} ?o",
        f"?s {p} {o}",
        f"{s} ?p {o}",
        f"{s} {p} {o}",
    ]
    for q in queries:
        pats = parse_bgp(q)
        assert binding_set(store, solve(store, pats)) == oracle_solve(store, pats), q


@pytest.mark.parametrize("kind", ["SOM", "ORM", "OJM"])
def test_bgp_matches_oracle(kind):
    store = _store(kind, n=600, n_poms=4)
    preds = _preds(store)
    s, p, o = _some_terms(store)
    bgps = [
        f"?m {preds[0]} ?a . ?m {preds[1]} ?b",
        f"?m {preds[0]} ?a . ?m {preds[1]} ?b . ?m {preds[-1]} ?c",
        f"?m ?p ?a . ?m {preds[0]} ?a",       # shared var across slots
        f"?m {preds[0]} ?a . ?x {preds[0]} ?a . ?x {preds[1]} ?b",  # 3-hop
    ]
    if len(preds) >= 4:
        bgps.append(
            f"?m {preds[0]} ?a . ?m {preds[1]} ?b . "
            f"?m {preds[2]} ?c . ?m {preds[3]} ?d"
        )
    for q in bgps:
        pats = parse_bgp(q)
        eng = binding_set(store, solve(store, pats))
        assert eng == oracle_solve(store, pats), q


def test_disconnected_and_late_connecting_bgp():
    """Cross-join semantics for genuinely disconnected patterns, and a BGP
    whose two smallest tables are disconnected until the largest pattern
    connects them (join order must prefer connected tables)."""
    store = _store("ORM", n=60, n_poms=2)
    preds = _preds(store)
    rdf_type = "<http://www.w3.org/1999/02/22-rdf-syntax-ns#type>"
    queries = [
        f"?a {preds[0]} ?x . ?b {preds[1]} ?y",             # disconnected
        f"?a {rdf_type} ?x . ?b {rdf_type} ?y . ?a {preds[0]} ?b",
    ]
    for q in queries:
        pats = parse_bgp(q)
        assert binding_set(store, solve(store, pats)) == oracle_solve(store, pats), q


def test_repeated_variable_within_pattern():
    store = _store("SOM")
    pats = parse_bgp("?x ?p ?x")
    assert binding_set(store, solve(store, pats)) == oracle_solve(store, pats)


def test_empty_store_queries_return_no_solutions():
    from repro.data.encoder import Dictionary
    from repro.kg.store import TripleStore

    store = TripleStore.from_kg(Dictionary.from_strings([]), {})
    assert store.n_triples == 0 and store.n_terms == 0
    assert match_counts(store, np.full((4, 3), -1, np.int32)).tolist() == [0] * 4
    pats = parse_bgp('?s ?p ?o . ?s <http://nowhere.example/p> "x"')
    assert solve(store, pats).n == 0
    assert oracle_solve(store, pats) == set()


def test_unknown_constant_yields_empty():
    store = _store("SOM", n=200)
    pats = parse_bgp('?s <http://nowhere.example/p> ?o')
    b = solve(store, pats)
    assert b.n == 0 and binding_set(store, b) == set()
    assert oracle_solve(store, pats) == set()


@pytest.mark.parametrize("kind", ["SOM", "OJM"])
def test_streamed_store_answers_match_eager(kind):
    """Stores built from eager and streamed runs answer identically (term
    ids differ between the runs; decoded bindings must not)."""
    tb = generator.make_testbed(kind, 700, 0.5, n_poms=2, seed=5)
    eager = create_kg(tb.doc, tables=_tables(tb)).to_store()
    streamed = create_kg(
        tb.doc, tables=_tables(tb), stream=True, block_rows=128
    ).to_store()
    assert streamed.n_triples == eager.n_triples
    preds = _preds(eager)
    assert preds == _preds(streamed)
    for q in ["?s ?p ?o", f"?s {preds[0]} ?o",
              f"?m {preds[0]} ?a . ?m {preds[-1]} ?b"]:
        pats = parse_bgp(q)
        assert binding_set(streamed, solve(streamed, pats)) == binding_set(
            eager, solve(eager, pats)
        ), q


@pytest.mark.parametrize("source", ["eager", "stream"])
def test_kgz_roundtrip_preserves_answers(tmp_path, source):
    tb = generator.make_testbed("OJM", 500, 0.5, n_poms=2, seed=2)
    kg = create_kg(tb.doc, tables=_tables(tb), stream=source == "stream")
    store = kg.to_store()
    path = str(tmp_path / "kg.kgz")
    persist.save(store, path)
    loaded = persist.load(path)
    assert loaded.n_triples == store.n_triples
    assert list(loaded.iter_ntriples()) == list(store.iter_ntriples())
    preds = _preds(store)
    for q in ["?s ?p ?o", f"?m {preds[0]} ?a . ?m {preds[-1]} ?b"]:
        pats = parse_bgp(q)
        assert binding_set(loaded, solve(loaded, pats)) == oracle_solve(store, pats)


def _overlap_kg():
    """Mapping whose constant maps render to the same terms as reference /
    template maps: 'hello' appears both as a constant-literal object and as
    a reference column value (under the *same* predicate, so the rendered
    triple itself collides too), and a constant-IRI object equals one of the
    template-built subjects."""
    table = {
        "ID": np.array(["r0", "r1", "r2"], dtype=object),
        "VAL": np.array(["hello", "world", "hello"], dtype=object),
    }
    tm = TriplesMap(
        name="T",
        source=LogicalSource(path="t.csv"),
        subject=TermMap(template="http://ex.org/r/{ID}"),
        poms=(
            PredicateObjectMap(
                predicate="http://ex.org/v", object_map=TermMap(reference="VAL")
            ),
            PredicateObjectMap(
                predicate="http://ex.org/v", object_map=TermMap(constant="hello")
            ),
            PredicateObjectMap(
                predicate="http://ex.org/w",
                object_map=TermMap(constant="http://ex.org/r/r1"),
            ),
        ),
    )
    doc = MappingDocument({"T": tm})
    return create_kg(doc, tables={"csv:t.csv": table})


def test_term_identity_is_rendered_term_across_encodings(tmp_path):
    """The same RDF term produced via different encodings (constant vs
    reference/template) must get ONE term id: constant-bound queries see all
    matching triples, joins unify across encodings, and the rendered-triple
    duplicates collapse (regression for encoding-keyed term identity)."""
    store = _overlap_kg().to_store()
    # r0/r1/r2 each get <v> "hello" via the constant POM; r0 and r2 repeat it
    # via VAL — as a set that is 3 triples, plus "world" and the 3 <w> ones
    assert store.n_triples == 7
    rendered = [store.decode_term(i) for i in range(store.n_terms)]
    assert len(rendered) == len(set(rendered))  # one id per rendered term
    assert rendered.count('"hello"') == 1
    assert sorted(store.iter_ntriples()) == sorted(set(store.iter_ntriples()))
    queries = [
        '?s <http://ex.org/v> "hello"',      # constant must match both encodings
        '?s ?p "hello"',
        '?s <http://ex.org/v> ?o',
        '<http://ex.org/r/r1> ?p ?o',
        # join: ?b bound from a constant-IRI object must unify with the
        # template-encoded subject of the <v> patterns
        '?a <http://ex.org/w> ?b . ?b <http://ex.org/v> ?c',
    ]
    for q in queries:
        pats = parse_bgp(q)
        assert binding_set(store, solve(store, pats)) == oracle_solve(store, pats), q
    pats = parse_bgp('?s <http://ex.org/v> "hello"')
    assert solve(store, pats).n == 3
    # the canonical store round-trips through .kgz unchanged
    path = str(tmp_path / "kg.kgz")
    persist.save(store, path)
    loaded = persist.load(path)
    assert list(loaded.iter_ntriples()) == list(store.iter_ntriples())
    for q in queries:
        pats = parse_bgp(q)
        assert binding_set(loaded, solve(loaded, pats)) == oracle_solve(store, pats), q


def test_kgz_version_check(tmp_path):
    store = _store("SOM", n=50)
    path = str(tmp_path / "kg.kgz")
    persist.save(store, path)
    with np.load(path) as z:
        members = {k: z[k] for k in z.files}
    members["meta"] = np.asarray([999, store.n_triples], np.int64)
    with open(path, "wb") as f:
        np.savez(f, **members)
    with pytest.raises(ValueError, match="format v999"):
        persist.load(path)


def test_kgz_rejects_corrupted_snapshots(tmp_path):
    """A truncated or corrupted permutation must fail loudly at load, never
    silently answer queries wrongly."""
    store = _store("SOM", n=80)
    path = str(tmp_path / "kg.kgz")
    persist.save(store, path)
    with np.load(path) as z:
        members = {k: z[k] for k in z.files}

    def rewrite(**overrides):
        with open(path, "wb") as f:
            np.savez(f, **{**members, **overrides})

    # truncated permutation
    rewrite(perm_spo=members["perm_spo"][:-1])
    with pytest.raises(ValueError, match="perm_spo"):
        persist.load(path)
    # repeated row (still right length, but not a bijection)
    bad = members["perm_osp"].copy()
    bad[0] = bad[1]
    rewrite(perm_osp=bad)
    with pytest.raises(ValueError, match="perm_osp"):
        persist.load(path)
    # huge bogus index (must raise cleanly, not allocate a giant bincount)
    bad = members["perm_spo"].copy()
    bad[0] = np.int32(2**31 - 1)
    rewrite(perm_spo=bad)
    with pytest.raises(ValueError, match="perm_spo"):
        persist.load(path)
    # valid permutation, wrong order: gathered index is unsorted
    rewrite(perm_pos=members["perm_pos"][::-1])
    with pytest.raises(ValueError, match="pos is not sorted"):
        persist.load(path)
    # truncated triple column vs meta
    rewrite(s=members["s"][:-1])
    with pytest.raises(ValueError, match="n_triples"):
        persist.load(path)
    # out-of-range term ids would decode garbage via negative indexing
    bad = members["s"].copy()
    bad[0] = -3
    rewrite(s=bad)
    with pytest.raises(ValueError, match="s ids out of range"):
        persist.load(path)
    bad = members["term_val"].copy()
    bad[0] = np.int32(len(members["dict_off"]))
    rewrite(term_val=bad)
    with pytest.raises(ValueError, match="term_val ids out of range"):
        persist.load(path)
    # non-monotonic string offsets would misalign every decoded term
    bad = members["dict_off"].copy()
    bad[0] = bad[-1] + 1
    rewrite(dict_off=bad)
    with pytest.raises(ValueError, match="dictionary offsets"):
        persist.load(path)
    # pre-canonicalization v1 snapshots may answer queries wrongly: rejected
    rewrite(meta=np.asarray([1, store.n_triples], np.int64))
    with pytest.raises(ValueError, match="format v1"):
        persist.load(path)
    # pristine members still load
    rewrite()
    assert persist.load(path).n_triples == store.n_triples


def test_batched_counts_match_individual_matches():
    store = _store("ORM", n=400, n_poms=3)
    rng = np.random.default_rng(0)
    rows = rng.integers(0, store.n_triples, 128)
    spo = np.stack([store.s[rows], store.p[rows], store.o[rows]], axis=1)
    masks = np.asarray(
        [(1, 1, 0), (0, 1, 1), (1, 0, 0), (0, 0, 1), (1, 0, 1), (0, 1, 0),
         (1, 1, 1), (0, 0, 0)],
        np.int32,
    )[rng.integers(0, 8, 128)]
    queries = np.where(masks == 1, spo, np.int32(-1)).astype(np.int32)
    counts = match_counts(store, queries)
    for q, c in zip(queries, counts):
        ids = [None if t < 0 else int(t) for t in q]
        assert len(match_pattern(store, ids)) == c
        assert c >= 1  # every query was derived from an existing triple


# --------------------------------------------------------------------------
# N-Triples escaping (satellite regression)
# --------------------------------------------------------------------------

HOSTILE = [
    'plain',
    'has "quotes" inside',
    'back\\slash',
    'line\nbreak',
    'carriage\rreturn',
    'tab\there',
    'mixed \\ "x" \n\t\r end',
    'control\x01char and del\x7f',
]


def _hostile_kg():
    table = {
        "ID": np.array([f"r{i}" for i in range(len(HOSTILE))], dtype=object),
        "VAL": np.array(HOSTILE, dtype=object),
    }
    tm = TriplesMap(
        name="T",
        source=LogicalSource(path="t.csv"),
        subject=TermMap(template="http://ex.org/r/{ID}"),
        poms=(
            PredicateObjectMap(
                predicate="http://ex.org/v", object_map=TermMap(reference="VAL")
            ),
        ),
    )
    doc = MappingDocument({"T": tm})
    return create_kg(doc, tables={"csv:t.csv": table})


def test_ntriples_escaping_hostile_literals(tmp_path):
    kg = _hostile_kg()
    out = tmp_path / "kg.nt"
    n = kg.write_ntriples(str(out))
    assert n == len(HOSTILE)
    lines = out.read_text(encoding="utf-8").splitlines()
    # one triple per line: raw newlines/CRs must have been escaped away
    assert len(lines) == len(HOSTILE)
    ntriple = re.compile(
        r'^<[^<>"{}|^`\\\x00-\x20]*> <[^<>"{}|^`\\\x00-\x20]*> '
        r'"(?:[^"\\\n\r\x00-\x1f]|\\[tbnrf"\'\\]|\\u[0-9A-Fa-f]{4})*" \.$'
    )
    for line in lines:
        assert ntriple.match(line), f"invalid N-Triples line: {line!r}"
    joined = "\n".join(lines)
    assert '\\"quotes\\"' in joined
    assert "back\\\\slash" in joined
    assert "line\\nbreak" in joined
    assert "tab\\there" in joined
    assert "control\\u0001char" in joined


def test_escape_unescape_roundtrip():
    for s in HOSTILE:
        assert unescape_literal(escape_literal(s)) == s


def test_kg_decode_shares_escaping_and_queries_hostile_literals():
    """The kg decode path renders the same escaped terms, and an escaped
    literal constant in a query resolves to the right subject."""
    store = _hostile_kg().to_store()
    rendered = {t for line in store.iter_ntriples() for t in [line]}
    assert any("line\\nbreak" in line for line in rendered)
    pats = parse_bgp('?s <http://ex.org/v> "line\\nbreak"')
    rows = decode_bindings(store, solve(store, pats))
    assert rows == [{"?s": "<http://ex.org/r/r3>"}]
    assert binding_set(store, solve(store, pats)) == oracle_solve(store, pats)
