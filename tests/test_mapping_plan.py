"""Mapping-level planner: column-set extraction, rule-group partitioning,
the planner-on == planner-off byte-identity property (eager, streamed, and
sharded), strict pushdown failure semantics, and the explain surface."""

import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # test image without hypothesis: seeded-example fallback
    from _hypothesis_shim import given, settings, st

from repro.core.executor import create_kg
from repro.rml import generator, parser, serializer
from repro.rml.plan import build_plan

EX = "http://example.com/"

WIDE_TTL = """
@prefix rr: <http://www.w3.org/ns/r2rml#> .
@prefix rml: <http://semweb.mmlab.be/ns/rml#> .
@prefix ql: <http://semweb.mmlab.be/ns/ql#> .
@prefix ex: <http://example.com/> .

ex:GeneMap a rr:TriplesMap ;
  rml:logicalSource [ rml:source "gene.csv" ; rml:referenceFormulation ql:CSV ] ;
  rr:subjectMap [ rr:template "http://example.com/gene/{GENE_ID}" ; rr:class ex:Gene ] ;
  rr:predicateObjectMap [ rr:predicate ex:name ; rr:objectMap [ rml:reference "GENE_NAME" ] ] ;
  rr:predicateObjectMap [ rr:predicate ex:label ; rr:objectMap [ rr:template "http://example.com/lbl/{GENE_ID}" ] ] .

ex:MutMap a rr:TriplesMap ;
  rml:logicalSource [ rml:source "mut.csv" ; rml:referenceFormulation ql:CSV ] ;
  rr:subjectMap [ rr:template "http://example.com/mut/{MUT_ID}" ] ;
  rr:predicateObjectMap [ rr:predicate ex:inGene ;
    rr:objectMap [ rr:parentTriplesMap ex:GeneMap ;
                   rr:joinCondition [ rr:child "GENE" ; rr:parent "GENE_ID" ] ] ] .

ex:OtherMap a rr:TriplesMap ;
  rml:logicalSource [ rml:source "other.csv" ; rml:referenceFormulation ql:CSV ] ;
  rr:subjectMap [ rr:template "http://example.com/o/{OID}" ] ;
  rr:predicateObjectMap [ rr:predicate ex:val ; rr:objectMap [ rml:reference "V" ] ] .
"""


def _write_wide_testbed(out_dir, n_genes=120, n_muts=200, n_junk=8, seed=0):
    """gene.csv carries ``n_junk`` never-mapped columns — the pushdown
    target; mut.csv joins into it; other.csv is source-disjoint."""
    rng = np.random.default_rng(seed)
    with open(os.path.join(out_dir, "gene.csv"), "w") as f:
        junk_hdr = ",".join(f"JUNK{j}" for j in range(n_junk))
        f.write(f"GENE_ID,GENE_NAME,{junk_hdr}\n")
        for i in range(n_genes):
            junk = ",".join(f"j{i}_{j}" for j in range(n_junk))
            f.write(f"g{i},name{i % 37},{junk}\n")
    with open(os.path.join(out_dir, "mut.csv"), "w") as f:
        f.write("MUT_ID,GENE\n")
        for i in range(n_muts):
            f.write(f"m{i},g{rng.integers(0, int(n_genes * 1.2))}\n")
    with open(os.path.join(out_dir, "other.csv"), "w") as f:
        f.write("OID,V\n")
        for i in range(40):
            f.write(f"o{i},v{i % 5}\n")


# ---------------------------------------------------------------------------
# column-set extraction (one case per object-map kind)
# ---------------------------------------------------------------------------


def _plan_for(ttl):
    return build_plan(parser.parse(ttl))


def test_columns_template_subject_and_reference_object():
    plan = _plan_for(WIDE_TTL)
    sp = plan.sources["csv:gene.csv"]
    assert sp.columns == ("GENE_ID", "GENE_NAME")
    assert sp.strict


def test_columns_join_child_and_parent():
    plan = _plan_for(WIDE_TTL)
    assert plan.sources["csv:mut.csv"].columns == ("GENE", "MUT_ID")
    # the parent side needs join column + subject columns, nothing else
    assert "GENE_ID" in plan.sources["csv:gene.csv"].columns


def test_columns_class_and_constant_read_nothing():
    ttl = """
@prefix rr: <http://www.w3.org/ns/r2rml#> .
@prefix rml: <http://semweb.mmlab.be/ns/rml#> .
@prefix ql: <http://semweb.mmlab.be/ns/ql#> .
@prefix ex: <http://example.com/> .
ex:M a rr:TriplesMap ;
  rml:logicalSource [ rml:source "t.csv" ; rml:referenceFormulation ql:CSV ] ;
  rr:subjectMap [ rr:template "http://example.com/{ID}" ; rr:class ex:Thing ] ;
  rr:predicateObjectMap [ rr:predicate ex:tag ; rr:objectMap [ rr:constant "fixed" ] ] .
"""
    plan = _plan_for(ttl)
    # CLASS + constant objects contribute no columns beyond the subject's
    assert plan.sources["csv:t.csv"].columns == ("ID",)


def test_columns_multi_placeholder_template():
    ttl = """
@prefix rr: <http://www.w3.org/ns/r2rml#> .
@prefix rml: <http://semweb.mmlab.be/ns/rml#> .
@prefix ql: <http://semweb.mmlab.be/ns/ql#> .
@prefix ex: <http://example.com/> .
ex:M a rr:TriplesMap ;
  rml:logicalSource [ rml:source "t.csv" ; rml:referenceFormulation ql:CSV ] ;
  rr:subjectMap [ rr:template "http://example.com/{A}/{B}" ] ;
  rr:predicateObjectMap [ rr:predicate ex:p ; rr:objectMap [ rr:template "http://example.com/x/{C}-{D}" ] ] .
"""
    plan = _plan_for(ttl)
    assert plan.sources["csv:t.csv"].columns == ("A", "B", "C", "D")


def test_columns_orm_shared_source():
    tb = generator.make_testbed("ORM", 50, 0.25, n_poms=1, seed=1)
    plan = build_plan(tb.doc)
    src = next(iter(plan.sources.values()))
    # ORM: child subject columns + parent subject columns, one source
    assert len(plan.sources) == 1
    assert len(src.columns) >= 2


def test_json_sources_are_tolerant():
    ttl = WIDE_TTL.replace(
        'rml:source "other.csv" ; rml:referenceFormulation ql:CSV',
        'rml:source "other.json" ; rml:referenceFormulation ql:JSONPath',
    )
    plan = _plan_for(ttl)
    assert not plan.sources["json:other.json"].strict
    assert plan.sources["csv:gene.csv"].strict


# ---------------------------------------------------------------------------
# shared-term factoring and rule groups
# ---------------------------------------------------------------------------


def test_shared_subject_template_is_factored():
    plan = _plan_for(WIDE_TTL)
    # GENE_ID feeds: GeneMap subject (x3 rules: class/name/label), the label
    # object template, the PJTT key and the PJTT subject -> one shared term
    sh = plan.shared[("csv:gene.csv", ("GENE_ID",))]
    assert sh.n_uses >= 4
    assert any("gene/" in p for p in sh.patterns)  # canonical subj pattern


def test_unshared_terms_are_not_factored():
    plan = _plan_for(WIDE_TTL)
    # GENE_NAME is referenced by exactly one rule
    assert ("csv:gene.csv", ("GENE_NAME",)) not in plan.shared


def test_groups_split_independent_maps():
    plan = _plan_for(WIDE_TTL)
    assert len(plan.groups) == 2
    g0, g1 = plan.groups
    # join dependency keeps GeneMap and MutMap together
    assert set(g0.triples_maps) == {"ex:GeneMap", "ex:MutMap"}
    assert g1.triples_maps == ("ex:OtherMap",)
    # groups are disjoint in predicates and sources
    assert not set(g0.predicates) & set(g1.predicates)
    assert not set(g0.sources) & set(g1.sources)
    assert plan.group_of_predicate(EX + "val").index == 1


def test_groups_merge_on_shared_source():
    ttl = WIDE_TTL.replace('rml:source "other.csv"', 'rml:source "gene.csv"')
    plan = _plan_for(ttl)
    assert len(plan.groups) == 1


def test_groups_merge_on_shared_predicate():
    # PTT dedup state is per predicate: two maps emitting ex:name must
    # land in one group even with disjoint sources
    ttl = WIDE_TTL.replace("ex:val", "ex:name")
    plan = _plan_for(ttl)
    assert len(plan.groups) == 1


# ---------------------------------------------------------------------------
# the hard bar: byte-identical output, planner on vs off
# ---------------------------------------------------------------------------


def _nt(doc, data_root, **opts):
    return create_kg(doc, data_root=data_root, **opts).sorted_ntriples()


@settings(max_examples=4, deadline=None)
@given(
    n_genes=st.integers(min_value=3, max_value=150),
    n_junk=st.integers(min_value=0, max_value=12),
    block_rows=st.sampled_from([16, 1024]),
    seed=st.integers(min_value=0, max_value=999),
)
def test_planner_identity_property(n_genes, n_junk, block_rows, seed):
    """Random wide-source mappings with shared templates: planner on and
    off produce byte-identical KGs, eager and streamed."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        _write_wide_testbed(
            d, n_genes=n_genes, n_muts=2 * n_genes, n_junk=n_junk, seed=seed
        )
        doc = parser.parse(WIDE_TTL)
        ref = _nt(doc, d, mapping_plan=False)
        assert _nt(doc, d, mapping_plan=True) == ref
        assert _nt(doc, d, mapping_plan=True, stream=True,
                   block_rows=block_rows) == ref
        assert _nt(doc, d, mapping_plan=False, stream=True,
                   block_rows=block_rows) == ref


@pytest.mark.parametrize("kind", ["SOM", "ORM", "OJM"])
def test_planner_identity_on_generator_testbeds(kind, tmp_path):
    tb = generator.make_testbed(kind, 700, 0.5, n_poms=2, seed=7)
    tb.write(str(tmp_path))
    ref = _nt(tb.doc, str(tmp_path), mapping_plan=False)
    assert _nt(tb.doc, str(tmp_path), mapping_plan=True) == ref
    assert _nt(tb.doc, str(tmp_path), mapping_plan=True, stream=True,
               block_rows=128) == ref


def test_planner_identity_sharded(tmp_path):
    """Group-parallel sharded build == monolithic sharded build, down to
    the shard .kgz bytes."""
    from repro.shard.ingest import ingest_mapping_sharded, shard_store

    _write_wide_testbed(str(tmp_path), n_genes=80, n_muts=150)
    doc = parser.parse(WIDE_TTL)
    mono = create_kg(doc, data_root=str(tmp_path), mapping_plan=False)
    shard_store(mono.to_store(), str(tmp_path / "mono.shards.json"), 2)
    ingest_mapping_sharded(
        WIDE_TTL, str(tmp_path), str(tmp_path / "grp.shards.json"), 2,
        workers=0, engine_opts=dict(stream=True, block_rows=64),
    )
    for i in range(2):
        a = (tmp_path / f"mono.shard{i}.kgz").read_bytes()
        b = (tmp_path / f"grp.shard{i}.kgz").read_bytes()
        assert a == b


def test_factoring_actually_happens(tmp_path):
    """plan.factored_rows counts cache-served slots; output is unchanged."""
    from repro import obs

    _write_wide_testbed(str(tmp_path))
    doc = parser.parse(WIDE_TTL)
    reg = obs.get_registry()
    reg.reset()
    on = _nt(doc, str(tmp_path), mapping_plan=True, stream=True)
    factored = reg.counter("plan.factored_rows").value
    assert factored > 0
    assert reg.counter("plan.columns_pruned").value > 0
    assert reg.gauge("plan.groups").value == 2
    reg.reset()
    off = _nt(doc, str(tmp_path), mapping_plan=False, stream=True)
    assert reg.counter("plan.factored_rows").value == 0
    assert on == off


# ---------------------------------------------------------------------------
# strict pushdown: missing mapped columns fail loudly at read time
# ---------------------------------------------------------------------------


def test_missing_mapped_column_raises_at_read(tmp_path):
    _write_wide_testbed(str(tmp_path))
    doc = parser.parse(WIDE_TTL.replace('rml:reference "V"',
                                        'rml:reference "NO_SUCH"'))
    with pytest.raises(KeyError, match="NO_SUCH"):
        create_kg(doc, data_root=str(tmp_path), mapping_plan=True,
                  stream=True)
    # planner-off keeps the same strict behavior via the downstream Project
    with pytest.raises(KeyError):
        create_kg(doc, data_root=str(tmp_path), mapping_plan=False,
                  stream=True)


def test_pushdown_prunes_csv_columns(tmp_path):
    """The reader accounts kept/pruned columns only when pushdown fires."""
    from repro import obs

    _write_wide_testbed(str(tmp_path), n_junk=6)
    doc = parser.parse(WIDE_TTL)
    reg = obs.get_registry()
    reg.reset()
    create_kg(doc, data_root=str(tmp_path), mapping_plan=True, stream=True)
    assert reg.counter("plan.columns_pruned").value >= 6
    reg.reset()
    create_kg(doc, data_root=str(tmp_path), mapping_plan=False, stream=True)
    assert reg.counter("plan.columns_pruned").value == 0


# ---------------------------------------------------------------------------
# explain surface
# ---------------------------------------------------------------------------


def test_explain_mapping_api(tmp_path):
    from repro import api

    _write_wide_testbed(str(tmp_path), n_junk=3)
    (tmp_path / "map.ttl").write_text(WIDE_TTL)
    tree = api.explain_mapping(str(tmp_path / "map.ttl"),
                               data_root=str(tmp_path))
    assert "mapping plan: " in tree and "-> 2 groups" in tree
    assert "pruned [JUNK0, JUNK1, JUNK2]" in tree
    assert "PJTT ex:GeneMap on GENE_ID" in tree
    assert "factored terms" in tree
    # also accepts a parsed document (no header peek -> kept only)
    tree2 = api.explain_mapping(parser.parse(WIDE_TTL))
    assert "kept [GENE_ID, GENE_NAME]" in tree2


def test_explain_mapping_cli(tmp_path, capsys, monkeypatch):
    from repro.launch import rdfize

    _write_wide_testbed(str(tmp_path))
    (tmp_path / "map.ttl").write_text(WIDE_TTL)
    monkeypatch.setattr(
        "sys.argv",
        ["rdfize", "--mapping", str(tmp_path / "map.ttl"),
         "--data-root", str(tmp_path), "--explain-mapping"],
    )
    rdfize.main()
    out = capsys.readouterr().out
    assert "mapping plan: " in out and "rules" in out
    assert "group 0" in out and "group 1" in out


def test_cli_no_mapping_plan_flag(tmp_path, capsys, monkeypatch):
    from repro.launch import rdfize

    tb = generator.make_testbed("SOM", 120, 0.25, n_poms=1)
    tb.write(str(tmp_path))
    serializer.write_turtle(tb.doc, str(tmp_path / "map.ttl"))
    out_nt = tmp_path / "kg.nt"
    monkeypatch.setattr(
        "sys.argv",
        ["rdfize", "--mapping", str(tmp_path / "map.ttl"),
         "--data-root", str(tmp_path), "--out", str(out_nt),
         "--no-mapping-plan"],
    )
    rdfize.main()
    out = capsys.readouterr().out
    assert "plan:" not in out  # summary line suppressed when disabled
    assert out_nt.read_text().count("\n") > 0


def test_cli_plan_summary_line(tmp_path, capsys, monkeypatch):
    from repro.launch import rdfize

    _write_wide_testbed(str(tmp_path))
    (tmp_path / "map.ttl").write_text(WIDE_TTL)
    monkeypatch.setattr(
        "sys.argv",
        ["rdfize", "--mapping", str(tmp_path / "map.ttl"),
         "--data-root", str(tmp_path)],
    )
    rdfize.main()
    assert "plan: 5 rules over 3 sources -> 2 groups" in \
        capsys.readouterr().out
