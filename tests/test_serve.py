"""repro.serve: algebra parsing, planner/executor vs the full-algebra
oracle (property tests over random graphs), deterministic result ordering,
capacity feedback, the open_store cache, and the batching socket server."""

import json
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # test image without hypothesis: seeded-example fallback
    from _hypothesis_shim import given, settings, st

from repro.core.executor import create_kg
from repro.kg import persist, solve, parse_bgp
from repro.kg.store import TripleStore
from repro.rml import generator
from repro.serve import (
    get_executor,
    oracle_select,
    parse_select,
    solve_select,
)
from repro.serve.algebra import SelectQuery


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

SUBS = [f"<http://ex/s{i}>" for i in range(5)]
PREDS = [f"<http://ex/p{i}>" for i in range(3)]
LITS = ['"1"', '"2"', '"10"', '"2.5"', '"-3"', '"abc"', '"b c"', '""']
OBJS = SUBS[:2] + LITS


def rand_store(seed: int, n_triples: int) -> TripleStore:
    rng = np.random.default_rng(seed)
    triples = {
        (
            SUBS[rng.integers(0, len(SUBS))],
            PREDS[rng.integers(0, len(PREDS))],
            OBJS[rng.integers(0, len(OBJS))],
        )
        for _ in range(n_triples)
    }
    return TripleStore.from_ntriples(sorted(triples))


def check(store: TripleStore, qtext: str) -> None:
    q = parse_select(qtext)
    got = solve_select(store, q).rows(0)
    want = oracle_select(store, q)
    assert got == want, f"{qtext}\n got: {got}\nwant: {want}"


def _tables(tb):
    tables = {"csv:child.csv": tb.child}
    if tb.parent is not None:
        tables["csv:parent.csv"] = tb.parent
    return tables


# --------------------------------------------------------------------------
# parser
# --------------------------------------------------------------------------


def test_parse_select_forms():
    q = parse_select(
        'SELECT DISTINCT ?a ?b WHERE { ?a <http://p> ?b . '
        'OPTIONAL { ?b <http://q> ?c } FILTER(?c > 3) } LIMIT 7'
    )
    assert q.select == ("?a", "?b") and q.distinct and q.limit == 7
    assert len(q.patterns) == 1 and len(q.optionals) == 1
    assert q.out_vars() == ("?a", "?b")
    # bare BGP shorthand
    q2 = parse_select('?s <http://p> ?o . ?o <http://q> "v"')
    assert q2.select is None and len(q2.patterns) == 2
    assert q2.out_vars() == ("?s", "?o")
    # SELECT * covers optional-only variables too
    q3 = parse_select(
        "SELECT * WHERE { ?a <http://p> ?b OPTIONAL { ?a <http://q> ?c } }"
    )
    assert q3.out_vars() == ("?a", "?b", "?c")


def test_parse_filter_grammar():
    q = parse_select(
        "SELECT * WHERE { ?a <http://p> ?b "
        'FILTER(!bound(?c) && (?b >= 2 || ?b = "x")) }'
    )
    assert len(q.filters) == 1
    # signature abstracts constants but keeps their kind
    q2 = parse_select(
        "SELECT * WHERE { ?a <http://p> ?b "
        'FILTER(!bound(?c) && (?b >= 9 || ?b = "y")) }'
    )
    assert q.signature() == q2.signature()
    q3 = parse_select(
        "SELECT * WHERE { ?a <http://p> ?b "
        "FILTER(!bound(?c) && (?b >= 9 || ?b = <http://x>)) }"
    )
    assert q.signature() != q3.signature()


def test_parse_union_group_order_forms():
    q = parse_select(
        "SELECT ?s ?x WHERE { ?s <http://p> ?v "
        "{ ?s <http://q> ?x } UNION { ?s <http://r> ?x } } ORDER BY DESC(?x)"
    )
    assert len(q.unions) == 2 and q.order_by == (("?x", False),)
    assert q.scope() == ("?s", "?v", "?x")
    assert q.union_always_vars() == {"?s", "?x"}
    # partial arm vars are tracked for validation
    q2 = parse_select(
        "SELECT * WHERE { { ?s <http://q> ?x } UNION { ?s <http://r> ?y } }"
    )
    assert q2.union_partial_vars() == {"?x", "?y"}
    assert q2.out_vars() == ("?s", "?x", "?y")
    # aggregates: alias rides at its SELECT position
    q3 = parse_select(
        "SELECT ?g (COUNT(?m) AS ?n) WHERE { ?m <http://p> ?g } "
        "GROUP BY ?g ORDER BY DESC(?n) ?g LIMIT 4"
    )
    assert q3.agg.var == "?m" and q3.agg.alias == "?n"
    assert q3.out_vars() == ("?g", "?n") and q3.group_by == ("?g",)
    assert q3.order_by == (("?n", False), ("?g", True))
    q4 = parse_select("SELECT (COUNT(*) AS ?n) WHERE { ?s ?p ?o }")
    assert q4.agg.var is None and q4.out_vars() == ("?n",)
    # signatures: constants abstracted, structure (arms/keys/dirs) kept
    a = parse_select(
        'SELECT * WHERE { { ?s <http://a> "1" } UNION { ?s <http://b> ?o } }'
    )
    b = parse_select(
        'SELECT * WHERE { { ?s <http://zz> "9" } UNION { ?s <http://b> ?o } }'
    )
    assert a.signature() == b.signature()
    c = parse_select(
        "SELECT * WHERE { { ?s <http://a> ?o } UNION { ?s <http://b> ?o } }"
    )
    assert a.signature() != c.signature()
    up = parse_select("SELECT ?s ?o WHERE { ?s <http://p> ?o } ORDER BY ?o")
    down = parse_select("SELECT ?s ?o WHERE { ?s <http://p> ?o } ORDER BY DESC(?o)")
    assert up.signature() != down.signature()


def test_parse_errors():
    for bad in (
        "SELECT WHERE { ?s <http://p> ?o }",            # no var list
        "SELECT * WHERE { }",                           # empty group
        "SELECT * WHERE { ?s <http://p> ?o } LIMIT -1", # bad limit
        "SELECT * WHERE { OPTIONAL { } ?s <http://p> ?o }",
        "SELECT * WHERE { ?s <http://p> ?o FILTER(3 < 4) }",  # no variable
        "SELECT * WHERE { ?s <http://p> ?o FILTER(?s < <http://x>) }",
        "SELECT * WHERE { ?s <http://p> ?o } trailing",
        "SELECT * WHERE { { ?s <http://p> ?o } }",        # 1-arm brace
        "SELECT * WHERE { { } UNION { ?s <http://p> ?o } }",
        "SELECT ?o WHERE { ?s ?p ?o } GROUP BY ?s",       # non-key selected
        "SELECT * WHERE { ?s ?p ?o } GROUP BY ?s",        # * with GROUP BY
        "SELECT DISTINCT (COUNT(*) AS ?n) WHERE { ?s ?p ?o }",
        "SELECT (COUNT(*) AS ?o) WHERE { ?s ?p ?o }",     # alias collision
        "SELECT (COUNT(?x) AS ?a) (COUNT(*) AS ?b) WHERE { ?s ?p ?o }",
        "SELECT ?s WHERE { ?s <http://p> ?o } ORDER BY ?o",  # key not projected
        "SELECT ?s WHERE { ?s <http://p> ?o } ORDER BY",
    ):
        with pytest.raises(ValueError):
            parse_select(bad)
    # OPTIONAL may not join on a variable bound in only SOME union arms
    with pytest.raises(ValueError, match="OPTIONAL groups"):
        parse_select(
            "SELECT * WHERE { { ?s <http://q> ?x } UNION { ?s <http://r> ?y } "
            "OPTIONAL { ?x <http://t> ?z } }"
        )
    # ...but a variable bound in EVERY arm is fine
    parse_select(
        "SELECT * WHERE { { ?s <http://q> ?x } UNION { ?s <http://r> ?x } "
        "OPTIONAL { ?x <http://t> ?z } }"
    )
    # optional groups may not share optional-only variables
    with pytest.raises(ValueError, match="OPTIONAL groups"):
        parse_select(
            "SELECT * WHERE { ?s <http://p> ?o "
            "OPTIONAL { ?s <http://q> ?x } OPTIONAL { ?s <http://r> ?x } }"
        )


# --------------------------------------------------------------------------
# hand-built graphs: OPTIONAL / FILTER semantics
# --------------------------------------------------------------------------


def _small_store() -> TripleStore:
    return TripleStore.from_ntriples(
        [
            ("<http://ex/s1>", "<http://ex/p>", '"10"'),
            ("<http://ex/s2>", "<http://ex/p>", '"3"'),
            ("<http://ex/s3>", "<http://ex/p>", '"abc"'),
            ("<http://ex/s1>", "<http://ex/q>", '"hi"'),
            ("<http://ex/s1>", "<http://ex/r>", "<http://ex/s2>"),
        ]
    )


def test_optional_backfills_unbound():
    store = _small_store()
    q = parse_select(
        "SELECT * WHERE { ?s <http://ex/p> ?v "
        "OPTIONAL { ?s <http://ex/q> ?h } }"
    )
    rows = solve_select(store, q).rows(0)
    assert rows == oracle_select(store, q)
    by_s = {r[0]: r[2] for r in rows}
    assert by_s["<http://ex/s1>"] == '"hi"'
    assert by_s["<http://ex/s2>"] is None and by_s["<http://ex/s3>"] is None


def test_filter_semantics_numeric_string_bound():
    store = _small_store()
    for qtext in (
        # numeric: "abc" errors out to false; 3 < 10 both pass ">2"? no: 3,10
        "SELECT * WHERE { ?s <http://ex/p> ?v FILTER(?v > 3) }",
        "SELECT * WHERE { ?s <http://ex/p> ?v FILTER(?v <= 10) }",
        "SELECT * WHERE { ?s <http://ex/p> ?v FILTER(?v = 10) }",
        "SELECT * WHERE { ?s <http://ex/p> ?v FILTER(?v != 3) }",
        # string order compares raw bodies ("10" < "3" as strings)
        'SELECT * WHERE { ?s <http://ex/p> ?v FILTER(?v < "3") }',
        'SELECT * WHERE { ?s <http://ex/p> ?v FILTER(?v >= "abc") }',
        # term identity, including a constant absent from the store
        'SELECT * WHERE { ?s <http://ex/p> ?v FILTER(?v = "abc") }',
        'SELECT * WHERE { ?s <http://ex/p> ?v FILTER(?v != "nope") }',
        # bound() over an OPTIONAL miss, negation, conjunction
        "SELECT * WHERE { ?s <http://ex/p> ?v "
        "OPTIONAL { ?s <http://ex/q> ?h } FILTER(bound(?h)) }",
        "SELECT * WHERE { ?s <http://ex/p> ?v "
        "OPTIONAL { ?s <http://ex/q> ?h } FILTER(!bound(?h) && ?v < 5) }",
        # var-vs-var: numeric pairs compare numerically, mixed are false
        "SELECT * WHERE { ?a <http://ex/p> ?x . ?b <http://ex/p> ?y "
        "FILTER(?x < ?y) }",
        "SELECT * WHERE { ?a <http://ex/p> ?x . ?b <http://ex/p> ?y "
        "FILTER(?x = ?y) }",
        # iri equality against a variable bound to an iri
        "SELECT * WHERE { ?s <http://ex/r> ?t FILTER(?t = <http://ex/s2>) }",
    ):
        check(store, qtext)


def test_filter_on_never_bound_variable():
    store = _small_store()
    check(store, "SELECT * WHERE { ?s <http://ex/p> ?v FILTER(bound(?zz)) }")
    check(store, "SELECT * WHERE { ?s <http://ex/p> ?v FILTER(!bound(?zz)) }")
    check(store, "SELECT * WHERE { ?s <http://ex/p> ?v FILTER(?zz > 1) }")


def test_projection_keeps_duplicates_and_unknown_vars():
    store = _small_store()
    # three subjects share predicate p: projecting ?p keeps multiplicity
    q = parse_select("SELECT ?p WHERE { ?s ?p ?v }")
    rows = solve_select(store, q).rows(0)
    assert rows == oracle_select(store, q)
    assert len(rows) == store.n_triples  # duplicates preserved
    # an unknown projected variable is unbound everywhere
    check(store, "SELECT ?s ?nope WHERE { ?s <http://ex/p> ?v }")
    # DISTINCT collapses
    check(store, "SELECT DISTINCT ?p WHERE { ?s ?p ?v }")
    check(store, "SELECT DISTINCT ?p WHERE { ?s ?p ?v } LIMIT 2")


def test_multi_pattern_optional_group():
    store = _small_store()
    # two-pattern OPTIONAL group evaluates as a unit: both must match
    check(
        store,
        "SELECT * WHERE { ?s <http://ex/p> ?v OPTIONAL { "
        "?s <http://ex/q> ?h . ?s <http://ex/r> ?t } }",
    )


def test_union_semantics():
    store = _small_store()
    for qtext in (
        # plain 2-arm union over one variable
        "SELECT * WHERE { { ?s <http://ex/p> ?v } UNION "
        "{ ?s <http://ex/q> ?v } }",
        # partial-arm variables come back unbound in the other arm's rows
        "SELECT * WHERE { { ?s <http://ex/q> ?h } UNION "
        "{ ?s <http://ex/r> ?t } }",
        # union joined with a required pattern (shared-scan arms)
        "SELECT * WHERE { ?s <http://ex/p> ?v "
        "{ ?s <http://ex/q> ?h } UNION { ?s <http://ex/r> ?t } }",
        # three arms; duplicate solutions keep bag multiplicity
        "SELECT ?s WHERE { { ?s <http://ex/p> ?v } UNION "
        "{ ?s <http://ex/p> ?v } UNION { ?s <http://ex/q> ?h } }",
        # an arm whose constant the store has never seen is empty
        "SELECT * WHERE { { ?s <http://ex/none> ?v } UNION "
        "{ ?s <http://ex/q> ?v } }",
        # filters over arm-bound variables apply after the union
        'SELECT * WHERE { { ?s <http://ex/p> ?v } UNION '
        '{ ?s <http://ex/q> ?v } FILTER(?v >= "hi" || ?v <= 3) }',
        # bound() distinguishes which arm produced a row
        "SELECT * WHERE { { ?s <http://ex/q> ?h } UNION "
        "{ ?s <http://ex/r> ?t } FILTER(bound(?h)) }",
        # DISTINCT collapses cross-arm duplicates
        "SELECT DISTINCT ?s WHERE { { ?s <http://ex/p> ?v } UNION "
        "{ ?s <http://ex/q> ?h } }",
        # OPTIONAL over a variable bound in every arm
        "SELECT * WHERE { { ?s <http://ex/p> ?v } UNION "
        "{ ?s <http://ex/q> ?v } OPTIONAL { ?s <http://ex/r> ?t } }",
    ):
        check(store, qtext)


def test_orderby_value_typed_not_term_order():
    store = _small_store()
    # term-id (rendered) order puts "10" before "3"; value order must not
    q = parse_select(
        "SELECT ?s ?v WHERE { ?s <http://ex/p> ?v } ORDER BY ?v"
    )
    rows = solve_select(store, q).rows(0)
    assert rows == oracle_select(store, q)
    vals = [r[1] for r in rows]
    assert vals == ['"3"', '"10"', '"abc"']  # 3 < 10 numerically, "abc" last
    # DESC reverses the whole key, unbound (OPTIONAL miss) sorts last
    for qtext in (
        "SELECT ?s ?v WHERE { ?s <http://ex/p> ?v } ORDER BY DESC(?v)",
        "SELECT ?s ?h WHERE { ?s <http://ex/p> ?v "
        "OPTIONAL { ?s <http://ex/q> ?h } } ORDER BY ?h",
        "SELECT ?s ?h WHERE { ?s <http://ex/p> ?v "
        "OPTIONAL { ?s <http://ex/q> ?h } } ORDER BY DESC(?h)",
        # multi-key with mixed directions; LIMIT takes the top-k
        "SELECT ?s ?v WHERE { ?s ?p ?v } ORDER BY DESC(?v) ?s LIMIT 3",
        # iris order by rendered term
        "SELECT ?t WHERE { ?s <http://ex/r> ?t } ORDER BY DESC(?t)",
    ):
        check(store, qtext)


def test_group_count_semantics():
    store = _small_store()
    for qtext in (
        "SELECT ?s (COUNT(?o) AS ?n) WHERE { ?s ?p ?o } GROUP BY ?s",
        "SELECT ?p (COUNT(*) AS ?n) WHERE { ?s ?p ?o } GROUP BY ?p "
        "ORDER BY DESC(?n)",
        # COUNT(?v) counts only bound rows (OPTIONAL misses don't count)
        "SELECT ?s (COUNT(?h) AS ?n) WHERE { ?s <http://ex/p> ?v "
        "OPTIONAL { ?s <http://ex/q> ?h } } GROUP BY ?s",
        # global aggregate: one row even over zero solutions
        "SELECT (COUNT(*) AS ?n) WHERE { ?s <http://ex/p> ?v }",
        "SELECT (COUNT(*) AS ?n) WHERE { ?s <http://ex/none> ?v }",
        # GROUP BY without COUNT = distinct keys
        "SELECT ?p WHERE { ?s ?p ?o } GROUP BY ?p",
        # grouping keys not selected still partition the groups
        "SELECT (COUNT(*) AS ?n) WHERE { ?s ?p ?o } GROUP BY ?s ?p",
        # aggregation over a union, ordered by the count
        "SELECT ?s (COUNT(*) AS ?n) WHERE { { ?s <http://ex/p> ?v } UNION "
        "{ ?s <http://ex/q> ?v } } GROUP BY ?s ORDER BY DESC(?n) ?s LIMIT 2",
    ):
        check(store, qtext)
    # counts arrive as plain ints and are flagged on the result
    q = parse_select(
        "SELECT ?s (COUNT(?o) AS ?n) WHERE { ?s ?p ?o } GROUP BY ?s"
    )
    res = solve_select(store, q)
    assert res.agg_vars == ("?n",)
    assert all(isinstance(r[1], int) for r in res.rows(0))
    assert sum(r[1] for r in res.rows(0)) == store.n_triples


def test_from_ntriples_template_chars():
    store = TripleStore.from_ntriples(
        [("<http://ex/s>", "<http://ex/p>", '"braces {} inside"')]
    )
    assert list(store.iter_ntriples()) == [
        '<http://ex/s> <http://ex/p> "braces {} inside" .'
    ]
    check(store, "?s ?p ?o")


# --------------------------------------------------------------------------
# property tests vs the oracle on random graphs
# --------------------------------------------------------------------------

TEMPLATES = [
    lambda p, o, x: "?s ?p ?o",
    lambda p, o, x: f"?s {p[0]} ?o",
    lambda p, o, x: f"?s {p[0]} {o[0]}",
    lambda p, o, x: f"?s {p[0]} ?o . ?o {p[1]} ?r",          # chain
    lambda p, o, x: f"?s {p[0]} ?o . ?s {p[1]} ?r",          # star
    lambda p, o, x: "?x ?p ?x",                               # repeated var
    lambda p, o, x: (
        f"SELECT ?s WHERE {{ ?s {p[0]} ?o OPTIONAL {{ ?s {p[1]} ?r }} }}"
    ),
    lambda p, o, x: (
        f"SELECT * WHERE {{ ?s {p[0]} ?o OPTIONAL {{ ?s {p[1]} ?r }} "
        f"FILTER(?o > {x}) }}"
    ),
    lambda p, o, x: "SELECT DISTINCT ?o WHERE { ?s ?p ?o } LIMIT 3",
    lambda p, o, x: (
        f"SELECT * WHERE {{ ?s {p[0]} ?o . ?s {p[1]} ?r FILTER(?o < ?r) }}"
    ),
    lambda p, o, x: (
        f'SELECT * WHERE {{ ?s {p[0]} ?o '
        f'FILTER(?o >= "a" || ?o = {o[0]}) }}'
    ),
    # --- UNION arms ---
    lambda p, o, x: (
        f"SELECT * WHERE {{ {{ ?s {p[0]} ?o }} UNION {{ ?s {p[1]} ?o }} }}"
    ),
    lambda p, o, x: (  # partial-arm variables; empty arm when o[0] rare
        f"SELECT * WHERE {{ {{ ?s {p[0]} {o[0]} }} UNION "
        f"{{ ?s {p[1]} ?r }} }}"
    ),
    lambda p, o, x: (  # union joined with a required pattern + filter
        f"SELECT * WHERE {{ ?s {p[0]} ?o "
        f"{{ ?s {p[1]} ?r }} UNION {{ ?o {p[1]} ?r }} "
        f"FILTER(?o != {o[0]}) }}"
    ),
    # --- ORDER BY keys ---
    lambda p, o, x: (
        f"SELECT ?s ?o WHERE {{ ?s {p[0]} ?o }} ORDER BY DESC(?o) LIMIT 4"
    ),
    lambda p, o, x: (
        f"SELECT ?s ?o WHERE {{ ?s {p[0]} ?o }} ORDER BY ?o ?s"
    ),
    lambda p, o, x: (  # order over an optional (maybe-unbound) column
        f"SELECT ?o ?r WHERE {{ ?s {p[0]} ?o "
        f"OPTIONAL {{ ?s {p[1]} ?r }} }} ORDER BY DESC(?r) ?o LIMIT 5"
    ),
    # --- GROUP BY / COUNT ---
    lambda p, o, x: (
        "SELECT ?s (COUNT(?o) AS ?n) WHERE { ?s ?p ?o } GROUP BY ?s "
        "ORDER BY DESC(?n) ?s"
    ),
    lambda p, o, x: f"SELECT (COUNT(*) AS ?n) WHERE {{ ?s {p[0]} ?o }}",
    lambda p, o, x: (  # count a maybe-unbound variable per group
        f"SELECT ?o (COUNT(?r) AS ?n) WHERE {{ ?s {p[0]} ?o "
        f"OPTIONAL {{ ?s {p[1]} ?r }} }} GROUP BY ?o"
    ),
    lambda p, o, x: (  # aggregate over a union
        f"SELECT ?s (COUNT(*) AS ?n) WHERE {{ {{ ?s {p[0]} ?o }} UNION "
        f"{{ ?s {p[1]} ?o }} }} GROUP BY ?s ORDER BY DESC(?n) LIMIT 3"
    ),
]


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    n=st.integers(0, 25),
    t=st.integers(0, len(TEMPLATES) - 1),
)
def test_engine_matches_oracle_on_random_graphs(seed, n, t):
    rng = np.random.default_rng(seed + 1)
    store = rand_store(seed, n)
    p = [PREDS[rng.integers(0, len(PREDS))] for _ in range(2)]
    o = [OBJS[rng.integers(0, len(OBJS))] for _ in range(1)]
    x = ["-3", "1", "2.5", "100"][rng.integers(0, 4)]
    check(store, TEMPLATES[t](p, o, x))


def test_empty_graph_edge_cases():
    store = TripleStore.from_ntriples([])
    assert store.n_triples == 0
    check(store, "?s ?p ?o")
    check(
        store,
        "SELECT * WHERE { ?s <http://ex/p> ?o "
        "OPTIONAL { ?s <http://ex/q> ?h } FILTER(?o > 1) }",
    )
    # the new algebra over nothing: unions and keyed groups answer zero
    # rows, a global COUNT answers exactly one zero row
    check(
        store,
        "SELECT * WHERE { { ?s <http://ex/p> ?o } UNION "
        "{ ?s <http://ex/q> ?o } }",
    )
    check(store, "SELECT ?o WHERE { ?s ?p ?o } ORDER BY DESC(?o)")
    check(store, "SELECT ?p (COUNT(*) AS ?n) WHERE { ?s ?p ?o } GROUP BY ?p")
    check(store, "SELECT (COUNT(*) AS ?n) WHERE { ?s ?p ?o }")
    assert oracle_select(
        store, parse_select("SELECT (COUNT(*) AS ?n) WHERE { ?s ?p ?o }")
    ) == [(0,)]
    check(store, "SELECT (COUNT(*) AS ?n) WHERE { ?s ?p ?o } LIMIT 0")


def test_all_unbound_scan_matches_oracle():
    store = rand_store(3, 20)
    check(store, "?s ?p ?o")
    check(store, "SELECT DISTINCT ?p WHERE { ?s ?p ?o }")


def test_unpacked_search_fallback_matches_oracle(monkeypatch):
    """Stores whose term ids overflow the packed key fields fall back to
    the 3-column lexicographic search — force that path and recheck."""
    monkeypatch.setattr(TripleStore, "device_keys", lambda self, order: None)
    store = rand_store(9, 22)
    assert store.device_keys("spo") is None
    for qtext in (
        "?s ?p ?o",
        f"?s {PREDS[0]} ?o . ?s {PREDS[1]} ?r",
        f"SELECT * WHERE {{ ?s {PREDS[0]} ?o "
        f"OPTIONAL {{ ?s {PREDS[1]} ?r }} FILTER(?o != \"zz\") }}",
    ):
        check(store, qtext)


# --------------------------------------------------------------------------
# deterministic ordering (satellite regression)
# --------------------------------------------------------------------------


def test_results_deterministically_ordered(tmp_path):
    """Row order is sorted by term id == rendered term, so repeated runs,
    eager-vs-streamed stores, and .kgz roundtrips return identical rows in
    identical order."""
    tb = generator.make_testbed("SOM", 400, 0.5, n_poms=2, seed=11)
    eager = create_kg(tb.doc, tables=_tables(tb)).to_store()
    streamed = create_kg(
        tb.doc, tables=_tables(tb), stream=True, block_rows=64
    ).to_store()
    path = str(tmp_path / "kg.kgz")
    persist.save(eager, path)
    loaded = persist.load(path)
    preds = sorted({eager.decode_term(int(t)) for t in np.unique(eager.p)})
    queries = [
        "?s ?p ?o",
        f"?m {preds[0]} ?a . ?m {preds[-1]} ?b",
        f"SELECT ?a WHERE {{ ?m {preds[0]} ?a OPTIONAL {{ ?m {preds[1]} ?b }} }}",
    ]
    for qtext in queries:
        q = parse_select(qtext)
        first = solve_select(eager, q).rows(0)
        assert first == sorted(first), "rows must come back sorted"
        assert first == solve_select(eager, q).rows(0)  # repeatable
        assert first == solve_select(streamed, q).rows(0)
        assert first == solve_select(loaded, q).rows(0)
    # the kg BGP path inherits the ordering (sorted by term id per column)
    pats = parse_bgp(queries[1])
    b = solve(eager, pats)
    first_var = next(iter(b.cols))
    col = b.cols[first_var]
    assert (np.diff(col) >= 0).all()


# --------------------------------------------------------------------------
# executor capacity feedback + batching
# --------------------------------------------------------------------------


def test_capacity_feedback_grows_to_exact_need():
    """Plan from a selective representative, then execute a batch whose
    other member needs far more rows: the needed-size feedback must grow
    the capacities and still return exact answers."""
    triples = [("<http://ex/a>", "<http://ex/rare>", '"x"')]
    triples += [
        (f"<http://ex/s{i}>", "<http://ex/common>", f'"{i}"')
        for i in range(150)
    ]
    store = TripleStore.from_ntriples(triples)
    qa = parse_select("?s <http://ex/rare> ?o")
    qb = parse_select("?s <http://ex/common> ?o")
    assert qa.signature() == qb.signature()
    ex = get_executor(store)
    plan = ex.plan(qa)  # est comes from the 1-row representative
    before = ex.dispatches
    res = ex.execute(plan, [qa, qb])
    assert ex.dispatches - before >= 2  # at least one re-dispatch to grow
    assert res.n(0) == 1 and res.n(1) == 150
    assert res.rows(1) == oracle_select(store, qb)
    # capacities are remembered per signature: the rerun is one dispatch
    before = ex.dispatches
    res2 = ex.execute(plan, [qa, qb])
    assert ex.dispatches - before == 1
    assert res2.rows(1) == res.rows(1)


def test_limit_value_is_runtime_data_not_plan_structure():
    """Different LIMIT values share one signature (one compiled pipeline,
    one server micro-batch group); the limit applies per query."""
    store = rand_store(21, 25)
    q2 = parse_select("SELECT ?o WHERE { ?s ?p ?o } LIMIT 2")
    q5 = parse_select("SELECT ?o WHERE { ?s ?p ?o } LIMIT 5")
    q0 = parse_select("SELECT ?o WHERE { ?s ?p ?o } LIMIT 0")
    assert q2.signature() == q5.signature() == q0.signature()
    assert q2.signature() != parse_select("SELECT ?o WHERE { ?s ?p ?o }").signature()
    ex = get_executor(store)
    res = ex.execute(ex.plan(q2), [q2, q5, q0])
    assert res.rows(0) == oracle_select(store, q2)
    assert res.rows(1) == oracle_select(store, q5)
    assert res.n(2) == 0


def test_batched_queries_match_individual():
    store = rand_store(17, 24)
    ex = get_executor(store)
    texts = [f"?s {p} ?o" for p in PREDS for _ in range(3)]
    queries = [parse_select(t) for t in texts]
    plan = ex.plan(queries[0])
    res = ex.execute(plan, queries)
    for i, q in enumerate(queries):
        assert res.rows(i) == oracle_select(store, q)


def test_new_operators_run_fused_batches():
    """UNION / ORDER BY / GROUP BY-COUNT queries with equal signatures run
    as ONE batched device dispatch (the server's micro-batch unit) — no
    per-query host fallback — and still match the oracle per query."""
    store = rand_store(23, 25)
    ex = get_executor(store)
    for template in (
        "SELECT * WHERE {{ {{ ?s {a} ?o }} UNION {{ ?s {b} ?o }} }}",
        "SELECT ?s ?o WHERE {{ ?s {a} ?o }} ORDER BY DESC(?o) LIMIT 3",
        "SELECT ?o (COUNT(?s) AS ?n) WHERE {{ ?s {a} ?o }} GROUP BY ?o "
        "ORDER BY DESC(?n)",
    ):
        queries = [
            parse_select(template.format(a=a, b=b))
            for a in PREDS
            for b in PREDS
        ][:6]
        sig = queries[0].signature()
        assert all(q.signature() == sig for q in queries)
        plan = ex.plan(queries[0])
        ex.execute(plan, queries)  # warm: compile + capacity convergence
        before = ex.dispatches
        res = ex.execute(plan, queries)
        assert ex.dispatches - before == 1, "batch must be one fused dispatch"
        for i, q in enumerate(queries):
            assert res.rows(i) == oracle_select(store, q), template


def test_new_operators_survive_store_roundtrips(tmp_path):
    """UNION / ORDER BY / COUNT answers (and their order) are identical
    across the eager store, a streamed-ingestion store, and a .kgz
    save/load roundtrip — term ids are ranks of rendered terms."""
    tb = generator.make_testbed("SOM", 300, 0.5, n_poms=2, seed=13)
    eager = create_kg(tb.doc, tables=_tables(tb)).to_store()
    streamed = create_kg(
        tb.doc, tables=_tables(tb), stream=True, block_rows=64
    ).to_store()
    path = str(tmp_path / "kg.kgz")
    persist.save(eager, path)
    loaded = persist.load(path)
    preds = sorted({eager.decode_term(int(t)) for t in np.unique(eager.p)})
    queries = [
        f"SELECT * WHERE {{ {{ ?m {preds[0]} ?x }} UNION "
        f"{{ ?m {preds[1]} ?x }} }}",
        f"SELECT ?m ?x WHERE {{ ?m {preds[0]} ?x }} ORDER BY DESC(?x) LIMIT 7",
        f"SELECT ?x (COUNT(?m) AS ?n) WHERE {{ ?m {preds[0]} ?x }} "
        "GROUP BY ?x ORDER BY DESC(?n) ?x",
    ]
    for qtext in queries:
        q = parse_select(qtext)
        want = oracle_select(eager, q)
        assert solve_select(eager, q).rows(0) == want
        assert solve_select(streamed, q).rows(0) == want
        assert solve_select(loaded, q).rows(0) == want


# --------------------------------------------------------------------------
# open_store cache
# --------------------------------------------------------------------------


def test_open_store_caches_until_file_changes(tmp_path):
    store = rand_store(5, 12)
    path = str(tmp_path / "kg.kgz")
    persist.save(store, path)
    a = persist.open_store(path)
    assert persist.open_store(path) is a
    # a rewritten snapshot (different content) must reload
    persist.save(rand_store(6, 18), path)
    b = persist.open_store(path)
    assert b is not a and b.n_triples != a.n_triples


# --------------------------------------------------------------------------
# the batching server
# --------------------------------------------------------------------------


def test_server_end_to_end():
    from repro.serve.client import connect
    from repro.serve.server import KGServer

    store = _small_store()
    srv = KGServer(store, port=0, linger_ms=1.0, log=False).start()
    try:
        with connect("127.0.0.1", srv.port, retry_s=5.0) as c:
            assert c.ping()
            r = c.query("?s <http://ex/p> ?v")
            assert r["vars"] == ["?s", "?v"]
            want = oracle_select(store, parse_select("?s <http://ex/p> ?v"))
            assert [tuple(x) for x in r["rows"]] == want
            # per-request decode limit does not change n_total
            r2 = c.query("?s <http://ex/p> ?v", limit=1)
            assert len(r2["rows"]) == 1 and r2["n_total"] == len(want)
            # OPTIONAL misses arrive as nulls on the wire
            r3 = c.query(
                "SELECT * WHERE { ?s <http://ex/p> ?v "
                "OPTIONAL { ?s <http://ex/q> ?h } }"
            )
            assert any(row[2] is None for row in r3["rows"])
            # parse errors come back as error replies, not dead sockets
            with pytest.raises(RuntimeError, match="server error"):
                c.query("SELECT WHERE {")
            assert c.ping()  # connection still alive
            # ...and so does a malformed 'limit' field
            with pytest.raises(RuntimeError, match="limit"):
                c.query("?s <http://ex/p> ?v", limit="abc")
            assert c.ping()
            assert "Scan" in c.explain("?s <http://ex/p> ?v")

        # concurrent same-shape clients: all answered correctly, batched
        results = []
        lock = threading.Lock()

        def hit():
            with connect("127.0.0.1", srv.port, retry_s=5.0) as cc:
                r = cc.query("?s <http://ex/p> ?v")
                with lock:
                    results.append(r)

        threads = [threading.Thread(target=hit) for _ in range(12)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(results) == 12
        assert all(
            [tuple(x) for x in r["rows"]] == want for r in results
        )
        with connect("127.0.0.1", srv.port) as c:
            stats = c.stats()
            assert stats["queries"] >= 13 and stats["errors"] >= 1
    finally:
        srv.stop()


def test_server_union_and_aggregate_wire_answers():
    """UNION rows and COUNT aggregates decode over the wire: counts are
    JSON numbers and the answer names its aggregate columns."""
    from repro.serve.client import connect
    from repro.serve.server import KGServer

    store = _small_store()
    srv = KGServer(store, port=0, linger_ms=1.0, log=False).start()
    try:
        with connect("127.0.0.1", srv.port, retry_s=5.0) as c:
            u = c.query(
                "SELECT * WHERE { { ?s <http://ex/p> ?v } UNION "
                "{ ?s <http://ex/q> ?v } }"
            )
            want = oracle_select(
                store,
                parse_select(
                    "SELECT * WHERE { { ?s <http://ex/p> ?v } UNION "
                    "{ ?s <http://ex/q> ?v } }"
                ),
            )
            assert [tuple(r) for r in u["rows"]] == want
            assert "agg_vars" not in u
            g = c.query(
                "SELECT ?s (COUNT(?o) AS ?n) WHERE { ?s ?p ?o } "
                "GROUP BY ?s ORDER BY DESC(?n)"
            )
            assert g["vars"] == ["?s", "?n"] and g["agg_vars"] == ["?n"]
            assert all(isinstance(row[1], int) for row in g["rows"])
            assert sum(row[1] for row in g["rows"]) == store.n_triples
            ns = [row[1] for row in g["rows"]]
            assert ns == sorted(ns, reverse=True)
    finally:
        srv.stop()


def test_server_caps_undeclared_row_decode():
    """Without a request limit the server decodes at most max_rows rows
    (protecting the dispatcher thread) while n_total stays exact."""
    from repro.serve.client import connect
    from repro.serve.server import KGServer

    store = _small_store()
    srv = KGServer(store, port=0, max_rows=2, log=False).start()
    try:
        with connect("127.0.0.1", srv.port, retry_s=5.0) as c:
            r = c.query("?s ?p ?o")
            assert len(r["rows"]) == 2 and r["n_total"] == store.n_triples
            # an explicit limit overrides the cap
            r2 = c.query("?s ?p ?o", limit=4)
            assert len(r2["rows"]) == 4
    finally:
        srv.stop()


def test_server_wire_protocol_raw_socket():
    """The protocol is plain NDJSON — speak it without the client class."""
    import socket as socketlib

    from repro.serve.server import KGServer

    store = _small_store()
    srv = KGServer(store, port=0, log=False).start()
    try:
        with socketlib.create_connection(("127.0.0.1", srv.port), 10) as s:
            f = s.makefile("r", encoding="utf-8")
            s.sendall(b"not json\n")
            assert "error" in json.loads(f.readline())
            s.sendall(
                json.dumps({"id": 42, "query": "?s <http://ex/q> ?h"}).encode()
                + b"\n"
            )
            resp = json.loads(f.readline())
            assert resp["id"] == 42
            assert resp["rows"] == [["<http://ex/s1>", '"hi"']]
    finally:
        srv.stop()

def test_server_metrics_wire_op():
    """The metrics op returns the registry snapshot: request/queue-wait/
    exec latency histograms plus per-signature histograms labeled with an
    example query text."""
    from repro.obs import MetricsRegistry
    from repro.serve.client import connect
    from repro.serve.server import KGServer

    store = _small_store()
    reg = MetricsRegistry()
    srv = KGServer(store, port=0, linger_ms=1.0, log=False,
                   registry=reg).start()
    try:
        with connect("127.0.0.1", srv.port, retry_s=5.0) as c:
            for _ in range(3):
                c.query("?s <http://ex/p> ?v")
            c.query("?s <http://ex/q> ?h")
            m = c.metrics()
            hists = m["metrics"]["histograms"]
            counters = m["metrics"]["counters"]
            assert counters["serve.queries"] == 4
            assert hists["serve.request_ms"]["count"] == 4
            assert hists["serve.queue_wait_ms"]["count"] == 4
            assert hists["serve.exec_ms"]["count"] >= 2
            assert hists["serve.request_ms"]["p50"] is not None
            assert hists["serve.request_ms"]["p99"] is not None
            # two distinct plan signatures, each with an example text
            sig_hists = {
                k for k in hists if k.startswith("serve.exec_ms.sig=")
            }
            assert len(sig_hists) == 2
            labels = {k.rsplit("=", 1)[-1] for k in sig_hists}
            assert labels == set(m["signatures"])
            assert any(
                "<http://ex/p>" in v for v in m["signatures"].values()
            )
            # the stats op reads the same registry: mutually consistent
            stats = c.stats()
            assert stats["queries"] == 4 and stats["errors"] == 0
    finally:
        srv.stop()


def test_server_concurrent_clients_exact_counts():
    """Regression for the old unlocked ServerStats: with the accept /
    client / dispatch threads all mutating counters, totals must still be
    exact under concurrency (the racy += used to drop increments)."""
    from repro.obs import MetricsRegistry
    from repro.serve.client import connect
    from repro.serve.server import KGServer

    store = _small_store()
    reg = MetricsRegistry()
    srv = KGServer(store, port=0, linger_ms=1.0, log=False,
                   registry=reg).start()
    n_threads, n_queries = 8, 6
    queries = ["?s <http://ex/p> ?v", "?s <http://ex/q> ?h", "?s ?p ?o"]
    errors = []
    lock = threading.Lock()

    def hit(i: int) -> None:
        try:
            with connect("127.0.0.1", srv.port, retry_s=5.0) as c:
                for j in range(n_queries):
                    c.query(queries[(i + j) % len(queries)])
                # one malformed query per client: error counters race too
                with pytest.raises(RuntimeError):
                    c.query("SELECT WHERE {")
        except Exception as e:  # noqa: BLE001 — surface in the main thread
            with lock:
                errors.append(e)

    try:
        threads = [
            threading.Thread(target=hit, args=(i,)) for i in range(n_threads)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        snap = reg.snapshot()
        assert snap["counters"]["serve.queries"] == n_threads * n_queries
        assert snap["counters"]["serve.errors"] == n_threads
        # per-request histograms observed exactly once per answered query
        assert (
            snap["histograms"]["serve.request_ms"]["count"]
            == n_threads * n_queries
        )
        assert (
            snap["histograms"]["serve.queue_wait_ms"]["count"]
            == n_threads * n_queries
        )
        # batch accounting stays consistent: queries partition into batches
        assert 1 <= snap["counters"]["serve.batches"] <= n_threads * n_queries
        assert snap["gauges"]["serve.busiest_batch"] >= 1
    finally:
        srv.stop()
