"""SO(3) substrate validation: the invariants every equivariant model needs.

* real SH orthonormality on the sphere (Monte Carlo),
* SH equivariance  Y(Rv) = D(R) Y(v),
* Wigner-D homomorphism and orthogonality (recursion vs products),
* CG contraction equivariance  W(D1 x, D2 y) = D3 W(x, y),
* frame alignment  R(v) v_hat = z_hat.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.models.gnn import so3


@pytest.fixture(scope="module")
def rot():
    rng = np.random.default_rng(0)
    return so3._rand_rot(rng), so3._rand_rot(rng), rng


def test_sph_harm_orthonormal():
    rng = np.random.default_rng(1)
    v = rng.normal(size=(200000, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    Y = np.asarray(so3.sph_harm(4, jnp.asarray(v, jnp.float32)))
    G = (Y.T @ Y) / len(v) * 4 * np.pi
    assert np.abs(G - np.eye(G.shape[0])).max() < 0.06  # MC noise ~1/sqrt(N)


def test_sph_harm_equivariance(rot):
    R, _, rng = rot
    v = rng.normal(size=(256, 3)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    Y0 = np.asarray(so3.sph_harm(4, jnp.asarray(v)))
    YR = np.asarray(so3.sph_harm(4, jnp.asarray((v @ R.T).astype(np.float32))))
    Ds = [np.asarray(d[0]) for d in so3.wigner_d_from_rot(4, jnp.asarray(R[None], jnp.float32))]
    for l in range(5):
        sl = slice(l * l, (l + 1) * (l + 1))
        assert np.abs(YR[:, sl] - Y0[:, sl] @ Ds[l].T).max() < 2e-3


def test_wigner_homomorphism(rot):
    R1, R2, _ = rot
    Da = so3.wigner_d_from_rot(6, jnp.asarray((R1 @ R2)[None], jnp.float32))
    D1 = so3.wigner_d_from_rot(6, jnp.asarray(R1[None], jnp.float32))
    D2 = so3.wigner_d_from_rot(6, jnp.asarray(R2[None], jnp.float32))
    for l in range(7):
        prod = np.asarray(D1[l][0]) @ np.asarray(D2[l][0])
        assert np.abs(prod - np.asarray(Da[l][0])).max() < 1e-3, l
        orth = np.asarray(D1[l][0]) @ np.asarray(D1[l][0]).T
        assert np.abs(orth - np.eye(2 * l + 1)).max() < 1e-3, l


@pytest.mark.parametrize(
    "l1,l2,l3",
    [(1, 1, 0), (1, 1, 1), (1, 1, 2), (2, 1, 1), (2, 1, 2), (2, 2, 2),
     (2, 2, 0), (3, 1, 4), (5, 2, 4), (6, 1, 6)],
)
def test_cg_contraction_equivariance(l1, l2, l3, rot):
    R, _, rng = rot
    W = so3.real_cg(l1, l2, l3)
    assert np.abs(W).max() > 0
    lmax = max(l1, l2, l3)
    Ds = [np.asarray(d[0]) for d in so3.wigner_d_from_rot(lmax, jnp.asarray(R[None], jnp.float32))]
    x = rng.normal(size=2 * l1 + 1)
    y = rng.normal(size=2 * l2 + 1)
    m0 = np.einsum("abc,a,b->c", W, x, y)
    m1 = np.einsum("abc,a,b->c", W, Ds[l1] @ x, Ds[l2] @ y)
    assert np.abs(m1 - Ds[l3] @ m0).max() < 1e-3 * max(np.abs(m0).max(), 1.0)


def test_cg_triangle_rule():
    assert np.abs(so3.real_cg(1, 1, 3)).max() == 0


def test_rot_to_align_z(rot):
    _, _, rng = rot
    v = rng.normal(size=(128, 3)).astype(np.float32)
    R = np.asarray(so3.rot_to_align_z(jnp.asarray(v)))
    vhat = v / np.linalg.norm(v, axis=1, keepdims=True)
    out = np.einsum("nij,nj->ni", R, vhat)
    assert np.abs(out - np.array([0.0, 0.0, 1.0])).max() < 1e-4
    # orthonormal frames
    assert np.abs(R @ np.transpose(R, (0, 2, 1)) - np.eye(3)).max() < 1e-4
